"""AOT lowering: every (op, tier) pair of the L2 model to HLO *text*
artifacts the Rust runtime loads through PJRT.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--tiers 8192,...]

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Node-count tiers: every Table-III dataset analogue is generated at one
# of these sizes (rust/src/gen/registry.rs must stay in sync).
TIERS = [8192, 16384, 32768, 65536]
FDIM = 64  # feature/hidden width
CDIM = 16  # classes
TOPK = 8  # pruning k


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def ops_for_tier(n):
    """(name, fn, example_args) for every artifact at tier `n`."""
    k = TOPK
    return [
        ("topk_mask", functools.partial(model.topk_sparsify, k=k), (f32(n, FDIM),)),
        ("layer_fwd", model.layer_fwd, (f32(n, FDIM), f32(FDIM, FDIM))),
        (
            "layer_bwd",
            model.layer_bwd,
            (f32(n, FDIM), f32(n, FDIM), f32(n, FDIM), f32(FDIM, FDIM)),
        ),
        ("out_fwd", model.out_fwd, (f32(n, FDIM), f32(FDIM, CDIM))),
        ("out_bwd", model.out_bwd, (f32(n, FDIM), f32(n, CDIM), f32(FDIM, CDIM))),
        ("loss_grad", model.loss_grad, (f32(n, CDIM), f32(n, CDIM))),
        (
            "sage_fwd",
            model.sage_fwd,
            (f32(n, FDIM), f32(n, FDIM), f32(FDIM, FDIM), f32(FDIM, FDIM)),
        ),
        (
            "sage_bwd",
            model.sage_bwd,
            (
                f32(n, FDIM),
                f32(n, FDIM),
                f32(n, FDIM),
                f32(n, FDIM),
                f32(FDIM, FDIM),
                f32(FDIM, FDIM),
            ),
        ),
    ]


def lower_one(fn, args):
    # Wrap so every artifact returns a tuple (rust side uses to_tuple()).
    def tupled(*xs):
        out = fn(*xs)
        return out if isinstance(out, tuple) else (out,)

    return to_hlo_text(jax.jit(tupled).lower(*args))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--tiers", default=",".join(str(t) for t in TIERS))
    args = p.parse_args()
    tiers = [int(t) for t in args.tiers.split(",") if t]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"fdim": FDIM, "cdim": CDIM, "topk": TOPK, "tiers": tiers, "artifacts": []}
    for n in tiers:
        for name, fn, ex in ops_for_tier(n):
            fname = f"{name}_n{n}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            text = lower_one(fn, ex)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "op": name,
                    "tier": n,
                    "file": fname,
                    "arg_shapes": [list(a.shape) for a in ex],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
