"""L2: the GNN dense compute graph in JAX, built on the L1 Pallas
kernels (topk / matmul). These functions are AOT-lowered per node-count
tier by `aot.py`; the Rust coordinator chains them with its own SpGEMM
aggregation (the paper's hybrid: sparse aggregation on the AIA-equipped
engine, dense transform on the matrix units).

Forward per layer (paper Eq. 1):  X_l = Â · TopK(X_{l-1}, k) · W_l
Backward        (paper Eq. 3):    ∂X_{l-1} = M_k ⊙ (Âᵀ · ∂Z_l · W_lᵀ)

The Â products happen in Rust; everything else is here.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.topk import topk_mask


# ---------------------------------------------------------------- layers
def layer_fwd(h, w):
    """Hidden layer: act = relu(h @ w); also emits the relu gate for the
    backward pass. h: [n, d], w: [d, d']."""
    z = matmul(h, w)
    return jnp.maximum(z, 0.0), (z > 0.0).astype(h.dtype)


def layer_bwd(h, d_out, gate, w):
    """Backward of `layer_fwd` given upstream grad `d_out` (w.r.t. the
    activation): returns (dW, dH)."""
    dz = d_out * gate
    dw = jnp.dot(h.T, dz, preferred_element_type=jnp.float32)
    dh = matmul(dz, w.T)
    return dw, dh


def out_fwd(h, w):
    """Output layer (no activation): logits = h @ w. w: [d, c]."""
    return matmul(h, w)


def out_bwd(h, dlogits, w):
    dw = jnp.dot(h.T, dlogits, preferred_element_type=jnp.float32)
    dh = matmul(dlogits, w.T)
    return dw, dh


def sage_fwd(h_self, h_neigh, w_self, w_neigh):
    """GraphSAGE layer: act = relu(h_self·W_s + h_neigh·W_n) + gate."""
    z = matmul(h_self, w_self) + matmul(h_neigh, w_neigh)
    return jnp.maximum(z, 0.0), (z > 0.0).astype(h_self.dtype)


def sage_bwd(h_self, h_neigh, d_out, gate, w_self, w_neigh):
    dz = d_out * gate
    dws = jnp.dot(h_self.T, dz, preferred_element_type=jnp.float32)
    dwn = jnp.dot(h_neigh.T, dz, preferred_element_type=jnp.float32)
    dh_self = matmul(dz, w_self.T)
    dh_neigh = matmul(dz, w_neigh.T)
    return dws, dwn, dh_self, dh_neigh


# ------------------------------------------------------------------ loss
def loss_grad(logits, y_onehot):
    """Mean softmax cross-entropy and its gradient w.r.t. logits."""
    logits = logits - jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    n = logits.shape[0]
    loss = -jnp.sum(y_onehot * logits) / n
    dlogits = (jnp.exp(logits) - y_onehot) / n
    return loss, dlogits


# --------------------------------------------------------------- pruning
def topk_sparsify(x, k):
    """The pruning layer (Eq. 2) as used on the forward path: the Rust
    side converts the masked output to CSR for the SpGEMM aggregation."""
    return topk_mask(x, k)


# ------------------------------------------------- full-jax training ref
def gcn_forward_ref(a_dense, x, ws, k):
    """Pure-JAX reference of the full GCN forward (dense Â) used by
    pytest to validate the artifact decomposition end-to-end."""
    h = x
    for w in ws[:-1]:
        hp = topk_mask(h, k)
        agg = a_dense @ hp
        h, _gate = layer_fwd(agg, w)
    hp = topk_mask(h, k)
    agg = a_dense @ hp
    return out_fwd(agg, ws[-1])
