"""Pallas kernel: MXU-tiled dense matmul for the GNN feature transform.

GPU→TPU adaptation: the paper's dense feature transform would use
tensor-core WMMA tiles staged through shared memory; here each grid step
owns a [BLOCK_M × K] × [K × N] product sized for the 128×128 MXU
systolic array, with the whole K dimension resident in VMEM (K = 64 for
every GNN layer in the reproduction, so no K-loop/accumulator pipeline
is needed — one MXU pass per tile).

interpret=True for CPU-PJRT executability; see topk.py's note.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped row tile. VMEM per step: 128·K·4 + K·N·4 + 128·N·4 bytes —
# 96 KiB at K=N=64.
BLOCK_M = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32).astype(o_ref.dtype)


@jax.jit
def matmul(x, w):
    """`x @ w` with f32 accumulation. x: [n, k], w: [k, m]."""
    n, k = x.shape
    k2, m = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    block = min(BLOCK_M, n)
    assert n % block == 0, f"n={n} must tile by {block}"
    return pl.pallas_call(
        _matmul_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x, w)


@functools.partial(jax.jit, static_argnames=())
def matmul_relu_gate(x, w):
    """Fused `relu(x @ w)` plus the relu gate (for backprop) — the form
    the GNN layer artifacts use so XLA keeps everything in one pass."""
    z = matmul(x, w)
    return jnp.maximum(z, 0.0), (z > 0.0).astype(x.dtype)
