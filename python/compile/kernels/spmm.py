"""Pallas kernel: gather-SpMM over a padded-ELL neighbour list — the
TPU expression of the paper's AIA ranged-indirect access.

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's AIA
engine turns `x[a[b[i]] .. a[b[i]]+R-1]` into one bulk descriptor that a
near-HBM engine resolves into a sequential stream. The TPU analogue is a
*data-dependent block schedule*: the neighbour indices live in a dense
[n × m] ELL tile, and the kernel's index map walks row blocks while the
feature table is gathered per block — the BlockSpec plays the role of
the AIA descriptor (what to fetch, at what granularity) and the compiler
pipelines HBM→VMEM copies the way AIA pipelines stack-local gathers.

interpret=True; correctness vs `ref.spmm_gather_ref`, and the runtime
aggregation path in Rust is the hash-SpGEMM engine (this kernel is the
kernel-level demonstrator + the L2 building block for dense tiers).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _spmm_kernel(idx_ref, w_ref, x_ref, o_ref):
    idx = idx_ref[...]  # [b, m] int32
    w = w_ref[...]  # [b, m]
    x = x_ref[...]  # [nsrc, d] (full table resident; see module docstring)
    gathered = jnp.take(x, idx, axis=0)  # [b, m, d]
    o_ref[...] = jnp.einsum("nm,nmd->nd", w, gathered).astype(o_ref.dtype)


@jax.jit
def spmm_gather(idx, w, x):
    """out[i] = Σ_j w[i,j] · x[idx[i,j]].

    idx: [n, m] int32 (padding rows allowed, weight 0), w: [n, m] f32,
    x: [nsrc, d] f32.
    """
    n, m = idx.shape
    nsrc, d = x.shape
    block = min(BLOCK_ROWS, n)
    assert n % block == 0, f"n={n} must tile by {block}"
    return pl.pallas_call(
        _spmm_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, m), lambda i: (i, 0)),
            pl.BlockSpec((block, m), lambda i: (i, 0)),
            pl.BlockSpec((nsrc, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(idx, w, x)
