"""Pallas kernel: per-row top-k masking (the paper's pruning layer,
Eq. 1–2).

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper fuses a
top-k selection in front of the SpMM so the feature matrix becomes
sparse. On TPU the natural unit is a VMEM-resident row block — each grid
step sorts its block's rows in-register/VMEM, derives the per-row k-th
value, and masks. No shared-memory reductions (GPU idiom); the 8×128
vector lanes handle the row dimension.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against `ref.topk_mask_ref` and
real-TPU perf is estimated from the VMEM footprint (see DESIGN.md §7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 256 rows × 64 features × 4 B = 64 KiB in, the sort
# scratch doubles it — comfortably inside a 16 MiB VMEM budget.
BLOCK_ROWS = 256


def _topk_kernel(x_ref, o_ref, *, k):
    x = x_ref[...]
    sorted_desc = jnp.sort(x, axis=-1)[:, ::-1]
    thresh = sorted_desc[:, k - 1]
    mask = x >= thresh[:, None]
    o_ref[...] = jnp.where(mask, x, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("k",))
def topk_mask(x, k):
    """`TopK(x, k)` per row: zero everything below the k-th largest.

    x: [n, d] float32 with n a multiple of BLOCK_ROWS or smaller.
    """
    n, d = x.shape
    if k >= d:
        return x
    block = min(BLOCK_ROWS, n)
    assert n % block == 0, f"n={n} must tile by {block}"
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x)
