"""L1: Pallas kernels for the paper's compute hot-spots (top-k pruning,
MXU matmul, gather-SpMM), with pure-jnp oracles in `ref`."""

from . import matmul, ref, spmm, topk  # noqa: F401
