"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has its semantics pinned here; pytest
(`python/tests/test_kernels.py`) sweeps shapes/dtypes with hypothesis and
asserts allclose between the Pallas (interpret-mode) kernel and these
references.
"""

import jax.numpy as jnp


def topk_mask_ref(x, k):
    """Per-row top-k mask (paper Eq. 2): keep entries >= the k-th largest
    value of their row, zero the rest.

    Ties at the threshold keep every tied entry (both implementations use
    the same `>= threshold` rule, so they agree exactly).
    """
    if k >= x.shape[-1]:
        return x
    sorted_desc = jnp.sort(x, axis=-1)[..., ::-1]
    thresh = sorted_desc[..., k - 1]
    mask = x >= thresh[..., None]
    return jnp.where(mask, x, jnp.zeros_like(x))


def matmul_ref(x, w):
    """Plain dense matmul with f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def spmm_gather_ref(idx, w, x):
    """Padded-ELL gather-aggregate: out[i] = sum_j w[i, j] * x[idx[i, j]].

    `idx`: [n, m] int32 source-row indices (padding entries point at any
    valid row and carry weight 0). `w`: [n, m] weights. `x`: [nsrc, d].
    This is the ranged-indirect (AIA-style) access pattern as a TPU
    gather.
    """
    gathered = x[idx]  # [n, m, d]
    return jnp.einsum("nm,nmd->nd", w, gathered)


def relu_ref(x):
    return jnp.maximum(x, 0.0)
