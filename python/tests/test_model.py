"""L2 model correctness: the hand-written backward functions must match
jax.grad, and loss_grad must be a real softmax cross-entropy."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def test_layer_bwd_matches_autodiff():
    rng = np.random.default_rng(0)
    h, w = rand(rng, 128, 64), rand(rng, 64, 64)
    d_out = rand(rng, 128, 64)

    def f(h, w):
        # pure-jnp twin of layer_fwd (pallas interpret kernels lack an
        # autodiff rule; forward equivalence is tested in test_kernels)
        return jnp.sum(jnp.maximum(h @ w, 0.0) * d_out)

    _, gate = model.layer_fwd(h, w)
    dw, dh = model.layer_bwd(h, d_out, gate, w)
    gh, gw = jax.grad(f, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(dw, gw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dh, gh, rtol=1e-4, atol=1e-4)


def test_out_bwd_matches_autodiff():
    rng = np.random.default_rng(1)
    h, w = rand(rng, 128, 64), rand(rng, 64, 16)
    dl = rand(rng, 128, 16)

    def f(h, w):
        return jnp.sum((h @ w) * dl)

    dw, dh = model.out_bwd(h, dl, w)
    gh, gw = jax.grad(f, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(dw, gw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dh, gh, rtol=1e-4, atol=1e-4)


def test_sage_bwd_matches_autodiff():
    rng = np.random.default_rng(2)
    hs, hn = rand(rng, 128, 64), rand(rng, 128, 64)
    ws, wn = rand(rng, 64, 64), rand(rng, 64, 64)
    d_out = rand(rng, 128, 64)

    def f(hs, hn, ws, wn):
        return jnp.sum(jnp.maximum(hs @ ws + hn @ wn, 0.0) * d_out)

    _, gate = model.sage_fwd(hs, hn, ws, wn)
    dws, dwn, dhs, dhn = model.sage_bwd(hs, hn, d_out, gate, ws, wn)
    g = jax.grad(f, argnums=(0, 1, 2, 3))(hs, hn, ws, wn)
    for got, want in zip((dhs, dhn, dws, dwn), g):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_loss_grad_matches_autodiff():
    rng = np.random.default_rng(3)
    logits = rand(rng, 256, 16)
    y = jax.nn.one_hot(jnp.asarray(rng.integers(0, 16, 256)), 16, dtype=jnp.float32)
    loss, dlogits = model.loss_grad(logits, y)

    def f(lg):
        return model.loss_grad(lg, y)[0]

    np.testing.assert_allclose(dlogits, jax.grad(f)(logits), rtol=1e-4, atol=1e-5)
    # perfect prediction → small loss; uniform → log(16)
    uniform = jnp.zeros((4, 16), jnp.float32)
    yu = jax.nn.one_hot(jnp.arange(4) % 16, 16, dtype=jnp.float32)
    lu, _ = model.loss_grad(uniform, yu)
    np.testing.assert_allclose(lu, np.log(16.0), rtol=1e-5)
    assert float(loss) > 0.0


def test_gcn_forward_ref_shapes():
    rng = np.random.default_rng(4)
    n, d, c = 256, 64, 16
    a = jnp.asarray((rng.random((n, n)) < 0.01).astype(np.float32))
    x = rand(rng, n, d)
    ws = [rand(rng, d, d), rand(rng, d, d), rand(rng, d, c)]
    logits = model.gcn_forward_ref(a, x, ws, k=8)
    assert logits.shape == (n, c)
    assert bool(jnp.isfinite(logits).all())
