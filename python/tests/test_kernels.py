"""L1 kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed (offline image)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_relu_gate
from compile.kernels.spmm import spmm_gather
from compile.kernels.topk import topk_mask

SET = settings(max_examples=20, deadline=None)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# ------------------------------------------------------------------ topk
@SET
@given(
    rows_pow=st.integers(0, 3),
    d=st.sampled_from([8, 16, 64, 128]),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_topk_matches_ref(rows_pow, d, k, seed):
    n = 256 * (2**rows_pow)
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d)
    got = topk_mask(x, k)
    want = ref.topk_mask_ref(x, k)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_topk_keeps_exactly_k_nonzeros_generic():
    rng = np.random.default_rng(0)
    x = rand(rng, 256, 64)
    out = np.asarray(topk_mask(x, 8))
    # generic floats: no ties, so exactly k survivors per row
    assert (np.count_nonzero(out, axis=1) == 8).all()


def test_topk_k_ge_d_is_identity():
    rng = np.random.default_rng(1)
    x = rand(rng, 256, 16)
    np.testing.assert_array_equal(topk_mask(x, 16), x)


def test_topk_tie_semantics_match_ref():
    # all-equal rows: both implementations keep every tied entry
    x = jnp.ones((256, 32), jnp.float32)
    np.testing.assert_array_equal(topk_mask(x, 4), ref.topk_mask_ref(x, 4))


# ---------------------------------------------------------------- matmul
@SET
@given(
    n_blocks=st.integers(1, 4),
    k=st.sampled_from([16, 64]),
    m=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(n_blocks, k, m, seed):
    n = 128 * n_blocks
    rng = np.random.default_rng(seed)
    x, w = rand(rng, n, k), rand(rng, k, m)
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_small_n_single_block():
    rng = np.random.default_rng(3)
    x, w = rand(rng, 64, 64), rand(rng, 64, 16)
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_relu_gate():
    rng = np.random.default_rng(4)
    x, w = rand(rng, 128, 64), rand(rng, 64, 64)
    act, gate = matmul_relu_gate(x, w)
    z = ref.matmul_ref(x, w)
    np.testing.assert_allclose(act, ref.relu_ref(z), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(gate, (z > 0).astype(np.float32))


# ------------------------------------------------------------------ spmm
@SET
@given(
    n_blocks=st.integers(1, 2),
    m=st.sampled_from([4, 16]),
    nsrc=st.sampled_from([128, 512]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_matches_ref(n_blocks, m, nsrc, d, seed):
    n = 128 * n_blocks
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, nsrc, size=(n, m)).astype(np.int32))
    w = rand(rng, n, m)
    x = rand(rng, nsrc, d)
    got = spmm_gather(idx, w, x)
    want = ref.spmm_gather_ref(idx, w, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spmm_zero_weights_are_padding():
    rng = np.random.default_rng(5)
    idx = jnp.asarray(rng.integers(0, 64, size=(128, 8)).astype(np.int32))
    w = jnp.zeros((128, 8), jnp.float32)
    x = rand(rng, 64, 32)
    np.testing.assert_array_equal(spmm_gather(idx, w, x), jnp.zeros((128, 32)))


def test_spmm_identity_gather():
    # each row gathers itself with weight 1 -> output == x
    n, d = 128, 16
    idx = jnp.arange(n, dtype=jnp.int32)[:, None]
    w = jnp.ones((n, 1), jnp.float32)
    rng = np.random.default_rng(6)
    x = rand(rng, n, d)
    np.testing.assert_allclose(spmm_gather(idx, w, x), x, rtol=1e-6, atol=1e-6)


# ----------------------------------------------------- interpret-mode HLO
def test_kernels_lower_to_plain_hlo():
    """interpret=True kernels must lower to ops a CPU PJRT client can run
    (no Mosaic custom-calls)."""
    x = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = jax.jit(lambda a: topk_mask(a, 8)).lower(x).compiler_ir("stablehlo")
    assert "tpu_custom_call" not in str(txt)
