"""Put `python/` on sys.path so the tests import `compile.*` the same
way `aot.py` does when invoked as a script (`python -m pytest
python/tests -q` from the repo root, as CI runs it)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
