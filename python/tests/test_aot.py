"""AOT lowering sanity: artifacts are valid HLO text with the expected
parameter counts, and the tier list matches the Rust registry's tiers."""

import jax.numpy as jnp

from compile import aot


def test_ops_cover_every_gnn_primitive():
    names = [name for name, _, _ in aot.ops_for_tier(8192)]
    assert names == [
        "topk_mask",
        "layer_fwd",
        "layer_bwd",
        "out_fwd",
        "out_bwd",
        "loss_grad",
        "sage_fwd",
        "sage_bwd",
    ]


def test_lower_small_tier_produces_hlo_text():
    # lower at a tiny (non-shipping) tier for speed; structure identical.
    for name, fn, ex in aot.ops_for_tier(256):
        text = aot.lower_one(fn, ex)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, name
        # one HLO parameter per example arg
        for i in range(len(ex)):
            assert f"parameter({i})" in text, f"{name}: missing parameter {i}"


def test_artifacts_return_tuples():
    # the rust runtime unconditionally calls to_tuple(); single-output ops
    # must still lower as 1-tuples
    name, fn, ex = aot.ops_for_tier(256)[3]  # out_fwd
    assert name == "out_fwd"
    text = aot.lower_one(fn, ex)
    assert "ROOT" in text and "tuple" in text.lower()


def test_tier_constants_match_rust_registry():
    assert aot.TIERS == [8192, 16384, 32768, 65536]
    assert aot.FDIM == 64 and aot.CDIM == 16 and aot.TOPK == 8


def test_dtype_is_f32_everywhere():
    for _, _, ex in aot.ops_for_tier(256):
        assert all(a.dtype == jnp.float32 for a in ex)
