#!/usr/bin/env python3
"""End-to-end smoke test of `spgemm-aia serve` over its Unix socket.

Drives the required `serve-smoke` CI job (std-lib only, per the repo's
offline policy). Two phases against one plan-cache directory:

Phase 1 — boot a daemon on a temp socket, run a scripted session:
register two inline CSR operands, multiply twice (first response must
be a `fresh` plan, the second a `mem` hit with zero symbolic seconds
and bit-identical nnz/checksum), run a masked multiply leg (a full
mask's checksum must equal the unmasked product's — the filtered
oracle bit-identity over the wire — a sparse mask must shrink nnz and
ride its own cached plan, and a wrong-shape mask must answer
bad_request), reconcile the stats counters, check released handles
error, then SIGTERM and require a clean exit within the deadline with
the socket file removed.

Phase 2 — boot a *second* daemon on the same cache directory,
re-register the same operands, and require the first multiply to be
served from the `disk` tier: zero symbolic seconds and a checksum
bit-identical to phase 1's. Exit via the `shutdown` protocol op.

The caller (CI) then runs `spgemm-aia plan-cache verify/ls` against the
same cache directory as a final step.
"""

import argparse
import json
import os
import random
import signal
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path

CONNECT_DEADLINE_S = 60.0
EXIT_DEADLINE_S = 20.0
IO_TIMEOUT_S = 120.0


def log(msg: str) -> None:
    print(f"serve-smoke: {msg}", flush=True)


def fail(msg: str) -> None:
    print(f"serve-smoke: FAIL: {msg}", file=sys.stderr, flush=True)
    sys.exit(1)


def make_csr(seed: int, n: int, per_row: int) -> dict:
    """A deterministic random CSR in the protocol's inline-matrix shape."""
    rng = random.Random(seed)
    rpt, col, val = [0], [], []
    for _ in range(n):
        k = rng.randint(0, per_row)
        for c in sorted(rng.sample(range(n), k)):
            col.append(c)
            val.append(round(rng.uniform(-4.0, 4.0), 6))
        rpt.append(len(col))
    return {"rows": n, "cols": n, "rpt": rpt, "col": col, "val": val}


def make_full_ones(n: int) -> dict:
    """Dense all-ones CSR: as a mask it admits everything."""
    return {
        "rows": n,
        "cols": n,
        "rpt": [i * n for i in range(n + 1)],
        "col": list(range(n)) * n,
        "val": [1.0] * (n * n),
    }


class Client:
    """One line-protocol session."""

    def __init__(self, sock_path: Path):
        deadline = time.monotonic() + CONNECT_DEADLINE_S
        while True:
            try:
                self.sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                self.sock.connect(str(sock_path))
                break
            except OSError:
                self.sock.close()
                if time.monotonic() > deadline:
                    fail(f"daemon socket {sock_path} never came up")
                time.sleep(0.2)
        self.sock.settimeout(IO_TIMEOUT_S)
        self.rfile = self.sock.makefile("r", encoding="utf-8")

    def request(self, obj: dict) -> dict:
        line = json.dumps(obj)
        self.sock.sendall(line.encode() + b"\n")
        resp = self.rfile.readline()
        if not resp:
            fail(f"daemon hung up answering {line}")
        try:
            return json.loads(resp)
        except json.JSONDecodeError:
            fail(f"unparsable response to {line}: {resp!r}")

    def ok(self, obj: dict) -> dict:
        resp = self.request(obj)
        if resp.get("ok") is not True:
            fail(f"request {obj} answered {resp}")
        return resp

    def err(self, obj: dict, code: str) -> dict:
        resp = self.request(obj)
        if resp.get("ok") is not False or resp.get("error") != code:
            fail(f"request {obj} should fail with {code!r}, answered {resp}")
        return resp

    def close(self) -> None:
        self.rfile.close()
        self.sock.close()


def spawn(binary: Path, sock: Path, cache: Path) -> subprocess.Popen:
    proc = subprocess.Popen(
        [str(binary), "serve", "--socket", str(sock), "--plan-cache", str(cache), "--queue", "8"],
    )
    log(f"daemon pid {proc.pid} on {sock}")
    return proc


def wait_exit(proc: subprocess.Popen, sock: Path, how: str) -> None:
    try:
        code = proc.wait(timeout=EXIT_DEADLINE_S)
    except subprocess.TimeoutExpired:
        proc.kill()
        fail(f"daemon did not exit within {EXIT_DEADLINE_S}s of {how}")
    if code != 0:
        fail(f"daemon exited {code} after {how}")
    if sock.exists():
        fail(f"daemon left its socket file behind after {how}")
    log(f"daemon exited cleanly after {how}")


def expect(cond: bool, msg: str) -> None:
    if not cond:
        fail(msg)


def phase1(binary: Path, sock: Path, cache: Path) -> str:
    proc = spawn(binary, sock, cache)
    c = Client(sock)
    c.ok({"op": "ping"})

    a = c.ok({"op": "register", "matrix": make_csr(42, 256, 6)})
    b = c.ok({"op": "register", "matrix": make_csr(43, 256, 6)})
    ha, hb = a["handle"], b["handle"]
    expect(a["nnz"] > 0 and b["nnz"] > 0, "registered operands must be non-empty")

    first = c.ok({"op": "multiply", "a": ha, "b": hb})
    expect(first["plan"] == "fresh", f"first multiply must build a plan, got {first}")
    expect(first["symbolic_s"] >= 0.0, f"fresh plan reports its symbolic seconds: {first}")

    second = c.ok({"op": "multiply", "a": ha, "b": hb})
    expect(second["plan"] == "mem", f"second multiply must be a memory hit, got {second}")
    expect(second["symbolic_s"] == 0.0, f"plan hits pay no symbolic seconds: {second}")
    expect(
        (second["nnz"], second["checksum"]) == (first["nnz"], first["checksum"]),
        f"hit must be bit-identical to the miss: {first} vs {second}",
    )
    log(f"multiply nnz={first['nnz']} checksum={first['checksum']} (fresh -> mem, bit-identical)")

    stats = c.ok({"op": "stats"})["stats"]
    expect(stats["requests"] == 2, f"stats.requests: {stats}")
    expect(stats["plan_hits"] == 1 and stats["plan_misses"] == 1, f"hit/miss split: {stats}")
    expect(stats["registered"] == 2 and stats["registered_live"] == 2, f"registration counters: {stats}")
    expect(stats["store"]["stores"] == 1, f"the fresh plan must be persisted: {stats}")

    # A cold one-shot under the per-request estimated policy: a structure
    # the store has never seen speculates (plan "estimated"), pays zero
    # symbolic seconds, and must not write a second plan file through to
    # disk — speculative plans are store-ineligible.
    hc = c.ok({"op": "register", "matrix": make_csr(44, 256, 6)})["handle"]
    spec = c.ok({"op": "multiply", "a": hc, "b": hc, "planner": "estimated"})
    expect(spec["plan"] == "estimated", f"cold one-shot with planner=estimated must speculate: {spec}")
    expect(spec["symbolic_s"] == 0.0, f"speculative plans never run the exact symbolic phase: {spec}")
    stats = c.ok({"op": "stats"})["stats"]
    expect(stats["plan_estimated"] == 1, f"estimated-plan counter: {stats}")
    expect(stats["store"]["stores"] == 1, f"speculative plans must never be persisted: {stats}")
    c.err({"op": "multiply", "a": hc, "b": hc, "planner": "frobnicate"}, "bad_request")
    log("estimated one-shot speculated; store untouched by the speculative plan")

    # Masked multiply leg (C = M . (A*B), the "mask" wire field): a full
    # mask admits every entry, so its checksum must be bit-identical to
    # the unmasked product — the multiply-then-filter oracle asserted
    # over the wire. A sparse mask (the operand's own structure, the
    # triangle-counting idiom) must shrink nnz, plan under its own
    # fingerprint, and hit the memory tier on repeat. A mask of the
    # wrong shape is a bad_request before any work is queued.
    hm = c.ok({"op": "register", "matrix": make_csr(46, 64, 5)})["handle"]
    hfull = c.ok({"op": "register", "matrix": make_full_ones(64)})["handle"]
    plain = c.ok({"op": "multiply", "a": hm, "b": hm})
    full_masked = c.ok({"op": "multiply", "a": hm, "b": hm, "mask": hfull})
    expect(
        (full_masked["nnz"], full_masked["checksum"]) == (plain["nnz"], plain["checksum"]),
        f"full mask must be bit-identical to the filtered oracle: {plain} vs {full_masked}",
    )
    sparse_masked = c.ok({"op": "multiply", "a": hm, "b": hm, "mask": hm})
    expect(sparse_masked["plan"] == "fresh", f"masked plan is its own fingerprint: {sparse_masked}")
    expect(sparse_masked["nnz"] <= plain["nnz"], f"mask must never add entries: {sparse_masked}")
    again = c.ok({"op": "multiply", "a": hm, "b": hm, "mask": hm})
    expect(again["plan"] == "mem", f"repeated masked product must hit memory: {again}")
    expect(again["symbolic_s"] == 0.0, f"masked plan hits pay no symbolic seconds: {again}")
    expect(again["checksum"] == sparse_masked["checksum"], f"masked hit must be bit-identical: {again}")
    tiny = c.ok({"op": "register", "matrix": {
        "rows": 8, "cols": 8, "rpt": list(range(9)), "col": list(range(8)), "val": [1.0] * 8,
    }})["handle"]
    c.err({"op": "multiply", "a": hm, "b": hm, "mask": tiny}, "bad_request")
    c.err({"op": "multiply", "a": hm, "b": hm, "mask": "x"}, "bad_request")
    log(f"masked leg: full-mask checksum matches oracle; sparse mask nnz {sparse_masked['nnz']}"
        f" <= {plain['nnz']}, fresh -> mem")

    c.ok({"op": "release", "handle": ha})
    c.err({"op": "release", "handle": ha}, "unknown_handle")
    c.err({"op": "multiply", "a": ha, "b": hb}, "unknown_handle")
    c.close()

    proc.send_signal(signal.SIGTERM)
    wait_exit(proc, sock, "SIGTERM")
    plans = list(cache.glob("*.plan"))
    expect(len(plans) >= 1, f"no plan files persisted under {cache}")
    log(f"{len(plans)} plan file(s) persisted under {cache}")
    return first["checksum"]


def phase2(binary: Path, sock: Path, cache: Path, checksum: str) -> None:
    proc = spawn(binary, sock, cache)
    c = Client(sock)

    ha = c.ok({"op": "register", "matrix": make_csr(42, 256, 6)})["handle"]
    hb = c.ok({"op": "register", "matrix": make_csr(43, 256, 6)})["handle"]
    hit = c.ok({"op": "multiply", "a": ha, "b": hb})
    expect(hit["plan"] == "disk", f"a fresh daemon on the same cache must hit disk, got {hit}")
    expect(hit["symbolic_s"] == 0.0, f"disk hits skip the symbolic phase: {hit}")
    expect(hit["checksum"] == checksum, f"cross-process result must be bit-identical: {hit}")
    stats = c.ok({"op": "stats"})["stats"]
    expect(stats["disk_hits"] == 1 and stats["plan_misses"] == 0, f"disk-hit counters: {stats}")
    log(f"cross-process disk hit, checksum {hit['checksum']} matches phase 1")

    resp = c.ok({"op": "shutdown"})
    expect(resp.get("stopping") is True, f"shutdown ack: {resp}")
    c.close()
    wait_exit(proc, sock, "the shutdown op")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--binary", type=Path, default=Path("rust/target/release/spgemm-aia"))
    ap.add_argument("--cache-dir", type=Path, default=None,
                    help="plan-cache directory (kept for the CI plan-cache verify step)")
    args = ap.parse_args()
    if not args.binary.exists():
        fail(f"binary {args.binary} not found (build with: cargo build --release)")

    work = Path(tempfile.mkdtemp(prefix="spgemm-serve-smoke-"))
    cache = args.cache_dir or (work / "plan-cache")
    cache.mkdir(parents=True, exist_ok=True)

    checksum = phase1(args.binary, work / "phase1.sock", cache)
    phase2(args.binary, work / "phase2.sock", cache, checksum)
    log(f"OK (plan cache kept at {cache})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
