#!/usr/bin/env python3
"""Diff two directories of BENCH_*.json files and annotate regressions.

Used by the `bench-trend` CI job: compares each benchmark's median wall
time in the current run against the previous successful run's artifact
and emits GitHub workflow annotations (`::warning::`/`::notice::`) for
median regressions/improvements beyond the threshold. Std-lib only (the
repo's offline policy), schema `spgemm-aia-bench-v1` (see
rust/src/util/bench.rs).

Exit code is always 0 unless strict mode is on — via the --strict flag
or the BENCH_TREND_STRICT=1 environment variable (any other value of
the variable is ignored, so CI can carry the knob without flipping it)
— in which case regressions fail the job. `--self-test` runs the
comparison logic against synthetic BENCH JSON instead of real
directories (the python-tests CI job runs it) and exits non-zero on
any assertion failure.
"""

import argparse
import json
import os
import sys
import tempfile
from pathlib import Path


def strict_mode(args) -> bool:
    """Strict when --strict is passed or BENCH_TREND_STRICT=1 is set.

    The env var lets CI flip the advisory bench-trend job to gating
    without editing the workflow's command line (e.g. on a dedicated
    runner with stable numbers). Only the exact value "1" activates it.
    """
    return args.strict or os.environ.get("BENCH_TREND_STRICT") == "1"


def load_results(directory: Path):
    """name -> median seconds, across every BENCH_*.json in directory."""
    medians = {}
    for path in sorted(directory.glob("BENCH_*.json")):
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"::warning::bench-trend: cannot read {path}: {e}")
            continue
        if doc.get("schema") != "spgemm-aia-bench-v1":
            print(f"::warning::bench-trend: {path} has unknown schema {doc.get('schema')!r}")
            continue
        bench = doc.get("bench", path.stem)
        for result in doc.get("results", []):
            name = result.get("name")
            median = result.get("median_s")
            if name is None or not isinstance(median, (int, float)) or median <= 0:
                continue
            medians[f"{bench}::{name}"] = float(median)
    return medians


def compare(previous: dict, current: dict, threshold_pct: float):
    """Pure comparison core, shared by main() and the self-test.

    Returns (rows, regressions, improvements, gone) where rows is
    [(name, prev_or_None, cur, delta_pct_or_None)] over the current
    set, regressions/improvements are the rows beyond +/- threshold,
    and gone is the sorted list of names only the previous run had.
    """
    rows, regressions, improvements = [], [], []
    for name, cur in sorted(current.items()):
        prev = previous.get(name)
        if prev is None:
            rows.append((name, None, cur, None))
            continue
        delta_pct = (cur - prev) / prev * 100.0
        rows.append((name, prev, cur, delta_pct))
        if delta_pct > threshold_pct:
            regressions.append((name, prev, cur, delta_pct))
        elif delta_pct < -threshold_pct:
            improvements.append((name, prev, cur, delta_pct))
    return rows, regressions, improvements, sorted(set(previous) - set(current))


def fmt(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.3f} s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.3f} ms"
    return f"{seconds * 1e6:.1f} us"


def self_test() -> int:
    """Unit assertions over synthetic BENCH JSON: loader filtering and
    every compare() outcome (ok / regression / improvement / new /
    gone), so the CI gate catches logic rot without real artifacts."""
    prev = {"b::steady": 1.0, "b::faster": 1.0, "b::slower": 1.0, "b::gone": 1.0}
    cur = {"b::steady": 1.05, "b::faster": 0.5, "b::slower": 2.0, "b::new": 0.1}
    rows, regs, imps, gone = compare(prev, cur, threshold_pct=15.0)
    assert len(rows) == 4, rows
    assert [r[0] for r in regs] == ["b::slower"], regs
    assert abs(regs[0][3] - 100.0) < 1e-9, regs
    assert [r[0] for r in imps] == ["b::faster"], imps
    assert gone == ["b::gone"], gone
    new = [r for r in rows if r[1] is None]
    assert [r[0] for r in new] == ["b::new"], rows
    steady = next(r for r in rows if r[0] == "b::steady")
    assert steady[3] is not None and abs(steady[3] - 5.0) < 1e-9, steady

    # Threshold edges: exactly-at-threshold is neither direction.
    _, regs, imps, _ = compare({"b::x": 1.0}, {"b::x": 1.15}, threshold_pct=15.0)
    assert not regs and not imps, (regs, imps)
    # Empty previous: everything is new, nothing regresses.
    rows, regs, imps, gone = compare({}, cur, threshold_pct=15.0)
    assert len(rows) == 4 and not regs and not imps and not gone

    # Loader: good files parse; bad schema, corrupt JSON, non-positive
    # or missing medians, and non-BENCH names are all skipped.
    with tempfile.TemporaryDirectory(prefix="bench-trend-selftest-") as td:
        d = Path(td)
        (d / "BENCH_good.json").write_text(json.dumps({
            "schema": "spgemm-aia-bench-v1",
            "bench": "good",
            "results": [
                {"name": "a", "median_s": 0.25},
                {"name": "b", "median_s": 2},
                {"name": "zero", "median_s": 0.0},
                {"name": "bad-type", "median_s": "fast"},
                {"median_s": 1.0},
            ],
        }))
        (d / "BENCH_badschema.json").write_text(json.dumps({
            "schema": "someone-elses-v9", "results": [{"name": "x", "median_s": 1.0}],
        }))
        (d / "BENCH_corrupt.json").write_text("{ not json")
        (d / "NOTBENCH_skipped.json").write_text(json.dumps({
            "schema": "spgemm-aia-bench-v1", "results": [{"name": "x", "median_s": 1.0}],
        }))
        # The waste bench's shape: timing results plus used/fetched meta
        # (see rust/benches/waste.rs). The meta must ride along without
        # confusing the loader — only `results` medians join the trend.
        (d / "BENCH_waste.json").write_text(json.dumps({
            "schema": "spgemm-aia-bench-v1",
            "bench": "waste",
            "results": [{"name": "waste/scircuit/aia", "median_s": 0.125}],
            "meta": {"waste/scircuit/aia": {
                "used_bytes": 96, "fetched_bytes": 128, "waste_ratio": 0.25,
                "regions": {"col_b": {"used_bytes": 96, "fetched_bytes": 128}},
            }},
        }))
        loaded = load_results(d)
        assert loaded == {"good::a": 0.25, "good::b": 2.0,
                          "waste::waste/scircuit/aia": 0.125}, loaded
        waste_meta = json.loads((d / "BENCH_waste.json").read_text())["meta"]["waste/scircuit/aia"]
        assert waste_meta["used_bytes"] <= waste_meta["fetched_bytes"], waste_meta

    assert fmt(2.5) == "2.500 s" and fmt(0.0025) == "2.500 ms" and fmt(2.5e-6) == "2.5 us"

    # Strict-mode activation ladder: the flag, the env var (exact value
    # "1" only), either alone, or neither.
    class Args:
        def __init__(self, strict):
            self.strict = strict

    saved = os.environ.pop("BENCH_TREND_STRICT", None)
    try:
        assert not strict_mode(Args(strict=False))
        assert strict_mode(Args(strict=True))
        os.environ["BENCH_TREND_STRICT"] = "1"
        assert strict_mode(Args(strict=False))
        os.environ["BENCH_TREND_STRICT"] = "0"
        assert not strict_mode(Args(strict=False)), "only the exact value '1' activates strict"
        os.environ["BENCH_TREND_STRICT"] = "true"
        assert not strict_mode(Args(strict=False)), "only the exact value '1' activates strict"
        assert strict_mode(Args(strict=True)), "the flag wins regardless of the env var"
    finally:
        if saved is None:
            os.environ.pop("BENCH_TREND_STRICT", None)
        else:
            os.environ["BENCH_TREND_STRICT"] = saved

    print("bench-trend: self-test ok")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("previous", type=Path, nargs="?",
                    help="directory with the previous run's BENCH_*.json")
    ap.add_argument("current", type=Path, nargs="?",
                    help="directory with this run's BENCH_*.json")
    ap.add_argument("--threshold-pct", type=float, default=15.0,
                    help="annotate when median wall time moved more than this percentage")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any regression exceeds the threshold")
    ap.add_argument("--self-test", action="store_true",
                    help="run unit assertions over synthetic BENCH JSON and exit")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if args.previous is None or args.current is None:
        ap.error("previous and current directories are required (or pass --self-test)")

    current = load_results(args.current)
    if not current:
        print(f"::warning::bench-trend: no parsable BENCH_*.json under {args.current}")
        return 0
    if not args.previous.is_dir():
        print(f"::notice::bench-trend: no previous artifact ({args.previous} missing) — "
              "baseline recorded, nothing to compare")
        return 0
    previous = load_results(args.previous)

    rows, regressions, improvements, gone = compare(previous, current, args.threshold_pct)
    for name, prev, cur, delta_pct in improvements:
        print(f"::notice::bench-trend: {name} improved {-delta_pct:.1f}% "
              f"({fmt(prev)} -> {fmt(cur)})")

    print(f"\nbench trend ({len(rows)} benchmarks, threshold ±{args.threshold_pct:.0f}%):")
    print(f"{'benchmark':<64} {'previous':>12} {'current':>12} {'delta':>8}")
    for name, prev, cur, delta_pct in rows:
        prev_s = fmt(prev) if prev is not None else "(new)"
        delta_s = f"{delta_pct:+.1f}%" if delta_pct is not None else "-"
        print(f"{name:<64} {prev_s:>12} {fmt(cur):>12} {delta_s:>8}")

    for name, prev, cur, delta_pct in regressions:
        print(f"::warning::bench-trend: median wall-time regression {delta_pct:+.1f}% "
              f"on {name} ({fmt(prev)} -> {fmt(cur)})")
    for name in gone:
        print(f"::notice::bench-trend: benchmark {name} disappeared from this run")

    if regressions and strict_mode(args):
        print(f"bench-trend: {len(regressions)} regression(s) beyond "
              f"{args.threshold_pct:.0f}% (strict mode)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
