#!/usr/bin/env python3
"""Diff two directories of BENCH_*.json files and annotate regressions.

Used by the advisory `bench-trend` CI job: compares each benchmark's
median wall time in the current run against the previous successful
run's artifact and emits GitHub workflow annotations
(`::warning::`/`::notice::`) for median regressions/improvements beyond
the threshold. Std-lib only (the repo's offline policy), schema
`spgemm-aia-bench-v1` (see rust/src/util/bench.rs).

Exit code is always 0 unless --strict is passed (then regressions fail
the job).
"""

import argparse
import json
import sys
from pathlib import Path


def load_results(directory: Path):
    """name -> median seconds, across every BENCH_*.json in directory."""
    medians = {}
    for path in sorted(directory.glob("BENCH_*.json")):
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"::warning::bench-trend: cannot read {path}: {e}")
            continue
        if doc.get("schema") != "spgemm-aia-bench-v1":
            print(f"::warning::bench-trend: {path} has unknown schema {doc.get('schema')!r}")
            continue
        bench = doc.get("bench", path.stem)
        for result in doc.get("results", []):
            name = result.get("name")
            median = result.get("median_s")
            if name is None or not isinstance(median, (int, float)) or median <= 0:
                continue
            medians[f"{bench}::{name}"] = float(median)
    return medians


def fmt(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.3f} s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.3f} ms"
    return f"{seconds * 1e6:.1f} us"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("previous", type=Path, help="directory with the previous run's BENCH_*.json")
    ap.add_argument("current", type=Path, help="directory with this run's BENCH_*.json")
    ap.add_argument("--threshold-pct", type=float, default=15.0,
                    help="annotate when median wall time moved more than this percentage")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any regression exceeds the threshold")
    args = ap.parse_args()

    current = load_results(args.current)
    if not current:
        print(f"::warning::bench-trend: no parsable BENCH_*.json under {args.current}")
        return 0
    if not args.previous.is_dir():
        print(f"::notice::bench-trend: no previous artifact ({args.previous} missing) — "
              "baseline recorded, nothing to compare")
        return 0
    previous = load_results(args.previous)

    regressions = []
    rows = []
    for name, cur in sorted(current.items()):
        prev = previous.get(name)
        if prev is None:
            rows.append((name, None, cur, None))
            continue
        delta_pct = (cur - prev) / prev * 100.0
        rows.append((name, prev, cur, delta_pct))
        if delta_pct > args.threshold_pct:
            regressions.append((name, prev, cur, delta_pct))
        elif delta_pct < -args.threshold_pct:
            print(f"::notice::bench-trend: {name} improved {-delta_pct:.1f}% "
                  f"({fmt(prev)} -> {fmt(cur)})")

    print(f"\nbench trend ({len(rows)} benchmarks, threshold ±{args.threshold_pct:.0f}%):")
    print(f"{'benchmark':<64} {'previous':>12} {'current':>12} {'delta':>8}")
    for name, prev, cur, delta_pct in rows:
        prev_s = fmt(prev) if prev is not None else "(new)"
        delta_s = f"{delta_pct:+.1f}%" if delta_pct is not None else "-"
        print(f"{name:<64} {prev_s:>12} {fmt(cur):>12} {delta_s:>8}")

    for name, prev, cur, delta_pct in regressions:
        print(f"::warning::bench-trend: median wall-time regression {delta_pct:+.1f}% "
              f"on {name} ({fmt(prev)} -> {fmt(cur)})")
    gone = sorted(set(previous) - set(current))
    for name in gone:
        print(f"::notice::bench-trend: benchmark {name} disappeared from this run")

    if regressions and args.strict:
        print(f"bench-trend: {len(regressions)} regression(s) beyond "
              f"{args.threshold_pct:.0f}% (strict mode)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
