# Build entry points for the spgemm-aia reproduction.
#
# `make artifacts` is the (future) PJRT artifact pipeline: it will run
# the L2/L1 Python AOT lowering (`python/compile/aot.py`) and drop
# `artifacts/*.hlo.txt` for the Rust runtime to load. The toolchain it
# needs (jax + the vendored `xla` crate closure behind the `pjrt`
# feature) is not wired up yet — see ROADMAP.md "PJRT artifact
# pipeline" — so for now the target fails with the actionable message
# the runtime's own errors point at.

.PHONY: artifacts
artifacts:
	@echo "error: the PJRT artifact pipeline is not wired up yet." >&2
	@echo "" >&2
	@echo "'make artifacts' will lower python/compile/ (aot.py: L2 model + L1 Pallas kernels)" >&2
	@echo "to artifacts/*.hlo.txt. Until the pipeline lands you need:" >&2
	@echo "  1. a Python env with jax[cpu] (pip install 'jax[cpu]'), then" >&2
	@echo "     python python/compile/aot.py --out artifacts/" >&2
	@echo "  2. a vendored 'xla' crate closure, built with: cargo build --features pjrt" >&2
	@echo "" >&2
	@echo "Everything else (engines, simulator, apps, benches) builds without this:" >&2
	@echo "  cd rust && cargo build --release" >&2
	@exit 1
