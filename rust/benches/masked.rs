//! Bench: masked SpGEMM `C = M ⊙ (A·B)` (DESIGN.md §2i) against the
//! multiply-then-filter oracle it replaces.
//!
//! Two legs: (1) a band-mask sparse-attention scenario on the Protein
//! and Economics analogues — the masked engine prunes both phases, so
//! it must come in at or under the oracle that builds the whole A² and
//! throws most of it away (the JSON meta records both medians and the
//! speedup, which `tools/bench_trend.py` tracks); (2) triangle counting
//! on an RMAT graph via masked A·A with the adjacency as its own mask,
//! against the same count through the oracle. CI archives
//! `BENCH_masked.json` as part of the perf trajectory.

use spgemm_aia::gen::{self, rmat, structured, RmatParams};
use spgemm_aia::sparse::{Coo, Csr};
use spgemm_aia::spgemm::hash::{self, Mask};
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;
use spgemm_aia::util::Pcg32;

/// Symmetrized, unit-valued, loop-free adjacency (what `triangles` on
/// the CLI builds before counting).
fn adjacency(m: &Csr) -> Csr {
    let mut coo = Coo::new(m.n_rows, m.n_cols);
    for i in 0..m.n_rows {
        let (cols, _) = m.row(i);
        for &j in cols {
            if j as usize != i {
                coo.push(i, j as usize, 1.0);
                coo.push(j as usize, i, 1.0);
            }
        }
    }
    let mut adj = coo.to_csr();
    adj.map_values(|_| 1.0);
    adj
}

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] = if quick { &["Economics"] } else { &["Protein", "Economics"] };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        let band = (a.n_rows / 64).max(8);
        let mask = Mask::from_structure(&structured::band_mask(a.n_rows, band));
        b.group(&format!("masked/{name}"));

        let masked =
            b.bench("band/masked multiply", || bb(hash::multiply_masked(&a, &a, &mask).nnz()));
        let oracle = b.bench("band/multiply-then-filter", || {
            bb(mask.filter(&hash::multiply(&a, &a)).nnz())
        });
        let speedup = oracle.median / masked.median;
        println!("  -> masked speedup over multiply-then-filter: {speedup:.2}x");

        let c = hash::multiply_masked(&a, &a, &mask);
        assert_eq!(c, mask.filter(&hash::multiply(&a, &a)), "{name}: bench outputs diverged");
        let mut o = Json::obj();
        o.set("band", band.into());
        o.set("mask_nnz", mask.nnz().into());
        o.set("out_nnz", c.nnz().into());
        o.set("masked_s", Json::Num(masked.median));
        o.set("oracle_s", Json::Num(oracle.median));
        o.set("speedup", Json::Num(speedup));
        b.meta(&format!("band/{name}"), o);
    }

    // Triangle counting: adjacency as its own mask. The masked product
    // only ever touches wedge endpoints that are already edges.
    b.group("masked/triangles");
    let (n, nnz) = if quick { (2_000, 16_000) } else { (8_000, 64_000) };
    let adj = adjacency(&rmat(n, nnz, RmatParams::web(), &mut Pcg32::seeded(3)));
    let amask = Mask::from_structure(&adj);
    let masked = b.bench("rmat/masked A.A", || {
        let c = hash::multiply_masked(&adj, &adj, &amask);
        bb((c.val.iter().sum::<f64>() / 6.0).round() as u64)
    });
    let oracle = b.bench("rmat/multiply-then-filter", || {
        let c = amask.filter(&hash::multiply(&adj, &adj));
        bb((c.val.iter().sum::<f64>() / 6.0).round() as u64)
    });
    let c = hash::multiply_masked(&adj, &adj, &amask);
    let triangles = (c.val.iter().sum::<f64>() / 6.0).round() as u64;
    let speedup = oracle.median / masked.median;
    println!("  -> {triangles} triangles; masked speedup {speedup:.2}x");
    let mut o = Json::obj();
    o.set("nodes", adj.n_rows.into());
    o.set("edges", (adj.nnz() / 2).into());
    o.set("triangles", (triangles as i64).into());
    o.set("masked_s", Json::Num(masked.median));
    o.set("oracle_s", Json::Num(oracle.median));
    o.set("speedup", Json::Num(speedup));
    b.meta("triangles/rmat", o);

    b.finish("masked");
}
