//! Bench: byte-accurate line-utilization accounting — the tracing cost
//! of the per-line interval tracker on the traced A^2 runs, with the
//! measured used/fetched/waste figures emitted as meta so the bench
//! trend keeps the paper's central quantity (cache-line waste, ±AIA)
//! under regression watch.

use spgemm_aia::gen::table2_by_name;
use spgemm_aia::sim::{simulate_stats, AiaMode, SimConfig, SimReport};
use spgemm_aia::spgemm::Algo;
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;

fn waste_meta(rep: &SimReport) -> Json {
    let mut o = Json::obj();
    o.set("used_bytes", (rep.used_bytes() as i64).into());
    o.set("fetched_bytes", (rep.fetched_bytes() as i64).into());
    o.set("waste_ratio", rep.waste_ratio().into());
    let mut regions = Json::obj();
    for r in rep.region_waste() {
        let mut ro = Json::obj();
        ro.set("used_bytes", (r.used_bytes as i64).into());
        ro.set("fetched_bytes", (r.fetched_bytes as i64).into());
        regions.set(r.region.name(), ro);
    }
    o.set("regions", regions);
    o
}

fn main() {
    let mut b = Bencher::new();
    for name in ["scircuit", "p2p-Gnutella04"] {
        let ds = table2_by_name(name).expect("registered dataset");
        let a = (ds.gen)(spgemm_aia::repro::SEED);
        b.group(&format!("waste/{name}"));
        for (label, aia) in [("aia", AiaMode::On), ("noaia", AiaMode::Off)] {
            let cfg = SimConfig::for_scale(aia, ds.scale);
            b.bench(label, || bb(simulate_stats(Algo::Hash, &a, &a, &cfg).total_ms));
            let rep = simulate_stats(Algo::Hash, &a, &a, &cfg);
            assert!(
                rep.used_bytes() <= rep.fetched_bytes(),
                "{name}/{label}: used {} > fetched {}",
                rep.used_bytes(),
                rep.fetched_bytes()
            );
            b.meta(&format!("waste/{name}/{label}"), waste_meta(&rep));
        }
    }
    b.finish("waste");
}
