//! Bench: estimated-plan speculation vs the exact pipeline on the
//! one-shot product shape (DESIGN.md §2g).
//!
//! Three planner policies on the Protein / WindTunnel (FEM) /
//! Economics analogues: `exact` (full grouping + symbolic + numeric),
//! `estimated` (sampled plan + fallback-guarded numeric), and `auto`
//! through a cold cached executor (store-first probe, then
//! speculation). Fallback-rate counters, the estimate-vs-actual nnz
//! gap, and the exact-vs-estimated crossover land in the JSON meta; CI
//! archives `BENCH_estimated.json` as part of the perf trajectory
//! (picked up by `tools/bench_trend.py`).

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::gen;
use spgemm_aia::spgemm::hash::{self, PlannerPolicy, TieredStore};
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] = if quick { &["Economics"] } else { &["Protein", "WindTunnel", "Economics"] };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        b.group(&format!("estimated/{name}"));

        let exact = b.bench("one-shot/exact", || bb(hash::multiply(&a, &a).nnz()));
        let est = b.bench("one-shot/estimated", || bb(hash::multiply_estimated(&a, &a).0.nnz()));
        // The cached entry point under `auto`, rebuilt cold each
        // iteration: fingerprint + store probe overhead included, the
        // configuration a one-shot service request actually runs.
        let auto = b.bench("one-shot/auto-cold", || {
            let mut ex = BatchExecutor::with_store(2, TieredStore::mem_only());
            ex.planner = PlannerPolicy::Auto;
            bb(ex.multiply_cached(&a, &a).nnz())
        });

        // Counters measured once, outside the timed loops — and the
        // bench doubles as a full-size bit-identity check.
        let c_exact = hash::multiply(&a, &a);
        let (c_est, rep) = hash::multiply_estimated(&a, &a);
        assert_eq!(c_est, c_exact, "{name}: estimated product must be bit-identical to exact");
        let fallback_rate = rep.fallback_rows as f64 / a.n_rows.max(1) as f64;
        println!(
            "  -> estimated vs exact: {:.2}x | sampled {} rows | fallback rows {} ({:.2}%)",
            exact.median / est.median,
            rep.sampled_rows,
            rep.fallback_rows,
            100.0 * fallback_rate
        );
        let mut o = Json::obj();
        o.set("exact_s", Json::Num(exact.median));
        o.set("estimated_s", Json::Num(est.median));
        o.set("auto_cold_s", Json::Num(auto.median));
        o.set("speedup", Json::Num(exact.median / est.median));
        o.set("estimate_s", Json::Num(rep.estimate_s));
        o.set("sampled_rows", rep.sampled_rows.into());
        o.set("total_rows", a.n_rows.into());
        o.set("fallback_rows", rep.fallback_rows.into());
        o.set("fallback_rate", Json::Num(fallback_rate));
        o.set("estimated_nnz", rep.estimated_nnz.into());
        o.set("nnz", rep.nnz.into());
        b.meta(&format!("crossover/{name}"), o);
    }

    b.finish("estimated");
}
