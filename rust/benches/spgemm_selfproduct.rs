//! Bench: matrix self-product (paper Fig. 6 / Table II workload).
//!
//! Measures the *real wall time* of the Rust engines (hash parallel,
//! ESC, reference) on Table-II analogues, plus the simulated-H200
//! pricing of each variant — the bench-side regeneration of Fig. 6.
//! `BENCH_QUICK=1` for a fast pass.

use spgemm_aia::coordinator::executor::Variant;
use spgemm_aia::gen;
use spgemm_aia::sim::{simulate_stats, AiaMode, SimConfig};
use spgemm_aia::spgemm::{esc, hash, ip, Algo};
use spgemm_aia::util::bench::{bb, Bencher};

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] =
        if quick { &["Economics", "scircuit"] } else { &["Economics", "scircuit", "p2p-Gnutella04", "amazon0601", "RoadTX", "cage15"] };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        let total_ip = ip::total_ip(&a, &a);
        b.group(&format!("selfproduct/{name} (IP={total_ip})"));
        b.bench("hash-parallel(wall)", || bb(hash::multiply(&a, &a).nnz()));
        if quick || a.nnz() < 2_000_000 {
            b.bench("esc(wall)", || bb(esc::multiply(&a, &a).nnz()));
        }
        b.bench("sim/hash+aia", || {
            bb(simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::On, ds.scale)).total_ms)
        });
        b.bench("sim/hash", || {
            bb(simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale)).total_ms)
        });
        b.bench("sim/esc-cusparse", || {
            bb(simulate_stats(Algo::Esc, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale)).total_ms)
        });
    }
    b.finish("spgemm_selfproduct");
}
