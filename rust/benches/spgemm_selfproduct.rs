//! Bench: matrix self-product (paper Fig. 6 / Table II workload).
//!
//! Measures the *real wall time* of the Rust engines — the two-phase
//! hash pipeline against the seed's single-pass engine it replaced, the
//! ESC baseline — plus the simulated-H200 pricing of each variant (the
//! bench-side regeneration of Fig. 6). Per-dataset symbolic/numeric
//! phase times and the speedup over the seed engine land in the JSON
//! meta, so `BENCH_spgemm.json` is the machine-readable perf trajectory
//! CI archives on every PR. `BENCH_QUICK=1` for a fast pass.

use spgemm_aia::gen;
use spgemm_aia::sim::{simulate_stats, AiaMode, SimConfig};
use spgemm_aia::spgemm::{esc, hash, ip, Algo};
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] = if quick {
        &["Economics", "scircuit"]
    } else {
        &["Economics", "scircuit", "p2p-Gnutella04", "amazon0601", "RoadTX", "cage15"]
    };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        let total_ip = ip::total_ip(&a, &a);
        b.group(&format!("selfproduct/{name} (IP={total_ip})"));
        let two = b.bench("hash-twophase(wall)", || bb(hash::multiply(&a, &a).nnz()));
        let single = b.bench("hash-singlepass-seed(wall)", || bb(hash::multiply_single_pass(&a, &a).nnz()));
        println!("  -> two-phase speedup over seed single-pass: {:.2}x", single.median / two.median);
        b.meta(&format!("speedup_vs_singlepass/{name}"), Json::Num(single.median / two.median));
        // Distinct per-phase wall times for the perf trajectory.
        let (_, phases) = hash::multiply_timed(&a, &a);
        b.meta(&format!("phases/{name}"), phases.to_json());
        if quick || a.nnz() < 2_000_000 {
            b.bench("esc(wall)", || bb(esc::multiply(&a, &a).nnz()));
        }
        b.bench("sim/hash+aia", || {
            bb(simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::On, ds.scale)).total_ms)
        });
        b.bench("sim/hash", || {
            bb(simulate_stats(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale)).total_ms)
        });
        b.bench("sim/esc-cusparse", || {
            bb(simulate_stats(Algo::Esc, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale)).total_ms)
        });
    }
    b.finish("spgemm");
}
