//! Bench: symbolic-phase kernel selection (ROADMAP "Symbolic-phase
//! SPA") on the same structured set the accumulator bench uses.
//!
//! The symbolic phase sizes every output row before a single value is
//! computed — and on dense-bound rows, hash counting pays the same
//! probe chains the numeric phase already avoids with the SPA. This
//! bench pins the win of the bitmap counting kernel: the same symbolic
//! analysis run hash-only (`symbolic_threshold = 8.0`, bitmap
//! disabled), bitmap-forced (`0.0`), and plan-guided (the IP-bound
//! rule at the cache-derived default). The plans are asserted
//! identical across kernels (also pinned by
//! `tests/symbolic_select.rs`), so the kernels are the only difference
//! measured.
//!
//! Emits `BENCH_symbolic.json` with per-dataset speedups, the
//! trivial/hash/bitmap row split, and the per-kernel symbolic seconds;
//! CI's bench-smoke job archives it and `tools/bench_trend.py` diffs
//! its medians against the previous main run.

use spgemm_aia::gen::structured;
use spgemm_aia::spgemm::hash::{
    default_spa_threshold, symbolic_cfg, EngineConfig, PlannedProduct, PlannerPolicy, SymbolicKind,
};
use spgemm_aia::sparse::Csr;
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;
use spgemm_aia::util::Pcg32;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 1 } else { 2 };

    let datasets: Vec<(&str, Csr)> = vec![
        // Dense-row heavy: protein-contact A² rows are nearly fully dense.
        ("protein", structured::protein_contact(600 * scale, 119, &mut Pcg32::seeded(1))),
        // Banded FEM mesh: moderately dense output rows.
        ("fem", structured::fem_banded(1500 * scale, 53, &mut Pcg32::seeded(2))),
        // Sparse control: most IP bounds stay under the default threshold.
        ("economics", structured::economics(4000 * scale, &mut Pcg32::seeded(3))),
    ];

    let base = default_spa_threshold();
    let planner = PlannerPolicy::Exact;
    let hash_only =
        EngineConfig { spa_threshold: base, symbolic_threshold: Some(8.0), planner, mask: None };
    let bitmap =
        EngineConfig { spa_threshold: base, symbolic_threshold: Some(0.0), planner, mask: None };
    let guided = EngineConfig { spa_threshold: base, symbolic_threshold: None, planner, mask: None };

    for (name, a) in &datasets {
        b.group(&format!("symbolic/{name}"));

        // Where does the IP-bound rule send the rows?
        let plan = symbolic_cfg(a, a, &guided);
        let rows = plan.symbolic_kind_rows();
        println!(
            "  plan: {} trivial rows, {} hash rows, {} bitmap rows",
            rows[SymbolicKind::Trivial.index()],
            rows[SymbolicKind::Hash.index()],
            rows[SymbolicKind::Bitmap.index()]
        );
        let mut kind_json = Json::obj();
        kind_json.set("trivial_rows", rows[0].into());
        kind_json.set("hash_rows", rows[1].into());
        kind_json.set("bitmap_rows", rows[2].into());
        b.meta(&format!("kinds/{name}"), kind_json);

        // The symbolic phase alone, per kernel mode. nnz() forces the
        // plan so the whole analysis is inside the measured region.
        let t_hash = b.bench("symbolic/hash-only", || bb(symbolic_cfg(a, a, &hash_only).nnz()));
        let t_bitmap = b.bench("symbolic/bitmap", || bb(symbolic_cfg(a, a, &bitmap).nnz()));
        let t_guided = b.bench("symbolic/plan-guided", || bb(symbolic_cfg(a, a, &guided).nnz()));
        let speedup = t_hash.median / t_bitmap.median;
        println!("  -> bitmap symbolic speedup over hash-only: {speedup:.2}x");
        b.meta(&format!("bitmap_speedup/{name}"), Json::Num(speedup));
        b.meta(&format!("guided_speedup/{name}"), Json::Num(t_hash.median / t_guided.median));

        // Per-kernel symbolic seconds of one guided plan, via the
        // plan-reuse layer's timed construction.
        let p = PlannedProduct::plan_cfg(a, a, &guided);
        b.meta(&format!("plan_times/{name}"), p.plan_times.to_json());

        // The kernels must agree on the plan exactly (keeps the bench
        // honest about measuring identical analysis).
        let ph = symbolic_cfg(a, a, &hash_only);
        let pb = symbolic_cfg(a, a, &bitmap);
        assert_eq!(ph.rpt, pb.rpt, "{name}: counting kernels disagree on row sizes");
        assert_eq!(ph.rpt, plan.rpt, "{name}: guided plan disagrees on row sizes");
    }
    b.finish("symbolic");
}
