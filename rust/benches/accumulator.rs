//! Bench: plan-guided accumulator selection (ROADMAP "Plan-guided
//! numeric accumulators") on dense-row-heavy structured generators.
//!
//! The hash engine pays probe chains and a scattered gather even on
//! rows whose output approaches full density — exactly the rows the
//! protein-contact analogue mass-produces in its self-product (dense
//! diagonal blocks + long-range contacts make nearly every C row
//! dense). This bench pins the win of the plan-guided dense-SPA
//! fallback: the same product run hash-only (`spa_threshold = 2.0`,
//! SPA disabled) vs plan-guided (default threshold), cold and as a
//! reused-plan numeric fill (the purest accumulator comparison — no
//! symbolic phase in the loop). A sparse control dataset where SPA
//! never triggers documents that the threshold is conservative.
//!
//! Emits `BENCH_accumulator.json` with per-dataset speedups, the
//! copy/hash/SPA row split, and the per-kind numeric seconds; CI
//! archives it as part of the perf trajectory and the bench-trend job
//! diffs it against the previous run.

use spgemm_aia::gen::structured;
use spgemm_aia::spgemm::hash::{
    multiply_cfg, numeric_timed, symbolic_cfg, AccumKind, EngineConfig, PlannerPolicy, DEFAULT_SPA_THRESHOLD,
};
use spgemm_aia::sparse::Csr;
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;
use spgemm_aia::util::Pcg32;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 1 } else { 2 };

    let datasets: Vec<(&str, Csr)> = vec![
        // Dense-row heavy: protein-contact A² rows are nearly fully dense.
        ("protein", structured::protein_contact(600 * scale, 119, &mut Pcg32::seeded(1))),
        // Banded FEM mesh: moderately dense output rows.
        ("fem", structured::fem_banded(1500 * scale, 53, &mut Pcg32::seeded(2))),
        // Sparse control: SPA must not trigger at the default threshold.
        ("economics", structured::economics(4000 * scale, &mut Pcg32::seeded(3))),
    ];

    let planner = PlannerPolicy::Exact;
    let hash_only = EngineConfig { spa_threshold: 2.0, symbolic_threshold: None, planner, mask: None };
    let guided =
        EngineConfig { spa_threshold: DEFAULT_SPA_THRESHOLD, symbolic_threshold: None, planner, mask: None };

    for (name, a) in &datasets {
        b.group(&format!("accumulator/{name}"));

        // Where does the plan send the rows?
        let plan = symbolic_cfg(a, a, &guided);
        let kinds = plan.kind_rows();
        println!(
            "  plan: {} copy rows, {} hash rows, {} spa rows across {} bins",
            kinds[0],
            kinds[1],
            kinds[2],
            plan.bins.len()
        );
        let mut kind_json = Json::obj();
        kind_json.set("copy_rows", kinds[0].into());
        kind_json.set("hash_rows", kinds[1].into());
        kind_json.set("spa_rows", kinds[2].into());
        kind_json.set("bins", plan.bins.len().into());
        b.meta(&format!("kinds/{name}"), kind_json);
        if *name == "economics" {
            assert_eq!(kinds[AccumKind::Spa.index()], 0, "sparse control must stay hash-only");
        }

        // Cold multiplies (symbolic + numeric each iteration).
        let cold_hash = b.bench("cold/hash-only", || bb(multiply_cfg(a, a, &hash_only).nnz()));
        let cold_spa = b.bench("cold/plan-guided", || bb(multiply_cfg(a, a, &guided).nnz()));
        b.meta(&format!("cold_speedup/{name}"), Json::Num(cold_hash.median / cold_spa.median));

        // Reused-plan numeric fills: the accumulator is the only
        // difference between these two loops.
        let plan_hash = symbolic_cfg(a, a, &hash_only);
        let fill_hash = b.bench("fill/hash-only", || bb(numeric_timed(a, a, &plan_hash).0.nnz()));
        let fill_spa = b.bench("fill/plan-guided", || bb(numeric_timed(a, a, &plan).0.nnz()));
        let speedup = fill_hash.median / fill_spa.median;
        println!("  -> plan-guided fill speedup over hash-only: {speedup:.2}x");
        b.meta(&format!("fill_speedup/{name}"), Json::Num(speedup));

        // Per-kind numeric seconds of one guided fill.
        let (_, times) = numeric_timed(a, a, &plan);
        b.meta(&format!("fill_times/{name}"), times.to_json());

        // The three paths must agree bit-for-bit (also pinned by
        // tests/accumulator_select.rs; asserting here keeps the bench
        // honest about measuring identical work).
        assert_eq!(multiply_cfg(a, a, &hash_only), multiply_cfg(a, a, &guided));
    }
    b.finish("accumulator");
}
