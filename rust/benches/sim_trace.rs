//! Bench: the simulator itself (probe event throughput, cache model,
//! stats-path sampling) — the §Perf target is ≥50 M events/s through
//! the machine model, and the Fig. 5/9 regeneration cost.

use spgemm_aia::gen::{rmat, RmatParams};
use spgemm_aia::sim::probe::{Kind, Phase, Probe, Region};
use spgemm_aia::sim::{simulate_stats, AiaMode, DeviceConfig, Machine, SimConfig};
use spgemm_aia::spgemm::Algo;
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::Pcg32;

fn main() {
    let mut b = Bencher::new();

    // --- raw event throughput through the machine model ---
    b.group("machine/event_throughput");
    let n_events = 1_000_000usize;
    let mut rng = Pcg32::seeded(3);
    let addrs: Vec<usize> = (0..n_events).map(|_| rng.below_usize(50_000_000)).collect();
    let s = b.bench("random_reads_1M", || {
        let mut m = Machine::new(DeviceConfig::h200_scaled(), AiaMode::Off, 1);
        m.begin_block(0, Phase::Allocation);
        for &a in &addrs {
            m.access(Region::ColB, a, 4, Kind::Read);
        }
        bb(m.finish().total_ms)
    });
    println!("  -> {:.1} M events/s", n_events as f64 / s.median / 1e6);

    let s = b.bench("indirect_ranges_aia_200k", || {
        let mut m = Machine::new(DeviceConfig::h200_scaled(), AiaMode::On, 1);
        m.begin_block(0, Phase::Allocation);
        for &a in &addrs[..200_000] {
            m.indirect_range(Region::RptB, a % 1_000_000, &[Region::ColB], a, a + 6);
        }
        bb(m.finish().total_ms)
    });
    println!("  -> {:.1} M gathered elems/s", 200_000.0 * 6.0 / s.median / 1e6);

    // --- end-to-end stats simulation with auto-sampling ---
    b.group("simulate_stats (rmat 40k/400k)");
    let a = rmat(40_000, 400_000, RmatParams::web(), &mut Pcg32::seeded(4));
    for (label, aia) in [("aia", AiaMode::On), ("noaia", AiaMode::Off)] {
        b.bench(label, || bb(simulate_stats(Algo::Hash, &a, &a, &SimConfig::new(aia)).total_ms));
    }
    b.bench("esc", || bb(simulate_stats(Algo::Esc, &a, &a, &SimConfig::new(AiaMode::Off)).total_ms));

    b.finish("sim_trace");
}
