//! Bench: the Algorithm-4 hash table and the phase row processors —
//! the L3 hot path (supports the §Perf iteration log and the Table I
//! sizing ablation).

use spgemm_aia::gen::{rmat, RmatParams};
use spgemm_aia::sim::probe::NullProbe;
use spgemm_aia::spgemm::hash::table::{HashTable, TableLoc};
use spgemm_aia::spgemm::hash::{self, Grouping};
use spgemm_aia::spgemm::ip;
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::Pcg32;

fn main() {
    let mut b = Bencher::new();

    // --- raw table ops ---
    b.group("hash_table/insert");
    let mut rng = Pcg32::seeded(1);
    let keys: Vec<u32> = (0..4096).map(|_| rng.next_u32() % 100_000).collect();
    for &size in &[1024usize, 8192, 65_536] {
        b.bench(&format!("numeric_size{size}"), || {
            let mut t = HashTable::new(size, TableLoc::Shared);
            for &k in &keys[..(size / 2).min(keys.len())] {
                t.insert_numeric(k % (size as u32), 1.0, &mut NullProbe);
            }
            bb(t.unique)
        });
    }

    // --- load-factor ablation (DESIGN.md: Table I sizing trade-off) ---
    b.group("hash_table/load_factor");
    for &fill_pct in &[25usize, 50, 75, 90] {
        let size = 8192usize;
        let n = size * fill_pct / 100;
        let ks: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761) % 60_000).collect();
        b.bench(&format!("fill{fill_pct}%"), || {
            let mut t = HashTable::new(size, TableLoc::Shared);
            for &k in &ks {
                t.insert_symbolic(k, &mut NullProbe);
            }
            bb(t.unique)
        });
    }

    // --- grouping + full engine on a skewed matrix ---
    b.group("engine");
    let a = rmat(30_000, 300_000, RmatParams::web(), &mut Pcg32::seeded(2));
    let ips = ip::intermediate_products(&a, &a);
    b.bench("ip_count", || bb(ip::intermediate_products(&a, &a).len()));
    b.bench("grouping", || bb(Grouping::build(&ips).map.len()));
    b.bench("hash_multiply_full", || bb(hash::multiply(&a, &a).nnz()));

    b.finish("hash_table");
}
