//! Bench: incremental dirty-row replanning (ROADMAP "Incremental
//! SpGEMM — dirty-row replan") on the dynamic-graph workload.
//!
//! A mutating graph dirties a few rows per step; the delta planner
//! (`spgemm::hash::incremental`) re-runs the symbolic phase for those
//! rows only and patches the plan in place. This bench pins that win
//! against the cold path it replaces: a full replan of the mutated
//! product vs a delta patch at 0.1 % / 1 % / 10 % dirty rows on the
//! Protein and Economics analogues, plus a 4-iteration MCL prune chain
//! where the per-iteration prune is the mutation source. Dirty-set
//! sizes and the hit/delta/miss split land in the JSON meta; CI
//! archives `BENCH_incremental.json` as part of the perf trajectory
//! (picked up by `tools/bench_trend.py`).

use spgemm_aia::apps::{mcl, MclParams};
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen;
use spgemm_aia::spgemm::hash::{
    delta_patch, mutate_row_fraction, DeltaOutcome, EngineConfig, PlannedProduct, TieredStore,
};
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] = if quick { &["Economics"] } else { &["Protein", "Economics"] };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        b.group(&format!("incremental/{name}"));
        let base = PlannedProduct::plan(&a, &a);

        for (frac, pct) in [(0.001f64, "0.1pct"), (0.01, "1pct"), (0.1, "10pct")] {
            let label = format!("dirty-{pct}");
            // Mutate `frac` of A's rows; the right operand stays the
            // unmutated structure, so the dirty set is exactly the
            // mutated rows (no B-side feeders).
            let a2 = mutate_row_fraction(&a, frac, 7);
            let cold = b.bench(&format!("{label}/cold replan"), || bb(PlannedProduct::plan(&a2, &a).nnz()));
            let delta = b.bench(&format!("{label}/delta replan"), || {
                match delta_patch(&base, &a2, &a, &EngineConfig::default()) {
                    DeltaOutcome::Patched(dp) => bb(dp.plan.nnz()),
                    DeltaOutcome::Rebuild(why) => panic!("{name} {label}: bench mutation must patch: {why}"),
                }
            });
            let speedup = cold.median / delta.median;
            println!("  -> delta replan speedup over cold at {label}: {speedup:.2}x");
            let dirty_rows = match delta_patch(&base, &a2, &a, &EngineConfig::default()) {
                DeltaOutcome::Patched(dp) => dp.dirty_rows,
                DeltaOutcome::Rebuild(why) => panic!("{name} {label}: bench mutation must patch: {why}"),
            };
            let mut o = Json::obj();
            o.set("dirty_rows", dirty_rows.into());
            o.set("total_rows", a.n_rows.into());
            o.set("cold_s", Json::Num(cold.median));
            o.set("delta_s", Json::Num(delta.median));
            o.set("speedup", Json::Num(speedup));
            b.meta(&format!("replan/{name}/{label}"), o);
        }
    }

    // A 4-iteration MCL prune chain: each iteration's prune step dirties
    // part of the flow structure, and the executor patches the displaced
    // slot plan instead of replanning cold — the same workload `repro
    // planreuse` reports on.
    b.group("incremental/mcl-prune-chain");
    let ds = gen::table2_by_name("Economics").unwrap();
    let g = (ds.gen)(1);
    let params = MclParams { max_iters: 4, tol: 0.0, top_k: 16, ..Default::default() };
    b.bench("mcl-4-iter/delta-executor", || {
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        ex.attach_plan_store(TieredStore::mem_only());
        let r = mcl(&g, &params, &mut ex);
        bb(r.iterations)
    });
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    ex.attach_plan_store(TieredStore::mem_only());
    let r = mcl(&g, &params, &mut ex);
    let mut o = Json::obj();
    o.set("iterations", r.iterations.into());
    o.set("plan_hits", r.plan_hits.into());
    o.set("plan_deltas", r.plan_deltas.into());
    o.set("plan_misses", r.plan_misses.into());
    o.set("delta_rows", r.delta_rows.into());
    b.meta("mcl_prune_chain", o);

    b.finish("incremental");
}
