//! Bench: graph applications (paper Figs. 7–8 workloads) — wall time of
//! the functional pipelines plus the simulated three-variant pricing on
//! one representative dataset.

use spgemm_aia::apps::{contract, mcl, random_labels, MclParams};
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen;
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::Pcg32;

fn main() {
    let mut b = Bencher::new();
    let ds = gen::table2_by_name("Economics").unwrap();
    let g = (ds.gen)(1);
    let mut rng = Pcg32::seeded(9);
    let labels = random_labels(g.n_rows, g.n_rows / 4, &mut rng);
    let params = MclParams { max_iters: 2, tol: 1e-3, top_k: 8, ..Default::default() };

    b.group("contraction/Economics");
    b.bench("functional(wall)", || {
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        bb(contract(&g, &labels, &mut ex).contracted.nnz())
    });
    for v in Variant::all() {
        b.bench(&format!("simulated/{}", v.name()), || {
            let mut ex = SpgemmExecutor::simulated_scaled(v, ds.scale);
            bb(contract(&g, &labels, &mut ex).sim_ms)
        });
    }

    b.group("mcl/Economics (2 iterations)");
    b.bench("functional(wall)", || {
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        bb(mcl(&g, &params, &mut ex).n_clusters)
    });
    for v in [Variant::HashAia, Variant::Cusparse] {
        b.bench(&format!("simulated/{}", v.name()), || {
            let mut ex = SpgemmExecutor::simulated_scaled(v, ds.scale);
            bb(mcl(&g, &params, &mut ex).sim_ms)
        });
    }

    b.finish("apps");
}
