//! Bench: plan-reuse batched execution (ROADMAP "Batched multi-matrix
//! execution") on the MCL self-product workload.
//!
//! An MCL iteration re-multiplies the flow matrix against a structure
//! that stabilises as clustering converges, so the symbolic phase can be
//! planned once and amortised. This bench pins that win: a cold
//! `multiply` (plan + fill every iteration) against a reused-plan
//! numeric fill, an expansion chain of 4 iterations both ways, and the
//! pipelined `BatchExecutor` path where planning of product k+1 hides
//! behind the fill of product k, and the cold-process disk-hit path
//! where a plan persisted by one `BatchExecutor`'s store is loaded,
//! validated, and filled by a fresh one (the `--plan-cache` /
//! `SPGEMM_AIA_PLAN_CACHE` cross-process win — the bench honors that
//! env var for its cache directory, so CI can warm the disk tier in one
//! invocation and hit it in the next). Per-dataset speedups and the
//! plan/fill split land in the JSON meta; CI archives
//! `BENCH_plan_reuse.json` as part of the perf trajectory.

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::gen;
use spgemm_aia::spgemm::hash::{self, PlannedProduct, TieredStore};
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] = if quick {
        &["Economics", "scircuit"]
    } else {
        &["Economics", "scircuit", "p2p-Gnutella04", "amazon0601", "cage15"]
    };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        b.group(&format!("plan_reuse/{name}"));

        // One MCL expansion, cold: grouping + symbolic + numeric per call.
        let cold = b.bench("cold plan+fill", || bb(hash::multiply(&a, &a).nnz()));
        // One MCL expansion with the structure already planned: numeric only.
        let plan = PlannedProduct::plan(&a, &a);
        let reused = b.bench("reused fill", || bb(plan.fill(&a, &a).nnz()));
        let speedup = cold.median / reused.median;
        println!("  -> reused-plan fill speedup over cold plan+fill: {speedup:.2}x");
        b.meta(&format!("reuse_speedup/{name}"), Json::Num(speedup));
        b.meta(&format!("plan_times/{name}"), plan.plan_times.to_json());

        // A 4-iteration expansion chain (structure stable), both ways.
        let chain_cold = b.bench("mcl-chain-4/cold", || {
            let mut nnz = 0;
            for _ in 0..4 {
                nnz = hash::multiply(&a, &a).nnz();
            }
            bb(nnz)
        });
        let chain_reused = b.bench("mcl-chain-4/reused", || {
            let p = PlannedProduct::plan(&a, &a);
            let mut nnz = 0;
            for _ in 0..4 {
                nnz = p.fill(&a, &a).nnz();
            }
            bb(nnz)
        });
        b.meta(&format!("chain4_speedup/{name}"), Json::Num(chain_cold.median / chain_reused.median));

        // Cold-process disk hit: the plan was persisted by one
        // executor's store (a previous process when the plan-cache env
        // dir is warm, the writer below otherwise); each iteration
        // stands in for a fresh process — a new BatchExecutor whose
        // memory tier is cold loads, validates, and fills from disk.
        let cache_dir = hash::default_plan_cache_dir()
            .unwrap_or_else(|| std::env::temp_dir().join("spgemm-aia-bench-plan-cache"));
        let mut writer = BatchExecutor::with_store(4, TieredStore::with_disk(&cache_dir));
        writer.multiply_cached(&a, &a); // ensure the plan file exists
        let disk_hit = b.bench("cold-process disk-hit fill", || {
            let mut bx = BatchExecutor::with_store(4, TieredStore::with_disk(&cache_dir));
            bb(bx.multiply_cached(&a, &a).nnz())
        });
        b.meta(&format!("disk_hit_speedup/{name}"), Json::Num(cold.median / disk_hit.median));
        // Counters from one representative cold-process run: a clean
        // hit is 1 disk hit, 0 plans built, 0 corrupt files.
        let mut probe = BatchExecutor::with_store(4, TieredStore::with_disk(&cache_dir));
        probe.multiply_cached(&a, &a);
        let mut dj = Json::obj();
        dj.set("disk_hits", probe.stats.disk_hits.into());
        dj.set("plans_built", probe.stats.plans_built.into());
        dj.set("disk_corrupt", probe.stats.disk_corrupt.into());
        dj.set("writer_disk_hits", writer.stats.disk_hits.into());
        b.meta(&format!("disk_tier/{name}"), dj);

        // Pipelined batch over 4 structurally distinct products (the
        // planner thread overlaps the fills; identical structures would
        // be deduped to one plan) vs the serial equivalent. Pinned to a
        // memory-only store: with a plan-cache env dir set, the process
        // default would turn iterations 2+ into disk hits and this
        // scenario would stop measuring the overlap it names.
        let variants: Vec<_> = (0..4u64).map(|k| (ds.gen)(1 + k)).collect();
        let pairs: Vec<_> = variants.iter().map(|m| (m, m)).collect();
        let serial = b.bench("batch-4-distinct/serial", || {
            bb(variants.iter().map(|m| hash::multiply(m, m).nnz()).sum::<usize>())
        });
        let piped = b.bench("batch-4-distinct/pipelined", || {
            let mut bx = BatchExecutor::with_store(4, TieredStore::mem_only());
            bb(bx.execute_batch(&pairs).len())
        });
        b.meta(&format!("batch_pipeline_speedup/{name}"), Json::Num(serial.median / piped.median));
        let mut bx = BatchExecutor::with_store(4, TieredStore::mem_only());
        bx.execute_batch(&pairs);
        if let Some(r) = &bx.last_batch {
            b.meta(&format!("batch_overlap_speedup/{name}"), Json::Num(r.overlap_speedup()));
            b.meta(&format!("batch_stream_utilization/{name}"), Json::Num(r.streams.utilization()));
        }
    }
    b.finish("plan_reuse");
}
