//! Bench: plan-reuse batched execution (ROADMAP "Batched multi-matrix
//! execution") on the MCL self-product workload.
//!
//! An MCL iteration re-multiplies the flow matrix against a structure
//! that stabilises as clustering converges, so the symbolic phase can be
//! planned once and amortised. This bench pins that win: a cold
//! `multiply` (plan + fill every iteration) against a reused-plan
//! numeric fill, an expansion chain of 4 iterations both ways, and the
//! pipelined `BatchExecutor` path where planning of product k+1 hides
//! behind the fill of product k. Per-dataset speedups and the plan/fill
//! split land in the JSON meta; CI archives `BENCH_plan_reuse.json` as
//! part of the perf trajectory.

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::gen;
use spgemm_aia::spgemm::hash::{self, PlannedProduct};
use spgemm_aia::util::bench::{bb, Bencher};
use spgemm_aia::util::json::Json;

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let names: &[&str] = if quick {
        &["Economics", "scircuit"]
    } else {
        &["Economics", "scircuit", "p2p-Gnutella04", "amazon0601", "cage15"]
    };

    for name in names {
        let ds = gen::table2_by_name(name).unwrap();
        let a = (ds.gen)(1);
        b.group(&format!("plan_reuse/{name}"));

        // One MCL expansion, cold: grouping + symbolic + numeric per call.
        let cold = b.bench("cold plan+fill", || bb(hash::multiply(&a, &a).nnz()));
        // One MCL expansion with the structure already planned: numeric only.
        let plan = PlannedProduct::plan(&a, &a);
        let reused = b.bench("reused fill", || bb(plan.fill(&a, &a).nnz()));
        let speedup = cold.median / reused.median;
        println!("  -> reused-plan fill speedup over cold plan+fill: {speedup:.2}x");
        b.meta(&format!("reuse_speedup/{name}"), Json::Num(speedup));
        b.meta(&format!("plan_times/{name}"), plan.plan_times.to_json());

        // A 4-iteration expansion chain (structure stable), both ways.
        let chain_cold = b.bench("mcl-chain-4/cold", || {
            let mut nnz = 0;
            for _ in 0..4 {
                nnz = hash::multiply(&a, &a).nnz();
            }
            bb(nnz)
        });
        let chain_reused = b.bench("mcl-chain-4/reused", || {
            let p = PlannedProduct::plan(&a, &a);
            let mut nnz = 0;
            for _ in 0..4 {
                nnz = p.fill(&a, &a).nnz();
            }
            bb(nnz)
        });
        b.meta(&format!("chain4_speedup/{name}"), Json::Num(chain_cold.median / chain_reused.median));

        // Pipelined batch over 4 structurally distinct products (the
        // planner thread overlaps the fills; identical structures would
        // be deduped to one plan) vs the serial equivalent.
        let variants: Vec<_> = (0..4u64).map(|k| (ds.gen)(1 + k)).collect();
        let pairs: Vec<_> = variants.iter().map(|m| (m, m)).collect();
        let serial = b.bench("batch-4-distinct/serial", || {
            bb(variants.iter().map(|m| hash::multiply(m, m).nnz()).sum::<usize>())
        });
        let piped = b.bench("batch-4-distinct/pipelined", || {
            let mut bx = BatchExecutor::new(4);
            bb(bx.execute_batch(&pairs).len())
        });
        b.meta(&format!("batch_pipeline_speedup/{name}"), Json::Num(serial.median / piped.median));
        let mut bx = BatchExecutor::new(4);
        bx.execute_batch(&pairs);
        if let Some(r) = &bx.last_batch {
            b.meta(&format!("batch_overlap_speedup/{name}"), Json::Num(r.overlap_speedup()));
            b.meta(&format!("batch_stream_utilization/{name}"), Json::Num(r.streams.utilization()));
        }
    }
    b.finish("plan_reuse");
}
