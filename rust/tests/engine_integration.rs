//! Integration tests across the sparse substrate, SpGEMM engines, the
//! simulator, and the applications — on registry-scale inputs.

use spgemm_aia::apps::{contract, mcl, random_labels, MclParams};
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen::{self, rmat, RmatParams};
use spgemm_aia::sim::{simulate_spgemm, simulate_spgemm_full, AiaMode, SimConfig};
use spgemm_aia::spgemm::{esc, hash, ip, reference::spgemm_reference, Algo};
use spgemm_aia::util::{qc, Pcg32};

#[test]
fn engines_agree_on_registry_dataset() {
    // p2p-Gnutella04 analogue is full-scale and quick.
    let ds = gen::table2_by_name("p2p-Gnutella04").unwrap();
    let a = (ds.gen)(1);
    let h = hash::multiply(&a, &a);
    let e = esc::multiply(&a, &a);
    assert_eq!(h.rpt, e.rpt);
    assert_eq!(h.col, e.col);
    assert!(h.approx_eq(&e, 1e-9));
    assert!(h.validate().is_ok());
}

#[test]
fn every_table2_generator_is_deterministic_and_valid() {
    for ds in gen::table2_datasets() {
        let a = (ds.gen)(7);
        let b = (ds.gen)(7);
        assert_eq!(a, b, "{} not deterministic", ds.paper.name);
        assert!(a.validate().is_ok(), "{} invalid", ds.paper.name);
        assert!(a.nnz() > 0);
    }
}

#[test]
fn stats_trace_matches_full_trace_counters() {
    // The stats-only path at every=1 must count the same accesses as the
    // full traced path.
    let mut rng = Pcg32::seeded(5);
    let a = rmat(800, 8000, RmatParams::web(), &mut rng);
    let cfg = SimConfig { sample: Some(1), ..SimConfig::new(AiaMode::Off) };
    let (_, full) = simulate_spgemm_full(Algo::Hash, &a, &a, &cfg);
    let stats = spgemm_aia::sim::simulate_stats(Algo::Hash, &a, &a, &cfg);
    for (pf, ps) in full.phases.iter().zip(&stats.phases) {
        assert_eq!(pf.phase, ps.phase);
        assert_eq!(pf.accesses, ps.accesses, "access count mismatch in {:?}", pf.phase);
        assert!((pf.l1_hit_ratio - ps.l1_hit_ratio).abs() < 1e-12);
    }
}

#[test]
fn simulated_executor_product_is_exact_across_variants() {
    let mut rng = Pcg32::seeded(6);
    let a = rmat(1500, 15_000, RmatParams::citation(), &mut rng);
    let oracle = spgemm_reference(&a, &a);
    for v in Variant::all() {
        let mut ex = SpgemmExecutor::simulated(v);
        let c = ex.multiply(&a, &a);
        assert!(c.approx_eq(&oracle, 1e-9), "variant {} wrong", v.name());
        assert!(ex.sim_ms > 0.0);
    }
}

#[test]
fn mcl_pipeline_on_registry_graph() {
    let ds = gen::table2_by_name("Economics").unwrap();
    let g = (ds.gen)(3);
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    let r = mcl(&g, &MclParams { max_iters: 3, tol: 1e-3, top_k: 8, ..Default::default() }, &mut ex);
    assert!(r.n_clusters > 0);
    assert_eq!(r.clusters.len(), g.n_rows);
    assert!(ex.jobs >= 1);
}

#[test]
fn contraction_shrinks_and_preserves_weight() {
    let ds = gen::table2_by_name("RoadTX").unwrap();
    let g = (ds.gen)(3);
    let mut rng = Pcg32::seeded(4);
    let labels = random_labels(g.n_rows, g.n_rows / 8, &mut rng);
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    let r = contract(&g, &labels, &mut ex);
    assert!(r.contracted.n_rows <= g.n_rows / 4);
    let w0: f64 = g.val.iter().sum();
    let w1: f64 = r.contracted.val.iter().sum();
    assert!((w0 - w1).abs() < 1e-6 * w0.abs().max(1.0));
}

#[test]
fn aia_improves_l1_hit_ratio_on_scattered_workload() {
    let ds = gen::table2_by_name("scircuit").unwrap();
    let a = (ds.gen)(20250710);
    let (_, off) = simulate_spgemm(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::Off, ds.scale));
    let (_, on) = simulate_spgemm(Algo::Hash, &a, &a, &SimConfig::for_scale(AiaMode::On, ds.scale));
    use spgemm_aia::sim::probe::Phase;
    let off_alloc = off.phase(Phase::Allocation).unwrap().l1_hit_ratio;
    let on_alloc = on.phase(Phase::Allocation).unwrap().l1_hit_ratio;
    assert!(on_alloc > off_alloc + 0.05, "alloc hit ratio: {off_alloc} -> {on_alloc}");
    // paper: allocation improves more than accumulation
    let off_acc = off.phase(Phase::Accumulation).unwrap().l1_hit_ratio;
    let on_acc = on.phase(Phase::Accumulation).unwrap().l1_hit_ratio;
    assert!((on_alloc - off_alloc) > (on_acc - off_acc) - 0.02);
}

#[test]
fn property_engines_agree_on_random_rectangular_products() {
    qc::check(12, 777, |g| {
        let m = 1 + g.dim() * 3;
        let k = 1 + g.dim() * 2;
        let n = 1 + g.dim() * 3;
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let mut coo_a = spgemm_aia::sparse::Coo::new(m, k);
        let mut coo_b = spgemm_aia::sparse::Coo::new(k, n);
        for _ in 0..(m * k / 6).max(1) {
            coo_a.push(rng.below_usize(m), rng.below_usize(k), rng.f64_range(-1.0, 1.0));
        }
        for _ in 0..(k * n / 6).max(1) {
            coo_b.push(rng.below_usize(k), rng.below_usize(n), rng.f64_range(-1.0, 1.0));
        }
        let a = coo_a.to_csr();
        let b = coo_b.to_csr();
        let r = spgemm_reference(&a, &b);
        assert!(hash::multiply(&a, &b).approx_eq(&r, 1e-10));
        assert!(esc::multiply(&a, &b).approx_eq(&r, 1e-10));
    });
}

#[test]
fn property_spgemm_distributes_over_identity_padding() {
    // (A·I)·B == A·(I·B) == A·B on random inputs.
    qc::check(8, 999, |g| {
        let n = 2 + g.dim();
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let mut coo = spgemm_aia::sparse::Coo::new(n, n);
        for _ in 0..(n * n / 4).max(1) {
            coo.push(rng.below_usize(n), rng.below_usize(n), rng.f64_range(-1.0, 1.0));
        }
        let a = coo.to_csr();
        let i = spgemm_aia::sparse::Csr::identity(n);
        let ab = hash::multiply(&a, &a);
        let a_ib = hash::multiply(&hash::multiply(&a, &i), &a);
        assert!(ab.approx_eq(&a_ib, 1e-10));
    });
}
