//! Accumulator-selection properties (`util/qc.rs` harness): the three
//! numeric paths — scaled-copy, hash, dense-SPA — must be
//! **bit-identical** to each other and to the reference oracle across
//! the RMAT and structured generators at any threshold, the threshold
//! boundary semantics must hold exactly (`0.0` forces SPA on every
//! multi-entry row, `1.0+` disables it), and the plan-guided paths must
//! survive the coordinator's per-bin batch pipeline unchanged.

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::{Coo, Csr};
use spgemm_aia::spgemm::hash::{self, AccumKind, EngineConfig, PlannedProduct, PlannerPolicy, TieredStore};
use spgemm_aia::spgemm::reference::spgemm_reference;
use spgemm_aia::util::{qc, Pcg32};

const THRESHOLDS: [f64; 5] = [0.0, 0.1, 0.25, 0.5, 1.0];

/// Exact-planner config at `spa_threshold` (the literal would blow past
/// `max_width` at every call site).
fn cfg_at(spa_threshold: f64) -> EngineConfig {
    EngineConfig { spa_threshold, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None }
}

fn dense_random(rng: &mut Pcg32, n: usize, density: f64) -> Csr {
    let mut coo = Coo::new(n, n);
    for _ in 0..((n * n) as f64 * density) as usize {
        coo.push(rng.below_usize(n), rng.below_usize(n), rng.f64_range(-2.0, 2.0));
    }
    coo.to_csr()
}

#[test]
fn property_accumulator_paths_bit_identical_rmat() {
    qc::check(10, 9090, |g| {
        let n = 16 + g.dim() * 8;
        let nnz = n * (2 + g.rng.below_usize(8));
        let params = match g.rng.below_usize(3) {
            0 => RmatParams::web(),
            1 => RmatParams::citation(),
            _ => RmatParams::uniform(),
        };
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, nnz, params, &mut rng);
        let oracle = spgemm_reference(&a, &a);
        let baseline = hash::multiply_cfg(&a, &a, &cfg_at(2.0));
        assert_eq!(baseline.rpt, oracle.rpt, "hash-only structure vs oracle");
        assert!(baseline.approx_eq(&oracle, 1e-10), "hash-only values vs oracle");
        for thr in THRESHOLDS {
            let c = hash::multiply_cfg(&a, &a, &cfg_at(thr));
            assert_eq!(c, baseline, "threshold {thr}: all accumulator paths must agree bit-for-bit");
        }
    });
}

#[test]
fn property_accumulator_paths_bit_identical_structured() {
    qc::check(8, 4242, |g| {
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let n = 32 + g.dim() * 4;
        let (name, a) = match g.rng.below_usize(4) {
            0 => ("protein", structured::protein_contact(n, 24, &mut rng)),
            1 => ("fem_banded", structured::fem_banded(n, 12, &mut rng)),
            2 => ("circuit", structured::circuit(n, &mut rng)),
            _ => ("economics", structured::economics(n, &mut rng)),
        };
        let baseline = hash::multiply_cfg(&a, &a, &cfg_at(2.0));
        for thr in THRESHOLDS {
            let c = hash::multiply_cfg(&a, &a, &cfg_at(thr));
            assert_eq!(c, baseline, "{name} at threshold {thr}: paths must agree bit-for-bit");
        }
    });
}

#[test]
fn threshold_zero_forces_spa_threshold_one_disables() {
    let mut rng = Pcg32::seeded(77);
    let a = dense_random(&mut rng, 96, 0.4);
    // 0.0: every multi-entry row with output goes SPA; hash bins vanish.
    let plan = hash::symbolic_cfg(&a, &a, &cfg_at(0.0));
    assert!(plan.bins.iter().all(|b| b.kind != AccumKind::Hash), "0.0 must force SPA");
    assert!(plan.kind_rows()[AccumKind::Spa.index()] > 0, "0.0 must produce SPA bins");
    // 1.0 and above: SPA disabled even on fully dense rows (strict >).
    for thr in [1.0, 4.0] {
        let plan = hash::symbolic_cfg(&a, &a, &cfg_at(thr));
        assert!(
            plan.bins.iter().all(|b| b.kind != AccumKind::Spa),
            "threshold {thr} must disable SPA"
        );
    }
    // Scaled-copy rows stay scaled-copy regardless of the threshold.
    let d = Csr::from_diag(&[1.5; 96]);
    for thr in [0.0, 0.25, 2.0] {
        let plan = hash::symbolic_cfg(&d, &a, &cfg_at(thr));
        assert!(
            plan.bins.iter().all(|b| b.kind == AccumKind::ScaledCopy),
            "diagonal A must stay on the copy path at threshold {thr}"
        );
    }
}

#[test]
fn planned_fills_reuse_the_accumulator_decision() {
    let mut rng = Pcg32::seeded(5);
    let a = dense_random(&mut rng, 80, 0.35);
    for thr in THRESHOLDS {
        let cfg = EngineConfig {
            spa_threshold: thr,
            symbolic_threshold: None,
            planner: PlannerPolicy::Exact,
            mask: None,
        };
        let p = PlannedProduct::plan_cfg(&a, &a, &cfg);
        assert_eq!(p.symbolic_plan().spa_threshold, thr, "plan must record its threshold");
        let cold = hash::multiply_cfg(&a, &a, &cfg);
        assert_eq!(p.fill(&a, &a), cold, "reused fill vs cold multiply at threshold {thr}");
        // Value-only updates keep both the plan and the kind decision.
        let mut a2 = a.clone();
        a2.map_values(|v| v * 0.5 + 2.0);
        assert!(p.matches(&a2, &a2));
        assert_eq!(p.fill(&a2, &a2), hash::multiply_cfg(&a2, &a2, &cfg));
    }
}

/// Half the rows are dense (SPA at the default threshold), half have
/// two entries pointing into the sparse half (tiny outputs → hash), so
/// a self-product is guaranteed to carry both bin kinds.
fn mixed_density(n: usize, rng: &mut Pcg32) -> Csr {
    let half = n / 2;
    let mut coo = Coo::new(n, n);
    for i in 0..half {
        for j in 0..n {
            if rng.coin(0.5) {
                coo.push(i, j, rng.f64_range(-1.0, 1.0));
            }
        }
    }
    for i in half..n {
        coo.push(i, half + (i * 7) % half, 1.0);
        coo.push(i, half + (i * 13 + 5) % half, -0.5);
    }
    coo.to_csr()
}

#[test]
fn batch_pipeline_preserves_spa_outputs() {
    // The per-bin batch pipeline fills SPA/hash/copy bins as separate
    // completion events; outputs must still equal serial multiplies
    // bit-for-bit (default threshold — mixed inputs guarantee both SPA
    // and hash bins in one product).
    let mut rng = Pcg32::seeded(31);
    let a = mixed_density(90, &mut rng);
    let b = mixed_density(90, &mut rng);
    let kinds = hash::symbolic(&a, &a).kind_rows();
    assert!(kinds[AccumKind::Spa.index()] > 0, "test needs SPA rows at the default threshold");
    assert!(kinds[AccumKind::Hash.index()] > 0, "test needs hash rows alongside the SPA rows");
    let pairs = [(&a, &a), (&a, &b), (&b, &b), (&a, &a)];
    // Memory-only store: keep this pipeline test off any plan-cache
    // directory a shell-exported SPGEMM_AIA_PLAN_CACHE might name.
    let mut ex = BatchExecutor::with_store(4, TieredStore::mem_only());
    let out = ex.execute_batch(&pairs);
    for (i, &(x, y)) in pairs.iter().enumerate() {
        assert_eq!(out[i], hash::multiply(x, y), "batch product {i} vs serial multiply");
    }
    let report = ex.last_batch.as_ref().expect("batch ran");
    assert!(report.bins > report.products, "mixed products must split into multiple bins");
    assert!(report.fill_kind_s[AccumKind::Spa.index()] > 0.0, "SPA bins must be timed");
}

#[test]
fn empty_and_degenerate_rows_never_select_spa_wrongly() {
    // Zero matrix, identity, and a matrix with empty B rows: every path
    // must agree at extreme thresholds.
    let z = Csr::zeros(8, 8);
    let i = Csr::identity(16);
    let mut rng = Pcg32::seeded(13);
    let m = dense_random(&mut rng, 16, 0.3);
    for thr in [0.0, 0.25, 2.0] {
        let cfg = EngineConfig {
            spa_threshold: thr,
            symbolic_threshold: None,
            planner: PlannerPolicy::Exact,
            mask: None,
        };
        assert_eq!(hash::multiply_cfg(&z, &z, &cfg).nnz(), 0);
        let half = EngineConfig {
            spa_threshold: 0.5,
            symbolic_threshold: None,
            planner: PlannerPolicy::Exact,
            mask: None,
        };
        assert_eq!(hash::multiply_cfg(&i, &m, &cfg), hash::multiply_cfg(&i, &m, &half));
        let plan = hash::symbolic_cfg(&z, &z, &cfg);
        assert!(plan.bins.is_empty(), "zero output must produce no numeric bins");
        assert_eq!(plan.accumulator_kind(0), None);
    }
}
