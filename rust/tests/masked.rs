//! Acceptance harness for masked SpGEMM (`C = M ⊙ (A·B)`, DESIGN.md
//! §2i):
//!
//! - the masked engine must be **bit-identical** to the
//!   multiply-then-filter oracle `M.filter(A·B)` across RMAT and
//!   structured generators × {empty, full, band, block, A-as-mask,
//!   random-rectangular} masks × planner policies;
//! - the masked symbolic phase must never count a mask-rejected entry:
//!   per-row masked counts ≤ unmasked counts, with strict shrinkage on
//!   a sparse mask (the perf claim's structural precondition);
//! - masked plans round-trip through the tiered store's disk tier
//!   (SAPL v3) under their own fingerprint, invisible to unmasked
//!   lookups, and delta-patch like any other plan — a mask change
//!   rebuilds.

use spgemm_aia::coordinator::batch::{BatchExecutor, PlanSource};
use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::{Coo, Csr};
use spgemm_aia::spgemm::hash::{
    self, delta_patch, mutate_row_fraction, DeltaOutcome, EngineConfig, Mask, PlannedProduct,
    PlannerPolicy, TieredStore,
};
use spgemm_aia::util::Pcg32;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("spgemm-aia-masked-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn empty_mask(n_rows: usize, n_cols: usize) -> Csr {
    Csr::new_unchecked(n_rows, n_cols, vec![0; n_rows + 1], Vec::new(), Vec::new())
}

fn random_mask(n_rows: usize, n_cols: usize, nnz: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::seeded(seed);
    let mut coo = Coo::new(n_rows, n_cols);
    for _ in 0..nnz {
        coo.push(rng.below_usize(n_rows), rng.below_usize(n_cols), 1.0);
    }
    coo.to_csr()
}

fn generators() -> Vec<(&'static str, Csr)> {
    let mut rng = Pcg32::seeded(77);
    vec![
        ("rmat-web", rmat(180, 1400, RmatParams::web(), &mut rng)),
        ("rmat-uniform", rmat(160, 1100, RmatParams::uniform(), &mut rng)),
        ("circuit", structured::circuit(220, &mut rng)),
        ("economics", structured::economics(200, &mut rng)),
        ("community", structured::community_powerlaw(150, 8, 6, &mut rng)),
    ]
}

/// Every mask class the feature claims to support, for a square
/// self-product of side `n`.
fn mask_suite(a: &Csr) -> Vec<(&'static str, Mask)> {
    let n = a.n_rows;
    vec![
        ("empty", Mask::from_structure(&empty_mask(n, n))),
        ("full", Mask::from_structure(&structured::band_mask(n, n))),
        ("band", Mask::from_structure(&structured::band_mask(n, n / 16 + 1))),
        ("block", Mask::from_structure(&structured::block_mask(n, n / 8 + 1))),
        ("a-as-mask", Mask::from_structure(a)),
        ("random", Mask::from_structure(&random_mask(n, n, n * 4, 99))),
    ]
}

#[test]
fn masked_multiply_is_bit_identical_to_the_filter_oracle() {
    for (gname, a) in generators() {
        let full = hash::multiply(&a, &a);
        for (mname, mask) in mask_suite(&a) {
            let c = hash::multiply_masked(&a, &a, &mask);
            let oracle = mask.filter(&full);
            assert_eq!(c, oracle, "{gname} x {mname}: masked product != filtered oracle");
        }
    }
}

#[test]
fn masked_rectangular_product_matches_the_oracle() {
    let mut rng = Pcg32::seeded(31);
    let a = rmat(128, 900, RmatParams::web(), &mut rng); // 128x128
    let mut coo = Coo::new(128, 96);
    for _ in 0..700 {
        coo.push(rng.below_usize(128), rng.below_usize(96), rng.f64_range(-1.0, 1.0));
    }
    let b = coo.to_csr();
    let mask = Mask::from_structure(&random_mask(128, 96, 640, 13));
    let c = hash::multiply_masked(&a, &b, &mask);
    assert_eq!(c, mask.filter(&hash::multiply(&a, &b)), "rectangular masked product diverged");
}

/// Acceptance criterion: the masked path never materializes (or even
/// counts) a mask-rejected entry — per-row symbolic counts under a mask
/// are bounded by the unmasked ones, and a sparse mask strictly shrinks
/// the total on these workloads.
#[test]
fn masked_symbolic_counts_never_exceed_unmasked() {
    for (gname, a) in generators() {
        let plain = hash::symbolic(&a, &a);
        for (mname, mask) in mask_suite(&a) {
            let cfg = EngineConfig { mask: Some(mask.clone()), ..EngineConfig::default() };
            let masked = hash::symbolic_cfg(&a, &a, &cfg);
            for r in 0..a.n_rows {
                let (m, p) = (masked.rpt[r + 1] - masked.rpt[r], plain.rpt[r + 1] - plain.rpt[r]);
                assert!(m <= p, "{gname} x {mname} row {r}: masked count {m} > unmasked {p}");
            }
            if mname == "empty" {
                assert_eq!(*masked.rpt.last().unwrap(), 0, "{gname}: empty mask must count 0");
            }
            if mname == "band" {
                assert!(
                    *masked.rpt.last().unwrap() < *plain.rpt.last().unwrap(),
                    "{gname}: a narrow band mask must strictly shrink the symbolic total"
                );
            }
        }
    }
}

/// The oracle holds under every planner policy: masked products never
/// speculate (`Estimated`/`Auto` degrade to exact planning), so the
/// result stays bit-identical and the estimate counter stays at zero.
#[test]
fn masked_output_is_policy_invariant_and_never_speculates() {
    let mut rng = Pcg32::seeded(41);
    let a = rmat(170, 1200, RmatParams::web(), &mut rng);
    let mask = Mask::from_structure(&structured::band_mask(170, 11));
    let oracle = mask.filter(&hash::multiply(&a, &a));
    for policy in [PlannerPolicy::Exact, PlannerPolicy::Estimated, PlannerPolicy::Auto] {
        let mut ex = BatchExecutor::new(2);
        let (c, _info) = ex.multiply_cached_masked_policy(&a, &a, &mask, policy);
        assert_eq!(c, oracle, "{policy:?}: masked product diverged");
        assert_eq!(ex.stats.estimated_plans, 0, "{policy:?}: masked products must not speculate");
    }
}

/// Masked plans persist to disk (SAPL v3) under a mask-extended
/// fingerprint: a fresh process reloads them, unmasked lookups of the
/// same operands never see them, and the reloaded plan fills to the
/// oracle.
#[test]
fn masked_plan_roundtrips_through_the_disk_tier() {
    let dir = tmp_dir("roundtrip");
    let mut rng = Pcg32::seeded(59);
    let a = rmat(140, 1000, RmatParams::uniform(), &mut rng);
    let mask = Mask::from_structure(&structured::block_mask(140, 20));
    let oracle = mask.filter(&hash::multiply(&a, &a));

    let mut writer = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    let (c, info) = writer.multiply_cached_masked_policy(&a, &a, &mask, PlannerPolicy::Exact);
    assert_eq!(info.source, PlanSource::Fresh);
    assert_eq!(c, oracle);

    // Fresh process analogue: new executor, same disk tier.
    let mut reader = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    let (c2, info2) = reader.multiply_cached_masked_policy(&a, &a, &mask, PlannerPolicy::Exact);
    assert_eq!(info2.source, PlanSource::Disk, "masked plan must reload from disk");
    assert_eq!(c2, oracle);

    // The unmasked product of the same operands is a different plan:
    // the masked file must be invisible to it.
    let (full, info3) = reader.multiply_cached_policy(&a, &a, PlannerPolicy::Exact);
    assert_eq!(info3.source, PlanSource::Fresh, "unmasked lookup must not see the masked plan");
    assert_eq!(full, hash::multiply(&a, &a));
    assert_eq!(reader.cached_plans(), 2, "masked and unmasked plans coexist in the store");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Delta patching under a mask: a small structural mutation patches the
/// masked plan to exactly the cold masked plan, and any mask change —
/// adding, dropping, or swapping — is a rebuild.
#[test]
fn masked_delta_patch_matches_cold_and_mask_changes_rebuild() {
    let mut rng = Pcg32::seeded(67);
    let a = rmat(210, 1600, RmatParams::web(), &mut rng);
    let mask = Mask::from_structure(&structured::band_mask(210, 13));
    let cfg = EngineConfig { mask: Some(mask.clone()), ..EngineConfig::default() };
    let base = PlannedProduct::plan_cfg(&a, &a, &cfg);

    let a2 = mutate_row_fraction(&a, 0.02, 23);
    match delta_patch(&base, &a2, &a, &cfg) {
        DeltaOutcome::Patched(p) => {
            let cold = PlannedProduct::plan_cfg(&a2, &a, &cfg);
            assert_eq!(p.plan.symbolic_plan().rpt, cold.symbolic_plan().rpt, "patched row sizes");
            assert_eq!(p.plan.mask_hash(), cold.mask_hash(), "patched mask lineage");
            assert_eq!(
                p.plan.fill(&a2, &a),
                mask.filter(&hash::multiply(&a2, &a)),
                "patched masked fill"
            );
        }
        DeltaOutcome::Rebuild(why) => panic!("2%-dirty masked patch refused: {why}"),
    }

    // Mask changes always rebuild: dropped, added, or swapped.
    let unmasked = EngineConfig::default();
    assert!(matches!(delta_patch(&base, &a2, &a, &unmasked), DeltaOutcome::Rebuild("mask changed")));
    let plain_base = PlannedProduct::plan(&a, &a);
    assert!(matches!(delta_patch(&plain_base, &a2, &a, &cfg), DeltaOutcome::Rebuild("mask changed")));
    let other = EngineConfig {
        mask: Some(Mask::from_structure(&structured::band_mask(210, 5))),
        ..EngineConfig::default()
    };
    assert!(matches!(delta_patch(&base, &a2, &a, &other), DeltaOutcome::Rebuild("mask changed")));
}
