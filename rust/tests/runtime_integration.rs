//! L2⇄L3 integration: PJRT loading and execution of the AOT artifacts,
//! and the hybrid GNN trainer end to end. Skipped gracefully when
//! `make artifacts` has not run (CI without Python).

use spgemm_aia::coordinator::executor::Variant;
use spgemm_aia::gnn::{Arch, GnnData, Trainer, CDIM, FDIM};
use spgemm_aia::runtime::{Runtime, Tensor};
use spgemm_aia::util::Pcg32;

fn runtime_or_skip() -> Option<Runtime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("[skip] built without the `pjrt` feature (std-only stub runtime)");
        return None;
    }
    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&dir).expect("PJRT client"))
}

#[test]
fn topk_artifact_masks_to_k_nonzeros() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 8192;
    let mut rng = Pcg32::seeded(1);
    let x = Tensor::matrix(n, FDIM, (0..n * FDIM).map(|_| rng.normal() as f32).collect());
    let out = rt.call("topk_mask", n, &[x]).unwrap().remove(0);
    assert_eq!(out.rows(), n);
    // generic floats: exactly k=8 survivors per row
    for i in 0..64 {
        let nnz = out.data[i * FDIM..(i + 1) * FDIM].iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, 8, "row {i}");
    }
}

#[test]
fn layer_fwd_matches_host_matmul() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 8192;
    let mut rng = Pcg32::seeded(2);
    let h = Tensor::matrix(n, FDIM, (0..n * FDIM).map(|_| rng.normal() as f32 * 0.1).collect());
    let w = Tensor::matrix(FDIM, FDIM, (0..FDIM * FDIM).map(|_| rng.normal() as f32 * 0.1).collect());
    let out = rt.call("layer_fwd", n, &[h.clone(), w.clone()]).unwrap();
    let (act, gate) = (&out[0], &out[1]);
    // spot-check a few rows against a host matmul
    for i in [0usize, 100, 8191] {
        for j in [0usize, 31, 63] {
            let mut z = 0f32;
            for k in 0..FDIM {
                z += h.data[i * FDIM + k] * w.data[k * FDIM + j];
            }
            let a = act.data[i * FDIM + j];
            assert!((a - z.max(0.0)).abs() < 1e-3, "({i},{j}): {a} vs {z}");
            assert_eq!(gate.data[i * FDIM + j] != 0.0, z > 0.0);
        }
    }
}

#[test]
fn loss_grad_artifact_is_softmax_xent() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 8192;
    // uniform logits -> loss = ln(16), dlogits rows sum to 0
    let logits = Tensor::zeros(vec![n as i64, CDIM as i64]);
    let mut y = vec![0f32; n * CDIM];
    for i in 0..n {
        y[i * CDIM + i % CDIM] = 1.0;
    }
    let out = rt.call("loss_grad", n, &[logits, Tensor::matrix(n, CDIM, y)]).unwrap();
    let loss = out[0].data[0];
    assert!((loss - (16f32).ln()).abs() < 1e-4, "loss={loss}");
    let row = &out[1].data[0..CDIM];
    let s: f32 = row.iter().sum();
    assert!(s.abs() < 1e-6);
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 8192;
    let x = Tensor::zeros(vec![n as i64, FDIM as i64]);
    rt.call("topk_mask", n, &[x.clone()]).unwrap();
    let compiled_after_first = rt.compiled_count();
    rt.call("topk_mask", n, &[x]).unwrap();
    assert_eq!(rt.compiled_count(), compiled_after_first);
    assert_eq!(rt.calls, 2);
}

#[test]
fn gnn_training_learns_on_all_architectures() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // small synthetic graph at the lowest artifact tier
    let adj = spgemm_aia::gen::structured::community_powerlaw(8192, 10, 16, &mut Pcg32::seeded(3));
    let data = GnnData::from_adj("it-test", adj, 11);
    for arch in Arch::all() {
        let mut trainer = Trainer::new(&mut rt, &data, arch, 5);
        trainer.lr = 2.0;
        let first = trainer.epoch().unwrap();
        let mut last = first.clone();
        for _ in 0..4 {
            last = trainer.epoch().unwrap();
        }
        assert!(
            last.loss < first.loss,
            "{}: loss did not decrease ({} -> {})",
            arch.name(),
            first.loss,
            last.loss
        );
        assert!(last.loss.is_finite());
        // SpGEMM jobs per epoch: fwd HIDDEN+1 plus bwd HIDDEN+1
        assert_eq!(last.spgemm_jobs, 6, "{}", arch.name());
        // variant pricing must order AIA <= noAIA for this workload class
        let aia = trainer.simulate_epoch_ms(Variant::HashAia);
        let sw = trainer.simulate_epoch_ms(Variant::Hash);
        let esc = trainer.simulate_epoch_ms(Variant::Cusparse);
        assert!(aia > 0.0 && sw > 0.0 && esc > sw * 0.5, "{}: {aia} {sw} {esc}", arch.name());
    }
}
