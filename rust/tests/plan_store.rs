//! Tiered plan-store acceptance tests (`DESIGN.md` §Plan persistence):
//!
//! - a plan written by one "process" (store instance) and loaded by
//!   another produces a `fill` result **bit-identical** to a cold
//!   `multiply`, with zero symbolic-phase seconds on the hit path
//!   (load + validation time still charged);
//! - the on-disk format round-trips across the RMAT and structured
//!   generators;
//! - every corruption case — truncated file, flipped version byte,
//!   stale fingerprint (file renamed under a foreign key) — degrades to
//!   a silent miss + clean replan, never a panic.

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::Csr;
use spgemm_aia::spgemm::hash::planstore::{DiskStore, PlanFingerprint, PlanStore, TieredStore};
use spgemm_aia::spgemm::hash::{self, DeltaOutcome, PlannedProduct};
use spgemm_aia::util::serial::fnv1a;
use spgemm_aia::util::Pcg32;
use std::path::PathBuf;
use std::sync::Arc;

/// Per-test scratch directory (tests run in parallel in one process —
/// the tag keeps them disjoint), cleaned on entry so every run is cold.
fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spgemm-aia-planstore-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn rmat_square(seed: u64, n: usize, per_row: usize) -> Csr {
    let mut rng = Pcg32::seeded(seed);
    rmat(n, n * per_row, RmatParams::uniform(), &mut rng)
}

/// The acceptance criterion, end to end on the batch path: plan written
/// by one executor, loaded by a fresh one (cold memory tier), fill
/// bit-identical to a cold multiply, zero symbolic seconds reported on
/// the hit path while validation time is still charged.
#[test]
fn cross_process_disk_hit_is_bit_identical_with_zero_symbolic_seconds() {
    let dir = scratch("cross-process");
    let a = rmat_square(1, 512, 6);
    let cold = hash::multiply(&a, &a);

    // "Process" 1: plans, fills, persists.
    let mut writer = BatchExecutor::with_store(4, TieredStore::with_disk(&dir));
    let c1 = writer.execute_batch(&[(&a, &a)]).remove(0);
    assert_eq!(c1, cold);
    assert_eq!(writer.stats.plans_built, 1);
    assert_eq!(writer.store_stats().stores, 1, "the fresh plan must be persisted");

    // "Process" 2: fresh executor, fresh store, same directory.
    let mut reader = BatchExecutor::with_store(4, TieredStore::with_disk(&dir));
    let c2 = reader.execute_batch(&[(&a, &a)]).remove(0);
    assert_eq!(c2, cold, "disk-hit fill must be bit-identical to a cold multiply");
    assert_eq!(reader.stats.plans_built, 0, "nothing replanned");
    assert_eq!((reader.stats.disk_hits, reader.stats.plan_hits, reader.stats.plan_misses), (1, 0, 0));
    let report = reader.last_batch.as_ref().expect("batch report recorded");
    assert_eq!(report.disk_hits, 1);
    assert_eq!(report.symbolic_kind_s, [0.0; 3], "the hit path must report zero symbolic-phase seconds");
    assert!(report.plan_s > 0.0, "load + fingerprint validation is still charged");
    assert!(reader.stats.hit_rate() > 0.99, "disk hits count as reuse");

    // And the cached entry point agrees: cold memory tier again, one
    // disk hit, promoted so the next call is a memory hit.
    let mut reader2 = BatchExecutor::with_store(4, TieredStore::with_disk(&dir));
    assert_eq!(reader2.multiply_cached(&a, &a), cold);
    assert_eq!(reader2.multiply_cached(&a, &a), cold);
    assert_eq!((reader2.stats.disk_hits, reader2.stats.plan_hits, reader2.stats.plans_built), (1, 1, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same criterion on the application entry point:
/// `SpgemmExecutor::multiply_reusing` with an attached store — a fresh
/// executor's slot miss is served by the disk tier, skipping the
/// symbolic phase (symbolic_s stays 0) while grouping_s charges the
/// load + validation.
#[test]
fn multiply_reusing_served_from_disk_skips_symbolic_phase() {
    let dir = scratch("reusing");
    let a = rmat_square(2, 384, 5);
    let cold = hash::multiply(&a, &a);

    let mut writer = SpgemmExecutor::fast(Variant::Hash);
    writer.attach_plan_store(TieredStore::with_disk(&dir));
    let mut slot = None;
    assert_eq!(writer.multiply_reusing(&mut slot, &a, &a), cold);
    assert_eq!((writer.plan_hits, writer.plan_misses, writer.disk_hits), (0, 1, 0));

    let mut reader = SpgemmExecutor::fast(Variant::Hash);
    reader.attach_plan_store(TieredStore::with_disk(&dir));
    let mut slot = None; // fresh process: no slot, cold memory tier
    let c = reader.multiply_reusing(&mut slot, &a, &a);
    assert_eq!(c, cold, "disk-served fill must be bit-identical to a cold multiply");
    assert_eq!((reader.plan_hits, reader.plan_misses, reader.disk_hits), (0, 0, 1));
    assert_eq!(reader.phase_times.symbolic_s, 0.0, "the symbolic phase must not run on a disk hit");
    assert!(reader.phase_times.grouping_s > 0.0, "load + validation time is still charged");
    assert!(reader.phase_times.numeric_s > 0.0, "the fill itself is timed");
    assert!(slot.is_some(), "the served plan lands in the slot for later in-process hits");
    assert!((reader.plan_hit_rate() - 1.0).abs() < 1e-12);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Round-trip across generators: persist, reload into a fresh store,
/// and compare the reloaded plan's `fill` bit-for-bit against a cold
/// `multiply`, for RMAT and each structured family.
#[test]
fn roundtrip_fill_matches_cold_multiply_across_generators() {
    let dir = scratch("generators");
    let mut rng = Pcg32::seeded(33);
    let mats: Vec<(&str, Csr)> = vec![
        ("rmat-web", rmat(192, 1400, RmatParams::web(), &mut rng)),
        ("rmat-citation", rmat(160, 1100, RmatParams::citation(), &mut rng)),
        ("circuit", structured::circuit(160, &mut rng)),
        ("economics", structured::economics(160, &mut rng)),
        ("fem_banded", structured::fem_banded(160, 4, &mut rng)),
        ("p2p", structured::p2p(160, &mut rng)),
        ("protein", structured::protein_contact(128, 6, &mut rng)),
    ];
    for (name, a) in &mats {
        let cold = hash::multiply(a, a);
        let mut store = DiskStore::new(&dir);
        store.put(Arc::new(PlannedProduct::plan(a, a)));
        let mut fresh = DiskStore::new(&dir);
        let fp = PlanFingerprint::of(a, a);
        let p = fresh.get(&fp).unwrap_or_else(|| panic!("{name}: persisted plan must load"));
        assert_eq!(p.fill(a, a), cold, "{name}: reloaded fill vs cold multiply");
        assert_eq!(p.nnz(), cold.nnz(), "{name}");
        assert_eq!(p.plan_times.total_s(), 0.0, "{name}: loaded plans carry no plan-time seconds");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Truncation at every byte boundary of a real plan file degrades to a
/// clean replan — silent miss, corrupt counter, correct output.
#[test]
fn truncated_plan_file_degrades_to_clean_replan() {
    let dir = scratch("truncate");
    let a = rmat_square(4, 256, 5);
    let cold = hash::multiply(&a, &a);
    let fp = PlanFingerprint::of(&a, &a);
    let mut writer = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    writer.multiply_cached(&a, &a);
    let path = DiskStore::new(&dir).path_for(fp.key());
    let bytes = std::fs::read(&path).expect("plan file written");
    // A sample of cut points, including pathological ones.
    for cut in [0usize, 1, 4, 8, bytes.len() / 3, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
        let c = ex.multiply_cached(&a, &a);
        assert_eq!(c, cold, "cut at {cut}: replanned output must match the cold multiply");
        assert_eq!(ex.stats.disk_corrupt, 1, "cut at {cut}: the corrupt file is counted");
        assert_eq!((ex.stats.disk_hits, ex.stats.plans_built), (0, 1), "cut at {cut}: silent miss + rebuild");
    }
    // The replan rewrote the file: the next cold process hits again.
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    ex.multiply_cached(&a, &a);
    assert_eq!((ex.stats.disk_hits, ex.stats.disk_corrupt), (1, 0), "replans must heal the cache");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A flipped version byte (or any other bit flip — the trailing
/// checksum covers the whole body) reads as corrupt and replans.
#[test]
fn flipped_version_byte_degrades_to_clean_replan() {
    let dir = scratch("version");
    let a = rmat_square(5, 256, 5);
    let cold = hash::multiply(&a, &a);
    let fp = PlanFingerprint::of(&a, &a);
    let mut writer = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    writer.multiply_cached(&a, &a);
    let path = DiskStore::new(&dir).path_for(fp.key());
    let mut bytes = std::fs::read(&path).expect("plan file written");
    bytes[4] ^= 0x01; // the version field sits right after the 4-byte magic
    std::fs::write(&path, &bytes).unwrap();
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex.multiply_cached(&a, &a), cold);
    assert_eq!((ex.stats.disk_corrupt, ex.stats.plans_built), (1, 1));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A structurally valid plan file sitting under the *wrong* key (e.g. a
/// renamed/copied cache entry) fails fingerprint validation: a stale
/// silent miss, a clean replan, and never a wrong result.
#[test]
fn stale_fingerprint_degrades_to_clean_replan() {
    let dir = scratch("stale");
    let a = rmat_square(6, 256, 5);
    let b = rmat_square(7, 256, 5); // same shape, different structure
    let cold_b = hash::multiply(&b, &b);
    let mut writer = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    writer.multiply_cached(&a, &a);
    // Masquerade a's plan file as b's.
    let ds = DiskStore::new(&dir);
    let a_path = ds.path_for(PlanFingerprint::of(&a, &a).key());
    let b_path = ds.path_for(PlanFingerprint::of(&b, &b).key());
    std::fs::rename(&a_path, &b_path).expect("rename plan file");
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    let c = ex.multiply_cached(&b, &b);
    assert_eq!(c, cold_b, "a stale plan must never leak into the output");
    assert_eq!(ex.stats.plans_built, 1, "stale fingerprint forces a replan");
    assert_eq!((ex.stats.disk_hits, ex.stats.disk_corrupt), (0, 0), "stale is a miss, not corruption");
    assert_eq!(ex.store_stats().stale, 1, "the store counts the stale file");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A plan persisted under a different `--spa-threshold` must not
/// override the current process's kernel selection: the disk tier
/// treats it as stale, replans under the configured knob, and the
/// rewrite heals the cache entry.
#[test]
fn foreign_threshold_plan_degrades_to_clean_replan() {
    let dir = scratch("threshold");
    let a = rmat_square(10, 256, 5);
    let cold = hash::multiply(&a, &a);
    // Simulate a previous run with a different knob by persisting a plan
    // selected under it directly.
    let foreign = hash::default_spa_threshold() + 1.0;
    let cfg = spgemm_aia::spgemm::hash::EngineConfig {
        spa_threshold: foreign,
        symbolic_threshold: None,
        planner: spgemm_aia::spgemm::hash::PlannerPolicy::Exact,
        mask: None,
    };
    let mut seed_store = DiskStore::new(&dir);
    seed_store.put(Arc::new(PlannedProduct::plan_cfg(&a, &a, &cfg)));
    // This process (default threshold): the file must read as stale.
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex.multiply_cached(&a, &a), cold);
    assert_eq!((ex.stats.disk_hits, ex.stats.plans_built), (0, 1), "foreign-threshold plan forces a replan");
    assert_eq!(ex.store_stats().stale, 1);
    // The replan rewrote the file under the current knob: next cold
    // process hits.
    let mut ex2 = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex2.multiply_cached(&a, &a), cold);
    assert_eq!((ex2.stats.disk_hits, ex2.stats.plans_built), (1, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The batch pipeline (planner thread + snapshot lookups) sees the disk
/// tier too, and repeated structures inside the batch still dedupe.
#[test]
fn batch_pipeline_mixes_disk_hits_and_fresh_plans() {
    let dir = scratch("pipeline");
    let a = rmat_square(8, 192, 4);
    let b = rmat_square(9, 192, 4);
    let mut writer = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    writer.multiply_cached(&a, &a); // persist a's plan only
    let mut ex = BatchExecutor::with_store(4, TieredStore::with_disk(&dir));
    let out = ex.execute_batch(&[(&a, &a), (&b, &b), (&a, &a)]);
    assert_eq!(out[0], hash::multiply(&a, &a));
    assert_eq!(out[1], hash::multiply(&b, &b));
    assert_eq!(out[0], out[2]);
    // a: disk hit (once; the repeat is an in-batch share), b: fresh.
    assert_eq!(ex.stats.disk_hits, 1);
    assert_eq!(ex.stats.plans_built, 1);
    assert_eq!(ex.stats.batch_shared, 1);
    // b's fresh plan was persisted: a fully warm third process.
    let mut ex2 = BatchExecutor::with_store(4, TieredStore::with_disk(&dir));
    ex2.execute_batch(&[(&a, &a), (&b, &b)]);
    assert_eq!((ex2.stats.disk_hits, ex2.stats.plans_built), (2, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A delta-patched plan is a first-class store citizen: persisted by
/// the batch path, served bit-identically to a cold process, and
/// counted as neither hit nor miss where it was patched.
#[test]
fn delta_patched_plan_roundtrips_across_processes() {
    let dir = scratch("delta-roundtrip");
    let a = rmat_square(21, 256, 5);
    let a2 = hash::mutate_row_fraction(&a, 0.01, 5);
    let cold2 = hash::multiply(&a2, &a2);
    // "Process" 1: cold plan for a, then the mutation delta-patches.
    let mut w = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    w.multiply_cached(&a, &a);
    assert_eq!(w.multiply_cached(&a2, &a2), cold2);
    assert_eq!(w.stats.delta_patches, 1, "the 1% mutation must patch, not replan");
    assert_eq!(w.stats.plans_built, 1, "only a's plan was built from scratch");
    assert_eq!(w.store_stats().delta_patches, 1, "the store reclassifies the probe miss");
    // "Process" 2: cold memory tier — the *patched* plan is served from
    // disk, lineage intact, fill bit-identical.
    let mut r = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(r.multiply_cached(&a2, &a2), cold2);
    assert_eq!((r.stats.disk_hits, r.stats.plans_built, r.stats.delta_patches), (1, 0, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Persist a patched plan, then forge its lineage digest in place
/// (re-sealing the body checksum so the file stays well-formed): the
/// chain no longer re-verifies, so the load degrades to a *stale*
/// silent miss — not corruption — and the replan heals the entry.
#[test]
fn forged_delta_digest_degrades_to_clean_replan() {
    let dir = scratch("delta-forged");
    let a = rmat_square(22, 256, 5);
    let a2 = hash::mutate_row_fraction(&a, 0.01, 9);
    let cold2 = hash::multiply(&a2, &a2);
    let base = PlannedProduct::plan(&a, &a);
    let patched = match hash::delta_patch(&base, &a2, &a2, &spgemm_aia::spgemm::hash::EngineConfig::default()) {
        DeltaOutcome::Patched(p) => p.plan,
        DeltaOutcome::Rebuild(why) => panic!("1% mutation must patch: {why}"),
    };
    let mut ds = DiskStore::new(&dir);
    ds.put(Arc::new(patched));
    let fp = PlanFingerprint::of(&a2, &a2);
    let path = ds.path_for(fp.key());
    let mut bytes = std::fs::read(&path).expect("patched plan persisted");
    let body = bytes.len() - 8; // trailing FNV checksum
    bytes[body - 8] ^= 0x01; // the digest is the last lineage field
    let sum = fnv1a(&bytes[..body]).to_le_bytes();
    bytes[body..].copy_from_slice(&sum);
    std::fs::write(&path, &bytes).unwrap();
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex.multiply_cached(&a2, &a2), cold2, "a forged chain must never leak into the output");
    assert_eq!((ex.stats.disk_hits, ex.stats.plans_built), (0, 1), "stale chain is a silent miss + replan");
    assert_eq!((ex.store_stats().stale, ex.store_stats().corrupt), (1, 0), "stale, not corrupt");
    // The replan rewrote a lineage-free plan: the next process hits.
    let mut ex2 = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex2.multiply_cached(&a2, &a2), cold2);
    assert_eq!((ex2.stats.disk_hits, ex2.stats.plans_built), (1, 0), "replan heals the cache");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A chain length past the rebuild threshold (forged on disk — the
/// planner itself re-anchors before ever writing one) fails lineage
/// validation the same way: stale, replan, heal.
#[test]
fn overlong_delta_chain_degrades_to_clean_replan() {
    let dir = scratch("delta-overlong");
    let a = rmat_square(23, 256, 5);
    let a2 = hash::mutate_row_fraction(&a, 0.01, 11);
    let cold2 = hash::multiply(&a2, &a2);
    let base = PlannedProduct::plan(&a, &a);
    let patched = match hash::delta_patch(&base, &a2, &a2, &spgemm_aia::spgemm::hash::EngineConfig::default()) {
        DeltaOutcome::Patched(p) => p.plan,
        DeltaOutcome::Rebuild(why) => panic!("1% mutation must patch: {why}"),
    };
    let mut ds = DiskStore::new(&dir);
    ds.put(Arc::new(patched));
    let fp = PlanFingerprint::of(&a2, &a2);
    let path = ds.path_for(fp.key());
    let mut bytes = std::fs::read(&path).unwrap();
    let body = bytes.len() - 8;
    // Lineage tail layout: … chain_len(4) prev_digest(8) digest(8).
    let cl = body - 20;
    bytes[cl..cl + 4].copy_from_slice(&(hash::MAX_DELTA_CHAIN + 7).to_le_bytes());
    let sum = fnv1a(&bytes[..body]).to_le_bytes();
    bytes[body..].copy_from_slice(&sum);
    std::fs::write(&path, &bytes).unwrap();
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex.multiply_cached(&a2, &a2), cold2);
    assert_eq!((ex.stats.disk_hits, ex.stats.plans_built), (0, 1));
    assert_eq!(ex.store_stats().stale, 1, "an over-long chain reads as stale");
    let mut ex2 = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!((ex2.multiply_cached(&a2, &a2), ex2.stats.disk_hits), (cold2, 1), "healed");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A bit flip or truncation *inside the delta record itself* (without
/// re-sealing) lands on the checksum rung below the lineage rung:
/// corrupt, silent miss, clean replan.
#[test]
fn damaged_delta_record_degrades_to_clean_replan() {
    let dir = scratch("delta-damaged");
    let a = rmat_square(24, 256, 5);
    let a2 = hash::mutate_row_fraction(&a, 0.01, 13);
    let cold2 = hash::multiply(&a2, &a2);
    let base = PlannedProduct::plan(&a, &a);
    let patched = match hash::delta_patch(&base, &a2, &a2, &spgemm_aia::spgemm::hash::EngineConfig::default()) {
        DeltaOutcome::Patched(p) => p.plan,
        DeltaOutcome::Rebuild(why) => panic!("1% mutation must patch: {why}"),
    };
    let mut ds = DiskStore::new(&dir);
    ds.put(Arc::new(patched));
    let fp = PlanFingerprint::of(&a2, &a2);
    let path = ds.path_for(fp.key());
    let orig = std::fs::read(&path).unwrap();
    // Bit flip mid-lineage, checksum left stale.
    let mut flipped = orig.clone();
    let body = flipped.len() - 8;
    flipped[body - 12] ^= 0x40; // inside prev_digest
    std::fs::write(&path, &flipped).unwrap();
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex.multiply_cached(&a2, &a2), cold2);
    assert_eq!((ex.stats.disk_corrupt, ex.stats.plans_built), (1, 1), "flip lands on the checksum rung");
    // Truncation mid-lineage record.
    std::fs::write(&path, &orig[..orig.len() - 13]).unwrap();
    let mut ex2 = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!(ex2.multiply_cached(&a2, &a2), cold2);
    assert_eq!((ex2.stats.disk_corrupt, ex2.stats.plans_built), (1, 1), "truncated record is corrupt");
    // Both replans healed the entry.
    let mut ex3 = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    assert_eq!((ex3.multiply_cached(&a2, &a2), ex3.stats.disk_hits), (cold2, 1));
    let _ = std::fs::remove_dir_all(&dir);
}

/// MCL driven with a store-attached executor: a second "process" on the
/// same graph replays its expansions from disk.
#[test]
fn mcl_rerun_starts_from_persisted_plans() {
    let dir = scratch("mcl");
    let mut rng = Pcg32::seeded(12);
    let g = spgemm_aia::gen::structured::community_powerlaw(96, 5, 3, &mut rng);
    let params = spgemm_aia::apps::MclParams { max_iters: 4, tol: 0.0, ..Default::default() };
    let mut ex1 = SpgemmExecutor::fast(Variant::Hash);
    ex1.attach_plan_store(TieredStore::with_disk(&dir));
    let r1 = spgemm_aia::apps::mcl(&g, &params, &mut ex1);
    assert!(r1.plan_misses >= 1, "first process must plan at least once");
    let mut ex2 = SpgemmExecutor::fast(Variant::Hash);
    ex2.attach_plan_store(TieredStore::with_disk(&dir));
    let r2 = spgemm_aia::apps::mcl(&g, &params, &mut ex2);
    assert_eq!(r1.clusters, r2.clusters, "persisted plans must not change the clustering");
    assert!(r2.disk_hits >= 1, "second process must be served from disk at least once");
    assert_eq!(r2.plan_misses, 0, "every structure of the rerun was already persisted");
    let _ = std::fs::remove_dir_all(&dir);
}
