//! Symbolic kernel-selection properties (`util/qc.rs` harness): the
//! counting kernel is an implementation detail — bitmap-counted and
//! hash-counted symbolic phases must produce **identical**
//! `SymbolicPlan`s (row sizes, bins, numeric kinds) across the RMAT and
//! structured generators at any threshold; the threshold boundary
//! semantics must hold exactly (`0.0` forces the bitmap on every
//! non-trivial row, any value ≥ 1.0 disables it); and the recorded
//! per-row kinds must follow the IP-bound decision rule.

use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::{Coo, Csr};
use spgemm_aia::spgemm::hash::{self, select_symbolic, EngineConfig, PlannerPolicy, SymbolicKind, SymbolicPlan};
use spgemm_aia::util::{qc, Pcg32};
use std::collections::BTreeMap;

/// The numeric thresholds each property sweeps: dense kernels forced,
/// the cache-geometry default, and disabled.
const THRESHOLDS: [f64; 3] = [0.0, 0.25, 1.5];

fn forced(spa_threshold: f64, kernel: SymbolicKind) -> EngineConfig {
    let t = match kernel {
        SymbolicKind::Bitmap => 0.0, // every non-trivial row counts via bitmap
        _ => 8.0,                    // bitmap disabled: every non-trivial row hashes
    };
    EngineConfig { spa_threshold, symbolic_threshold: Some(t), planner: PlannerPolicy::Exact, mask: None }
}

/// Plan-guided (no forced kernel) config at `spa_threshold`.
fn guided(spa_threshold: f64) -> EngineConfig {
    EngineConfig { spa_threshold, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None }
}

/// Flatten a plan's bins to a `(group, numeric kind) -> (rows, weight)`
/// view — everything about the numeric work list that must not depend
/// on which kernel counted the rows.
fn numeric_view(plan: &SymbolicPlan) -> BTreeMap<(u8, usize), (Vec<u32>, u64)> {
    let mut m: BTreeMap<(u8, usize), (Vec<u32>, u64)> = BTreeMap::new();
    for bin in &plan.bins {
        let e = m.entry((bin.group, bin.kind.index())).or_insert_with(|| (Vec::new(), 0));
        e.0.extend(&bin.rows);
        e.1 += bin.weight;
    }
    for e in m.values_mut() {
        e.0.sort_unstable();
    }
    m
}

fn assert_plans_identical(reference: &SymbolicPlan, other: &SymbolicPlan, ctx: &str) {
    assert_eq!(reference.rpt, other.rpt, "{ctx}: row sizes must not depend on the counting kernel");
    assert_eq!(reference.accum, other.accum, "{ctx}: numeric kinds must not depend on the counting kernel");
    assert_eq!(
        numeric_view(reference),
        numeric_view(other),
        "{ctx}: the numeric work list must not depend on the counting kernel"
    );
}

/// All three symbolic modes — forced bitmap, forced hash, plan-guided —
/// at every threshold, on one operand pair.
fn check_kernel_independence(a: &Csr, name: &str) {
    for thr in THRESHOLDS {
        let bitmap = hash::symbolic_cfg(a, a, &forced(thr, SymbolicKind::Bitmap));
        let hashed = hash::symbolic_cfg(a, a, &forced(thr, SymbolicKind::Hash));
        let guided = hash::symbolic_cfg(a, a, &guided(thr));
        assert_plans_identical(&hashed, &bitmap, &format!("{name} thr={thr} bitmap-vs-hash"));
        assert_plans_identical(&hashed, &guided, &format!("{name} thr={thr} guided-vs-hash"));
        // Boundary semantics of the forcing override.
        assert_eq!(
            bitmap.symbolic_kind_rows()[SymbolicKind::Hash.index()],
            0,
            "{name}: symbolic_threshold 0.0 must force the bitmap on every non-trivial row"
        );
        assert_eq!(
            hashed.symbolic_kind_rows()[SymbolicKind::Bitmap.index()],
            0,
            "{name}: symbolic_threshold 8.0 must disable the bitmap"
        );
        // The numeric output is bit-identical across counting kernels.
        let c_bitmap = hash::multiply_cfg(a, a, &forced(thr, SymbolicKind::Bitmap));
        let c_hashed = hash::multiply_cfg(a, a, &forced(thr, SymbolicKind::Hash));
        assert_eq!(c_bitmap, c_hashed, "{name} thr={thr}: products must agree bit-for-bit");
    }
}

#[test]
fn property_symbolic_kernels_plan_identical_rmat() {
    qc::check(10, 7171, |g| {
        let n = 16 + g.dim() * 8;
        let nnz = n * (2 + g.rng.below_usize(8));
        let params = match g.rng.below_usize(3) {
            0 => RmatParams::web(),
            1 => RmatParams::citation(),
            _ => RmatParams::uniform(),
        };
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, nnz, params, &mut rng);
        check_kernel_independence(&a, "rmat");
    });
}

#[test]
fn property_symbolic_kernels_plan_identical_structured() {
    qc::check(8, 2626, |g| {
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let n = 32 + g.dim() * 4;
        let (name, a) = match g.rng.below_usize(4) {
            0 => ("protein", structured::protein_contact(n, 24, &mut rng)),
            1 => ("fem_banded", structured::fem_banded(n, 12, &mut rng)),
            2 => ("circuit", structured::circuit(n, &mut rng)),
            _ => ("economics", structured::economics(n, &mut rng)),
        };
        check_kernel_independence(&a, name);
    });
}

#[test]
fn shared_threshold_boundaries_drive_the_symbolic_kernel() {
    // Without a symbolic override, the shared knob decides both halves:
    // 0.0 forces the bitmap on every non-trivial row, ≥ 1.0 disables it
    // (the IP bound is capped at n_cols, so even hub rows cannot cross
    // a threshold of 1.0).
    let mut rng = Pcg32::seeded(99);
    let mut coo = Coo::new(96, 96);
    for _ in 0..96 * 24 {
        coo.push(rng.below_usize(96), rng.below_usize(96), rng.f64_range(-1.0, 1.0));
    }
    let a = coo.to_csr();
    let plan = hash::symbolic_cfg(&a, &a, &guided(0.0));
    let rows = plan.symbolic_kind_rows();
    assert_eq!(rows[SymbolicKind::Hash.index()], 0, "0.0 must force the bitmap");
    assert!(rows[SymbolicKind::Bitmap.index()] > 0, "0.0 must actually produce bitmap rows");
    for thr in [1.0, 4.0] {
        let plan = hash::symbolic_cfg(&a, &a, &guided(thr));
        assert_eq!(
            plan.symbolic_kind_rows()[SymbolicKind::Bitmap.index()],
            0,
            "threshold {thr} must disable the bitmap"
        );
    }
}

#[test]
fn recorded_kinds_follow_the_ip_bound_rule() {
    let mut rng = Pcg32::seeded(7);
    let a = rmat(256, 2048, RmatParams::web(), &mut rng);
    let cfg = guided(0.25);
    let plan = hash::symbolic_cfg(&a, &a, &cfg);
    for r in 0..a.n_rows {
        let expect = select_symbolic(a.row_nnz(r), plan.ip[r], a.n_cols, 0.25);
        assert_eq!(plan.symbolic_kind(r), expect, "row {r}");
        if let Some(kernel) = plan.row_kernel(r) {
            assert_eq!(kernel.symbolic, plan.symbolic_kind(r));
            assert_eq!(Some(kernel.numeric), plan.accumulator_kind(r));
        }
    }
    // Every bin is homogeneous in its pair, and the plan's bins agree
    // with the per-row record.
    for bin in &plan.bins {
        for &r in &bin.rows {
            assert_eq!(plan.symbolic_kind(r as usize), bin.symbolic_kind);
            assert_eq!(plan.accumulator_kind(r as usize), Some(bin.kind));
        }
    }
}

#[test]
fn planned_products_preserve_the_symbolic_kernel_split() {
    // Through the plan-reuse layer: plan once per kernel mode, fill —
    // outputs identical, and the plan's per-kernel symbolic seconds
    // land in `plan_times`.
    let mut rng = Pcg32::seeded(13);
    let a = rmat(192, 3000, RmatParams::uniform(), &mut rng);
    let bitmap = hash::PlannedProduct::plan_cfg(&a, &a, &forced(0.25, SymbolicKind::Bitmap));
    let hashed = hash::PlannedProduct::plan_cfg(&a, &a, &forced(0.25, SymbolicKind::Hash));
    assert_eq!(bitmap.fill(&a, &a), hashed.fill(&a, &a));
    let bitmap_s = bitmap.plan_times.symbolic_kind_s;
    assert_eq!(bitmap_s[SymbolicKind::Hash.index()], 0.0, "forced-bitmap plan ran no hash kernel");
    if bitmap.symbolic_plan().symbolic_kind_rows()[SymbolicKind::Bitmap.index()] > 0 {
        assert!(bitmap_s[SymbolicKind::Bitmap.index()] > 0.0, "bitmap kernel seconds must be recorded");
    }
}
