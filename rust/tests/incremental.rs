//! Differential acceptance harness for incremental dirty-row
//! replanning (`spgemm::hash::incremental`, `DESIGN.md` §Incremental
//! replanning):
//!
//! - randomized mutation sequences — edge inserts, edge deletes,
//!   reweights, whole-row clears, and no-op structural rewrites — over
//!   RMAT and structured generators, where at every step the
//!   delta-patched plan and its fill must be **bit-identical** to a
//!   cold plan + multiply of the mutated operands: same `rpt`, same
//!   per-row kernel kinds, same bin membership and order;
//! - the acceptance bound: a 1 %-dirty mutation replans symbolic work
//!   for ≤ 5 % of the rows, asserted on `DeltaPatch::dirty_rows` and on
//!   the executor / batch `delta_rows` counters that surface it.

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::Csr;
use spgemm_aia::spgemm::hash::{
    self, delta_patch, mutate_row_fraction, DeltaOutcome, EngineConfig, PlannedProduct, TieredStore,
};
use spgemm_aia::util::Pcg32;

/// Full structural equality of two plans: everything the numeric phase
/// consumes, down to bin membership order. `PlannedProduct` exposes no
/// `==` on purpose — this spells out exactly which facts must agree.
fn assert_plans_identical(tag: &str, got: &PlannedProduct, want: &PlannedProduct) {
    let (g, w) = (got.symbolic_plan(), want.symbolic_plan());
    assert_eq!(g.ip, w.ip, "{tag}: IP bounds");
    assert_eq!(g.rpt, w.rpt, "{tag}: exact row pointers");
    assert_eq!(g.accum, w.accum, "{tag}: accumulator kinds");
    assert_eq!(g.symbolic, w.symbolic, "{tag}: symbolic counting kinds");
    assert_eq!(g.spa_threshold, w.spa_threshold, "{tag}: SPA threshold");
    assert_eq!(g.grouping.group_of, w.grouping.group_of, "{tag}: group assignment");
    assert_eq!(g.grouping.map, w.grouping.map, "{tag}: group sort order");
    assert_eq!(g.grouping.ranges, w.grouping.ranges, "{tag}: group ranges");
    assert_eq!(g.bins.len(), w.bins.len(), "{tag}: bin count");
    for (i, (x, y)) in g.bins.iter().zip(&w.bins).enumerate() {
        assert_eq!(x.group, y.group, "{tag}: bin {i} group");
        assert_eq!(x.kind, y.kind, "{tag}: bin {i} accumulator");
        assert_eq!(x.symbolic_kind, y.symbolic_kind, "{tag}: bin {i} symbolic kind");
        assert_eq!(x.rows, y.rows, "{tag}: bin {i} membership/order");
        assert_eq!(x.weight, y.weight, "{tag}: bin {i} weight");
    }
}

#[derive(Clone, Copy, Debug)]
enum Mutation {
    /// Insert a few edges at random positions (skip if already present).
    InsertEdges,
    /// Delete a few random existing edges.
    DeleteEdges,
    /// Scale a few values — structure unchanged, plan must plain-reuse.
    Reweight,
    /// Clear one whole row.
    ClearRow,
    /// Rebuild the matrix from its own triplets — byte-identical
    /// structure through a fresh constructor (fresh hash memos), the
    /// plan must plain-reuse.
    NoopRewrite,
}

const SEQUENCE: [Mutation; 10] = [
    Mutation::InsertEdges,
    Mutation::DeleteEdges,
    Mutation::Reweight,
    Mutation::ClearRow,
    Mutation::NoopRewrite,
    Mutation::InsertEdges,
    Mutation::ClearRow,
    Mutation::DeleteEdges,
    Mutation::InsertEdges,
    Mutation::Reweight,
];

fn to_rows(m: &Csr) -> Vec<Vec<(u32, f64)>> {
    (0..m.n_rows)
        .map(|r| {
            let (c, v) = m.row(r);
            c.iter().copied().zip(v.iter().copied()).collect()
        })
        .collect()
}

fn from_rows(n_cols: usize, rows: Vec<Vec<(u32, f64)>>) -> Csr {
    let n = rows.len();
    let mut rpt = Vec::with_capacity(n + 1);
    rpt.push(0usize);
    let mut col = Vec::new();
    let mut val = Vec::new();
    for row in rows {
        for (c, v) in row {
            col.push(c);
            val.push(v);
        }
        rpt.push(col.len());
    }
    Csr::new(n, n_cols, rpt, col, val).expect("mutated matrix must stay a valid CSR")
}

fn apply(m: &Csr, kind: Mutation, rng: &mut Pcg32) -> Csr {
    let mut rows = to_rows(m);
    let n = rows.len();
    match kind {
        Mutation::InsertEdges => {
            for _ in 0..3 {
                let r = rng.below_usize(n);
                let c = rng.below_usize(m.n_cols) as u32;
                let row = &mut rows[r];
                if let Err(pos) = row.binary_search_by_key(&c, |e| e.0) {
                    row.insert(pos, (c, rng.f64_range(0.5, 1.5)));
                }
            }
        }
        Mutation::DeleteEdges => {
            for _ in 0..3 {
                let r = rng.below_usize(n);
                if !rows[r].is_empty() {
                    let i = rng.below_usize(rows[r].len());
                    rows[r].remove(i);
                }
            }
        }
        Mutation::Reweight => {
            for _ in 0..5 {
                let r = rng.below_usize(n);
                if !rows[r].is_empty() {
                    let i = rng.below_usize(rows[r].len());
                    rows[r][i].1 *= 1.5;
                }
            }
        }
        Mutation::ClearRow => {
            let r = rng.below_usize(n);
            rows[r].clear();
        }
        Mutation::NoopRewrite => {}
    }
    from_rows(m.n_cols, rows)
}

/// The tentpole criterion: over a randomized mutation sequence, every
/// structural step delta-patches (or openly rebuilds — never silently
/// degrades) and the patched plan + fill are bit-identical to a cold
/// plan + multiply; every non-structural step is a plain plan reuse
/// whose fill still matches a cold multiply of the new values.
#[test]
fn mutation_sequences_patch_bit_identically_across_generators() {
    let mut rng = Pcg32::seeded(2025);
    let mats: Vec<(&str, Csr)> = vec![
        ("rmat-web", rmat(160, 1100, RmatParams::web(), &mut rng)),
        ("rmat-uniform", rmat(192, 1300, RmatParams::uniform(), &mut rng)),
        ("circuit", structured::circuit(144, &mut rng)),
        ("economics", structured::economics(144, &mut rng)),
        ("protein", structured::protein_contact(112, 6, &mut rng)),
    ];
    for (name, base) in mats {
        let b = base.clone(); // fixed right operand: A_t · B with A drifting
        let mut a = base;
        let mut plan = PlannedProduct::plan(&a, &b);
        let (mut patched, mut reused, mut rebuilt) = (0usize, 0usize, 0usize);
        for (step, kind) in SEQUENCE.iter().cycle().take(16).enumerate() {
            a = apply(&a, *kind, &mut rng);
            let tag = format!("{name} step {step} ({kind:?})");
            let cold = PlannedProduct::plan(&a, &b);
            if plan.matches(&a, &b) {
                // Structure unchanged (reweight / no-op rewrite): the
                // held plan serves the new values directly.
                assert_plans_identical(&tag, &plan, &cold);
                reused += 1;
            } else {
                match delta_patch(&plan, &a, &b, &EngineConfig::default()) {
                    DeltaOutcome::Patched(dp) => {
                        assert_plans_identical(&tag, &dp.plan, &cold);
                        let d = dp.plan.delta().expect("patched plan must carry lineage");
                        assert!(d.chain_len >= 1, "{tag}: chain length");
                        plan = dp.plan;
                        patched += 1;
                    }
                    DeltaOutcome::Rebuild(_) => {
                        // e.g. the chain hit MAX_DELTA_CHAIN — the cold
                        // plan re-anchors it.
                        plan = cold;
                        rebuilt += 1;
                        continue;
                    }
                }
            }
            assert_eq!(
                plan.fill(&a, &b),
                hash::multiply(&a, &b),
                "{tag}: fill must be bit-identical to a cold multiply"
            );
        }
        assert!(patched >= 5, "{name}: structural steps must mostly patch (patched {patched}, rebuilt {rebuilt})");
        assert!(reused >= 1, "{name}: non-structural steps must plain-reuse (reused {reused})");
    }
}

/// Mutations must be able to change kernel decisions, not just counts:
/// clearing a heavy row / inserting into an empty one moves rows across
/// bins, and the patched plan tracks the membership change exactly.
#[test]
fn row_clears_move_rows_across_bins_bit_identically() {
    let mut rng = Pcg32::seeded(77);
    let a = rmat(200, 2600, RmatParams::web(), &mut rng);
    let b = a.clone();
    let base = PlannedProduct::plan(&a, &b);
    // Clear the heaviest row: its bin loses a member (and possibly its
    // group changes for feeders in A = same matrix here, b fixed).
    let heavy = (0..a.n_rows).max_by_key(|&r| a.row_nnz(r)).unwrap();
    let mut rows = to_rows(&a);
    rows[heavy].clear();
    let a2 = from_rows(a.n_cols, rows);
    let cold = PlannedProduct::plan(&a2, &b);
    match delta_patch(&base, &a2, &b, &EngineConfig::default()) {
        DeltaOutcome::Patched(dp) => {
            assert_plans_identical("heavy-row clear", &dp.plan, &cold);
            assert_eq!(dp.plan.fill(&a2, &b), hash::multiply(&a2, &b));
            // The cleared row's symbolic kind / grouping really changed:
            // the old and new plans must disagree somewhere observable.
            let (old, new) = (base.symbolic_plan(), dp.plan.symbolic_plan());
            assert_ne!(old.ip[heavy], new.ip[heavy], "cleared row must drop its IP bound");
            assert_eq!(new.rpt[heavy + 1] - new.rpt[heavy], 0, "cleared row has no output");
        }
        DeltaOutcome::Rebuild(why) => panic!("single-row clear must patch: {why}"),
    }
}

/// The acceptance bound end to end: a 1 %-dirty mutation replans ≤ 5 %
/// of the rows, and the executor / batch layers report that through
/// `delta_rows` without counting the patch as a hit or a miss.
#[test]
fn one_percent_dirty_replans_at_most_five_percent_of_rows() {
    let mut rng = Pcg32::seeded(17);
    let a = rmat(1200, 9600, RmatParams::uniform(), &mut rng);
    let b = rmat(1200, 9600, RmatParams::uniform(), &mut rng);
    let bound = (0.05 * a.n_rows as f64) as usize;
    let a2 = mutate_row_fraction(&a, 0.01, 41);

    let base = PlannedProduct::plan(&a, &b);
    match delta_patch(&base, &a2, &b, &EngineConfig::default()) {
        DeltaOutcome::Patched(dp) => {
            assert!(dp.dirty_rows <= bound, "1% dirty replanned {} rows (bound {bound})", dp.dirty_rows);
            assert_plans_identical("1%-dirty", &dp.plan, &PlannedProduct::plan(&a2, &b));
            assert_eq!(dp.plan.fill(&a2, &b), hash::multiply(&a2, &b));
        }
        DeltaOutcome::Rebuild(why) => panic!("1%-dirty mutation must patch: {why}"),
    }

    // Application entry point: the displaced slot plan is the baseline.
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    ex.attach_plan_store(TieredStore::mem_only());
    let mut slot = None;
    ex.multiply_reusing(&mut slot, &a, &b);
    let c = ex.multiply_reusing(&mut slot, &a2, &b);
    assert_eq!(c, hash::multiply(&a2, &b));
    assert_eq!((ex.plan_deltas, ex.plan_misses), (1, 1), "one cold plan, one delta patch");
    assert!(ex.delta_rows <= bound, "executor delta_rows {} (bound {bound})", ex.delta_rows);
    assert!(ex.delta_plan_s > 0.0, "the patch's own seconds are charged");
    let ss = ex.plan_store_stats().expect("store attached");
    assert_eq!((ss.delta_patches, ss.hits(), ss.misses), (1, 0, 1), "a patch is neither hit nor miss");

    // Batch entry point: the report carries the same counters for
    // `repro planreuse` and the bench harness.
    let mut bx = BatchExecutor::with_store(2, TieredStore::mem_only());
    bx.execute_batch(&[(&a, &b)]);
    bx.execute_batch(&[(&a2, &b)]);
    let r = bx.last_batch.as_ref().expect("batch ran");
    assert_eq!(r.delta_patches, 1, "the second batch must patch, not replan");
    assert!(r.delta_rows <= bound, "batch delta_rows {} (bound {bound})", r.delta_rows);
    assert!(r.symbolic_delta_s >= 0.0 && r.delta_plan_s >= r.symbolic_delta_s);
    assert_eq!(bx.stats.delta_patches, 1);
    assert_eq!(bx.stats.plans_built, 1, "only the first batch built a plan from scratch");
    assert_eq!(bx.store_stats().delta_patches, 1);
}

/// Chained drift through the executor: repeated small mutations keep
/// patching until the lineage cap forces one clean re-anchor, and every
/// output along the way is bit-identical to a cold multiply.
#[test]
fn executor_chain_survives_repeated_drift() {
    let mut rng = Pcg32::seeded(3);
    let mut a = rmat(256, 1800, RmatParams::uniform(), &mut rng);
    let b = a.clone();
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    ex.attach_plan_store(TieredStore::mem_only());
    let mut slot = None;
    ex.multiply_reusing(&mut slot, &a, &b);
    for step in 0..12u64 {
        a = mutate_row_fraction(&a, 0.02, 500 + step);
        let c = ex.multiply_reusing(&mut slot, &a, &b);
        assert_eq!(c, hash::multiply(&a, &b), "step {step}: drifted output must stay exact");
    }
    assert!(ex.plan_deltas >= 8, "most drift steps must patch (got {})", ex.plan_deltas);
    assert!(ex.plan_misses >= 2, "the chain cap must force at least one re-anchor");
    assert_eq!(ex.plan_deltas + ex.plan_misses, 13, "every job is either a patch or a full plan");
}
