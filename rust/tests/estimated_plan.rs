//! Estimated-plan acceptance tests (`DESIGN.md` §2g): the speculative
//! planner may be arbitrarily wrong and the product must not move.
//!
//! - An adversarial estimator-injection harness forces systematic
//!   under-estimates (0.1×), over-estimates (10×), zero estimates, and
//!   per-row mixed error through the test-only injector hook, and
//!   asserts the estimated path stays **bit-identical** (`rpt`, `col`,
//!   `val` compared bitwise) to the exact `multiply` across the RMAT
//!   and structured generators — with the grow-and-retry fallback
//!   (`fallback_rows > 0`) actually observed on the underestimate
//!   cases, so the recovery path is exercised, not just reachable.
//! - Policy-boundary properties: `auto` rides the store hit / batch /
//!   delta paths exactly and speculates only on fully-cold one-shot
//!   calls, and no speculative plan is ever admitted to the store —
//!   [`StoreStats::stores`] (disk write-throughs) stays 0 and the
//!   cache directory stays empty until an *exact* plan is built.

use spgemm_aia::coordinator::batch::{BatchExecutor, PlanSource};
use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::Csr;
use spgemm_aia::spgemm::hash::planstore::{DiskStore, TieredStore};
use spgemm_aia::spgemm::hash::{self, EngineConfig, EstimateParams, PlannerPolicy};
use spgemm_aia::util::{qc, Pcg32};
use std::path::PathBuf;

/// Per-test scratch directory (tests run in parallel in one process —
/// the tag keeps them disjoint), cleaned on entry so every run is cold.
fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spgemm-aia-estplan-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Bitwise output identity: same row pointers, same column indices,
/// and values equal as raw f64 bit patterns (no epsilon — speculation
/// must not even reorder an accumulation).
fn assert_bit_identical(exact: &Csr, got: &Csr, ctx: &str) {
    assert_eq!((exact.n_rows, exact.n_cols), (got.n_rows, got.n_cols), "{ctx}: shape diverged");
    assert_eq!(exact.rpt, got.rpt, "{ctx}: row pointers diverged");
    assert_eq!(exact.col, got.col, "{ctx}: column indices diverged");
    let eb: Vec<u64> = exact.val.iter().map(|v| v.to_bits()).collect();
    let gb: Vec<u64> = got.val.iter().map(|v| v.to_bits()).collect();
    assert_eq!(eb, gb, "{ctx}: values diverged bitwise");
}

/// The adversarial estimator ladder. Each entry receives
/// `(row, honest_estimate)` and returns the estimate the planner is
/// forced to believe; the engine owns recovering from every one of
/// them.
const INJECTORS: [(&str, fn(usize, u64) -> u64); 4] = [
    // Systematic 0.1× underestimate: every hash table starts ~10× too
    // small, so the pre-insert load guard must trip and grow.
    ("under-0.1x", |_r, e| (e / 10).max(1)),
    // Systematic 10× overestimate: tables are oversized (clamped to
    // the IP bound); wasteful but never wrong.
    ("over-10x", |_r, e| e.saturating_mul(10)),
    // Zero for every row: the planner believes the product is empty
    // and every non-trivial row climbs the grow ladder from the
    // smallest table.
    ("zero", |_r, _e| 0),
    // Per-row mixed error — under, over, zero, and honest interleaved,
    // so adjacent rows of one bin disagree about their sizing.
    ("mixed", |r, e| match r % 4 {
        0 => (e / 10).max(1),
        1 => e.saturating_mul(10),
        2 => 0,
        _ => e,
    }),
];

/// One operand pair through the whole ladder: honest estimates first,
/// then every injected estimator, all bit-identical to the exact
/// engine.
fn assert_injection_immune(a: &Csr, b: &Csr, name: &str) {
    let exact = hash::multiply(a, b);
    let cfg = EngineConfig::default();
    let params = EstimateParams::default();
    let (c, rep) = hash::multiply_estimated_cfg(a, b, &cfg, &params);
    assert_bit_identical(&exact, &c, &format!("{name} honest"));
    assert_eq!(rep.nnz, exact.nnz(), "{name}: report must carry the exact output nnz");
    for (tag, inj) in INJECTORS {
        let (c, _) = hash::multiply_estimated_injected(a, b, &cfg, &params, &inj);
        assert_bit_identical(&exact, &c, &format!("{name} {tag}"));
    }
}

#[test]
fn property_injected_estimates_stay_bit_identical_rmat() {
    qc::check(8, 4242, |g| {
        let n = 16 + g.dim() * 8;
        let nnz = n * (2 + g.rng.below_usize(8));
        let params = match g.rng.below_usize(3) {
            0 => RmatParams::web(),
            1 => RmatParams::citation(),
            _ => RmatParams::uniform(),
        };
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, nnz, params, &mut rng);
        assert_injection_immune(&a, &a, "rmat");
        // A distinct right operand as well — the estimator samples A
        // but sizes tables from B's rows, so a ≠ b must hold too.
        let b = rmat(n, nnz, RmatParams::uniform(), &mut rng);
        assert_injection_immune(&a, &b, "rmat-pair");
    });
}

#[test]
fn property_injected_estimates_stay_bit_identical_structured() {
    qc::check(8, 8484, |g| {
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let n = 32 + g.dim() * 4;
        let (name, a) = match g.rng.below_usize(4) {
            0 => ("protein", structured::protein_contact(n, 24, &mut rng)),
            1 => ("fem_banded", structured::fem_banded(n, 12, &mut rng)),
            2 => ("circuit", structured::circuit(n, &mut rng)),
            _ => ("economics", structured::economics(n, &mut rng)),
        };
        assert_injection_immune(&a, &a, name);
    });
}

/// The underestimate cases must actually take the recovery path, not
/// merely be survivable: on a product dense enough that rows exceed
/// the deliberately shrunken tables, `fallback_rows` is observed > 0
/// for the 0.1×, zero, and mixed injectors (and the honest/over paths
/// still agree bit-for-bit).
#[test]
fn forced_underestimates_are_observed_falling_back() {
    let mut rng = Pcg32::seeded(11);
    let a = rmat(512, 512 * 8, RmatParams::web(), &mut rng);
    let exact = hash::multiply(&a, &a);
    let cfg = EngineConfig::default();
    let params = EstimateParams::default();
    for (tag, inj) in INJECTORS {
        let (c, rep) = hash::multiply_estimated_injected(&a, &a, &cfg, &params, &inj);
        assert_bit_identical(&exact, &c, &format!("dense {tag}"));
        if matches!(tag, "under-0.1x" | "zero" | "mixed") {
            assert!(rep.fallback_rows > 0, "{tag}: the grow-and-retry ladder must actually fire (report: {rep:?})");
        }
    }
}

/// Policy boundaries under `auto`, disk-backed, across random RMAT
/// inputs: a fully-cold one-shot call speculates and leaves the store
/// untouched (no disk write-through, no memory-tier entry, no plan
/// file); the batch path stays exact and persists; once the store is
/// warm the same call rides the memory hit instead of re-estimating.
#[test]
fn property_auto_speculates_cold_only_and_never_persists() {
    qc::check(6, 5151, |g| {
        let n = 48 + g.dim() * 4;
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, n * 6, RmatParams::uniform(), &mut rng);
        let exact = hash::multiply(&a, &a);
        let dir = scratch(&format!("auto-{n}-{}", g.rng.next_u64()));

        let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
        ex.planner = PlannerPolicy::Auto;
        let (c, t) = ex.multiply_cached_traced(&a, &a);
        assert_eq!(t.source, PlanSource::Estimated, "cold one-shot under auto must speculate");
        assert_bit_identical(&exact, &c, "auto cold");
        assert_eq!(ex.store_stats().stores, 0, "a speculative plan must never be written through to disk");
        assert_eq!(ex.cached_plans(), 0, "a speculative plan must not populate the memory tier either");
        assert!(DiskStore::new(&dir).entries().is_empty(), "no plan file may exist after a speculative call");

        // Batch slots are reused across fills — always planned exactly,
        // and the exact plan is store-eligible.
        let c2 = ex.execute_batch(&[(&a, &a)]).remove(0);
        assert_bit_identical(&exact, &c2, "auto batch");
        assert_eq!(ex.stats.estimated_plans, 1, "execute_batch must not speculate");
        assert_eq!(ex.store_stats().stores, 1, "the exact batch plan is persisted");
        assert_eq!(DiskStore::new(&dir).entries().len(), 1);

        // Warm store: auto rides the hit, estimate counters stay put.
        let (c3, t3) = ex.multiply_cached_traced(&a, &a);
        assert_eq!(t3.source, PlanSource::Mem, "auto must prefer the stored exact plan over re-estimating");
        assert_bit_identical(&exact, &c3, "auto warm");
        assert_eq!(ex.stats.estimated_plans, 1);
        assert_eq!(t3.symbolic_s, 0.0, "the hit path pays no symbolic seconds");
        let _ = std::fs::remove_dir_all(&dir);
    });
}

/// The delta boundary under `auto`, pinned deterministically: a
/// same-shape drift on a warm baseline delta-patches (exact symbolic
/// re-run over the dirty rows) instead of speculating, and the patched
/// plan — unlike the speculative one — is admitted to the store.
#[test]
fn auto_prefers_delta_patch_over_speculation_on_drift() {
    let dir = scratch("auto-delta");
    let mut rng = Pcg32::seeded(23);
    let a = rmat(400, 400 * 6, RmatParams::uniform(), &mut rng);
    let b = rmat(400, 400 * 6, RmatParams::web(), &mut rng);

    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    ex.planner = PlannerPolicy::Auto;
    // Seed the baseline exactly (explicit policy override beats the
    // executor default — the serve daemon leans on this).
    let (_, t0) = ex.multiply_cached_policy(&a, &b, PlannerPolicy::Exact);
    assert_eq!(t0.source, PlanSource::Fresh);
    assert_eq!(ex.store_stats().stores, 1);

    // 2% of A's rows drift: the store misses on the new fingerprint,
    // but the same-shape baseline patches — no speculation.
    let a2 = hash::mutate_row_fraction(&a, 0.02, 77);
    let exact2 = hash::multiply(&a2, &b);
    let (c2, t2) = ex.multiply_cached_traced(&a2, &b);
    assert_eq!(t2.source, PlanSource::Delta, "warm same-shape drift under auto must delta-patch");
    assert_bit_identical(&exact2, &c2, "auto delta");
    assert_eq!(ex.stats.estimated_plans, 0, "the estimator must not have run at all");
    assert_eq!(ex.stats.fallback_rows, 0);
    assert_eq!(ex.store_stats().stores, 2, "a delta-patched plan is exact and store-eligible");

    // A genuinely new shape is still cold → speculation, still no
    // third store write.
    let d = rmat(256, 256 * 5, RmatParams::citation(), &mut rng);
    let (c3, t3) = ex.multiply_cached_traced(&d, &d);
    assert_eq!(t3.source, PlanSource::Estimated);
    assert_bit_identical(&hash::multiply(&d, &d), &c3, "auto cold new shape");
    assert_eq!(ex.store_stats().stores, 2, "the speculative plan for the new shape must not be stored");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `estimated` (unconditional) vs `exact` on the same executor: the
/// explicit per-call policy decides, and repeated estimated calls on
/// an unwarmed store keep speculating — nothing leaks into the store
/// that would turn the second call into a hit.
#[test]
fn estimated_policy_never_warms_the_store_by_itself() {
    let dir = scratch("est-no-warm");
    let mut rng = Pcg32::seeded(31);
    let a = rmat(300, 300 * 5, RmatParams::uniform(), &mut rng);
    let exact = hash::multiply(&a, &a);
    let mut ex = BatchExecutor::with_store(2, TieredStore::with_disk(&dir));
    for round in 0..3 {
        let (c, t) = ex.multiply_cached_policy(&a, &a, PlannerPolicy::Estimated);
        assert_eq!(t.source, PlanSource::Estimated, "round {round}: nothing may have been cached");
        assert_bit_identical(&exact, &c, "estimated round");
    }
    assert_eq!(ex.stats.estimated_plans, 3);
    assert_eq!((ex.stats.plan_hits, ex.stats.plan_misses, ex.stats.plans_built), (0, 0, 0));
    assert_eq!(ex.store_stats().stores, 0);
    assert!(DiskStore::new(&dir).entries().is_empty());
    // The exact policy on the very same executor plans and persists.
    let (c, t) = ex.multiply_cached_policy(&a, &a, PlannerPolicy::Exact);
    assert_eq!(t.source, PlanSource::Fresh);
    assert_bit_identical(&exact, &c, "exact after estimated");
    assert_eq!(ex.store_stats().stores, 1);
    let _ = std::fs::remove_dir_all(&dir);
}
