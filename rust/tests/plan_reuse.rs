//! Plan-reuse properties (`util/qc.rs` harness): a reused
//! `PlannedProduct` must produce output **bit-identical** to a cold
//! `multiply` across the RMAT and structured generators; structural
//! change between fills must be detected and replanned; and the
//! coordinator's `BatchExecutor` / `SpgemmExecutor::multiply_reusing`
//! paths must agree with their serial counterparts exactly.

use spgemm_aia::coordinator::batch::BatchExecutor;
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::{Coo, Csr};
use spgemm_aia::spgemm::hash::{self, PlannedProduct, TieredStore};
use spgemm_aia::util::{qc, Pcg32};

fn random_rect(rng: &mut Pcg32, rows: usize, cols: usize) -> Csr {
    let mut coo = Coo::new(rows, cols);
    for _ in 0..(rows * cols / 5).max(1) {
        coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-1.0, 1.0));
    }
    coo.to_csr()
}

#[test]
fn property_reused_plan_is_bit_identical_rmat() {
    qc::check(10, 7171, |g| {
        let n = 16 + g.dim() * 8;
        let nnz = n * (2 + g.rng.below_usize(6));
        let params = match g.rng.below_usize(3) {
            0 => RmatParams::web(),
            1 => RmatParams::citation(),
            _ => RmatParams::uniform(),
        };
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, nnz, params, &mut rng);
        let cold = hash::multiply(&a, &a);
        let p = PlannedProduct::plan(&a, &a);
        // Two fills from one plan: both bit-identical to the cold path.
        assert_eq!(p.fill(&a, &a), cold, "reused fill vs cold multiply (1st)");
        assert_eq!(p.fill(&a, &a), cold, "reused fill vs cold multiply (2nd)");
        // New values under the same structure still reuse exactly.
        let mut a2 = a.clone();
        a2.map_values(|v| v * 1.5 - 0.25);
        assert!(p.matches(&a2, &a2), "value-only change must keep the plan valid");
        assert_eq!(p.fill(&a2, &a2), hash::multiply(&a2, &a2), "reused fill after value update");
    });
}

#[test]
fn property_reused_plan_is_bit_identical_structured() {
    qc::check(8, 5252, |g| {
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let n = 32 + g.dim() * 4;
        let (name, a) = match g.rng.below_usize(4) {
            0 => ("circuit", structured::circuit(n, &mut rng)),
            1 => ("economics", structured::economics(n, &mut rng)),
            2 => ("fem_banded", structured::fem_banded(n, 4, &mut rng)),
            _ => ("p2p", structured::p2p(n, &mut rng)),
        };
        let p = PlannedProduct::plan(&a, &a);
        assert_eq!(p.fill(&a, &a), hash::multiply(&a, &a), "{name}: reused fill vs cold multiply");
    });
}

#[test]
fn property_rectangular_batch_matches_serial() {
    qc::check(8, 6060, |g| {
        let m = 1 + g.dim() * 2;
        let k = 1 + g.dim();
        let n = 1 + g.dim() * 3;
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = random_rect(&mut rng, m, k);
        let b = random_rect(&mut rng, k, n);
        let b2 = random_rect(&mut rng, k, n);
        let pairs = [(&a, &b), (&a, &b2), (&a, &b)];
        // Memory-only store: qc generates many structures — do not
        // write them into a shell-configured plan-cache directory.
        let mut ex = BatchExecutor::with_store(2, TieredStore::mem_only());
        let out = ex.execute_batch(&pairs);
        for (i, &(x, y)) in pairs.iter().enumerate() {
            assert_eq!(out[i], hash::multiply(x, y), "batch product {i} vs serial multiply");
        }
    });
}

#[test]
fn replan_when_structure_changes_between_fills() {
    let mut rng = Pcg32::seeded(99);
    let a = rmat(128, 768, RmatParams::uniform(), &mut rng);
    // Grow the structure: add a row's worth of new entries.
    let mut coo = Coo::new(128, 128);
    for i in 0..128 {
        let (cols, vals) = a.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            coo.push(i, c as usize, v);
        }
    }
    for j in 0..16 {
        coo.push(7, (j * 5 + 1) % 128, 0.5);
    }
    let grown = coo.to_csr();
    assert_ne!(a.structure_hash(), grown.structure_hash());

    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    // Memory-only store: this test asserts exact hit/miss counts, which
    // a SPGEMM_AIA_PLAN_CACHE env var from the developer's shell (warm
    // disk tier) would turn stateful across `cargo test` runs.
    ex.attach_plan_store(TieredStore::mem_only());
    let mut slot = None;
    let c1 = ex.multiply_reusing(&mut slot, &a, &a);
    assert_eq!(c1, hash::multiply(&a, &a));
    // The edge case: the input structure changed between fills — the
    // stale plan must be detected (not silently reused) and replanned.
    let c2 = ex.multiply_reusing(&mut slot, &grown, &grown);
    assert_eq!(c2, hash::multiply(&grown, &grown), "post-change result must come from a fresh plan");
    assert_eq!((ex.plan_hits, ex.plan_misses), (0, 2));
    // And the slot now holds the new structure's plan: next call hits.
    let c3 = ex.multiply_reusing(&mut slot, &grown, &grown);
    assert_eq!(c3, c2);
    assert_eq!((ex.plan_hits, ex.plan_misses), (1, 2));
}

#[test]
fn stale_plan_fill_panics_instead_of_corrupting() {
    let mut rng = Pcg32::seeded(13);
    let a = rmat(64, 384, RmatParams::uniform(), &mut rng);
    let b = rmat(64, 512, RmatParams::uniform(), &mut rng);
    let p = PlannedProduct::plan(&a, &a);
    let r = std::panic::catch_unwind(|| p.fill(&b, &b));
    assert!(r.is_err(), "filling a stale plan must panic, not return garbage");
}
