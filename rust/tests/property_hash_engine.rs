//! Property tests for the two-phase hash engine (`util/qc.rs` harness):
//! against the dense-accumulator oracle (`spgemm/reference.rs`) the
//! output structure must be **bit-for-bit** identical (rpt and col
//! arrays) and values must agree to 1e-10, across RMAT and structured
//! generators; and the refactored symbolic/numeric pipeline must equal
//! the seed single-pass engine exactly.

use spgemm_aia::gen::{rmat, structured, RmatParams};
use spgemm_aia::sparse::Csr;
use spgemm_aia::spgemm::hash;
use spgemm_aia::spgemm::reference::spgemm_reference;
use spgemm_aia::util::{qc, Pcg32};

fn assert_matches_oracle(c: &Csr, r: &Csr, what: &str) {
    assert_eq!((c.n_rows, c.n_cols), (r.n_rows, r.n_cols), "{what}: shape");
    assert_eq!(c.rpt, r.rpt, "{what}: rpt differs (structure must be bit-for-bit)");
    assert_eq!(c.col, r.col, "{what}: col differs (structure must be bit-for-bit)");
    assert!(c.approx_eq(r, 1e-10), "{what}: values beyond 1e-10");
    assert!(c.validate().is_ok(), "{what}: invalid CSR");
}

#[test]
fn property_rmat_self_products_match_oracle() {
    qc::check(10, 4242, |g| {
        let n = 16 + g.dim() * 8;
        let nnz = n * (2 + g.rng.below_usize(6));
        let params = match g.rng.below_usize(3) {
            0 => RmatParams::web(),
            1 => RmatParams::citation(),
            _ => RmatParams::uniform(),
        };
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, nnz, params, &mut rng);
        let r = spgemm_reference(&a, &a);
        let c = hash::multiply(&a, &a);
        assert_matches_oracle(&c, &r, "rmat self-product");
        // The symbolic phase alone must already be exact (sizes, not
        // bounds), and the two-phase result must equal the seed engine
        // bit-for-bit — same structure AND same value bits.
        let plan = hash::symbolic(&a, &a);
        assert_eq!(plan.rpt, r.rpt, "symbolic plan sizes must be exact");
        assert_eq!(c, hash::multiply_single_pass(&a, &a), "two-phase vs seed single-pass");
    });
}

#[test]
fn property_structured_self_products_match_oracle() {
    qc::check(8, 2025, |g| {
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let n = 32 + g.dim() * 4;
        let (name, a) = match g.rng.below_usize(4) {
            0 => ("circuit", structured::circuit(n, &mut rng)),
            1 => ("economics", structured::economics(n, &mut rng)),
            2 => ("fem_banded", structured::fem_banded(n, 4, &mut rng)),
            _ => ("p2p", structured::p2p(n, &mut rng)),
        };
        let r = spgemm_reference(&a, &a);
        let c = hash::multiply(&a, &a);
        assert_matches_oracle(&c, &r, name);
        assert_eq!(c, hash::multiply_single_pass(&a, &a), "{name}: two-phase vs seed single-pass");
    });
}

#[test]
fn property_rectangular_products_and_plan_reuse() {
    qc::check(10, 909, |g| {
        let m = 1 + g.dim() * 2;
        let k = 1 + g.dim();
        let n = 1 + g.dim() * 3;
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let mut coo_a = spgemm_aia::sparse::Coo::new(m, k);
        let mut coo_b = spgemm_aia::sparse::Coo::new(k, n);
        for _ in 0..(m * k / 5).max(1) {
            coo_a.push(rng.below_usize(m), rng.below_usize(k), rng.f64_range(-1.0, 1.0));
        }
        for _ in 0..(k * n / 5).max(1) {
            coo_b.push(rng.below_usize(k), rng.below_usize(n), rng.f64_range(-1.0, 1.0));
        }
        let a = coo_a.to_csr();
        let b = coo_b.to_csr();
        let r = spgemm_reference(&a, &b);
        // One plan, two numeric runs: the plan is a pure function of the
        // structure and can be reused across value fills.
        let plan = hash::symbolic(&a, &b);
        let c1 = hash::numeric(&a, &b, &plan);
        let c2 = hash::numeric(&a, &b, &plan);
        assert_matches_oracle(&c1, &r, "rectangular");
        assert_eq!(c1, c2, "numeric must be deterministic given a plan");
    });
}

#[test]
fn property_phase_times_are_consistent() {
    qc::check(6, 31337, |g| {
        let n = 64 + g.dim() * 8;
        let mut rng = Pcg32::seeded(g.rng.next_u64());
        let a = rmat(n, n * 6, RmatParams::web(), &mut rng);
        let (c, t) = hash::multiply_timed(&a, &a);
        assert!(c.validate().is_ok());
        assert!(t.grouping_s >= 0.0 && t.symbolic_s >= 0.0 && t.numeric_s >= 0.0);
        let total = t.total_s();
        assert!((total - (t.grouping_s + t.symbolic_s + t.numeric_s)).abs() < 1e-15);
        assert!(total > 0.0, "timed phases cannot all be zero-width");
    });
}
