//! Service-daemon acceptance tests (`DESIGN.md` §2e), all in-process
//! through [`ServeHandle`] — the socket layer is a thin shell over the
//! same API and is exercised end to end by `tools/serve_smoke.py` in CI:
//!
//! - N concurrent clients with shared and distinct operands get results
//!   **bit-identical** to a cold `hash::multiply`, with plan sharing
//!   visible in the stats;
//! - a full queue answers `busy` — explicit backpressure, never a
//!   deadlock and never unbounded buffering;
//! - released handles error, and a reused slot can never alias a new
//!   matrix (generation counting);
//! - stats counters reconcile with the requests actually made, and
//!   export into the metrics registry;
//! - the daemon's store comes from *its own* configuration, not the
//!   process-wide `OnceLock` default (regression: a latched default
//!   must not hijack the daemon's cache directory);
//! - a second server on the same cache directory is served from disk
//!   with zero symbolic seconds.

use spgemm_aia::coordinator::PlanSource;
use spgemm_aia::gen::{rmat, RmatParams};
use spgemm_aia::serve::{csr_checksum, ServeConfig, ServeError, Server};
use spgemm_aia::sparse::Csr;
use spgemm_aia::spgemm::hash::{self, DiskStore, TieredStore};
use spgemm_aia::util::Pcg32;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-test scratch directory (tests run in parallel in one process —
/// the tag keeps them disjoint), cleaned on entry so every run is cold.
fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spgemm-aia-serve-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn rmat_square(seed: u64, n: usize, per_row: usize) -> Csr {
    let mut rng = Pcg32::seeded(seed);
    rmat(n, n * per_row, RmatParams::uniform(), &mut rng)
}

fn mem_cfg(queue_capacity: usize) -> ServeConfig {
    ServeConfig { queue_capacity, n_streams: 2, ..ServeConfig::default() }
}

/// Four clients on their own threads, every one multiplying the shared
/// `A` by `A` and by a private `B_i`. Every result must be
/// bit-identical to a cold multiply, and the shared structure must be
/// planned exactly once (the worker serializes, so every `A*A` after
/// the first is a memory hit).
#[test]
fn concurrent_clients_get_bit_identical_results_and_share_plans() {
    const CLIENTS: usize = 4;
    let server = Server::start_with_store(&mem_cfg(16), TieredStore::mem_only());
    let handle = server.handle();
    let a = Arc::new(rmat_square(1, 256, 5));
    let cold_aa = Arc::new(hash::multiply(&a, &a));

    let workers: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let handle = handle.clone();
            let a = Arc::clone(&a);
            let cold_aa = Arc::clone(&cold_aa);
            std::thread::spawn(move || {
                let client = handle.new_client();
                let b = Arc::new(rmat_square(10 + i as u64, 256, 4));
                let cold_ab = hash::multiply(&a, &b);
                let out_aa = handle.multiply(client, Arc::clone(&a), Arc::clone(&a)).expect("A*A");
                let out_ab = handle.multiply(client, Arc::clone(&a), Arc::clone(&b)).expect("A*B_i");
                assert_eq!(out_aa.c, *cold_aa, "client {i}: A*A must match a cold multiply bit for bit");
                assert_eq!(out_ab.c, cold_ab, "client {i}: A*B_{i} must match a cold multiply bit for bit");
                assert_eq!(out_aa.checksum, csr_checksum(&cold_aa));
                assert_eq!(out_ab.source, PlanSource::Fresh, "every B_i is a distinct structure");
                (client, out_aa.source)
            })
        })
        .collect();
    let outcomes: Vec<_> = workers.into_iter().map(|w| w.join().expect("client thread")).collect();

    // Exactly one client paid the symbolic phase for A*A.
    let fresh_aa = outcomes.iter().filter(|(_, s)| *s == PlanSource::Fresh).count();
    assert_eq!(fresh_aa, 1, "the shared structure must be planned exactly once");
    assert!(outcomes.iter().all(|(_, s)| *s != PlanSource::Disk), "memory-only store: no disk tier");

    let stats = handle.stats();
    assert_eq!(stats.requests, 2 * CLIENTS as u64);
    assert_eq!(stats.plan_hits, CLIENTS as u64 - 1, "3 of 4 A*A requests reuse the plan");
    assert_eq!(stats.plan_misses, CLIENTS as u64 + 1, "4 distinct B_i plus the first A*A");
    assert_eq!(stats.busy_rejections, 0);
    for (client, _) in &outcomes {
        let cs = stats.per_client.get(client).expect("per-client stats recorded");
        assert_eq!(cs.requests, 2, "client {client}: two multiplies");
        assert_eq!(cs.hits + cs.misses, 2, "client {client}: every request is a hit or a miss");
    }
    server.shutdown();
}

/// Backpressure, deterministically: quiesce parks the worker, the
/// bounded queue fills to exactly its capacity, and every further
/// submission bounces with `busy` instead of blocking or buffering.
/// Releasing the worker drains everything and all clients — including
/// the ones that had to retry — get bit-identical results.
#[test]
fn full_queue_answers_busy_then_drains_without_deadlock() {
    const CLIENTS: usize = 4;
    const CAPACITY: usize = 2; // deliberately < CLIENTS
    let server = Server::start_with_store(&mem_cfg(CAPACITY), TieredStore::mem_only());
    let handle = server.handle();
    assert_eq!(handle.queue_capacity(), CAPACITY);
    let a = Arc::new(rmat_square(2, 192, 4));
    let cold = hash::multiply(&a, &a);

    let guard = handle.quiesce().expect("park the worker");
    let workers: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let handle = handle.clone();
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                let client = handle.new_client();
                loop {
                    match handle.multiply(client, Arc::clone(&a), Arc::clone(&a)) {
                        Ok(out) => return out,
                        Err(ServeError::Busy { capacity, .. }) => {
                            assert_eq!(capacity, CAPACITY);
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => panic!("unexpected serve error: {e}"),
                    }
                }
            })
        })
        .collect();

    // With the worker parked, the queue must pin at capacity and the
    // overflow clients must be bouncing, not blocking. (Asserted on the
    // observed condition, not a fresh read — a retrying client's
    // in-flight submit transiently inflates the depth gauge by design.)
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut pinned = false;
    while Instant::now() < deadline && !pinned {
        pinned = handle.queue_depth() == CAPACITY && handle.stats().busy_rejections >= 2;
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        pinned,
        "queue must fill to its capacity with overflow rejected, not buffered (depth {}, busy {})",
        handle.queue_depth(),
        handle.stats().busy_rejections
    );

    drop(guard); // resume the worker: everything drains
    for w in workers {
        let out = w.join().expect("client thread");
        assert_eq!(out.c, cold, "retried requests must still be bit-identical");
    }
    assert_eq!(handle.stats().requests, CLIENTS as u64);
    assert_eq!(handle.queue_depth(), 0, "the queue drains completely");
    server.shutdown();
}

/// Generation-counted handles: a released handle errors everywhere it
/// could be used, and a new matrix landing in the recycled slot gets a
/// different raw id — the stale handle can never alias it.
#[test]
fn released_handles_error_and_never_alias_recycled_slots() {
    let server = Server::start_with_store(&mem_cfg(8), TieredStore::mem_only());
    let handle = server.handle();
    let client = handle.new_client();
    let a = rmat_square(3, 128, 4);
    let b = rmat_square(4, 128, 4);
    let cold_bb = hash::multiply(&b, &b);

    let ha = handle.register(a).expect("register A").raw();
    assert_eq!(handle.registered_live(), 1);
    handle.release(ha).expect("release A");
    assert_eq!(handle.registered_live(), 0);

    // Every use of the released handle is an error, not a stale read.
    assert!(matches!(handle.resolve(ha), Err(ServeError::UnknownHandle(_))));
    assert!(matches!(handle.release(ha), Err(ServeError::UnknownHandle(_))));
    match handle.multiply_by_handle(client, ha, ha) {
        Err(e @ ServeError::UnknownHandle(_)) => assert_eq!(e.code(), "unknown_handle"),
        other => panic!("released handle must be unknown, got {other:?}"),
    }

    // B recycles A's slot but under a bumped generation: new raw id,
    // and the old handle still resolves to nothing.
    let hb = handle.register(b).expect("register B").raw();
    assert_ne!(hb, ha, "recycled slot must mint a fresh raw id");
    assert!(matches!(handle.resolve(ha), Err(ServeError::UnknownHandle(_))));
    let out = handle.multiply_by_handle(client, hb, hb).expect("B*B through the fresh handle");
    assert_eq!(out.c, cold_bb);

    let stats = handle.stats();
    assert_eq!((stats.registered, stats.released), (2, 1));
    server.shutdown();
}

/// The stats counters reconcile with the requests actually made, and
/// the metrics export carries them (plus the queue gauges and the
/// per-client breakdown) into the registry.
#[test]
fn stats_reconcile_with_requests_and_export_to_metrics() {
    let server = Server::start_with_store(&mem_cfg(8), TieredStore::mem_only());
    let handle = server.handle();
    let client = handle.new_client();
    let a = Arc::new(rmat_square(5, 192, 4));

    let first = handle.multiply(client, Arc::clone(&a), Arc::clone(&a)).expect("first multiply");
    let second = handle.multiply(client, Arc::clone(&a), Arc::clone(&a)).expect("second multiply");
    assert_eq!(first.source, PlanSource::Fresh);
    assert_eq!(second.source, PlanSource::Mem);
    assert_eq!(second.symbolic_s, 0.0, "plan hits pay no symbolic seconds");
    assert_eq!((first.nnz, first.checksum), (second.nnz, second.checksum));

    let stats = handle.stats();
    assert_eq!((stats.requests, stats.plan_hits, stats.plan_misses, stats.disk_hits), (2, 1, 1, 0));
    assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    let cs = stats.per_client.get(&client).expect("per-client stats");
    assert_eq!((cs.requests, cs.hits, cs.misses), (2, 1, 1));
    // The worker's own store agrees with the serve-level counters.
    let ss = handle.store_stats();
    assert_eq!((ss.mem_hits, ss.misses, ss.stores), (1, 1, 1));

    let mut m = spgemm_aia::coordinator::metrics::Metrics::default();
    handle.export_metrics(&mut m);
    assert_eq!(m.counter("serve.requests"), 2);
    assert_eq!(m.counter("serve.plan_hits"), 1);
    assert_eq!(m.counter("serve.plan_misses"), 1);
    assert_eq!(m.counter(&format!("serve.client.{client}.requests")), 2);
    assert_eq!(m.counter("serve.store.mem_hits"), 1);
    let rendered = m.to_json().render();
    assert!(rendered.contains("serve.queue_depth"), "queue depth gauge exported: {rendered}");
    assert!(rendered.contains("serve.plan_hit_rate"), "hit-rate gauge exported: {rendered}");
    let js = handle.stats_json().render();
    assert!(js.contains("\"requests\":2") && js.contains("\"clients\""), "stats_json shape: {js}");
    server.shutdown();
}

/// Dynamic-graph path through the daemon: a client re-registers a
/// mutated matrix and multiplies; the response reports the delta
/// planner (`plan: "delta"`), the checksum matches a cold-process
/// oracle, and the serve/client/store stats all reconcile the patch as
/// neither hit nor miss.
#[test]
fn reregistered_mutated_matrix_is_served_by_delta_patch() {
    let server = Server::start_with_store(&mem_cfg(8), TieredStore::mem_only());
    let handle = server.handle();
    let client = handle.new_client();
    let a = rmat_square(8, 256, 5);
    let a2 = hash::mutate_row_fraction(&a, 0.01, 21);
    let oracle = hash::multiply(&a2, &a2); // cold-process oracle

    let ha = handle.register(a).expect("register A").raw();
    let warm = handle.multiply_by_handle(client, ha, ha).expect("warm multiply");
    assert_eq!(warm.source, PlanSource::Fresh);

    // Re-register the drifted structure and multiply: the worker's
    // executor patches the displaced plan instead of replanning cold.
    let ha2 = handle.register(a2).expect("register mutated A").raw();
    let out = handle.multiply_by_handle(client, ha2, ha2).expect("mutated multiply");
    assert_eq!(out.source, PlanSource::Delta, "a small structural drift must be delta-patched");
    assert_eq!(out.source.label(), "delta", "the wire `plan` field reports the delta path");
    assert!(!out.source.is_hit(), "a patch is not reuse — symbolic work ran for the dirty rows");
    assert_eq!(out.c, oracle, "delta-served fill must be bit-identical to a cold multiply");
    assert_eq!(out.checksum, csr_checksum(&oracle), "checksum must match the cold-process oracle");

    let stats = handle.stats();
    assert_eq!(stats.plan_deltas, 1);
    assert_eq!((stats.plan_hits, stats.plan_misses, stats.disk_hits), (0, 1, 0), "neither hit nor miss");
    assert_eq!(
        stats.requests,
        stats.plan_hits + stats.plan_misses + stats.disk_hits + stats.plan_deltas,
        "every request reconciles to exactly one plan source"
    );
    let cs = stats.per_client.get(&client).expect("per-client stats");
    assert_eq!((cs.requests, cs.hits, cs.misses, cs.deltas), (2, 0, 1, 1));
    let ss = handle.store_stats();
    assert_eq!(ss.delta_patches, 1, "the store reclassifies the probe miss as a patch");
    assert_eq!((ss.hits(), ss.misses), (0, 1), "only the warm request was a true miss");

    let mut m = spgemm_aia::coordinator::metrics::Metrics::default();
    handle.export_metrics(&mut m);
    assert_eq!(m.counter("serve.plan_deltas"), 1);
    assert_eq!(m.counter("serve.store.delta_patches"), 1);
    assert_eq!(m.counter(&format!("serve.client.{client}.deltas")), 1);
    let js = handle.stats_json().render();
    assert!(js.contains("\"plan_deltas\":1"), "stats_json carries the delta count: {js}");
    server.shutdown();
}

/// Regression (the `OnceLock` bug): the daemon's store must come from
/// its *own* flag/env resolution, never the process-wide default. A
/// latched default pointing elsewhere must not receive the daemon's
/// plan files.
#[test]
fn serve_store_comes_from_its_own_flag_not_the_process_default() {
    let decoy = scratch("oncelock-decoy");
    let flagged = scratch("oncelock-flag");
    // Latch the process default onto the decoy directory (first writer
    // wins; either way the cell now holds *something* that is not the
    // daemon's flag).
    let _ = hash::set_default_plan_cache_dir(decoy.clone());

    // Flag-over-env resolution is what `serve` feeds its config from.
    assert_eq!(
        spgemm_aia::serve::resolve_plan_cache(Some(flagged.to_str().unwrap()), Some(decoy.to_str().unwrap())),
        Some(flagged.clone()),
        "the flag must win over the environment"
    );
    assert_eq!(spgemm_aia::serve::resolve_plan_cache(None, Some("from-env")), Some(PathBuf::from("from-env")));
    assert_eq!(spgemm_aia::serve::resolve_plan_cache(Some(""), None), None, "empty flag counts as unset");

    let cfg = ServeConfig { plan_cache: Some(flagged.clone()), ..mem_cfg(8) };
    let server = Server::start(&cfg);
    let handle = server.handle();
    let a = Arc::new(rmat_square(6, 192, 4));
    handle.multiply(handle.new_client(), Arc::clone(&a), Arc::clone(&a)).expect("multiply");
    server.shutdown();

    assert!(
        !DiskStore::new(&flagged).entries().is_empty(),
        "the daemon must persist plans under its flagged directory"
    );
    assert!(
        DiskStore::new(&decoy).entries().is_empty(),
        "the latched process default must not receive the daemon's plans"
    );
    let _ = std::fs::remove_dir_all(&decoy);
    let _ = std::fs::remove_dir_all(&flagged);
}

/// Cross-process reuse through the daemon: a second server on the same
/// cache directory answers from the disk tier, bit-identically and
/// with zero symbolic seconds.
#[test]
fn second_server_on_same_cache_dir_is_served_from_disk() {
    let dir = scratch("cross-server");
    let a = Arc::new(rmat_square(7, 256, 5));
    let cfg = ServeConfig { plan_cache: Some(dir.clone()), ..mem_cfg(8) };

    let first = Server::start(&cfg);
    let h1 = first.handle();
    let warm = h1.multiply(h1.new_client(), Arc::clone(&a), Arc::clone(&a)).expect("warm the cache");
    assert_eq!(warm.source, PlanSource::Fresh);
    first.shutdown();

    let second = Server::start(&cfg);
    let h2 = second.handle();
    let hit = h2.multiply(h2.new_client(), Arc::clone(&a), Arc::clone(&a)).expect("served from disk");
    assert_eq!(hit.source, PlanSource::Disk, "a fresh server must find the persisted plan");
    assert_eq!(hit.symbolic_s, 0.0, "the disk hit skips the symbolic phase");
    assert_eq!((hit.nnz, hit.checksum), (warm.nnz, warm.checksum), "bit-identical across servers");
    assert_eq!(h2.store_stats().disk_hits, 1);
    assert_eq!(h2.stats().disk_hits, 1);
    second.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
