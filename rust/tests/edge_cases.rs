//! Edge-case and failure-injection tests across the public API.

use spgemm_aia::sparse::{io, Coo, Csr};
use spgemm_aia::spgemm::{esc, hash, ip, reference::spgemm_reference};
use std::io::Cursor;

#[test]
fn zero_dimension_products() {
    // 0xK · KxN and Mx0 · 0xN
    let a = Csr::zeros(0, 5);
    let b = Csr::zeros(5, 3);
    assert_eq!(hash::multiply(&a, &b).n_rows, 0);
    let a = Csr::zeros(4, 0);
    let b = Csr::zeros(0, 3);
    let c = hash::multiply(&a, &b);
    assert_eq!((c.n_rows, c.n_cols, c.nnz()), (4, 3, 0));
    assert_eq!(esc::multiply(&a, &b).nnz(), 0);
}

#[test]
fn single_element_matrices() {
    let a = Csr::from_dense(&[vec![2.0]]);
    let c = hash::multiply(&a, &a);
    assert_eq!(c.to_dense(), vec![vec![4.0]]);
    assert_eq!(ip::total_ip(&a, &a), 1);
}

#[test]
fn dense_row_times_dense_column_pattern() {
    // one full row × matrix with one full column — max collision pressure
    let n = 500;
    let mut coo_a = Coo::new(n, n);
    for j in 0..n {
        coo_a.push(0, j, 1.0);
    }
    let mut coo_b = Coo::new(n, n);
    for i in 0..n {
        coo_b.push(i, 0, 1.0);
        coo_b.push(i, (i * 7 + 1) % n, 0.5);
    }
    let a = coo_a.to_csr();
    let b = coo_b.to_csr();
    let c = hash::multiply(&a, &b);
    assert!(c.approx_eq(&spgemm_reference(&a, &b), 1e-10));
    // row 0 of C sums B's full column 0 (plus one aliased 0.5 extra)
    assert!(c.to_dense()[0][0] >= n as f64 - 1e-9);
}

#[test]
fn extreme_skew_one_hub_row() {
    // hub row with IP >> 8192 forces the group-3 global-table path
    let n = 3000;
    let mut coo = Coo::new(n, n);
    for j in 0..n {
        coo.push(0, j, 1.0); // hub row: IP = nnz(B) > 8192
        coo.push(j, (j + 1) % n, 1.0);
        coo.push(j, (j * 13 + 5) % n, 1.0);
    }
    let a = coo.to_csr();
    let ips = ip::intermediate_products(&a, &a);
    assert!(ips[0] >= 8192, "hub IP {} must land in group 3", ips[0]);
    let c = hash::multiply(&a, &a);
    assert!(c.approx_eq(&spgemm_reference(&a, &a), 1e-10));
}

#[test]
fn matrix_market_failure_injection() {
    // entry out of declared bounds
    let bad = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
    assert!(io::read_matrix_market_from(Cursor::new(bad)).is_err());
    // non-numeric value
    let bad = "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 xyz\n";
    assert!(io::read_matrix_market_from(Cursor::new(bad)).is_err());
    // truncated size line
    let bad = "%%MatrixMarket matrix coordinate real general\n2 2\n";
    assert!(io::read_matrix_market_from(Cursor::new(bad)).is_err());
    // empty file
    assert!(io::read_matrix_market_from(Cursor::new("")).is_err());
}

#[test]
fn runtime_missing_artifact_is_actionable() {
    let dir = std::env::temp_dir().join("spgemm_aia_missing_artifacts");
    let _ = std::fs::create_dir_all(&dir);
    let mut rt = spgemm_aia::runtime::Runtime::new(&dir).expect("client");
    let err = rt
        .call("layer_fwd", 8192, &[spgemm_aia::runtime::Tensor::zeros(vec![1])])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "error should point at the fix: {msg}");
}

#[test]
fn mcl_trivial_graphs() {
    use spgemm_aia::apps::{mcl, MclParams};
    use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
    // single node
    let g = Csr::from_dense(&[vec![0.0]]);
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    let r = mcl(&g, &MclParams::default(), &mut ex);
    assert_eq!(r.n_clusters, 1);
    // two isolated nodes
    let g = Csr::zeros(2, 2);
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    let r = mcl(&g, &MclParams::default(), &mut ex);
    assert_eq!(r.n_clusters, 2);
}

#[test]
fn contraction_to_single_supernode() {
    use spgemm_aia::apps::contract;
    use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
    let g = Csr::from_dense(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
    let mut ex = SpgemmExecutor::fast(Variant::Hash);
    let r = contract(&g, &[0, 0], &mut ex);
    assert_eq!(r.contracted.n_rows, 1);
    assert_eq!(r.contracted.to_dense(), vec![vec![2.0]]);
}

#[test]
fn cancellation_is_structural_in_all_engines() {
    // +1 and -1 products on the same output cell stay as explicit zeros
    let a = Csr::from_dense(&[vec![1.0, 1.0]]);
    let b = Csr::from_dense(&[vec![1.0], vec![-1.0]]);
    for c in [hash::multiply(&a, &b), esc::multiply(&a, &b), spgemm_reference(&a, &b)] {
        assert_eq!(c.nnz(), 1, "structural semantics");
        assert_eq!(c.val[0], 0.0);
    }
}

/// Degenerate shapes through the estimated planner (DESIGN.md §2g):
/// the sampler and the speculative numeric driver must agree bit-for-
/// bit with the exact engine on empty operands, all-zero rows, a
/// single-row matrix, and an `n_cols = 0` product — the shapes where
/// "sample 2% of rows" rounds to nothing or everything.
#[test]
fn estimated_path_degenerate_shapes() {
    let cases: Vec<(&str, Csr, Csr)> = vec![
        ("empty 0x5 * 5x3", Csr::zeros(0, 5), Csr::zeros(5, 3)),
        ("inner-empty 4x0 * 0x3", Csr::zeros(4, 0), Csr::zeros(0, 3)),
        ("all-zero rows 6x6", Csr::zeros(6, 6), Csr::zeros(6, 6)),
        ("n_cols=0 3x2 * 2x0", Csr::from_dense(&[vec![1.0, 2.0], vec![0.0, 1.0], vec![3.0, 0.0]]), Csr::zeros(2, 0)),
        (
            "single row 1x3",
            Csr::from_dense(&[vec![1.0, 0.0, 2.0]]),
            Csr::from_dense(&[vec![1.0, 1.0, 0.0], vec![0.0, 0.0, 0.0], vec![0.5, 0.0, 4.0]]),
        ),
        (
            "sparse rows interleaved with zero rows",
            Csr::from_dense(&[vec![1.0, 0.0], vec![0.0, 0.0], vec![2.0, 3.0], vec![0.0, 0.0]]),
            Csr::from_dense(&[vec![1.0, 2.0], vec![3.0, 0.0]]),
        ),
    ];
    for (name, a, b) in &cases {
        let exact = hash::multiply(a, b);
        let (c, rep) = hash::multiply_estimated(a, b);
        assert_eq!((c.n_rows, c.n_cols), (exact.n_rows, exact.n_cols), "{name}: shape");
        assert_eq!(c.rpt, exact.rpt, "{name}: row pointers");
        assert_eq!(c.col, exact.col, "{name}: column indices");
        let (eb, gb): (Vec<u64>, Vec<u64>) =
            (exact.val.iter().map(|v| v.to_bits()).collect(), c.val.iter().map(|v| v.to_bits()).collect());
        assert_eq!(eb, gb, "{name}: values bitwise");
        assert_eq!(rep.nnz, exact.nnz(), "{name}: reported nnz");
    }
}

/// The same degenerate shapes through the *forced-fallback* grow path:
/// a zero-estimate injector sends every non-trivial row down the
/// grow-and-retry ladder from the smallest table, which must recover
/// bit-identically even when there is nothing (or only one row) to
/// grow.
#[test]
fn estimated_path_degenerate_shapes_forced_fallback() {
    use spgemm_aia::spgemm::hash::{EngineConfig, EstimateParams};
    let dense_row: Vec<f64> = (0..32).map(|j| 1.0 + j as f64).collect();
    let eye: Vec<Vec<f64>> = (0..32).map(|i| (0..32).map(|j| if i == j { 2.0 } else { 0.0 }).collect()).collect();
    let cases: Vec<(&str, Csr, Csr)> = vec![
        ("empty 0x5 * 5x3", Csr::zeros(0, 5), Csr::zeros(5, 3)),
        ("inner-empty 4x0 * 0x3", Csr::zeros(4, 0), Csr::zeros(0, 3)),
        ("n_cols=0 2x2 * 2x0", Csr::from_dense(&[vec![1.0, 2.0], vec![3.0, 4.0]]), Csr::zeros(2, 0)),
        ("single dense row 1x32", Csr::from_dense(&[dense_row]), Csr::from_dense(&eye)),
    ];
    let (cfg, params) = (EngineConfig::default(), EstimateParams::default());
    for (name, a, b) in &cases {
        let exact = hash::multiply(a, b);
        let (c, _) = hash::multiply_estimated_injected(a, b, &cfg, &params, &|_r, _e| 0);
        assert_eq!(c.rpt, exact.rpt, "{name}: row pointers under forced zero estimates");
        assert_eq!(c.col, exact.col, "{name}: column indices under forced zero estimates");
        let (eb, gb): (Vec<u64>, Vec<u64>) =
            (exact.val.iter().map(|v| v.to_bits()).collect(), c.val.iter().map(|v| v.to_bits()).collect());
        assert_eq!(eb, gb, "{name}: values bitwise under forced zero estimates");
    }
}
