//! Acceptance tests for measurement-calibrated kernel thresholds: the
//! resolution ladder (explicit flag > `SPGEMM_AIA_SPA_THRESHOLD` >
//! persisted `calibration.json` next to the plan cache > cache
//! geometry), the cross-process `calibrate` → load flow through the
//! real binary, corruption fallback, plan-cache tooling tolerance of
//! the calibration file, and bit-identical outputs under any
//! threshold. Cross-process behavior is exercised with fresh
//! `spgemm-aia` processes — the in-process defaults latch on first
//! read (`OnceLock`), so a library test could never observe more than
//! one rung of the ladder.

use spgemm_aia::gen::table2_by_name;
use spgemm_aia::sim::DeviceConfig;
use spgemm_aia::spgemm::hash::{
    multiply_cfg, resolve_default_spa_threshold, Calibration, DiskStore, EngineConfig, PlannerPolicy,
    CALIBRATION_FILE, CALIBRATION_VERSION,
};
use std::path::{Path, PathBuf};
use std::process::Command;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spgemm-aia-calib-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The binary under test, with the developer shell's threshold/cache
/// configuration scrubbed so every rung of the ladder is ours to set.
fn bin() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_spgemm-aia"));
    c.env_remove("SPGEMM_AIA_PLAN_CACHE");
    c.env_remove("SPGEMM_AIA_SPA_THRESHOLD");
    c
}

/// Run `spgemm-aia info [extra_args]` in a fresh process and parse the
/// threshold it resolved as its default.
fn info_threshold(extra_args: &[&str], cache_dir: Option<&Path>) -> f64 {
    let mut c = bin();
    if let Some(d) = cache_dir {
        c.env("SPGEMM_AIA_PLAN_CACHE", d);
    }
    let out = c.arg("info").args(extra_args).output().expect("spawn spgemm-aia info");
    assert!(out.status.success(), "info failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let line = stdout
        .lines()
        .find_map(|l| l.strip_prefix("spa-threshold: "))
        .unwrap_or_else(|| panic!("no spa-threshold line in:\n{stdout}"));
    line.trim().parse().unwrap_or_else(|_| panic!("unparsable threshold {line:?}"))
}

fn geometry() -> f64 {
    DeviceConfig::h200_scaled().dense_row_threshold_base()
}

#[test]
fn resolver_implements_the_ladder() {
    let g = geometry();
    // Geometry is the floor...
    assert_eq!(resolve_default_spa_threshold(None, None, g), g);
    // ...a persisted calibration beats it...
    assert_eq!(resolve_default_spa_threshold(None, Some(0.4), g), 0.4);
    // ...and an explicit env value beats both.
    assert_eq!(resolve_default_spa_threshold(Some("0.1"), Some(0.4), g), 0.1);
    // Unparsable or out-of-range env values drop to the next rung, they
    // never poison the resolution.
    assert_eq!(resolve_default_spa_threshold(Some("junk"), Some(0.4), g), 0.4);
    assert_eq!(resolve_default_spa_threshold(Some("-1"), None, g), g);
    assert_eq!(resolve_default_spa_threshold(Some("9"), None, g), g);
}

#[test]
fn calibrate_writes_a_file_a_fresh_process_loads_as_its_default() {
    let dir = tmp_dir("flow");
    let out = bin()
        .args(["calibrate", "--datasets", "p2p-Gnutella04", "--grid", "0.1,0.5", "--out"])
        .arg(&dir)
        .output()
        .expect("spawn spgemm-aia calibrate");
    assert!(out.status.success(), "calibrate failed: {}", String::from_utf8_lossy(&out.stderr));
    let cal = Calibration::load(&dir).expect("calibrate must write a valid calibration.json");
    assert_eq!(cal.version, CALIBRATION_VERSION);
    assert!([0.1, 0.5].contains(&cal.spa_threshold), "winner must come from the grid, got {}", cal.spa_threshold);
    assert_eq!(cal.sweep.len(), 2, "one point per grid threshold");
    assert_eq!(cal.datasets, vec!["p2p-Gnutella04".to_string()]);
    // A fresh process pointed at the directory resolves the calibrated
    // value as its default...
    assert_eq!(info_threshold(&[], Some(&dir)), cal.spa_threshold);
    // ...an explicit flag still wins...
    assert_eq!(info_threshold(&["--spa-threshold", "0.33"], Some(&dir)), 0.33);
    // ...and without the cache dir the geometry fallback stands.
    assert_eq!(info_threshold(&[], None), geometry());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_or_foreign_calibration_degrades_to_geometry() {
    let dir = tmp_dir("corrupt");
    std::fs::write(dir.join(CALIBRATION_FILE), b"{ definitely not json").unwrap();
    assert_eq!(info_threshold(&[], Some(&dir)), geometry());
    // A structurally valid file from a *future* format version is
    // ignored the same way, never reinterpreted.
    let future = Calibration {
        version: CALIBRATION_VERSION + 1,
        spa_threshold: 0.4,
        geometry_threshold: geometry(),
        datasets: vec![],
        sweep: vec![],
    };
    future.save(&dir).unwrap();
    assert_eq!(info_threshold(&[], Some(&dir)), geometry());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_cache_tooling_tolerates_the_calibration_file() {
    let dir = tmp_dir("tooling");
    let cal = Calibration {
        version: CALIBRATION_VERSION,
        spa_threshold: 0.2,
        geometry_threshold: geometry(),
        datasets: vec!["x".into()],
        sweep: vec![],
    };
    cal.save(&dir).unwrap();
    // The disk store's listing is .plan-scoped: the calibration file
    // must not surface as a (necessarily corrupt) plan entry.
    let store = DiskStore::new(&dir);
    assert!(store.entries().is_empty(), "calibration.json must not appear as a plan entry");
    // And the CLI lifecycle tooling over the same directory stays green.
    let out = bin().args(["plan-cache", "verify", "--dir"]).arg(&dir).output().expect("spawn verify");
    assert!(out.status.success(), "verify failed: {}", String::from_utf8_lossy(&out.stderr));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn outputs_are_bit_identical_under_any_threshold() {
    let ds = table2_by_name("p2p-Gnutella04").unwrap();
    let a = (ds.gen)(spgemm_aia::repro::SEED);
    let cfg = |t: f64| EngineConfig {
        spa_threshold: t,
        symbolic_threshold: None,
        planner: PlannerPolicy::Exact,
        mask: None,
    };
    // 0.1 routes dense rows through SPA/bitmap, 8.0 disables both — the
    // threshold steers kernel choice only, never the result.
    let c_lo = multiply_cfg(&a, &a, &cfg(0.1));
    let c_mid = multiply_cfg(&a, &a, &cfg(geometry()));
    let c_hi = multiply_cfg(&a, &a, &cfg(8.0));
    assert_eq!(c_lo, c_hi);
    assert_eq!(c_lo, c_mid);
}
