//! SpGEMM engines: the paper's hash-based multi-phase algorithm, the
//! ESC baseline standing in for cuSPARSE, and a dense-accumulator
//! reference oracle.
//!
//! All engines compute standard *structural* SpGEMM semantics (the
//! output pattern is every column reachable through an intermediate
//! product, including cancellations) and agree bit-for-bit on structure
//! and to 1e-10 on values — enforced by cross-tests and property tests.

pub mod esc;
pub mod hash;
pub mod ip;
pub mod reference;

use crate::sim::probe::Probe;
use crate::sparse::Csr;

/// Engine selector used by applications, the coordinator, and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Paper's hash-based multi-phase engine (§III).
    Hash,
    /// Expand–sort–compress baseline ("cuSPARSE").
    Esc,
    /// Sequential dense-accumulator oracle.
    Reference,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Hash => "hash",
            Algo::Esc => "esc",
            Algo::Reference => "reference",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(Algo::Hash),
            "esc" | "cusparse" => Some(Algo::Esc),
            "reference" | "ref" => Some(Algo::Reference),
            _ => None,
        }
    }
}

/// `C = A · B` with the chosen engine (fast functional path).
pub fn spgemm(algo: Algo, a: &Csr, b: &Csr) -> Csr {
    match algo {
        Algo::Hash => hash::engine::multiply(a, b),
        Algo::Esc => esc::multiply(a, b),
        Algo::Reference => reference::spgemm_reference(a, b),
    }
}

/// `C = A · B` with a full memory trace (sequential; used by the AIA
/// simulator). `Reference` has no GPU realization — traces as Hash.
pub fn spgemm_traced<P: Probe>(algo: Algo, a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    match algo {
        Algo::Hash | Algo::Reference => hash::engine::multiply_traced(a, b, probe),
        Algo::Esc => esc::multiply_traced(a, b, probe),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_parse_roundtrip() {
        assert_eq!(Algo::parse("hash"), Some(Algo::Hash));
        assert_eq!(Algo::parse("CUSPARSE"), Some(Algo::Esc));
        assert_eq!(Algo::parse("ref"), Some(Algo::Reference));
        assert_eq!(Algo::parse("bogus"), None);
        assert_eq!(Algo::Hash.name(), "hash");
    }

    #[test]
    fn all_engines_agree() {
        let a = Csr::from_dense(&[vec![1.0, 0.0, 2.0], vec![0.0, 3.0, 0.0], vec![4.0, 5.0, 6.0]]);
        let b = Csr::from_dense(&[vec![1.0, 1.0, 0.0], vec![0.0, 2.0, 1.0], vec![3.0, 0.0, 1.0]]);
        let r = spgemm(Algo::Reference, &a, &b);
        assert!(spgemm(Algo::Hash, &a, &b).approx_eq(&r, 1e-12));
        assert!(spgemm(Algo::Esc, &a, &b).approx_eq(&r, 1e-12));
    }
}
