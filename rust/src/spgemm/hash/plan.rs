//! Plan reuse for iterative workloads (ROADMAP "Batched multi-matrix
//! execution").
//!
//! The headline iterative workloads — Markov clustering re-multiplying
//! `M·M` every iteration, GNN training reusing one sparsified adjacency
//! every epoch — repeat products whose *structure* is stable while only
//! the *values* change. The symbolic phase is a pure function of the
//! operands' structure, so its output ([`SymbolicPlan`]: exact row
//! pointers, row grouping, IP bounds) can be computed once and amortised
//! across numeric fills. [`PlannedProduct`] packages that: it owns the
//! plan plus the structure fingerprints of the operands it was built
//! from, validates every fill against them
//! ([`PlannedProduct::matches`]), and times plan construction separately
//! from fills so executors can account grouping/symbolic/numeric wall
//! time exactly as [`super::engine::multiply_timed`] does.
//!
//! Because the row-kernel decision is part of the plan
//! ([`SymbolicPlan::bins`] carries each Table-I bin split by the
//! ([`super::grouping::SymbolicKind`], [`super::grouping::AccumKind`])
//! pair), a reused fill also reuses the hash/SPA/scaled-copy selection
//! — iterative callers pay the density analysis once, at plan time —
//! and the plan records which counting kernel produced each row's size
//! (`plan_times` keeps the per-kernel symbolic split alongside the
//! grouping/symbolic totals).
//!
//! Callers that manage whole batches (plan product *k+1* while product
//! *k* fills, stream-schedule the per-kind Table-I bins, dispatch
//! per-bin completion events) sit one layer up, in
//! [`crate::coordinator::batch::BatchExecutor`].

use super::engine::{numeric, numeric_timed, symbolic_timed, EngineConfig, SymbolicPlan};
use crate::sim::probe::PhaseTimes;
use crate::sparse::Csr;

/// A reusable symbolic plan for one `A·B` product, bound to the
/// structure of the operands it was planned from.
///
/// Obtain one with [`PlannedProduct::plan`], then run any number of
/// numeric fills with [`PlannedProduct::fill`] — each fill costs only
/// the numeric phase. [`PlannedProduct::matches`] reports whether the
/// plan is still valid for a (possibly mutated) operand pair, which is
/// how iterative callers decide between reuse and replan.
pub struct PlannedProduct {
    plan: SymbolicPlan,
    a_shape: (usize, usize),
    b_shape: (usize, usize),
    a_hash: u64,
    b_hash: u64,
    /// Per-row structure hashes of the operands at plan time
    /// ([`Csr::row_structure_hashes`]) — what the incremental replanner
    /// diffs against a mutated operand to find the dirty rows.
    a_row_hashes: Vec<u64>,
    b_row_hashes: Vec<u64>,
    /// `None` for a cold (full-symbolic) plan; `Some` when this plan was
    /// produced by patching an earlier plan in place — the lineage is
    /// what keeps the fingerprint chain honest across the store tiers.
    delta: Option<DeltaLineage>,
    /// Wall time spent building the plan (`grouping_s` + `symbolic_s`;
    /// `numeric_s` stays 0 — fills report their own time).
    pub plan_times: PhaseTimes,
}

/// Provenance of a delta-patched plan: which cold plan it descends
/// from, how many patches deep, and an order-sensitive digest of every
/// applied dirty set. A patched plan's *identity* (its `a_hash`/
/// `b_hash`, hence its store key) is that of the mutated operands it
/// now serves; the lineage is the audit trail the plan store validates
/// so a stale or forged chain degrades to a full replan, never a wrong
/// answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeltaLineage {
    /// `a_hash` of the root cold plan this chain grew from.
    pub base_a_hash: u64,
    /// `b_hash` of the root cold plan.
    pub base_b_hash: u64,
    /// Number of patches applied since the cold plan (≥ 1).
    pub chain_len: u32,
    /// Digest the chain carried *before* this patch: the root's
    /// [`pair_key_from_hashes`] for the first patch, the previous
    /// lineage's `digest` afterwards. Stored so validators can recompute
    /// `digest` without replaying the mutation history.
    pub prev_digest: u64,
    /// Ordered fold over every applied delta:
    /// `digest = fnv1a_seeded(prev_digest, encode(lineage fields,
    /// patched identity, patched row hashes))` — see [`chain_digest`].
    /// Order-sensitive (each step seeds from the last) and verifiable
    /// from the plan's own content, so both store tiers can reject a
    /// forged or bit-damaged chain as stale.
    pub digest: u64,
}

impl DeltaLineage {
    /// The digest this lineage must carry to be coherent with a plan
    /// whose identity is `(a_hash, b_hash)` and whose per-row hashes are
    /// `(a_rows, b_rows)` — anything else marks the chain stale.
    pub(crate) fn expected_digest(&self, a_hash: u64, b_hash: u64, a_rows: &[u64], b_rows: &[u64]) -> u64 {
        chain_digest(self.prev_digest, self.base_a_hash, self.base_b_hash, self.chain_len, a_hash, b_hash, a_rows, b_rows)
    }
}

/// One step of the delta-digest fold (see [`DeltaLineage::digest`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn chain_digest(
    prev: u64,
    base_a_hash: u64,
    base_b_hash: u64,
    chain_len: u32,
    a_hash: u64,
    b_hash: u64,
    a_rows: &[u64],
    b_rows: &[u64],
) -> u64 {
    let mut w = crate::util::serial::Writer::new();
    w.put_u64(base_a_hash);
    w.put_u64(base_b_hash);
    w.put_u32(chain_len);
    w.put_u64(a_hash);
    w.put_u64(b_hash);
    w.put_u64_slice(a_rows);
    w.put_u64_slice(b_rows);
    crate::util::serial::fnv1a_seeded(prev, w.bytes())
}

impl PlannedProduct {
    /// Run grouping + symbolic analysis for `a·b` and capture the
    /// operands' structure fingerprints (process-default
    /// [`EngineConfig`]).
    pub fn plan(a: &Csr, b: &Csr) -> PlannedProduct {
        PlannedProduct::plan_cfg(a, b, &EngineConfig::default())
    }

    /// [`PlannedProduct::plan`] with an explicit [`EngineConfig`] — the
    /// SPA threshold is baked into the plan and reused by every fill.
    pub fn plan_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> PlannedProduct {
        PlannedProduct::plan_cfg_hashed(a, b, cfg, a.structure_hash(), b.structure_hash())
    }

    /// [`PlannedProduct::plan_cfg`] with the operands' structure hashes
    /// precomputed by the caller — cache layers already hold them as
    /// keys, so this skips a second O(nnz) hashing pass. The hashes
    /// must be `a.structure_hash()`/`b.structure_hash()` of these exact
    /// operands.
    pub(crate) fn plan_cfg_hashed(a: &Csr, b: &Csr, cfg: &EngineConfig, a_hash: u64, b_hash: u64) -> PlannedProduct {
        let (plan, plan_times) = symbolic_timed(a, b, cfg);
        PlannedProduct {
            plan,
            a_shape: (a.n_rows, a.n_cols),
            b_shape: (b.n_rows, b.n_cols),
            a_hash,
            b_hash,
            a_row_hashes: a.row_structure_hashes().to_vec(),
            b_row_hashes: b.row_structure_hashes().to_vec(),
            delta: None,
            plan_times,
        }
    }

    /// Rebuild a handle from deserialized parts (the plan store's disk
    /// tier). `plan_times` is zeroed: a loaded plan paid no symbolic
    /// seconds in this process — loaders charge their load+validate wall
    /// time themselves. The caller (the store) is responsible for plan /
    /// fingerprint coherence; a wrong pairing is caught by the same
    /// `matches` guard every fill path runs.
    pub(crate) fn from_parts(
        plan: SymbolicPlan,
        a_shape: (usize, usize),
        b_shape: (usize, usize),
        a_hash: u64,
        b_hash: u64,
        a_row_hashes: Vec<u64>,
        b_row_hashes: Vec<u64>,
        delta: Option<DeltaLineage>,
    ) -> PlannedProduct {
        PlannedProduct {
            plan,
            a_shape,
            b_shape,
            a_hash,
            b_hash,
            a_row_hashes,
            b_row_hashes,
            delta,
            plan_times: PhaseTimes::default(),
        }
    }

    /// Assemble a delta-patched plan (the incremental replanner's
    /// constructor): the patched `SymbolicPlan`, the mutated operands'
    /// whole-structure and per-row hashes, and the extended lineage.
    /// `plan_times` carries only the patch's own symbolic seconds.
    pub(crate) fn from_patch(
        plan: SymbolicPlan,
        a: &Csr,
        b: &Csr,
        a_hash: u64,
        b_hash: u64,
        delta: DeltaLineage,
        plan_times: PhaseTimes,
    ) -> PlannedProduct {
        PlannedProduct {
            plan,
            a_shape: (a.n_rows, a.n_cols),
            b_shape: (b.n_rows, b.n_cols),
            a_hash,
            b_hash,
            a_row_hashes: a.row_structure_hashes().to_vec(),
            b_row_hashes: b.row_structure_hashes().to_vec(),
            delta: Some(delta),
            plan_times,
        }
    }

    /// Per-row structure hashes of operand A at plan time.
    pub(crate) fn a_row_hashes(&self) -> &[u64] {
        &self.a_row_hashes
    }

    /// Per-row structure hashes of operand B at plan time.
    pub(crate) fn b_row_hashes(&self) -> &[u64] {
        &self.b_row_hashes
    }

    /// Delta lineage, if this plan was produced by incremental patching
    /// (`None` for cold full-symbolic plans).
    pub fn delta(&self) -> Option<&DeltaLineage> {
        self.delta.as_ref()
    }

    /// Whether the delta lineage (if any) is internally coherent: chain
    /// length within the rebuild threshold and the digest reproducible
    /// from the plan's own identity and row hashes. Cold plans are
    /// trivially coherent. Both store tiers gate on this so a stale,
    /// truncated, or forged chain degrades to a silent full replan.
    pub(crate) fn lineage_is_coherent(&self) -> bool {
        match &self.delta {
            None => true,
            Some(d) => {
                (1..=super::incremental::MAX_DELTA_CHAIN).contains(&d.chain_len)
                    && d.digest == d.expected_digest(self.a_hash, self.b_hash, &self.a_row_hashes, &self.b_row_hashes)
            }
        }
    }

    /// Shape of operand A at plan time (serialization accessor).
    pub(crate) fn a_shape(&self) -> (usize, usize) {
        self.a_shape
    }

    /// Shape of operand B at plan time (serialization accessor).
    pub(crate) fn b_shape(&self) -> (usize, usize) {
        self.b_shape
    }

    /// Structure hash of operand A at plan time (serialization accessor).
    pub(crate) fn a_hash(&self) -> u64 {
        self.a_hash
    }

    /// Structure hash of operand B at plan time (serialization accessor).
    pub(crate) fn b_hash(&self) -> u64 {
        self.b_hash
    }

    /// Whether this plan is valid for `(a, b)`: same shapes and same
    /// structure hashes as at plan time. The operands' hashes are
    /// memoized ([`Csr::structure_hash`]), so on hot reuse paths this is
    /// a cell read, not an O(nnz) re-scan. Callers that already hold the
    /// hashes (e.g. as a cache key) can use
    /// [`PlannedProduct::matches_fingerprint`] directly.
    pub fn matches(&self, a: &Csr, b: &Csr) -> bool {
        self.matches_fingerprint(
            (a.n_rows, a.n_cols),
            (b.n_rows, b.n_cols),
            a.structure_hash(),
            b.structure_hash(),
        )
    }

    /// [`PlannedProduct::matches`] against precomputed shapes and
    /// structure hashes — no operand scan. Structure-only: masked
    /// callers must additionally check [`PlannedProduct::mask_hash`]
    /// (the store tiers do, via `PlanFingerprint`).
    pub fn matches_fingerprint(
        &self,
        a_shape: (usize, usize),
        b_shape: (usize, usize),
        a_hash: u64,
        b_hash: u64,
    ) -> bool {
        self.a_shape == a_shape && self.b_shape == b_shape && self.a_hash == a_hash && self.b_hash == b_hash
    }

    /// Structure hash of the output mask this plan was built under
    /// (`None` for unmasked plans). A plan only serves requests with
    /// the same mask identity — the sizes in `rpt` are masked exact
    /// counts, meaningless under any other mask.
    pub fn mask_hash(&self) -> Option<u64> {
        self.plan.mask.as_ref().map(|m| m.structure_hash())
    }

    /// Numeric fill under this plan: identical output to a cold
    /// [`super::engine::multiply`] on the same operands, at the cost of
    /// the numeric phase only.
    ///
    /// Panics if the operands' structure no longer matches the plan
    /// (callers should [`PlannedProduct::matches`]-check and replan on
    /// structural change instead of relying on this guard).
    pub fn fill(&self, a: &Csr, b: &Csr) -> Csr {
        assert!(
            self.matches(a, b),
            "PlannedProduct::fill: operand structure changed since plan time — replan"
        );
        self.fill_unchecked(a, b)
    }

    /// [`PlannedProduct::fill`] plus the fill's wall time as a
    /// [`PhaseTimes`] (only the `numeric*` fields are populated — the
    /// numeric total and the per-accumulator-kind split; validation
    /// runs before the timer starts).
    pub fn fill_timed(&self, a: &Csr, b: &Csr) -> (Csr, PhaseTimes) {
        assert!(
            self.matches(a, b),
            "PlannedProduct::fill: operand structure changed since plan time — replan"
        );
        self.fill_unchecked_timed(a, b)
    }

    /// Fill without revalidating the operands — for callers that just
    /// ran [`PlannedProduct::matches`]/[`PlannedProduct::matches_fingerprint`]
    /// or built the plan from these exact operands. A stale plan still
    /// cannot corrupt memory (the numeric phase asserts per-row counts),
    /// but the panic arrives later and uglier than `fill`'s.
    pub(crate) fn fill_unchecked(&self, a: &Csr, b: &Csr) -> Csr {
        numeric(a, b, &self.plan)
    }

    /// [`PlannedProduct::fill_unchecked`] plus the fill's wall time
    /// (numeric fields of [`PhaseTimes`] only).
    pub(crate) fn fill_unchecked_timed(&self, a: &Csr, b: &Csr) -> (Csr, PhaseTimes) {
        numeric_timed(a, b, &self.plan)
    }

    /// The underlying symbolic plan (exact output sizes, grouping, IP).
    pub fn symbolic_plan(&self) -> &SymbolicPlan {
        &self.plan
    }

    /// Exact output non-zeros this plan will produce.
    pub fn nnz(&self) -> usize {
        self.plan.nnz()
    }

    /// Estimated work (summed intermediate products) per Table-I row
    /// group. These are the per-bin job weights the coordinator's stream
    /// scheduler packs onto streams, letting the group-3 (global-table,
    /// AIA-heavy) bin co-schedule with the PWPR bins.
    pub fn group_work(&self) -> [u64; 4] {
        let mut w = [0u64; 4];
        for (g, wg) in w.iter_mut().enumerate() {
            for &r in self.plan.grouping.group_rows(g) {
                *wg += self.plan.ip[r as usize];
            }
        }
        w
    }

    /// Combined fingerprint of the operand pair this plan was built for
    /// (cache key for plan caches). Masked plans fold the mask's
    /// structure hash in exactly as
    /// [`super::planstore::PlanFingerprint::key`] does, so the two key
    /// derivations can never disagree on the same plan.
    pub fn key(&self) -> u64 {
        let k = pair_key_from_hashes(self.a_hash, self.b_hash);
        match self.mask_hash() {
            None => k,
            Some(mh) => pair_key_from_hashes(k, mh),
        }
    }
}

/// Cache key for an `(a, b)` operand pair — combines both structure
/// hashes order-sensitively (`a·b` and `b·a` get distinct keys).
pub fn pair_key(a: &Csr, b: &Csr) -> u64 {
    pair_key_from_hashes(a.structure_hash(), b.structure_hash())
}

/// [`pair_key`] from precomputed structure hashes (no operand scan).
pub fn pair_key_from_hashes(ah: u64, bh: u64) -> u64 {
    let h = (ah ^ bh.rotate_left(17)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^ (h >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::hash::engine::multiply;
    use crate::util::Pcg32;

    fn random_csr(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Csr {
        let mut coo = crate::sparse::Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density) as usize;
        for _ in 0..target {
            coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-2.0, 2.0));
        }
        coo.to_csr()
    }

    #[test]
    fn reused_fill_is_bit_identical_to_cold_multiply() {
        let mut rng = Pcg32::seeded(42);
        let a = random_csr(&mut rng, 200, 180, 0.03);
        let b = random_csr(&mut rng, 180, 160, 0.03);
        let p = PlannedProduct::plan(&a, &b);
        assert_eq!(p.nnz(), multiply(&a, &b).nnz());
        let c1 = p.fill(&a, &b);
        let c2 = p.fill(&a, &b);
        assert_eq!(c1, multiply(&a, &b), "planned fill must equal cold multiply bit-for-bit");
        assert_eq!(c1, c2, "fills must be deterministic");
    }

    #[test]
    fn fill_accepts_new_values_same_structure() {
        let mut rng = Pcg32::seeded(7);
        let a = random_csr(&mut rng, 120, 120, 0.05);
        let p = PlannedProduct::plan(&a, &a);
        let mut a2 = a.clone();
        a2.map_values(|v| v * 3.0 - 1.0);
        assert!(p.matches(&a2, &a2), "value changes must not invalidate the plan");
        assert_eq!(p.fill(&a2, &a2), multiply(&a2, &a2));
    }

    #[test]
    fn matches_rejects_structural_change() {
        let a = Csr::from_dense(&[vec![1.0, 0.0, 2.0], vec![0.0, 3.0, 0.0], vec![4.0, 0.0, 5.0]]);
        let p = PlannedProduct::plan(&a, &a);
        assert!(p.matches(&a, &a));
        // Same shape and nnz count, one entry moved to a new column.
        let moved = Csr::from_dense(&[vec![1.0, 2.0, 0.0], vec![0.0, 3.0, 0.0], vec![4.0, 0.0, 5.0]]);
        assert!(!p.matches(&moved, &moved));
        // One extra entry.
        let grown = Csr::from_dense(&[vec![1.0, 6.0, 2.0], vec![0.0, 3.0, 0.0], vec![4.0, 0.0, 5.0]]);
        assert!(!p.matches(&grown, &grown));
    }

    #[test]
    #[should_panic(expected = "structure changed")]
    fn fill_panics_on_stale_plan() {
        let a = Csr::identity(8);
        let p = PlannedProduct::plan(&a, &a);
        let b = Csr::identity(9);
        p.fill(&b, &b);
    }

    #[test]
    fn group_work_covers_all_ip() {
        let mut rng = Pcg32::seeded(3);
        let a = random_csr(&mut rng, 150, 150, 0.04);
        let p = PlannedProduct::plan(&a, &a);
        let total: u64 = p.group_work().iter().sum();
        assert_eq!(total, p.symbolic_plan().ip.iter().sum::<u64>(), "group work must partition total IP");
    }

    #[test]
    fn pair_key_is_order_sensitive() {
        let mut rng = Pcg32::seeded(5);
        let a = random_csr(&mut rng, 40, 30, 0.1);
        let b = random_csr(&mut rng, 30, 40, 0.1);
        assert_ne!(pair_key(&a, &b), pair_key(&b, &a));
        assert_eq!(pair_key(&a, &b), PlannedProduct::plan(&a, &b).key());
    }
}
