//! Column-index sorting for the accumulation phase's final step.
//!
//! The paper uses an in-block bitonic sort over the gathered (col, val)
//! pairs. We implement the same network so the traced path counts its
//! real compare/exchange work; the functional fast path uses
//! `sort_unstable_by_key`, which produces an identical result because
//! column keys within a row are unique.

use crate::sim::probe::Probe;

/// Bitonic sort by ascending key. Pads physically to a power of two with
/// +∞ sentinel keys (keys are column indices, always < u32::MAX). Emits
/// one compute op per compare/exchange through the probe.
pub fn bitonic_sort_by_key<P: Probe>(data: &mut [(u32, f64)], probe: &mut P) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    let m = n.next_power_of_two();
    let mut buf: Vec<(u32, f64)> = Vec::with_capacity(m);
    buf.extend_from_slice(data);
    buf.resize(m, (u32::MAX, 0.0));
    let mut k = 2;
    while k <= m {
        let mut j = k / 2;
        while j > 0 {
            for i in 0..m {
                let l = i ^ j;
                if l > i {
                    probe.compute(1);
                    let ascending = (i & k) == 0;
                    let out_of_order = if ascending { buf[i].0 > buf[l].0 } else { buf[i].0 < buf[l].0 };
                    if out_of_order {
                        buf.swap(i, l);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
    data.copy_from_slice(&buf[..n]);
    debug_assert!(data.windows(2).all(|w| w[0].0 <= w[1].0));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::{CountingProbe, NullProbe};
    use crate::util::Pcg32;

    #[test]
    fn sorts_exact_power_of_two() {
        let mut d = vec![(3u32, 0.3), (1, 0.1), (4, 0.4), (2, 0.2)];
        bitonic_sort_by_key(&mut d, &mut NullProbe);
        assert_eq!(d.iter().map(|e| e.0).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        // values travel with their keys
        assert!((d[0].1 - 0.1).abs() < 1e-15);
    }

    #[test]
    fn sorts_non_power_of_two() {
        for n in [1usize, 2, 3, 5, 7, 13, 100] {
            let mut rng = Pcg32::seeded(n as u64);
            let mut keys: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut keys);
            let mut d: Vec<(u32, f64)> = keys.iter().map(|&k| (k, k as f64)).collect();
            bitonic_sort_by_key(&mut d, &mut NullProbe);
            assert!(d.windows(2).all(|w| w[0].0 < w[1].0), "n={n}: {d:?}");
            assert!(d.iter().all(|&(k, v)| v == k as f64));
        }
    }

    #[test]
    fn matches_std_sort() {
        let mut rng = Pcg32::seeded(99);
        for _ in 0..20 {
            let n = 1 + rng.below_usize(64);
            let mut keys: Vec<u32> = (0..(n * 3) as u32).collect();
            rng.shuffle(&mut keys);
            keys.truncate(n);
            let mut a: Vec<(u32, f64)> = keys.iter().map(|&k| (k, (k * 7) as f64)).collect();
            let mut b = a.clone();
            bitonic_sort_by_key(&mut a, &mut NullProbe);
            b.sort_unstable_by_key(|e| e.0);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn counts_compare_ops() {
        let mut d = vec![(3u32, 0.0), (1, 0.0), (2, 0.0), (0, 0.0)];
        let mut p = CountingProbe::default();
        bitonic_sort_by_key(&mut d, &mut p);
        // n=4 bitonic: 3 stages of 2 compares = 6 (well-defined network size)
        assert_eq!(p.compute_ops, 6);
    }
}
