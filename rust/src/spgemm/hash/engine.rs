//! The hash-based multi-phase SpGEMM engine (paper §III), structured as
//! the paper's true pipeline:
//!
//! 1. **grouping** — per-row intermediate-product upper bounds
//!   (Algorithm 1) binned into the Table I row categories;
//! 2. **symbolic** — per-row *exact* output sizes via symbolic hash
//!   inserts (Algorithms 2–3), producing the output row pointers;
//! 3. **numeric** — value accumulation into pre-sized, disjoint output
//!   slices (Algorithm 5), with PWPR / TBPR thread assignment per
//!   Table I.
//!
//! Each phase is parallelised bin-by-bin through
//! [`crate::util::parallel::par_dynamic_with`]: every worker owns one
//! reusable hash table (plus gather scratch in the numeric phase) that
//! survives across all rows it processes — no per-row allocation. The
//! numeric phase additionally exploits the symbolic phase's exact counts:
//! group-3 (global-table) rows get tables sized `2·nnz(C_i)` instead of
//! `2·IP_i`, and rows with a single A entry are scaled copies of one B
//! row — no table, no sort.
//!
//! Entry points:
//! - [`multiply`] / [`multiply_timed`] — the fast functional path
//!   ([`NullProbe`], instrumentation compiles away); `_timed` also
//!   reports wall time per phase as a [`PhaseTimes`];
//! - [`symbolic`] + [`numeric`] — the two phases as separate calls, for
//!   callers that reuse a plan (or inspect it); iterative callers should
//!   prefer the validated handle [`super::plan::PlannedProduct`], which
//!   binds a plan to the operands' structure hashes and amortises the
//!   symbolic phase across numeric fills;
//! - [`multiply_single_pass`] — the seed engine kept as the regression
//!   baseline for `benches/spgemm_selfproduct.rs`;
//! - [`multiply_traced`] — deterministic sequential path that emits the
//!   full memory trace through a [`Probe`], in thread-block program
//!   order, for the AIA simulator.

use super::grouping::{global_table_size, GroupSpec, Grouping, Strategy, GROUP_SPECS};
use super::sort::bitonic_sort_by_key;
use super::table::{HashTable, TableLoc};
use crate::sim::probe::{Kind, NullProbe, Phase, PhaseTimes, Probe, Region};
use crate::spgemm::ip::{intermediate_products, intermediate_products_traced, IP_BLOCK_ROWS};
use crate::sparse::Csr;
use crate::util::{par_chunks, parallel::par_dynamic_with};
use std::time::Instant;

/// Output of the symbolic phase: everything the numeric phase needs to
/// fill values without re-deriving structure.
pub struct SymbolicPlan {
    /// Per-row intermediate-product upper bounds (Algorithm 1).
    pub ip: Vec<u64>,
    /// Table I row-category bins over `ip`.
    pub grouping: Grouping,
    /// *Exact* output row pointers: `rpt[i+1] - rpt[i]` = nnz of C row i.
    pub rpt: Vec<usize>,
}

impl SymbolicPlan {
    /// Total output non-zeros.
    pub fn nnz(&self) -> usize {
        *self.rpt.last().unwrap_or(&0)
    }

    /// Exact nnz of output row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.rpt[i + 1] - self.rpt[i]
    }
}

/// Dynamic-scheduling batch for a bin: PWPR bins hand each worker a
/// block's worth of small rows; TBPR bins hand out fat rows a few at a
/// time so the atomic counter isn't hammered.
fn bin_batch(spec: &GroupSpec) -> usize {
    match spec.strategy {
        Strategy::Pwpr => spec.rows_per_block(),
        Strategy::Tbpr => 4,
    }
}

/// One reusable per-worker table for a bin.
fn bin_table(spec: &GroupSpec) -> HashTable {
    match spec.table_size {
        Some(s) => HashTable::new(s, TableLoc::Shared),
        None => HashTable::new(1024, TableLoc::Global),
    }
}

/// Fast parallel hash SpGEMM (symbolic + numeric phases).
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    multiply_timed(a, b).0
}

/// [`multiply`] plus wall time per phase.
pub fn multiply_timed(a: &Csr, b: &Csr) -> (Csr, PhaseTimes) {
    let (plan, mut times) = symbolic_timed(a, b);
    let t = Instant::now();
    let c = numeric(a, b, &plan);
    times.numeric_s = t.elapsed().as_secs_f64();
    (c, times)
}

/// The symbolic half of [`multiply_timed`]: grouping + symbolic
/// analysis with per-stage wall times (`numeric_s` left 0). Shared with
/// the plan-reuse layer so phase attribution stays identical between
/// cold multiplies and planned products.
pub(super) fn symbolic_timed(a: &Csr, b: &Csr) -> (SymbolicPlan, PhaseTimes) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let t0 = Instant::now();
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    let grouping_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let plan = symbolic_with(a, b, ip, grouping);
    let symbolic_s = t1.elapsed().as_secs_f64();

    (plan, PhaseTimes { grouping_s, symbolic_s, numeric_s: 0.0 })
}

/// Symbolic phase: IP estimation, row binning, and exact per-row output
/// sizes.
pub fn symbolic(a: &Csr, b: &Csr) -> SymbolicPlan {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    symbolic_with(a, b, ip, grouping)
}

/// Symbolic counting given precomputed IP + bins (shared by
/// [`symbolic`] and [`symbolic_timed`], which times the stages apart).
fn symbolic_with(a: &Csr, b: &Csr, ip: Vec<u64>, grouping: Grouping) -> SymbolicPlan {
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for spec in &GROUP_SPECS {
            let rows = grouping.group_rows(spec.id);
            if rows.is_empty() {
                continue;
            }
            let ip = &ip;
            par_dynamic_with(
                rows.len(),
                bin_batch(spec),
                || bin_table(spec),
                |table, ri| {
                    let row = rows[ri] as usize;
                    let u = symbolic_row_nnz(a, b, row, ip[row], spec, table);
                    // SAFETY: each row index occurs once in the bins, so
                    // every `row_nnz` slot is written by exactly one
                    // worker, and the Vec outlives the scope.
                    unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                },
            );
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    SymbolicPlan { ip, grouping, rpt }
}

/// Exact nnz of one output row (symbolic hash inserts, with the trivial
/// cases short-circuited).
fn symbolic_row_nnz(a: &Csr, b: &Csr, row: usize, ip_row: u64, spec: &GroupSpec, table: &mut HashTable) -> u32 {
    // No hashing needed when collisions are impossible: a single A entry
    // reaches one B row (whose columns are unique by CSR invariant), and
    // IP ≤ 1 yields at most one product.
    if ip_row <= 1 || a.row_nnz(row) <= 1 {
        return ip_row as u32;
    }
    match spec.table_size {
        Some(_) => table.clear(),
        // Unique count is bounded by both IP and the output width, so
        // hub rows never allocate beyond 2·n_cols.
        None => table.reset_with_capacity(global_table_size(ip_row.min(b.n_cols as u64))),
    }
    alloc_row(a, b, row, table, &mut NullProbe)
}

/// Numeric phase: accumulate values into the plan's pre-sized, disjoint
/// output slices. The plan must come from [`symbolic`] on the same
/// `(a, b)` pair.
pub fn numeric(a: &Csr, b: &Csr, plan: &SymbolicPlan) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    assert_eq!(plan.rpt.len(), a.n_rows + 1, "plan does not match A");
    let nnz_c = plan.nnz();
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    {
        let col_ptr = col.as_mut_ptr() as usize;
        let val_ptr = val.as_mut_ptr() as usize;
        for spec in &GROUP_SPECS {
            let rows = plan.grouping.group_rows(spec.id);
            if rows.is_empty() {
                continue;
            }
            par_dynamic_with(
                rows.len(),
                bin_batch(spec),
                || (bin_table(spec), Vec::<(u32, f64)>::new()),
                |(table, scratch), ri| {
                    let row = rows[ri] as usize;
                    let start = plan.rpt[row];
                    let n_out = plan.rpt[row + 1] - start;
                    if n_out == 0 {
                        return;
                    }
                    let cp = col_ptr as *mut u32;
                    let vp = val_ptr as *mut f64;
                    // Single-A-entry rows are scaled copies of one B row:
                    // already sorted, collision-free — no table, no sort.
                    if a.row_nnz(row) == 1 {
                        let j = a.rpt[row];
                        let av = a.val[j];
                        let (bc, bv) = b.row(a.col[j] as usize);
                        // Real assert, not debug: the pointer writes below
                        // are bounded by the plan, so a plan/input mismatch
                        // must panic rather than corrupt memory.
                        assert_eq!(bc.len(), n_out, "plan does not match inputs at row {row}");
                        for (o, (&c, &v)) in bc.iter().zip(bv).enumerate() {
                            // SAFETY: rows write disjoint
                            // [rpt[i], rpt[i+1]) slices.
                            unsafe {
                                *cp.add(start + o) = c;
                                *vp.add(start + o) = av * v;
                            }
                        }
                        return;
                    }
                    match spec.table_size {
                        Some(_) => table.clear(),
                        // Exact sizing from the symbolic count: 2·nnz(C_i)
                        // keeps load factor ≤ 0.5 and is far below the
                        // 2·IP_i the single-pass engine allocated for hub
                        // rows.
                        None => table.reset_with_capacity(global_table_size(n_out as u64)),
                    }
                    accum_row_fast(a, b, row, table, scratch);
                    // Real assert, not debug: bounds the unsafe writes below
                    // (a stale/mismatched plan must panic, not scribble).
                    assert_eq!(scratch.len(), n_out, "symbolic/numeric disagree on row {row}");
                    // fast path: std sort (identical result to bitonic —
                    // keys unique)
                    scratch.sort_unstable_by_key(|e| e.0);
                    for (o, &(c, v)) in scratch.iter().enumerate() {
                        // SAFETY: as above — disjoint output slices.
                        unsafe {
                            *cp.add(start + o) = c;
                            *vp.add(start + o) = v;
                        }
                    }
                },
            );
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, plan.rpt.clone(), col, val)
}

/// The seed's engine: allocation and accumulation fused per bin, one
/// freshly allocated table per worker chunk (PWPR) and IP-sized global
/// tables. Kept as the regression baseline the two-phase pipeline is
/// benched against (`benches/spgemm_selfproduct.rs`); output is
/// identical to [`multiply`].
pub fn multiply_single_pass(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);

    // ---- allocation phase: per-row unique counts -> rpt_C ----
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            match spec.strategy {
                Strategy::Pwpr => {
                    // many small rows: static chunks, one table per chunk
                    par_chunks(rows.len(), |start, end| {
                        let p = nnz_ptr as *mut u32;
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        for &row in &rows[start..end] {
                            table.clear();
                            let u = alloc_row(a, b, row as usize, &mut table, &mut NullProbe);
                            unsafe { *p.add(row as usize) = u };
                        }
                    });
                }
                Strategy::Tbpr => {
                    // fewer, fatter rows: dynamic scheduling with one
                    // growable table per worker (no per-row allocation)
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || HashTable::new(base, loc),
                        |table, ri| {
                            let p = nnz_ptr as *mut u32;
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            let u = alloc_row(a, b, row, table, &mut NullProbe);
                            unsafe { *p.add(row) = u };
                        },
                    );
                }
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation phase: values into disjoint output slices ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    {
        let col_ptr = col.as_mut_ptr() as usize;
        let val_ptr = val.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            let run_row = |row: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>| {
                accum_row_fast(a, b, row, table, scratch);
                scratch.sort_unstable_by_key(|e| e.0);
                let start = rpt[row];
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = v;
                    }
                }
            };
            match spec.strategy {
                Strategy::Pwpr => {
                    par_chunks(rows.len(), |start, end| {
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        let mut scratch = Vec::new();
                        for &row in &rows[start..end] {
                            table.clear();
                            run_row(row as usize, &mut table, &mut scratch);
                        }
                    });
                }
                Strategy::Tbpr => {
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || (HashTable::new(base, loc), Vec::new()),
                        |(table, scratch), ri| {
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            run_row(row, table, scratch);
                        },
                    );
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Instrumented sequential hash SpGEMM: identical output to [`multiply`],
/// plus a full program-order memory trace through `probe`. Blocks are
/// numbered globally across phases so the machine model's round-robin
/// SM assignment interleaves groups the way concurrent streams would.
pub fn multiply_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    // ---- grouping phase ----
    let ip = intermediate_products_traced(a, b, probe);
    let grouping = Grouping::build(&ip);
    let mut next_block = a.n_rows.div_ceil(IP_BLOCK_ROWS);

    // ---- allocation (symbolic) phase ----
    let mut row_nnz = vec![0u32; a.n_rows];
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Allocation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None; // fresh global table per huge row
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation (numeric) phase ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Accumulation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                // Column-index sorting: the paper's in-block bitonic network.
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                let start = rpt[row];
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                    col[start + o] = c;
                    val[start + o] = v;
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Statistics-only traced run: emits the memory trace of every
/// `every`-th thread block and **skips the functional work of the
/// rest** (their output-row sizes are approximated by their IP upper
/// bound, which only shifts unsampled output addresses). Use when only
/// the [`crate::sim::SimReport`] is needed — the fast parallel
/// [`multiply`] provides the actual product. `every = 1` traces every
/// block (identical trace to [`multiply_traced`]).
pub fn multiply_traced_stats<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let every = every.max(1);
    // IP for *all* rows (cheap, parallel) — grouping must be exact.
    let ip = intermediate_products(a, b);
    // Grouping-phase trace for sampled blocks only.
    let n_ip_blocks = a.n_rows.div_ceil(IP_BLOCK_ROWS);
    for blk in 0..n_ip_blocks {
        if blk % every != 0 {
            continue;
        }
        probe.begin_block(blk, Phase::Grouping);
        let lo = blk * IP_BLOCK_ROWS;
        let hi = ((blk + 1) * IP_BLOCK_ROWS).min(a.n_rows);
        for i in lo..hi {
            probe.access(Region::RptA, i, 4, Kind::Read);
            probe.access(Region::RptA, i + 1, 4, Kind::Read);
            for (jo, &c) in a.row(i).0.iter().enumerate() {
                probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
                probe.indirect_range(Region::RptB, c as usize, &[], 0, 0);
                probe.compute(2);
            }
            probe.access(Region::IpCount, i, 8, Kind::Write);
            probe.access(Region::GroupCtr, crate::spgemm::ip::group_index_for_ip(ip[i]), 4, Kind::Atomic);
            probe.compute(4);
        }
    }
    let grouping = Grouping::build(&ip);
    let mut next_block = n_ip_blocks;

    // Allocation phase: real hash work on sampled blocks, IP bound for
    // the rest (address generation only).
    let mut row_nnz = vec![0u32; a.n_rows];
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Allocation);
            }
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                if !sampled {
                    row_nnz[row] = ip[row].min(b.n_cols as u64) as u32;
                    continue;
                }
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None;
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }

    // Accumulation phase: sampled blocks only.
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Accumulation);
            }
            next_block += 1;
            if !sampled {
                continue;
            }
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                let start = rpt[row];
                for (o, &(_c, _v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
}

/// Allocation-phase row processor (Algorithms 2–3 minus the thread
/// bookkeeping): symbolic hash inserts of every B-column reachable from
/// row `i` of A. Returns the unique count (= nnz of output row).
fn alloc_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, probe: &mut P) -> u32 {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Two-level indirection on B, allocation needs col_B only.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB], lo, hi);
        for k in lo..hi {
            table.insert_symbolic(b.col[k], probe);
        }
    }
    table.unique as u32
}

/// Accumulation-phase row processor (Algorithm 5): numeric hash inserts
/// of every intermediate product, then whole-table gather into `scratch`
/// (unsorted — the caller sorts).
fn accum_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>, probe: &mut P) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Accumulation streams both col_B and val_B.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB, Region::ValB], lo, hi);
        for k in lo..hi {
            table.insert_numeric(b.col[k], av * b.val[k], probe);
            probe.compute(1); // the multiply
        }
    }
    table.gather(scratch, probe);
}

/// Fast-path accumulation row processor: same inserts as [`accum_row`]
/// but gathers in O(unique) via the occupied list (no probe events).
fn accum_row_fast(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>) {
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            table.insert_numeric(b.col[k], av * b.val[k], &mut NullProbe);
        }
    }
    table.gather_list(scratch);
}

/// Strategy assigned to a row with the given IP (for tests/diagnostics).
pub fn strategy_for_ip(ip: u64) -> Strategy {
    GROUP_SPECS[crate::spgemm::ip::group_index_for_ip(ip)].strategy
}

/// Expose the spec list for the coordinator's stream scheduler.
pub fn group_specs() -> &'static [GroupSpec; 4] {
    &GROUP_SPECS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::{qc, Pcg32};

    fn random_csr(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Csr {
        let mut coo = crate::sparse::Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density) as usize;
        for _ in 0..target {
            coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-2.0, 2.0));
        }
        coo.to_csr()
    }

    #[test]
    fn matches_reference_small() {
        let a = Csr::from_dense(&[vec![1.0, 2.0, 0.0], vec![0.0, 0.0, 3.0], vec![1.0, 0.0, 1.0]]);
        let b = Csr::from_dense(&[vec![0.0, 1.0], vec![1.0, 0.0], vec![2.0, 2.0]]);
        let c = multiply(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert!(c.approx_eq(&r, 1e-12), "{:?} vs {:?}", c.to_dense(), r.to_dense());
    }

    #[test]
    fn two_phase_equals_single_pass_exactly() {
        let mut rng = Pcg32::seeded(321);
        let a = random_csr(&mut rng, 300, 250, 0.03);
        let b = random_csr(&mut rng, 250, 280, 0.02);
        // bit-for-bit: same structure, same value sums in the same order
        assert_eq!(multiply(&a, &b), multiply_single_pass(&a, &b));
    }

    #[test]
    fn symbolic_plan_is_exact() {
        let mut rng = Pcg32::seeded(17);
        let a = random_csr(&mut rng, 120, 100, 0.05);
        let b = random_csr(&mut rng, 100, 90, 0.05);
        let plan = symbolic(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert_eq!(plan.rpt, r.rpt, "symbolic sizes must be exact, not bounds");
        assert_eq!(plan.nnz(), r.nnz());
        let c = numeric(&a, &b, &plan);
        assert!(c.approx_eq(&r, 1e-10));
    }

    #[test]
    fn phase_times_are_reported() {
        let mut rng = Pcg32::seeded(23);
        let a = random_csr(&mut rng, 400, 400, 0.02);
        let (c, t) = multiply_timed(&a, &a);
        assert!(c.nnz() > 0);
        assert!(t.grouping_s >= 0.0 && t.symbolic_s >= 0.0 && t.numeric_s >= 0.0);
        assert!(t.total_s() >= t.numeric_s);
        assert!(t.total_s() > 0.0, "three timed phases cannot all be zero-width");
    }

    #[test]
    fn single_entry_rows_take_copy_path() {
        // Diagonal × random exercises the no-table scaled-copy path on
        // every row; result must still be exact.
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        let d = Csr::from_diag(&[2.5; 64]);
        let c = multiply(&d, &m);
        let mut expect = m.clone();
        expect.map_values(|v| 2.5 * v);
        assert!(c.approx_eq(&expect, 1e-12));
    }

    #[test]
    fn traced_equals_fast_path() {
        let mut rng = Pcg32::seeded(77);
        let a = random_csr(&mut rng, 200, 150, 0.02);
        let b = random_csr(&mut rng, 150, 180, 0.03);
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
        assert!(probe.indirect_ranges > 0);
        assert!(probe.shared > 0);
    }

    #[test]
    fn matches_reference_randomized() {
        qc::check(24, 2024, |g| {
            let rows = g.dim();
            let inner = g.dim();
            let cols = g.dim();
            let density = 0.02 + g.rng.f64() * 0.2;
            let a = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, rows, inner, density)
            };
            let b = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, inner, cols, density)
            };
            let c = multiply(&a, &b);
            let r = spgemm_reference(&a, &b);
            assert!(c.validate().is_ok(), "invalid CSR output");
            assert!(c.approx_eq(&r, 1e-10), "hash engine disagrees with reference");
        });
    }

    #[test]
    fn exercises_all_four_groups() {
        // Build a matrix whose rows produce IPs in every group: B dense-ish
        // rows amplify.
        let mut rng = Pcg32::seeded(5);
        let n = 600;
        let mut coo = crate::sparse::Coo::new(n, n);
        // row 0: 1 nnz (group 0); row 1: 40 nnz (g1); row 2: 300 nnz (g2 via
        // IP multiplication); rows 3..: heavy hub rows for group 3.
        for j in 0..1 {
            coo.push(0, j * 7 % n, 1.0);
        }
        for j in 0..40 {
            coo.push(1, (j * 13) % n, 1.0);
        }
        for j in 0..300 {
            coo.push(2, (j * 2 + 1) % n, 1.0);
        }
        for r in 3..40 {
            for j in 0..r * 20 % n {
                coo.push(r, (j * 3 + r) % n, 1.0);
            }
        }
        for r in 40..n {
            for _ in 0..6 {
                coo.push(r, rng.below_usize(n), 1.0);
            }
        }
        let a = coo.to_csr();
        let ip = intermediate_products(&a, &a);
        let grouping = Grouping::build(&ip);
        let non_empty = (0..4).filter(|&g| !grouping.group_rows(g).is_empty()).count();
        assert!(non_empty >= 3, "expected ≥3 groups populated, got {non_empty}");
        let c = multiply(&a, &a);
        let r = spgemm_reference(&a, &a);
        assert!(c.approx_eq(&r, 1e-10));
        // and the seed baseline still agrees on the same stress input
        assert_eq!(c, multiply_single_pass(&a, &a));
    }

    #[test]
    fn empty_and_identity_edge_cases() {
        let z = Csr::zeros(5, 5);
        assert_eq!(multiply(&z, &z).nnz(), 0);
        let i = Csr::identity(64);
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        assert!(multiply(&i, &m).approx_eq(&m, 1e-12));
        assert!(multiply(&m, &i).approx_eq(&m, 1e-12));
    }

    #[test]
    fn strategy_assignment() {
        assert_eq!(strategy_for_ip(10), Strategy::Pwpr);
        assert_eq!(strategy_for_ip(100), Strategy::Tbpr);
    }
}
