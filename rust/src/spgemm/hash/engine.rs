//! The hash-based multi-phase SpGEMM engine (paper §III): row-grouping →
//! allocation (symbolic, Algorithms 2–3) → accumulation (numeric,
//! Algorithm 5), with PWPR / TBPR thread-assignment per Table I.
//!
//! Two entry points share the same row processors:
//! - [`multiply`] — the fast functional path, parallel across rows with
//!   [`NullProbe`] (instrumentation compiles away);
//! - [`multiply_traced`] — deterministic sequential path that emits the
//!   full memory trace through a [`Probe`], in thread-block program
//!   order, for the AIA simulator.

use super::grouping::{global_table_size, GroupSpec, Grouping, Strategy, GROUP_SPECS};
use super::sort::bitonic_sort_by_key;
use super::table::{HashTable, TableLoc};
use crate::sim::probe::{Kind, NullProbe, Phase, Probe, Region};
use crate::spgemm::ip::{intermediate_products, intermediate_products_traced, IP_BLOCK_ROWS};
use crate::sparse::Csr;
use crate::util::{par_chunks, parallel::par_dynamic_with};

/// Fast parallel hash SpGEMM.
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);

    // ---- allocation phase: per-row unique counts -> rpt_C ----
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            match spec.strategy {
                Strategy::Pwpr => {
                    // many small rows: static chunks, one table per chunk
                    par_chunks(rows.len(), |start, end| {
                        let p = nnz_ptr as *mut u32;
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        for &row in &rows[start..end] {
                            table.clear();
                            let u = alloc_row(a, b, row as usize, &mut table, &mut NullProbe);
                            unsafe { *p.add(row as usize) = u };
                        }
                    });
                }
                Strategy::Tbpr => {
                    // fewer, fatter rows: dynamic scheduling with one
                    // growable table per worker (no per-row allocation)
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || HashTable::new(base, loc),
                        |table, ri| {
                            let p = nnz_ptr as *mut u32;
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            let u = alloc_row(a, b, row, table, &mut NullProbe);
                            unsafe { *p.add(row) = u };
                        },
                    );
                }
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation phase: values into disjoint output slices ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    {
        let col_ptr = col.as_mut_ptr() as usize;
        let val_ptr = val.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            let run_row = |row: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>| {
                accum_row_fast(a, b, row, table, scratch);
                // fast path: std sort (identical result to bitonic — keys unique)
                scratch.sort_unstable_by_key(|e| e.0);
                let start = rpt[row];
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = v;
                    }
                }
            };
            match spec.strategy {
                Strategy::Pwpr => {
                    par_chunks(rows.len(), |start, end| {
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        let mut scratch = Vec::new();
                        for &row in &rows[start..end] {
                            table.clear();
                            run_row(row as usize, &mut table, &mut scratch);
                        }
                    });
                }
                Strategy::Tbpr => {
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || (HashTable::new(base, loc), Vec::new()),
                        |(table, scratch), ri| {
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            run_row(row, table, scratch);
                        },
                    );
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Instrumented sequential hash SpGEMM: identical output to [`multiply`],
/// plus a full program-order memory trace through `probe`. Blocks are
/// numbered globally across phases so the machine model's round-robin
/// SM assignment interleaves groups the way concurrent streams would.
pub fn multiply_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    // ---- grouping phase ----
    let ip = intermediate_products_traced(a, b, probe);
    let grouping = Grouping::build(&ip);
    let mut next_block = a.n_rows.div_ceil(IP_BLOCK_ROWS);

    // ---- allocation phase ----
    let mut row_nnz = vec![0u32; a.n_rows];
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Allocation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None; // fresh global table per huge row
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation phase ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Accumulation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                // Column-index sorting: the paper's in-block bitonic network.
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                let start = rpt[row];
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                    col[start + o] = c;
                    val[start + o] = v;
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Statistics-only traced run: emits the memory trace of every
/// `every`-th thread block and **skips the functional work of the
/// rest** (their output-row sizes are approximated by their IP upper
/// bound, which only shifts unsampled output addresses). Use when only
/// the [`crate::sim::SimReport`] is needed — the fast parallel
/// [`multiply`] provides the actual product. `every = 1` traces every
/// block (identical trace to [`multiply_traced`]).
pub fn multiply_traced_stats<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let every = every.max(1);
    // IP for *all* rows (cheap, parallel) — grouping must be exact.
    let ip = intermediate_products(a, b);
    // Grouping-phase trace for sampled blocks only.
    let n_ip_blocks = a.n_rows.div_ceil(IP_BLOCK_ROWS);
    for blk in 0..n_ip_blocks {
        if blk % every != 0 {
            continue;
        }
        probe.begin_block(blk, Phase::Grouping);
        let lo = blk * IP_BLOCK_ROWS;
        let hi = ((blk + 1) * IP_BLOCK_ROWS).min(a.n_rows);
        for i in lo..hi {
            probe.access(Region::RptA, i, 4, Kind::Read);
            probe.access(Region::RptA, i + 1, 4, Kind::Read);
            for (jo, &c) in a.row(i).0.iter().enumerate() {
                probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
                probe.indirect_range(Region::RptB, c as usize, &[], 0, 0);
                probe.compute(2);
            }
            probe.access(Region::IpCount, i, 8, Kind::Write);
            probe.access(Region::GroupCtr, crate::spgemm::ip::group_index_for_ip(ip[i]), 4, Kind::Atomic);
            probe.compute(4);
        }
    }
    let grouping = Grouping::build(&ip);
    let mut next_block = n_ip_blocks;

    // Allocation phase: real hash work on sampled blocks, IP bound for
    // the rest (address generation only).
    let mut row_nnz = vec![0u32; a.n_rows];
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Allocation);
            }
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                if !sampled {
                    row_nnz[row] = ip[row].min(b.n_cols as u64) as u32;
                    continue;
                }
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None;
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }

    // Accumulation phase: sampled blocks only.
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Accumulation);
            }
            next_block += 1;
            if !sampled {
                continue;
            }
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                let start = rpt[row];
                for (o, &(_c, _v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
}

/// Allocation-phase row processor (Algorithms 2–3 minus the thread
/// bookkeeping): symbolic hash inserts of every B-column reachable from
/// row `i` of A. Returns the unique count (= nnz of output row).
fn alloc_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, probe: &mut P) -> u32 {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Two-level indirection on B, allocation needs col_B only.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB], lo, hi);
        for k in lo..hi {
            table.insert_symbolic(b.col[k], probe);
        }
    }
    table.unique as u32
}

/// Accumulation-phase row processor (Algorithm 5): numeric hash inserts
/// of every intermediate product, then whole-table gather into `scratch`
/// (unsorted — the caller sorts).
fn accum_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>, probe: &mut P) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Accumulation streams both col_B and val_B.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB, Region::ValB], lo, hi);
        for k in lo..hi {
            table.insert_numeric(b.col[k], av * b.val[k], probe);
            probe.compute(1); // the multiply
        }
    }
    table.gather(scratch, probe);
}

/// Fast-path accumulation row processor: same inserts as [`accum_row`]
/// but gathers in O(unique) via the occupied list (no probe events).
fn accum_row_fast(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>) {
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            table.insert_numeric(b.col[k], av * b.val[k], &mut NullProbe);
        }
    }
    table.gather_list(scratch);
}

/// Strategy assigned to a row with the given IP (for tests/diagnostics).
pub fn strategy_for_ip(ip: u64) -> Strategy {
    GROUP_SPECS[crate::spgemm::ip::group_index_for_ip(ip)].strategy
}

/// Expose the spec list for the coordinator's stream scheduler.
pub fn group_specs() -> &'static [GroupSpec; 4] {
    &GROUP_SPECS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::{qc, Pcg32};

    fn random_csr(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Csr {
        let mut coo = crate::sparse::Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density) as usize;
        for _ in 0..target {
            coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-2.0, 2.0));
        }
        coo.to_csr()
    }

    #[test]
    fn matches_reference_small() {
        let a = Csr::from_dense(&[vec![1.0, 2.0, 0.0], vec![0.0, 0.0, 3.0], vec![1.0, 0.0, 1.0]]);
        let b = Csr::from_dense(&[vec![0.0, 1.0], vec![1.0, 0.0], vec![2.0, 2.0]]);
        let c = multiply(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert!(c.approx_eq(&r, 1e-12), "{:?} vs {:?}", c.to_dense(), r.to_dense());
    }

    #[test]
    fn traced_equals_fast_path() {
        let mut rng = Pcg32::seeded(77);
        let a = random_csr(&mut rng, 200, 150, 0.02);
        let b = random_csr(&mut rng, 150, 180, 0.03);
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
        assert!(probe.indirect_ranges > 0);
        assert!(probe.shared > 0);
    }

    #[test]
    fn matches_reference_randomized() {
        qc::check(24, 2024, |g| {
            let rows = g.dim();
            let inner = g.dim();
            let cols = g.dim();
            let density = 0.02 + g.rng.f64() * 0.2;
            let a = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, rows, inner, density)
            };
            let b = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, inner, cols, density)
            };
            let c = multiply(&a, &b);
            let r = spgemm_reference(&a, &b);
            assert!(c.validate().is_ok(), "invalid CSR output");
            assert!(c.approx_eq(&r, 1e-10), "hash engine disagrees with reference");
        });
    }

    #[test]
    fn exercises_all_four_groups() {
        // Build a matrix whose rows produce IPs in every group: B dense-ish
        // rows amplify.
        let mut rng = Pcg32::seeded(5);
        let n = 600;
        let mut coo = crate::sparse::Coo::new(n, n);
        // row 0: 1 nnz (group 0); row 1: 40 nnz (g1); row 2: 300 nnz (g2 via
        // IP multiplication); rows 3..: heavy hub rows for group 3.
        for j in 0..1 {
            coo.push(0, j * 7 % n, 1.0);
        }
        for j in 0..40 {
            coo.push(1, (j * 13) % n, 1.0);
        }
        for j in 0..300 {
            coo.push(2, (j * 2 + 1) % n, 1.0);
        }
        for r in 3..40 {
            for j in 0..r * 20 % n {
                coo.push(r, (j * 3 + r) % n, 1.0);
            }
        }
        for r in 40..n {
            for _ in 0..6 {
                coo.push(r, rng.below_usize(n), 1.0);
            }
        }
        let a = coo.to_csr();
        let ip = intermediate_products(&a, &a);
        let grouping = Grouping::build(&ip);
        let non_empty = (0..4).filter(|&g| !grouping.group_rows(g).is_empty()).count();
        assert!(non_empty >= 3, "expected ≥3 groups populated, got {non_empty}");
        let c = multiply(&a, &a);
        let r = spgemm_reference(&a, &a);
        assert!(c.approx_eq(&r, 1e-10));
    }

    #[test]
    fn empty_and_identity_edge_cases() {
        let z = Csr::zeros(5, 5);
        assert_eq!(multiply(&z, &z).nnz(), 0);
        let i = Csr::identity(64);
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        assert!(multiply(&i, &m).approx_eq(&m, 1e-12));
        assert!(multiply(&m, &i).approx_eq(&m, 1e-12));
    }

    #[test]
    fn strategy_assignment() {
        assert_eq!(strategy_for_ip(10), Strategy::Pwpr);
        assert_eq!(strategy_for_ip(100), Strategy::Tbpr);
    }
}
