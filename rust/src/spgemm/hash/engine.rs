//! The hash-based multi-phase SpGEMM engine (paper §III), structured as
//! the paper's true pipeline:
//!
//! 1. **grouping** — per-row intermediate-product upper bounds
//!   (Algorithm 1) binned into the Table I row categories;
//! 2. **symbolic** — per-row *exact* output sizes via symbolic hash
//!   inserts (Algorithms 2–3), producing the output row pointers;
//! 3. **numeric** — value accumulation into pre-sized, disjoint output
//!   slices (Algorithm 5), with PWPR / TBPR thread assignment per
//!   Table I.
//!
//! Each phase is parallelised bin-by-bin through
//! [`crate::util::parallel::par_dynamic_with`]: every worker owns one
//! reusable accumulator (plus gather scratch in the numeric phase) that
//! survives across all rows it processes — no per-row allocation.
//!
//! # The symbolic → numeric contract
//!
//! The symbolic phase produces a [`SymbolicPlan`]: *exact* output row
//! pointers, the Table-I row grouping, the per-row IP bounds — and,
//! new with the plan-guided accumulator layer, the numeric work list
//! itself ([`SymbolicPlan::bins`]). Because the symbolic phase knows
//! every row's exact `nnz(C_i)`, the accumulator choice is made **at
//! plan time, for free**: each Table-I bin is split by
//! [`super::grouping::AccumKind`] into up to three homogeneous numeric
//! bins —
//!
//! - **scaled-copy** rows (single A entry) copy one scaled B row, no
//!   accumulator, no sort;
//! - **hash** rows run Algorithm 4 linear probing, with group-3
//!   (global-table) rows sized `2·nnz(C_i)` instead of `2·IP_i`;
//! - **SPA** rows (output denser than [`EngineConfig::spa_threshold`])
//!   stream into a [`super::table::DenseAccumulator`] — no probe
//!   chains, sequential gather, priced as streaming by the simulator
//!   (AIA-ineligible).
//!
//! All three paths are **bit-identical**: per-column accumulation order
//! is the B-stream encounter order in each, and the final sort is over
//! unique keys. The numeric phase ([`numeric`] / [`numeric_bin_into`])
//! only consumes the plan; callers may fill bins one at a time (the
//! per-bin overlap pipeline in `coordinator::batch` does) or all at
//! once.
//!
//! Entry points:
//! - [`multiply`] / [`multiply_timed`] — the fast functional path
//!   ([`NullProbe`], instrumentation compiles away); `_timed` also
//!   reports wall time per phase as a [`PhaseTimes`], with the numeric
//!   seconds split per accumulator kind; `_cfg` variants take an
//!   explicit [`EngineConfig`] (threshold knob);
//! - [`symbolic`] + [`numeric`] — the two phases as separate calls, for
//!   callers that reuse a plan (or inspect it); iterative callers should
//!   prefer the validated handle [`super::plan::PlannedProduct`], which
//!   binds a plan to the operands' structure hashes and amortises the
//!   symbolic phase across numeric fills;
//! - [`multiply_single_pass`] — the seed engine kept as the regression
//!   baseline for `benches/spgemm_selfproduct.rs`;
//! - [`multiply_traced`] — deterministic sequential path that emits the
//!   full memory trace through a [`Probe`], in thread-block program
//!   order, for the AIA simulator; SPA rows emit plain streaming
//!   accesses instead of [`Probe::indirect_range`].

use super::grouping::{
    global_table_size, select_accumulator, AccumKind, GroupSpec, Grouping, Strategy, DEFAULT_SPA_THRESHOLD,
    GROUP_SPECS,
};
use super::sort::bitonic_sort_by_key;
use super::table::{DenseAccumulator, HashTable, TableLoc};
use crate::sim::probe::{Kind, NullProbe, Phase, PhaseTimes, Probe, Region};
use crate::spgemm::ip::{intermediate_products, intermediate_products_traced, IP_BLOCK_ROWS};
use crate::sparse::Csr;
use crate::util::{par_chunks, parallel::par_dynamic_with};
use std::sync::OnceLock;
use std::time::Instant;

/// Tunables of the plan-guided numeric phase.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineConfig {
    /// Density threshold of the SPA fallback: a row switches from hash
    /// to dense-SPA accumulation when `nnz(C_i) / n_cols` **exceeds**
    /// this value (strict, so `0.0` forces SPA on every multi-entry row
    /// and any value ≥ 1.0 disables it). See
    /// [`super::grouping::select_accumulator`] for the full decision
    /// table.
    pub spa_threshold: f64,
}

impl Default for EngineConfig {
    /// The process-wide default threshold: the value set by
    /// [`set_default_spa_threshold`] (the CLI's `--spa-threshold`), else
    /// the `SPGEMM_AIA_SPA_THRESHOLD` env var, else
    /// [`DEFAULT_SPA_THRESHOLD`].
    fn default() -> EngineConfig {
        EngineConfig { spa_threshold: default_spa_threshold() }
    }
}

static SPA_THRESHOLD_CELL: OnceLock<f64> = OnceLock::new();

/// Set the process-wide default SPA threshold (the CLI's
/// `--spa-threshold` knob). Returns `false` if the default was already
/// read or set — call once, at startup, before any multiply.
pub fn set_default_spa_threshold(t: f64) -> bool {
    SPA_THRESHOLD_CELL.set(t).is_ok()
}

/// The process-wide default SPA threshold (see
/// [`EngineConfig::default`]). Env values outside the CLI's accepted
/// `[0, 8]` range (or unparsable ones) are ignored, not latched — a
/// stray `SPGEMM_AIA_SPA_THRESHOLD=-1` must not force the SPA onto
/// every row of every multiply in the process.
pub fn default_spa_threshold() -> f64 {
    *SPA_THRESHOLD_CELL.get_or_init(|| {
        std::env::var("SPGEMM_AIA_SPA_THRESHOLD")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|t: &f64| (0.0..=8.0).contains(t))
            .unwrap_or(DEFAULT_SPA_THRESHOLD)
    })
}

/// One homogeneous unit of numeric work: the rows of one Table-I group
/// that share one accumulator kind. Bins are the granularity at which
/// the numeric phase runs, the stream scheduler packs, and the batch
/// pipeline dispatches per-bin completion events.
#[derive(Clone, Debug)]
pub struct NumericBin {
    /// Table-I group id (0–3) — fixes strategy, block and table sizes.
    pub group: u8,
    /// Accumulator every row in this bin uses.
    pub kind: AccumKind,
    /// Member rows (original row ids, stable within the group). Rows
    /// with zero output are excluded from every bin.
    pub rows: Vec<u32>,
    /// Summed intermediate products — the bin's scheduling weight.
    pub weight: u64,
}

impl NumericBin {
    /// Short label for schedules and metrics, e.g. `g3/spa`.
    pub fn label(&self) -> String {
        format!("g{}/{}", self.group, self.kind.name())
    }
}

/// Output of the symbolic phase: everything the numeric phase needs to
/// fill values without re-deriving structure, including the
/// accumulator-kind decision per row (made here, where exact sizes are
/// known — the numeric phase only consumes it).
pub struct SymbolicPlan {
    /// Per-row intermediate-product upper bounds (Algorithm 1).
    pub ip: Vec<u64>,
    /// Table I row-category bins over `ip`.
    pub grouping: Grouping,
    /// *Exact* output row pointers: `rpt[i+1] - rpt[i]` = nnz of C row i.
    pub rpt: Vec<usize>,
    /// Per-row accumulator kind (rows with zero output hold a
    /// placeholder — use [`SymbolicPlan::accumulator_kind`]).
    pub accum: Vec<AccumKind>,
    /// The numeric work list: each Table-I bin split by accumulator
    /// kind, empty bins dropped.
    pub bins: Vec<NumericBin>,
    /// Density threshold the kinds were selected with.
    pub spa_threshold: f64,
}

impl SymbolicPlan {
    /// Total output non-zeros.
    pub fn nnz(&self) -> usize {
        *self.rpt.last().unwrap_or(&0)
    }

    /// Exact nnz of output row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.rpt[i + 1] - self.rpt[i]
    }

    /// Accumulator the numeric phase will use for row `i` (`None` for
    /// rows with no output — they are skipped entirely).
    pub fn accumulator_kind(&self, i: usize) -> Option<AccumKind> {
        if self.row_nnz(i) == 0 {
            None
        } else {
            Some(self.accum[i])
        }
    }

    /// Row counts per accumulator kind, indexed by
    /// [`AccumKind::index`] (copy, hash, SPA).
    pub fn kind_rows(&self) -> [usize; 3] {
        let mut n = [0usize; 3];
        for b in &self.bins {
            n[b.kind.index()] += b.rows.len();
        }
        n
    }
}

/// Dynamic-scheduling batch for a bin: PWPR bins hand each worker a
/// block's worth of small rows; TBPR bins hand out fat rows a few at a
/// time so the atomic counter isn't hammered.
fn bin_batch(spec: &GroupSpec) -> usize {
    match spec.strategy {
        Strategy::Pwpr => spec.rows_per_block(),
        Strategy::Tbpr => 4,
    }
}

/// One reusable per-worker table for a bin.
fn bin_table(spec: &GroupSpec) -> HashTable {
    match spec.table_size {
        Some(s) => HashTable::new(s, TableLoc::Shared),
        None => HashTable::new(1024, TableLoc::Global),
    }
}

/// Fast parallel hash SpGEMM (symbolic + numeric phases), at the
/// process-default [`EngineConfig`].
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    multiply_cfg(a, b, &EngineConfig::default())
}

/// [`multiply`] with an explicit [`EngineConfig`].
pub fn multiply_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> Csr {
    multiply_timed_cfg(a, b, cfg).0
}

/// [`multiply`] plus wall time per phase (numeric seconds split per
/// accumulator kind).
pub fn multiply_timed(a: &Csr, b: &Csr) -> (Csr, PhaseTimes) {
    multiply_timed_cfg(a, b, &EngineConfig::default())
}

/// [`multiply_timed`] with an explicit [`EngineConfig`].
pub fn multiply_timed_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> (Csr, PhaseTimes) {
    let (plan, mut times) = symbolic_timed(a, b, cfg);
    let (c, numeric_times) = numeric_timed(a, b, &plan);
    times.numeric_s = numeric_times.numeric_s;
    times.numeric_kind_s = numeric_times.numeric_kind_s;
    (c, times)
}

/// The symbolic half of [`multiply_timed`]: grouping + symbolic
/// analysis with per-stage wall times (`numeric_s` left 0). Shared with
/// the plan-reuse layer so phase attribution stays identical between
/// cold multiplies and planned products.
pub(super) fn symbolic_timed(a: &Csr, b: &Csr, cfg: &EngineConfig) -> (SymbolicPlan, PhaseTimes) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let t0 = Instant::now();
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    let grouping_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let plan = symbolic_with(a, b, ip, grouping, cfg);
    let symbolic_s = t1.elapsed().as_secs_f64();

    (plan, PhaseTimes { grouping_s, symbolic_s, ..PhaseTimes::default() })
}

/// Symbolic phase: IP estimation, row binning, exact per-row output
/// sizes, and the per-row accumulator decision — at the process-default
/// [`EngineConfig`].
pub fn symbolic(a: &Csr, b: &Csr) -> SymbolicPlan {
    symbolic_cfg(a, b, &EngineConfig::default())
}

/// [`symbolic`] with an explicit [`EngineConfig`]: the threshold decides
/// which rows the numeric phase will run through the dense SPA.
///
/// ```
/// use spgemm_aia::sparse::Csr;
/// use spgemm_aia::spgemm::hash::{symbolic_cfg, AccumKind, EngineConfig};
///
/// // Row 0 of C = A·B is fully dense (4/4 columns), row 1 comes from a
/// // single A entry.
/// let a = Csr::from_dense(&[vec![1.0, 1.0], vec![1.0, 0.0]]);
/// let b = Csr::from_dense(&[
///     vec![1.0, 1.0, 0.0, 0.0],
///     vec![0.0, 0.0, 1.0, 1.0],
/// ]);
/// let plan = symbolic_cfg(&a, &b, &EngineConfig { spa_threshold: 0.5 });
/// assert_eq!(plan.accumulator_kind(0), Some(AccumKind::Spa));
/// assert_eq!(plan.accumulator_kind(1), Some(AccumKind::ScaledCopy));
/// // Raising the threshold past 1.0 disables the SPA entirely.
/// let plan = symbolic_cfg(&a, &b, &EngineConfig { spa_threshold: 2.0 });
/// assert_eq!(plan.accumulator_kind(0), Some(AccumKind::Hash));
/// ```
pub fn symbolic_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> SymbolicPlan {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    symbolic_with(a, b, ip, grouping, cfg)
}

/// Symbolic counting given precomputed IP + bins (shared by
/// [`symbolic_cfg`] and [`symbolic_timed`], which times the stages
/// apart).
fn symbolic_with(a: &Csr, b: &Csr, ip: Vec<u64>, grouping: Grouping, cfg: &EngineConfig) -> SymbolicPlan {
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for spec in &GROUP_SPECS {
            let rows = grouping.group_rows(spec.id);
            if rows.is_empty() {
                continue;
            }
            let ip = &ip;
            par_dynamic_with(
                rows.len(),
                bin_batch(spec),
                || bin_table(spec),
                |table, ri| {
                    let row = rows[ri] as usize;
                    let u = symbolic_row_nnz(a, b, row, ip[row], spec, table);
                    // SAFETY: each row index occurs once in the bins, so
                    // every `row_nnz` slot is written by exactly one
                    // worker, and the Vec outlives the scope.
                    unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                },
            );
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    // Accumulator selection: exact sizes are now known, so the kind per
    // row — and with it the numeric work list — costs one pass.
    let mut accum = vec![AccumKind::ScaledCopy; a.n_rows];
    let mut bins = Vec::new();
    for spec in &GROUP_SPECS {
        let mut parts: [Vec<u32>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let mut weights = [0u64; 3];
        for &row in grouping.group_rows(spec.id) {
            let r = row as usize;
            let n_out = row_nnz[r] as usize;
            if n_out == 0 {
                continue; // never reaches the numeric phase
            }
            let kind = select_accumulator(a.row_nnz(r), n_out, b.n_cols, cfg.spa_threshold);
            accum[r] = kind;
            parts[kind.index()].push(row);
            weights[kind.index()] += ip[r];
        }
        for (ki, rows) in parts.into_iter().enumerate() {
            if !rows.is_empty() {
                bins.push(NumericBin {
                    group: spec.id as u8,
                    kind: AccumKind::from_index(ki),
                    rows,
                    weight: weights[ki],
                });
            }
        }
    }
    SymbolicPlan { ip, grouping, rpt, accum, bins, spa_threshold: cfg.spa_threshold }
}

/// Exact nnz of one output row (symbolic hash inserts, with the trivial
/// cases short-circuited).
fn symbolic_row_nnz(a: &Csr, b: &Csr, row: usize, ip_row: u64, spec: &GroupSpec, table: &mut HashTable) -> u32 {
    // No hashing needed when collisions are impossible: a single A entry
    // reaches one B row (whose columns are unique by CSR invariant), and
    // IP ≤ 1 yields at most one product.
    if ip_row <= 1 || a.row_nnz(row) <= 1 {
        return ip_row as u32;
    }
    match spec.table_size {
        Some(_) => table.clear(),
        // Unique count is bounded by both IP and the output width, so
        // hub rows never allocate beyond 2·n_cols.
        None => table.reset_with_capacity(global_table_size(ip_row.min(b.n_cols as u64))),
    }
    alloc_row(a, b, row, table, &mut NullProbe)
}

/// Numeric phase: accumulate values into the plan's pre-sized, disjoint
/// output slices, one plan bin at a time. The plan must come from
/// [`symbolic`] on the same `(a, b)` pair.
pub fn numeric(a: &Csr, b: &Csr, plan: &SymbolicPlan) -> Csr {
    numeric_timed(a, b, plan).0
}

/// [`numeric`] plus wall time: total numeric seconds and the split per
/// accumulator kind (only the `numeric*` fields of the returned
/// [`PhaseTimes`] are populated).
pub fn numeric_timed(a: &Csr, b: &Csr, plan: &SymbolicPlan) -> (Csr, PhaseTimes) {
    // Validate here, not only per bin: a plan with zero bins (empty
    // output) must still reject mismatched operands instead of handing
    // back a malformed Csr.
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    assert_eq!(plan.rpt.len(), a.n_rows + 1, "plan does not match A");
    // Timer covers the O(nnz) output allocation too, matching what the
    // plan-reuse fill timer has always measured (longitudinal bench
    // numbers depend on this).
    let t0 = Instant::now();
    let nnz_c = plan.nnz();
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut times = PhaseTimes::default();
    for bi in 0..plan.bins.len() {
        let t = Instant::now();
        numeric_bin_into(a, b, plan, bi, &mut col, &mut val);
        times.numeric_kind_s[plan.bins[bi].kind.index()] += t.elapsed().as_secs_f64();
    }
    times.numeric_s = t0.elapsed().as_secs_f64();
    (Csr::new_unchecked(a.n_rows, b.n_cols, plan.rpt.clone(), col, val), times)
}

/// Fill one numeric bin of `plan` into caller-owned output buffers
/// (`col`/`val` must be sized to `plan.nnz()`). Rows write disjoint
/// `[rpt[i], rpt[i+1])` slices, so bins of the same plan may be filled
/// in any order — this is the per-bin dispatch unit of the batch
/// pipeline's phase overlap.
pub fn numeric_bin_into(a: &Csr, b: &Csr, plan: &SymbolicPlan, bin_idx: usize, col: &mut [u32], val: &mut [f64]) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    assert_eq!(plan.rpt.len(), a.n_rows + 1, "plan does not match A");
    assert_eq!(col.len(), plan.nnz(), "output buffers must be sized to the plan");
    assert_eq!(val.len(), plan.nnz(), "output buffers must be sized to the plan");
    let bin = &plan.bins[bin_idx];
    let spec = &GROUP_SPECS[bin.group as usize];
    let rows = &bin.rows[..];
    let col_ptr = col.as_mut_ptr() as usize;
    let val_ptr = val.as_mut_ptr() as usize;
    match bin.kind {
        // Single-A-entry rows are scaled copies of one B row: already
        // sorted, collision-free — no accumulator, no sort.
        AccumKind::ScaledCopy => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (),
            |_, ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                let j = a.rpt[row];
                let av = a.val[j];
                let (bc, bv) = b.row(a.col[j] as usize);
                // Real assert, not debug: the pointer writes below are
                // bounded by the plan, so a plan/input mismatch must
                // panic rather than corrupt memory.
                assert_eq!(bc.len(), n_out, "plan does not match inputs at row {row}");
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, (&c, &v)) in bc.iter().zip(bv).enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = av * v;
                    }
                }
            },
        ),
        AccumKind::Hash => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (bin_table(spec), Vec::<(u32, f64)>::new()),
            |(table, scratch), ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                match spec.table_size {
                    Some(_) => table.clear(),
                    // Exact sizing from the symbolic count: 2·nnz(C_i)
                    // keeps load factor ≤ 0.5 and is far below the
                    // 2·IP_i the single-pass engine allocated for hub
                    // rows.
                    None => table.reset_with_capacity(global_table_size(n_out as u64)),
                }
                accum_row_fast(a, b, row, table, scratch);
                write_sorted_row(scratch, row, start, n_out, col_ptr, val_ptr);
            },
        ),
        // Dense rows stream into a per-worker SPA: no probe chains, and
        // the accumulation order per column is identical to the hash
        // path's, so the sorted output is bit-identical.
        AccumKind::Spa => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (DenseAccumulator::new(b.n_cols), Vec::<(u32, f64)>::new()),
            |(spa, scratch), ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                spa.clear();
                accum_row_spa(a, b, row, spa, scratch);
                write_sorted_row(scratch, row, start, n_out, col_ptr, val_ptr);
            },
        ),
    }
}

/// Shared epilogue of the hash and SPA arms of [`numeric_bin_into`]:
/// sort the gathered row (std sort — identical result to bitonic, keys
/// unique) and write it into the row's disjoint output slice.
///
/// The length assert is a real assert, not debug: it bounds the unsafe
/// writes below, so a stale/mismatched plan must panic, not scribble.
fn write_sorted_row(scratch: &mut [(u32, f64)], row: usize, start: usize, n_out: usize, col_ptr: usize, val_ptr: usize) {
    assert_eq!(scratch.len(), n_out, "symbolic/numeric disagree on row {row}");
    scratch.sort_unstable_by_key(|e| e.0);
    let cp = col_ptr as *mut u32;
    let vp = val_ptr as *mut f64;
    for (o, &(c, v)) in scratch.iter().enumerate() {
        // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
        unsafe {
            *cp.add(start + o) = c;
            *vp.add(start + o) = v;
        }
    }
}

/// The seed's engine: allocation and accumulation fused per bin, one
/// freshly allocated table per worker chunk (PWPR) and IP-sized global
/// tables. Kept as the regression baseline the two-phase pipeline is
/// benched against (`benches/spgemm_selfproduct.rs`); output is
/// identical to [`multiply`].
pub fn multiply_single_pass(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);

    // ---- allocation phase: per-row unique counts -> rpt_C ----
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            match spec.strategy {
                Strategy::Pwpr => {
                    // many small rows: static chunks, one table per chunk
                    par_chunks(rows.len(), |start, end| {
                        let p = nnz_ptr as *mut u32;
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        for &row in &rows[start..end] {
                            table.clear();
                            let u = alloc_row(a, b, row as usize, &mut table, &mut NullProbe);
                            unsafe { *p.add(row as usize) = u };
                        }
                    });
                }
                Strategy::Tbpr => {
                    // fewer, fatter rows: dynamic scheduling with one
                    // growable table per worker (no per-row allocation)
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || HashTable::new(base, loc),
                        |table, ri| {
                            let p = nnz_ptr as *mut u32;
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            let u = alloc_row(a, b, row, table, &mut NullProbe);
                            unsafe { *p.add(row) = u };
                        },
                    );
                }
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation phase: values into disjoint output slices ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    {
        let col_ptr = col.as_mut_ptr() as usize;
        let val_ptr = val.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            let run_row = |row: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>| {
                accum_row_fast(a, b, row, table, scratch);
                scratch.sort_unstable_by_key(|e| e.0);
                let start = rpt[row];
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = v;
                    }
                }
            };
            match spec.strategy {
                Strategy::Pwpr => {
                    par_chunks(rows.len(), |start, end| {
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        let mut scratch = Vec::new();
                        for &row in &rows[start..end] {
                            table.clear();
                            run_row(row as usize, &mut table, &mut scratch);
                        }
                    });
                }
                Strategy::Tbpr => {
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || (HashTable::new(base, loc), Vec::new()),
                        |(table, scratch), ri| {
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            run_row(row, table, scratch);
                        },
                    );
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Instrumented sequential hash SpGEMM: identical output to [`multiply`],
/// plus a full program-order memory trace through `probe`. Blocks are
/// numbered globally across phases so the machine model's round-robin
/// SM assignment interleaves groups the way concurrent streams would.
pub fn multiply_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    // ---- grouping phase ----
    let ip = intermediate_products_traced(a, b, probe);
    let grouping = Grouping::build(&ip);
    let mut next_block = a.n_rows.div_ceil(IP_BLOCK_ROWS);

    // ---- allocation (symbolic) phase ----
    let mut row_nnz = vec![0u32; a.n_rows];
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Allocation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None; // fresh global table per huge row
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation (numeric) phase ----
    let spa_threshold = EngineConfig::default().spa_threshold;
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut spa_holder: Option<DenseAccumulator> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Accumulation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let start = rpt[row];
                // Plan-guided SPA rows: streamed accumulation, sequential
                // gather (already column-sorted — no bitonic network).
                if traced_row_uses_spa(a, b, row, row_nnz[row] as usize, spa_threshold) {
                    let spa = spa_holder.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                    spa.clear();
                    accum_row_spa_traced(a, b, row, spa, &mut scratch, probe);
                    probe.access(Region::RptC, row, 4, Kind::Read);
                    for (o, &(c, v)) in scratch.iter().enumerate() {
                        probe.access(Region::ColC, start + o, 4, Kind::Write);
                        probe.access(Region::ValC, start + o, 8, Kind::Write);
                        col[start + o] = c;
                        val[start + o] = v;
                    }
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                // Column-index sorting: the paper's in-block bitonic network.
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                    col[start + o] = c;
                    val[start + o] = v;
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Statistics-only traced run: emits the memory trace of every
/// `every`-th thread block and **skips the functional work of the
/// rest** (their output-row sizes are approximated by their IP upper
/// bound, which only shifts unsampled output addresses). Use when only
/// the [`crate::sim::SimReport`] is needed — the fast parallel
/// [`multiply`] provides the actual product. `every = 1` traces every
/// block (identical trace to [`multiply_traced`]).
pub fn multiply_traced_stats<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let every = every.max(1);
    // IP for *all* rows (cheap, parallel) — grouping must be exact.
    let ip = intermediate_products(a, b);
    // Grouping-phase trace for sampled blocks only.
    let n_ip_blocks = a.n_rows.div_ceil(IP_BLOCK_ROWS);
    for blk in 0..n_ip_blocks {
        if blk % every != 0 {
            continue;
        }
        probe.begin_block(blk, Phase::Grouping);
        let lo = blk * IP_BLOCK_ROWS;
        let hi = ((blk + 1) * IP_BLOCK_ROWS).min(a.n_rows);
        for i in lo..hi {
            probe.access(Region::RptA, i, 4, Kind::Read);
            probe.access(Region::RptA, i + 1, 4, Kind::Read);
            for (jo, &c) in a.row(i).0.iter().enumerate() {
                probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
                probe.indirect_range(Region::RptB, c as usize, &[], 0, 0);
                probe.compute(2);
            }
            probe.access(Region::IpCount, i, 8, Kind::Write);
            probe.access(Region::GroupCtr, crate::spgemm::ip::group_index_for_ip(ip[i]), 4, Kind::Atomic);
            probe.compute(4);
        }
    }
    let grouping = Grouping::build(&ip);
    let mut next_block = n_ip_blocks;

    // Allocation phase: real hash work on sampled blocks, IP bound for
    // the rest (address generation only; `exact` remembers which is
    // which — the accumulator decision below must never run on a
    // bound).
    let mut row_nnz = vec![0u32; a.n_rows];
    let mut exact = vec![false; a.n_rows];
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Allocation);
            }
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                if !sampled {
                    row_nnz[row] = ip[row].min(b.n_cols as u64) as u32;
                    continue;
                }
                exact[row] = true;
                probe.access(Region::Map, row, 4, Kind::Read);
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None;
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }

    // Accumulation phase: sampled blocks only.
    let spa_threshold = EngineConfig::default().spa_threshold;
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut spa_holder: Option<DenseAccumulator> = None;
    // Untraced counting table for rows whose allocation block was
    // unsampled: their `row_nnz` is an IP upper bound, good enough for
    // output addresses but not for the accumulator decision — deciding
    // SPA-vs-hash on a bound would trace the wrong path entirely.
    let mut count_table = HashTable::new(1024, TableLoc::Global);
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Accumulation);
            }
            next_block += 1;
            if !sampled {
                continue;
            }
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let start = rpt[row];
                let bound = ip[row].min(b.n_cols as u64) as usize;
                let n_out = if exact[row] {
                    row_nnz[row] as usize
                } else if bound as f64 <= spa_threshold * b.n_cols as f64 {
                    // The IP bound already rules SPA out (n_out ≤ bound):
                    // no need for the exact recount on sparse rows.
                    bound
                } else {
                    count_table.reset_with_capacity(global_table_size(bound as u64));
                    alloc_row(a, b, row, &mut count_table, &mut NullProbe) as usize
                };
                // SPA rows: streamed accumulation, sequential sorted
                // gather — same decision as the fast path's plan.
                if traced_row_uses_spa(a, b, row, n_out, spa_threshold) {
                    let spa = spa_holder.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                    spa.clear();
                    accum_row_spa_traced(a, b, row, spa, &mut scratch, probe);
                    probe.access(Region::RptC, row, 4, Kind::Read);
                    for (o, &(_c, _v)) in scratch.iter().enumerate() {
                        probe.access(Region::ColC, start + o, 4, Kind::Write);
                        probe.access(Region::ValC, start + o, 8, Kind::Write);
                    }
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                for (o, &(_c, _v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
}

/// Allocation-phase row processor (Algorithms 2–3 minus the thread
/// bookkeeping): symbolic hash inserts of every B-column reachable from
/// row `i` of A. Returns the unique count (= nnz of output row).
fn alloc_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, probe: &mut P) -> u32 {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Two-level indirection on B, allocation needs col_B only.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB], lo, hi);
        for k in lo..hi {
            table.insert_symbolic(b.col[k], probe);
        }
    }
    table.unique as u32
}

/// Accumulation-phase row processor (Algorithm 5): numeric hash inserts
/// of every intermediate product, then whole-table gather into `scratch`
/// (unsorted — the caller sorts).
fn accum_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>, probe: &mut P) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Accumulation streams both col_B and val_B.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB, Region::ValB], lo, hi);
        for k in lo..hi {
            table.insert_numeric(b.col[k], av * b.val[k], probe);
            probe.compute(1); // the multiply
        }
    }
    table.gather(scratch, probe);
}

/// Fast-path accumulation row processor: same inserts as [`accum_row`]
/// but gathers in O(unique) via the occupied list (no probe events).
fn accum_row_fast(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>) {
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            table.insert_numeric(b.col[k], av * b.val[k], &mut NullProbe);
        }
    }
    table.gather_list(scratch);
}

/// Dense-SPA accumulation row processor (plan-guided dense rows): same
/// intermediate products, same per-column accumulation order as the
/// hash path, but into `vals[col]` directly — no probing. Caller clears
/// the SPA and sorts `scratch`.
fn accum_row_spa(a: &Csr, b: &Csr, i: usize, spa: &mut DenseAccumulator, scratch: &mut Vec<(u32, f64)>) {
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            spa.add(b.col[k], av * b.val[k]);
        }
    }
    spa.gather_list(scratch);
}

/// Traced dense-SPA row processor: the B rows are read as **plain
/// streamed loads** (never [`Probe::indirect_range`] — SPA rows are
/// AIA-ineligible by design, the gather/scatter engine buys nothing for
/// a row that streams into a contiguous accumulator), and the SPA
/// accesses land on [`Region::SpaVals`]/[`Region::SpaFlags`]. The
/// gather is the GPU's sequential scan, so `scratch` comes back sorted
/// by column — no bitonic network needed.
fn accum_row_spa_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    spa: &mut DenseAccumulator,
    scratch: &mut Vec<(u32, f64)>,
    probe: &mut P,
) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        probe.access(Region::RptB, colk, 4, Kind::Read);
        probe.access(Region::RptB, colk + 1, 4, Kind::Read);
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            probe.access(Region::ColB, k, 4, Kind::Read);
            probe.access(Region::ValB, k, 8, Kind::Read);
            spa.add_traced(b.col[k], av * b.val[k], probe);
            probe.compute(1); // the multiply
        }
    }
    spa.gather(scratch, probe);
}

/// Whether the traced paths run row `i` through the SPA — the same
/// decision [`symbolic_cfg`] bakes into the plan, evaluated at the
/// process-default threshold (the traced engine replans inline).
fn traced_row_uses_spa(a: &Csr, b: &Csr, row: usize, n_out: usize, spa_threshold: f64) -> bool {
    n_out > 0 && select_accumulator(a.row_nnz(row), n_out, b.n_cols, spa_threshold) == AccumKind::Spa
}

/// Strategy assigned to a row with the given IP (for tests/diagnostics).
pub fn strategy_for_ip(ip: u64) -> Strategy {
    GROUP_SPECS[crate::spgemm::ip::group_index_for_ip(ip)].strategy
}

/// Expose the spec list for the coordinator's stream scheduler.
pub fn group_specs() -> &'static [GroupSpec; 4] {
    &GROUP_SPECS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::{qc, Pcg32};

    fn random_csr(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Csr {
        let mut coo = crate::sparse::Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density) as usize;
        for _ in 0..target {
            coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-2.0, 2.0));
        }
        coo.to_csr()
    }

    #[test]
    fn matches_reference_small() {
        let a = Csr::from_dense(&[vec![1.0, 2.0, 0.0], vec![0.0, 0.0, 3.0], vec![1.0, 0.0, 1.0]]);
        let b = Csr::from_dense(&[vec![0.0, 1.0], vec![1.0, 0.0], vec![2.0, 2.0]]);
        let c = multiply(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert!(c.approx_eq(&r, 1e-12), "{:?} vs {:?}", c.to_dense(), r.to_dense());
    }

    #[test]
    fn two_phase_equals_single_pass_exactly() {
        let mut rng = Pcg32::seeded(321);
        let a = random_csr(&mut rng, 300, 250, 0.03);
        let b = random_csr(&mut rng, 250, 280, 0.02);
        // bit-for-bit: same structure, same value sums in the same order
        assert_eq!(multiply(&a, &b), multiply_single_pass(&a, &b));
    }

    #[test]
    fn symbolic_plan_is_exact() {
        let mut rng = Pcg32::seeded(17);
        let a = random_csr(&mut rng, 120, 100, 0.05);
        let b = random_csr(&mut rng, 100, 90, 0.05);
        let plan = symbolic(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert_eq!(plan.rpt, r.rpt, "symbolic sizes must be exact, not bounds");
        assert_eq!(plan.nnz(), r.nnz());
        let c = numeric(&a, &b, &plan);
        assert!(c.approx_eq(&r, 1e-10));
    }

    #[test]
    fn phase_times_are_reported() {
        let mut rng = Pcg32::seeded(23);
        let a = random_csr(&mut rng, 400, 400, 0.02);
        let (c, t) = multiply_timed(&a, &a);
        assert!(c.nnz() > 0);
        assert!(t.grouping_s >= 0.0 && t.symbolic_s >= 0.0 && t.numeric_s >= 0.0);
        assert!(t.total_s() >= t.numeric_s);
        assert!(t.total_s() > 0.0, "three timed phases cannot all be zero-width");
    }

    #[test]
    fn single_entry_rows_take_copy_path() {
        // Diagonal × random exercises the no-table scaled-copy path on
        // every row; result must still be exact.
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        let d = Csr::from_diag(&[2.5; 64]);
        let c = multiply(&d, &m);
        let mut expect = m.clone();
        expect.map_values(|v| 2.5 * v);
        assert!(c.approx_eq(&expect, 1e-12));
    }

    #[test]
    fn traced_equals_fast_path() {
        let mut rng = Pcg32::seeded(77);
        let a = random_csr(&mut rng, 200, 150, 0.02);
        let b = random_csr(&mut rng, 150, 180, 0.03);
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
        assert!(probe.indirect_ranges > 0);
        assert!(probe.shared > 0);
    }

    #[test]
    fn matches_reference_randomized() {
        qc::check(24, 2024, |g| {
            let rows = g.dim();
            let inner = g.dim();
            let cols = g.dim();
            let density = 0.02 + g.rng.f64() * 0.2;
            let a = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, rows, inner, density)
            };
            let b = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, inner, cols, density)
            };
            let c = multiply(&a, &b);
            let r = spgemm_reference(&a, &b);
            assert!(c.validate().is_ok(), "invalid CSR output");
            assert!(c.approx_eq(&r, 1e-10), "hash engine disagrees with reference");
        });
    }

    #[test]
    fn exercises_all_four_groups() {
        // Build a matrix whose rows produce IPs in every group: B dense-ish
        // rows amplify.
        let mut rng = Pcg32::seeded(5);
        let n = 600;
        let mut coo = crate::sparse::Coo::new(n, n);
        // row 0: 1 nnz (group 0); row 1: 40 nnz (g1); row 2: 300 nnz (g2 via
        // IP multiplication); rows 3..: heavy hub rows for group 3.
        for j in 0..1 {
            coo.push(0, j * 7 % n, 1.0);
        }
        for j in 0..40 {
            coo.push(1, (j * 13) % n, 1.0);
        }
        for j in 0..300 {
            coo.push(2, (j * 2 + 1) % n, 1.0);
        }
        for r in 3..40 {
            for j in 0..r * 20 % n {
                coo.push(r, (j * 3 + r) % n, 1.0);
            }
        }
        for r in 40..n {
            for _ in 0..6 {
                coo.push(r, rng.below_usize(n), 1.0);
            }
        }
        let a = coo.to_csr();
        let ip = intermediate_products(&a, &a);
        let grouping = Grouping::build(&ip);
        let non_empty = (0..4).filter(|&g| !grouping.group_rows(g).is_empty()).count();
        assert!(non_empty >= 3, "expected ≥3 groups populated, got {non_empty}");
        let c = multiply(&a, &a);
        let r = spgemm_reference(&a, &a);
        assert!(c.approx_eq(&r, 1e-10));
        // and the seed baseline still agrees on the same stress input
        assert_eq!(c, multiply_single_pass(&a, &a));
    }

    #[test]
    fn empty_and_identity_edge_cases() {
        let z = Csr::zeros(5, 5);
        assert_eq!(multiply(&z, &z).nnz(), 0);
        let i = Csr::identity(64);
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        assert!(multiply(&i, &m).approx_eq(&m, 1e-12));
        assert!(multiply(&m, &i).approx_eq(&m, 1e-12));
    }

    #[test]
    fn strategy_assignment() {
        assert_eq!(strategy_for_ip(10), Strategy::Pwpr);
        assert_eq!(strategy_for_ip(100), Strategy::Tbpr);
    }

    /// Dense-ish operands so the default threshold actually selects SPA
    /// rows (every output row of a dense product is fully dense).
    fn dense_pair(seed: u64, n: usize) -> (Csr, Csr) {
        let mut rng = Pcg32::seeded(seed);
        (random_csr(&mut rng, n, n, 0.5), random_csr(&mut rng, n, n, 0.5))
    }

    #[test]
    fn spa_and_hash_paths_are_bit_identical() {
        let (a, b) = dense_pair(101, 96);
        let forced_spa = multiply_cfg(&a, &b, &EngineConfig { spa_threshold: 0.0 });
        let no_spa = multiply_cfg(&a, &b, &EngineConfig { spa_threshold: 2.0 });
        let default = multiply(&a, &b);
        // bit-for-bit across all accumulator selections
        assert_eq!(forced_spa, no_spa);
        assert_eq!(forced_spa, default);
        let r = spgemm_reference(&a, &b);
        assert!(forced_spa.approx_eq(&r, 1e-10));
    }

    #[test]
    fn threshold_boundaries_select_kinds() {
        let (a, b) = dense_pair(7, 64);
        // 0.0 forces SPA on every multi-entry row: no hash bins remain.
        let plan = symbolic_cfg(&a, &b, &EngineConfig { spa_threshold: 0.0 });
        assert!(plan.bins.iter().all(|bin| bin.kind != AccumKind::Hash), "0.0 must force SPA");
        assert!(plan.kind_rows()[AccumKind::Spa.index()] > 0);
        // ≥ 1.0 disables SPA entirely.
        for thr in [1.0, 1.5] {
            let plan = symbolic_cfg(&a, &b, &EngineConfig { spa_threshold: thr });
            assert!(plan.bins.iter().all(|bin| bin.kind != AccumKind::Spa), "{thr} must disable SPA");
        }
    }

    #[test]
    fn plan_bins_partition_nonempty_rows() {
        let mut rng = Pcg32::seeded(55);
        let a = random_csr(&mut rng, 300, 260, 0.03);
        let b = random_csr(&mut rng, 260, 240, 0.03);
        let plan = symbolic(&a, &b);
        let mut seen = vec![false; a.n_rows];
        for bin in &plan.bins {
            assert!(!bin.rows.is_empty(), "empty bins must be dropped");
            for &r in &bin.rows {
                assert!(!seen[r as usize], "row {r} appears in two bins");
                seen[r as usize] = true;
                assert_eq!(plan.accumulator_kind(r as usize), Some(bin.kind));
                assert_eq!(plan.grouping.group_of[r as usize], bin.group);
            }
            assert_eq!(bin.weight, bin.rows.iter().map(|&r| plan.ip[r as usize]).sum::<u64>());
        }
        for r in 0..a.n_rows {
            assert_eq!(seen[r], plan.row_nnz(r) > 0, "row {r} binned iff it has output");
            if plan.row_nnz(r) == 0 {
                assert_eq!(plan.accumulator_kind(r), None);
            }
        }
    }

    #[test]
    fn numeric_bin_into_fills_bins_in_any_order() {
        let (a, b) = dense_pair(33, 80);
        let plan = symbolic(&a, &b);
        let expect = numeric(&a, &b, &plan);
        let mut col = vec![0u32; plan.nnz()];
        let mut val = vec![0f64; plan.nnz()];
        for bi in (0..plan.bins.len()).rev() {
            numeric_bin_into(&a, &b, &plan, bi, &mut col, &mut val);
        }
        let c = Csr::new_unchecked(a.n_rows, b.n_cols, plan.rpt.clone(), col, val);
        assert_eq!(c, expect, "bins write disjoint slices — order must not matter");
    }

    #[test]
    fn traced_spa_rows_equal_fast_path() {
        // Dense product: the default threshold picks SPA on most rows,
        // and the traced path must still match the fast path exactly.
        let (a, b) = dense_pair(88, 72);
        let plan = symbolic(&a, &b);
        assert!(
            plan.kind_rows()[AccumKind::Spa.index()] > 0,
            "test needs SPA rows at the default threshold"
        );
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
    }

    #[test]
    fn timed_numeric_splits_by_kind() {
        let (a, b) = dense_pair(14, 96);
        let (c, t) = multiply_timed(&a, &b);
        assert!(c.nnz() > 0);
        let kind_total: f64 = t.numeric_kind_s.iter().sum();
        assert!(kind_total > 0.0, "per-kind numeric times must be recorded");
        assert!(kind_total <= t.numeric_s + 1e-9, "kind split cannot exceed the numeric total");
    }

    #[test]
    fn default_threshold_is_sane() {
        // The accepted range matches the CLI/env validation ([0, 8]);
        // values past 1.0 are legal and mean "SPA disabled".
        let t = default_spa_threshold();
        assert!((0.0..=8.0).contains(&t), "default threshold {t} out of range");
        assert_eq!(EngineConfig::default().spa_threshold, t);
    }
}
