//! Measurement-calibrated kernel thresholds (ROADMAP item: derive the
//! SPA/bitmap crossovers from measured curves, not geometry).
//!
//! The dense-row threshold that decides which rows run the SPA/bitmap
//! kernels defaults to a static cache-geometry formula
//! ([`crate::sim::DeviceConfig::dense_row_threshold_base`]). This
//! module closes the loop from *measurement*: [`calibrate_sweep`] runs
//! the traced engine over the registered datasets at a grid of
//! thresholds, records the simulated wall time and the byte-accurate
//! waste ratio of each run (see `sim::ranges`), and picks the threshold
//! minimising the mean min-normalised time (waste breaks ties). The
//! result persists as a versioned `calibration.json` **next to the plan
//! cache**, where the threshold ladder
//! ([`super::engine::default_spa_threshold`]) picks it up in later
//! processes: flag > env > calibration > geometry.
//!
//! Thresholds only steer kernel *choice*, never results — outputs stay
//! bit-identical under any calibration (pinned by
//! `tests/accumulator_select.rs`, `tests/symbolic_select.rs`, and the
//! calibration acceptance suite) — so a stale or corrupt file can cost
//! speed, not correctness. Corruption, schema/version mismatches, and
//! out-of-range values all degrade to the geometry fallback silently.

use super::engine::EngineConfig;
use super::estimate::PlannerPolicy;
use super::planstore::peek_plan_cache_dir;
use crate::sim::{simulate_stats_engine_cfg, AiaMode, DeviceConfig, SimConfig};
use crate::sparse::Csr;
use crate::util::error::{anyhow, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// File name of the persisted calibration, inside the plan-cache
/// directory. The plan store's lifecycle tooling (`ls`/`verify`/
/// `prune`) operates on `.plan` files only and leaves it alone.
pub const CALIBRATION_FILE: &str = "calibration.json";

/// Schema tag every calibration file carries.
pub const CALIBRATION_SCHEMA: &str = "spgemm-aia-calibration-v1";

/// Current calibration format version; files from other versions are
/// ignored (→ geometry fallback), never reinterpreted.
pub const CALIBRATION_VERSION: i64 = 1;

/// One dataset the sweep measures: the matrix is squared (`A·A`, the
/// registered datasets' canonical workload) on a device scaled for the
/// dataset's down-scaling factor.
pub struct CalibrateInput {
    pub name: String,
    pub a: Csr,
    pub scale: usize,
}

/// One grid point of the sweep, aggregated across datasets.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationPoint {
    pub threshold: f64,
    /// Mean simulated wall time across datasets, in ms.
    pub mean_time_ms: f64,
    /// Mean of per-dataset time normalised by that dataset's best
    /// threshold (1.0 = this threshold is every dataset's optimum) —
    /// the fit minimises this, so big datasets don't drown small ones.
    pub mean_norm_time: f64,
    /// Mean overall waste ratio (unused fetched bytes / fetched bytes).
    pub mean_waste: f64,
}

/// A fitted, persistable threshold calibration.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    pub version: i64,
    /// The winning SPA/bitmap threshold — what the ladder loads.
    pub spa_threshold: f64,
    /// The geometry fallback at fit time, kept for context in reports.
    pub geometry_threshold: f64,
    /// Dataset names the sweep measured.
    pub datasets: Vec<String>,
    /// The measured curve, one point per grid threshold.
    pub sweep: Vec<CalibrationPoint>,
}

impl Calibration {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("schema", CALIBRATION_SCHEMA.into());
        o.set("version", Json::Int(self.version));
        o.set("spa_threshold", self.spa_threshold.into());
        o.set("geometry_threshold", self.geometry_threshold.into());
        o.set("datasets", Json::Arr(self.datasets.iter().map(|d| Json::Str(d.clone())).collect()));
        let mut sweep = Vec::new();
        for p in &self.sweep {
            let mut po = Json::obj();
            po.set("threshold", p.threshold.into());
            po.set("mean_time_ms", p.mean_time_ms.into());
            po.set("mean_norm_time", p.mean_norm_time.into());
            po.set("mean_waste", p.mean_waste.into());
            sweep.push(po);
        }
        o.set("sweep", Json::Arr(sweep));
        o
    }

    /// Strict on what matters (schema, version, a sane threshold),
    /// lenient on context fields — any disqualifying anomaly returns
    /// `None` and the ladder falls back to geometry.
    pub fn from_json(j: &Json) -> Option<Calibration> {
        if j.get("schema")?.as_str()? != CALIBRATION_SCHEMA {
            return None;
        }
        let version = j.get("version")?.as_i64()?;
        if version != CALIBRATION_VERSION {
            return None;
        }
        let spa_threshold = j.get("spa_threshold")?.as_f64()?;
        if !spa_threshold.is_finite() || !(0.0..=8.0).contains(&spa_threshold) {
            return None;
        }
        let geometry_threshold = j.get("geometry_threshold").and_then(Json::as_f64).unwrap_or(0.0);
        let datasets = j
            .get("datasets")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|d| d.as_str().map(str::to_string)).collect())
            .unwrap_or_default();
        let sweep = j
            .get("sweep")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|p| {
                        Some(CalibrationPoint {
                            threshold: p.get("threshold")?.as_f64()?,
                            mean_time_ms: p.get("mean_time_ms")?.as_f64()?,
                            mean_norm_time: p.get("mean_norm_time")?.as_f64()?,
                            mean_waste: p.get("mean_waste")?.as_f64()?,
                        })
                    })
                    .collect()
            })
            .unwrap_or_default();
        Some(Calibration { version, spa_threshold, geometry_threshold, datasets, sweep })
    }

    /// Write atomically (temp file + rename) as `calibration.json`
    /// inside `dir`, creating the directory if needed. Returns the
    /// final path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir).map_err(|e| anyhow!("create {}: {e}", dir.display()))?;
        let path = dir.join(CALIBRATION_FILE);
        let tmp = dir.join(format!("{CALIBRATION_FILE}.tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.to_json().render_pretty()).map_err(|e| anyhow!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path).map_err(|e| anyhow!("rename {}: {e}", path.display()))?;
        Ok(path)
    }

    /// Load `calibration.json` from `dir`. Missing, unreadable,
    /// unparsable, or invalid files all yield `None` — calibration is
    /// an optimisation, never an error source.
    pub fn load(dir: &Path) -> Option<Calibration> {
        let text = std::fs::read_to_string(dir.join(CALIBRATION_FILE)).ok()?;
        Calibration::from_json(&Json::parse(&text).ok()?)
    }
}

/// The threshold a persisted calibration next to the plan cache
/// recommends, if one exists and validates. Reads the plan-cache
/// location *without* latching it (see
/// `planstore::peek_plan_cache_dir`) so threshold resolution can't
/// steal a later `--plan-cache` flag's slot.
pub fn calibrated_spa_threshold() -> Option<f64> {
    Calibration::load(&peek_plan_cache_dir()?).map(|c| c.spa_threshold)
}

/// The default sweep grid: dense around the geometric base (0.25 at
/// 32-byte sectors), sparse toward the disable end.
pub fn default_threshold_grid() -> Vec<f64> {
    vec![0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4, 0.5, 0.75, 1.0]
}

/// Sweep `thresholds` across `inputs` under the traced engine (AIA on —
/// the device being calibrated) and fit the crossover: the winner
/// minimises the mean min-normalised simulated time, with the measured
/// waste ratio breaking ties (lower grid value breaks exact ties, for
/// determinism). `on_point` fires after each `(dataset, threshold)` run
/// with `(name, threshold, time_ms, waste_ratio)` — the CLI prints
/// progress through it; pass `|_, _, _, _| {}` to stay silent.
pub fn calibrate_sweep<F>(inputs: &[CalibrateInput], thresholds: &[f64], mut on_point: F) -> Calibration
where
    F: FnMut(&str, f64, f64, f64),
{
    assert!(!inputs.is_empty(), "calibrate_sweep: no datasets");
    assert!(!thresholds.is_empty(), "calibrate_sweep: empty threshold grid");
    let mut times = vec![vec![0.0f64; thresholds.len()]; inputs.len()];
    let mut wastes = vec![vec![0.0f64; thresholds.len()]; inputs.len()];
    for (d, input) in inputs.iter().enumerate() {
        let sim = SimConfig::for_scale(AiaMode::On, input.scale);
        for (k, &t) in thresholds.iter().enumerate() {
            let engine = EngineConfig {
                spa_threshold: t,
                symbolic_threshold: None,
                planner: PlannerPolicy::Exact,
                mask: None,
            };
            let r = simulate_stats_engine_cfg(&input.a, &input.a, &sim, &engine);
            times[d][k] = r.total_ms;
            wastes[d][k] = r.waste_ratio();
            on_point(&input.name, t, r.total_ms, r.waste_ratio());
        }
    }
    let n = inputs.len() as f64;
    let mut sweep = Vec::with_capacity(thresholds.len());
    for (k, &t) in thresholds.iter().enumerate() {
        let mut ms = 0.0;
        let mut norm = 0.0;
        let mut waste = 0.0;
        for d in 0..inputs.len() {
            let best = times[d].iter().copied().fold(f64::INFINITY, f64::min).max(1e-12);
            ms += times[d][k];
            norm += times[d][k] / best;
            waste += wastes[d][k];
        }
        sweep.push(CalibrationPoint {
            threshold: t,
            mean_time_ms: ms / n,
            mean_norm_time: norm / n,
            mean_waste: waste / n,
        });
    }
    let mut best = 0;
    for k in 1..sweep.len() {
        let (cand, cur) = (&sweep[k], &sweep[best]);
        let faster = cand.mean_norm_time < cur.mean_norm_time - 1e-9;
        let tied = (cand.mean_norm_time - cur.mean_norm_time).abs() <= 1e-9;
        if faster || (tied && cand.mean_waste < cur.mean_waste - 1e-9) {
            best = k;
        }
    }
    Calibration {
        version: CALIBRATION_VERSION,
        spa_threshold: sweep[best].threshold,
        geometry_threshold: DeviceConfig::h200_scaled().dense_row_threshold_base(),
        datasets: inputs.iter().map(|i| i.name.clone()).collect(),
        sweep,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Calibration {
        Calibration {
            version: CALIBRATION_VERSION,
            spa_threshold: 0.15,
            geometry_threshold: 0.25,
            datasets: vec!["scircuit".into()],
            sweep: vec![CalibrationPoint {
                threshold: 0.15,
                mean_time_ms: 1.5,
                mean_norm_time: 1.0,
                mean_waste: 0.4,
            }],
        }
    }

    #[test]
    fn json_round_trip() {
        let c = sample();
        let j = c.to_json();
        assert_eq!(Calibration::from_json(&j), Some(c));
    }

    #[test]
    fn from_json_rejects_anomalies() {
        let ok = sample().to_json();
        assert!(Calibration::from_json(&ok).is_some());
        let mut wrong_schema = ok.clone();
        wrong_schema.set("schema", "other-v9".into());
        assert_eq!(Calibration::from_json(&wrong_schema), None);
        let mut future = ok.clone();
        future.set("version", Json::Int(CALIBRATION_VERSION + 1));
        assert_eq!(Calibration::from_json(&future), None);
        let mut oob = ok.clone();
        oob.set("spa_threshold", 9.5.into());
        assert_eq!(Calibration::from_json(&oob), None);
        let mut nan = ok.clone();
        nan.set("spa_threshold", f64::NAN.into());
        assert_eq!(Calibration::from_json(&nan), None);
        let mut missing = ok;
        missing.set("spa_threshold", Json::Null);
        assert_eq!(Calibration::from_json(&missing), None);
    }

    #[test]
    fn load_missing_or_corrupt_is_none() {
        let dir = std::env::temp_dir().join(format!("spgemm-aia-cal-none-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(Calibration::load(&dir), None);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(CALIBRATION_FILE), b"{ not json").unwrap();
        assert_eq!(Calibration::load(&dir), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("spgemm-aia-cal-rt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = sample();
        let path = c.save(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), CALIBRATION_FILE);
        assert_eq!(Calibration::load(&dir), Some(c));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
