//! Incremental dirty-row replanning for dynamic graphs (ROADMAP
//! "Incremental SpGEMM — dirty-row replan").
//!
//! Iterative apps mutate operand *structure* a few rows at a time —
//! MCL's per-iteration prune, GNN sparsification, streaming edge
//! inserts/deletes — yet a structure-hash mismatch used to throw the
//! whole plan away and re-pay the full symbolic phase. This module
//! patches a [`PlannedProduct`] in place instead:
//!
//! 1. **Diff** the old and new operands through memoized per-row FNV
//!    hashes ([`crate::sparse::Csr::row_structure_hashes`]). A row of A
//!    is *dirty* when its own pattern changed, or when it touches a row
//!    of B whose pattern changed (the column-touch rule — its IP bound
//!    and hash-table sizing depend on those B rows). Scanning the *new*
//!    A suffices: a clean row's pattern is by definition unchanged, so
//!    its touch set is too.
//! 2. **Re-run symbolic work only for dirty rows** — IP bounds, the
//!    counting kernel (the same
//!    [`super::engine`] `symbolic_row_nnz_hash`/`_bitmap` kernels the
//!    cold path runs), and exact output sizes. Clean rows keep their
//!    counts: they are structure-derived facts of unchanged rows.
//! 3. **Rebuild the cheap O(n) derived state wholesale** — grouping
//!    (a stable counting sort of the IP vector), `rpt` prefix sum,
//!    per-row kernel kinds, and the IP-weighted bins
//!    ([`super::engine`] `build_bins`). Within-bin row order is
//!    ascending row id in both the cold and patched paths, so the
//!    patched plan is **bit-identical** to a cold plan by construction
//!    (pinned by `tests/incremental.rs`).
//!
//! The patched plan's identity is the mutated operands' fingerprint;
//! its provenance is a [`DeltaLineage`] — base fingerprint plus an
//! ordered, self-verifiable delta digest — which both plan-store tiers
//! validate so a stale or damaged chain degrades to a silent full
//! replan, never a wrong answer (see `DESIGN.md` §"Incremental
//! replanning").

use super::engine::{
    build_bins, effective_thresholds, symbolic_row_nnz_bitmap, symbolic_row_nnz_bitmap_masked,
    symbolic_row_nnz_hash, symbolic_row_nnz_hash_masked, symbolic_row_nnz_trivial_masked, EngineConfig,
    SymbolicPlan,
};
use super::grouping::{select_symbolic, select_symbolic_masked, Grouping, SymbolicKind, GROUP_SPECS};
use super::mask::{mask_hash_of, MaskRowProbe};
use super::plan::{pair_key_from_hashes, DeltaLineage, PlannedProduct};
use super::table::{HashTable, RowCounter};
use crate::sim::probe::PhaseTimes;
use crate::sparse::Csr;
use std::time::Instant;

/// Longest admissible patch chain. The digest chain is exact at any
/// length, but each patch re-derives O(n) state from retained counts —
/// a bounded chain caps how far a plan can drift from a cold build and
/// forces a periodic full replan that re-anchors the lineage.
pub const MAX_DELTA_CHAIN: u32 = 8;

/// Dirty-row fraction above which patching is pointless: past half the
/// rows, a full symbolic pass is no slower and resets the chain. This
/// is also what keeps *unrelated* same-shape matrices off the delta
/// path — their diff is ~100% dirty, so they fall through to a cold
/// plan (`PlanSource::Fresh`), not a bogus "delta".
pub const REBUILD_DIRTY_FRACTION: f64 = 0.5;

/// A successful in-place patch.
pub struct DeltaPatch {
    /// The patched plan, bound to the mutated operands' fingerprint and
    /// carrying the extended [`DeltaLineage`]. `plan_times` holds only
    /// the patch's own seconds (diff + grouping in `grouping_s`,
    /// dirty-row counting + bin rebuild in `symbolic_s`).
    pub plan: PlannedProduct,
    /// Rows of A whose symbolic work was actually re-run — the quantity
    /// the ≤ 5 %-of-rows acceptance bound is asserted on.
    pub dirty_rows: usize,
}

/// What [`delta_patch`] decided.
pub enum DeltaOutcome {
    /// The plan was patched; use `patch.plan` instead of replanning.
    Patched(Box<DeltaPatch>),
    /// Patching was refused (reason is diagnostic only) — run a cold
    /// plan. Never an error: the cold path is always correct.
    Rebuild(&'static str),
}

/// Try to patch `base` (a plan for some earlier structure of this
/// operand pair) into a plan for the *current* `(a, b)`.
///
/// Callers should first check `base.matches(a, b)` — operands whose
/// structure is unchanged need no patch at all (a value-only mutation
/// is a plain plan hit). The patch is refused — `Rebuild` — when the
/// shapes changed, the chain is at [`MAX_DELTA_CHAIN`], or more than
/// [`REBUILD_DIRTY_FRACTION`] of A's rows are dirty.
///
/// The patched plan is bit-identical to `PlannedProduct::plan_cfg(a,
/// b, cfg)` — same `rpt`, row kinds, bins, and fills — for any `cfg`:
/// every retained per-row fact (IP bound, exact count) is a pure
/// function of unchanged structure, and everything threshold-dependent
/// (kernel kinds, bins) is recomputed under `cfg`.
pub fn delta_patch(base: &PlannedProduct, a: &Csr, b: &Csr, cfg: &EngineConfig) -> DeltaOutcome {
    if base.a_shape() != (a.n_rows, a.n_cols) || base.b_shape() != (b.n_rows, b.n_cols) {
        return DeltaOutcome::Rebuild("operand shape changed");
    }
    // A plan's retained counts are only valid under the mask they were
    // counted with — a different mask (or adding/dropping one) changes
    // every row's exact size, so the clean-row retention premise fails.
    if mask_hash_of(&cfg.mask) != base.mask_hash() {
        return DeltaOutcome::Rebuild("mask changed");
    }
    let chain_len = base.delta().map_or(0, |d| d.chain_len);
    if chain_len >= MAX_DELTA_CHAIN {
        return DeltaOutcome::Rebuild("delta chain at rebuild threshold");
    }

    // --- dirty-set diff (charged as grouping time, like cold IP/binning) ---
    let t0 = Instant::now();
    let a_hash = a.structure_hash();
    let b_hash = b.structure_hash();
    let (a_old, b_old) = (base.a_row_hashes(), base.b_row_hashes());
    let (a_new, b_new) = (a.row_structure_hashes(), b.row_structure_hashes());
    let mut b_dirty = vec![false; b.n_rows];
    let mut any_b_dirty = false;
    for r in 0..b.n_rows {
        if b_old[r] != b_new[r] {
            b_dirty[r] = true;
            any_b_dirty = true;
        }
    }
    let mut dirty: Vec<u32> = Vec::new(); // ascending by construction
    for r in 0..a.n_rows {
        let self_dirty = a_old[r] != a_new[r];
        let feeds_dirty = any_b_dirty && a.row(r).0.iter().any(|&c| b_dirty[c as usize]);
        if self_dirty || feeds_dirty {
            dirty.push(r as u32);
        }
    }
    if dirty.is_empty() {
        // Hash changed but no row did — collision paranoia; the cold
        // path is the only safe answer.
        return DeltaOutcome::Rebuild("structure hash changed but no dirty rows found");
    }
    if (dirty.len() as f64) > REBUILD_DIRTY_FRACTION * a.n_rows as f64 {
        return DeltaOutcome::Rebuild("dirty fraction above rebuild threshold");
    }

    // --- patch: dirty IP + wholesale grouping / kernel selection ---
    let old = base.symbolic_plan();
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);
    let mut ip = old.ip.clone();
    for &r in &dirty {
        let r = r as usize;
        ip[r] = a.row(r).0.iter().map(|&c| (b.rpt[c as usize + 1] - b.rpt[c as usize]) as u64).sum();
    }
    let grouping = Grouping::build(&ip);
    let mask = cfg.mask.as_ref();
    let mut sym = vec![SymbolicKind::Trivial; a.n_rows];
    for (r, k) in sym.iter_mut().enumerate() {
        *k = match mask {
            None => select_symbolic(a.row_nnz(r), ip[r], b.n_cols, sym_threshold),
            Some(m) => select_symbolic_masked(a.row_nnz(r), ip[r], m.row_nnz(r), b.n_cols, sym_threshold),
        };
    }
    let grouping_s = t0.elapsed().as_secs_f64();

    // --- dirty-row counting with the cold path's kernels ---
    let t1 = Instant::now();
    let mut counts: Vec<usize> = (0..a.n_rows).map(|r| old.rpt[r + 1] - old.rpt[r]).collect();
    let mut tables: [Option<HashTable>; GROUP_SPECS.len()] = Default::default();
    let mut counter: Option<RowCounter> = None;
    let mut admit: Option<MaskRowProbe> = None;
    let mut symbolic_kind_s = [0f64; 3];
    for &r in &dirty {
        let r = r as usize;
        let tk = Instant::now();
        let n = match (sym[r], mask) {
            // Same short-circuit as the cold trivial sub-bin: the IP
            // bound *is* the exact count. Under a mask the shortcut is
            // invalid (it would count rejected columns) — the masked
            // trivial kernel intersects instead, exactly like the cold
            // masked symbolic phase.
            (SymbolicKind::Trivial, None) => ip[r] as u32,
            (SymbolicKind::Trivial, Some(m)) => symbolic_row_nnz_trivial_masked(a, b, r, m),
            (SymbolicKind::Hash, None) => {
                let g = grouping.group_of[r] as usize;
                let spec = &GROUP_SPECS[g];
                let table = tables[g].get_or_insert_with(|| super::engine::bin_table(spec));
                symbolic_row_nnz_hash(a, b, r, ip[r], spec, table)
            }
            (SymbolicKind::Hash, Some(m)) => {
                let g = grouping.group_of[r] as usize;
                let spec = &GROUP_SPECS[g];
                let table = tables[g].get_or_insert_with(|| super::engine::bin_table(spec));
                let probe = admit.get_or_insert_with(|| MaskRowProbe::new(b.n_cols));
                symbolic_row_nnz_hash_masked(a, b, r, ip[r], spec, table, probe, m)
            }
            (SymbolicKind::Bitmap, None) => {
                let c = counter.get_or_insert_with(|| RowCounter::new(b.n_cols));
                symbolic_row_nnz_bitmap(a, b, r, c)
            }
            (SymbolicKind::Bitmap, Some(m)) => {
                let c = counter.get_or_insert_with(|| RowCounter::new(b.n_cols));
                let probe = admit.get_or_insert_with(|| MaskRowProbe::new(b.n_cols));
                symbolic_row_nnz_bitmap_masked(a, b, r, c, probe, m)
            }
        };
        symbolic_kind_s[sym[r].index()] += tk.elapsed().as_secs_f64();
        counts[r] = n as usize;
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + counts[i];
    }
    let (accum, bins) = build_bins(a, b.n_cols, &ip, &grouping, &rpt, &sym, num_threshold);
    let plan = SymbolicPlan {
        ip,
        grouping,
        rpt,
        accum,
        symbolic: sym,
        bins,
        spa_threshold: cfg.spa_threshold,
        mask: cfg.mask.clone(),
    };
    let symbolic_s = t1.elapsed().as_secs_f64();

    // --- extend the lineage ---
    let (base_a_hash, base_b_hash, prev_digest) = match base.delta() {
        Some(d) => (d.base_a_hash, d.base_b_hash, d.digest),
        None => (base.a_hash(), base.b_hash(), pair_key_from_hashes(base.a_hash(), base.b_hash())),
    };
    let mut lineage =
        DeltaLineage { base_a_hash, base_b_hash, chain_len: chain_len + 1, prev_digest, digest: 0 };
    lineage.digest = lineage.expected_digest(a_hash, b_hash, a.row_structure_hashes(), b.row_structure_hashes());

    let plan_times = PhaseTimes { grouping_s, symbolic_s, symbolic_kind_s, ..PhaseTimes::default() };
    let planned = PlannedProduct::from_patch(plan, a, b, a_hash, b_hash, lineage, plan_times);
    DeltaOutcome::Patched(Box::new(DeltaPatch { plan: planned, dirty_rows: dirty.len() }))
}

/// Deterministically flip the structure of `fraction` of `m`'s rows —
/// an edge insert-or-delete per selected row (remove column `(seed +
/// row) % n_cols` when present, insert it when absent). Shared by the
/// differential tests, `benches/incremental.rs`, and `repro
/// planreuse`'s delta section so all three exercise the same mutation
/// model. `fraction` is clamped to `[0, 1]`; at least one row mutates
/// whenever `fraction > 0` and the matrix is non-empty.
pub fn mutate_row_fraction(m: &Csr, fraction: f64, seed: u64) -> Csr {
    let n = m.n_rows;
    if n == 0 || m.n_cols == 0 || fraction <= 0.0 {
        return m.clone();
    }
    let count = ((fraction.min(1.0) * n as f64).ceil() as usize).clamp(1, n);
    let mut rng = crate::util::Pcg32::seeded(seed);
    let mut pick = vec![false; n];
    let mut picked = 0usize;
    while picked < count {
        let r = rng.below_usize(n);
        if !pick[r] {
            pick[r] = true;
            picked += 1;
        }
    }
    let mut rpt = Vec::with_capacity(n + 1);
    rpt.push(0usize);
    let mut col = Vec::with_capacity(m.nnz() + count);
    let mut val = Vec::with_capacity(m.nnz() + count);
    for r in 0..n {
        let (cs, vs) = m.row(r);
        if !pick[r] {
            col.extend_from_slice(cs);
            val.extend_from_slice(vs);
        } else {
            let flip = ((seed.wrapping_add(r as u64)) % m.n_cols as u64) as u32;
            let mut inserted = false;
            for (&c, &v) in cs.iter().zip(vs) {
                if c == flip {
                    inserted = true; // delete: skip the entry
                    continue;
                }
                if !inserted && c > flip {
                    col.push(flip);
                    val.push(1.0);
                    inserted = true;
                }
                col.push(c);
                val.push(v);
            }
            if !inserted {
                col.push(flip);
                val.push(1.0);
            }
        }
        rpt.push(col.len());
    }
    Csr::new_unchecked(n, m.n_cols, rpt, col, val)
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::random_csr;
    use super::*;
    use crate::util::Pcg32;

    fn assert_plans_identical(p: &PlannedProduct, q: &PlannedProduct) {
        let (sp, sq) = (p.symbolic_plan(), q.symbolic_plan());
        assert_eq!(sp.ip, sq.ip, "ip");
        assert_eq!(sp.rpt, sq.rpt, "rpt");
        assert_eq!(sp.accum, sq.accum, "accum kinds");
        assert_eq!(sp.symbolic, sq.symbolic, "symbolic kinds");
        assert_eq!(sp.bins.len(), sq.bins.len(), "bin count");
        for (x, y) in sp.bins.iter().zip(&sq.bins) {
            assert_eq!(x.group, y.group);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.symbolic_kind, y.symbolic_kind);
            assert_eq!(x.rows, y.rows, "bin membership/order");
            assert_eq!(x.weight, y.weight);
        }
    }

    #[test]
    fn patched_plan_is_bit_identical_to_cold() {
        let mut rng = Pcg32::seeded(91);
        let a = random_csr(&mut rng, 250, 220, 0.03);
        let b = random_csr(&mut rng, 220, 200, 0.03);
        let base = PlannedProduct::plan(&a, &b);
        let a2 = mutate_row_fraction(&a, 0.02, 7);
        assert_ne!(a.structure_hash(), a2.structure_hash());
        match delta_patch(&base, &a2, &b, &EngineConfig::default()) {
            DeltaOutcome::Patched(p) => {
                let cold = PlannedProduct::plan(&a2, &b);
                assert_plans_identical(&p.plan, &cold);
                assert_eq!(p.plan.fill(&a2, &b), cold.fill(&a2, &b), "fills must be bit-identical");
                assert!(p.dirty_rows <= 5 + 250 * 2 / 100, "delta must localize: {} rows", p.dirty_rows);
                let d = p.plan.delta().expect("patched plan must carry lineage");
                assert_eq!(d.chain_len, 1);
                assert_eq!(d.base_a_hash, a.structure_hash());
            }
            DeltaOutcome::Rebuild(why) => panic!("small mutation must patch, got rebuild: {why}"),
        }
    }

    #[test]
    fn b_side_mutation_dirties_feeding_rows_only() {
        let mut rng = Pcg32::seeded(13);
        let a = random_csr(&mut rng, 180, 150, 0.02);
        let b = random_csr(&mut rng, 150, 140, 0.03);
        let base = PlannedProduct::plan(&a, &b);
        let b2 = mutate_row_fraction(&b, 0.01, 3);
        match delta_patch(&base, &a, &b2, &EngineConfig::default()) {
            DeltaOutcome::Patched(p) => {
                let cold = PlannedProduct::plan(&a, &b2);
                assert_plans_identical(&p.plan, &cold);
                assert_eq!(p.plan.fill(&a, &b2), cold.fill(&a, &b2));
                // Only rows of A touching the mutated B rows are dirty.
                let dirty_b: Vec<usize> = (0..b.n_rows)
                    .filter(|&r| b.row_structure_hashes()[r] != b2.row_structure_hashes()[r])
                    .collect();
                let expect = (0..a.n_rows)
                    .filter(|&r| a.row(r).0.iter().any(|&c| dirty_b.contains(&(c as usize))))
                    .count();
                assert_eq!(p.dirty_rows, expect, "column-touch rule must be exact");
            }
            DeltaOutcome::Rebuild(why) => panic!("B-side mutation must patch: {why}"),
        }
    }

    #[test]
    fn chains_extend_and_cap_at_rebuild_threshold() {
        let mut rng = Pcg32::seeded(29);
        let mut a = random_csr(&mut rng, 120, 120, 0.05);
        let b = random_csr(&mut rng, 120, 110, 0.05);
        let mut plan = PlannedProduct::plan(&a, &b);
        let root_hash = a.structure_hash();
        for step in 0..MAX_DELTA_CHAIN {
            let a2 = mutate_row_fraction(&a, 0.02, 100 + step as u64);
            match delta_patch(&plan, &a2, &b, &EngineConfig::default()) {
                DeltaOutcome::Patched(p) => {
                    let d = *p.plan.delta().unwrap();
                    assert_eq!(d.chain_len, step + 1);
                    assert_eq!(d.base_a_hash, root_hash, "lineage must point at the cold root");
                    assert_plans_identical(&p.plan, &PlannedProduct::plan(&a2, &b));
                    plan = p.plan;
                    a = a2;
                }
                DeltaOutcome::Rebuild(why) => panic!("step {step} must patch: {why}"),
            }
        }
        let a2 = mutate_row_fraction(&a, 0.02, 999);
        assert!(
            matches!(delta_patch(&plan, &a2, &b, &EngineConfig::default()), DeltaOutcome::Rebuild(_)),
            "chain past MAX_DELTA_CHAIN must force a rebuild"
        );
    }

    #[test]
    fn refuses_unrelated_matrices_and_shape_changes() {
        let mut rng = Pcg32::seeded(5);
        let a = random_csr(&mut rng, 100, 100, 0.04);
        let b = random_csr(&mut rng, 100, 100, 0.04);
        let base = PlannedProduct::plan(&a, &a);
        // An unrelated same-shape matrix is ~all-dirty — Rebuild, so
        // executor paths keep reporting it Fresh.
        let c = random_csr(&mut rng, 100, 100, 0.04);
        assert!(matches!(delta_patch(&base, &c, &c, &EngineConfig::default()), DeltaOutcome::Rebuild(_)));
        // Shape change is refused outright.
        let d = random_csr(&mut rng, 101, 100, 0.04);
        assert!(matches!(delta_patch(&base, &d, &b, &EngineConfig::default()), DeltaOutcome::Rebuild(_)));
    }

    #[test]
    fn lineage_digest_is_coherent_and_tamper_evident() {
        let mut rng = Pcg32::seeded(61);
        let a = random_csr(&mut rng, 90, 90, 0.05);
        let base = PlannedProduct::plan(&a, &a);
        let a2 = mutate_row_fraction(&a, 0.03, 17);
        let DeltaOutcome::Patched(p) = delta_patch(&base, &a2, &a2, &EngineConfig::default()) else {
            panic!("must patch");
        };
        assert!(p.plan.lineage_is_coherent(), "a fresh patch must validate");
        let d = p.plan.delta().unwrap();
        let expect = d.expected_digest(
            a2.structure_hash(),
            a2.structure_hash(),
            a2.row_structure_hashes(),
            a2.row_structure_hashes(),
        );
        assert_eq!(d.digest, expect);
        // Any field flip breaks the digest.
        let mut forged = *d;
        forged.chain_len += 1;
        assert_ne!(
            forged.expected_digest(
                a2.structure_hash(),
                a2.structure_hash(),
                a2.row_structure_hashes(),
                a2.row_structure_hashes(),
            ),
            d.digest
        );
    }

    #[test]
    fn masked_patch_matches_cold_and_mask_change_rebuilds() {
        use super::super::mask::Mask;
        use super::super::multiply;
        let mut rng = Pcg32::seeded(77);
        let a = random_csr(&mut rng, 200, 200, 0.03);
        let b = random_csr(&mut rng, 200, 180, 0.03);
        let mut mc = crate::sparse::Coo::new(a.n_rows, b.n_cols);
        for i in 0..a.n_rows {
            for jj in i.saturating_sub(9)..(i + 10).min(b.n_cols) {
                mc.push(i, jj, 1.0);
            }
        }
        let mask = Mask::from_structure(&mc.to_csr());
        let cfg = EngineConfig { mask: Some(mask.clone()), ..EngineConfig::default() };
        let base = PlannedProduct::plan_cfg(&a, &b, &cfg);
        let a2 = mutate_row_fraction(&a, 0.02, 31);
        match delta_patch(&base, &a2, &b, &cfg) {
            DeltaOutcome::Patched(p) => {
                let cold = PlannedProduct::plan_cfg(&a2, &b, &cfg);
                assert_plans_identical(&p.plan, &cold);
                assert_eq!(p.plan.mask_hash(), Some(mask.structure_hash()));
                assert_eq!(
                    p.plan.fill(&a2, &b),
                    mask.filter(&multiply(&a2, &b)),
                    "masked patch must fill to the multiply-then-filter oracle"
                );
            }
            DeltaOutcome::Rebuild(why) => panic!("masked small mutation must patch: {why}"),
        }
        // Adding, dropping, or swapping the mask invalidates every
        // retained count — only a rebuild is safe.
        assert!(
            matches!(delta_patch(&base, &a2, &b, &EngineConfig::default()), DeltaOutcome::Rebuild("mask changed")),
            "unmasked cfg against a masked base must rebuild"
        );
        let unmasked_base = PlannedProduct::plan(&a, &b);
        assert!(
            matches!(delta_patch(&unmasked_base, &a2, &b, &cfg), DeltaOutcome::Rebuild("mask changed")),
            "masked cfg against an unmasked base must rebuild"
        );
    }

    #[test]
    fn mutate_row_fraction_is_deterministic_and_valid() {
        let mut rng = Pcg32::seeded(8);
        let a = random_csr(&mut rng, 70, 60, 0.05);
        let m1 = mutate_row_fraction(&a, 0.1, 4);
        let m2 = mutate_row_fraction(&a, 0.1, 4);
        assert_eq!(m1, m2, "same seed must give the same mutation");
        assert!(m1.validate().is_ok());
        assert_ne!(m1.structure_hash(), a.structure_hash());
        let changed = (0..a.n_rows)
            .filter(|&r| a.row_structure_hashes()[r] != m1.row_structure_hashes()[r])
            .count();
        assert_eq!(changed, 7, "exactly ceil(0.1·70) rows must change");
        assert_eq!(mutate_row_fraction(&a, 0.0, 4), a, "fraction 0 is the identity");
    }
}
