//! The versioned on-disk tier: one binary file per planned product,
//! keyed by the operand pair's structure fingerprint, so a plan built
//! by one process serves the numeric-only fill path of the next.
//!
//! # Format (`SAPL` v3, little-endian, see `util/serial.rs`)
//!
//! | field | type | notes |
//! |-------|------|-------|
//! | magic | 4 B `b"SAPL"` | SpGEMM-Aia PLan |
//! | version | u32 | currently [`FORMAT_VERSION`]; mismatch ⇒ miss |
//! | a_rows, a_cols, b_rows, b_cols | 4 × u64 | operand shapes |
//! | a_hash, b_hash | 2 × u64 | [`crate::sparse::Csr::structure_hash`] fingerprints |
//! | spa_threshold | f64 bits | knob the row kernels were selected with |
//! | ip | u64-slice | per-row IP bounds; the Table-I grouping is rebuilt from these ([`Grouping::build`] is a pure function of `ip`) |
//! | rpt | u64-slice | exact output row pointers (`n_rows + 1`) |
//! | accum | u8-slice | per-row [`AccumKind`] ordinals |
//! | symbolic | u8-slice | per-row [`SymbolicKind`] ordinals |
//! | bins | u64 count, then per bin: group u8, kind u8, symbolic u8, weight u64, rows u32-slice | the numeric work list |
//! | a_row_hashes, b_row_hashes | 2 × u64-slice | per-row structure hashes (v2: the incremental replanner's diff baseline) |
//! | mask flag | u8 | v3 only: 0 = unmasked plan, 1 = a mask record follows |
//! | mask | n_rows u64, n_cols u64, structure_hash u64, rpt u64-slice, col u32-slice | present iff flag = 1; the output mask a masked plan's exact sizes were counted under ([`crate::spgemm::hash::Mask`]) |
//! | delta flag | u8 | 0 = cold plan, 1 = a lineage record follows |
//! | lineage | base_a_hash u64, base_b_hash u64, chain_len u32, prev_digest u64, digest u64 | present iff flag = 1 ([`crate::spgemm::hash::DeltaLineage`]) |
//! | checksum | u64 | FNV-1a of every preceding byte |
//!
//! v2 files (no mask record) still decode — as unmasked plans, which is
//! exactly what every v2 writer produced; their file names are
//! unchanged too (the mask hash joins the key only when present).
//! v1 files (no row hashes, no lineage) read as a version mismatch —
//! a clean miss that replans and rewrites the entry in v3.
//!
//! # Validation ladder (any failure ⇒ silent miss + replan, never a panic)
//!
//! 1. **checksum** — trailing FNV-1a over the whole body (covers the
//!    magic and version bytes too, so a flipped version byte or any
//!    other bit flip surfaces here) ⇒ [`DiskLoad::Corrupt`];
//! 2. **magic / version** — wrong file type or a future/old format
//!    revision ⇒ [`DiskLoad::Corrupt`];
//! 3. **fingerprint + configuration** — shapes + structure hashes vs
//!    the probe (a key collision or a renamed file), and the persisted
//!    `spa_threshold` vs the process's configured knob (the row-kernel
//!    selection is baked into the plan — a file written under a
//!    different `--spa-threshold` must not override the current run's
//!    configuration) ⇒ [`DiskLoad::Stale`];
//! 4. **delta-chain coherence** — a lineage-carrying plan whose chain
//!    is over-long or whose digest does not reproduce from the plan's
//!    own identity and row hashes
//!    ([`PlannedProduct::lineage_is_coherent`]) ⇒ [`DiskLoad::Stale`]
//!    (the chain is unverifiable, so the entry degrades to a full
//!    replan that rewrites it with a fresh, lineage-free plan);
//! 5. **structural sanity** — truncated payload, out-of-range kind
//!    ordinals, non-monotonic `rpt`, row ids ≥ `n_rows`, row-hash
//!    vectors that disagree with the shapes
//!    ⇒ [`DiskLoad::Corrupt`]. This keeps a decoded plan safe to hand
//!    to `numeric_bin_into`, whose release build skips re-validation.
//!
//! Writes go through a same-directory temp file + rename, so a reader
//! racing a writer sees either the old plan or the new one, not a
//! torn file.

use super::{PlanFingerprint, PlanStore, StoreStats};
use crate::spgemm::hash::engine::{NumericBin, SymbolicPlan};
use crate::spgemm::hash::grouping::{AccumKind, Grouping, SymbolicKind};
use crate::spgemm::hash::mask::Mask;
use crate::spgemm::hash::plan::{DeltaLineage, PlannedProduct};
use crate::util::error::{anyhow, bail, ensure, Result};
use crate::util::serial::{fnv1a, Reader, Writer};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// First four bytes of every plan file.
pub const MAGIC: [u8; 4] = *b"SAPL";
/// Current revision of the on-disk layout. Bump on any layout change;
/// old files then read as a clean miss and are rewritten on the next
/// replan. v2 added the per-row structure hashes and the optional
/// delta lineage record; v3 added the optional output-mask record
/// (v2 files stay loadable, as unmasked plans).
pub const FORMAT_VERSION: u32 = 3;

/// Oldest revision [`decode_plan`] still accepts (v2 bodies are a
/// strict prefix-compatible subset of v3: no mask record).
pub(crate) const MIN_FORMAT_VERSION: u32 = 2;

/// Outcome of probing the disk tier for one fingerprint.
pub enum DiskLoad {
    /// File present, checksum and fingerprint valid: the plan, ready to
    /// fill (its `plan_times` are zero — the loader charges load time).
    Hit(Arc<PlannedProduct>),
    /// File parsed but was built for a different operand pair
    /// (fingerprint mismatch — e.g. a key collision or a moved file).
    Stale,
    /// File unreadable: bad magic/version/checksum, truncated, or
    /// structurally insane payload.
    Corrupt,
    /// No file for this fingerprint.
    Absent,
}

/// Filesystem-backed plan store rooted at one cache directory.
///
/// Loads are `&self` and stateless, so a cheap clone of the store can
/// serve lookups from the batch planner thread; the [`PlanStore`] impl
/// layers hit/miss/corrupt counters on top for standalone use.
#[derive(Clone)]
pub struct DiskStore {
    dir: PathBuf,
    stats: StoreStats,
}

impl DiskStore {
    /// Store rooted at `dir` (created lazily on the first save).
    pub fn new(dir: impl Into<PathBuf>) -> DiskStore {
        DiskStore { dir: dir.into(), stats: StoreStats::default() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Deterministic file path for a fingerprint key (one file per
    /// operand-pair structure).
    pub fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.plan"))
    }

    /// Probe the tier for `fp` (pure — no stats, no writes).
    ///
    /// A parsed plan must match both the operand fingerprint *and* the
    /// process's configured SPA threshold: the per-row kernel selection
    /// is baked into the plan at plan time, so a file persisted under a
    /// different `--spa-threshold` would silently serve the wrong
    /// kernel selection (outputs stay bit-identical, but the knob's
    /// semantics would break across the process boundary). Either
    /// mismatch reads as [`DiskLoad::Stale`] — replanning under the
    /// current threshold rewrites the file.
    pub fn load(&self, fp: &PlanFingerprint) -> DiskLoad {
        let bytes = match std::fs::read(self.path_for(fp.key())) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return DiskLoad::Absent,
            Err(_) => return DiskLoad::Corrupt,
        };
        let configured = crate::spgemm::hash::engine::EngineConfig::default().spa_threshold;
        match decode_plan(&bytes) {
            Ok(p) if !fp.matches(&p) => DiskLoad::Stale,
            Ok(p) if p.symbolic_plan().spa_threshold.to_bits() != configured.to_bits() => DiskLoad::Stale,
            // A delta-patched plan whose chain cannot be re-verified
            // from its own content (forged/mismatched digest, over-long
            // chain) is unusable-but-well-formed: stale, so the replan
            // rewrites the entry with a fresh lineage-free plan.
            Ok(p) if !p.lineage_is_coherent() => DiskLoad::Stale,
            Ok(p) => DiskLoad::Hit(Arc::new(p)),
            Err(_) => DiskLoad::Corrupt,
        }
    }

    /// Persist one plan (pure — no stats). Best-effort: IO failures
    /// return `false` and leave the tier a silent no-op, mirroring the
    /// load side's miss-don't-panic contract.
    pub fn save(&self, plan: &PlannedProduct) -> bool {
        let bytes = encode_plan_with_version(plan, FORMAT_VERSION);
        if std::fs::create_dir_all(&self.dir).is_err() {
            return false;
        }
        let key = plan.key();
        // Same-directory temp + rename: readers never see a torn file.
        // The temp name carries pid *and* a process-wide sequence number,
        // so two same-process threads saving the same key cannot
        // interleave writes into one temp path.
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = self.dir.join(format!(".{key:016x}.tmp{}-{seq}", std::process::id()));
        if std::fs::write(&tmp, &bytes).is_err() {
            return false;
        }
        std::fs::rename(&tmp, self.path_for(key)).is_ok()
    }
}

/// One `.plan` file as the lifecycle tooling (`spgemm-aia plan-cache`)
/// sees it — filesystem facts only; decode facts are a
/// [`PlanSummary`].
#[derive(Clone, Debug)]
pub struct PlanFileInfo {
    pub path: PathBuf,
    /// Store key parsed from the `<key:016x>.plan` file name, `None`
    /// when the name does not follow the store's convention (such a
    /// file can never be probed and is dead weight).
    pub key: Option<u64>,
    pub bytes: u64,
    /// Modification time, when the filesystem reports one — the age
    /// order [`DiskStore::prune`] evicts in.
    pub modified: Option<std::time::SystemTime>,
}

/// Facts decoded from one valid plan file (`plan-cache ls`/`verify`).
#[derive(Clone, Copy, Debug)]
pub struct PlanSummary {
    /// The plan's own pair key — on a healthy file this matches the
    /// key in the file name; a mismatch means the file was renamed and
    /// will only ever read as stale at runtime.
    pub key: u64,
    pub a_shape: (usize, usize),
    pub b_shape: (usize, usize),
    /// Exact output nnz the plan's row pointers promise.
    pub nnz: usize,
    /// Numeric bins in the plan's work list.
    pub bins: usize,
    /// The SPA threshold the plan's row kernels were selected under.
    pub spa_threshold: f64,
    /// Length of the plan's delta-patch chain (0 for a cold plan).
    pub delta_chain: u32,
}

/// What one [`DiskStore::prune`] sweep did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneReport {
    /// Plan files left in the directory.
    pub kept: usize,
    /// Plan files deleted (oldest-modified first).
    pub removed: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

impl DiskStore {
    /// Every `.plan` file under the cache directory, oldest-modified
    /// first (the eviction order [`DiskStore::prune`] uses; files with
    /// unreadable metadata sort first, i.e. evict first). Best-effort:
    /// an unreadable directory is an empty listing, mirroring the
    /// load side's miss-don't-panic contract.
    pub fn entries(&self) -> Vec<PlanFileInfo> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let path = e.path();
                if !path.extension().is_some_and(|x| x == "plan") {
                    continue;
                }
                let key = path.file_stem().and_then(|s| s.to_str()).and_then(|s| u64::from_str_radix(s, 16).ok());
                let meta = e.metadata().ok();
                out.push(PlanFileInfo {
                    key,
                    bytes: meta.as_ref().map(|m| m.len()).unwrap_or(0),
                    modified: meta.and_then(|m| m.modified().ok()),
                    path,
                });
            }
        }
        out.sort_by(|a, b| a.modified.cmp(&b.modified).then_with(|| a.path.cmp(&b.path)));
        out
    }

    /// Run the full validation ladder over one plan file — read,
    /// checksum, magic/version, structural sanity — exactly what a
    /// runtime load would accept, and return the decoded header facts.
    /// Deliberately does *not* compare the persisted SPA threshold to
    /// this process's knob: a file from a differently-configured run is
    /// stale for this process, not damaged, and `plan-cache verify`
    /// must not fail a healthy shared cache over configuration skew.
    pub fn verify_path(path: &Path) -> Result<PlanSummary> {
        let bytes = std::fs::read(path).map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let p = decode_plan(&bytes)?;
        let sp = p.symbolic_plan();
        Ok(PlanSummary {
            key: p.key(),
            a_shape: p.a_shape(),
            b_shape: p.b_shape(),
            nnz: p.nnz(),
            bins: sp.bins.len(),
            spa_threshold: sp.spa_threshold,
            delta_chain: p.delta().map(|d| d.chain_len).unwrap_or(0),
        })
    }

    /// Shrink the cache directory to at most `max_bytes` of plan files
    /// by deleting the oldest-modified first, and sweep any abandoned
    /// writer temp files (a crashed process leaves its `.tmp` behind;
    /// a live writer's rename simply fails afterwards and degrades to
    /// the save path's silent no-op). Best-effort throughout.
    pub fn prune(&self, max_bytes: u64) -> PruneReport {
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.starts_with('.') && name.contains(".tmp") {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        let entries = self.entries();
        let total: u64 = entries.iter().map(|e| e.bytes).sum();
        let mut report =
            PruneReport { kept: entries.len(), removed: 0, bytes_before: total, bytes_after: total };
        for e in &entries {
            if report.bytes_after <= max_bytes {
                break;
            }
            if std::fs::remove_file(&e.path).is_ok() {
                report.removed += 1;
                report.kept -= 1;
                report.bytes_after -= e.bytes;
            }
        }
        report
    }
}

impl PlanStore for DiskStore {
    fn get(&mut self, fp: &PlanFingerprint) -> Option<Arc<PlannedProduct>> {
        match self.load(fp) {
            DiskLoad::Hit(p) => {
                self.stats.disk_hits += 1;
                Some(p)
            }
            DiskLoad::Stale => {
                self.stats.stale += 1;
                self.stats.misses += 1;
                None
            }
            DiskLoad::Corrupt => {
                self.stats.corrupt += 1;
                self.stats.misses += 1;
                None
            }
            DiskLoad::Absent => {
                self.stats.misses += 1;
                None
            }
        }
    }

    fn put(&mut self, plan: Arc<PlannedProduct>) {
        if self.save(&plan) {
            self.stats.stores += 1;
        }
    }

    /// Plan files currently in the cache directory.
    fn len(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().is_some_and(|x| x == "plan"))
                    .count()
            })
            .unwrap_or(0)
    }

    /// Delete every plan file under the cache directory (best effort).
    fn clear(&mut self) {
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                if e.path().extension().is_some_and(|x| x == "plan") {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
    }

    fn stats(&self) -> StoreStats {
        self.stats
    }
}

/// Serialize one plan into the v-`version` byte layout (the version
/// parameter exists so tests can fabricate future-revision files with
/// valid checksums).
pub(crate) fn encode_plan_with_version(plan: &PlannedProduct, version: u32) -> Vec<u8> {
    let sp = plan.symbolic_plan();
    let mut w = Writer::new();
    w.put_bytes(&MAGIC);
    w.put_u32(version);
    let (ar, ac) = plan.a_shape();
    let (br, bc) = plan.b_shape();
    w.put_usize(ar);
    w.put_usize(ac);
    w.put_usize(br);
    w.put_usize(bc);
    w.put_u64(plan.a_hash());
    w.put_u64(plan.b_hash());
    w.put_f64(sp.spa_threshold);
    w.put_u64_slice(&sp.ip);
    w.put_usize_slice(&sp.rpt);
    let accum: Vec<u8> = sp.accum.iter().map(|k| k.index() as u8).collect();
    w.put_u8_slice(&accum);
    let symbolic: Vec<u8> = sp.symbolic.iter().map(|k| k.index() as u8).collect();
    w.put_u8_slice(&symbolic);
    w.put_usize(sp.bins.len());
    for bin in &sp.bins {
        w.put_u8(bin.group);
        w.put_u8(bin.kind.index() as u8);
        w.put_u8(bin.symbolic_kind.index() as u8);
        w.put_u64(bin.weight);
        w.put_u32_slice(&bin.rows);
    }
    w.put_u64_slice(plan.a_row_hashes());
    w.put_u64_slice(plan.b_row_hashes());
    if version >= 3 {
        // Mask record before the delta record so the lineage digest
        // stays the last 8 body bytes (forged-digest test relies on it).
        match sp.mask.as_ref() {
            None => w.put_u8(0),
            Some(m) => {
                w.put_u8(1);
                w.put_usize(m.n_rows());
                w.put_usize(m.n_cols());
                w.put_u64(m.structure_hash());
                w.put_usize_slice(m.rpt());
                w.put_u32_slice(m.col());
            }
        }
    }
    match plan.delta() {
        None => w.put_u8(0),
        Some(d) => {
            w.put_u8(1);
            w.put_u64(d.base_a_hash);
            w.put_u64(d.base_b_hash);
            w.put_u32(d.chain_len);
            w.put_u64(d.prev_digest);
            w.put_u64(d.digest);
        }
    }
    let sum = fnv1a(w.bytes());
    w.put_u64(sum);
    w.into_bytes()
}

/// Parse and structurally validate one plan file body. Errors on any
/// corruption; the *fingerprint* decision (hit vs stale) is the
/// caller's, via [`PlanFingerprint::matches`] on the result.
pub(crate) fn decode_plan(bytes: &[u8]) -> Result<PlannedProduct> {
    ensure!(bytes.len() > 8, "file shorter than its checksum trailer");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let declared = u64::from_le_bytes(tail.try_into().expect("8-byte split"));
    ensure!(fnv1a(body) == declared, "checksum mismatch");
    let mut r = Reader::new(body);
    ensure!(r.take(4)? == &MAGIC[..], "bad magic");
    let version = r.get_u32()?;
    ensure!(
        (MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version),
        "format version {version} outside {MIN_FORMAT_VERSION}..={FORMAT_VERSION}"
    );
    let a_shape = (r.get_usize()?, r.get_usize()?);
    let b_shape = (r.get_usize()?, r.get_usize()?);
    let a_hash = r.get_u64()?;
    let b_hash = r.get_u64()?;
    let spa_threshold = r.get_f64()?;
    let ip = r.get_u64_vec()?;
    let n_rows = ip.len();
    ensure!(n_rows == a_shape.0, "ip rows {n_rows} != A rows {}", a_shape.0);
    let rpt = r.get_usize_vec()?;
    ensure!(rpt.len() == n_rows + 1, "rpt len {} != rows+1 {}", rpt.len(), n_rows + 1);
    ensure!(rpt.first() == Some(&0), "rpt[0] must be 0");
    for w in rpt.windows(2) {
        ensure!(w[0] <= w[1], "rpt not monotonic");
    }
    let accum = decode_kinds(&r.get_u8_vec()?, n_rows, AccumKind::from_index, AccumKind::ALL.len())?;
    let symbolic = decode_kinds(&r.get_u8_vec()?, n_rows, SymbolicKind::from_index, SymbolicKind::ALL.len())?;
    let n_bins = r.get_usize()?;
    let mut bins = Vec::new();
    for _ in 0..n_bins {
        let group = r.get_u8()?;
        ensure!((group as usize) < 4, "bin group {group} out of range");
        let kind_ix = r.get_u8()? as usize;
        ensure!(kind_ix < AccumKind::ALL.len(), "bin accumulator ordinal {kind_ix} out of range");
        let sym_ix = r.get_u8()? as usize;
        ensure!(sym_ix < SymbolicKind::ALL.len(), "bin symbolic ordinal {sym_ix} out of range");
        let weight = r.get_u64()?;
        let rows = r.get_u32_vec()?;
        for &row in &rows {
            ensure!((row as usize) < n_rows, "bin row {row} out of range {n_rows}");
        }
        bins.push(NumericBin {
            group,
            kind: AccumKind::from_index(kind_ix),
            symbolic_kind: SymbolicKind::from_index(sym_ix),
            rows,
            weight,
        });
    }
    let a_row_hashes = r.get_u64_vec()?;
    ensure!(a_row_hashes.len() == a_shape.0, "A row-hash len {} != A rows {}", a_row_hashes.len(), a_shape.0);
    let b_row_hashes = r.get_u64_vec()?;
    ensure!(b_row_hashes.len() == b_shape.0, "B row-hash len {} != B rows {}", b_row_hashes.len(), b_shape.0);
    let mask = if version >= 3 {
        match r.get_u8()? {
            0 => None,
            1 => {
                let m_rows = r.get_usize()?;
                let m_cols = r.get_usize()?;
                ensure!(m_rows == a_shape.0, "mask rows {m_rows} != A rows {}", a_shape.0);
                ensure!(m_cols == b_shape.1, "mask cols {m_cols} != B cols {}", b_shape.1);
                let declared_hash = r.get_u64()?;
                let m_rpt = r.get_usize_vec()?;
                ensure!(m_rpt.len() == m_rows + 1, "mask rpt len {} != rows+1 {}", m_rpt.len(), m_rows + 1);
                ensure!(m_rpt.first() == Some(&0), "mask rpt[0] must be 0");
                for w in m_rpt.windows(2) {
                    ensure!(w[0] <= w[1], "mask rpt not monotonic");
                }
                let m_col = r.get_u32_vec()?;
                ensure!(m_rpt.last() == Some(&m_col.len()), "mask rpt end {} != col len {}", m_rpt.last().copied().unwrap_or(0), m_col.len());
                for row in 0..m_rows {
                    let slice = &m_col[m_rpt[row]..m_rpt[row + 1]];
                    for w in slice.windows(2) {
                        ensure!(w[0] < w[1], "mask row {row} columns not strictly sorted");
                    }
                    for &c in slice {
                        ensure!((c as usize) < m_cols, "mask col {c} out of range {m_cols}");
                    }
                }
                let m = Mask::from_parts(m_rows, m_cols, m_rpt, m_col);
                // `from_parts` recomputes the structure hash, so the
                // stored one is a pure integrity check on the record.
                ensure!(m.structure_hash() == declared_hash, "mask structure hash mismatch");
                Some(m)
            }
            flag => bail!("mask flag {flag} out of range"),
        }
    } else {
        None // v2 writers never had masks; their plans are unmasked.
    };
    let delta = match r.get_u8()? {
        0 => None,
        1 => Some(DeltaLineage {
            base_a_hash: r.get_u64()?,
            base_b_hash: r.get_u64()?,
            chain_len: r.get_u32()?,
            prev_digest: r.get_u64()?,
            digest: r.get_u64()?,
        }),
        flag => bail!("delta flag {flag} out of range"),
    };
    ensure!(r.is_done(), "trailing bytes after the delta record");
    // The Table-I grouping is a pure function of the IP bounds — rebuilt
    // rather than stored (smaller files, one representation to corrupt).
    let grouping = Grouping::build(&ip);
    let plan = SymbolicPlan { ip, grouping, rpt, accum, symbolic, bins, spa_threshold, mask };
    Ok(PlannedProduct::from_parts(plan, a_shape, b_shape, a_hash, b_hash, a_row_hashes, b_row_hashes, delta))
}

/// Decode a per-row kind array from its ordinal bytes, rejecting
/// out-of-range ordinals (the enums' `from_index` panics — corrupt
/// input must error instead).
fn decode_kinds<K>(bytes: &[u8], n_rows: usize, from_index: fn(usize) -> K, n_kinds: usize) -> Result<Vec<K>> {
    ensure!(bytes.len() == n_rows, "kind array len {} != rows {n_rows}", bytes.len());
    let mut out = Vec::with_capacity(n_rows);
    for &b in bytes {
        if (b as usize) >= n_kinds {
            bail!("kind ordinal {b} out of range {n_kinds}");
        }
        out.push(from_index(b as usize));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;
    use crate::util::Pcg32;

    fn unique_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spgemm-aia-diskstore-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn random_plan(seed: u64, n: usize) -> (Csr, PlannedProduct) {
        let mut rng = Pcg32::seeded(seed);
        let a = crate::gen::rmat(n, n * 5, crate::gen::RmatParams::uniform(), &mut rng);
        let p = PlannedProduct::plan(&a, &a);
        (a, p)
    }

    #[test]
    fn encode_decode_roundtrip_is_exact() {
        let (a, p) = random_plan(3, 128);
        let bytes = encode_plan_with_version(&p, FORMAT_VERSION);
        let q = decode_plan(&bytes).expect("roundtrip decode");
        assert!(q.matches(&a, &a));
        assert_eq!(q.nnz(), p.nnz());
        assert_eq!(q.symbolic_plan().rpt, p.symbolic_plan().rpt);
        assert_eq!(q.symbolic_plan().ip, p.symbolic_plan().ip);
        assert_eq!(q.symbolic_plan().bins.len(), p.symbolic_plan().bins.len());
        assert_eq!(q.symbolic_plan().spa_threshold.to_bits(), p.symbolic_plan().spa_threshold.to_bits());
        // Loaded plans report zero plan-time seconds — the loader
        // charges its own load+validate wall time instead.
        assert_eq!(q.plan_times.total_s(), 0.0);
        // And the fill is bit-identical to the original plan's.
        assert_eq!(q.fill(&a, &a), p.fill(&a, &a));
    }

    #[test]
    fn store_and_load_through_the_trait() {
        let dir = unique_dir("trait");
        let mut s = DiskStore::new(&dir);
        let (a, p) = random_plan(5, 96);
        let fp = PlanFingerprint::of(&a, &a);
        assert!(s.get(&fp).is_none(), "empty directory misses");
        s.put(Arc::new(p));
        assert_eq!(s.len(), 1);
        let q = s.get(&fp).expect("persisted plan must load");
        assert_eq!(q.fill(&a, &a), crate::spgemm::hash::multiply(&a, &a));
        assert_eq!((s.stats().disk_hits, s.stats().misses, s.stats().stores), (1, 1, 1));
        s.clear();
        assert_eq!(s.len(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn every_truncation_point_decodes_to_an_error() {
        let (_, p) = random_plan(7, 64);
        let bytes = encode_plan_with_version(&p, FORMAT_VERSION);
        for cut in 0..bytes.len() {
            assert!(decode_plan(&bytes[..cut]).is_err(), "truncation at {cut} must fail cleanly");
        }
        assert!(decode_plan(&bytes).is_ok());
    }

    #[test]
    fn any_flipped_byte_fails_the_checksum() {
        let (_, p) = random_plan(9, 64);
        let bytes = encode_plan_with_version(&p, FORMAT_VERSION);
        // Flip a sample of bytes across the file, version field included.
        for pos in [0usize, 4, 5, 20, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(decode_plan(&bad).is_err(), "flip at {pos} must fail");
        }
    }

    #[test]
    fn foreign_threshold_is_stale_not_served() {
        let dir = unique_dir("threshold");
        let mut rng = Pcg32::seeded(13);
        let a = crate::gen::rmat(96, 96 * 5, crate::gen::RmatParams::uniform(), &mut rng);
        // A knob guaranteed to differ from whatever this process runs at.
        let foreign = crate::spgemm::hash::default_spa_threshold() + 1.0;
        let cfg = crate::spgemm::hash::engine::EngineConfig {
            spa_threshold: foreign,
            symbolic_threshold: None,
            planner: crate::spgemm::hash::PlannerPolicy::Exact,
            mask: None,
        };
        let mut s = DiskStore::new(&dir);
        s.put(Arc::new(PlannedProduct::plan_cfg(&a, &a, &cfg)));
        let fp = PlanFingerprint::of(&a, &a);
        assert!(s.get(&fp).is_none(), "a plan selected under a foreign threshold must not load");
        assert_eq!(s.stats().stale, 1, "threshold mismatch is stale, not corrupt");
        // Rewriting under the process default heals the entry.
        s.put(Arc::new(PlannedProduct::plan(&a, &a)));
        assert!(s.get(&fp).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lifecycle_entries_verify_and_prune() {
        let dir = unique_dir("lifecycle");
        let s = DiskStore::new(&dir);
        for seed in [31, 32, 33] {
            let (_, p) = random_plan(seed, 64 + seed as usize);
            assert!(s.save(&p));
        }
        let entries = s.entries();
        assert_eq!(entries.len(), 3);
        let total: u64 = entries.iter().map(|e| e.bytes).sum();
        for e in &entries {
            assert!(e.bytes > 0);
            let summary = DiskStore::verify_path(&e.path).expect("freshly saved file must verify");
            assert_eq!(Some(summary.key), e.key, "file name key must match the plan's own key");
            assert_eq!(summary.a_shape.0, summary.b_shape.0);
        }
        // Corrupt one file in place: verify must now error on it.
        let victim = &entries[0].path;
        let mut bytes = std::fs::read(victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(victim, &bytes).unwrap();
        assert!(DiskStore::verify_path(victim).is_err(), "flipped byte must fail verify");
        // An abandoned writer temp file gets swept by prune...
        std::fs::write(dir.join(".deadbeef.tmp999-0"), b"junk").unwrap();
        // ...and pruning to roughly one file's budget deletes oldest-first.
        let keep = entries.last().unwrap().bytes;
        let r = s.prune(keep);
        assert_eq!(r.bytes_before, total);
        assert!(r.bytes_after <= keep.max(entries.iter().map(|e| e.bytes).max().unwrap()));
        assert_eq!(r.kept + r.removed, 3);
        assert!(r.removed >= 2, "a one-file budget must evict the other two");
        assert_eq!(s.entries().len(), r.kept);
        assert!(!dir.join(".deadbeef.tmp999-0").exists(), "prune sweeps abandoned temp files");
        // Pruning to zero empties the directory of plans.
        let r = s.prune(0);
        assert_eq!((r.kept, r.bytes_after), (0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn delta_patched_plan_roundtrips_and_forged_digest_is_stale() {
        use crate::spgemm::hash::engine::EngineConfig;
        use crate::spgemm::hash::incremental::{delta_patch, mutate_row_fraction, DeltaOutcome};
        let dir = unique_dir("delta");
        let mut s = DiskStore::new(&dir);
        let (a, base) = random_plan(17, 128);
        let a2 = mutate_row_fraction(&a, 0.01, 99);
        let patched = match delta_patch(&base, &a2, &a2, &EngineConfig::default()) {
            DeltaOutcome::Patched(p) => p.plan,
            DeltaOutcome::Rebuild(why) => panic!("small mutation must patch, got rebuild: {why}"),
        };
        assert!(patched.delta().is_some());
        let fp = PlanFingerprint::of(&a2, &a2);
        s.put(Arc::new(patched));
        let q = s.get(&fp).expect("delta-patched plan must round-trip through disk");
        let d = q.delta().expect("lineage must survive serialization");
        assert_eq!(d.chain_len, 1);
        assert!(q.lineage_is_coherent());
        assert_eq!(q.fill(&a2, &a2), crate::spgemm::hash::multiply(&a2, &a2));
        // Forge the lineage digest in place and re-seal the checksum:
        // the file is well-formed but its chain no longer re-verifies,
        // so it must read as stale (silent full replan), not corrupt.
        let path = s.path_for(fp.key());
        let mut bytes = std::fs::read(&path).unwrap();
        let body_len = bytes.len() - 8;
        bytes[body_len - 8] ^= 0x01; // digest is the last lineage field
        let sum = fnv1a(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&sum);
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(s.load(&fp), DiskLoad::Stale), "forged digest must be stale, not corrupt");
        assert!(s.get(&fp).is_none());
        // A full replan heals the entry with a lineage-free plan.
        s.put(Arc::new(PlannedProduct::plan(&a2, &a2)));
        let healed = s.get(&fp).expect("rewritten entry must load");
        assert!(healed.delta().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn future_version_with_valid_checksum_is_a_miss() {
        let (_, p) = random_plan(11, 64);
        let bytes = encode_plan_with_version(&p, FORMAT_VERSION + 1);
        assert!(decode_plan(&bytes).is_err(), "unknown format revision must not parse");
    }

    #[test]
    fn v2_bytes_still_load_as_an_unmasked_plan() {
        let (a, p) = random_plan(23, 64);
        assert!(p.symbolic_plan().mask.is_none());
        // Fabricate a true v2 file: the encoder gates the mask record
        // on the requested version, so these bytes match what every
        // pre-mask writer produced.
        let bytes = encode_plan_with_version(&p, 2);
        let q = decode_plan(&bytes).expect("v2 layout must stay readable");
        assert!(q.symbolic_plan().mask.is_none());
        assert_eq!(q.mask_hash(), None);
        assert!(q.matches(&a, &a));
        assert_eq!(q.fill(&a, &a), crate::spgemm::hash::multiply(&a, &a));
    }

    #[test]
    fn masked_plan_roundtrips_and_serves_only_the_masked_fingerprint() {
        use crate::spgemm::hash::engine::EngineConfig;
        use crate::spgemm::hash::Mask;
        let dir = unique_dir("masked");
        let mut s = DiskStore::new(&dir);
        let (a, _) = random_plan(21, 96);
        let mask = Mask::from_structure(&a);
        let cfg = EngineConfig { mask: Some(mask.clone()), ..EngineConfig::default() };
        let masked_fp = PlanFingerprint::of_masked(&a, &a, &mask);
        let plain_fp = PlanFingerprint::of(&a, &a);
        assert_ne!(masked_fp.key(), plain_fp.key(), "mask hash must join the file name");
        s.put(Arc::new(PlannedProduct::plan_cfg(&a, &a, &cfg)));
        assert!(s.get(&plain_fp).is_none(), "a masked plan must not serve the unmasked fingerprint");
        let q = s.get(&masked_fp).expect("masked plan must round-trip through disk");
        assert_eq!(q.mask_hash(), Some(mask.structure_hash()));
        assert_eq!(
            q.fill(&a, &a),
            mask.filter(&crate::spgemm::hash::multiply(&a, &a)),
            "decoded masked plan must fill to the multiply-then-filter oracle"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
