//! Tiered plan store (ROADMAP "Plan persistence"): the plan cache as a
//! subsystem instead of a `HashMap` welded into the batch executor.
//!
//! The symbolic phase is a pure function of the operands' *structure*,
//! so its output survives not just across iterations (in-memory plan
//! reuse, PR 2) but across **process lifetimes**: a CLI run that planned
//! `A²` for a generated dataset can leave the plan on disk, and the next
//! run on the same dataset skips straight to the numeric fill. Liu &
//! Vinter (arXiv:1504.05022) and OCEAN (arXiv:2604.19004) both identify
//! the upper-bound/estimation analysis as the dominant non-numeric cost
//! worth amortizing — persistence extends that amortization to every
//! future process.
//!
//! Three pieces:
//!
//! - [`PlanStore`] — the trait: fingerprint-keyed `get`/`put` of
//!   `Arc<PlannedProduct>`s plus hit/miss/evict/corrupt counters
//!   ([`StoreStats`]).
//! - [`MemStore`] / [`DiskStore`] — the tiers. `MemStore` is the
//!   bounded structure-keyed map that used to live in `BatchExecutor`;
//!   `DiskStore` is the versioned binary format (`disk.rs` documents
//!   the layout and its validation ladder — stale fingerprint, version
//!   mismatch, or truncated file all degrade to a silent miss + replan,
//!   never a panic).
//! - [`TieredStore`] — the `mem → disk` composition every consumer
//!   holds: lookups try memory first, then load-validate-or-replan
//!   through disk (disk hits are promoted to the memory tier); fresh
//!   plans are written through to both tiers.
//!
//! Consumers: [`crate::coordinator::batch::BatchExecutor`] (including
//! its planner thread, via [`TieredStore::snapshot`]),
//! [`crate::coordinator::executor::SpgemmExecutor::multiply_reusing`]
//! on slot misses, and through those MCL, GNN training, and the
//! `repro planreuse` experiment. The CLI's `--plan-cache DIR` (env
//! `SPGEMM_AIA_PLAN_CACHE`) selects the process-default disk tier —
//! see [`default_plan_cache_dir`].

mod disk;
mod mem;

pub use disk::{DiskLoad, DiskStore, PlanFileInfo, PlanSummary, PruneReport, FORMAT_VERSION};
pub use mem::{MemStore, DEFAULT_MEM_CAP};

use super::mask::Mask;
use super::plan::{pair_key_from_hashes, PlannedProduct};
use crate::sparse::Csr;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Structure identity of one `A·B` product: operand shapes plus their
/// [`Csr::structure_hash`] fingerprints. This is the store key *and*
/// the validation record — every tier re-checks the full fingerprint on
/// lookup, so a key collision (or a renamed plan file) degrades to a
/// miss rather than serving a wrong plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanFingerprint {
    pub a_shape: (usize, usize),
    pub b_shape: (usize, usize),
    pub a_hash: u64,
    pub b_hash: u64,
    /// Structure hash of the output mask, for masked products
    /// (`C = M ⊙ (A·B)`); `None` for plain products. Part of the
    /// identity: a masked plan's sizes are masked exact counts, so it
    /// must never be served for a different (or no) mask.
    pub mask_hash: Option<u64>,
}

impl PlanFingerprint {
    /// Fingerprint of an operand pair. The structure hashes are
    /// memoized on the matrices, so repeated fingerprinting of the same
    /// operands is a cell read, not an O(nnz) scan.
    pub fn of(a: &Csr, b: &Csr) -> PlanFingerprint {
        PlanFingerprint {
            a_shape: (a.n_rows, a.n_cols),
            b_shape: (b.n_rows, b.n_cols),
            a_hash: a.structure_hash(),
            b_hash: b.structure_hash(),
            mask_hash: None,
        }
    }

    /// Fingerprint of a masked product `M ⊙ (a·b)`.
    pub fn of_masked(a: &Csr, b: &Csr, mask: &Mask) -> PlanFingerprint {
        PlanFingerprint { mask_hash: Some(mask.structure_hash()), ..PlanFingerprint::of(a, b) }
    }

    /// 64-bit store key (order-sensitive combination of both hashes —
    /// the same key [`PlannedProduct::key`] reports for its plan).
    /// Masked fingerprints fold the mask hash in as a second round, so
    /// unmasked keys — and with them every v2 plan-file name on disk —
    /// are unchanged.
    pub fn key(&self) -> u64 {
        let k = pair_key_from_hashes(self.a_hash, self.b_hash);
        match self.mask_hash {
            None => k,
            Some(mh) => pair_key_from_hashes(k, mh),
        }
    }

    /// Full-fingerprint validation against a candidate plan, mask
    /// identity included.
    pub fn matches(&self, p: &PlannedProduct) -> bool {
        p.matches_fingerprint(self.a_shape, self.b_shape, self.a_hash, self.b_hash)
            && p.mask_hash() == self.mask_hash
    }
}

/// Counters every [`PlanStore`] reports. Tier naming: `mem_hits` /
/// `disk_hits` split where a hit was served; `stale` and `corrupt`
/// sub-classify disk misses (fingerprint/configuration mismatch vs
/// unreadable file); `evictions` counts memory-tier capacity
/// evictions; `stores` counts successful writes to the
/// implementation's *persistent* tier — a standalone [`MemStore`]
/// counts every insert, while [`TieredStore`] counts disk
/// write-throughs only (0 without a disk tier: memory-tier population
/// is visible through `len`, not `stores`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub mem_hits: u64,
    pub disk_hits: u64,
    pub misses: u64,
    pub stores: u64,
    pub evictions: u64,
    pub corrupt: u64,
    pub stale: u64,
    /// Plans produced by patching a cached predecessor
    /// ([`crate::spgemm::hash::incremental`]) instead of a full replan.
    /// A patch is **neither a hit nor a miss**: the store did not serve
    /// the requested fingerprint (so counting it a hit would inflate
    /// `hits()`), but real — partial — symbolic work ran (so counting
    /// it a miss would double-charge it against the lookup that already
    /// recorded the miss). It is excluded from [`StoreStats::hits`] and
    /// from every consumer hit rate, pinned by regression tests.
    pub delta_patches: u64,
}

impl StoreStats {
    /// Hits across all tiers (`delta_patches` excluded — a patch served
    /// new symbolic work, not a cached plan).
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }

    /// Accumulate another counter set (tier composition / batch tallies).
    pub fn merge(&mut self, o: &StoreStats) {
        self.mem_hits += o.mem_hits;
        self.disk_hits += o.disk_hits;
        self.misses += o.misses;
        self.stores += o.stores;
        self.evictions += o.evictions;
        self.corrupt += o.corrupt;
        self.stale += o.stale;
        self.delta_patches += o.delta_patches;
    }
}

/// A fingerprint-keyed cache of planned products. `get` must validate
/// the full fingerprint (never trust the key alone), `put` must be
/// best-effort (an unwritable tier degrades to a smaller cache, not an
/// error), and implementations keep their own [`StoreStats`].
pub trait PlanStore {
    fn get(&mut self, fp: &PlanFingerprint) -> Option<Arc<PlannedProduct>>;
    fn put(&mut self, plan: Arc<PlannedProduct>);
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn clear(&mut self);
    fn stats(&self) -> StoreStats;
}

/// Where a [`TieredStore::get_traced`] lookup was resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GetOutcome {
    MemHit,
    DiskHit,
    /// Nothing served; the flags say whether the disk tier saw an
    /// unreadable file or a fingerprint mismatch on the way.
    Miss { corrupt: bool, stale: bool },
}

/// The `mem → disk` composition. Disk is optional — [`TieredStore::mem_only`]
/// reproduces the pre-persistence behavior exactly.
///
/// The store is a shared *handle*: the tiers and their counters live
/// behind an `Arc<Mutex<..>>`, and **cloning shares them** rather than
/// copying. That is what lets one resident store back every executor
/// and client session of the serve daemon ([`crate::serve`]) — a plan
/// built for one session's operands is a memory hit for every other
/// session, and `serve.plan_hit_rate` is a property of the store, not
/// of whichever executor happened to build the plan. Constructors
/// (`mem_only`/`with_disk`/`process_default`) still mint *independent*
/// stores, so existing per-test and per-CLI-run isolation is unchanged.
///
/// Locking: every operation takes the mutex for its whole duration,
/// including disk-tier I/O on `get_traced`/`admit` — lookups and
/// write-throughs are serialized, which is exactly the coherence the
/// daemon wants. Latency-sensitive planner threads avoid the lock via
/// [`TieredStore::snapshot`] (unchanged: an `Arc`-cloned view).
#[derive(Clone)]
pub struct TieredStore {
    inner: Arc<Mutex<TieredInner>>,
}

/// The actual tiers, behind [`TieredStore`]'s mutex.
struct TieredInner {
    mem: MemStore,
    disk: Option<DiskStore>,
    stats: StoreStats,
}

impl Default for TieredStore {
    /// [`TieredStore::process_default`].
    fn default() -> TieredStore {
        TieredStore::process_default()
    }
}

impl TieredStore {
    fn from_tiers(mem: MemStore, disk: Option<DiskStore>) -> TieredStore {
        TieredStore { inner: Arc::new(Mutex::new(TieredInner { mem, disk, stats: StoreStats::default() })) }
    }

    /// Lock the tiers. A panic elsewhere can only have abandoned whole
    /// operations (tiers mutate by whole-value inserts, never partial
    /// writes), so a poisoned lock is recovered, not propagated — the
    /// daemon must not brick its plan cache because one request died.
    fn lock(&self) -> MutexGuard<'_, TieredInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Memory tier only (no persistence).
    pub fn mem_only() -> TieredStore {
        TieredStore::from_tiers(MemStore::default(), None)
    }

    /// Memory tier backed by a disk tier rooted at `dir`.
    pub fn with_disk(dir: impl Into<PathBuf>) -> TieredStore {
        TieredStore::from_tiers(MemStore::default(), Some(DiskStore::new(dir)))
    }

    /// A *fresh* store configured the way the process was: disk-backed
    /// when `--plan-cache` / `SPGEMM_AIA_PLAN_CACHE` named a directory
    /// ([`default_plan_cache_dir`]), memory-only otherwise. Each call
    /// mints an independent store (shared residency is opt-in, via
    /// `clone` of one handle).
    pub fn process_default() -> TieredStore {
        match default_plan_cache_dir() {
            Some(dir) => TieredStore::with_disk(dir),
            None => TieredStore::mem_only(),
        }
    }

    /// The disk tier's directory, if one is attached (owned: the path
    /// must outlive the lock guard).
    pub fn disk_dir(&self) -> Option<PathBuf> {
        self.lock().disk.as_ref().map(|d| d.dir().to_path_buf())
    }

    /// [`PlanStore::get`] plus *where* the lookup resolved. Disk hits
    /// are promoted into the memory tier, so the next lookup of the
    /// same structure is a map probe. `&self`: safe from any holder of
    /// a shared handle.
    pub fn get_traced(&self, fp: &PlanFingerprint) -> (Option<Arc<PlannedProduct>>, GetOutcome) {
        let mut g = self.lock();
        if let Some(p) = g.mem.lookup(fp) {
            g.stats.mem_hits += 1;
            return (Some(p), GetOutcome::MemHit);
        }
        let (mut corrupt, mut stale) = (false, false);
        if let Some(disk) = &g.disk {
            match disk.load(fp) {
                DiskLoad::Hit(p) => {
                    g.stats.disk_hits += 1;
                    if g.mem.insert(Arc::clone(&p)) {
                        g.stats.evictions += 1;
                    }
                    return (Some(p), GetOutcome::DiskHit);
                }
                DiskLoad::Corrupt => {
                    g.stats.corrupt += 1;
                    corrupt = true;
                }
                DiskLoad::Stale => {
                    g.stats.stale += 1;
                    stale = true;
                }
                DiskLoad::Absent => {}
            }
        }
        g.stats.misses += 1;
        (None, GetOutcome::Miss { corrupt, stale })
    }

    /// Insert a plan into the memory tier, writing through to disk only
    /// when `to_disk` (freshly built plans persist; plans just loaded
    /// *from* disk are promoted without being rewritten).
    ///
    /// A delta-patched plan whose lineage does not validate
    /// ([`PlannedProduct::lineage_is_coherent`]) is refused outright —
    /// the caller keeps its (still correct) plan, but an unverifiable
    /// chain never enters either tier.
    pub fn admit(&self, plan: Arc<PlannedProduct>, to_disk: bool) {
        if !plan.lineage_is_coherent() {
            return;
        }
        let mut g = self.lock();
        if to_disk {
            if let Some(disk) = &g.disk {
                if disk.save(&plan) {
                    g.stats.stores += 1;
                }
            }
        }
        if g.mem.insert(plan) {
            g.stats.evictions += 1;
        }
    }

    /// Fold outcome counters observed outside `get`/`put` (the batch
    /// planner thread resolves against a [`TieredStore::snapshot`] and
    /// reports what happened here) into this store's [`StoreStats`].
    pub fn tally(&self, outcomes: &StoreStats) {
        self.lock().stats.merge(outcomes);
    }

    /// Record one delta patch by *reclassifying* the miss the preceding
    /// lookup counted (see [`StoreStats::delta_patches`]): the caller
    /// probed this store, missed, and then patched a predecessor plan
    /// instead of fully replanning — so the product ends up as neither
    /// a hit nor a miss. Callers that resolved against a
    /// [`TieredStore::snapshot`] (no miss was counted here) report
    /// patches through [`TieredStore::tally`] instead.
    pub fn note_delta_patch(&self) {
        let mut g = self.lock();
        g.stats.misses = g.stats.misses.saturating_sub(1);
        g.stats.delta_patches += 1;
    }

    /// Probe the memory tier by raw store key, with **no stats side
    /// effects** — the delta planner fetching a *predecessor* plan for
    /// an operand pair that already missed is bookkeeping, not a second
    /// cache query.
    pub fn peek_key(&self, key: u64) -> Option<Arc<PlannedProduct>> {
        self.lock().mem.peek_key(key)
    }

    /// Immutable view for a planner thread: an `Arc`-cloned copy of the
    /// memory tier plus a stateless handle on the disk tier. Lookups
    /// are pure; the caller reports outcomes back via
    /// [`TieredStore::tally`] and inserts via [`TieredStore::admit`].
    pub fn snapshot(&self) -> StoreSnapshot {
        let g = self.lock();
        StoreSnapshot { mem: g.mem.snapshot_map(), disk: g.disk.as_ref().map(|d| DiskStore::new(d.dir())) }
    }
}

impl PlanStore for TieredStore {
    fn get(&mut self, fp: &PlanFingerprint) -> Option<Arc<PlannedProduct>> {
        self.get_traced(fp).0
    }

    fn put(&mut self, plan: Arc<PlannedProduct>) {
        self.admit(plan, true);
    }

    /// Plans in the *memory* tier (the bounded working set; the disk
    /// tier is unbounded and only consulted on memory misses).
    fn len(&self) -> usize {
        self.lock().mem.len()
    }

    /// Drop the memory tier. Disk files are left in place: they are
    /// fingerprint-validated on every load, so a stale file can only
    /// ever cost a read, never a wrong result.
    fn clear(&mut self) {
        self.lock().mem.clear();
    }

    fn stats(&self) -> StoreStats {
        self.lock().stats
    }
}

/// Read-only view of a [`TieredStore`] for lock-free planner-thread
/// lookups (see [`TieredStore::snapshot`]).
pub struct StoreSnapshot {
    mem: HashMap<u64, Arc<PlannedProduct>>,
    disk: Option<DiskStore>,
}

impl StoreSnapshot {
    /// Fingerprint-validated lookup, memory tier first, then disk —
    /// the pure counterpart of [`TieredStore::get_traced`], with the
    /// same `(plan, outcome)` shape (no stats, no promotion; the
    /// caller reports outcomes back via [`TieredStore::tally`]).
    pub fn lookup(&self, fp: &PlanFingerprint) -> (Option<Arc<PlannedProduct>>, GetOutcome) {
        if let Some(p) = self.mem.get(&fp.key()).filter(|p| fp.matches(p)) {
            return (Some(Arc::clone(p)), GetOutcome::MemHit);
        }
        match self.disk.as_ref().map(|d| d.load(fp)) {
            Some(DiskLoad::Hit(p)) => (Some(p), GetOutcome::DiskHit),
            Some(DiskLoad::Corrupt) => (None, GetOutcome::Miss { corrupt: true, stale: false }),
            Some(DiskLoad::Stale) => (None, GetOutcome::Miss { corrupt: false, stale: true }),
            Some(DiskLoad::Absent) | None => (None, GetOutcome::Miss { corrupt: false, stale: false }),
        }
    }

    /// Raw memory-tier key probe (the planner thread's predecessor
    /// fetch for the delta path) — pure, like [`StoreSnapshot::lookup`].
    pub fn peek_key(&self, key: u64) -> Option<Arc<PlannedProduct>> {
        self.mem.get(&key).map(Arc::clone)
    }
}

static PLAN_CACHE_DIR_CELL: OnceLock<Option<PathBuf>> = OnceLock::new();

/// Set the process-wide plan-cache directory (the CLI's `--plan-cache`
/// knob). Returns `false` if the default was already read or set — call
/// once, at startup, before the first executor is built.
pub fn set_default_plan_cache_dir(dir: PathBuf) -> bool {
    PLAN_CACHE_DIR_CELL.set(Some(dir)).is_ok()
}

/// The process-wide plan-cache directory: the value set by
/// [`set_default_plan_cache_dir`], else the `SPGEMM_AIA_PLAN_CACHE` env
/// var, else `None` (no disk tier — plans live and die with the
/// process). Empty env values are treated as unset.
pub fn default_plan_cache_dir() -> Option<PathBuf> {
    PLAN_CACHE_DIR_CELL
        .get_or_init(|| {
            std::env::var_os("SPGEMM_AIA_PLAN_CACHE")
                .filter(|v| !v.is_empty())
                .map(PathBuf::from)
        })
        .clone()
}

/// Like [`default_plan_cache_dir`] but *without* latching the cell: if
/// the flag was set, that wins; otherwise the env var is read fresh and
/// the cell stays writable. The threshold ladder uses this to look for
/// `calibration.json` next to the plan cache — resolving a threshold
/// must not steal the one-shot `--plan-cache` slot from a later
/// [`set_default_plan_cache_dir`] call.
pub(crate) fn peek_plan_cache_dir() -> Option<PathBuf> {
    match PLAN_CACHE_DIR_CELL.get() {
        Some(v) => v.clone(),
        None => std::env::var_os("SPGEMM_AIA_PLAN_CACHE")
            .filter(|v| !v.is_empty())
            .map(PathBuf::from),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn unique_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spgemm-aia-tiered-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn random_square(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg32::seeded(seed);
        crate::gen::rmat(n, n * 4, crate::gen::RmatParams::uniform(), &mut rng)
    }

    #[test]
    fn fingerprint_key_matches_plan_key() {
        let a = random_square(1, 64);
        let fp = PlanFingerprint::of(&a, &a);
        let p = PlannedProduct::plan(&a, &a);
        assert_eq!(fp.key(), p.key());
        assert!(fp.matches(&p));
        let b = random_square(2, 64);
        assert!(!PlanFingerprint::of(&b, &b).matches(&p));
    }

    #[test]
    fn masked_fingerprint_is_a_distinct_identity() {
        use crate::spgemm::hash::engine::EngineConfig;
        use crate::spgemm::hash::mask::Mask;
        let a = random_square(11, 64);
        let mask = Mask::from_structure(&a);
        let plain = PlanFingerprint::of(&a, &a);
        let masked = PlanFingerprint::of_masked(&a, &a, &mask);
        assert_ne!(plain.key(), masked.key(), "mask hash must join the store key");
        assert_eq!(masked.mask_hash, Some(a.structure_hash()));
        // A masked plan matches only the masked fingerprint, and both
        // key derivations agree on it.
        let cfg = EngineConfig { mask: Some(mask), ..EngineConfig::default() };
        let p = PlannedProduct::plan_cfg(&a, &a, &cfg);
        assert!(masked.matches(&p));
        assert!(!plain.matches(&p), "an unmasked lookup must never serve a masked plan");
        assert_eq!(p.key(), masked.key());
        // And the store keeps the two identities apart.
        let s = TieredStore::mem_only();
        s.admit(Arc::new(p), false);
        assert!(s.get_traced(&masked).0.is_some());
        assert!(s.get_traced(&plain).0.is_none());
    }

    #[test]
    fn tiered_promotes_disk_hits_to_mem() {
        let dir = unique_dir("promote");
        let a = random_square(3, 96);
        let fp = PlanFingerprint::of(&a, &a);
        // Writer "process": build and persist.
        let mut writer = TieredStore::with_disk(&dir);
        writer.put(Arc::new(PlannedProduct::plan(&a, &a)));
        assert_eq!(writer.stats().stores, 1);
        // Reader "process": cold memory tier, warm disk.
        let reader = TieredStore::with_disk(&dir);
        let (p, how) = reader.get_traced(&fp);
        assert!(p.is_some());
        assert_eq!(how, GetOutcome::DiskHit);
        // Promoted: second lookup is a memory hit.
        let (_, how2) = reader.get_traced(&fp);
        assert_eq!(how2, GetOutcome::MemHit);
        assert_eq!((reader.stats().disk_hits, reader.stats().mem_hits), (1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mem_only_store_misses_cold() {
        let a = random_square(4, 64);
        let s = TieredStore::mem_only();
        let (p, how) = s.get_traced(&PlanFingerprint::of(&a, &a));
        assert!(p.is_none());
        assert_eq!(how, GetOutcome::Miss { corrupt: false, stale: false });
        assert_eq!(s.stats().misses, 1);
        assert!(s.disk_dir().is_none());
    }

    #[test]
    fn snapshot_lookup_agrees_with_store() {
        let dir = unique_dir("snapshot");
        let a = random_square(5, 96);
        let b = random_square(6, 96);
        let mut s = TieredStore::with_disk(&dir);
        s.put(Arc::new(PlannedProduct::plan(&a, &a)));
        let snap = s.snapshot();
        let (hit, how) = snap.lookup(&PlanFingerprint::of(&a, &a));
        assert!(hit.is_some());
        assert_eq!(how, GetOutcome::MemHit);
        let (miss, how) = snap.lookup(&PlanFingerprint::of(&b, &b));
        assert!(miss.is_none());
        assert_eq!(how, GetOutcome::Miss { corrupt: false, stale: false });
        // A fresh store's snapshot sees only the disk tier.
        let cold = TieredStore::with_disk(&dir).snapshot();
        let (hit, how) = cold.lookup(&PlanFingerprint::of(&a, &a));
        assert!(hit.is_some());
        assert_eq!(how, GetOutcome::DiskHit);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clones_share_tiers_and_counters() {
        // A cloned handle is the *same* store: a plan admitted through
        // one clone is a memory hit through the other, and the counters
        // are one set — the property the serve daemon's shared
        // residency is built on.
        let a = random_square(8, 64);
        let fp = PlanFingerprint::of(&a, &a);
        let s = TieredStore::mem_only();
        let t = s.clone();
        s.admit(Arc::new(PlannedProduct::plan(&a, &a)), false);
        let (p, how) = t.get_traced(&fp);
        assert!(p.is_some(), "clone must see the original's plan");
        assert_eq!(how, GetOutcome::MemHit);
        assert_eq!(s.stats().mem_hits, 1, "counters are shared, not per-clone");
        // And misses observed through the clone land in the same stats.
        let b = random_square(9, 64);
        let _ = t.get_traced(&PlanFingerprint::of(&b, &b));
        assert_eq!((s.stats().mem_hits, s.stats().misses), (1, 1));
    }

    /// Satellite regression: a delta-patched plan counts as **neither**
    /// a `mem_hit` nor a `miss` in [`StoreStats`] — `note_delta_patch`
    /// reclassifies the lookup's miss, `hits()` excludes the counter,
    /// `merge` carries it, and `admit` refuses a chain that does not
    /// re-verify from the plan's own content.
    #[test]
    fn delta_patches_are_neither_hits_nor_misses() {
        use crate::spgemm::hash::engine::{EngineConfig, SymbolicPlan};
        use crate::spgemm::hash::grouping::Grouping;
        use crate::spgemm::hash::{delta_patch, mutate_row_fraction, DeltaOutcome};
        let a = random_square(10, 128);
        let s = TieredStore::mem_only();
        let base = Arc::new(PlannedProduct::plan(&a, &a));
        s.admit(Arc::clone(&base), false);
        let a2 = mutate_row_fraction(&a, 0.02, 3);
        let fp2 = PlanFingerprint::of(&a2, &a2);
        // The consumer's sequence: probe (miss), patch, reclassify, admit.
        let (found, _) = s.get_traced(&fp2);
        assert!(found.is_none());
        assert_eq!(s.stats().misses, 1);
        let patched = match delta_patch(&base, &a2, &a2, &EngineConfig::default()) {
            DeltaOutcome::Patched(p) => Arc::new(p.plan),
            DeltaOutcome::Rebuild(why) => panic!("small mutation must patch, got rebuild: {why}"),
        };
        s.note_delta_patch();
        s.admit(Arc::clone(&patched), false);
        let st = s.stats();
        assert_eq!((st.mem_hits, st.misses, st.delta_patches), (0, 0, 1), "a patch is neither hit nor miss");
        assert_eq!(st.hits(), 0, "hits() must exclude delta patches");
        let mut folded = StoreStats::default();
        folded.merge(&st);
        assert_eq!(folded.delta_patches, 1, "merge must carry the counter");
        // The admitted patch is a normal citizen afterwards.
        assert!(s.get_traced(&fp2).0.is_some());
        assert_eq!(s.stats().hits(), 1);
        // An unverifiable chain is refused by admit: same plan content,
        // one flipped digest bit.
        let sp = patched.symbolic_plan();
        let forged_sp = SymbolicPlan {
            ip: sp.ip.clone(),
            grouping: Grouping::build(&sp.ip),
            rpt: sp.rpt.clone(),
            accum: sp.accum.clone(),
            symbolic: sp.symbolic.clone(),
            bins: sp.bins.clone(),
            spa_threshold: sp.spa_threshold,
            mask: sp.mask.clone(),
        };
        let mut lineage = *patched.delta().expect("patched plan carries lineage");
        lineage.digest ^= 1;
        let forged = PlannedProduct::from_parts(
            forged_sp,
            patched.a_shape(),
            patched.b_shape(),
            patched.a_hash(),
            patched.b_hash(),
            patched.a_row_hashes().to_vec(),
            patched.b_row_hashes().to_vec(),
            Some(lineage),
        );
        assert!(!forged.lineage_is_coherent());
        s.admit(Arc::new(forged), false);
        let served = s.get_traced(&fp2).0.expect("the coherent plan must still be served");
        assert!(served.lineage_is_coherent(), "admit must refuse an unverifiable chain");
    }

    #[test]
    fn clear_keeps_disk_files() {
        let dir = unique_dir("clear");
        let a = random_square(7, 64);
        let mut s = TieredStore::with_disk(&dir);
        s.put(Arc::new(PlannedProduct::plan(&a, &a)));
        assert_eq!(s.len(), 1);
        s.clear();
        assert_eq!(s.len(), 0, "memory tier dropped");
        let (p, how) = s.get_traced(&PlanFingerprint::of(&a, &a));
        assert!(p.is_some(), "disk tier survives an invalidate");
        assert_eq!(how, GetOutcome::DiskHit);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
