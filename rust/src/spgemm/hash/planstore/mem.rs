//! The in-memory tier: the structure-keyed `HashMap` of `Arc`-shared
//! plans that used to live inside `coordinator::batch::BatchExecutor`,
//! now behind the [`PlanStore`] trait so it composes with the disk tier.

use super::{PlanFingerprint, PlanStore, StoreStats};
use crate::spgemm::hash::plan::PlannedProduct;
use std::collections::HashMap;
use std::sync::Arc;

/// Plans kept before arbitrary eviction kicks in (iterative workloads
/// cycle over a handful of structures; the cap only bounds pathological
/// callers).
pub const DEFAULT_MEM_CAP: usize = 32;

/// Bounded in-memory plan cache, keyed by [`PlanFingerprint::key`] and
/// fingerprint-validated on every lookup (a key collision must degrade
/// to a miss, never serve a wrong plan).
pub struct MemStore {
    cap: usize,
    map: HashMap<u64, Arc<PlannedProduct>>,
    stats: StoreStats,
}

impl Default for MemStore {
    fn default() -> MemStore {
        MemStore::new(DEFAULT_MEM_CAP)
    }
}

impl MemStore {
    /// A store holding at most `cap` plans (arbitrary eviction at the cap).
    pub fn new(cap: usize) -> MemStore {
        assert!(cap > 0, "a zero-capacity plan cache is a typo, not a policy");
        MemStore { cap, map: HashMap::new(), stats: StoreStats::default() }
    }

    /// Fingerprint-validated lookup with no stats side effects — the
    /// composing [`super::TieredStore`] keeps one coherent counter set
    /// instead of double-counting per tier.
    pub(crate) fn lookup(&self, fp: &PlanFingerprint) -> Option<Arc<PlannedProduct>> {
        self.map.get(&fp.key()).filter(|p| fp.matches(p)).map(Arc::clone)
    }

    /// Insert without stats; returns `true` if an unrelated entry was
    /// evicted to make room.
    pub(crate) fn insert(&mut self, plan: Arc<PlannedProduct>) -> bool {
        let key = plan.key();
        let mut evicted = false;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            if let Some(k) = self.map.keys().next().copied() {
                self.map.remove(&k);
                evicted = true;
            }
        }
        self.map.insert(key, plan);
        evicted
    }

    /// Raw key probe without fingerprint validation or stats — the
    /// delta planner's predecessor fetch (the caller re-derives validity
    /// from the plan's own shapes and row hashes).
    pub(crate) fn peek_key(&self, key: u64) -> Option<Arc<PlannedProduct>> {
        self.map.get(&key).map(Arc::clone)
    }

    /// Read-only clone of the map for lock-free planner-thread lookups
    /// (`Arc` clones — plans are shared, not copied).
    pub(crate) fn snapshot_map(&self) -> HashMap<u64, Arc<PlannedProduct>> {
        self.map.clone()
    }
}

impl PlanStore for MemStore {
    fn get(&mut self, fp: &PlanFingerprint) -> Option<Arc<PlannedProduct>> {
        match self.lookup(fp) {
            Some(p) => {
                self.stats.mem_hits += 1;
                Some(p)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    fn put(&mut self, plan: Arc<PlannedProduct>) {
        if self.insert(plan) {
            self.stats.evictions += 1;
        }
        self.stats.stores += 1;
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn clear(&mut self) {
        self.map.clear();
    }

    fn stats(&self) -> StoreStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn plan_of(n: usize) -> Arc<PlannedProduct> {
        let a = Csr::identity(n);
        Arc::new(PlannedProduct::plan(&a, &a))
    }

    #[test]
    fn hit_miss_and_eviction_counters() {
        let mut s = MemStore::new(2);
        let a = Csr::identity(4);
        let fp = PlanFingerprint::of(&a, &a);
        assert!(s.get(&fp).is_none());
        s.put(plan_of(4));
        let got = s.get(&fp).expect("stored plan must hit");
        assert_eq!(got.nnz(), 4);
        assert_eq!((s.stats().mem_hits, s.stats().misses, s.stats().stores), (1, 1, 1));
        // Two more distinct structures overflow the cap of 2.
        s.put(plan_of(5));
        s.put(plan_of(6));
        assert_eq!(s.len(), 2);
        assert_eq!(s.stats().evictions, 1);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn lookup_rejects_fingerprint_mismatch() {
        // Same key slot, different structure: forced by inserting under
        // a's key but probing with b's fingerprint — absent key → miss;
        // the validation path is exercised by the tiered/disk tests.
        let mut s = MemStore::default();
        s.put(plan_of(4));
        let b = Csr::identity(5);
        assert!(s.get(&PlanFingerprint::of(&b, &b)).is_none());
    }
}
