//! First-class mask support for masked SpGEMM: `C = M ⊙ (A·B)`.
//!
//! [`Mask`] is a cheap, shareable *structure-only* view over a
//! [`Csr`]: per-row sorted column sets plus the matrix's memoized
//! structure hash. The engine threads it through
//! [`EngineConfig`](super::EngineConfig) so the symbolic phase counts
//! only mask-admitted columns and the numeric phase never materializes
//! a rejected entry (DESIGN.md §2i). Because admitted columns keep the
//! exact B-stream encounter order the unmasked kernels use, the masked
//! product is bit-identical to the multiply-then-filter oracle
//! ([`Mask::filter`]) — pinned by `tests/masked.rs`.
//!
//! The mask's structure hash joins the plan fingerprint
//! ([`PlanFingerprint`](super::PlanFingerprint)), so masked plans
//! cache, persist (SAPL v3), delta-patch, and serve like any other
//! plan; an unmasked product's key is untouched, which is what keeps
//! v2 plan files loadable.
//!
//! Two probing idioms, chosen per row kernel:
//!
//! - [`Mask::admits`] — binary search on the sorted mask row; right
//!   for trivial/scaled-copy rows with a handful of candidates.
//! - [`MaskRowProbe`] — a stamped dense bitmap seeded once per output
//!   row (O(mask-row nnz)), then O(1) membership per candidate; right
//!   for hash/bitmap/SPA rows that stream many candidates. The stamp
//!   generation makes `clear` free, exactly like the symbolic
//!   `RowCounter`.

use crate::sparse::Csr;
use std::sync::Arc;

/// Shared immutable mask payload ([`Mask`] is a cheap `Arc` clone so a
/// mask can ride inside configs, plans, and serve jobs without copying
/// its column sets).
#[derive(Debug)]
struct MaskData {
    n_rows: usize,
    n_cols: usize,
    rpt: Vec<usize>,
    col: Vec<u32>,
    structure_hash: u64,
}

/// Structure-only view of a [`Csr`] used as the `M` in
/// `C = M ⊙ (A·B)`. Rows are sorted column sets; equality and the
/// plan-key contribution are by shape + structure hash.
#[derive(Clone, Debug)]
pub struct Mask(Arc<MaskData>);

impl Mask {
    /// Snapshot a matrix's *structure* as a mask (values ignored).
    /// The hash is the matrix's own memoized [`Csr::structure_hash`],
    /// so `Mask::from_structure(&a)` and a plan fingerprinted against
    /// `a`'s structure agree by construction.
    pub fn from_structure(m: &Csr) -> Mask {
        debug_assert!(
            (0..m.n_rows).all(|i| m.row(i).0.windows(2).all(|w| w[0] < w[1])),
            "mask rows must be strictly sorted column sets"
        );
        Mask(Arc::new(MaskData {
            n_rows: m.n_rows,
            n_cols: m.n_cols,
            rpt: m.rpt.clone(),
            col: m.col.clone(),
            structure_hash: m.structure_hash(),
        }))
    }

    /// Rebuild a mask from raw structure parts (the SAPL v3 decode
    /// path). The structure hash is *recomputed* through the same
    /// [`Csr::structure_hash`] the live path uses, so a decoded mask
    /// can never disagree with a freshly built one.
    pub fn from_parts(n_rows: usize, n_cols: usize, rpt: Vec<usize>, col: Vec<u32>) -> Mask {
        let vals = vec![1.0; col.len()];
        let csr = Csr::new_unchecked(n_rows, n_cols, rpt, col, vals);
        Mask::from_structure(&csr)
    }

    pub fn n_rows(&self) -> usize {
        self.0.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.0.n_cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.0.n_rows, self.0.n_cols)
    }

    /// Admitted entries across the whole mask.
    pub fn nnz(&self) -> usize {
        self.0.col.len()
    }

    /// The sorted admitted-column set of one output row.
    pub fn row(&self, i: usize) -> &[u32] {
        &self.0.col[self.0.rpt[i]..self.0.rpt[i + 1]]
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.0.rpt[i + 1] - self.0.rpt[i]
    }

    /// Row-pointer array (SAPL v3 encode).
    pub fn rpt(&self) -> &[usize] {
        &self.0.rpt
    }

    /// Concatenated column array (SAPL v3 encode).
    pub fn col(&self) -> &[u32] {
        &self.0.col
    }

    /// Structure hash — the mask's contribution to the plan key.
    pub fn structure_hash(&self) -> u64 {
        self.0.structure_hash
    }

    /// O(log row-nnz) membership test on one row's sorted column set.
    pub fn admits(&self, row: usize, col: u32) -> bool {
        self.row(row).binary_search(&col).is_ok()
    }

    /// Multiply-then-filter oracle: keep exactly the entries of `c`
    /// the mask admits (order preserved, values untouched). The masked
    /// engine must be bit-identical to `mask.filter(&multiply(a, b))`.
    pub fn filter(&self, c: &Csr) -> Csr {
        assert_eq!(
            (c.n_rows, c.n_cols),
            self.shape(),
            "mask shape must match the matrix it filters"
        );
        let mut rpt = Vec::with_capacity(c.n_rows + 1);
        let mut col = Vec::new();
        let mut val = Vec::new();
        rpt.push(0);
        for i in 0..c.n_rows {
            let (cols, vals) = c.row(i);
            for (&cc, &vv) in cols.iter().zip(vals) {
                if self.admits(i, cc) {
                    col.push(cc);
                    val.push(vv);
                }
            }
            rpt.push(col.len());
        }
        Csr::new_unchecked(c.n_rows, c.n_cols, rpt, col, val)
    }
}

impl PartialEq for Mask {
    /// Structural equality by shape + structure hash — the same notion
    /// the plan fingerprint uses, so two equal masks always share plan
    /// cache entries.
    fn eq(&self, other: &Mask) -> bool {
        self.shape() == other.shape() && self.structure_hash() == other.structure_hash()
    }
}

/// Config/plan-level mask identity: `None` vs `Some(hash)`, mixed into
/// plan keys only when present so unmasked keys (and their on-disk
/// file names) are byte-for-byte what v2 produced.
pub fn mask_hash_of(mask: &Option<Mask>) -> Option<u64> {
    mask.as_ref().map(Mask::structure_hash)
}

/// Stamped dense membership bitmap over one mask row: seed once per
/// output row, then O(1) [`MaskRowProbe::admits`] per streamed
/// candidate. `width` is the output column count; reseeding bumps a
/// generation instead of clearing, so per-row setup is O(mask-row
/// nnz), never O(n_cols).
pub struct MaskRowProbe {
    stamp: Vec<u32>,
    generation: u32,
}

impl MaskRowProbe {
    pub fn new(width: usize) -> MaskRowProbe {
        MaskRowProbe { stamp: vec![0; width], generation: 0 }
    }

    pub fn width(&self) -> usize {
        self.stamp.len()
    }

    /// Load one mask row's column set (O(row nnz); previous rows'
    /// stamps are invalidated by the generation bump).
    pub fn seed(&mut self, row: &[u32]) {
        if self.generation == u32::MAX {
            self.stamp.fill(0);
            self.generation = 0;
        }
        self.generation += 1;
        for &c in row {
            self.stamp[c as usize] = self.generation;
        }
    }

    /// Membership in the most recently seeded row.
    pub fn admits(&self, col: u32) -> bool {
        self.stamp[col as usize] == self.generation
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::Pcg32;

    fn small() -> Csr {
        let mut rng = Pcg32::seeded(7);
        gen::rmat(64, 256, gen::RmatParams::uniform(), &mut rng)
    }

    #[test]
    fn mask_views_structure_and_admits() {
        let m = small();
        let mask = Mask::from_structure(&m);
        assert_eq!(mask.shape(), (m.n_rows, m.n_cols));
        assert_eq!(mask.nnz(), m.nnz());
        assert_eq!(mask.structure_hash(), m.structure_hash());
        for i in 0..m.n_rows {
            assert_eq!(mask.row(i), m.row(i).0);
            for &c in m.row(i).0 {
                assert!(mask.admits(i, c));
            }
        }
        // A column absent from row 0 must be rejected.
        let absent = (0..m.n_cols as u32).find(|c| !m.row(0).0.contains(c)).unwrap();
        assert!(!mask.admits(0, absent));
    }

    #[test]
    fn from_parts_agrees_with_from_structure() {
        let m = small();
        let a = Mask::from_structure(&m);
        let b = Mask::from_parts(m.n_rows, m.n_cols, m.rpt.clone(), m.col.clone());
        assert_eq!(a, b);
        assert_eq!(a.structure_hash(), b.structure_hash());
    }

    #[test]
    fn equality_is_structural_not_pointer() {
        let m = small();
        let a = Mask::from_structure(&m);
        let mut m2 = m.clone();
        m2.map_values(|v| v * 3.0);
        // Same structure, different values: equal masks.
        assert_eq!(a, Mask::from_structure(&m2));
        assert_ne!(a, Mask::from_structure(&Csr::identity(m.n_rows)));
        assert_eq!(mask_hash_of(&Some(a.clone())), Some(a.structure_hash()));
        assert_eq!(mask_hash_of(&None), None);
    }

    #[test]
    fn filter_keeps_exactly_admitted_entries() {
        let m = small();
        let self_mask = Mask::from_structure(&m);
        assert_eq!(self_mask.filter(&m), m, "a matrix filtered by its own structure is unchanged");
        let none = Mask::from_structure(&Csr::zeros(m.n_rows, m.n_cols));
        assert_eq!(none.filter(&m).nnz(), 0);
        let diag = Mask::from_structure(&Csr::identity(m.n_rows));
        let kept = diag.filter(&m);
        for i in 0..m.n_rows {
            let (cols, _) = kept.row(i);
            assert!(cols.iter().all(|&c| c as usize == i), "identity mask keeps only the diagonal");
        }
    }

    #[test]
    fn probe_tracks_generations() {
        let mut p = MaskRowProbe::new(16);
        p.seed(&[1, 5, 9]);
        assert!(p.admits(1) && p.admits(5) && p.admits(9));
        assert!(!p.admits(0) && !p.admits(15));
        p.seed(&[2]);
        assert!(p.admits(2), "new row admitted");
        assert!(!p.admits(5), "old row invalidated without clearing");
        assert_eq!(p.width(), 16);
    }
}
