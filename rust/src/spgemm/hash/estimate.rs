//! Estimation-based speculative planning for cold one-shot products
//! (ROADMAP "Estimation-based planning for one-shot products", after
//! OCEAN — PAPERS.md, arxiv 2604.19004).
//!
//! The exact symbolic phase is worth amortising across repeated
//! products (the plan store and delta replanner do exactly that), but
//! for a *cold, single-shot* multiply its cost is pure overhead: the
//! plan is built, used once, and thrown away. This module replaces it
//! with a sampled estimate:
//!
//! 1. **Sample** a deterministic subset of A's rows (Pcg32, fixed
//!    seed) and count their output sizes *exactly* with the same
//!    group-3 counting kernel a cold plan would run.
//! 2. **Extrapolate** one compression ratio `Σ exact / Σ IP` over the
//!    sample and estimate every unsampled row as `clamp(IP · ratio)`;
//!    sampled rows keep their exact counts for free.
//! 3. **Plan speculatively**: the estimates flow through the *same*
//!    kernel-selection and bin-construction code as an exact plan
//!    ([`select_symbolic`] + [`build_bins`]), producing a
//!    [`SymbolicPlan`]-shaped plan whose `rpt` is a guess and whose
//!    hash tables are sized `estimate × slack`.
//! 4. **Execute with a fallback ladder**: the speculative numeric
//!    driver ([`multiply_estimated`]) detects an underestimate *per
//!    row* — a hash table crossing 50 % load — and retries that row
//!    from scratch at double the capacity until it fits, counting it
//!    in [`EstimateReport::fallback_rows`]. Scaled-copy rows are
//!    estimate-independent (the output *is* the scaled B row) and SPA
//!    rows are dense and cannot overflow, so only hash rows ever
//!    fall back.
//!
//! **Only sizing and kernel choice are speculative — never values.**
//! Per-column accumulation order is the B-stream encounter order at
//! any table capacity (each unique column owns one slot; capacity only
//! permutes *slot positions*, which the final sort over unique keys
//! canonicalises), so a grown retry is bit-identical to a right-sized
//! first attempt, and the whole estimated pipeline is bit-identical to
//! the exact engine. `tests/estimated_plan.rs` pins this with
//! adversarial estimator injection (forced 0.1×/10×/0× estimates)
//! through [`multiply_estimated_injected`].
//!
//! Speculative plans are **never persisted**: their `rpt` is a guess,
//! and the [`super::planstore`] disk format round-trips plans other
//! processes will trust as exact. The policy layer
//! ([`PlannerPolicy`]) therefore only speculates on fully-cold
//! one-shot calls — store hits, batch/iterative products, and delta
//! patches stay exact end to end.

use super::engine::{accum_row_spa, symbolic_row_nnz_hash};
use super::engine::{build_bins, effective_thresholds, EngineConfig, SymbolicPlan};
use super::grouping::{global_table_size, select_symbolic, AccumKind, Grouping, GROUP_SPECS};
use super::table::{DenseAccumulator, HashTable, TableLoc};
use crate::sim::probe::NullProbe;
use crate::spgemm::ip::intermediate_products;
use crate::sparse::Csr;
use crate::util::Pcg32;
use std::sync::OnceLock;
use std::time::Instant;

/// Which symbolic planner a call site runs (`--planner`, threaded
/// through [`EngineConfig::planner`] and the coordinator/serve
/// layers).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlannerPolicy {
    /// Always run the exact symbolic phase (the pre-PR-8 behaviour).
    #[default]
    Exact,
    /// Speculate on cold one-shot products: sampled estimates size the
    /// plan, the numeric phase grows-and-retries underestimated rows.
    /// Store hits, batch products, and delta patches stay exact.
    Estimated,
    /// Let each call site decide: identical to `Estimated` today —
    /// speculation is already restricted to cold one-shot calls — but
    /// reserved for measurement-driven crossover selection.
    Auto,
}

impl PlannerPolicy {
    /// Parse a `--planner` / `SPGEMM_AIA_PLANNER` value.
    pub fn parse(s: &str) -> Option<PlannerPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Some(PlannerPolicy::Exact),
            "estimated" | "estimate" | "est" => Some(PlannerPolicy::Estimated),
            "auto" => Some(PlannerPolicy::Auto),
            _ => None,
        }
    }

    /// Stable lowercase name (CLI/JSON vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            PlannerPolicy::Exact => "exact",
            PlannerPolicy::Estimated => "estimated",
            PlannerPolicy::Auto => "auto",
        }
    }

    /// Whether a *cold one-shot* product should use the estimated
    /// planner under this policy. Store-backed, batch, and delta paths
    /// ignore this — they are exact under every policy.
    pub fn speculates(self) -> bool {
        matches!(self, PlannerPolicy::Estimated | PlannerPolicy::Auto)
    }
}

/// Process-default planner policy, set once (same latching knob shape
/// as `set_default_spa_threshold`): first writer wins, first *reader*
/// freezes the `SPGEMM_AIA_PLANNER` fallback.
static PLANNER_CELL: OnceLock<PlannerPolicy> = OnceLock::new();

/// Install the process-default [`PlannerPolicy`] (the CLI's
/// `--planner` flag). Returns `false` if the default was already
/// latched by an earlier set or read.
pub fn set_default_planner_policy(p: PlannerPolicy) -> bool {
    PLANNER_CELL.set(p).is_ok()
}

/// The process-default [`PlannerPolicy`]: whatever
/// [`set_default_planner_policy`] installed, else `SPGEMM_AIA_PLANNER`
/// (unparsable values are ignored), else [`PlannerPolicy::Exact`].
pub fn default_planner_policy() -> PlannerPolicy {
    *PLANNER_CELL.get_or_init(|| {
        std::env::var("SPGEMM_AIA_PLANNER")
            .ok()
            .and_then(|s| PlannerPolicy::parse(&s))
            .unwrap_or(PlannerPolicy::Exact)
    })
}

/// Knobs of the sampled estimator. The defaults keep the estimate
/// cheap (a few % of rows counted exactly) with enough slack that
/// honest estimates rarely fall back; the adversarial harness
/// overrides the estimates themselves, not these knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EstimateParams {
    /// Fraction of A's rows counted exactly (clamped to `min_samples`
    /// from below and the row count from above).
    pub sample_fraction: f64,
    /// Sample at least this many rows (small matrices are effectively
    /// counted exactly — the estimate degenerates gracefully).
    pub min_samples: usize,
    /// Hash tables are sized `estimate × slack` (then rounded to the
    /// usual ≤ 50 %-load power of two): headroom against per-row
    /// variance around the global compression ratio.
    pub slack: f64,
    /// Seed of the deterministic sampling PRNG — same inputs, same
    /// sample, same plan.
    pub seed: u64,
}

impl Default for EstimateParams {
    fn default() -> Self {
        EstimateParams { sample_fraction: 0.02, min_samples: 64, slack: 1.5, seed: 0x0CEA }
    }
}

/// What the estimated pipeline did — the speculative counterpart of
/// the exact engine's `PhaseTimes`, surfaced through executor/serve
/// metrics (`estimate_s`, `fallback_rows`) and `repro planreuse`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EstimateReport {
    /// Seconds spent sampling + extrapolating + building the
    /// speculative plan (replaces the exact `grouping_s +
    /// symbolic_s`).
    pub estimate_s: f64,
    /// Seconds spent in the speculative numeric driver, retries
    /// included.
    pub numeric_s: f64,
    /// Rows whose hash table crossed 50 % load and re-ran at a grown
    /// capacity (0 when every estimate was sufficient).
    pub fallback_rows: usize,
    /// Rows counted exactly by the sampler.
    pub sampled_rows: usize,
    /// The speculative plan's total size guess (`plan.nnz()`), kept
    /// for over/under-shoot reporting against `nnz`.
    pub estimated_nnz: usize,
    /// Exact nnz of the output actually produced.
    pub nnz: usize,
}

/// Test-only estimator override: `(row, default_estimate) → estimate`,
/// applied after sampling/extrapolation with the raw return value
/// trusted verbatim (0 allowed). This is the adversarial-injection
/// hook — production call sites never pass one.
pub type EstimateInjector<'a> = &'a dyn Fn(usize, u64) -> u64;

/// Build a speculative [`SymbolicPlan`] from sampled estimates at the
/// default config/params. The plan is shaped exactly like an exact
/// one — same grouping, same kernel-selection rules, same bin
/// construction — but `rpt` holds estimates, so it must only ever be
/// executed by [`multiply_estimated`]'s fallback-aware driver (the
/// exact `numeric()` hard-asserts `rpt` against the buffers it sizes)
/// and must never reach the plan store.
pub fn estimate_plan(a: &Csr, b: &Csr) -> SymbolicPlan {
    estimate_plan_with(a, b, &EngineConfig::default(), &EstimateParams::default(), None).0
}

/// [`estimate_plan`] with explicit config/params; returns the sampled
/// row count alongside the plan.
pub fn estimate_plan_cfg(
    a: &Csr,
    b: &Csr,
    cfg: &EngineConfig,
    params: &EstimateParams,
) -> (SymbolicPlan, usize) {
    estimate_plan_with(a, b, cfg, params, None)
}

/// Core estimator: deterministic sample → exact counts → one global
/// compression ratio → per-row clamped estimates → the exact engine's
/// own kernel-selection + bin-construction path.
fn estimate_plan_with(
    a: &Csr,
    b: &Csr,
    cfg: &EngineConfig,
    params: &EstimateParams,
    inject: Option<EstimateInjector>,
) -> (SymbolicPlan, usize) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    // Masked products never speculate: a mask shrinks rows far below
    // the global compression ratio's reach, so every caller routes
    // masked work to the exact planner (`batch`/`executor` enforce it).
    assert!(cfg.mask.is_none(), "estimated plans do not support masks");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);

    // --- deterministic row sample, counted exactly ---
    let n = a.n_rows;
    let want = ((n as f64 * params.sample_fraction).ceil() as usize).max(params.min_samples).min(n);
    let sampled: Vec<u32> = if want == n {
        (0..n as u32).collect()
    } else {
        // Partial Fisher–Yates over the row ids: the first `want`
        // positions of a seeded shuffle — uniform without replacement,
        // reproducible.
        let mut ids: Vec<u32> = (0..n as u32).collect();
        let mut rng = Pcg32::seeded(params.seed);
        for i in 0..want {
            let j = i + rng.below_usize(n - i);
            ids.swap(i, j);
        }
        ids.truncate(want);
        ids
    };
    // Exact counts through the group-3 growable-table kernel (handles
    // trivial rows internally; capacity is bounded by min(IP, n_cols)).
    let mut exact = vec![0u32; sampled.len()];
    {
        let mut table = HashTable::new(1024, TableLoc::Global);
        for (s, &row) in sampled.iter().enumerate() {
            let r = row as usize;
            exact[s] = symbolic_row_nnz_hash(a, b, r, ip[r], &GROUP_SPECS[3], &mut table);
        }
    }

    // --- one global compression ratio, applied per row ---
    let sum_ip: u64 = sampled.iter().map(|&r| ip[r as usize]).sum();
    let sum_exact: u64 = exact.iter().map(|&u| u as u64).sum();
    let ratio = if sum_ip == 0 { 1.0 } else { sum_exact as f64 / sum_ip as f64 };
    let mut est = vec![0u64; n];
    for r in 0..n {
        if ip[r] == 0 {
            continue; // provably empty — IP is an upper bound
        }
        let cap = ip[r].min(b.n_cols as u64);
        est[r] = (((ip[r] as f64 * ratio).round() as u64).max(1)).min(cap);
    }
    // Sampled rows keep their exact counts (free, and tightens the
    // common small-matrix case to a fully exact plan).
    for (s, &row) in sampled.iter().enumerate() {
        est[row as usize] = exact[s] as u64;
    }
    // Adversarial override — raw values pass through, 0 included.
    if let Some(f) = inject {
        for (r, e) in est.iter_mut().enumerate() {
            *e = f(r, *e);
        }
    }

    // --- the exact planner's own selection + binning, fed estimates ---
    let mut sym = Vec::with_capacity(n);
    for r in 0..n {
        sym.push(select_symbolic(a.row_nnz(r), ip[r], b.n_cols, sym_threshold));
    }
    let mut rpt = vec![0usize; n + 1];
    for r in 0..n {
        rpt[r + 1] = rpt[r] + est[r] as usize;
    }
    let (accum, bins) = build_bins(a, b.n_cols, &ip, &grouping, &rpt, &sym, num_threshold);
    let plan = SymbolicPlan {
        ip,
        grouping,
        rpt,
        accum,
        symbolic: sym,
        bins,
        spa_threshold: cfg.spa_threshold,
        mask: None,
    };
    (plan, sampled.len())
}

/// Estimated-plan multiply at the default config/params: speculative
/// plan + fallback-aware numeric driver. Bit-identical to
/// [`super::engine::multiply`] — see the module docs for why.
pub fn multiply_estimated(a: &Csr, b: &Csr) -> (Csr, EstimateReport) {
    multiply_estimated_cfg(a, b, &EngineConfig::default(), &EstimateParams::default())
}

/// [`multiply_estimated`] with explicit config/params.
pub fn multiply_estimated_cfg(
    a: &Csr,
    b: &Csr,
    cfg: &EngineConfig,
    params: &EstimateParams,
) -> (Csr, EstimateReport) {
    multiply_estimated_with(a, b, cfg, params, None)
}

/// [`multiply_estimated_cfg`] with a test-only estimator override —
/// the adversarial-injection entry point (`tests/estimated_plan.rs`).
/// Whatever the injector returns, the output is bit-identical to the
/// exact engine; only `fallback_rows` and the timings move.
pub fn multiply_estimated_injected(
    a: &Csr,
    b: &Csr,
    cfg: &EngineConfig,
    params: &EstimateParams,
    inject: EstimateInjector,
) -> (Csr, EstimateReport) {
    multiply_estimated_with(a, b, cfg, params, Some(inject))
}

fn multiply_estimated_with(
    a: &Csr,
    b: &Csr,
    cfg: &EngineConfig,
    params: &EstimateParams,
    inject: Option<EstimateInjector>,
) -> (Csr, EstimateReport) {
    let t0 = Instant::now();
    let (plan, sampled_rows) = estimate_plan_with(a, b, cfg, params, inject);
    let estimate_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let (c, fallback_rows) = numeric_estimated(a, b, &plan, params.slack);
    let numeric_s = t1.elapsed().as_secs_f64();

    let report = EstimateReport {
        estimate_s,
        numeric_s,
        fallback_rows,
        sampled_rows,
        estimated_nnz: plan.nnz(),
        nnz: c.nnz(),
    };
    (c, report)
}

/// The speculative numeric driver. The exact `numeric()` cannot run a
/// speculative plan — it hard-asserts its buffers against `rpt` and
/// writes into pre-sized disjoint slices — so this driver assembles
/// the output row by row from *actual* gathered sizes, with the
/// per-row grow-and-retry ladder on hash rows:
///
/// - **scaled-copy** (single A entry): the output is the scaled B row
///   verbatim — estimate-independent, never falls back;
/// - **SPA** (planned dense): one slot per output column — cannot
///   overflow whatever the estimate was, never falls back;
/// - **hash**: table sized `max(2, pow2(2 · estimate × slack))`; a row
///   crossing 50 % load aborts, doubles, and re-runs from scratch
///   until it fits (counted once in `fallback_rows`). Zero-estimated
///   rows with live IP start the ladder at minimum capacity.
///
/// Returns the exact output CSR plus the fallback-row count.
fn numeric_estimated(a: &Csr, b: &Csr, plan: &SymbolicPlan, slack: f64) -> (Csr, usize) {
    assert_eq!(plan.rpt.len(), a.n_rows + 1, "plan does not match inputs");
    let mut rpt = vec![0usize; a.n_rows + 1];
    let mut col: Vec<u32> = Vec::with_capacity(plan.nnz());
    let mut val: Vec<f64> = Vec::with_capacity(plan.nnz());
    let mut table = HashTable::new(2, TableLoc::Global);
    let mut spa: Option<DenseAccumulator> = None;
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut fallback_rows = 0usize;

    for r in 0..a.n_rows {
        if plan.ip[r] == 0 {
            rpt[r + 1] = col.len();
            continue; // provably empty output row
        }
        let est = plan.rpt[r + 1] - plan.rpt[r];
        // Kernel choice follows the speculative plan, with two
        // estimate-proof overrides: single-entry rows are always
        // scaled copies (the plan agrees whenever est > 0), and
        // zero-estimated live rows — which `build_bins` skipped —
        // run the hash ladder from minimum capacity.
        let kind = if a.row_nnz(r) == 1 {
            AccumKind::ScaledCopy
        } else if est == 0 {
            AccumKind::Hash
        } else {
            plan.accum[r]
        };
        match kind {
            AccumKind::ScaledCopy => {
                // Same expression order as the exact engine's
                // scaled-copy arm: av * b_val, B-row (sorted) order.
                let j = a.rpt[r];
                let av = a.val[j];
                let (bc, bv) = b.row(a.col[j] as usize);
                col.extend_from_slice(bc);
                val.extend(bv.iter().map(|&v| av * v));
            }
            AccumKind::Spa => {
                let spa = spa.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                spa.clear();
                accum_row_spa(a, b, r, spa, &mut scratch);
                scratch.sort_unstable_by_key(|e| e.0);
                col.extend(scratch.iter().map(|e| e.0));
                val.extend(scratch.iter().map(|e| e.1));
            }
            AccumKind::Hash => {
                // Start at estimate × slack (≤ 50 % load if the
                // estimate holds), never below the minimum table and
                // never above what min(IP, n_cols) justifies.
                let bound = plan.ip[r].min(b.n_cols as u64).max(1);
                let want = (((est as f64) * slack).ceil() as u64).clamp(1, bound);
                let mut capacity = global_table_size(want);
                let mut grew = false;
                loop {
                    table.reset_with_capacity(capacity);
                    let mut overflow = false;
                    'row: for j in a.row_range(r) {
                        let av = a.val[j];
                        let colk = a.col[j] as usize;
                        for k in b.rpt[colk]..b.rpt[colk + 1] {
                            // The underestimate detector: crossing
                            // 50 % load means the sizing premise is
                            // gone — abort before the probe chains
                            // (or the table itself) degrade.
                            if table.unique * 2 > table.capacity() {
                                overflow = true;
                                break 'row;
                            }
                            table.insert_numeric(b.col[k], av * b.val[k], &mut NullProbe);
                        }
                    }
                    if overflow {
                        capacity = table.capacity() * 2;
                        grew = true;
                        continue;
                    }
                    table.gather_list(&mut scratch);
                    break;
                }
                if grew {
                    fallback_rows += 1;
                }
                scratch.sort_unstable_by_key(|e| e.0);
                col.extend(scratch.iter().map(|e| e.0));
                val.extend(scratch.iter().map(|e| e.1));
            }
        }
        rpt[r + 1] = col.len();
    }
    (Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val), fallback_rows)
}

#[cfg(test)]
mod tests {
    use super::super::engine::{multiply, testutil::random_csr};
    use super::*;

    fn assert_bit_identical(c: &Csr, r: &Csr) {
        assert_eq!(c.rpt, r.rpt, "row pointers differ");
        assert_eq!(c.col, r.col, "column indices differ");
        assert_eq!(
            c.val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r.val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "values are not bit-identical"
        );
    }

    #[test]
    fn policy_parse_and_name_round_trip() {
        for p in [PlannerPolicy::Exact, PlannerPolicy::Estimated, PlannerPolicy::Auto] {
            assert_eq!(PlannerPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlannerPolicy::parse("EXACT"), Some(PlannerPolicy::Exact));
        assert_eq!(PlannerPolicy::parse("bogus"), None);
        assert_eq!(PlannerPolicy::default(), PlannerPolicy::Exact);
        assert!(!PlannerPolicy::Exact.speculates());
        assert!(PlannerPolicy::Estimated.speculates());
        assert!(PlannerPolicy::Auto.speculates());
    }

    #[test]
    fn estimated_multiply_is_bit_identical_to_exact() {
        let mut rng = Pcg32::seeded(99);
        let a = random_csr(&mut rng, 150, 120, 0.04);
        let b = random_csr(&mut rng, 120, 110, 0.04);
        let exact = multiply(&a, &b);
        let (c, report) = multiply_estimated(&a, &b);
        assert_bit_identical(&c, &exact);
        assert_eq!(report.nnz, exact.nnz());
        assert!(report.sampled_rows > 0);
    }

    #[test]
    fn estimate_plan_is_deterministic() {
        let mut rng = Pcg32::seeded(5);
        let a = random_csr(&mut rng, 300, 200, 0.03);
        let b = random_csr(&mut rng, 200, 180, 0.03);
        let cfg = EngineConfig::default();
        let params = EstimateParams { sample_fraction: 0.1, min_samples: 8, ..Default::default() };
        let (p1, s1) = estimate_plan_cfg(&a, &b, &cfg, &params);
        let (p2, s2) = estimate_plan_cfg(&a, &b, &cfg, &params);
        assert_eq!(s1, s2);
        assert_eq!(p1.rpt, p2.rpt, "same seed must sample the same rows");
    }

    #[test]
    fn forced_underestimate_falls_back_and_stays_identical() {
        let mut rng = Pcg32::seeded(7);
        let a = random_csr(&mut rng, 120, 100, 0.08);
        let b = random_csr(&mut rng, 100, 100, 0.08);
        let exact = multiply(&a, &b);
        let cfg = EngineConfig::default();
        let params = EstimateParams::default();
        let (c, report) =
            multiply_estimated_injected(&a, &b, &cfg, &params, &|_r, e| (e / 10).max(1));
        assert_bit_identical(&c, &exact);
        assert!(report.fallback_rows > 0, "forced 0.1x underestimates must trigger the ladder");
    }

    #[test]
    fn zero_estimates_still_produce_exact_output() {
        let mut rng = Pcg32::seeded(11);
        let a = random_csr(&mut rng, 80, 60, 0.1);
        let b = random_csr(&mut rng, 60, 50, 0.1);
        let exact = multiply(&a, &b);
        let (c, _) = multiply_estimated_injected(
            &a,
            &b,
            &EngineConfig::default(),
            &EstimateParams::default(),
            &|_r, _e| 0,
        );
        assert_bit_identical(&c, &exact);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let (c, report) = multiply_estimated(&Csr::zeros(0, 5), &Csr::zeros(5, 3));
        assert_eq!((c.n_rows, c.n_cols, c.nnz()), (0, 3, 0));
        assert_eq!(report.fallback_rows, 0);
        let (c, _) = multiply_estimated(&Csr::zeros(4, 0), &Csr::zeros(0, 3));
        assert_eq!((c.n_rows, c.n_cols, c.nnz()), (4, 3, 0));
        let (c, _) = multiply_estimated(&Csr::zeros(4, 6), &Csr::zeros(6, 0));
        assert_eq!((c.n_rows, c.n_cols, c.nnz()), (4, 0, 0));
    }
}
