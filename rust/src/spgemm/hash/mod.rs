//! Hash-based multi-phase SpGEMM (paper §III): row grouping (Table I),
//! PWPR/TBPR thread assignment, the Algorithm-4 linear-probing hash
//! table, and the explicit symbolic (size) / numeric (value) phases —
//! see `DESIGN.md` §"Two-phase hash engine".

pub mod engine;
pub mod grouping;
pub mod sort;
pub mod table;

pub use engine::{multiply, multiply_single_pass, multiply_timed, multiply_traced, numeric, symbolic, SymbolicPlan};
pub use grouping::{Grouping, Strategy, GROUP_SPECS};
