//! Hash-based multi-phase SpGEMM (paper §III): row grouping (Table I),
//! PWPR/TBPR thread assignment, the Algorithm-4 linear-probing hash
//! table, the explicit symbolic (size) / numeric (value) phases with
//! plan-guided **row-kernel selection** (the [`RowKernel`] pair:
//! [`SymbolicKind`] trivial / hash / bitmap counting decided from the
//! IP upper bound, [`AccumKind`] scaled-copy / hash / dense-SPA decided
//! from the exact `nnz(C_i)`), and the plan-reuse handle
//! ([`PlannedProduct`]) that amortises symbolic analysis across the
//! numeric fills of iterative workloads, backed by the tiered plan
//! store ([`planstore`]: in-memory + versioned on-disk caching, so the
//! amortization extends across process lifetimes) — see `DESIGN.md`
//! §"Two-phase hash engine", §"Plan reuse", §"Accumulator selection",
//! §"Symbolic kernel selection", and §"Plan persistence".

pub mod calibrate;
pub mod engine;
pub mod estimate;
pub mod grouping;
pub mod incremental;
pub mod mask;
pub mod plan;
pub mod planstore;
pub mod sort;
pub mod table;

pub use calibrate::{
    calibrate_sweep, calibrated_spa_threshold, default_threshold_grid, CalibrateInput, Calibration,
    CalibrationPoint, CALIBRATION_FILE, CALIBRATION_SCHEMA, CALIBRATION_VERSION,
};
pub use engine::{
    default_spa_threshold, multiply, multiply_cfg, multiply_masked, multiply_masked_cfg, multiply_single_pass,
    multiply_timed, multiply_timed_cfg, multiply_traced, multiply_traced_cfg, numeric, numeric_bin_into,
    numeric_timed, resolve_default_spa_threshold, set_default_spa_threshold, symbolic, symbolic_cfg, EngineConfig,
    NumericBin, SymbolicPlan,
};
pub use estimate::{
    default_planner_policy, estimate_plan, estimate_plan_cfg, multiply_estimated, multiply_estimated_cfg,
    multiply_estimated_injected, set_default_planner_policy, EstimateInjector, EstimateParams,
    EstimateReport, PlannerPolicy,
};
pub use grouping::{
    select_accumulator, select_symbolic, select_symbolic_masked, AccumKind, Grouping, RowKernel, Strategy,
    SymbolicKind, DEFAULT_SPA_THRESHOLD, GROUP_SPECS,
};
pub use incremental::{
    delta_patch, mutate_row_fraction, DeltaOutcome, DeltaPatch, MAX_DELTA_CHAIN, REBUILD_DIRTY_FRACTION,
};
pub use mask::{mask_hash_of, Mask, MaskRowProbe};
pub use plan::{pair_key, pair_key_from_hashes, DeltaLineage, PlannedProduct};
pub use planstore::{
    default_plan_cache_dir, set_default_plan_cache_dir, DiskStore, GetOutcome, MemStore, PlanFileInfo,
    PlanFingerprint, PlanStore, PlanSummary, PruneReport, StoreStats, TieredStore,
};
pub use table::{DenseAccumulator, RowCounter};
