//! Hash-based multi-phase SpGEMM (paper §III): row grouping (Table I),
//! PWPR/TBPR thread assignment, the Algorithm-4 linear-probing hash
//! table, and the allocation/accumulation phases.

pub mod engine;
pub mod grouping;
pub mod sort;
pub mod table;

pub use engine::{multiply, multiply_traced};
pub use grouping::{Grouping, Strategy, GROUP_SPECS};
