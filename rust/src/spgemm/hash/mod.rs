//! Hash-based multi-phase SpGEMM (paper §III): row grouping (Table I),
//! PWPR/TBPR thread assignment, the Algorithm-4 linear-probing hash
//! table, the explicit symbolic (size) / numeric (value) phases, and the
//! plan-reuse handle ([`PlannedProduct`]) that amortises symbolic
//! analysis across the numeric fills of iterative workloads — see
//! `DESIGN.md` §"Two-phase hash engine" and §"Plan reuse".

pub mod engine;
pub mod grouping;
pub mod plan;
pub mod sort;
pub mod table;

pub use engine::{multiply, multiply_single_pass, multiply_timed, multiply_traced, numeric, symbolic, SymbolicPlan};
pub use grouping::{Grouping, Strategy, GROUP_SPECS};
pub use plan::{pair_key, pair_key_from_hashes, PlannedProduct};
