//! Row-grouping phase (paper §III-B): logarithmic binning of rows by
//! intermediate-product count into four groups, each with its own thread
//! assignment strategy, block size, and hash-table size (Table I).
//!
//! The matrix is *not* reordered; `Map` holds row ids sorted by group
//! (stable within a group), exactly the paper's `Map[i]` indirection.

use super::super::ip::group_index_for_ip;

/// Thread-assignment strategy (paper §III-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Partial warp per row: 4 threads per row (group 0).
    Pwpr,
    /// Thread block per row (groups 1–3).
    Tbpr,
}

/// Per-group GPU resource allocation — Table I of the paper.
#[derive(Clone, Copy, Debug)]
pub struct GroupSpec {
    pub id: usize,
    pub ip_lo: u64,
    /// Inclusive upper bound (`u64::MAX` for group 3).
    pub ip_hi: u64,
    pub strategy: Strategy,
    pub block_size: usize,
    /// Shared-memory hash-table size; `None` = global-memory fallback
    /// (group 3), sized per row at runtime.
    pub table_size: Option<usize>,
}

impl GroupSpec {
    /// Rows processed by one thread block under this spec.
    pub fn rows_per_block(&self) -> usize {
        match self.strategy {
            Strategy::Pwpr => self.block_size / 4, // 4 threads per row
            Strategy::Tbpr => 1,
        }
    }
}

/// Table I, verbatim.
pub const GROUP_SPECS: [GroupSpec; 4] = [
    GroupSpec { id: 0, ip_lo: 0, ip_hi: 31, strategy: Strategy::Pwpr, block_size: 512, table_size: Some(64) },
    GroupSpec { id: 1, ip_lo: 32, ip_hi: 511, strategy: Strategy::Tbpr, block_size: 256, table_size: Some(1024) },
    GroupSpec { id: 2, ip_lo: 512, ip_hi: 8191, strategy: Strategy::Tbpr, block_size: 1024, table_size: Some(8192) },
    GroupSpec { id: 3, ip_lo: 8192, ip_hi: u64::MAX, strategy: Strategy::Tbpr, block_size: 1024, table_size: None },
];

/// Output of the row-grouping phase.
#[derive(Clone, Debug)]
pub struct Grouping {
    /// Row ids sorted by group (stable): `map[sorted_idx] = original row`.
    pub map: Vec<u32>,
    /// `group_of[row] = group id`.
    pub group_of: Vec<u8>,
    /// `ranges[g]` = the slice of `map` belonging to group g.
    pub ranges: [std::ops::Range<usize>; 4],
}

impl Grouping {
    /// Classify rows by IP count (counting sort by group, stable).
    pub fn build(ip: &[u64]) -> Grouping {
        let n = ip.len();
        let mut group_of = vec![0u8; n];
        let mut counts = [0usize; 4];
        for (i, &v) in ip.iter().enumerate() {
            let g = group_index_for_ip(v);
            group_of[i] = g as u8;
            counts[g] += 1;
        }
        let mut starts = [0usize; 4];
        for g in 1..4 {
            starts[g] = starts[g - 1] + counts[g - 1];
        }
        let ranges = [
            starts[0]..starts[0] + counts[0],
            starts[1]..starts[1] + counts[1],
            starts[2]..starts[2] + counts[2],
            starts[3]..starts[3] + counts[3],
        ];
        let mut map = vec![0u32; n];
        let mut next = starts;
        for (i, &g) in group_of.iter().enumerate() {
            map[next[g as usize]] = i as u32;
            next[g as usize] += 1;
        }
        Grouping { map, group_of, ranges }
    }

    pub fn group_rows(&self, g: usize) -> &[u32] {
        &self.map[self.ranges[g].clone()]
    }

    /// Number of thread blocks group `g` launches.
    pub fn blocks_in_group(&self, g: usize) -> usize {
        let rows = self.ranges[g].len();
        let per_block = GROUP_SPECS[g].rows_per_block();
        rows.div_ceil(per_block)
    }
}

/// Global-memory table size for a group-3 row: next power of two ≥ 2·IP
/// (load factor ≤ 0.5 keeps probe chains short on huge rows).
pub fn global_table_size(ip: u64) -> usize {
    ((ip.max(1) as usize) * 2).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_specs_match_paper() {
        assert_eq!(GROUP_SPECS[0].block_size, 512);
        assert_eq!(GROUP_SPECS[0].table_size, Some(64));
        assert_eq!(GROUP_SPECS[0].strategy, Strategy::Pwpr);
        assert_eq!(GROUP_SPECS[1].block_size, 256);
        assert_eq!(GROUP_SPECS[1].table_size, Some(1024));
        assert_eq!(GROUP_SPECS[2].block_size, 1024);
        assert_eq!(GROUP_SPECS[2].table_size, Some(8192));
        assert_eq!(GROUP_SPECS[3].table_size, None);
        assert!(GROUP_SPECS.iter().skip(1).all(|g| g.strategy == Strategy::Tbpr));
    }

    #[test]
    fn table_sizes_cover_group_ip_bounds() {
        // A shared table must hold every possible unique count in its
        // group: unique ≤ IP ≤ ip_hi < table_size.
        for spec in &GROUP_SPECS[..3] {
            let size = spec.table_size.unwrap() as u64;
            assert!(spec.ip_hi < size, "group {}: ip_hi {} ≥ table {}", spec.id, spec.ip_hi, size);
        }
    }

    #[test]
    fn grouping_is_stable_partition() {
        let ip = vec![10, 5000, 40, 0, 9000, 33, 600];
        let g = Grouping::build(&ip);
        assert_eq!(g.group_rows(0), &[0, 3]);
        assert_eq!(g.group_rows(1), &[2, 5]);
        assert_eq!(g.group_rows(2), &[1, 6]);
        assert_eq!(g.group_rows(3), &[4]);
        // map is a permutation
        let mut sorted = g.map.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn rows_per_block() {
        assert_eq!(GROUP_SPECS[0].rows_per_block(), 128); // 512 threads / 4
        assert_eq!(GROUP_SPECS[1].rows_per_block(), 1);
    }

    #[test]
    fn blocks_in_group_rounds_up() {
        let ip = vec![1u64; 300]; // all group 0, 128 rows per block
        let g = Grouping::build(&ip);
        assert_eq!(g.blocks_in_group(0), 3);
        assert_eq!(g.blocks_in_group(1), 0);
    }

    #[test]
    fn global_table_size_is_pow2_and_roomy() {
        assert_eq!(global_table_size(8192), 16384);
        assert!(global_table_size(10_000) >= 20_000);
        assert!(global_table_size(0).is_power_of_two());
    }
}
