//! Row-grouping phase (paper §III-B): logarithmic binning of rows by
//! intermediate-product count into four groups, each with its own thread
//! assignment strategy, block size, and hash-table size (Table I), plus
//! the **accumulator-selection model** the numeric phase is guided by.
//!
//! The matrix is *not* reordered; `Map` holds row ids sorted by group
//! (stable within a group), exactly the paper's `Map[i]` indirection.
//!
//! # Accumulator selection
//!
//! Table I fixes *where the hash table lives* per IP bin; it does not
//! decide *whether a hash table is the right accumulator at all*. Once
//! the symbolic phase has exact per-row output sizes, every row can be
//! classified by [`select_accumulator`] into one of three
//! [`AccumKind`]s — the decision the plan bakes into each numeric bin
//! (see `engine::SymbolicPlan::bins`):
//!
//! | kind | chosen when | why |
//! |------|-------------|-----|
//! | [`AccumKind::ScaledCopy`] | row of A has exactly 1 entry | `C_i = a·B_k`: already sorted, collision-free — no accumulator, no sort |
//! | [`AccumKind::Spa`] | `nnz(C_i) / n_cols > spa_threshold` | dense output row: a dense accumulator streams `vals[col] += v` with zero probe chains and a sequential gather (Nagasaka et al., arXiv:1804.01698) |
//! | [`AccumKind::Hash`] | otherwise | sparse output row: Algorithm 4 linear probing, Table I sizing |
//!
//! The threshold is tunable (`--spa-threshold`, default
//! [`DEFAULT_SPA_THRESHOLD`]); `0.0` forces SPA on every multi-entry
//! row, any value ≥ 1.0 disables SPA (the comparison is strict, and
//! `nnz(C_i)` can never exceed `n_cols`).

use super::super::ip::group_index_for_ip;

/// Numeric-phase accumulator for one output row, chosen at plan time
/// from the symbolic phase's exact `nnz(C_i)` (see
/// [`select_accumulator`] and the module-level decision table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccumKind {
    /// Single-A-entry row: `C_i` is one B row scaled by a constant —
    /// copied straight into the output slice, no accumulator at all.
    ScaledCopy,
    /// Linear-probing hash table (Algorithm 4), sized per Table I.
    Hash,
    /// Dense sparse-accumulator (SPA): one `f64` slot per output
    /// column, generation-stamped occupancy, O(unique) gather. Wins
    /// when the output row is dense enough that hash probing degrades
    /// to scanning anyway.
    Spa,
}

impl AccumKind {
    /// Stable ordinal for per-kind arrays (`PhaseTimes::numeric_kind_s`).
    pub fn index(self) -> usize {
        match self {
            AccumKind::ScaledCopy => 0,
            AccumKind::Hash => 1,
            AccumKind::Spa => 2,
        }
    }

    /// Inverse of [`AccumKind::index`]. Panics on out-of-range input.
    pub fn from_index(i: usize) -> AccumKind {
        match i {
            0 => AccumKind::ScaledCopy,
            1 => AccumKind::Hash,
            2 => AccumKind::Spa,
            _ => panic!("AccumKind index {i} out of range"),
        }
    }

    /// Stable lowercase name for metrics keys, bench meta, and logs.
    pub fn name(self) -> &'static str {
        match self {
            AccumKind::ScaledCopy => "copy",
            AccumKind::Hash => "hash",
            AccumKind::Spa => "spa",
        }
    }

    pub const ALL: [AccumKind; 3] = [AccumKind::ScaledCopy, AccumKind::Hash, AccumKind::Spa];
}

/// Default SPA density threshold: a row whose output is more than a
/// quarter dense stops hashing. At load factor 0.5 a Table-I hash row
/// touches `2·nnz(C_i)` scattered slots plus probe chains; the SPA
/// touches `nnz(C_i)` streamed slots plus an `n_cols` sequential scan,
/// so the crossover sits near `nnz(C_i) ≈ n_cols/4` on the simulated
/// device (see `benches/accumulator.rs` for the measured sweep).
pub const DEFAULT_SPA_THRESHOLD: f64 = 0.25;

/// Pick the numeric accumulator for one output row (module-level
/// decision table). `a_row_nnz` is the row's entry count in A,
/// `row_nnz` the *exact* output size from the symbolic phase, `n_cols`
/// the output width. Rows with `row_nnz == 0` never reach the numeric
/// phase and should not be classified.
pub fn select_accumulator(a_row_nnz: usize, row_nnz: usize, n_cols: usize, spa_threshold: f64) -> AccumKind {
    if a_row_nnz == 1 {
        return AccumKind::ScaledCopy;
    }
    // Strict `>`: threshold 0.0 forces SPA on every multi-entry row with
    // output, and any threshold ≥ 1.0 disables SPA (nnz ≤ n_cols).
    if row_nnz as f64 > spa_threshold * n_cols as f64 {
        AccumKind::Spa
    } else {
        AccumKind::Hash
    }
}

/// Thread-assignment strategy (paper §III-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Partial warp per row: 4 threads per row (group 0).
    Pwpr,
    /// Thread block per row (groups 1–3).
    Tbpr,
}

/// Per-group GPU resource allocation — Table I of the paper.
#[derive(Clone, Copy, Debug)]
pub struct GroupSpec {
    pub id: usize,
    pub ip_lo: u64,
    /// Inclusive upper bound (`u64::MAX` for group 3).
    pub ip_hi: u64,
    pub strategy: Strategy,
    pub block_size: usize,
    /// Shared-memory hash-table size; `None` = global-memory fallback
    /// (group 3), sized per row at runtime.
    pub table_size: Option<usize>,
}

impl GroupSpec {
    /// Rows processed by one thread block under this spec.
    pub fn rows_per_block(&self) -> usize {
        match self.strategy {
            Strategy::Pwpr => self.block_size / 4, // 4 threads per row
            Strategy::Tbpr => 1,
        }
    }
}

/// Table I, verbatim.
pub const GROUP_SPECS: [GroupSpec; 4] = [
    GroupSpec { id: 0, ip_lo: 0, ip_hi: 31, strategy: Strategy::Pwpr, block_size: 512, table_size: Some(64) },
    GroupSpec { id: 1, ip_lo: 32, ip_hi: 511, strategy: Strategy::Tbpr, block_size: 256, table_size: Some(1024) },
    GroupSpec { id: 2, ip_lo: 512, ip_hi: 8191, strategy: Strategy::Tbpr, block_size: 1024, table_size: Some(8192) },
    GroupSpec { id: 3, ip_lo: 8192, ip_hi: u64::MAX, strategy: Strategy::Tbpr, block_size: 1024, table_size: None },
];

/// Output of the row-grouping phase.
#[derive(Clone, Debug)]
pub struct Grouping {
    /// Row ids sorted by group (stable): `map[sorted_idx] = original row`.
    pub map: Vec<u32>,
    /// `group_of[row] = group id`.
    pub group_of: Vec<u8>,
    /// `ranges[g]` = the slice of `map` belonging to group g.
    pub ranges: [std::ops::Range<usize>; 4],
}

impl Grouping {
    /// Classify rows by IP count (counting sort by group, stable).
    pub fn build(ip: &[u64]) -> Grouping {
        let n = ip.len();
        let mut group_of = vec![0u8; n];
        let mut counts = [0usize; 4];
        for (i, &v) in ip.iter().enumerate() {
            let g = group_index_for_ip(v);
            group_of[i] = g as u8;
            counts[g] += 1;
        }
        let mut starts = [0usize; 4];
        for g in 1..4 {
            starts[g] = starts[g - 1] + counts[g - 1];
        }
        let ranges = [
            starts[0]..starts[0] + counts[0],
            starts[1]..starts[1] + counts[1],
            starts[2]..starts[2] + counts[2],
            starts[3]..starts[3] + counts[3],
        ];
        let mut map = vec![0u32; n];
        let mut next = starts;
        for (i, &g) in group_of.iter().enumerate() {
            map[next[g as usize]] = i as u32;
            next[g as usize] += 1;
        }
        Grouping { map, group_of, ranges }
    }

    pub fn group_rows(&self, g: usize) -> &[u32] {
        &self.map[self.ranges[g].clone()]
    }

    /// Number of thread blocks group `g` launches.
    pub fn blocks_in_group(&self, g: usize) -> usize {
        let rows = self.ranges[g].len();
        let per_block = GROUP_SPECS[g].rows_per_block();
        rows.div_ceil(per_block)
    }
}

/// Global-memory table size for a group-3 row: next power of two ≥ 2·IP
/// (load factor ≤ 0.5 keeps probe chains short on huge rows).
pub fn global_table_size(ip: u64) -> usize {
    ((ip.max(1) as usize) * 2).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_specs_match_paper() {
        assert_eq!(GROUP_SPECS[0].block_size, 512);
        assert_eq!(GROUP_SPECS[0].table_size, Some(64));
        assert_eq!(GROUP_SPECS[0].strategy, Strategy::Pwpr);
        assert_eq!(GROUP_SPECS[1].block_size, 256);
        assert_eq!(GROUP_SPECS[1].table_size, Some(1024));
        assert_eq!(GROUP_SPECS[2].block_size, 1024);
        assert_eq!(GROUP_SPECS[2].table_size, Some(8192));
        assert_eq!(GROUP_SPECS[3].table_size, None);
        assert!(GROUP_SPECS.iter().skip(1).all(|g| g.strategy == Strategy::Tbpr));
    }

    #[test]
    fn table_sizes_cover_group_ip_bounds() {
        // A shared table must hold every possible unique count in its
        // group: unique ≤ IP ≤ ip_hi < table_size.
        for spec in &GROUP_SPECS[..3] {
            let size = spec.table_size.unwrap() as u64;
            assert!(spec.ip_hi < size, "group {}: ip_hi {} ≥ table {}", spec.id, spec.ip_hi, size);
        }
    }

    #[test]
    fn grouping_is_stable_partition() {
        let ip = vec![10, 5000, 40, 0, 9000, 33, 600];
        let g = Grouping::build(&ip);
        assert_eq!(g.group_rows(0), &[0, 3]);
        assert_eq!(g.group_rows(1), &[2, 5]);
        assert_eq!(g.group_rows(2), &[1, 6]);
        assert_eq!(g.group_rows(3), &[4]);
        // map is a permutation
        let mut sorted = g.map.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn rows_per_block() {
        assert_eq!(GROUP_SPECS[0].rows_per_block(), 128); // 512 threads / 4
        assert_eq!(GROUP_SPECS[1].rows_per_block(), 1);
    }

    #[test]
    fn blocks_in_group_rounds_up() {
        let ip = vec![1u64; 300]; // all group 0, 128 rows per block
        let g = Grouping::build(&ip);
        assert_eq!(g.blocks_in_group(0), 3);
        assert_eq!(g.blocks_in_group(1), 0);
    }

    #[test]
    fn global_table_size_is_pow2_and_roomy() {
        assert_eq!(global_table_size(8192), 16384);
        assert!(global_table_size(10_000) >= 20_000);
        assert!(global_table_size(0).is_power_of_two());
    }

    #[test]
    fn accumulator_decision_table() {
        // Single-A-entry rows copy regardless of density.
        assert_eq!(select_accumulator(1, 1000, 1000, 0.25), AccumKind::ScaledCopy);
        assert_eq!(select_accumulator(1, 1, 1000, 0.25), AccumKind::ScaledCopy);
        // Sparse output rows hash, dense ones take the SPA.
        assert_eq!(select_accumulator(8, 10, 1000, 0.25), AccumKind::Hash);
        assert_eq!(select_accumulator(8, 600, 1000, 0.25), AccumKind::Spa);
    }

    #[test]
    fn spa_threshold_boundaries() {
        // 0.0 forces SPA on every multi-entry row with output...
        assert_eq!(select_accumulator(2, 1, 1_000_000, 0.0), AccumKind::Spa);
        // ...and ≥ 1.0 disables it, even for a fully dense row (strict >).
        assert_eq!(select_accumulator(2, 1000, 1000, 1.0), AccumKind::Hash);
        assert_eq!(select_accumulator(2, 1000, 1000, 2.0), AccumKind::Hash);
        // Exactly at the threshold stays on the hash path (strict >).
        assert_eq!(select_accumulator(2, 250, 1000, 0.25), AccumKind::Hash);
        assert_eq!(select_accumulator(2, 251, 1000, 0.25), AccumKind::Spa);
    }

    #[test]
    fn accum_kind_index_roundtrip() {
        for k in AccumKind::ALL {
            assert_eq!(AccumKind::from_index(k.index()), k);
        }
        assert_eq!(AccumKind::Spa.name(), "spa");
    }
}
