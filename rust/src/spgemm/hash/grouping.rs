//! Row-grouping phase (paper §III-B): logarithmic binning of rows by
//! intermediate-product count into four groups, each with its own thread
//! assignment strategy, block size, and hash-table size (Table I), plus
//! the **row-kernel selection model** both engine phases are guided by.
//!
//! The matrix is *not* reordered; `Map` holds row ids sorted by group
//! (stable within a group), exactly the paper's `Map[i]` indirection.
//!
//! # Row-kernel selection
//!
//! Table I fixes *where the hash table lives* per IP bin; it does not
//! decide *whether a hash table is the right kernel at all*. Every row
//! gets a [`RowKernel`] pair at plan time — a symbolic counting kernel
//! and a numeric accumulator — and the Table-I bins carry the pair end
//! to end (see `engine::SymbolicPlan::bins`). The two halves are
//! decided from different information, because they run at different
//! points of the pipeline:
//!
//! **Numeric** ([`select_accumulator`], [`AccumKind`]) — decided from
//! the symbolic phase's *exact* per-row output sizes:
//!
//! | kind | chosen when | why |
//! |------|-------------|-----|
//! | [`AccumKind::ScaledCopy`] | row of A has exactly 1 entry | `C_i = a·B_k`: already sorted, collision-free — no accumulator, no sort |
//! | [`AccumKind::Spa`] | `nnz(C_i) / n_cols > spa_threshold` | dense output row: a dense accumulator streams `vals[col] += v` with zero probe chains and a sequential gather (Nagasaka et al., arXiv:1804.01698) |
//! | [`AccumKind::Hash`] | otherwise | sparse output row: Algorithm 4 linear probing, Table I sizing |
//!
//! **Symbolic** ([`select_symbolic`], [`SymbolicKind`]) — exact sizes
//! do not exist before the symbolic phase, so the decision comes from
//! the IP *upper bound* instead (capped at `n_cols`, since a row can
//! never have more uniques than output columns):
//!
//! | kind | chosen when | why |
//! |------|-------------|-----|
//! | [`SymbolicKind::Trivial`] | `IP_i ≤ 1` or row of A has ≤ 1 entry | collisions impossible — the count *is* `IP_i`, no kernel runs |
//! | [`SymbolicKind::Bitmap`] | `min(IP_i, n_cols) / n_cols > threshold` | potentially dense row: a generation-stamped dense bitmap ([`super::table::RowCounter`]) counts uniques with zero probe chains — streaming, AIA-ineligible, exactly like the numeric SPA |
//! | [`SymbolicKind::Hash`] | otherwise | sparse bound: Algorithms 2–3 symbolic hash inserts, Table I sizing |
//!
//! Both halves share one threshold knob (`--spa-threshold`). Its
//! default is **derived from the simulated device's cache geometry**
//! ([`crate::sim::DeviceConfig::dense_row_threshold_base`], the
//! crossover where hash probing's scattered extra traffic outweighs a
//! dense kernel's sequential scan — [`DEFAULT_SPA_THRESHOLD`] is that
//! derivation evaluated for the H200's 32-byte sectors), and the
//! engine scales it up when a dense row stops fitting in the
//! per-resident-block L2 share. Both comparisons are strict, so `0.0`
//! forces the dense kernel on every non-trivial row and any value
//! ≥ 1.0 disables it (the symbolic bound is capped at `n_cols`, and
//! `nnz(C_i)` can never exceed `n_cols`).

use super::super::ip::group_index_for_ip;

/// Numeric-phase accumulator for one output row, chosen at plan time
/// from the symbolic phase's exact `nnz(C_i)` (see
/// [`select_accumulator`] and the module-level decision table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccumKind {
    /// Single-A-entry row: `C_i` is one B row scaled by a constant —
    /// copied straight into the output slice, no accumulator at all.
    ScaledCopy,
    /// Linear-probing hash table (Algorithm 4), sized per Table I.
    Hash,
    /// Dense sparse-accumulator (SPA): one `f64` slot per output
    /// column, generation-stamped occupancy, O(unique) gather. Wins
    /// when the output row is dense enough that hash probing degrades
    /// to scanning anyway.
    Spa,
}

impl AccumKind {
    /// Stable ordinal for per-kind arrays (`PhaseTimes::numeric_kind_s`).
    pub fn index(self) -> usize {
        match self {
            AccumKind::ScaledCopy => 0,
            AccumKind::Hash => 1,
            AccumKind::Spa => 2,
        }
    }

    /// Inverse of [`AccumKind::index`]. Panics on out-of-range input.
    pub fn from_index(i: usize) -> AccumKind {
        match i {
            0 => AccumKind::ScaledCopy,
            1 => AccumKind::Hash,
            2 => AccumKind::Spa,
            _ => panic!("AccumKind index {i} out of range"),
        }
    }

    /// Stable lowercase name for metrics keys, bench meta, and logs.
    pub fn name(self) -> &'static str {
        match self {
            AccumKind::ScaledCopy => "copy",
            AccumKind::Hash => "hash",
            AccumKind::Spa => "spa",
        }
    }

    pub const ALL: [AccumKind; 3] = [AccumKind::ScaledCopy, AccumKind::Hash, AccumKind::Spa];
}

/// Symbolic-phase counting kernel for one output row, chosen at plan
/// time from the IP *upper bound* (exact sizes do not exist yet — see
/// [`select_symbolic`] and the module-level decision table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SymbolicKind {
    /// `IP_i ≤ 1` or single-A-entry row: collisions are impossible, the
    /// unique count *is* `IP_i` — no counting kernel runs at all.
    Trivial,
    /// Symbolic hash inserts (Algorithms 2–3), sized per Table I.
    Hash,
    /// Generation-stamped dense bitmap ([`super::table::RowCounter`]):
    /// one occupancy word per output column, O(1) clear, first-touch
    /// counting with zero probe chains. Streaming / AIA-ineligible,
    /// exactly like the numeric SPA.
    Bitmap,
}

impl SymbolicKind {
    /// Stable ordinal for per-kind arrays (`PhaseTimes::symbolic_kind_s`).
    pub fn index(self) -> usize {
        match self {
            SymbolicKind::Trivial => 0,
            SymbolicKind::Hash => 1,
            SymbolicKind::Bitmap => 2,
        }
    }

    /// Inverse of [`SymbolicKind::index`]. Panics on out-of-range input.
    pub fn from_index(i: usize) -> SymbolicKind {
        match i {
            0 => SymbolicKind::Trivial,
            1 => SymbolicKind::Hash,
            2 => SymbolicKind::Bitmap,
            _ => panic!("SymbolicKind index {i} out of range"),
        }
    }

    /// Stable lowercase name for metrics keys, bench meta, and logs.
    pub fn name(self) -> &'static str {
        match self {
            SymbolicKind::Trivial => "trivial",
            SymbolicKind::Hash => "hash",
            SymbolicKind::Bitmap => "bitmap",
        }
    }

    pub const ALL: [SymbolicKind; 3] = [SymbolicKind::Trivial, SymbolicKind::Hash, SymbolicKind::Bitmap];
}

/// The kernel pair the plan selects for one row: how the symbolic phase
/// counts it and how the numeric phase accumulates it. Carried by every
/// `engine::NumericBin`, so the pair survives from the table primitives
/// through the batch pipeline to the metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RowKernel {
    pub symbolic: SymbolicKind,
    pub numeric: AccumKind,
}

impl RowKernel {
    /// Short label for schedules and metrics, e.g. `bitmap/spa`.
    pub fn label(&self) -> String {
        format!("{}/{}", self.symbolic.name(), self.numeric.name())
    }
}

/// Default dense-kernel density threshold: the cache-geometry
/// derivation of [`crate::sim::DeviceConfig::dense_row_threshold_base`]
/// evaluated for the simulated H200's 32-byte sectors. At load factor
/// 0.5 a Table-I hash row touches `2·nnz(C_i)` scattered slots plus
/// probe chains; the dense kernels touch `nnz(C_i)` streamed slots plus
/// a sequential scan costing one line per `line_bytes / 4` columns, so
/// the crossover sits at `2·4 / line_bytes = 0.25` (see
/// `benches/accumulator.rs` for the measured sweep, and the equality
/// test below pinning the constant to the derivation).
pub const DEFAULT_SPA_THRESHOLD: f64 = 0.25;

/// Pick the numeric accumulator for one output row (module-level
/// decision table). `a_row_nnz` is the row's entry count in A,
/// `row_nnz` the *exact* output size from the symbolic phase, `n_cols`
/// the output width. Rows with `row_nnz == 0` never reach the numeric
/// phase and should not be classified.
pub fn select_accumulator(a_row_nnz: usize, row_nnz: usize, n_cols: usize, spa_threshold: f64) -> AccumKind {
    if a_row_nnz == 1 {
        return AccumKind::ScaledCopy;
    }
    // Strict `>`: threshold 0.0 forces SPA on every multi-entry row with
    // output, and any threshold ≥ 1.0 disables SPA (nnz ≤ n_cols).
    if row_nnz as f64 > spa_threshold * n_cols as f64 {
        AccumKind::Spa
    } else {
        AccumKind::Hash
    }
}

/// Pick the symbolic counting kernel for one row (module-level decision
/// table). Unlike [`select_accumulator`] this runs *before* the
/// symbolic phase, so the decision comes from the IP upper bound `ip`,
/// capped at `n_cols` (unique count can never exceed the output
/// width). The comparison is strict on the capped bound, mirroring the
/// numeric rule's boundary semantics: `0.0` forces the bitmap on every
/// non-trivial row, any threshold ≥ 1.0 disables it.
pub fn select_symbolic(a_row_nnz: usize, ip: u64, n_cols: usize, threshold: f64) -> SymbolicKind {
    if ip <= 1 || a_row_nnz <= 1 {
        return SymbolicKind::Trivial;
    }
    let bound = ip.min(n_cols as u64);
    if bound as f64 > threshold * n_cols as f64 {
        SymbolicKind::Bitmap
    } else {
        SymbolicKind::Hash
    }
}

/// [`select_symbolic`] under an output mask (DESIGN.md §2i): the
/// unique-count bound tightens to `min(ip, mask_row_nnz, n_cols)` —
/// a masked row can never produce more entries than its mask row
/// admits — so dense-bound rows whose mask is narrow fall back to the
/// cheaper hash kernel. The trivial domain is the *unmasked* rule
/// (`ip ≤ 1` or a single A entry: candidates are collision-free, so
/// the masked-trivial kernel counts by sorted intersection) plus
/// `mask_row_nnz == 0`, where the count is 0 without touching B at
/// all.
pub fn select_symbolic_masked(
    a_row_nnz: usize,
    ip: u64,
    mask_row_nnz: usize,
    n_cols: usize,
    threshold: f64,
) -> SymbolicKind {
    if ip <= 1 || a_row_nnz <= 1 || mask_row_nnz == 0 {
        return SymbolicKind::Trivial;
    }
    let bound = ip.min(mask_row_nnz as u64).min(n_cols as u64);
    if bound as f64 > threshold * n_cols as f64 {
        SymbolicKind::Bitmap
    } else {
        SymbolicKind::Hash
    }
}

/// Thread-assignment strategy (paper §III-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Partial warp per row: 4 threads per row (group 0).
    Pwpr,
    /// Thread block per row (groups 1–3).
    Tbpr,
}

/// Per-group GPU resource allocation — Table I of the paper.
#[derive(Clone, Copy, Debug)]
pub struct GroupSpec {
    pub id: usize,
    pub ip_lo: u64,
    /// Inclusive upper bound (`u64::MAX` for group 3).
    pub ip_hi: u64,
    pub strategy: Strategy,
    pub block_size: usize,
    /// Shared-memory hash-table size; `None` = global-memory fallback
    /// (group 3), sized per row at runtime.
    pub table_size: Option<usize>,
}

impl GroupSpec {
    /// Rows processed by one thread block under this spec.
    pub fn rows_per_block(&self) -> usize {
        match self.strategy {
            Strategy::Pwpr => self.block_size / 4, // 4 threads per row
            Strategy::Tbpr => 1,
        }
    }
}

/// Table I, verbatim.
pub const GROUP_SPECS: [GroupSpec; 4] = [
    GroupSpec { id: 0, ip_lo: 0, ip_hi: 31, strategy: Strategy::Pwpr, block_size: 512, table_size: Some(64) },
    GroupSpec { id: 1, ip_lo: 32, ip_hi: 511, strategy: Strategy::Tbpr, block_size: 256, table_size: Some(1024) },
    GroupSpec { id: 2, ip_lo: 512, ip_hi: 8191, strategy: Strategy::Tbpr, block_size: 1024, table_size: Some(8192) },
    GroupSpec { id: 3, ip_lo: 8192, ip_hi: u64::MAX, strategy: Strategy::Tbpr, block_size: 1024, table_size: None },
];

/// Output of the row-grouping phase.
#[derive(Clone, Debug)]
pub struct Grouping {
    /// Row ids sorted by group (stable): `map[sorted_idx] = original row`.
    pub map: Vec<u32>,
    /// `group_of[row] = group id`.
    pub group_of: Vec<u8>,
    /// `ranges[g]` = the slice of `map` belonging to group g.
    pub ranges: [std::ops::Range<usize>; 4],
}

impl Grouping {
    /// Classify rows by IP count (counting sort by group, stable).
    pub fn build(ip: &[u64]) -> Grouping {
        let n = ip.len();
        let mut group_of = vec![0u8; n];
        let mut counts = [0usize; 4];
        for (i, &v) in ip.iter().enumerate() {
            let g = group_index_for_ip(v);
            group_of[i] = g as u8;
            counts[g] += 1;
        }
        let mut starts = [0usize; 4];
        for g in 1..4 {
            starts[g] = starts[g - 1] + counts[g - 1];
        }
        let ranges = [
            starts[0]..starts[0] + counts[0],
            starts[1]..starts[1] + counts[1],
            starts[2]..starts[2] + counts[2],
            starts[3]..starts[3] + counts[3],
        ];
        let mut map = vec![0u32; n];
        let mut next = starts;
        for (i, &g) in group_of.iter().enumerate() {
            map[next[g as usize]] = i as u32;
            next[g as usize] += 1;
        }
        Grouping { map, group_of, ranges }
    }

    pub fn group_rows(&self, g: usize) -> &[u32] {
        &self.map[self.ranges[g].clone()]
    }

    /// Number of thread blocks group `g` launches.
    pub fn blocks_in_group(&self, g: usize) -> usize {
        let rows = self.ranges[g].len();
        let per_block = GROUP_SPECS[g].rows_per_block();
        rows.div_ceil(per_block)
    }
}

/// Global-memory table size for a group-3 row: next power of two ≥ 2·IP
/// (load factor ≤ 0.5 keeps probe chains short on huge rows).
pub fn global_table_size(ip: u64) -> usize {
    ((ip.max(1) as usize) * 2).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_specs_match_paper() {
        assert_eq!(GROUP_SPECS[0].block_size, 512);
        assert_eq!(GROUP_SPECS[0].table_size, Some(64));
        assert_eq!(GROUP_SPECS[0].strategy, Strategy::Pwpr);
        assert_eq!(GROUP_SPECS[1].block_size, 256);
        assert_eq!(GROUP_SPECS[1].table_size, Some(1024));
        assert_eq!(GROUP_SPECS[2].block_size, 1024);
        assert_eq!(GROUP_SPECS[2].table_size, Some(8192));
        assert_eq!(GROUP_SPECS[3].table_size, None);
        assert!(GROUP_SPECS.iter().skip(1).all(|g| g.strategy == Strategy::Tbpr));
    }

    #[test]
    fn table_sizes_cover_group_ip_bounds() {
        // A shared table must hold every possible unique count in its
        // group: unique ≤ IP ≤ ip_hi < table_size.
        for spec in &GROUP_SPECS[..3] {
            let size = spec.table_size.unwrap() as u64;
            assert!(spec.ip_hi < size, "group {}: ip_hi {} ≥ table {}", spec.id, spec.ip_hi, size);
        }
    }

    #[test]
    fn grouping_is_stable_partition() {
        let ip = vec![10, 5000, 40, 0, 9000, 33, 600];
        let g = Grouping::build(&ip);
        assert_eq!(g.group_rows(0), &[0, 3]);
        assert_eq!(g.group_rows(1), &[2, 5]);
        assert_eq!(g.group_rows(2), &[1, 6]);
        assert_eq!(g.group_rows(3), &[4]);
        // map is a permutation
        let mut sorted = g.map.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn rows_per_block() {
        assert_eq!(GROUP_SPECS[0].rows_per_block(), 128); // 512 threads / 4
        assert_eq!(GROUP_SPECS[1].rows_per_block(), 1);
    }

    #[test]
    fn blocks_in_group_rounds_up() {
        let ip = vec![1u64; 300]; // all group 0, 128 rows per block
        let g = Grouping::build(&ip);
        assert_eq!(g.blocks_in_group(0), 3);
        assert_eq!(g.blocks_in_group(1), 0);
    }

    #[test]
    fn global_table_size_is_pow2_and_roomy() {
        assert_eq!(global_table_size(8192), 16384);
        assert!(global_table_size(10_000) >= 20_000);
        assert!(global_table_size(0).is_power_of_two());
    }

    #[test]
    fn accumulator_decision_table() {
        // Single-A-entry rows copy regardless of density.
        assert_eq!(select_accumulator(1, 1000, 1000, 0.25), AccumKind::ScaledCopy);
        assert_eq!(select_accumulator(1, 1, 1000, 0.25), AccumKind::ScaledCopy);
        // Sparse output rows hash, dense ones take the SPA.
        assert_eq!(select_accumulator(8, 10, 1000, 0.25), AccumKind::Hash);
        assert_eq!(select_accumulator(8, 600, 1000, 0.25), AccumKind::Spa);
    }

    #[test]
    fn spa_threshold_boundaries() {
        // 0.0 forces SPA on every multi-entry row with output...
        assert_eq!(select_accumulator(2, 1, 1_000_000, 0.0), AccumKind::Spa);
        // ...and ≥ 1.0 disables it, even for a fully dense row (strict >).
        assert_eq!(select_accumulator(2, 1000, 1000, 1.0), AccumKind::Hash);
        assert_eq!(select_accumulator(2, 1000, 1000, 2.0), AccumKind::Hash);
        // Exactly at the threshold stays on the hash path (strict >).
        assert_eq!(select_accumulator(2, 250, 1000, 0.25), AccumKind::Hash);
        assert_eq!(select_accumulator(2, 251, 1000, 0.25), AccumKind::Spa);
    }

    #[test]
    fn accum_kind_index_roundtrip() {
        for k in AccumKind::ALL {
            assert_eq!(AccumKind::from_index(k.index()), k);
        }
        assert_eq!(AccumKind::Spa.name(), "spa");
    }

    #[test]
    fn symbolic_kind_index_roundtrip() {
        for k in SymbolicKind::ALL {
            assert_eq!(SymbolicKind::from_index(k.index()), k);
        }
        assert_eq!(SymbolicKind::Bitmap.name(), "bitmap");
        let rk = RowKernel { symbolic: SymbolicKind::Bitmap, numeric: AccumKind::Spa };
        assert_eq!(rk.label(), "bitmap/spa");
    }

    #[test]
    fn symbolic_decision_table() {
        // Trivial short-circuits: IP ≤ 1 or a single A entry.
        assert_eq!(select_symbolic(1, 1000, 1000, 0.25), SymbolicKind::Trivial);
        assert_eq!(select_symbolic(8, 1, 1000, 0.25), SymbolicKind::Trivial);
        assert_eq!(select_symbolic(8, 0, 1000, 0.25), SymbolicKind::Trivial);
        // Sparse bound hashes, dense bound takes the bitmap.
        assert_eq!(select_symbolic(8, 100, 1000, 0.25), SymbolicKind::Hash);
        assert_eq!(select_symbolic(8, 600, 1000, 0.25), SymbolicKind::Bitmap);
        // The bound is capped at n_cols before comparing.
        assert_eq!(select_symbolic(8, 50_000, 1000, 0.25), SymbolicKind::Bitmap);
    }

    #[test]
    fn symbolic_threshold_boundaries() {
        // 0.0 forces the bitmap on every non-trivial row...
        assert_eq!(select_symbolic(2, 2, 1_000_000, 0.0), SymbolicKind::Bitmap);
        // ...and ≥ 1.0 disables it even when IP exceeds the width (the
        // capped bound can never beat n_cols under a strict compare).
        assert_eq!(select_symbolic(2, 1000, 1000, 1.0), SymbolicKind::Hash);
        assert_eq!(select_symbolic(2, 50_000, 1000, 1.0), SymbolicKind::Hash);
        assert_eq!(select_symbolic(2, 1000, 1000, 2.0), SymbolicKind::Hash);
        // Exactly at the threshold stays on the hash path (strict >).
        assert_eq!(select_symbolic(2, 250, 1000, 0.25), SymbolicKind::Hash);
        assert_eq!(select_symbolic(2, 251, 1000, 0.25), SymbolicKind::Bitmap);
    }

    #[test]
    fn masked_symbolic_decision_table() {
        // The trivial domain is the unmasked rule plus empty mask rows.
        assert_eq!(select_symbolic_masked(1, 1000, 500, 1000, 0.25), SymbolicKind::Trivial);
        assert_eq!(select_symbolic_masked(8, 1, 500, 1000, 0.25), SymbolicKind::Trivial);
        assert_eq!(select_symbolic_masked(8, 600, 0, 1000, 0.25), SymbolicKind::Trivial);
        // A wide mask changes nothing relative to the unmasked rule...
        assert_eq!(select_symbolic_masked(8, 600, 1000, 1000, 0.25), SymbolicKind::Bitmap);
        assert_eq!(select_symbolic_masked(8, 100, 1000, 1000, 0.25), SymbolicKind::Hash);
        // ...but a narrow mask caps the bound below the density cut, so
        // the same dense-bound row hashes instead of running the bitmap.
        assert_eq!(select_symbolic_masked(8, 600, 100, 1000, 0.25), SymbolicKind::Hash);
        // A narrow mask never flips a multi-source row to Trivial — the
        // trivial kernel's no-collision argument needs ip ≤ 1 or a
        // single A entry, not a small admitted set.
        assert_eq!(select_symbolic_masked(8, 600, 1, 1000, 0.25), SymbolicKind::Hash);
    }

    #[test]
    fn default_threshold_matches_cache_geometry_derivation() {
        // The constant is the H200 instantiation of the cache-geometry
        // crossover — if the device's sector size changes, this pins
        // the drift.
        let dev = crate::sim::DeviceConfig::h200_scaled();
        assert_eq!(DEFAULT_SPA_THRESHOLD, dev.dense_row_threshold_base());
    }
}
