//! The numeric (accumulation) phase: value fills into the plan's
//! pre-sized, disjoint output slices, one plan bin at a time.
//!
//! Each [`NumericBin`] is homogeneous in its row-kernel pair, so one
//! `par_dynamic_with` call per bin hands every worker exactly the
//! reusable state its accumulator needs (nothing for scaled copies, a
//! Table-I hash table, or a [`DenseAccumulator`] SPA). All three paths
//! are bit-identical — see the module docs of [`super`].

use super::super::grouping::{global_table_size, AccumKind, GROUP_SPECS};
use super::super::mask::{Mask, MaskRowProbe};
use super::super::table::{DenseAccumulator, HashTable};
use super::{bin_batch, bin_table, SymbolicPlan};
use crate::sim::probe::{Kind, NullProbe, PhaseTimes, Probe, Region};
use crate::sparse::Csr;
use crate::util::parallel::par_dynamic_with;
use std::time::Instant;

/// Numeric phase: accumulate values into the plan's pre-sized, disjoint
/// output slices, one plan bin at a time. The plan must come from
/// [`super::symbolic()`] on the same `(a, b)` pair.
pub fn numeric(a: &Csr, b: &Csr, plan: &SymbolicPlan) -> Csr {
    numeric_timed(a, b, plan).0
}

/// [`numeric()`] plus wall time: total numeric seconds and the split per
/// accumulator kind (only the `numeric*` fields of the returned
/// [`PhaseTimes`] are populated).
pub fn numeric_timed(a: &Csr, b: &Csr, plan: &SymbolicPlan) -> (Csr, PhaseTimes) {
    // Validate here, not only per bin: a plan with zero bins (empty
    // output) must still reject mismatched operands instead of handing
    // back a malformed Csr.
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    assert_eq!(plan.rpt.len(), a.n_rows + 1, "plan does not match A");
    // Timer covers the O(nnz) output allocation too, matching what the
    // plan-reuse fill timer has always measured (longitudinal bench
    // numbers depend on this).
    let t0 = Instant::now();
    let nnz_c = plan.nnz();
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut times = PhaseTimes::default();
    for bi in 0..plan.bins.len() {
        let t = Instant::now();
        numeric_bin_into(a, b, plan, bi, &mut col, &mut val);
        times.numeric_kind_s[plan.bins[bi].kind.index()] += t.elapsed().as_secs_f64();
    }
    times.numeric_s = t0.elapsed().as_secs_f64();
    (Csr::new_unchecked(a.n_rows, b.n_cols, plan.rpt.clone(), col, val), times)
}

/// Fill one numeric bin of `plan` into caller-owned output buffers
/// (`col`/`val` must be sized to `plan.nnz()`). Rows write disjoint
/// `[rpt[i], rpt[i+1])` slices, so bins of the same plan may be filled
/// in any order — this is the per-bin dispatch unit of the batch
/// pipeline's phase overlap.
pub fn numeric_bin_into(a: &Csr, b: &Csr, plan: &SymbolicPlan, bin_idx: usize, col: &mut [u32], val: &mut [f64]) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    assert_eq!(plan.rpt.len(), a.n_rows + 1, "plan does not match A");
    assert_eq!(col.len(), plan.nnz(), "output buffers must be sized to the plan");
    assert_eq!(val.len(), plan.nnz(), "output buffers must be sized to the plan");
    let bin = &plan.bins[bin_idx];
    let spec = &GROUP_SPECS[bin.group as usize];
    let rows = &bin.rows[..];
    let mask = plan.mask.as_ref();
    let col_ptr = col.as_mut_ptr() as usize;
    let val_ptr = val.as_mut_ptr() as usize;
    match (bin.kind, mask) {
        // Single-A-entry rows are scaled copies of one B row: already
        // sorted, collision-free — no accumulator, no sort.
        (AccumKind::ScaledCopy, None) => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (),
            |_, ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                let j = a.rpt[row];
                let av = a.val[j];
                let (bc, bv) = b.row(a.col[j] as usize);
                // Real assert, not debug: the pointer writes below are
                // bounded by the plan, so a plan/input mismatch must
                // panic rather than corrupt memory.
                assert_eq!(bc.len(), n_out, "plan does not match inputs at row {row}");
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, (&c, &v)) in bc.iter().zip(bv).enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = av * v;
                    }
                }
            },
        ),
        // Masked scaled copy: merge the (sorted) B row with the
        // (sorted) mask row, copying only admitted entries — output
        // order is still the B row's order, so the row is bit-identical
        // to filtering the unmasked copy.
        (AccumKind::ScaledCopy, Some(m)) => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (),
            |_, ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                let j = a.rpt[row];
                let av = a.val[j];
                let (bc, bv) = b.row(a.col[j] as usize);
                let mrow = m.row(row);
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                let (mut x, mut y, mut o) = (0usize, 0usize, 0usize);
                while x < bc.len() && y < mrow.len() {
                    match bc[x].cmp(&mrow[y]) {
                        std::cmp::Ordering::Less => x += 1,
                        std::cmp::Ordering::Greater => y += 1,
                        std::cmp::Ordering::Equal => {
                            // Real assert: bounds the unsafe writes, so a
                            // plan/input mismatch panics, never scribbles.
                            assert!(o < n_out, "plan does not match inputs at row {row}");
                            // SAFETY: rows write disjoint [rpt[i], rpt[i+1])
                            // slices, and o < n_out above.
                            unsafe {
                                *cp.add(start + o) = bc[x];
                                *vp.add(start + o) = av * bv[x];
                            }
                            o += 1;
                            x += 1;
                            y += 1;
                        }
                    }
                }
                assert_eq!(o, n_out, "plan does not match inputs at row {row}");
            },
        ),
        (AccumKind::Hash, None) => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (bin_table(spec), Vec::<(u32, f64)>::new()),
            |(table, scratch), ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                match spec.table_size {
                    Some(_) => table.clear(),
                    // Exact sizing from the symbolic count: 2·nnz(C_i)
                    // keeps load factor ≤ 0.5 and is far below the
                    // 2·IP_i the single-pass engine allocated for hub
                    // rows.
                    None => table.reset_with_capacity(global_table_size(n_out as u64)),
                }
                accum_row_fast(a, b, row, table, scratch);
                write_sorted_row(scratch, row, start, n_out, col_ptr, val_ptr);
            },
        ),
        (AccumKind::Hash, Some(m)) => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (bin_table(spec), Vec::<(u32, f64)>::new(), MaskRowProbe::new(b.n_cols)),
            |(table, scratch, admit), ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                match spec.table_size {
                    Some(_) => table.clear(),
                    None => table.reset_with_capacity(global_table_size(n_out as u64)),
                }
                accum_row_fast_masked(a, b, row, table, scratch, admit, m);
                write_sorted_row(scratch, row, start, n_out, col_ptr, val_ptr);
            },
        ),
        // Dense rows stream into a per-worker SPA: no probe chains, and
        // the accumulation order per column is identical to the hash
        // path's, so the sorted output is bit-identical.
        (AccumKind::Spa, None) => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (DenseAccumulator::new(b.n_cols), Vec::<(u32, f64)>::new()),
            |(spa, scratch), ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                spa.clear();
                accum_row_spa(a, b, row, spa, scratch);
                write_sorted_row(scratch, row, start, n_out, col_ptr, val_ptr);
            },
        ),
        (AccumKind::Spa, Some(m)) => par_dynamic_with(
            rows.len(),
            bin_batch(spec),
            || (DenseAccumulator::new(b.n_cols), Vec::<(u32, f64)>::new(), MaskRowProbe::new(b.n_cols)),
            |(spa, scratch, admit), ri| {
                let row = rows[ri] as usize;
                let start = plan.rpt[row];
                let n_out = plan.rpt[row + 1] - start;
                spa.clear();
                accum_row_spa_masked(a, b, row, spa, scratch, admit, m);
                write_sorted_row(scratch, row, start, n_out, col_ptr, val_ptr);
            },
        ),
    }
}

/// Shared epilogue of the hash and SPA arms of [`numeric_bin_into`]:
/// sort the gathered row (std sort — identical result to bitonic, keys
/// unique) and write it into the row's disjoint output slice.
///
/// The length assert is a real assert, not debug: it bounds the unsafe
/// writes below, so a stale/mismatched plan must panic, not scribble.
fn write_sorted_row(
    scratch: &mut [(u32, f64)],
    row: usize,
    start: usize,
    n_out: usize,
    col_ptr: usize,
    val_ptr: usize,
) {
    assert_eq!(scratch.len(), n_out, "symbolic/numeric disagree on row {row}");
    scratch.sort_unstable_by_key(|e| e.0);
    let cp = col_ptr as *mut u32;
    let vp = val_ptr as *mut f64;
    for (o, &(c, v)) in scratch.iter().enumerate() {
        // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
        unsafe {
            *cp.add(start + o) = c;
            *vp.add(start + o) = v;
        }
    }
}

/// Accumulation-phase row processor (Algorithm 5): numeric hash inserts
/// of every intermediate product, then whole-table gather into `scratch`
/// (unsorted — the caller sorts).
pub(crate) fn accum_row<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    table: &mut HashTable,
    scratch: &mut Vec<(u32, f64)>,
    probe: &mut P,
) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Accumulation streams both col_B and val_B.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB, Region::ValB], lo, hi);
        for k in lo..hi {
            table.insert_numeric(b.col[k], av * b.val[k], probe);
            probe.compute(1); // the multiply
        }
    }
    table.gather(scratch, probe);
}

/// Fast-path accumulation row processor: same inserts as [`accum_row`]
/// but gathers in O(unique) via the occupied list (no probe events).
pub(crate) fn accum_row_fast(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>) {
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            table.insert_numeric(b.col[k], av * b.val[k], &mut NullProbe);
        }
    }
    table.gather_list(scratch);
}

/// Dense-SPA accumulation row processor (plan-guided dense rows): same
/// intermediate products, same per-column accumulation order as the
/// hash path, but into `vals[col]` directly — no probing. Caller clears
/// the SPA and sorts `scratch`. `pub(crate)` so the speculative driver
/// ([`super::super::estimate`]) runs the byte-identical float sequence.
pub(crate) fn accum_row_spa(
    a: &Csr,
    b: &Csr,
    i: usize,
    spa: &mut DenseAccumulator,
    scratch: &mut Vec<(u32, f64)>,
) {
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            spa.add(b.col[k], av * b.val[k]);
        }
    }
    spa.gather_list(scratch);
}

/// Masked sibling of [`accum_row_fast`]: identical intermediate-product
/// stream, but each insert is gated on mask admission, so rejected
/// columns never touch the table. Admitted columns accumulate in the
/// same B-stream encounter order as the unmasked path — the surviving
/// float sums are bit-identical to filtering the unmasked row.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accum_row_fast_masked(
    a: &Csr,
    b: &Csr,
    i: usize,
    table: &mut HashTable,
    scratch: &mut Vec<(u32, f64)>,
    admit: &mut MaskRowProbe,
    mask: &Mask,
) {
    admit.seed(mask.row(i));
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            let c = b.col[k];
            if admit.admits(c) {
                table.insert_numeric(c, av * b.val[k], &mut NullProbe);
            }
        }
    }
    table.gather_list(scratch);
}

/// Masked sibling of [`accum_row_spa`]: gate each SPA add on mask
/// admission. Per-column accumulation order matches the masked hash
/// path (B-stream encounter order), keeping all masked paths
/// bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accum_row_spa_masked(
    a: &Csr,
    b: &Csr,
    i: usize,
    spa: &mut DenseAccumulator,
    scratch: &mut Vec<(u32, f64)>,
    admit: &mut MaskRowProbe,
    mask: &Mask,
) {
    admit.seed(mask.row(i));
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        let av = a.val[j];
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            let c = b.col[k];
            if admit.admits(c) {
                spa.add(c, av * b.val[k]);
            }
        }
    }
    spa.gather_list(scratch);
}

/// Traced dense-SPA row processor: the B rows are read as **plain
/// streamed loads** (never `indirect_range` — SPA rows are
/// AIA-ineligible by design, the gather/scatter engine buys nothing for
/// a row that streams into a contiguous accumulator), and the SPA
/// accesses land on [`Region::SpaVals`]/[`Region::SpaFlags`]. The
/// gather is the GPU's sequential scan, so `scratch` comes back sorted
/// by column — no bitonic network needed.
pub(crate) fn accum_row_spa_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    spa: &mut DenseAccumulator,
    scratch: &mut Vec<(u32, f64)>,
    probe: &mut P,
) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        probe.access(Region::RptB, colk, 4, Kind::Read);
        probe.access(Region::RptB, colk + 1, 4, Kind::Read);
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            probe.access(Region::ColB, k, 4, Kind::Read);
            probe.access(Region::ValB, k, 8, Kind::Read);
            spa.add_traced(b.col[k], av * b.val[k], probe);
            probe.compute(1); // the multiply
        }
    }
    spa.gather(scratch, probe);
}

#[cfg(test)]
mod tests {
    use super::super::testutil::dense_pair;
    use super::super::{multiply, multiply_cfg, multiply_timed, symbolic, EngineConfig, PlannerPolicy};
    use super::*;
    use crate::spgemm::reference::spgemm_reference;

    #[test]
    fn spa_and_hash_paths_are_bit_identical() {
        let (a, b) = dense_pair(101, 96);
        let spa_cfg = EngineConfig {
            spa_threshold: 0.0,
            symbolic_threshold: None,
            planner: PlannerPolicy::Exact,
            mask: None,
        };
        let forced_spa = multiply_cfg(&a, &b, &spa_cfg);
        let no_spa = multiply_cfg(&a, &b, &EngineConfig { spa_threshold: 2.0, ..spa_cfg.clone() });
        let default = multiply(&a, &b);
        // bit-for-bit across all accumulator selections
        assert_eq!(forced_spa, no_spa);
        assert_eq!(forced_spa, default);
        let r = spgemm_reference(&a, &b);
        assert!(forced_spa.approx_eq(&r, 1e-10));
    }

    #[test]
    fn masked_numeric_matches_filtered_oracle_across_accumulators() {
        use super::super::super::mask::Mask;
        use super::super::multiply_masked_cfg;
        use crate::util::Pcg32;

        // RMAT mixes 1-nnz rows (ScaledCopy) with hub rows, so all
        // three accumulator arms run; the threshold sweep flips the
        // dense rows between the hash and SPA arms.
        let mut rng = Pcg32::seeded(41);
        let a = crate::gen::rmat(96, 700, crate::gen::RmatParams::uniform(), &mut rng);
        let b = crate::gen::rmat(96, 700, crate::gen::RmatParams::uniform(), &mut rng);
        let mut mc = crate::sparse::Coo::new(a.n_rows, b.n_cols);
        for i in 0..a.n_rows {
            for jj in i.saturating_sub(7)..(i + 8).min(b.n_cols) {
                mc.push(i, jj, 1.0);
            }
        }
        let mask = Mask::from_structure(&mc.to_csr());
        let oracle = mask.filter(&multiply(&a, &b));
        for thr in [0.0, 2.0] {
            let cfg = EngineConfig {
                spa_threshold: thr,
                symbolic_threshold: None,
                planner: PlannerPolicy::Exact,
                mask: None,
            };
            let c = multiply_masked_cfg(&a, &b, &mask, &cfg);
            assert_eq!(c, oracle, "masked numeric must be bit-identical at spa_threshold {thr}");
        }
    }

    #[test]
    fn numeric_bin_into_fills_bins_in_any_order() {
        let (a, b) = dense_pair(33, 80);
        let plan = symbolic(&a, &b);
        let expect = numeric(&a, &b, &plan);
        let mut col = vec![0u32; plan.nnz()];
        let mut val = vec![0f64; plan.nnz()];
        for bi in (0..plan.bins.len()).rev() {
            numeric_bin_into(&a, &b, &plan, bi, &mut col, &mut val);
        }
        let c = Csr::new_unchecked(a.n_rows, b.n_cols, plan.rpt.clone(), col, val);
        assert_eq!(c, expect, "bins write disjoint slices — order must not matter");
    }

    #[test]
    fn timed_numeric_splits_by_kind() {
        let (a, b) = dense_pair(14, 96);
        let (c, t) = multiply_timed(&a, &b);
        assert!(c.nnz() > 0);
        let kind_total: f64 = t.numeric_kind_s.iter().sum();
        assert!(kind_total > 0.0, "per-kind numeric times must be recorded");
        assert!(kind_total <= t.numeric_s + 1e-9, "kind split cannot exceed the numeric total");
    }
}
