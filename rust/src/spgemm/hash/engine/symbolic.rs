//! The symbolic (allocation) phase: exact per-row output sizes through
//! plan-selected counting kernels.
//!
//! Every row's [`SymbolicKind`] is decided *before* counting, from the
//! IP upper bound (exact sizes do not exist yet —
//! [`super::super::grouping::select_symbolic`]): trivial rows skip
//! counting entirely, sparse-bound rows run Algorithms 2–3 symbolic
//! hash inserts, dense-bound rows count first touches in a
//! [`RowCounter`] bitmap — no probe chains, O(1) clear, identical
//! counts by construction. Each Table-I group is partitioned by kind
//! and the sub-bins run (and are timed) separately, which is where
//! [`PhaseTimes::symbolic_kind_s`] comes from.

use super::super::grouping::{
    global_table_size, select_accumulator, select_symbolic, select_symbolic_masked, AccumKind, GroupSpec,
    Grouping, SymbolicKind, GROUP_SPECS,
};
use super::super::mask::{Mask, MaskRowProbe};
use super::super::table::{HashTable, RowCounter};
use super::{bin_batch, bin_table, effective_thresholds, EngineConfig, NumericBin, SymbolicPlan};
use crate::sim::probe::{Kind, NullProbe, PhaseTimes, Probe, Region};
use crate::spgemm::ip::intermediate_products;
use crate::sparse::Csr;
use crate::util::parallel::par_dynamic_with;
use std::time::Instant;

/// Symbolic phase: IP estimation, row binning, exact per-row output
/// sizes, and the per-row kernel decision — at the process-default
/// [`EngineConfig`].
pub fn symbolic(a: &Csr, b: &Csr) -> SymbolicPlan {
    symbolic_cfg(a, b, &EngineConfig::default())
}

/// [`symbolic()`] with an explicit [`EngineConfig`]: the threshold decides
/// which rows count through the bitmap and which rows the numeric phase
/// will run through the dense SPA.
///
/// ```
/// use spgemm_aia::sparse::Csr;
/// use spgemm_aia::spgemm::hash::{symbolic_cfg, AccumKind, EngineConfig, PlannerPolicy};
///
/// // Row 0 of C = A·B is fully dense (4/4 columns), row 1 comes from a
/// // single A entry.
/// let a = Csr::from_dense(&[vec![1.0, 1.0], vec![1.0, 0.0]]);
/// let b = Csr::from_dense(&[
///     vec![1.0, 1.0, 0.0, 0.0],
///     vec![0.0, 0.0, 1.0, 1.0],
/// ]);
/// let plan = symbolic_cfg(&a, &b, &EngineConfig { spa_threshold: 0.5, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None });
/// assert_eq!(plan.accumulator_kind(0), Some(AccumKind::Spa));
/// assert_eq!(plan.accumulator_kind(1), Some(AccumKind::ScaledCopy));
/// // Raising the threshold past 1.0 disables the SPA entirely.
/// let plan = symbolic_cfg(&a, &b, &EngineConfig { spa_threshold: 2.0, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None });
/// assert_eq!(plan.accumulator_kind(0), Some(AccumKind::Hash));
/// ```
pub fn symbolic_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> SymbolicPlan {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    symbolic_with(a, b, ip, grouping, cfg).0
}

/// The symbolic half of [`super::multiply_timed`]: grouping + symbolic
/// analysis with per-stage wall times (`numeric_s` left 0, the
/// per-kernel symbolic split populated). Shared with the plan-reuse
/// layer so phase attribution stays identical between cold multiplies
/// and planned products.
pub(crate) fn symbolic_timed(a: &Csr, b: &Csr, cfg: &EngineConfig) -> (SymbolicPlan, PhaseTimes) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let t0 = Instant::now();
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);
    let grouping_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let (plan, symbolic_kind_s) = symbolic_with(a, b, ip, grouping, cfg);
    let symbolic_s = t1.elapsed().as_secs_f64();

    (plan, PhaseTimes { grouping_s, symbolic_s, symbolic_kind_s, ..PhaseTimes::default() })
}

/// Symbolic counting given precomputed IP + bins (shared by
/// [`symbolic_cfg`] and [`symbolic_timed`], which times the stages
/// apart). Returns the plan plus the wall seconds each counting kernel
/// spent, indexed by [`SymbolicKind::index`].
fn symbolic_with(
    a: &Csr,
    b: &Csr,
    ip: Vec<u64>,
    grouping: Grouping,
    cfg: &EngineConfig,
) -> (SymbolicPlan, [f64; 3]) {
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);
    let mask = cfg.mask.as_ref();
    if let Some(m) = mask {
        assert_eq!(
            m.shape(),
            (a.n_rows, b.n_cols),
            "mask shape must equal the output shape a.n_rows x b.n_cols"
        );
    }
    // --- symbolic kernel selection: per row, from the IP bound (the
    // masked rule additionally caps the bound by the mask row's size
    // and routes empty-mask rows through the trivial kernel) ---
    let mut sym = vec![SymbolicKind::Trivial; a.n_rows];
    for (r, k) in sym.iter_mut().enumerate() {
        *k = match mask {
            None => select_symbolic(a.row_nnz(r), ip[r], b.n_cols, sym_threshold),
            Some(m) => select_symbolic_masked(a.row_nnz(r), ip[r], m.row_nnz(r), b.n_cols, sym_threshold),
        };
    }
    // --- counting, one (group × kernel) sub-bin at a time ---
    let mut row_nnz = vec![0u32; a.n_rows];
    let mut symbolic_kind_s = [0f64; 3];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for spec in &GROUP_SPECS {
            let rows = grouping.group_rows(spec.id);
            if rows.is_empty() {
                continue;
            }
            let mut parts: [Vec<u32>; 3] = [Vec::new(), Vec::new(), Vec::new()];
            for &row in rows {
                parts[sym[row as usize].index()].push(row);
            }
            let ip = &ip;
            for (ki, part) in parts.iter().enumerate() {
                if part.is_empty() {
                    continue;
                }
                let t0 = Instant::now();
                match (SymbolicKind::from_index(ki), mask) {
                    // Collisions impossible: a single A entry reaches one
                    // B row (whose columns are unique by CSR invariant),
                    // and IP ≤ 1 yields at most one product — the count
                    // *is* the IP bound.
                    (SymbolicKind::Trivial, None) => {
                        for &row in part {
                            let row = row as usize;
                            // SAFETY: each row index occurs once across
                            // all sub-bins, so every `row_nnz` slot is
                            // written exactly once, and the Vec outlives
                            // the scope.
                            unsafe { *(nnz_ptr as *mut u32).add(row) = ip[row] as u32 };
                        }
                    }
                    // The masked-trivial count is the sorted intersection
                    // of the (collision-free) candidate stream with the
                    // mask row — the IP shortcut would overcount.
                    (SymbolicKind::Trivial, Some(m)) => par_dynamic_with(
                        part.len(),
                        bin_batch(spec),
                        || (),
                        |_, ri| {
                            let row = part[ri] as usize;
                            let u = symbolic_row_nnz_trivial_masked(a, b, row, m);
                            // SAFETY: see above — disjoint slots.
                            unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                        },
                    ),
                    (SymbolicKind::Hash, None) => par_dynamic_with(
                        part.len(),
                        bin_batch(spec),
                        || bin_table(spec),
                        |table, ri| {
                            let row = part[ri] as usize;
                            let u = symbolic_row_nnz_hash(a, b, row, ip[row], spec, table);
                            // SAFETY: see above — disjoint slots.
                            unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                        },
                    ),
                    (SymbolicKind::Hash, Some(m)) => par_dynamic_with(
                        part.len(),
                        bin_batch(spec),
                        || (bin_table(spec), MaskRowProbe::new(b.n_cols)),
                        |(table, admit), ri| {
                            let row = part[ri] as usize;
                            let u = symbolic_row_nnz_hash_masked(a, b, row, ip[row], spec, table, admit, m);
                            // SAFETY: see above — disjoint slots.
                            unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                        },
                    ),
                    (SymbolicKind::Bitmap, None) => par_dynamic_with(
                        part.len(),
                        bin_batch(spec),
                        || RowCounter::new(b.n_cols),
                        |counter, ri| {
                            let row = part[ri] as usize;
                            let u = symbolic_row_nnz_bitmap(a, b, row, counter);
                            // SAFETY: see above — disjoint slots.
                            unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                        },
                    ),
                    (SymbolicKind::Bitmap, Some(m)) => par_dynamic_with(
                        part.len(),
                        bin_batch(spec),
                        || (RowCounter::new(b.n_cols), MaskRowProbe::new(b.n_cols)),
                        |(counter, admit), ri| {
                            let row = part[ri] as usize;
                            let u = symbolic_row_nnz_bitmap_masked(a, b, row, counter, admit, m);
                            // SAFETY: see above — disjoint slots.
                            unsafe { *(nnz_ptr as *mut u32).add(row) = u };
                        },
                    ),
                }
                symbolic_kind_s[ki] += t0.elapsed().as_secs_f64();
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let (accum, bins) = build_bins(a, b.n_cols, &ip, &grouping, &rpt, &sym, num_threshold);
    let plan = SymbolicPlan {
        ip,
        grouping,
        rpt,
        accum,
        symbolic: sym,
        bins,
        spa_threshold: cfg.spa_threshold,
        mask: cfg.mask.clone(),
    };
    (plan, symbolic_kind_s)
}

/// Accumulator selection + bin construction: exact sizes are known
/// (`rpt`), so the numeric kind per row — and with it the numeric work
/// list — costs one pass. Bins are split by the full (symbolic,
/// numeric) kernel pair so the pair survives into the scheduler and
/// the metrics; within a bin rows stay in ascending id order (the
/// grouping's stable sort), which makes bins a pure function of
/// (grouping, rpt, sym) — the incremental replanner
/// ([`super::super::incremental`]) rebuilds them wholesale and gets
/// bit-identical bins to a cold plan by construction.
pub(crate) fn build_bins(
    a: &Csr,
    b_n_cols: usize,
    ip: &[u64],
    grouping: &Grouping,
    rpt: &[usize],
    sym: &[SymbolicKind],
    num_threshold: f64,
) -> (Vec<AccumKind>, Vec<NumericBin>) {
    let mut accum = vec![AccumKind::ScaledCopy; a.n_rows];
    let mut bins = Vec::new();
    for spec in &GROUP_SPECS {
        let mut parts: [[Vec<u32>; 3]; 3] = Default::default();
        let mut weights = [[0u64; 3]; 3];
        for &row in grouping.group_rows(spec.id) {
            let r = row as usize;
            let n_out = rpt[r + 1] - rpt[r];
            if n_out == 0 {
                continue; // never reaches the numeric phase
            }
            let kind = select_accumulator(a.row_nnz(r), n_out, b_n_cols, num_threshold);
            accum[r] = kind;
            let (si, ni) = (sym[r].index(), kind.index());
            parts[si][ni].push(row);
            weights[si][ni] += ip[r];
        }
        for (si, by_numeric) in parts.into_iter().enumerate() {
            for (ni, rows) in by_numeric.into_iter().enumerate() {
                if !rows.is_empty() {
                    bins.push(NumericBin {
                        group: spec.id as u8,
                        kind: AccumKind::from_index(ni),
                        symbolic_kind: SymbolicKind::from_index(si),
                        rows,
                        weight: weights[si][ni],
                    });
                }
            }
        }
    }
    (accum, bins)
}

/// Exact nnz of one output row via symbolic hash inserts (the hash
/// counting kernel — callers have already routed trivial rows away).
/// `pub(crate)` so the incremental replanner can recount exactly the
/// dirty rows with the identical kernel a cold plan would run.
pub(crate) fn symbolic_row_nnz_hash(
    a: &Csr,
    b: &Csr,
    row: usize,
    ip_row: u64,
    spec: &GroupSpec,
    table: &mut HashTable,
) -> u32 {
    if ip_row <= 1 || a.row_nnz(row) <= 1 {
        return ip_row as u32;
    }
    match spec.table_size {
        Some(_) => table.clear(),
        // Unique count is bounded by both IP and the output width, so
        // hub rows never allocate beyond 2·n_cols.
        None => table.reset_with_capacity(global_table_size(ip_row.min(b.n_cols as u64))),
    }
    alloc_row(a, b, row, table, &mut NullProbe)
}

/// Exact nnz of one output row via the dense bitmap counter (the
/// bitmap counting kernel): first-touch counting, no probe chains, no
/// gather — the count is the CAS-success tally.
pub(crate) fn symbolic_row_nnz_bitmap(a: &Csr, b: &Csr, row: usize, counter: &mut RowCounter) -> u32 {
    counter.clear();
    for j in a.row_range(row) {
        let colk = a.col[j] as usize;
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            counter.count(b.col[k]);
        }
    }
    counter.unique() as u32
}

/// Entries shared by two strictly sorted column lists (two-pointer
/// merge). Only valid for counting when the caller guarantees the
/// candidate stream is collision-free — which the trivial domain does.
fn sorted_intersection_count(x: &[u32], y: &[u32]) -> u32 {
    let (mut i, mut k, mut n) = (0usize, 0usize, 0u32);
    while i < x.len() && k < y.len() {
        match x[i].cmp(&y[k]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => k += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                k += 1;
            }
        }
    }
    n
}

/// Masked-trivial counting kernel: exact masked nnz of a row in the
/// trivial domain (`IP ≤ 1` or a single A entry — candidates are
/// collision-free, so the count is the sorted intersection of each
/// reached B row with the mask row). The unmasked IP shortcut is
/// **invalid** under a mask: it would count rejected columns. Empty
/// mask rows (the third trivial case
/// [`select_symbolic_masked`] adds) return 0 without touching B.
pub(crate) fn symbolic_row_nnz_trivial_masked(a: &Csr, b: &Csr, row: usize, mask: &Mask) -> u32 {
    let mrow = mask.row(row);
    if mrow.is_empty() {
        return 0;
    }
    let mut n = 0u32;
    for j in a.row_range(row) {
        let colk = a.col[j] as usize;
        n += sorted_intersection_count(&b.col[b.rpt[colk]..b.rpt[colk + 1]], mrow);
    }
    n
}

/// Masked hash counting kernel: [`symbolic_row_nnz_hash`] probing the
/// mask before every insert, so rejected columns never enter the table
/// — the count is the *masked* exact size and the table is bounded by
/// the mask row, not the IP bound. `admit` is the per-worker stamped
/// membership probe, seeded once per row.
#[allow(clippy::too_many_arguments)]
pub(crate) fn symbolic_row_nnz_hash_masked(
    a: &Csr,
    b: &Csr,
    row: usize,
    ip_row: u64,
    spec: &GroupSpec,
    table: &mut HashTable,
    admit: &mut MaskRowProbe,
    mask: &Mask,
) -> u32 {
    let mrow = mask.row(row);
    if mrow.is_empty() {
        return 0;
    }
    if ip_row <= 1 || a.row_nnz(row) <= 1 {
        return symbolic_row_nnz_trivial_masked(a, b, row, mask);
    }
    match spec.table_size {
        Some(_) => table.clear(),
        // Unique count is bounded by IP, the output width, *and* the
        // mask row — hub rows with narrow masks stay small.
        None => {
            table.reset_with_capacity(global_table_size(ip_row.min(b.n_cols as u64).min(mrow.len() as u64)))
        }
    }
    admit.seed(mrow);
    for j in a.row_range(row) {
        let colk = a.col[j] as usize;
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            let c = b.col[k];
            if admit.admits(c) {
                table.insert_symbolic(c, &mut NullProbe);
            }
        }
    }
    table.unique as u32
}

/// Masked bitmap counting kernel: [`symbolic_row_nnz_bitmap`] probing
/// the mask before every first-touch count.
pub(crate) fn symbolic_row_nnz_bitmap_masked(
    a: &Csr,
    b: &Csr,
    row: usize,
    counter: &mut RowCounter,
    admit: &mut MaskRowProbe,
    mask: &Mask,
) -> u32 {
    let mrow = mask.row(row);
    if mrow.is_empty() {
        return 0;
    }
    counter.clear();
    admit.seed(mrow);
    for j in a.row_range(row) {
        let colk = a.col[j] as usize;
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            let c = b.col[k];
            if admit.admits(c) {
                counter.count(c);
            }
        }
    }
    counter.unique() as u32
}

/// Allocation-phase row processor (Algorithms 2–3 minus the thread
/// bookkeeping): symbolic hash inserts of every B-column reachable from
/// row `i` of A. Returns the unique count (= nnz of output row).
pub(crate) fn alloc_row<P: Probe>(a: &Csr, b: &Csr, i: usize, table: &mut HashTable, probe: &mut P) -> u32 {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        // Two-level indirection on B, allocation needs col_B only.
        probe.indirect_range(Region::RptB, colk, &[Region::ColB], lo, hi);
        for k in lo..hi {
            table.insert_symbolic(b.col[k], probe);
        }
    }
    table.unique as u32
}

/// Traced bitmap counting row processor: the B rows are read as **plain
/// streamed loads** (never `indirect_range` — bitmap rows are
/// AIA-ineligible by design, mirroring the numeric SPA's pricing), and
/// the counter accesses land on `Region::SpaFlags`. No gather scan
/// follows: on the GPU the unique count is the tally of successful
/// flag CASes, reduced per block.
pub(crate) fn alloc_row_bitmap_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    counter: &mut RowCounter,
    probe: &mut P,
) -> u32 {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        probe.access(Region::RptB, colk, 4, Kind::Read);
        probe.access(Region::RptB, colk + 1, 4, Kind::Read);
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            probe.access(Region::ColB, k, 4, Kind::Read);
            counter.count_traced(b.col[k], probe);
        }
    }
    counter.unique() as u32
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{dense_pair, random_csr};
    use super::super::{numeric, PlannerPolicy};
    use super::*;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::Pcg32;

    #[test]
    fn symbolic_plan_is_exact() {
        let mut rng = Pcg32::seeded(17);
        let a = random_csr(&mut rng, 120, 100, 0.05);
        let b = random_csr(&mut rng, 100, 90, 0.05);
        let plan = symbolic(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert_eq!(plan.rpt, r.rpt, "symbolic sizes must be exact, not bounds");
        assert_eq!(plan.nnz(), r.nnz());
        let c = numeric(&a, &b, &plan);
        assert!(c.approx_eq(&r, 1e-10));
    }

    #[test]
    fn threshold_boundaries_select_kinds() {
        let (a, b) = dense_pair(7, 64);
        // 0.0 forces SPA on every multi-entry row: no hash bins remain.
        let cfg =
            EngineConfig { spa_threshold: 0.0, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None };
        let plan = symbolic_cfg(&a, &b, &cfg);
        assert!(plan.bins.iter().all(|bin| bin.kind != AccumKind::Hash), "0.0 must force SPA");
        assert!(plan.kind_rows()[AccumKind::Spa.index()] > 0);
        // ≥ 1.0 disables SPA entirely.
        for thr in [1.0, 1.5] {
            let cfg = EngineConfig { spa_threshold: thr, ..cfg.clone() };
            let plan = symbolic_cfg(&a, &b, &cfg);
            assert!(plan.bins.iter().all(|bin| bin.kind != AccumKind::Spa), "{thr} must disable SPA");
        }
    }

    #[test]
    fn symbolic_kernel_follows_the_ip_bound_rule() {
        let mut rng = Pcg32::seeded(41);
        let a = random_csr(&mut rng, 200, 180, 0.04);
        let b = random_csr(&mut rng, 180, 150, 0.04);
        let cfg =
            EngineConfig { spa_threshold: 0.25, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None };
        let plan = symbolic_cfg(&a, &b, &cfg);
        for r in 0..a.n_rows {
            let expect = select_symbolic(a.row_nnz(r), plan.ip[r], b.n_cols, 0.25);
            assert_eq!(plan.symbolic_kind(r), expect, "row {r} kernel must follow the IP-bound rule");
        }
        assert_eq!(plan.symbolic_kind_rows().iter().sum::<usize>(), a.n_rows);
        // A symbolic override rewires only the counting kernel, never
        // the sizes or the numeric kinds.
        let forced = symbolic_cfg(&a, &b, &EngineConfig { symbolic_threshold: Some(0.0), ..cfg.clone() });
        assert_eq!(forced.rpt, plan.rpt);
        assert_eq!(forced.accum, plan.accum);
        assert!(
            (0..a.n_rows).all(|r| forced.symbolic_kind(r) != SymbolicKind::Hash),
            "symbolic_threshold 0.0 must force the bitmap on every non-trivial row"
        );
    }

    #[test]
    fn plan_bins_partition_nonempty_rows() {
        let mut rng = Pcg32::seeded(55);
        let a = random_csr(&mut rng, 300, 260, 0.03);
        let b = random_csr(&mut rng, 260, 240, 0.03);
        let plan = symbolic(&a, &b);
        let mut seen = vec![false; a.n_rows];
        for bin in &plan.bins {
            assert!(!bin.rows.is_empty(), "empty bins must be dropped");
            for &r in &bin.rows {
                assert!(!seen[r as usize], "row {r} appears in two bins");
                seen[r as usize] = true;
                assert_eq!(plan.accumulator_kind(r as usize), Some(bin.kind));
                assert_eq!(plan.symbolic_kind(r as usize), bin.symbolic_kind);
                assert_eq!(plan.row_kernel(r as usize), Some(bin.kernel()));
                assert_eq!(plan.grouping.group_of[r as usize], bin.group);
            }
            assert_eq!(bin.weight, bin.rows.iter().map(|&r| plan.ip[r as usize]).sum::<u64>());
        }
        for r in 0..a.n_rows {
            assert_eq!(seen[r], plan.row_nnz(r) > 0, "row {r} binned iff it has output");
            if plan.row_nnz(r) == 0 {
                assert_eq!(plan.accumulator_kind(r), None);
                assert_eq!(plan.row_kernel(r), None);
            }
        }
    }

    #[test]
    fn timed_symbolic_splits_by_kernel() {
        // Dense product at a forced-bitmap threshold: the bitmap kernel
        // must be the one accumulating symbolic seconds.
        let (a, b) = dense_pair(14, 96);
        let cfg = EngineConfig {
            spa_threshold: 0.25,
            symbolic_threshold: Some(0.0),
            planner: PlannerPolicy::Exact,
            mask: None,
        };
        let (plan, t) = symbolic_timed(&a, &b, &cfg);
        assert!(plan.symbolic_kind_rows()[SymbolicKind::Bitmap.index()] > 0);
        assert!(t.symbolic_kind_s[SymbolicKind::Bitmap.index()] > 0.0, "bitmap seconds must be recorded");
        assert_eq!(t.symbolic_kind_s[SymbolicKind::Hash.index()], 0.0, "no hash sub-bin ran");
        assert!(t.symbolic_kind_s.iter().sum::<f64>() <= t.symbolic_s + 1e-9);
    }

    #[test]
    fn masked_symbolic_counts_are_exact_and_never_exceed_unmasked() {
        use super::super::super::mask::Mask;
        let mut rng = Pcg32::seeded(61);
        let a = random_csr(&mut rng, 150, 130, 0.05);
        let b = random_csr(&mut rng, 130, 110, 0.05);
        let unmasked = symbolic(&a, &b);
        let oracle = spgemm_reference(&a, &b);
        // Mask = a band over the (rectangular) output shape; exercise
        // every kernel by sweeping the threshold from forced-bitmap to
        // forced-hash.
        let mut coo = crate::sparse::Coo::new(a.n_rows, b.n_cols);
        for i in 0..a.n_rows {
            for j in i.saturating_sub(9)..(i + 10).min(b.n_cols) {
                coo.push(i, j, 1.0);
            }
        }
        let mask = Mask::from_structure(&coo.to_csr());
        for sym_thr in [Some(0.0), Some(8.0), None] {
            let cfg = EngineConfig {
                spa_threshold: 0.25,
                symbolic_threshold: sym_thr,
                planner: PlannerPolicy::Exact,
                mask: Some(mask.clone()),
            };
            let plan = symbolic_cfg(&a, &b, &cfg);
            let expect = mask.filter(&oracle);
            assert_eq!(plan.rpt, expect.rpt, "masked symbolic sizes must be exact (thr {sym_thr:?})");
            for r in 0..a.n_rows {
                assert!(plan.row_nnz(r) <= unmasked.row_nnz(r), "masked count exceeds unmasked on row {r}");
            }
        }
    }
}
