//! The hash-based multi-phase SpGEMM engine (paper §III), structured as
//! the paper's true pipeline:
//!
//! 1. **grouping** — per-row intermediate-product upper bounds
//!   (Algorithm 1) binned into the Table I row categories;
//! 2. **symbolic** — per-row *exact* output sizes ([`symbolic()`]:
//!   Algorithms 2–3 hash inserts, or a dense bitmap counter on rows
//!   whose IP bound crosses the density threshold), producing the
//!   output row pointers;
//! 3. **numeric** — value accumulation into pre-sized, disjoint output
//!   slices ([`numeric()`]: Algorithm 5), with PWPR / TBPR thread
//!   assignment per Table I.
//!
//! Each phase is parallelised bin-by-bin through
//! [`crate::util::parallel::par_dynamic_with`]: every worker owns one
//! reusable kernel state (hash table, bitmap counter, or SPA, plus
//! gather scratch in the numeric phase) that survives across all rows
//! it processes — no per-row allocation. `Probe` below refers to
//! [`crate::sim::probe::Probe`]; the fast path's
//! [`crate::sim::probe::NullProbe`] compiles to nothing.
//!
//! # The row-kernel abstraction
//!
//! Both phases run the same play: pick a per-row kernel at plan time,
//! then execute homogeneous (group × kernel) sub-bins with reusable
//! per-worker state. The pair of decisions is the
//! [`super::grouping::RowKernel`]:
//!
//! - the **symbolic kind** ([`SymbolicKind`]: trivial / hash / bitmap)
//!   is decided *before* the symbolic phase from the IP upper bound
//!   (exact sizes do not exist yet) — bitmap rows count uniques through
//!   a [`super::table::RowCounter`], the counting counterpart of the
//!   numeric SPA;
//! - the **numeric kind** ([`AccumKind`]: scaled-copy / hash / SPA) is
//!   decided *after* it, from the exact `nnz(C_i)` the symbolic phase
//!   produced.
//!
//! Both selections share the [`EngineConfig::spa_threshold`] knob,
//! whose default derives from the simulated device's cache geometry
//! (see [`crate::sim::DeviceConfig::dense_row_threshold_base`]) and
//! which the engine scales up when one dense row stops fitting in the
//! per-resident-block L2 share. The dense kernels of both phases are
//! priced as **streaming / AIA-ineligible** by the simulator (plain
//! `SpaVals`/`SpaFlags` accesses and sequential B loads, never
//! [`crate::sim::probe::Probe::indirect_range`]).
//!
//! # The symbolic → numeric contract
//!
//! The symbolic phase produces a [`SymbolicPlan`]: *exact* output row
//! pointers, the Table-I row grouping, the per-row IP bounds, the
//! per-row kernel pair, and the numeric work list itself
//! ([`SymbolicPlan::bins`] — every Table-I bin split by kernel pair
//! into homogeneous [`NumericBin`]s). All numeric paths are
//! **bit-identical**: per-column accumulation order is the B-stream
//! encounter order in each, and the final sort is over unique keys.
//! The numeric phase ([`numeric()`] / [`numeric_bin_into`]) only
//! consumes the plan; callers may fill bins one at a time (the
//! per-bin overlap pipeline in `coordinator::batch` does) or all at
//! once.
//!
//! Entry points:
//! - [`multiply`] / [`multiply_timed`] — the fast functional path
//!   ([`crate::sim::probe::NullProbe`] instrumentation compiles away); `_timed` also
//!   reports wall time per phase as a [`PhaseTimes`], with the numeric
//!   seconds split per accumulator kind and the symbolic seconds split
//!   per counting kernel; `_cfg` variants take an explicit
//!   [`EngineConfig`] (threshold knobs);
//! - [`symbolic()`] + [`numeric()`] — the two phases as separate calls, for
//!   callers that reuse a plan (or inspect it); iterative callers should
//!   prefer the validated handle [`super::plan::PlannedProduct`], which
//!   binds a plan to the operands' structure hashes and amortises the
//!   symbolic phase across numeric fills;
//! - [`multiply_single_pass`] — the seed engine kept as the regression
//!   baseline for `benches/spgemm_selfproduct.rs`;
//! - [`multiply_traced`] / [`multiply_traced_cfg`] — deterministic
//!   sequential path that emits the full memory trace through a
//!   [`crate::sim::probe::Probe`], in thread-block program order, for the AIA simulator;
//!   bitmap-symbolic and SPA-numeric rows emit plain streaming
//!   accesses instead of `indirect_range`.

mod numeric;
mod symbolic;
mod traced;

pub use numeric::{numeric, numeric_bin_into, numeric_timed};
pub(crate) use numeric::accum_row_spa;
pub use symbolic::{symbolic, symbolic_cfg};
pub(crate) use symbolic::{
    build_bins, symbolic_row_nnz_bitmap, symbolic_row_nnz_bitmap_masked, symbolic_row_nnz_hash,
    symbolic_row_nnz_hash_masked, symbolic_row_nnz_trivial_masked, symbolic_timed,
};
pub use traced::{
    multiply_single_pass, multiply_traced, multiply_traced_cfg, multiply_traced_stats, multiply_traced_stats_cfg,
};

use super::estimate::{default_planner_policy, PlannerPolicy};
use super::grouping::{AccumKind, GroupSpec, Grouping, RowKernel, Strategy, SymbolicKind, GROUP_SPECS};
use super::mask::Mask;
use super::table::{HashTable, TableLoc};
use crate::sim::gpu::DeviceConfig;
use crate::sim::probe::PhaseTimes;
use crate::sparse::Csr;
use std::sync::OnceLock;

/// Tunables of the plan-guided row kernels. (`Clone` but not `Copy`:
/// the optional mask holds an `Arc`d structure view.)
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Density threshold of the dense row kernels: a row switches from
    /// hash to dense-SPA accumulation when `nnz(C_i) / n_cols`
    /// **exceeds** this value (strict, so `0.0` forces SPA on every
    /// multi-entry row and any value ≥ 1.0 disables it), and — unless
    /// [`EngineConfig::symbolic_threshold`] overrides — from hash to
    /// bitmap unique-counting when the capped IP bound does. See
    /// [`super::grouping::select_accumulator`] and
    /// [`super::grouping::select_symbolic`] for the full decision
    /// tables. The engine scales the knob by the simulated device's
    /// L2-overflow factor for the output width and clamps to the CLI's
    /// `[0, 8]` range (cache-adaptive — the same composition
    /// [`crate::sim::DeviceConfig::dense_row_threshold`] provides for
    /// the geometric base).
    pub spa_threshold: f64,
    /// Separate density threshold for the *symbolic* bitmap counter,
    /// decided from the IP upper bound. `None` (the default) uses
    /// [`EngineConfig::spa_threshold`] for both phases; tests and
    /// benches pin the counting kernel with `Some(0.0)` (bitmap
    /// everywhere) / `Some(8.0)` (hash everywhere).
    pub symbolic_threshold: Option<f64>,
    /// Which symbolic planner policy-aware call sites run
    /// ([`PlannerPolicy`]): exact (default), estimated (speculate on
    /// cold one-shot products), or auto. The engine's own entry points
    /// ([`multiply`], [`symbolic()`]) are always exact — the policy is
    /// consulted by the coordinator/serve layers, which route cold
    /// one-shot products through
    /// [`super::estimate::multiply_estimated`] when it speculates.
    pub planner: PlannerPolicy,
    /// Output mask for masked SpGEMM `C = M ⊙ (A·B)` (DESIGN.md §2i).
    /// When present, the symbolic phase counts only mask-admitted
    /// columns (so `rpt` is the *masked* exact size — never the
    /// unmasked one), the numeric phase never materializes a rejected
    /// entry, and the mask's structure hash joins the plan key. The
    /// mask's shape must equal the output shape
    /// (`a.n_rows × b.n_cols`). Masked products never speculate —
    /// policy-aware call sites route them through the exact planner
    /// regardless of [`EngineConfig::planner`].
    pub mask: Option<Mask>,
}

impl Default for EngineConfig {
    /// The process-wide default threshold: the value set by
    /// [`set_default_spa_threshold`] (the CLI's `--spa-threshold`), else
    /// the `SPGEMM_AIA_SPA_THRESHOLD` env var, else the cache-geometry
    /// derivation for the simulated device
    /// ([`super::grouping::DEFAULT_SPA_THRESHOLD`] is its H200 value).
    /// The planner policy defaults analogously (`--planner`, else
    /// `SPGEMM_AIA_PLANNER`, else exact).
    fn default() -> EngineConfig {
        EngineConfig {
            spa_threshold: default_spa_threshold(),
            symbolic_threshold: None,
            planner: default_planner_policy(),
            mask: None,
        }
    }
}

static SPA_THRESHOLD_CELL: OnceLock<f64> = OnceLock::new();

/// Set the process-wide default SPA threshold (the CLI's
/// `--spa-threshold` knob). Returns `false` if the default was already
/// read or set — call once, at startup, before any multiply.
pub fn set_default_spa_threshold(t: f64) -> bool {
    SPA_THRESHOLD_CELL.set(t).is_ok()
}

/// The process-wide default SPA threshold (see
/// [`EngineConfig::default`]), resolved through the **threshold
/// ladder**: the CLI's `--spa-threshold` flag (latched into the cell
/// directly), else a valid `SPGEMM_AIA_SPA_THRESHOLD` env value, else a
/// persisted `calibration.json` next to the plan cache (written by
/// `spgemm-aia calibrate` — see [`super::calibrate`]), else the
/// cache-geometry derivation
/// ([`crate::sim::DeviceConfig::dense_row_threshold_base`]). Env values
/// outside the CLI's accepted `[0, 8]` range (or unparsable ones) are
/// ignored, not latched — a stray `SPGEMM_AIA_SPA_THRESHOLD=-1` must
/// not force the SPA onto every row of every multiply in the process;
/// corrupt or mismatched calibration files degrade to the geometry
/// fallback the same way.
pub fn default_spa_threshold() -> f64 {
    *SPA_THRESHOLD_CELL.get_or_init(|| {
        resolve_default_spa_threshold(
            std::env::var("SPGEMM_AIA_SPA_THRESHOLD").ok().as_deref(),
            super::calibrate::calibrated_spa_threshold(),
            DeviceConfig::h200_scaled().dense_row_threshold_base(),
        )
    })
}

/// The flag-less tiers of the threshold ladder, as a pure function so
/// the precedence is testable without touching the process-wide cell: a
/// valid env value wins, else the persisted calibration, else the
/// cache-geometry derivation. (The CLI flag sits above all three — it
/// latches the cell directly via [`set_default_spa_threshold`].)
pub fn resolve_default_spa_threshold(env: Option<&str>, calibrated: Option<f64>, geometry: f64) -> f64 {
    env.and_then(|s| s.parse().ok())
        .filter(|t: &f64| (0.0..=8.0).contains(t))
        .or(calibrated)
        .unwrap_or(geometry)
}

/// The thresholds a multiply actually runs at for outputs of width
/// `n_cols`: the configured knobs scaled by the simulated device's
/// dense-row L2-overflow factor (1.0 while one dense row fits in the
/// per-resident-block L2 share, growing past it — so the dense kernels
/// switch off progressively on very wide outputs). Returns
/// `(symbolic, numeric)`; the scaling preserves both boundary
/// invariants (`0.0` still forces, ≥ 1.0 still disables).
pub(crate) fn effective_thresholds(cfg: &EngineConfig, n_cols: usize) -> (f64, f64) {
    // Same scaling-and-clamp [`DeviceConfig::dense_row_threshold`]
    // documents for the geometric base, applied to the configured knob.
    let overflow = DeviceConfig::h200_scaled().dense_row_l2_overflow(n_cols);
    let scale = |t: f64| (t * overflow).min(8.0);
    (scale(cfg.symbolic_threshold.unwrap_or(cfg.spa_threshold)), scale(cfg.spa_threshold))
}

/// One homogeneous unit of numeric work: the rows of one Table-I group
/// that share one row-kernel pair (symbolic counting kernel × numeric
/// accumulator). Bins are the granularity at which the numeric phase
/// runs, the stream scheduler packs, and the batch pipeline dispatches
/// per-bin completion events.
#[derive(Clone, Debug)]
pub struct NumericBin {
    /// Table-I group id (0–3) — fixes strategy, block and table sizes.
    pub group: u8,
    /// Accumulator every row in this bin uses in the numeric phase.
    pub kind: AccumKind,
    /// Counting kernel every row in this bin used in the symbolic phase.
    pub symbolic_kind: SymbolicKind,
    /// Member rows (original row ids, stable within the group). Rows
    /// with zero output are excluded from every bin.
    pub rows: Vec<u32>,
    /// Summed intermediate products — the bin's scheduling weight.
    pub weight: u64,
}

impl NumericBin {
    /// The bin's row-kernel pair.
    pub fn kernel(&self) -> RowKernel {
        RowKernel { symbolic: self.symbolic_kind, numeric: self.kind }
    }

    /// Short label for schedules and metrics, e.g. `g3/bitmap/spa`.
    pub fn label(&self) -> String {
        format!("g{}/{}", self.group, self.kernel().label())
    }
}

/// Output of the symbolic phase: everything the numeric phase needs to
/// fill values without re-deriving structure, including the row-kernel
/// decision per row (the numeric half is made here, where exact sizes
/// are known — the numeric phase only consumes it; the symbolic half
/// was made before counting, from the IP bound).
pub struct SymbolicPlan {
    /// Per-row intermediate-product upper bounds (Algorithm 1).
    pub ip: Vec<u64>,
    /// Table I row-category bins over `ip`.
    pub grouping: Grouping,
    /// *Exact* output row pointers: `rpt[i+1] - rpt[i]` = nnz of C row i.
    pub rpt: Vec<usize>,
    /// Per-row accumulator kind (rows with zero output hold a
    /// placeholder — use [`SymbolicPlan::accumulator_kind`]).
    pub accum: Vec<AccumKind>,
    /// Per-row symbolic counting kernel (defined for *every* row — the
    /// symbolic phase processed them all, empty output or not).
    pub symbolic: Vec<SymbolicKind>,
    /// The numeric work list: each Table-I bin split by row-kernel
    /// pair, empty bins dropped.
    pub bins: Vec<NumericBin>,
    /// Density threshold knob the kinds were selected with (the base
    /// value, before the cache-adaptive width scaling).
    pub spa_threshold: f64,
    /// The output mask this plan was built under (`None` = unmasked).
    /// `rpt`, `accum`, and `bins` are all *masked* quantities when
    /// present; the numeric phase re-applies the same mask so the fill
    /// stays consistent with the counted sizes. Rides into the plan
    /// fingerprint and SAPL v3 persistence.
    pub mask: Option<Mask>,
}

impl SymbolicPlan {
    /// Total output non-zeros.
    pub fn nnz(&self) -> usize {
        *self.rpt.last().unwrap_or(&0)
    }

    /// Exact nnz of output row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.rpt[i + 1] - self.rpt[i]
    }

    /// Accumulator the numeric phase will use for row `i` (`None` for
    /// rows with no output — they are skipped entirely).
    pub fn accumulator_kind(&self, i: usize) -> Option<AccumKind> {
        if self.row_nnz(i) == 0 {
            None
        } else {
            Some(self.accum[i])
        }
    }

    /// Counting kernel the symbolic phase used for row `i`.
    pub fn symbolic_kind(&self, i: usize) -> SymbolicKind {
        self.symbolic[i]
    }

    /// The full row-kernel pair for row `i` (`None` for rows with no
    /// output — they have a symbolic kind but never reach the numeric
    /// phase).
    pub fn row_kernel(&self, i: usize) -> Option<RowKernel> {
        self.accumulator_kind(i).map(|numeric| RowKernel { symbolic: self.symbolic[i], numeric })
    }

    /// Row counts per accumulator kind, indexed by
    /// [`AccumKind::index`] (copy, hash, SPA).
    pub fn kind_rows(&self) -> [usize; 3] {
        let mut n = [0usize; 3];
        for b in &self.bins {
            n[b.kind.index()] += b.rows.len();
        }
        n
    }

    /// Row counts per symbolic counting kernel, indexed by
    /// [`SymbolicKind::index`] (trivial, hash, bitmap) — over **all**
    /// rows, since the symbolic phase processes every row.
    pub fn symbolic_kind_rows(&self) -> [usize; 3] {
        let mut n = [0usize; 3];
        for &k in &self.symbolic {
            n[k.index()] += 1;
        }
        n
    }
}

/// Dynamic-scheduling batch for a bin: PWPR bins hand each worker a
/// block's worth of small rows; TBPR bins hand out fat rows a few at a
/// time so the atomic counter isn't hammered.
pub(crate) fn bin_batch(spec: &GroupSpec) -> usize {
    match spec.strategy {
        Strategy::Pwpr => spec.rows_per_block(),
        Strategy::Tbpr => 4,
    }
}

/// One reusable per-worker table for a bin.
pub(crate) fn bin_table(spec: &GroupSpec) -> HashTable {
    match spec.table_size {
        Some(s) => HashTable::new(s, TableLoc::Shared),
        None => HashTable::new(1024, TableLoc::Global),
    }
}

/// Fast parallel hash SpGEMM (symbolic + numeric phases), at the
/// process-default [`EngineConfig`].
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    multiply_cfg(a, b, &EngineConfig::default())
}

/// [`multiply`] with an explicit [`EngineConfig`].
pub fn multiply_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> Csr {
    multiply_timed_cfg(a, b, cfg).0
}

/// [`multiply`] plus wall time per phase (numeric seconds split per
/// accumulator kind, symbolic seconds per counting kernel).
pub fn multiply_timed(a: &Csr, b: &Csr) -> (Csr, PhaseTimes) {
    multiply_timed_cfg(a, b, &EngineConfig::default())
}

/// [`multiply_timed`] with an explicit [`EngineConfig`].
pub fn multiply_timed_cfg(a: &Csr, b: &Csr, cfg: &EngineConfig) -> (Csr, PhaseTimes) {
    let (plan, mut times) = symbolic_timed(a, b, cfg);
    let (c, numeric_times) = numeric_timed(a, b, &plan);
    times.numeric_s = numeric_times.numeric_s;
    times.numeric_kind_s = numeric_times.numeric_kind_s;
    (c, times)
}

/// Masked SpGEMM `C = M ⊙ (A·B)` at the process-default config: both
/// phases prune through the mask, so mask-rejected entries are never
/// counted, sized, or filled. Bit-identical to
/// `mask.filter(&multiply(a, b))` (pinned by `tests/masked.rs`).
pub fn multiply_masked(a: &Csr, b: &Csr, mask: &Mask) -> Csr {
    multiply_masked_cfg(a, b, mask, &EngineConfig::default())
}

/// [`multiply_masked`] with an explicit [`EngineConfig`] (whose own
/// `mask` field is replaced by `mask`). Panics if the mask's shape is
/// not the output shape `a.n_rows × b.n_cols`.
pub fn multiply_masked_cfg(a: &Csr, b: &Csr, mask: &Mask, cfg: &EngineConfig) -> Csr {
    assert_eq!(
        mask.shape(),
        (a.n_rows, b.n_cols),
        "mask shape must equal the output shape a.n_rows x b.n_cols"
    );
    multiply_cfg(a, b, &EngineConfig { mask: Some(mask.clone()), ..cfg.clone() })
}

/// Strategy assigned to a row with the given IP (for tests/diagnostics).
pub fn strategy_for_ip(ip: u64) -> Strategy {
    GROUP_SPECS[crate::spgemm::ip::group_index_for_ip(ip)].strategy
}

/// Expose the spec list for the coordinator's stream scheduler.
pub fn group_specs() -> &'static [GroupSpec; 4] {
    &GROUP_SPECS
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Pcg32;

    pub fn random_csr(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Csr {
        let mut coo = crate::sparse::Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density) as usize;
        for _ in 0..target {
            coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-2.0, 2.0));
        }
        coo.to_csr()
    }

    /// Dense-ish operands so the default threshold actually selects SPA
    /// rows (every output row of a dense product is fully dense).
    pub fn dense_pair(seed: u64, n: usize) -> (Csr, Csr) {
        let mut rng = Pcg32::seeded(seed);
        (random_csr(&mut rng, n, n, 0.5), random_csr(&mut rng, n, n, 0.5))
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::random_csr;
    use super::*;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::{qc, Pcg32};

    #[test]
    fn matches_reference_small() {
        let a = Csr::from_dense(&[vec![1.0, 2.0, 0.0], vec![0.0, 0.0, 3.0], vec![1.0, 0.0, 1.0]]);
        let b = Csr::from_dense(&[vec![0.0, 1.0], vec![1.0, 0.0], vec![2.0, 2.0]]);
        let c = multiply(&a, &b);
        let r = spgemm_reference(&a, &b);
        assert!(c.approx_eq(&r, 1e-12), "{:?} vs {:?}", c.to_dense(), r.to_dense());
    }

    #[test]
    fn phase_times_are_reported() {
        let mut rng = Pcg32::seeded(23);
        let a = random_csr(&mut rng, 400, 400, 0.02);
        let (c, t) = multiply_timed(&a, &a);
        assert!(c.nnz() > 0);
        assert!(t.grouping_s >= 0.0 && t.symbolic_s >= 0.0 && t.numeric_s >= 0.0);
        assert!(t.total_s() >= t.numeric_s);
        assert!(t.total_s() > 0.0, "three timed phases cannot all be zero-width");
        // The per-kernel symbolic split is recorded and bounded by the
        // phase total (the remainder is partitioning overhead).
        let sym_kind: f64 = t.symbolic_kind_s.iter().sum();
        assert!(sym_kind > 0.0, "per-kernel symbolic times must be recorded");
        assert!(sym_kind <= t.symbolic_s + 1e-9, "kernel split cannot exceed the symbolic total");
    }

    #[test]
    fn single_entry_rows_take_copy_path() {
        // Diagonal × random exercises the no-table scaled-copy path on
        // every row; result must still be exact.
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        let d = Csr::from_diag(&[2.5; 64]);
        let c = multiply(&d, &m);
        let mut expect = m.clone();
        expect.map_values(|v| 2.5 * v);
        assert!(c.approx_eq(&expect, 1e-12));
    }

    #[test]
    fn matches_reference_randomized() {
        qc::check(24, 2024, |g| {
            let rows = g.dim();
            let inner = g.dim();
            let cols = g.dim();
            let density = 0.02 + g.rng.f64() * 0.2;
            let a = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, rows, inner, density)
            };
            let b = {
                let mut rng = Pcg32::seeded(g.rng.next_u64());
                random_csr(&mut rng, inner, cols, density)
            };
            let c = multiply(&a, &b);
            let r = spgemm_reference(&a, &b);
            assert!(c.validate().is_ok(), "invalid CSR output");
            assert!(c.approx_eq(&r, 1e-10), "hash engine disagrees with reference");
        });
    }

    #[test]
    fn empty_and_identity_edge_cases() {
        let z = Csr::zeros(5, 5);
        assert_eq!(multiply(&z, &z).nnz(), 0);
        let i = Csr::identity(64);
        let mut rng = Pcg32::seeded(9);
        let m = random_csr(&mut rng, 64, 64, 0.1);
        assert!(multiply(&i, &m).approx_eq(&m, 1e-12));
        assert!(multiply(&m, &i).approx_eq(&m, 1e-12));
    }

    #[test]
    fn strategy_assignment() {
        assert_eq!(strategy_for_ip(10), Strategy::Pwpr);
        assert_eq!(strategy_for_ip(100), Strategy::Tbpr);
    }

    #[test]
    fn default_threshold_is_sane() {
        // The accepted range matches the CLI/env validation ([0, 8]);
        // values past 1.0 are legal and mean "dense kernels disabled".
        let t = default_spa_threshold();
        assert!((0.0..=8.0).contains(&t), "default threshold {t} out of range");
        assert_eq!(EngineConfig::default().spa_threshold, t);
        assert_eq!(EngineConfig::default().symbolic_threshold, None);
    }

    #[test]
    fn effective_thresholds_scale_with_width() {
        // Narrow outputs keep the configured knob as-is; a symbolic
        // override replaces only the symbolic half. The boundary
        // invariants survive scaling: 0.0 stays 0.0, ≥ 1.0 stays ≥ 1.0.
        let cfg =
            EngineConfig { spa_threshold: 0.25, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None };
        assert_eq!(effective_thresholds(&cfg, 1_000), (0.25, 0.25));
        let cfg = EngineConfig {
            spa_threshold: 0.25,
            symbolic_threshold: Some(0.0),
            planner: PlannerPolicy::Exact,
            mask: None,
        };
        assert_eq!(effective_thresholds(&cfg, 1_000), (0.0, 0.25));
        // Past the per-block L2 share (512 KiB / 4 B = 131072 columns)
        // both halves scale up together.
        let cfg =
            EngineConfig { spa_threshold: 0.25, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None };
        let (sym, num) = effective_thresholds(&cfg, 4 * 131_072);
        assert!((num - 1.0).abs() < 1e-12, "numeric threshold must scale with L2 overflow");
        assert_eq!(sym, num);
        let cfg =
            EngineConfig { spa_threshold: 0.0, symbolic_threshold: None, planner: PlannerPolicy::Exact, mask: None };
        assert_eq!(effective_thresholds(&cfg, 4 * 131_072), (0.0, 0.0));
    }

    #[test]
    fn bin_labels_carry_the_kernel_pair() {
        let bin = NumericBin {
            group: 3,
            kind: AccumKind::Spa,
            symbolic_kind: SymbolicKind::Bitmap,
            rows: vec![1],
            weight: 10,
        };
        assert_eq!(bin.label(), "g3/bitmap/spa");
        assert_eq!(bin.kernel(), RowKernel { symbolic: SymbolicKind::Bitmap, numeric: AccumKind::Spa });
    }
}
