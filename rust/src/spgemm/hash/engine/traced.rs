//! The instrumented engines: the seed single-pass baseline and the
//! deterministic sequential traced paths that feed the AIA simulator.
//!
//! The traced paths replay the same row-kernel decisions the fast
//! path's plan bakes in ([`super::symbolic_cfg`]), evaluated inline at
//! the same effective thresholds: bitmap-symbolic and SPA-numeric rows
//! emit plain streaming accesses (`SpaFlags`/`SpaVals` plus sequential
//! B loads — AIA-ineligible), hash rows emit the two-level indirection
//! the AIA engine model rewrites.

use super::super::grouping::{
    global_table_size, select_accumulator, select_symbolic, select_symbolic_masked, AccumKind, Grouping,
    Strategy, SymbolicKind, GROUP_SPECS,
};
use super::super::mask::{Mask, MaskRowProbe};
use super::super::sort::bitonic_sort_by_key;
use super::super::table::{DenseAccumulator, HashTable, RowCounter, TableLoc};
use super::numeric::{accum_row, accum_row_fast, accum_row_spa_traced};
use super::symbolic::{alloc_row, alloc_row_bitmap_traced};
use super::{effective_thresholds, EngineConfig};
use crate::sim::probe::{Kind, NullProbe, Phase, Probe, Region};
use crate::spgemm::ip::{intermediate_products, intermediate_products_traced, IP_BLOCK_ROWS};
use crate::sparse::Csr;
use crate::util::{par_chunks, parallel::par_dynamic_with};

/// Whether the traced paths run row `i` through the numeric SPA — the
/// same decision [`super::symbolic_cfg`] bakes into the plan, at the
/// effective (width-scaled) threshold the caller resolved.
fn traced_row_uses_spa(a: &Csr, b: &Csr, row: usize, n_out: usize, num_threshold: f64) -> bool {
    n_out > 0 && select_accumulator(a.row_nnz(row), n_out, b.n_cols, num_threshold) == AccumKind::Spa
}

/// The seed's engine: allocation and accumulation fused per bin, one
/// freshly allocated table per worker chunk (PWPR) and IP-sized global
/// tables. Kept as the regression baseline the two-phase pipeline is
/// benched against (`benches/spgemm_selfproduct.rs`); output is
/// identical to [`super::multiply`].
pub fn multiply_single_pass(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);

    // ---- allocation phase: per-row unique counts -> rpt_C ----
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            match spec.strategy {
                Strategy::Pwpr => {
                    // many small rows: static chunks, one table per chunk
                    par_chunks(rows.len(), |start, end| {
                        let p = nnz_ptr as *mut u32;
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        for &row in &rows[start..end] {
                            table.clear();
                            let u = alloc_row(a, b, row as usize, &mut table, &mut NullProbe);
                            unsafe { *p.add(row as usize) = u };
                        }
                    });
                }
                Strategy::Tbpr => {
                    // fewer, fatter rows: dynamic scheduling with one
                    // growable table per worker (no per-row allocation)
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || HashTable::new(base, loc),
                        |table, ri| {
                            let p = nnz_ptr as *mut u32;
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            let u = alloc_row(a, b, row, table, &mut NullProbe);
                            unsafe { *p.add(row) = u };
                        },
                    );
                }
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation phase: values into disjoint output slices ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    {
        let col_ptr = col.as_mut_ptr() as usize;
        let val_ptr = val.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            let run_row = |row: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>| {
                accum_row_fast(a, b, row, table, scratch);
                scratch.sort_unstable_by_key(|e| e.0);
                let start = rpt[row];
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = v;
                    }
                }
            };
            match spec.strategy {
                Strategy::Pwpr => {
                    par_chunks(rows.len(), |start, end| {
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        let mut scratch = Vec::new();
                        for &row in &rows[start..end] {
                            table.clear();
                            run_row(row as usize, &mut table, &mut scratch);
                        }
                    });
                }
                Strategy::Tbpr => {
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || (HashTable::new(base, loc), Vec::new()),
                        |(table, scratch), ri| {
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            run_row(row, table, scratch);
                        },
                    );
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Instrumented sequential hash SpGEMM at the process-default
/// [`EngineConfig`]: identical output to [`super::multiply`], plus a
/// full program-order memory trace through `probe`.
pub fn multiply_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    multiply_traced_cfg(a, b, probe, &EngineConfig::default())
}

/// [`multiply_traced`] with an explicit [`EngineConfig`] — the traced
/// path replays the same row-kernel selection the fast path's plan
/// would bake in at this config. Blocks are numbered globally across
/// phases so the machine model's round-robin SM assignment interleaves
/// groups the way concurrent streams would.
pub fn multiply_traced_cfg<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, cfg: &EngineConfig) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);
    let mask = cfg.mask.as_ref();
    if let Some(m) = mask {
        assert_eq!(m.shape(), (a.n_rows, b.n_cols), "mask shape must equal the output shape");
    }
    let mut admit = mask.map(|_| MaskRowProbe::new(b.n_cols));
    // ---- grouping phase ----
    let ip = intermediate_products_traced(a, b, probe);
    let grouping = Grouping::build(&ip);
    let mut next_block = a.n_rows.div_ceil(IP_BLOCK_ROWS);

    // ---- allocation (symbolic) phase ----
    let mut row_nnz = vec![0u32; a.n_rows];
    let mut bitmap_holder: Option<RowCounter> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Allocation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let kind = match mask {
                    None => select_symbolic(a.row_nnz(row), ip[row], b.n_cols, sym_threshold),
                    Some(m) => {
                        select_symbolic_masked(a.row_nnz(row), ip[row], m.row_nnz(row), b.n_cols, sym_threshold)
                    }
                };
                // Plan-guided bitmap rows: streaming first-touch counts,
                // no hash table, no indirection (AIA-ineligible).
                if kind == SymbolicKind::Bitmap {
                    let counter = bitmap_holder.get_or_insert_with(|| RowCounter::new(b.n_cols));
                    counter.clear();
                    row_nnz[row] = match mask {
                        None => alloc_row_bitmap_traced(a, b, row, counter, probe),
                        Some(m) => {
                            alloc_row_bitmap_masked_traced(a, b, row, counter, admit.as_mut().unwrap(), m, probe)
                        }
                    };
                    probe.access(Region::RptC, row + 1, 4, Kind::Write);
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                // The traced path has no separate trivial arm: trivial
                // rows (masked or not) count correctly through the hash
                // table, they just never collide.
                row_nnz[row] = match mask {
                    None => alloc_row(a, b, row, table, probe),
                    Some(m) => alloc_row_masked_traced(a, b, row, table, admit.as_mut().unwrap(), m, probe),
                };
                if spec.table_size.is_none() {
                    table_holder = None; // fresh global table per huge row
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation (numeric) phase ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut spa_holder: Option<DenseAccumulator> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Accumulation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let start = rpt[row];
                // Plan-guided SPA rows: streamed accumulation, sequential
                // gather (already column-sorted — no bitonic network).
                if traced_row_uses_spa(a, b, row, row_nnz[row] as usize, num_threshold) {
                    let spa = spa_holder.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                    spa.clear();
                    match mask {
                        None => accum_row_spa_traced(a, b, row, spa, &mut scratch, probe),
                        Some(m) => {
                            accum_row_spa_masked_traced(a, b, row, spa, &mut scratch, admit.as_mut().unwrap(), m, probe)
                        }
                    }
                    probe.access(Region::RptC, row, 4, Kind::Read);
                    for (o, &(c, v)) in scratch.iter().enumerate() {
                        probe.access(Region::ColC, start + o, 4, Kind::Write);
                        probe.access(Region::ValC, start + o, 8, Kind::Write);
                        col[start + o] = c;
                        val[start + o] = v;
                    }
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                match mask {
                    None => accum_row(a, b, row, table, &mut scratch, probe),
                    Some(m) => accum_row_masked_traced(a, b, row, table, &mut scratch, admit.as_mut().unwrap(), m, probe),
                }
                // Column-index sorting: the paper's in-block bitonic network.
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                    col[start + o] = c;
                    val[start + o] = v;
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Statistics-only traced run: emits the memory trace of every
/// `every`-th thread block and **skips the functional work of the
/// rest** (their output-row sizes are approximated by their IP upper
/// bound, which only shifts unsampled output addresses). Use when only
/// the [`crate::sim::SimReport`] is needed — the fast parallel
/// [`super::multiply`] provides the actual product. `every = 1` traces
/// every block (identical trace to [`multiply_traced`]). Runs at the
/// process-default [`EngineConfig`], like the fast path it samples.
pub fn multiply_traced_stats<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize) {
    multiply_traced_stats_cfg(a, b, probe, every, &EngineConfig::default());
}

/// [`multiply_traced_stats`] at an explicit [`EngineConfig`] — the
/// calibration sweep uses this to trace the same workload under a grid
/// of SPA/bitmap thresholds without touching the latched process
/// default.
pub fn multiply_traced_stats_cfg<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize, cfg: &EngineConfig) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);
    let mask = cfg.mask.as_ref();
    if let Some(m) = mask {
        assert_eq!(m.shape(), (a.n_rows, b.n_cols), "mask shape must equal the output shape");
    }
    let mut admit = mask.map(|_| MaskRowProbe::new(b.n_cols));
    let every = every.max(1);
    // IP for *all* rows (cheap, parallel) — grouping must be exact.
    let ip = intermediate_products(a, b);
    // Grouping-phase trace for sampled blocks only.
    let n_ip_blocks = a.n_rows.div_ceil(IP_BLOCK_ROWS);
    for blk in 0..n_ip_blocks {
        if blk % every != 0 {
            continue;
        }
        probe.begin_block(blk, Phase::Grouping);
        let lo = blk * IP_BLOCK_ROWS;
        let hi = ((blk + 1) * IP_BLOCK_ROWS).min(a.n_rows);
        for i in lo..hi {
            probe.access(Region::RptA, i, 4, Kind::Read);
            probe.access(Region::RptA, i + 1, 4, Kind::Read);
            for (jo, &c) in a.row(i).0.iter().enumerate() {
                probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
                probe.indirect_range(Region::RptB, c as usize, &[], 0, 0);
                probe.compute(2);
            }
            probe.access(Region::IpCount, i, 8, Kind::Write);
            probe.access(Region::GroupCtr, crate::spgemm::ip::group_index_for_ip(ip[i]), 4, Kind::Atomic);
            probe.compute(4);
        }
    }
    let grouping = Grouping::build(&ip);
    let mut next_block = n_ip_blocks;

    // Allocation phase: real work on sampled blocks (bitmap or hash,
    // per the plan's kernel rule), IP bound for the rest (address
    // generation only; `exact` remembers which is which — the
    // accumulator decision below must never run on a bound).
    let mut row_nnz = vec![0u32; a.n_rows];
    let mut exact = vec![false; a.n_rows];
    let mut bitmap_holder: Option<RowCounter> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Allocation);
            }
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                if !sampled {
                    // The approximate size of an unsampled row: IP and
                    // output width, capped by the mask row (the masked
                    // exact size can never exceed it).
                    let mut bound = ip[row].min(b.n_cols as u64);
                    if let Some(m) = mask {
                        bound = bound.min(m.row_nnz(row) as u64);
                    }
                    row_nnz[row] = bound as u32;
                    continue;
                }
                exact[row] = true;
                probe.access(Region::Map, row, 4, Kind::Read);
                let kind = match mask {
                    None => select_symbolic(a.row_nnz(row), ip[row], b.n_cols, sym_threshold),
                    Some(m) => {
                        select_symbolic_masked(a.row_nnz(row), ip[row], m.row_nnz(row), b.n_cols, sym_threshold)
                    }
                };
                if kind == SymbolicKind::Bitmap {
                    let counter = bitmap_holder.get_or_insert_with(|| RowCounter::new(b.n_cols));
                    counter.clear();
                    row_nnz[row] = match mask {
                        None => alloc_row_bitmap_traced(a, b, row, counter, probe),
                        Some(m) => {
                            alloc_row_bitmap_masked_traced(a, b, row, counter, admit.as_mut().unwrap(), m, probe)
                        }
                    };
                    probe.access(Region::RptC, row + 1, 4, Kind::Write);
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = match mask {
                    None => alloc_row(a, b, row, table, probe),
                    Some(m) => alloc_row_masked_traced(a, b, row, table, admit.as_mut().unwrap(), m, probe),
                };
                if spec.table_size.is_none() {
                    table_holder = None;
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }

    // Accumulation phase: sampled blocks only.
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut spa_holder: Option<DenseAccumulator> = None;
    // Untraced counting table for rows whose allocation block was
    // unsampled: their `row_nnz` is an IP upper bound, good enough for
    // output addresses but not for the accumulator decision — deciding
    // SPA-vs-hash on a bound would trace the wrong path entirely.
    let mut count_table = HashTable::new(1024, TableLoc::Global);
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Accumulation);
            }
            next_block += 1;
            if !sampled {
                continue;
            }
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let start = rpt[row];
                let mut bound = ip[row].min(b.n_cols as u64) as usize;
                if let Some(m) = mask {
                    bound = bound.min(m.row_nnz(row));
                }
                let n_out = if exact[row] {
                    row_nnz[row] as usize
                } else if bound as f64 <= num_threshold * b.n_cols as f64 {
                    // The (masked) bound already rules SPA out
                    // (n_out ≤ bound): no need for the exact recount on
                    // sparse rows.
                    bound
                } else {
                    count_table.reset_with_capacity(global_table_size(bound as u64));
                    match mask {
                        None => alloc_row(a, b, row, &mut count_table, &mut NullProbe) as usize,
                        Some(m) => {
                            count_row_masked(a, b, row, &mut count_table, admit.as_mut().unwrap(), m) as usize
                        }
                    }
                };
                // SPA rows: streamed accumulation, sequential sorted
                // gather — same decision as the fast path's plan.
                if traced_row_uses_spa(a, b, row, n_out, num_threshold) {
                    let spa = spa_holder.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                    spa.clear();
                    match mask {
                        None => accum_row_spa_traced(a, b, row, spa, &mut scratch, probe),
                        Some(m) => {
                            accum_row_spa_masked_traced(a, b, row, spa, &mut scratch, admit.as_mut().unwrap(), m, probe)
                        }
                    }
                    probe.access(Region::RptC, row, 4, Kind::Read);
                    for (o, &(_c, _v)) in scratch.iter().enumerate() {
                        probe.access(Region::ColC, start + o, 4, Kind::Write);
                        probe.access(Region::ValC, start + o, 8, Kind::Write);
                    }
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                match mask {
                    None => accum_row(a, b, row, table, &mut scratch, probe),
                    Some(m) => accum_row_masked_traced(a, b, row, table, &mut scratch, admit.as_mut().unwrap(), m, probe),
                }
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                for (o, &(_c, _v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
}

/// Price one mask-row load: two `MaskRpt` pointer reads bracket the
/// row, then its column indices stream as sequential 4-byte `MaskCol`
/// reads into the per-block membership probe. Plain streamed loads,
/// never `indirect_range` — the mask row is consumed once, in order,
/// so the AIA engine buys nothing.
fn mask_row_traced<'m, P: Probe>(mask: &'m Mask, row: usize, probe: &mut P) -> &'m [u32] {
    probe.access(Region::MaskRpt, row, 4, Kind::Read);
    probe.access(Region::MaskRpt, row + 1, 4, Kind::Read);
    let lo = mask.rpt()[row];
    let mrow = mask.row(row);
    for o in 0..mrow.len() {
        probe.access(Region::MaskCol, lo + o, 4, Kind::Read);
    }
    mrow
}

/// Masked traced allocation row processor: [`alloc_row`] plus the
/// mask-row load and a one-op membership check per candidate — rejected
/// columns never touch the table, which is exactly the traffic
/// reduction the simulator should see.
fn alloc_row_masked_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    table: &mut HashTable,
    admit: &mut MaskRowProbe,
    mask: &Mask,
    probe: &mut P,
) -> u32 {
    admit.seed(mask_row_traced(mask, i, probe));
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        probe.indirect_range(Region::RptB, colk, &[Region::ColB], lo, hi);
        for k in lo..hi {
            let c = b.col[k];
            probe.compute(1); // mask membership check
            if admit.admits(c) {
                table.insert_symbolic(c, probe);
            }
        }
    }
    table.unique as u32
}

/// Masked traced bitmap counting row processor:
/// [`alloc_row_bitmap_traced`] gated on mask admission (same streaming
/// pricing — bitmap rows stay AIA-ineligible under a mask).
fn alloc_row_bitmap_masked_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    counter: &mut RowCounter,
    admit: &mut MaskRowProbe,
    mask: &Mask,
    probe: &mut P,
) -> u32 {
    admit.seed(mask_row_traced(mask, i, probe));
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        let colk = a.col[j] as usize;
        probe.access(Region::RptB, colk, 4, Kind::Read);
        probe.access(Region::RptB, colk + 1, 4, Kind::Read);
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            probe.access(Region::ColB, k, 4, Kind::Read);
            let c = b.col[k];
            probe.compute(1); // mask membership check
            if admit.admits(c) {
                counter.count_traced(c, probe);
            }
        }
    }
    counter.unique() as u32
}

/// Masked traced accumulation row processor: [`accum_row`] with the
/// mask-row load priced and every insert gated — admitted columns keep
/// the B-stream accumulation order, so the output stays bit-identical
/// to the fast masked path.
#[allow(clippy::too_many_arguments)]
fn accum_row_masked_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    table: &mut HashTable,
    scratch: &mut Vec<(u32, f64)>,
    admit: &mut MaskRowProbe,
    mask: &Mask,
    probe: &mut P,
) {
    admit.seed(mask_row_traced(mask, i, probe));
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        let (lo, hi) = (b.rpt[colk], b.rpt[colk + 1]);
        probe.indirect_range(Region::RptB, colk, &[Region::ColB, Region::ValB], lo, hi);
        for k in lo..hi {
            let c = b.col[k];
            probe.compute(1); // mask membership check
            if admit.admits(c) {
                table.insert_numeric(c, av * b.val[k], probe);
                probe.compute(1); // the multiply
            }
        }
    }
    table.gather(scratch, probe);
}

/// Masked traced dense-SPA row processor:
/// [`accum_row_spa_traced`] gated on mask admission, same streaming
/// pricing.
#[allow(clippy::too_many_arguments)]
fn accum_row_spa_masked_traced<P: Probe>(
    a: &Csr,
    b: &Csr,
    i: usize,
    spa: &mut DenseAccumulator,
    scratch: &mut Vec<(u32, f64)>,
    admit: &mut MaskRowProbe,
    mask: &Mask,
    probe: &mut P,
) {
    admit.seed(mask_row_traced(mask, i, probe));
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    for j in a.row_range(i) {
        probe.access(Region::ColA, j, 4, Kind::Read);
        probe.access(Region::ValA, j, 8, Kind::Read);
        let colk = a.col[j] as usize;
        let av = a.val[j];
        probe.access(Region::RptB, colk, 4, Kind::Read);
        probe.access(Region::RptB, colk + 1, 4, Kind::Read);
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            probe.access(Region::ColB, k, 4, Kind::Read);
            probe.access(Region::ValB, k, 8, Kind::Read);
            let c = b.col[k];
            probe.compute(1); // mask membership check
            if admit.admits(c) {
                spa.add_traced(c, av * b.val[k], probe);
                probe.compute(1); // the multiply
            }
        }
    }
    spa.gather(scratch, probe);
}

/// Untraced gated recount for the stats path's unsampled-allocation
/// rows: the masked exact size the sampled accumulation block needs for
/// its accumulator decision.
fn count_row_masked(
    a: &Csr,
    b: &Csr,
    i: usize,
    table: &mut HashTable,
    admit: &mut MaskRowProbe,
    mask: &Mask,
) -> u32 {
    admit.seed(mask.row(i));
    for j in a.row_range(i) {
        let colk = a.col[j] as usize;
        for k in b.rpt[colk]..b.rpt[colk + 1] {
            let c = b.col[k];
            if admit.admits(c) {
                table.insert_symbolic(c, &mut NullProbe);
            }
        }
    }
    table.unique as u32
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{dense_pair, random_csr};
    use super::super::{multiply, symbolic, symbolic_cfg, PlannerPolicy};
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::Pcg32;

    #[test]
    fn two_phase_equals_single_pass_exactly() {
        let mut rng = Pcg32::seeded(321);
        let a = random_csr(&mut rng, 300, 250, 0.03);
        let b = random_csr(&mut rng, 250, 280, 0.02);
        // bit-for-bit: same structure, same value sums in the same order
        assert_eq!(multiply(&a, &b), multiply_single_pass(&a, &b));
    }

    #[test]
    fn traced_equals_fast_path() {
        let mut rng = Pcg32::seeded(77);
        let a = random_csr(&mut rng, 200, 150, 0.02);
        let b = random_csr(&mut rng, 150, 180, 0.03);
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
        assert!(probe.indirect_ranges > 0);
        assert!(probe.shared > 0);
    }

    #[test]
    fn exercises_all_four_groups() {
        // Build a matrix whose rows produce IPs in every group: B dense-ish
        // rows amplify.
        let mut rng = Pcg32::seeded(5);
        let n = 600;
        let mut coo = crate::sparse::Coo::new(n, n);
        // row 0: 1 nnz (group 0); row 1: 40 nnz (g1); row 2: 300 nnz (g2 via
        // IP multiplication); rows 3..: heavy hub rows for group 3.
        for j in 0..1 {
            coo.push(0, j * 7 % n, 1.0);
        }
        for j in 0..40 {
            coo.push(1, (j * 13) % n, 1.0);
        }
        for j in 0..300 {
            coo.push(2, (j * 2 + 1) % n, 1.0);
        }
        for r in 3..40 {
            for j in 0..r * 20 % n {
                coo.push(r, (j * 3 + r) % n, 1.0);
            }
        }
        for r in 40..n {
            for _ in 0..6 {
                coo.push(r, rng.below_usize(n), 1.0);
            }
        }
        let a = coo.to_csr();
        let ip = intermediate_products(&a, &a);
        let grouping = Grouping::build(&ip);
        let non_empty = (0..4).filter(|&g| !grouping.group_rows(g).is_empty()).count();
        assert!(non_empty >= 3, "expected ≥3 groups populated, got {non_empty}");
        let c = multiply(&a, &a);
        let r = spgemm_reference(&a, &a);
        assert!(c.approx_eq(&r, 1e-10));
        // and the seed baseline still agrees on the same stress input
        assert_eq!(c, multiply_single_pass(&a, &a));
    }

    #[test]
    fn traced_spa_rows_equal_fast_path() {
        // Dense product: the default threshold picks SPA on most rows,
        // and the traced path must still match the fast path exactly.
        let (a, b) = dense_pair(88, 72);
        let plan = symbolic(&a, &b);
        assert!(
            plan.kind_rows()[AccumKind::Spa.index()] > 0,
            "test needs SPA rows at the default threshold"
        );
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
    }

    #[test]
    fn traced_bitmap_symbolic_is_streaming_and_exact() {
        // Same numeric threshold both ways, only the symbolic kernel
        // flips: outputs must stay bit-identical, and the bitmap run
        // must drop the allocation phase's indirect ranges (it reads B
        // as plain streamed loads — AIA-ineligible).
        let (a, b) = dense_pair(19, 90);
        let planner = PlannerPolicy::Exact;
        let bitmap = EngineConfig { spa_threshold: 0.25, symbolic_threshold: Some(0.0), planner, mask: None };
        let hash = EngineConfig { spa_threshold: 0.25, symbolic_threshold: Some(8.0), planner, mask: None };
        let mut probe_b = CountingProbe::default();
        let mut probe_h = CountingProbe::default();
        let c_b = multiply_traced_cfg(&a, &b, &mut probe_b, &bitmap);
        let c_h = multiply_traced_cfg(&a, &b, &mut probe_h, &hash);
        assert_eq!(c_b, c_h, "the symbolic kernel must never change the product");
        assert_eq!(c_b, multiply(&a, &b));
        assert!(
            probe_b.indirect_ranges < probe_h.indirect_ranges,
            "bitmap symbolic rows must not emit indirect ranges (bitmap={} hash={})",
            probe_b.indirect_ranges,
            probe_h.indirect_ranges
        );
        // The forced-bitmap plan actually had bitmap rows to trace.
        let plan = symbolic_cfg(&a, &b, &bitmap);
        assert!(plan.symbolic_kind_rows()[SymbolicKind::Bitmap.index()] > 0);
    }

    #[test]
    fn masked_traced_equals_fast_masked_path_and_prices_the_mask() {
        use super::super::super::mask::Mask;
        use super::super::{multiply_masked, multiply_masked_cfg};
        let mut rng = Pcg32::seeded(99);
        let a = random_csr(&mut rng, 160, 140, 0.04);
        let b = random_csr(&mut rng, 140, 120, 0.05);
        let mut coo = crate::sparse::Coo::new(a.n_rows, b.n_cols);
        for i in 0..a.n_rows {
            for j in i.saturating_sub(11)..(i + 12).min(b.n_cols) {
                coo.push(i, j, 1.0);
            }
        }
        let mask = Mask::from_structure(&coo.to_csr());
        let fast = multiply_masked(&a, &b, &mask);
        // The traced path must replay the masked kernel decisions
        // bit-identically at every threshold corner.
        for (spa_thr, sym_thr) in [(0.25, None), (0.0, Some(0.0)), (2.0, Some(8.0))] {
            let cfg = EngineConfig {
                spa_threshold: spa_thr,
                symbolic_threshold: sym_thr,
                planner: PlannerPolicy::Exact,
                mask: Some(mask.clone()),
            };
            let mut probe = CountingProbe::default();
            let traced = multiply_traced_cfg(&a, &b, &mut probe, &cfg);
            let fast_cfg = multiply_masked_cfg(
                &a,
                &b,
                &mask,
                &EngineConfig { spa_threshold: spa_thr, symbolic_threshold: sym_thr, planner: PlannerPolicy::Exact, mask: None },
            );
            assert_eq!(traced, fast_cfg, "traced masked output must match the fast path");
            assert!(probe.accesses > 0);
        }
        assert_eq!(fast, mask.filter(&multiply(&a, &b)), "fast masked path must equal the filtered oracle");
        assert!(fast.nnz() <= multiply(&a, &b).nnz(), "a mask can only shrink the product");
    }
}
