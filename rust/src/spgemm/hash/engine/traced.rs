//! The instrumented engines: the seed single-pass baseline and the
//! deterministic sequential traced paths that feed the AIA simulator.
//!
//! The traced paths replay the same row-kernel decisions the fast
//! path's plan bakes in ([`super::symbolic_cfg`]), evaluated inline at
//! the same effective thresholds: bitmap-symbolic and SPA-numeric rows
//! emit plain streaming accesses (`SpaFlags`/`SpaVals` plus sequential
//! B loads — AIA-ineligible), hash rows emit the two-level indirection
//! the AIA engine model rewrites.

use super::super::grouping::{
    global_table_size, select_accumulator, select_symbolic, AccumKind, Grouping, Strategy, SymbolicKind,
    GROUP_SPECS,
};
use super::super::sort::bitonic_sort_by_key;
use super::super::table::{DenseAccumulator, HashTable, RowCounter, TableLoc};
use super::numeric::{accum_row, accum_row_fast, accum_row_spa_traced};
use super::symbolic::{alloc_row, alloc_row_bitmap_traced};
use super::{effective_thresholds, EngineConfig};
use crate::sim::probe::{Kind, NullProbe, Phase, Probe, Region};
use crate::spgemm::ip::{intermediate_products, intermediate_products_traced, IP_BLOCK_ROWS};
use crate::sparse::Csr;
use crate::util::{par_chunks, parallel::par_dynamic_with};

/// Whether the traced paths run row `i` through the numeric SPA — the
/// same decision [`super::symbolic_cfg`] bakes into the plan, at the
/// effective (width-scaled) threshold the caller resolved.
fn traced_row_uses_spa(a: &Csr, b: &Csr, row: usize, n_out: usize, num_threshold: f64) -> bool {
    n_out > 0 && select_accumulator(a.row_nnz(row), n_out, b.n_cols, num_threshold) == AccumKind::Spa
}

/// The seed's engine: allocation and accumulation fused per bin, one
/// freshly allocated table per worker chunk (PWPR) and IP-sized global
/// tables. Kept as the regression baseline the two-phase pipeline is
/// benched against (`benches/spgemm_selfproduct.rs`); output is
/// identical to [`super::multiply`].
pub fn multiply_single_pass(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let ip = intermediate_products(a, b);
    let grouping = Grouping::build(&ip);

    // ---- allocation phase: per-row unique counts -> rpt_C ----
    let mut row_nnz = vec![0u32; a.n_rows];
    {
        let nnz_ptr = row_nnz.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            match spec.strategy {
                Strategy::Pwpr => {
                    // many small rows: static chunks, one table per chunk
                    par_chunks(rows.len(), |start, end| {
                        let p = nnz_ptr as *mut u32;
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        for &row in &rows[start..end] {
                            table.clear();
                            let u = alloc_row(a, b, row as usize, &mut table, &mut NullProbe);
                            unsafe { *p.add(row as usize) = u };
                        }
                    });
                }
                Strategy::Tbpr => {
                    // fewer, fatter rows: dynamic scheduling with one
                    // growable table per worker (no per-row allocation)
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || HashTable::new(base, loc),
                        |table, ri| {
                            let p = nnz_ptr as *mut u32;
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            let u = alloc_row(a, b, row, table, &mut NullProbe);
                            unsafe { *p.add(row) = u };
                        },
                    );
                }
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation phase: values into disjoint output slices ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    {
        let col_ptr = col.as_mut_ptr() as usize;
        let val_ptr = val.as_mut_ptr() as usize;
        for g in 0..4 {
            let spec = &GROUP_SPECS[g];
            let rows = grouping.group_rows(g);
            let run_row = |row: usize, table: &mut HashTable, scratch: &mut Vec<(u32, f64)>| {
                accum_row_fast(a, b, row, table, scratch);
                scratch.sort_unstable_by_key(|e| e.0);
                let start = rpt[row];
                let cp = col_ptr as *mut u32;
                let vp = val_ptr as *mut f64;
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    // SAFETY: rows write disjoint [rpt[i], rpt[i+1]) slices.
                    unsafe {
                        *cp.add(start + o) = c;
                        *vp.add(start + o) = v;
                    }
                }
            };
            match spec.strategy {
                Strategy::Pwpr => {
                    par_chunks(rows.len(), |start, end| {
                        let mut table = HashTable::new(spec.table_size.unwrap(), TableLoc::Shared);
                        let mut scratch = Vec::new();
                        for &row in &rows[start..end] {
                            table.clear();
                            run_row(row as usize, &mut table, &mut scratch);
                        }
                    });
                }
                Strategy::Tbpr => {
                    let loc = if spec.table_size.is_some() { TableLoc::Shared } else { TableLoc::Global };
                    let base = spec.table_size.unwrap_or(1024);
                    par_dynamic_with(
                        rows.len(),
                        4,
                        || (HashTable::new(base, loc), Vec::new()),
                        |(table, scratch), ri| {
                            let row = rows[ri] as usize;
                            let size = spec.table_size.unwrap_or_else(|| global_table_size(ip[row]));
                            table.reset_with_capacity(size);
                            run_row(row, table, scratch);
                        },
                    );
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Instrumented sequential hash SpGEMM at the process-default
/// [`EngineConfig`]: identical output to [`super::multiply`], plus a
/// full program-order memory trace through `probe`.
pub fn multiply_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    multiply_traced_cfg(a, b, probe, &EngineConfig::default())
}

/// [`multiply_traced`] with an explicit [`EngineConfig`] — the traced
/// path replays the same row-kernel selection the fast path's plan
/// would bake in at this config. Blocks are numbered globally across
/// phases so the machine model's round-robin SM assignment interleaves
/// groups the way concurrent streams would.
pub fn multiply_traced_cfg<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, cfg: &EngineConfig) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);
    // ---- grouping phase ----
    let ip = intermediate_products_traced(a, b, probe);
    let grouping = Grouping::build(&ip);
    let mut next_block = a.n_rows.div_ceil(IP_BLOCK_ROWS);

    // ---- allocation (symbolic) phase ----
    let mut row_nnz = vec![0u32; a.n_rows];
    let mut bitmap_holder: Option<RowCounter> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Allocation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                // Plan-guided bitmap rows: streaming first-touch counts,
                // no hash table, no indirection (AIA-ineligible).
                if select_symbolic(a.row_nnz(row), ip[row], b.n_cols, sym_threshold) == SymbolicKind::Bitmap {
                    let counter = bitmap_holder.get_or_insert_with(|| RowCounter::new(b.n_cols));
                    counter.clear();
                    row_nnz[row] = alloc_row_bitmap_traced(a, b, row, counter, probe);
                    probe.access(Region::RptC, row + 1, 4, Kind::Write);
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None; // fresh global table per huge row
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }
    let nnz_c = rpt[a.n_rows];

    // ---- accumulation (numeric) phase ----
    let mut col = vec![0u32; nnz_c];
    let mut val = vec![0f64; nnz_c];
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut spa_holder: Option<DenseAccumulator> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            probe.begin_block(next_block, Phase::Accumulation);
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let start = rpt[row];
                // Plan-guided SPA rows: streamed accumulation, sequential
                // gather (already column-sorted — no bitonic network).
                if traced_row_uses_spa(a, b, row, row_nnz[row] as usize, num_threshold) {
                    let spa = spa_holder.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                    spa.clear();
                    accum_row_spa_traced(a, b, row, spa, &mut scratch, probe);
                    probe.access(Region::RptC, row, 4, Kind::Read);
                    for (o, &(c, v)) in scratch.iter().enumerate() {
                        probe.access(Region::ColC, start + o, 4, Kind::Write);
                        probe.access(Region::ValC, start + o, 8, Kind::Write);
                        col[start + o] = c;
                        val[start + o] = v;
                    }
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                // Column-index sorting: the paper's in-block bitonic network.
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                for (o, &(c, v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                    col[start + o] = c;
                    val[start + o] = v;
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Statistics-only traced run: emits the memory trace of every
/// `every`-th thread block and **skips the functional work of the
/// rest** (their output-row sizes are approximated by their IP upper
/// bound, which only shifts unsampled output addresses). Use when only
/// the [`crate::sim::SimReport`] is needed — the fast parallel
/// [`super::multiply`] provides the actual product. `every = 1` traces
/// every block (identical trace to [`multiply_traced`]). Runs at the
/// process-default [`EngineConfig`], like the fast path it samples.
pub fn multiply_traced_stats<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize) {
    multiply_traced_stats_cfg(a, b, probe, every, &EngineConfig::default());
}

/// [`multiply_traced_stats`] at an explicit [`EngineConfig`] — the
/// calibration sweep uses this to trace the same workload under a grid
/// of SPA/bitmap thresholds without touching the latched process
/// default.
pub fn multiply_traced_stats_cfg<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize, cfg: &EngineConfig) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let (sym_threshold, num_threshold) = effective_thresholds(cfg, b.n_cols);
    let every = every.max(1);
    // IP for *all* rows (cheap, parallel) — grouping must be exact.
    let ip = intermediate_products(a, b);
    // Grouping-phase trace for sampled blocks only.
    let n_ip_blocks = a.n_rows.div_ceil(IP_BLOCK_ROWS);
    for blk in 0..n_ip_blocks {
        if blk % every != 0 {
            continue;
        }
        probe.begin_block(blk, Phase::Grouping);
        let lo = blk * IP_BLOCK_ROWS;
        let hi = ((blk + 1) * IP_BLOCK_ROWS).min(a.n_rows);
        for i in lo..hi {
            probe.access(Region::RptA, i, 4, Kind::Read);
            probe.access(Region::RptA, i + 1, 4, Kind::Read);
            for (jo, &c) in a.row(i).0.iter().enumerate() {
                probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
                probe.indirect_range(Region::RptB, c as usize, &[], 0, 0);
                probe.compute(2);
            }
            probe.access(Region::IpCount, i, 8, Kind::Write);
            probe.access(Region::GroupCtr, crate::spgemm::ip::group_index_for_ip(ip[i]), 4, Kind::Atomic);
            probe.compute(4);
        }
    }
    let grouping = Grouping::build(&ip);
    let mut next_block = n_ip_blocks;

    // Allocation phase: real work on sampled blocks (bitmap or hash,
    // per the plan's kernel rule), IP bound for the rest (address
    // generation only; `exact` remembers which is which — the
    // accumulator decision below must never run on a bound).
    let mut row_nnz = vec![0u32; a.n_rows];
    let mut exact = vec![false; a.n_rows];
    let mut bitmap_holder: Option<RowCounter> = None;
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Allocation);
            }
            next_block += 1;
            for &row in chunk {
                let row = row as usize;
                if !sampled {
                    row_nnz[row] = ip[row].min(b.n_cols as u64) as u32;
                    continue;
                }
                exact[row] = true;
                probe.access(Region::Map, row, 4, Kind::Read);
                if select_symbolic(a.row_nnz(row), ip[row], b.n_cols, sym_threshold) == SymbolicKind::Bitmap {
                    let counter = bitmap_holder.get_or_insert_with(|| RowCounter::new(b.n_cols));
                    counter.clear();
                    row_nnz[row] = alloc_row_bitmap_traced(a, b, row, counter, probe);
                    probe.access(Region::RptC, row + 1, 4, Kind::Write);
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                row_nnz[row] = alloc_row(a, b, row, table, probe);
                if spec.table_size.is_none() {
                    table_holder = None;
                }
                probe.access(Region::RptC, row + 1, 4, Kind::Write);
            }
        }
    }
    let mut rpt = vec![0usize; a.n_rows + 1];
    for i in 0..a.n_rows {
        rpt[i + 1] = rpt[i] + row_nnz[i] as usize;
    }

    // Accumulation phase: sampled blocks only.
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    let mut spa_holder: Option<DenseAccumulator> = None;
    // Untraced counting table for rows whose allocation block was
    // unsampled: their `row_nnz` is an IP upper bound, good enough for
    // output addresses but not for the accumulator decision — deciding
    // SPA-vs-hash on a bound would trace the wrong path entirely.
    let mut count_table = HashTable::new(1024, TableLoc::Global);
    for g in 0..4 {
        let spec = &GROUP_SPECS[g];
        let rows = grouping.group_rows(g);
        let mut table_holder: Option<HashTable> = spec.table_size.map(|s| HashTable::new(s, TableLoc::Shared));
        for chunk in rows.chunks(spec.rows_per_block()) {
            let sampled = next_block % every == 0;
            if sampled {
                probe.begin_block(next_block, Phase::Accumulation);
            }
            next_block += 1;
            if !sampled {
                continue;
            }
            for &row in chunk {
                let row = row as usize;
                probe.access(Region::Map, row, 4, Kind::Read);
                let start = rpt[row];
                let bound = ip[row].min(b.n_cols as u64) as usize;
                let n_out = if exact[row] {
                    row_nnz[row] as usize
                } else if bound as f64 <= num_threshold * b.n_cols as f64 {
                    // The IP bound already rules SPA out (n_out ≤ bound):
                    // no need for the exact recount on sparse rows.
                    bound
                } else {
                    count_table.reset_with_capacity(global_table_size(bound as u64));
                    alloc_row(a, b, row, &mut count_table, &mut NullProbe) as usize
                };
                // SPA rows: streamed accumulation, sequential sorted
                // gather — same decision as the fast path's plan.
                if traced_row_uses_spa(a, b, row, n_out, num_threshold) {
                    let spa = spa_holder.get_or_insert_with(|| DenseAccumulator::new(b.n_cols));
                    spa.clear();
                    accum_row_spa_traced(a, b, row, spa, &mut scratch, probe);
                    probe.access(Region::RptC, row, 4, Kind::Read);
                    for (o, &(_c, _v)) in scratch.iter().enumerate() {
                        probe.access(Region::ColC, start + o, 4, Kind::Write);
                        probe.access(Region::ValC, start + o, 8, Kind::Write);
                    }
                    continue;
                }
                let table = match &mut table_holder {
                    Some(t) => {
                        t.clear();
                        t
                    }
                    None => {
                        table_holder = Some(HashTable::new(global_table_size(ip[row]), TableLoc::Global));
                        table_holder.as_mut().unwrap()
                    }
                };
                accum_row(a, b, row, table, &mut scratch, probe);
                bitonic_sort_by_key(&mut scratch, probe);
                probe.access(Region::RptC, row, 4, Kind::Read);
                for (o, &(_c, _v)) in scratch.iter().enumerate() {
                    probe.access(Region::ColC, start + o, 4, Kind::Write);
                    probe.access(Region::ValC, start + o, 8, Kind::Write);
                }
                if spec.table_size.is_none() {
                    table_holder = None;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{dense_pair, random_csr};
    use super::super::{multiply, symbolic, symbolic_cfg, PlannerPolicy};
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::Pcg32;

    #[test]
    fn two_phase_equals_single_pass_exactly() {
        let mut rng = Pcg32::seeded(321);
        let a = random_csr(&mut rng, 300, 250, 0.03);
        let b = random_csr(&mut rng, 250, 280, 0.02);
        // bit-for-bit: same structure, same value sums in the same order
        assert_eq!(multiply(&a, &b), multiply_single_pass(&a, &b));
    }

    #[test]
    fn traced_equals_fast_path() {
        let mut rng = Pcg32::seeded(77);
        let a = random_csr(&mut rng, 200, 150, 0.02);
        let b = random_csr(&mut rng, 150, 180, 0.03);
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
        assert!(probe.indirect_ranges > 0);
        assert!(probe.shared > 0);
    }

    #[test]
    fn exercises_all_four_groups() {
        // Build a matrix whose rows produce IPs in every group: B dense-ish
        // rows amplify.
        let mut rng = Pcg32::seeded(5);
        let n = 600;
        let mut coo = crate::sparse::Coo::new(n, n);
        // row 0: 1 nnz (group 0); row 1: 40 nnz (g1); row 2: 300 nnz (g2 via
        // IP multiplication); rows 3..: heavy hub rows for group 3.
        for j in 0..1 {
            coo.push(0, j * 7 % n, 1.0);
        }
        for j in 0..40 {
            coo.push(1, (j * 13) % n, 1.0);
        }
        for j in 0..300 {
            coo.push(2, (j * 2 + 1) % n, 1.0);
        }
        for r in 3..40 {
            for j in 0..r * 20 % n {
                coo.push(r, (j * 3 + r) % n, 1.0);
            }
        }
        for r in 40..n {
            for _ in 0..6 {
                coo.push(r, rng.below_usize(n), 1.0);
            }
        }
        let a = coo.to_csr();
        let ip = intermediate_products(&a, &a);
        let grouping = Grouping::build(&ip);
        let non_empty = (0..4).filter(|&g| !grouping.group_rows(g).is_empty()).count();
        assert!(non_empty >= 3, "expected ≥3 groups populated, got {non_empty}");
        let c = multiply(&a, &a);
        let r = spgemm_reference(&a, &a);
        assert!(c.approx_eq(&r, 1e-10));
        // and the seed baseline still agrees on the same stress input
        assert_eq!(c, multiply_single_pass(&a, &a));
    }

    #[test]
    fn traced_spa_rows_equal_fast_path() {
        // Dense product: the default threshold picks SPA on most rows,
        // and the traced path must still match the fast path exactly.
        let (a, b) = dense_pair(88, 72);
        let plan = symbolic(&a, &b);
        assert!(
            plan.kind_rows()[AccumKind::Spa.index()] > 0,
            "test needs SPA rows at the default threshold"
        );
        let fast = multiply(&a, &b);
        let mut probe = CountingProbe::default();
        let traced = multiply_traced(&a, &b, &mut probe);
        assert_eq!(fast, traced);
    }

    #[test]
    fn traced_bitmap_symbolic_is_streaming_and_exact() {
        // Same numeric threshold both ways, only the symbolic kernel
        // flips: outputs must stay bit-identical, and the bitmap run
        // must drop the allocation phase's indirect ranges (it reads B
        // as plain streamed loads — AIA-ineligible).
        let (a, b) = dense_pair(19, 90);
        let planner = PlannerPolicy::Exact;
        let bitmap = EngineConfig { spa_threshold: 0.25, symbolic_threshold: Some(0.0), planner };
        let hash = EngineConfig { spa_threshold: 0.25, symbolic_threshold: Some(8.0), planner };
        let mut probe_b = CountingProbe::default();
        let mut probe_h = CountingProbe::default();
        let c_b = multiply_traced_cfg(&a, &b, &mut probe_b, &bitmap);
        let c_h = multiply_traced_cfg(&a, &b, &mut probe_h, &hash);
        assert_eq!(c_b, c_h, "the symbolic kernel must never change the product");
        assert_eq!(c_b, multiply(&a, &b));
        assert!(
            probe_b.indirect_ranges < probe_h.indirect_ranges,
            "bitmap symbolic rows must not emit indirect ranges (bitmap={} hash={})",
            probe_b.indirect_ranges,
            probe_h.indirect_ranges
        );
        // The forced-bitmap plan actually had bitmap rows to trace.
        let plan = symbolic_cfg(&a, &b, &bitmap);
        assert!(plan.symbolic_kind_rows()[SymbolicKind::Bitmap.index()] > 0);
    }
}
