//! Algorithm 4 — the linear-probing hash table with multiplicative
//! hashing used by both the allocation (symbolic) and accumulation
//! (numeric) phases.
//!
//! On the GPU the table lives in shared memory for groups 0–2 and in
//! global memory for group 3; insertion uses atomicCAS / atomicAdd. Here
//! each simulated thread block owns its table, so insertion is plain
//! (the simulator charges atomic latencies through the probe events,
//! which mirror the access pattern 1:1 — same hash position sequence,
//! same probe chain length, same gather scan).

use crate::sim::probe::{Kind, Probe, Region};

/// Knuth's multiplicative constant (the paper's "multiplier").
pub const HASH_MULTIPLIER: u32 = 2_654_435_761;

/// Where the table lives — decides which probe events insertions emit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableLoc {
    /// Shared memory (groups 0–2): probe events are bank accesses.
    Shared,
    /// Global memory (group 3 fallback): probe events hit the cache
    /// hierarchy on the HashKeys/HashVals regions.
    Global,
}

/// EMPTY sentinel (the paper initializes the table to -1).
const EMPTY: u32 = u32::MAX;

/// A fixed-capacity linear-probing table for one output row.
///
/// Slot emptiness is tracked by a per-slot stamp against the table's
/// current generation, so `clear()` is O(1) — on a GPU the table memory
/// is re-initialized per block, but charging an O(capacity) clear per
/// *row* on the host made the fast path ~2× slower on group-2 rows
/// (see EXPERIMENTS.md §Perf).
pub struct HashTable {
    keys: Vec<u32>,
    vals: Vec<f64>,
    stamps: Vec<u32>,
    stamp: u32,
    mask: usize,
    pub unique: usize,
    loc: TableLoc,
    /// Slots occupied this generation — lets the *functional* fast path
    /// gather in O(unique) (`gather_list`). The traced path still uses
    /// the GPU-faithful full-capacity scan (`gather`).
    occupied: Vec<u32>,
}

impl HashTable {
    /// `size` must be a power of two (Table I sizes are).
    pub fn new(size: usize, loc: TableLoc) -> HashTable {
        assert!(size.is_power_of_two(), "table size {size} not a power of two");
        HashTable {
            keys: vec![EMPTY; size],
            vals: vec![0.0; size],
            stamps: vec![0; size],
            stamp: 1,
            mask: size - 1,
            unique: 0,
            loc,
            occupied: Vec::new(),
        }
    }

    /// Ensure capacity ≥ `size` (rounded up to a power of two), clearing
    /// in either case. Reusing one growable table across group-3 rows
    /// avoids an O(size) allocation + zero-init per row (§Perf).
    pub fn reset_with_capacity(&mut self, size: usize) {
        let size = size.next_power_of_two();
        if size > self.capacity() {
            self.keys = vec![EMPTY; size];
            self.vals = vec![0.0; size];
            self.stamps = vec![0; size];
            self.stamp = 0;
            self.mask = size - 1;
        }
        self.clear();
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Reset for the next row: O(1) generation bump (keeps the
    /// allocation; full re-init only on stamp wraparound).
    pub fn clear(&mut self) {
        self.unique = 0;
        self.occupied.clear();
        if self.stamp == u32::MAX {
            self.stamps.fill(0);
            self.stamp = 1;
        } else {
            self.stamp += 1;
        }
    }

    #[inline]
    fn live(&self, pos: usize) -> bool {
        self.stamps[pos] == self.stamp
    }

    #[inline]
    fn occupy(&mut self, pos: usize, key: u32) {
        self.stamps[pos] = self.stamp;
        self.keys[pos] = key;
        self.vals[pos] = 0.0;
        self.occupied.push(pos as u32);
    }

    #[inline]
    fn hash(&self, key: u32) -> usize {
        (key.wrapping_mul(HASH_MULTIPLIER) as usize) & self.mask
    }

    #[inline]
    fn emit<P: Probe>(&self, probe: &mut P, pos: usize, numeric: bool, kind: Kind) {
        match self.loc {
            TableLoc::Shared => probe.shared(pos, kind),
            TableLoc::Global => {
                probe.access(Region::HashKeys, pos, 4, kind);
                if numeric {
                    probe.access(Region::HashVals, pos, 8, kind);
                }
            }
        }
    }

    /// Symbolic insert (allocation phase): record the key, return `true`
    /// if it was new. Panics if the table is full (cannot happen when
    /// capacity ≥ the group's IP upper bound — see Table I).
    pub fn insert_symbolic<P: Probe>(&mut self, key: u32, probe: &mut P) -> bool {
        debug_assert_ne!(key, EMPTY);
        let mut pos = self.hash(key);
        probe.compute(2); // multiply + mask
        loop {
            self.emit(probe, pos, false, Kind::Read);
            if self.live(pos) && self.keys[pos] == key {
                return false;
            }
            if !self.live(pos) {
                // atomicCAS on the GPU.
                self.emit(probe, pos, false, Kind::Atomic);
                self.occupy(pos, key);
                self.unique += 1;
                return true;
            }
            pos = (pos + 1) & self.mask;
            probe.compute(1);
            assert_ne!(pos, self.hash(key), "hash table overflow (size {})", self.capacity());
        }
    }

    /// Numeric insert (accumulation phase): `Table[pos] += v` under the
    /// key, creating the slot if needed (AddInTable in Algorithm 4).
    pub fn insert_numeric<P: Probe>(&mut self, key: u32, v: f64, probe: &mut P) {
        debug_assert_ne!(key, EMPTY);
        let mut pos = self.hash(key);
        probe.compute(2);
        loop {
            self.emit(probe, pos, false, Kind::Read);
            if self.live(pos) && self.keys[pos] == key {
                // atomicAdd on Tableval.
                self.emit(probe, pos, true, Kind::Atomic);
                self.vals[pos] += v;
                probe.compute(2); // fma
                return;
            }
            if !self.live(pos) {
                self.emit(probe, pos, false, Kind::Atomic);
                self.occupy(pos, key);
                self.unique += 1;
                self.emit(probe, pos, true, Kind::Atomic);
                self.vals[pos] += v;
                probe.compute(2);
                return;
            }
            pos = (pos + 1) & self.mask;
            probe.compute(1);
            assert_ne!(pos, self.hash(key), "hash table overflow (size {})", self.capacity());
        }
    }

    /// Gather non-empty `(key, val)` slots by scanning the whole table
    /// (the element-gathering step of the accumulation phase). Emits one
    /// read per scanned slot.
    pub fn gather<P: Probe>(&self, out: &mut Vec<(u32, f64)>, probe: &mut P) {
        out.clear();
        for pos in 0..=self.mask {
            self.emit(probe, pos, false, Kind::Read);
            if self.live(pos) {
                out.push((self.keys[pos], self.vals[pos]));
            }
        }
        debug_assert_eq!(out.len(), self.unique);
    }

    /// O(unique) gather for the functional fast path (no probe events —
    /// the traced path uses [`HashTable::gather`]'s full scan, which is
    /// what the GPU kernel does).
    pub fn gather_list(&self, out: &mut Vec<(u32, f64)>) {
        out.clear();
        out.extend(self.occupied.iter().map(|&p| (self.keys[p as usize], self.vals[p as usize])));
        debug_assert_eq!(out.len(), self.unique);
    }

    /// Gather keys only (allocation phase does not need them in the
    /// paper, but tests use this to check symbolic/numeric agreement).
    pub fn keys(&self) -> Vec<u32> {
        let mut ks: Vec<u32> =
            (0..=self.mask).filter(|&p| self.live(p)).map(|p| self.keys[p]).collect();
        ks.sort_unstable();
        ks
    }
}

/// Dense sparse-accumulator (SPA) for plan-guided dense output rows:
/// one `f64` slot per output column plus a generation-stamped occupancy
/// word, so `clear()` is O(1) exactly like [`HashTable`]'s.
///
/// The accumulation order per column is the B-stream encounter order —
/// identical to the hash path's `Table[pos] += v` order — so a SPA row
/// is **bit-identical** to the same row accumulated through a hash
/// table (the caller sorts the gathered pairs by column either way; the
/// keys are unique, so the sort is deterministic).
///
/// On the GPU the SPA lives in global memory (one array per thread
/// block); inserts are `atomicAdd`s at `vals[col]` and the gather is a
/// sequential scan — streaming, not indirection, which is why the
/// simulator prices SPA rows through [`Region::SpaVals`]/
/// [`Region::SpaFlags`] accesses instead of `indirect_range` (SPA rows
/// are AIA-ineligible).
pub struct DenseAccumulator {
    vals: Vec<f64>,
    stamps: Vec<u32>,
    stamp: u32,
    /// Columns touched this generation, in first-touch order.
    occupied: Vec<u32>,
}

impl DenseAccumulator {
    /// Accumulator for output rows of width `n_cols`.
    pub fn new(n_cols: usize) -> DenseAccumulator {
        DenseAccumulator { vals: vec![0.0; n_cols], stamps: vec![0; n_cols], stamp: 1, occupied: Vec::new() }
    }

    /// Output width this accumulator covers.
    pub fn width(&self) -> usize {
        self.vals.len()
    }

    /// Distinct columns touched since the last [`DenseAccumulator::clear`].
    pub fn unique(&self) -> usize {
        self.occupied.len()
    }

    /// Reset for the next row: O(1) generation bump (full re-init only
    /// on stamp wraparound).
    pub fn clear(&mut self) {
        self.occupied.clear();
        if self.stamp == u32::MAX {
            self.stamps.fill(0);
            self.stamp = 1;
        } else {
            self.stamp += 1;
        }
    }

    /// `vals[col] += v` (fast functional path, no probe events).
    #[inline]
    pub fn add(&mut self, col: u32, v: f64) {
        let p = col as usize;
        if self.stamps[p] != self.stamp {
            self.stamps[p] = self.stamp;
            // Mirror the hash path exactly: occupy zeroes, then adds.
            self.vals[p] = 0.0;
            self.occupied.push(col);
        }
        self.vals[p] += v;
    }

    /// [`DenseAccumulator::add`] with the GPU access pattern emitted:
    /// an occupancy-flag read, a flag CAS on first touch, and the
    /// value `atomicAdd` — all column-indexed into the contiguous SPA
    /// arrays (no probe chain, no indirection).
    pub fn add_traced<P: Probe>(&mut self, col: u32, v: f64, probe: &mut P) {
        let p = col as usize;
        probe.access(Region::SpaFlags, p, 4, Kind::Read);
        if self.stamps[p] != self.stamp {
            self.stamps[p] = self.stamp;
            self.vals[p] = 0.0;
            self.occupied.push(col);
            probe.access(Region::SpaFlags, p, 4, Kind::Atomic);
        }
        probe.access(Region::SpaVals, p, 8, Kind::Atomic);
        self.vals[p] += v;
        probe.compute(2); // fma
    }

    /// O(unique) gather for the functional fast path (first-touch
    /// order; the caller sorts by column, same as the hash path).
    pub fn gather_list(&self, out: &mut Vec<(u32, f64)>) {
        out.clear();
        out.extend(self.occupied.iter().map(|&c| (c, self.vals[c as usize])));
    }

    /// GPU-faithful gather: sequentially scan the whole dense array,
    /// emitting one flag read per column and one value read per live
    /// slot. This streaming scan is the SPA's cost signature — compare
    /// [`HashTable::gather`]'s scattered full-capacity walk.
    pub fn gather<P: Probe>(&self, out: &mut Vec<(u32, f64)>, probe: &mut P) {
        out.clear();
        for p in 0..self.vals.len() {
            probe.access(Region::SpaFlags, p, 4, Kind::Read);
            if self.stamps[p] == self.stamp {
                probe.access(Region::SpaVals, p, 8, Kind::Read);
                out.push((p as u32, self.vals[p]));
            }
        }
        debug_assert_eq!(out.len(), self.unique());
    }
}

/// Dense bitmap unique-counter for plan-guided *symbolic* rows — the
/// counting counterpart of [`DenseAccumulator`]: one generation-stamped
/// occupancy word per output column, O(1) clear, no values at all (the
/// symbolic phase only needs the unique count).
///
/// On the GPU the bitmap lives in global memory (one array per thread
/// block); a first touch is an `atomicCAS` on the flag word whose
/// success feeds a per-block unique counter, so — unlike the hash
/// kernel — counting never probes a chain and never scans a table: the
/// accesses are column-indexed into one contiguous array. That is why
/// the simulator prices bitmap rows through [`Region::SpaFlags`]
/// accesses and plain streamed B-row loads instead of
/// [`Probe::indirect_range`] (bitmap symbolic rows are AIA-ineligible,
/// mirroring the numeric SPA's pricing).
pub struct RowCounter {
    stamps: Vec<u32>,
    stamp: u32,
    unique: usize,
}

impl RowCounter {
    /// Counter for output rows of width `n_cols`.
    pub fn new(n_cols: usize) -> RowCounter {
        RowCounter { stamps: vec![0; n_cols], stamp: 1, unique: 0 }
    }

    /// Output width this counter covers.
    pub fn width(&self) -> usize {
        self.stamps.len()
    }

    /// Distinct columns counted since the last [`RowCounter::clear`].
    pub fn unique(&self) -> usize {
        self.unique
    }

    /// Reset for the next row: O(1) generation bump (full re-init only
    /// on stamp wraparound).
    pub fn clear(&mut self) {
        self.unique = 0;
        if self.stamp == u32::MAX {
            self.stamps.fill(0);
            self.stamp = 1;
        } else {
            self.stamp += 1;
        }
    }

    /// Count `col`, returning `true` on first touch (fast functional
    /// path, no probe events).
    #[inline]
    pub fn count(&mut self, col: u32) -> bool {
        let p = col as usize;
        if self.stamps[p] != self.stamp {
            self.stamps[p] = self.stamp;
            self.unique += 1;
            return true;
        }
        false
    }

    /// [`RowCounter::count`] with the GPU access pattern emitted: an
    /// occupancy-flag read, and on first touch the flag CAS (whose
    /// success is the count — no gather scan ever runs). All accesses
    /// are column-indexed into the contiguous flag array: no probe
    /// chain, no indirection.
    pub fn count_traced<P: Probe>(&mut self, col: u32, probe: &mut P) -> bool {
        let p = col as usize;
        probe.access(Region::SpaFlags, p, 4, Kind::Read);
        probe.compute(1); // the stamp compare
        if self.stamps[p] != self.stamp {
            self.stamps[p] = self.stamp;
            self.unique += 1;
            probe.access(Region::SpaFlags, p, 4, Kind::Atomic);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::{CountingProbe, NullProbe};

    #[test]
    fn symbolic_counts_unique() {
        let mut t = HashTable::new(64, TableLoc::Shared);
        let mut p = NullProbe;
        assert!(t.insert_symbolic(5, &mut p));
        assert!(!t.insert_symbolic(5, &mut p));
        assert!(t.insert_symbolic(9, &mut p));
        assert_eq!(t.unique, 2);
        assert_eq!(t.keys(), vec![5, 9]);
    }

    #[test]
    fn numeric_accumulates() {
        let mut t = HashTable::new(16, TableLoc::Shared);
        let mut p = NullProbe;
        t.insert_numeric(3, 1.5, &mut p);
        t.insert_numeric(3, 2.5, &mut p);
        t.insert_numeric(7, -1.0, &mut p);
        let mut out = Vec::new();
        t.gather(&mut out, &mut p);
        out.sort_unstable_by_key(|e| e.0);
        assert_eq!(out, vec![(3, 4.0), (7, -1.0)]);
    }

    #[test]
    fn collisions_resolved_by_linear_probing() {
        // size 4: many keys collide; all must still be stored
        let mut t = HashTable::new(4, TableLoc::Shared);
        let mut p = NullProbe;
        for k in [0u32, 1, 2, 3] {
            t.insert_symbolic(k, &mut p);
        }
        assert_eq!(t.unique, 4);
        assert_eq!(t.keys(), vec![0, 1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "hash table overflow")]
    fn overflow_panics() {
        let mut t = HashTable::new(4, TableLoc::Shared);
        let mut p = NullProbe;
        for k in 0..5u32 {
            t.insert_symbolic(k, &mut p);
        }
    }

    #[test]
    fn shared_vs_global_probe_events() {
        let mut shared = HashTable::new(8, TableLoc::Shared);
        let mut global = HashTable::new(8, TableLoc::Global);
        let mut ps = CountingProbe::default();
        let mut pg = CountingProbe::default();
        shared.insert_numeric(1, 1.0, &mut ps);
        global.insert_numeric(1, 1.0, &mut pg);
        assert!(ps.shared > 0 && ps.accesses == 0);
        assert!(pg.accesses > 0 && pg.shared == 0);
    }

    #[test]
    fn clear_resets() {
        let mut t = HashTable::new(8, TableLoc::Shared);
        let mut p = NullProbe;
        t.insert_numeric(1, 1.0, &mut p);
        t.clear();
        assert_eq!(t.unique, 0);
        assert!(t.keys().is_empty());
    }

    #[test]
    fn gather_scans_full_capacity() {
        let mut t = HashTable::new(32, TableLoc::Global);
        let mut p = NullProbe;
        t.insert_numeric(1, 1.0, &mut p);
        let mut c = CountingProbe::default();
        let mut out = Vec::new();
        t.gather(&mut out, &mut c);
        assert_eq!(c.accesses, 32); // whole-table scan
    }

    #[test]
    fn spa_accumulates_like_hash_table() {
        // Same insert stream through both accumulators: sorted gathers
        // must be bit-identical (this is the SPA correctness contract).
        let stream = [(3u32, 1.5), (7, -1.0), (3, 2.5), (0, 0.125), (7, 4.0), (3, -0.5)];
        let mut t = HashTable::new(16, TableLoc::Shared);
        let mut spa = DenseAccumulator::new(16);
        for &(c, v) in &stream {
            t.insert_numeric(c, v, &mut NullProbe);
            spa.add(c, v);
        }
        let mut from_t = Vec::new();
        t.gather_list(&mut from_t);
        from_t.sort_unstable_by_key(|e| e.0);
        let mut from_spa = Vec::new();
        spa.gather_list(&mut from_spa);
        from_spa.sort_unstable_by_key(|e| e.0);
        assert_eq!(from_t, from_spa);
        assert_eq!(spa.unique(), 3);
    }

    #[test]
    fn spa_clear_is_generation_bump() {
        let mut spa = DenseAccumulator::new(8);
        spa.add(2, 1.0);
        spa.add(2, 1.0);
        assert_eq!(spa.unique(), 1);
        spa.clear();
        assert_eq!(spa.unique(), 0);
        spa.add(2, 0.5);
        let mut out = Vec::new();
        spa.gather_list(&mut out);
        assert_eq!(out, vec![(2, 0.5)], "stale generation must not leak");
    }

    #[test]
    fn row_counter_counts_uniques_like_symbolic_hash() {
        // Same column stream through the hash table's symbolic inserts
        // and the bitmap counter: unique counts must agree exactly.
        let stream = [3u32, 7, 3, 0, 7, 3, 12, 0];
        let mut t = HashTable::new(16, TableLoc::Shared);
        let mut c = RowCounter::new(16);
        for &col in &stream {
            let new_t = t.insert_symbolic(col, &mut NullProbe);
            let new_c = c.count(col);
            assert_eq!(new_t, new_c, "first-touch detection must agree on col {col}");
        }
        assert_eq!(c.unique(), t.unique);
        assert_eq!(c.unique(), 4);
        assert_eq!(c.width(), 16);
    }

    #[test]
    fn row_counter_clear_is_generation_bump() {
        let mut c = RowCounter::new(8);
        assert!(c.count(2));
        assert!(!c.count(2));
        assert_eq!(c.unique(), 1);
        c.clear();
        assert_eq!(c.unique(), 0);
        assert!(c.count(2), "stale generation must not leak");
        assert_eq!(c.unique(), 1);
    }

    #[test]
    fn row_counter_traced_streams_not_probes() {
        let mut c = RowCounter::new(32);
        let mut p = CountingProbe::default();
        assert!(c.count_traced(5, &mut p));
        assert!(!c.count_traced(5, &mut p));
        // First touch: flag read + flag CAS; repeat: flag read only.
        // No shared-memory events, no indirection, no value traffic.
        assert_eq!(p.accesses, 3);
        assert_eq!(p.atomic, 1);
        assert_eq!(p.shared, 0);
        assert_eq!(p.indirect_ranges, 0);
    }

    #[test]
    fn spa_traced_streams_not_probes() {
        let mut spa = DenseAccumulator::new(32);
        let mut c = CountingProbe::default();
        spa.add_traced(5, 1.0, &mut c);
        spa.add_traced(5, 2.0, &mut c);
        // First touch: flag read + flag CAS + val atomic; repeat: flag
        // read + val atomic. No shared-memory events, no indirection.
        assert_eq!(c.accesses, 5);
        assert_eq!(c.atomic, 3);
        assert_eq!(c.shared, 0);
        assert_eq!(c.indirect_ranges, 0);
        // GPU-faithful gather scans the full width (one flag read per
        // column + one value read per live slot), in column order.
        let mut out = Vec::new();
        let mut g = CountingProbe::default();
        spa.gather(&mut out, &mut g);
        assert_eq!(g.accesses, 32 + 1);
        assert_eq!(out, vec![(5, 3.0)]);
    }
}
