//! Algorithm 1 — intermediate-product counting.
//!
//! `IP[i] = Σ_{j ∈ row i of A} nnz(B[col_A[j], :])` is the workload
//! metric the row-grouping phase bins on, and `2·ΣIP` is the FLOP count
//! the paper's GFLOPS figures use.

use crate::sim::probe::{Kind, Phase, Probe, Region};
use crate::sparse::Csr;
use crate::util::par_chunks;

/// Rows per simulated thread block in the grouping/IP kernel.
pub const IP_BLOCK_ROWS: usize = 256;

/// Fast parallel IP count (no instrumentation).
pub fn intermediate_products(a: &Csr, b: &Csr) -> Vec<u64> {
    assert_eq!(a.n_cols, b.n_rows);
    let mut ip = vec![0u64; a.n_rows];
    {
        let ptr = ip.as_mut_ptr() as usize;
        par_chunks(a.n_rows, |start, end| {
            let p = ptr as *mut u64;
            for i in start..end {
                let (cols, _) = a.row(i);
                let mut count = 0u64;
                for &c in cols {
                    count += b.row_nnz(c as usize) as u64;
                }
                // SAFETY: disjoint chunks.
                unsafe { *p.add(i) = count };
            }
        });
    }
    ip
}

/// Instrumented IP count: emits the grouping-phase memory trace
/// (sequential reads of rpt_A/col_A, the *indirect* rpt_B lookups that
/// AIA's range-2 gather accelerates, and the atomic group-counter and
/// IpCount writes).
pub fn intermediate_products_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Vec<u64> {
    assert_eq!(a.n_cols, b.n_rows);
    let mut ip = vec![0u64; a.n_rows];
    let n_blocks = a.n_rows.div_ceil(IP_BLOCK_ROWS);
    for blk in 0..n_blocks {
        probe.begin_block(blk, Phase::Grouping);
        let lo = blk * IP_BLOCK_ROWS;
        let hi = ((blk + 1) * IP_BLOCK_ROWS).min(a.n_rows);
        for i in lo..hi {
            probe.access(Region::RptA, i, 4, Kind::Read);
            probe.access(Region::RptA, i + 1, 4, Kind::Read);
            let (cols, _) = a.row(i);
            let mut count = 0u64;
            for (jo, &c) in cols.iter().enumerate() {
                probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
                // rpt_B[c], rpt_B[c+1]: the two-level indirection, bounds
                // only (AIA ranged index with R = 2 over rpt_B).
                probe.indirect_range(Region::RptB, c as usize, &[], 0, 0);
                count += b.row_nnz(c as usize) as u64;
                probe.compute(2);
            }
            ip[i] = count;
            probe.access(Region::IpCount, i, 8, Kind::Write);
            // Group classification uses an atomic counter per group
            // (the paper reports >10 % of time here due to atomics).
            probe.access(Region::GroupCtr, group_index_for_ip(count), 4, Kind::Atomic);
            probe.compute(4);
        }
    }
    ip
}

/// Logarithmic binning of an IP value into the paper's four groups
/// (Table I ranges).
#[inline]
pub fn group_index_for_ip(ip: u64) -> usize {
    match ip {
        0..=31 => 0,
        32..=511 => 1,
        512..=8191 => 2,
        _ => 3,
    }
}

/// Total intermediate products (the paper's FLOP basis: FLOPs = 2·total).
pub fn total_ip(a: &Csr, b: &Csr) -> u64 {
    intermediate_products(a, b).iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::sparse::Csr;

    fn small() -> (Csr, Csr) {
        let a = Csr::from_dense(&[vec![1.0, 1.0, 0.0], vec![0.0, 0.0, 1.0], vec![0.0, 0.0, 0.0]]);
        let b = Csr::from_dense(&[vec![1.0, 1.0, 1.0], vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 1.0]]);
        (a, b)
    }

    #[test]
    fn counts_match_definition() {
        let (a, b) = small();
        // row 0 of A hits B rows 0 (3 nnz) and 1 (1 nnz) → 4
        // row 1 hits B row 2 (2 nnz) → 2 ; row 2 empty → 0
        assert_eq!(intermediate_products(&a, &b), vec![4, 2, 0]);
        assert_eq!(total_ip(&a, &b), 6);
    }

    #[test]
    fn traced_matches_fast_path() {
        let (a, b) = small();
        let mut probe = CountingProbe::default();
        let traced = intermediate_products_traced(&a, &b, &mut probe);
        assert_eq!(traced, intermediate_products(&a, &b));
        // one indirect range per nnz(A)
        assert_eq!(probe.indirect_ranges, a.nnz() as u64);
        // one atomic per row
        assert_eq!(probe.atomic, a.n_rows as u64);
        assert!(probe.blocks >= 1);
    }

    #[test]
    fn group_bins_match_table1() {
        assert_eq!(group_index_for_ip(0), 0);
        assert_eq!(group_index_for_ip(31), 0);
        assert_eq!(group_index_for_ip(32), 1);
        assert_eq!(group_index_for_ip(511), 1);
        assert_eq!(group_index_for_ip(512), 2);
        assert_eq!(group_index_for_ip(8191), 2);
        assert_eq!(group_index_for_ip(8192), 3);
        assert_eq!(group_index_for_ip(u64::MAX), 3);
    }
}
