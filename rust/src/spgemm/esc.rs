//! ESC (expand–sort–compress) SpGEMM — the "classic baseline" standing
//! in for cuSPARSE's csrgemm (DESIGN.md §Hardware substitution).
//!
//! The defining property vs. the hash engine is *memory traffic*: every
//! intermediate product is materialized to global memory (expand), the
//! whole buffer is sorted (multiple full passes), then compressed. That
//! traffic profile — not constant factors — is why cuSPARSE loses on
//! skewed workloads, and the simulator charges it faithfully.
//!
//! The functional path processes row *tiles* so host memory stays
//! bounded on huge products; the traced path charges the full global
//! expand buffer the GPU algorithm would allocate.

use crate::sim::probe::{Kind, NullProbe, Phase, Probe, Region};
use crate::sparse::Csr;
use crate::util::{par_chunks, par_map};

/// Rows per functional tile (bounds the live expand buffer).
const TILE_ROWS: usize = 4096;

/// Simulated thread-block extent in the expand kernel (for block ids).
const EXPAND_BLOCK_ROWS: usize = 128;

/// Fast parallel ESC SpGEMM.
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    multiply_impl(a, b, &mut NullProbe, false)
}

/// Instrumented sequential ESC SpGEMM (same output).
pub fn multiply_traced<P: Probe>(a: &Csr, b: &Csr, probe: &mut P) -> Csr {
    multiply_impl(a, b, probe, true)
}

fn multiply_impl<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, traced: bool) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let n = a.n_rows;
    let mut rpt = vec![0usize; n + 1];
    let mut col: Vec<u32> = Vec::new();
    let mut val: Vec<f64> = Vec::new();
    let mut next_block = 0usize;

    let mut tile_entries: Vec<(u32, u32, f64)> = Vec::new();
    for tile_start in (0..n).step_by(TILE_ROWS) {
        let tile_end = (tile_start + TILE_ROWS).min(n);
        tile_entries.clear();

        // ---- expand ----
        if traced {
            for (bi, blk_start) in (tile_start..tile_end).step_by(EXPAND_BLOCK_ROWS).enumerate() {
                let _ = bi;
                probe.begin_block(next_block, Phase::EscExpand);
                next_block += 1;
                let blk_end = (blk_start + EXPAND_BLOCK_ROWS).min(tile_end);
                for i in blk_start..blk_end {
                    expand_row_traced(a, b, i, &mut tile_entries, probe);
                }
            }
        } else {
            // Parallel expand: per-row offsets from IP counts.
            let ips: Vec<usize> = par_map(tile_end - tile_start, |o| {
                let i = tile_start + o;
                a.row(i).0.iter().map(|&c| b.row_nnz(c as usize)).sum()
            });
            let mut offsets = vec![0usize; ips.len() + 1];
            for (i, &c) in ips.iter().enumerate() {
                offsets[i + 1] = offsets[i] + c;
            }
            tile_entries.resize(offsets[ips.len()], (0, 0, 0.0));
            let ptr = tile_entries.as_mut_ptr() as usize;
            par_chunks(tile_end - tile_start, |s, e| {
                let p = ptr as *mut (u32, u32, f64);
                for o in s..e {
                    let i = tile_start + o;
                    let mut w = offsets[o];
                    let (ac, av) = a.row(i);
                    for (&k, &x) in ac.iter().zip(av) {
                        let (bc, bv) = b.row(k as usize);
                        for (&c, &y) in bc.iter().zip(bv) {
                            // SAFETY: per-row output ranges are disjoint.
                            unsafe { *p.add(w) = (i as u32, c, x * y) };
                            w += 1;
                        }
                    }
                }
            });
        }

        // ---- sort ----
        if traced {
            // Radix/merge sort on the GPU: ~log passes over the buffer,
            // each reading and writing every 16-byte entry. Charge 4
            // passes (typical for 64-bit keys with 16-bit digits).
            probe.begin_block(next_block, Phase::EscSort);
            next_block += 1;
            let len = tile_entries.len();
            for pass in 0..4 {
                for e in 0..len {
                    probe.access(Region::EscExpand, (pass * len + e) % len.max(1), 16, Kind::Read);
                    probe.access(Region::EscExpand, (pass * len + e) % len.max(1), 16, Kind::Write);
                    probe.compute(2);
                }
            }
        }
        tile_entries.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);

        // ---- compress ----
        if traced {
            probe.begin_block(next_block, Phase::EscCompress);
            next_block += 1;
        }
        let mut idx = 0usize;
        while idx < tile_entries.len() {
            let (r, c, mut v) = tile_entries[idx];
            if traced {
                probe.access(Region::EscExpand, idx, 16, Kind::Read);
            }
            let mut j = idx + 1;
            while j < tile_entries.len() && tile_entries[j].0 == r && tile_entries[j].1 == c {
                if traced {
                    probe.access(Region::EscExpand, j, 16, Kind::Read);
                }
                v += tile_entries[j].2;
                probe.compute(1);
                j += 1;
            }
            col.push(c);
            val.push(v);
            if traced {
                probe.access(Region::ColC, col.len() - 1, 4, Kind::Write);
                probe.access(Region::ValC, val.len() - 1, 8, Kind::Write);
            }
            rpt[r as usize + 1] += 1;
            idx = j;
        }
    }
    for i in 0..n {
        rpt[i + 1] += rpt[i];
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

/// Statistics-only traced ESC run: traces every `every`-th expand block
/// and scales the sort/compress phases to the sampled entry count
/// (the machine model scales counters back up). No product is built —
/// use [`multiply`] for the functional result.
pub fn multiply_traced_stats<P: Probe>(a: &Csr, b: &Csr, probe: &mut P, every: usize) {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch");
    let every = every.max(1);
    let mut next_block = 0usize;
    let mut sampled_entries = 0usize;
    let mut scratch: Vec<(u32, u32, f64)> = Vec::new();
    // ---- expand (sampled blocks) ----
    for blk_start in (0..a.n_rows).step_by(EXPAND_BLOCK_ROWS) {
        let sampled = next_block % every == 0;
        if sampled {
            probe.begin_block(next_block, Phase::EscExpand);
        }
        next_block += 1;
        if !sampled {
            continue;
        }
        let blk_end = (blk_start + EXPAND_BLOCK_ROWS).min(a.n_rows);
        for i in blk_start..blk_end {
            expand_row_traced(a, b, i, &mut scratch, probe);
        }
        sampled_entries += scratch.len();
        scratch.clear();
    }
    // ---- sort: 4 radix passes, blocked so work spreads across SMs ----
    const SORT_BLOCK: usize = 8192;
    for pass in 0..4usize {
        for blk_start in (0..sampled_entries).step_by(SORT_BLOCK) {
            probe.begin_block(next_block, Phase::EscSort);
            next_block += 1;
            let blk_end = (blk_start + SORT_BLOCK).min(sampled_entries);
            for e in blk_start..blk_end {
                // radix scatter: read sequential, write to a
                // digit-dependent (effectively random) position.
                probe.access(Region::EscExpand, e, 16, Kind::Read);
                probe.access(Region::EscExpand, (e.wrapping_mul(2654435761)) % sampled_entries.max(1), 16, Kind::Write);
                probe.compute(2 + (pass & 1) as u64);
            }
        }
    }
    // ---- compress: one blocked pass ----
    for blk_start in (0..sampled_entries).step_by(SORT_BLOCK) {
        probe.begin_block(next_block, Phase::EscCompress);
        next_block += 1;
        let blk_end = (blk_start + SORT_BLOCK).min(sampled_entries);
        for e in blk_start..blk_end {
            probe.access(Region::EscExpand, e, 16, Kind::Read);
            probe.compute(1);
            // charging every entry an output write is the upper bound the
            // GPU baseline pays with atomically-bumped output cursors.
            probe.access(Region::ColC, e, 4, Kind::Write);
            probe.access(Region::ValC, e, 8, Kind::Write);
        }
    }
}

/// Traced expand of one row: reads A row, performs the same two-level
/// indirection into B (which the baseline does *without* AIA — it is the
/// paper's comparison point), and writes every intermediate product to
/// the global expand buffer.
fn expand_row_traced<P: Probe>(a: &Csr, b: &Csr, i: usize, out: &mut Vec<(u32, u32, f64)>, probe: &mut P) {
    probe.access(Region::RptA, i, 4, Kind::Read);
    probe.access(Region::RptA, i + 1, 4, Kind::Read);
    let (ac, av) = a.row(i);
    for (jo, (&k, &x)) in ac.iter().zip(av).enumerate() {
        probe.access(Region::ColA, a.rpt[i] + jo, 4, Kind::Read);
        probe.access(Region::ValA, a.rpt[i] + jo, 8, Kind::Read);
        let (lo, hi) = (b.rpt[k as usize], b.rpt[k as usize + 1]);
        probe.indirect_range(Region::RptB, k as usize, &[Region::ColB, Region::ValB], lo, hi);
        let (bc, bv) = b.row(k as usize);
        for (&c, &y) in bc.iter().zip(bv) {
            out.push((i as u32, c, x * y));
            probe.access(Region::EscExpand, out.len() - 1, 16, Kind::Write);
            probe.compute(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::CountingProbe;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::{qc, Pcg32};

    fn random_csr(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Csr {
        let mut coo = crate::sparse::Coo::new(rows, cols);
        let target = ((rows * cols) as f64 * density) as usize;
        for _ in 0..target {
            coo.push(rng.below_usize(rows), rng.below_usize(cols), rng.f64_range(-2.0, 2.0));
        }
        coo.to_csr()
    }

    #[test]
    fn matches_reference_small() {
        let a = Csr::from_dense(&[vec![1.0, 2.0], vec![3.0, 0.0]]);
        let b = Csr::from_dense(&[vec![0.0, 1.0], vec![1.0, 1.0]]);
        assert!(multiply(&a, &b).approx_eq(&spgemm_reference(&a, &b), 1e-12));
    }

    #[test]
    fn traced_equals_fast() {
        let mut rng = Pcg32::seeded(3);
        let a = random_csr(&mut rng, 120, 90, 0.05);
        let b = random_csr(&mut rng, 90, 110, 0.05);
        let mut probe = CountingProbe::default();
        assert_eq!(multiply(&a, &b), multiply_traced(&a, &b, &mut probe));
        // Baseline also goes through the indirection callback (the machine
        // model decides that baseline runs never get AIA).
        assert!(probe.indirect_ranges > 0);
        assert!(probe.accesses > 0);
    }

    #[test]
    fn matches_reference_randomized() {
        qc::check(20, 4096, |g| {
            let rows = g.dim();
            let inner = g.dim();
            let cols = g.dim();
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let a = random_csr(&mut rng, rows, inner, 0.15);
            let b = random_csr(&mut rng, inner, cols, 0.15);
            let c = multiply(&a, &b);
            assert!(c.validate().is_ok());
            assert!(c.approx_eq(&spgemm_reference(&a, &b), 1e-10));
        });
    }

    #[test]
    fn tiling_boundary_is_seamless() {
        // More rows than one tile to cross the TILE_ROWS boundary.
        let mut rng = Pcg32::seeded(8);
        let n = TILE_ROWS + 500;
        let a = random_csr(&mut rng, n, 300, 0.004);
        let b = random_csr(&mut rng, 300, 200, 0.02);
        assert!(multiply(&a, &b).approx_eq(&spgemm_reference(&a, &b), 1e-10));
    }
}
