//! Reference SpGEMM: sequential Gustavson row-wise product with a dense
//! accumulator (SPA). Slow but obviously correct — the oracle every
//! other engine is tested against.

use crate::sparse::Csr;

/// `C = A · B` with a dense sparse-accumulator per row.
pub fn spgemm_reference(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.n_cols, b.n_rows, "dimension mismatch: {}x{} · {}x{}", a.n_rows, a.n_cols, b.n_rows, b.n_cols);
    let n_cols = b.n_cols;
    let mut acc: Vec<f64> = vec![0.0; n_cols];
    let mut touched: Vec<u32> = Vec::new();

    let mut rpt = Vec::with_capacity(a.n_rows + 1);
    rpt.push(0usize);
    let mut col: Vec<u32> = Vec::new();
    let mut val: Vec<f64> = Vec::new();

    for i in 0..a.n_rows {
        touched.clear();
        let (a_cols, a_vals) = a.row(i);
        for (&k, &av) in a_cols.iter().zip(a_vals) {
            let (b_cols, b_vals) = b.row(k as usize);
            for (&c, &bv) in b_cols.iter().zip(b_vals) {
                if acc[c as usize] == 0.0 && !touched.contains(&c) {
                    touched.push(c);
                }
                acc[c as usize] += av * bv;
            }
        }
        touched.sort_unstable();
        for &c in &touched {
            // Keep numeric zeros that arose from cancellation out of the
            // pattern? The paper's hash kernels keep every structurally
            // produced column, so we keep them too (standard SpGEMM
            // semantics: structural, not numeric, sparsity).
            col.push(c);
            val.push(acc[c as usize]);
            acc[c as usize] = 0.0;
        }
        rpt.push(col.len());
    }
    Csr::new_unchecked(a.n_rows, b.n_cols, rpt, col, val)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    #[test]
    fn matches_dense_multiply() {
        let a = Csr::from_dense(&[vec![1.0, 2.0, 0.0], vec![0.0, 0.0, 3.0]]);
        let b = Csr::from_dense(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![2.0, 2.0]]);
        let c = spgemm_reference(&a, &b);
        assert_eq!(c.to_dense(), vec![vec![1.0, 2.0], vec![6.0, 6.0]]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Csr::from_dense(&[vec![1.5, 0.0], vec![0.0, -2.0]]);
        let i = Csr::identity(2);
        assert!(spgemm_reference(&a, &i).approx_eq(&a, 1e-15));
        assert!(spgemm_reference(&i, &a).approx_eq(&a, 1e-15));
    }

    #[test]
    fn keeps_structural_zeros_from_cancellation() {
        // a row producing +1 and -1 on the same output column
        let a = Csr::from_dense(&[vec![1.0, 1.0]]);
        let b = Csr::from_dense(&[vec![1.0], vec![-1.0]]);
        let c = spgemm_reference(&a, &b);
        assert_eq!(c.nnz(), 1);
        assert_eq!(c.val[0], 0.0);
    }

    #[test]
    fn empty_rows_and_cols() {
        let a = Csr::zeros(3, 4);
        let b = Csr::zeros(4, 2);
        let c = spgemm_reference(&a, &b);
        assert_eq!(c.nnz(), 0);
        assert_eq!((c.n_rows, c.n_cols), (3, 2));
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn rejects_dimension_mismatch() {
        let a = Csr::zeros(2, 3);
        let b = Csr::zeros(4, 2);
        spgemm_reference(&a, &b);
    }
}
