//! # spgemm-aia
//!
//! Reproduction of *"Accelerating Sparse Matrix-Matrix Multiplication on
//! GPUs with Processing Near HBMs"* (SK hynix SOLAB, CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's hash-based multi-phase SpGEMM
//!   engine with a plan-reuse layer for iterative workloads
//!   ([`spgemm::hash::PlannedProduct`],
//!   [`coordinator::batch::BatchExecutor`]), a cycle-approximate
//!   GPU + HBM + AIA memory-system simulator, the evaluated
//!   applications (graph contraction, Markov clustering, GNN training),
//!   the coordinator/CLI, and a service daemon ([`serve`]) exposing a
//!   resident executor over one shared plan store through a
//!   Unix-socket line protocol.
//! - **L2 (`python/compile/model.py`)** — GNN dense compute (layer
//!   fwd/bwd, loss) in JAX, AOT-lowered to HLO text artifacts.
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels (top-k pruning,
//!   MXU-tiled matmul, gather-SpMM) called from L2.
//!
//! Python never runs at request time: the Rust binary loads
//! `artifacts/*.hlo.txt` through PJRT (`runtime`, behind the `pjrt`
//! cargo feature — the default build ships a std-only stub) and is
//! self-contained.
//!
//! See `README.md` (repo root) for the quickstart and bench workflow,
//! and `DESIGN.md` for the full system inventory, the two-phase
//! hash-engine split, the plan-reuse batched execution flow, and the
//! experiment index mapping every paper table/figure to a module and
//! bench target.

// The engine mirrors the paper's GPU kernels: index-coupled loops over
// CSR arrays and pointer-based disjoint writes are the idiom, not an
// accident — keep clippy focused on real defects.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]

pub mod util;
pub mod sparse;
pub mod gen;
pub mod sim;
pub mod coordinator;
pub mod apps;
pub mod runtime;
pub mod gnn;
pub mod repro;
pub mod serve;
pub mod spgemm;
