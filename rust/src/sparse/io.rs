//! MatrixMarket coordinate-format IO.
//!
//! Supports the `%%MatrixMarket matrix coordinate (real|pattern|integer)
//! (general|symmetric)` subset, which covers every matrix in the paper's
//! Table II (SuiteSparse exports). Pattern matrices get value 1.0;
//! symmetric matrices are expanded.

use super::coo::Coo;
use super::csr::Csr;
use crate::util::error::{bail, ensure, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Read a MatrixMarket file into CSR.
pub fn read_matrix_market(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_matrix_market_from(std::io::BufReader::new(f))
}

/// Read MatrixMarket from any reader (exposed for tests).
pub fn read_matrix_market_from<R: BufRead>(mut r: R) -> Result<Csr> {
    let mut header = String::new();
    r.read_line(&mut header)?;
    let h: Vec<&str> = header.split_whitespace().collect();
    ensure!(h.len() >= 5 && h[0] == "%%MatrixMarket" && h[1] == "matrix", "bad MatrixMarket header: {header:?}");
    ensure!(h[2] == "coordinate", "only coordinate format supported, got {}", h[2]);
    let field = h[3].to_ascii_lowercase();
    let symmetry = h[4].to_ascii_lowercase();
    let pattern = match field.as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        other => bail!("unsupported field type {other}"),
    };
    let symmetric = match symmetry.as_str() {
        "general" => false,
        "symmetric" => true,
        other => bail!("unsupported symmetry {other}"),
    };

    // Skip comments, read the size line.
    let mut line = String::new();
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("EOF before size line");
        }
        let t = line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break;
        }
    }
    let dims: Vec<usize> = line.split_whitespace().map(|t| t.parse::<usize>()).collect::<Result<_, _>>()?;
    ensure!(dims.len() == 3, "size line must have 3 fields: {line:?}");
    let (n_rows, n_cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::with_capacity(n_rows, n_cols, if symmetric { nnz * 2 } else { nnz });
    let mut count = 0usize;
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("missing row")?.parse()?;
        let j: usize = it.next().context("missing col")?.parse()?;
        let v: f64 = if pattern { 1.0 } else { it.next().context("missing value")?.parse()? };
        ensure!(i >= 1 && i <= n_rows && j >= 1 && j <= n_cols, "entry ({i},{j}) out of bounds");
        coo.push(i - 1, j - 1, v);
        if symmetric && i != j {
            coo.push(j - 1, i - 1, v);
        }
        count += 1;
    }
    ensure!(count == nnz, "declared nnz {nnz} != parsed entries {count}");
    Ok(coo.to_csr())
}

/// Write CSR as MatrixMarket `coordinate real general`.
pub fn write_matrix_market(m: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by spgemm-aia")?;
    writeln!(w, "{} {} {}", m.n_rows, m.n_cols, m.nnz())?;
    for i in 0..m.n_rows {
        let (cs, vs) = m.row(i);
        for (&c, &v) in cs.iter().zip(vs) {
            writeln!(w, "{} {} {:.17e}", i + 1, c as usize + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 3\n1 1 2.0\n2 3 -1.5\n3 1 4\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.n_rows, 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_dense()[1][2], -1.5);
    }

    #[test]
    fn parses_pattern_symmetric() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n2 2 2\n1 1\n2 1\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        // symmetric expansion: (0,0), (1,0), (0,1)
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_dense(), vec![vec![1.0, 1.0], vec![1.0, 0.0]]);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_matrix_market_from(Cursor::new("garbage\n1 1 0\n")).is_err());
        assert!(read_matrix_market_from(Cursor::new("%%MatrixMarket matrix array real general\n")).is_err());
    }

    #[test]
    fn rejects_nnz_mismatch() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let m = Csr::new(2, 3, vec![0, 2, 3], vec![0, 2, 1], vec![1.5, 2.0, -3.0]).unwrap();
        let dir = std::env::temp_dir().join("spgemm_aia_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.mtx");
        write_matrix_market(&m, &p).unwrap();
        let m2 = read_matrix_market(&p).unwrap();
        assert!(m.approx_eq(&m2, 1e-15));
    }
}
