//! COO (triplet) builder — the entry format for generators and
//! MatrixMarket IO. Duplicates are combined by summation on conversion
//! to CSR, matching the usual sparse-library semantics.

use super::csr::Csr;

/// Coordinate-format builder.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub n_rows: usize,
    pub n_cols: usize,
    pub entries: Vec<(u32, u32, f64)>,
}

impl Coo {
    pub fn new(n_rows: usize, n_cols: usize) -> Coo {
        Coo { n_rows, n_cols, entries: Vec::new() }
    }

    pub fn with_capacity(n_rows: usize, n_cols: usize, cap: usize) -> Coo {
        Coo { n_rows, n_cols, entries: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.n_rows && c < self.n_cols, "({r},{c}) out of {}x{}", self.n_rows, self.n_cols);
        self.entries.push((r as u32, c as u32, v));
    }

    pub fn nnz_raw(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSR, summing duplicate coordinates and dropping entries
    /// that cancel to exactly zero.
    pub fn to_csr(&self) -> Csr {
        let mut entries = self.entries.clone();
        entries.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut rpt = vec![0usize; self.n_rows + 1];
        let mut col: Vec<u32> = Vec::with_capacity(entries.len());
        let mut val: Vec<f64> = Vec::with_capacity(entries.len());
        let mut it = entries.into_iter().peekable();
        while let Some((r, c, mut v)) = it.next() {
            while let Some(&(r2, c2, v2)) = it.peek() {
                if r2 == r && c2 == c {
                    v += v2;
                    it.next();
                } else {
                    break;
                }
            }
            if v != 0.0 {
                col.push(c);
                val.push(v);
                rpt[r as usize + 1] += 1;
            }
        }
        for i in 0..self.n_rows {
            rpt[i + 1] += rpt[i];
        }
        Csr::new_unchecked(self.n_rows, self.n_cols, rpt, col, val)
    }

    /// Symmetrize: for every (r, c, v) also add (c, r, v). Requires square.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.n_rows, self.n_cols, "symmetrize requires a square matrix");
        let orig = self.entries.clone();
        for (r, c, v) in orig {
            if r != c {
                self.entries.push((c, r, v));
            }
        }
    }
}

impl From<&Csr> for Coo {
    fn from(m: &Csr) -> Coo {
        let mut coo = Coo::with_capacity(m.n_rows, m.n_cols, m.nnz());
        for i in 0..m.n_rows {
            let (cs, vs) = m.row(i);
            for (&c, &v) in cs.iter().zip(vs) {
                coo.push(i, c as usize, v);
            }
        }
        coo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicates_sum() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(0, 1, 2.5);
        coo.push(1, 0, -1.0);
        let m = coo.to_csr();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense(), vec![vec![0.0, 3.5], vec![-1.0, 0.0]]);
    }

    #[test]
    fn cancellation_drops_entry() {
        let mut coo = Coo::new(1, 1);
        coo.push(0, 0, 2.0);
        coo.push(0, 0, -2.0);
        assert_eq!(coo.to_csr().nnz(), 0);
    }

    #[test]
    fn unsorted_input_sorts() {
        let mut coo = Coo::new(3, 3);
        coo.push(2, 2, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(2, 0, 1.0);
        coo.push(0, 0, 1.0);
        let m = coo.to_csr();
        assert!(m.validate().is_ok());
        assert_eq!(m.row(0).0, &[0, 1]);
        assert_eq!(m.row(2).0, &[0, 2]);
    }

    #[test]
    fn symmetrize_mirrors_offdiagonal() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 1, 1.0);
        coo.push(1, 1, 5.0);
        coo.symmetrize();
        let m = coo.to_csr();
        assert_eq!(m.to_dense()[1][0], 1.0);
        assert_eq!(m.to_dense()[0][1], 1.0);
        assert_eq!(m.to_dense()[1][1], 5.0);
    }

    #[test]
    fn csr_coo_roundtrip() {
        let m = Csr::new(2, 3, vec![0, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(Coo::from(&m).to_csr(), m);
    }
}
