//! Per-matrix statistics — the columns of the paper's Table II and
//! Table III.

use super::csr::Csr;

/// Summary statistics for a sparse matrix / graph adjacency.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub avg_nnz_row: f64,
    pub max_nnz_row: usize,
    /// Fraction of entries that are non-zero, in percent (Table III).
    pub density_pct: f64,
}

impl MatrixStats {
    pub fn of(m: &Csr) -> MatrixStats {
        let max_nnz_row = (0..m.n_rows).map(|i| m.row_nnz(i)).max().unwrap_or(0);
        let nnz = m.nnz();
        MatrixStats {
            rows: m.n_rows,
            cols: m.n_cols,
            nnz,
            avg_nnz_row: if m.n_rows == 0 { 0.0 } else { nnz as f64 / m.n_rows as f64 },
            max_nnz_row,
            density_pct: if m.n_rows == 0 || m.n_cols == 0 {
                0.0
            } else {
                100.0 * nnz as f64 / (m.n_rows as f64 * m.n_cols as f64)
            },
        }
    }
}

/// Histogram of per-row nnz in logarithmic bins (diagnostics for the
/// row-grouping phase; bin k covers [2^k, 2^(k+1))).
pub fn row_nnz_log_histogram(m: &Csr) -> Vec<usize> {
    let mut bins = vec![0usize; 33];
    for i in 0..m.n_rows {
        let nnz = m.row_nnz(i);
        let bin = if nnz == 0 { 0 } else { (usize::BITS - nnz.leading_zeros()) as usize };
        bins[bin] += 1;
    }
    while bins.len() > 1 && *bins.last().unwrap() == 0 {
        bins.pop();
    }
    bins
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_small() {
        let m = Csr::new(3, 4, vec![0, 2, 2, 5], vec![0, 2, 0, 1, 3], vec![1.0; 5]).unwrap();
        let s = MatrixStats::of(&m);
        assert_eq!(s.rows, 3);
        assert_eq!(s.cols, 4);
        assert_eq!(s.nnz, 5);
        assert_eq!(s.max_nnz_row, 3);
        assert!((s.avg_nnz_row - 5.0 / 3.0).abs() < 1e-12);
        assert!((s.density_pct - 100.0 * 5.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix_stats() {
        let s = MatrixStats::of(&Csr::zeros(0, 0));
        assert_eq!(s.nnz, 0);
        assert_eq!(s.avg_nnz_row, 0.0);
        assert_eq!(s.density_pct, 0.0);
    }

    #[test]
    fn log_histogram_bins() {
        // rows with nnz 0,1,2,3,8
        let m = Csr::new(
            5,
            16,
            vec![0, 0, 1, 3, 6, 14],
            vec![0, 0, 1, 0, 1, 2, 0, 1, 2, 3, 4, 5, 6, 7],
            vec![1.0; 14],
        )
        .unwrap();
        let h = row_nnz_log_histogram(&m);
        assert_eq!(h[0], 1); // nnz=0
        assert_eq!(h[1], 1); // nnz=1
        assert_eq!(h[2], 2); // nnz in [2,4)
        assert_eq!(h[4], 1); // nnz in [8,16)
    }
}
