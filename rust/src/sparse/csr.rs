//! Compressed Sparse Row matrices — the substrate every layer of the
//! reproduction builds on.
//!
//! Representation follows the paper's kernels exactly: `rpt` (row
//! pointers, `len = n_rows + 1`), `col` (column indices, sorted within a
//! row), `val` (values). Column indices are `u32` (all evaluated
//! matrices have < 2^32 columns); row pointers are `usize`.

use crate::util::error::{bail, ensure, Result};
use std::sync::OnceLock;

/// A CSR sparse matrix with f64 values.
#[derive(Clone, Debug)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Row pointers; `rpt[i]..rpt[i+1]` indexes row i's entries.
    pub rpt: Vec<usize>,
    /// Column indices, strictly increasing within each row.
    pub col: Vec<u32>,
    /// Non-zero values, parallel to `col`.
    pub val: Vec<f64>,
    /// Compute-once memo of [`Csr::structure_hash`]. Values may be
    /// mutated freely (the hash ignores them); every in-tree *structural*
    /// change builds a new `Csr` through a constructor, which starts
    /// with an empty memo. `OnceLock` keeps the matrix `Sync` (plan
    /// fingerprints are taken on the batch planner thread) and `Clone`
    /// carries the memo along — a clone shares the original's structure.
    structure_memo: OnceLock<u64>,
    /// Compute-once memo of [`Csr::row_structure_hashes`] — one hash per
    /// row, same lifecycle rules as `structure_memo`.
    row_hash_memo: OnceLock<Vec<u64>>,
}

/// Equality is over the five public fields only — the lazily computed
/// structure-hash memo is derived state and must not affect `==` (a
/// freshly built matrix equals a hashed one).
impl PartialEq for Csr {
    fn eq(&self, other: &Csr) -> bool {
        self.n_rows == other.n_rows
            && self.n_cols == other.n_cols
            && self.rpt == other.rpt
            && self.col == other.col
            && self.val == other.val
    }
}

impl Csr {
    /// Construct with full structural validation.
    pub fn new(n_rows: usize, n_cols: usize, rpt: Vec<usize>, col: Vec<u32>, val: Vec<f64>) -> Result<Csr> {
        ensure!(rpt.len() == n_rows + 1, "rpt len {} != n_rows+1 {}", rpt.len(), n_rows + 1);
        ensure!(rpt[0] == 0, "rpt[0] must be 0");
        ensure!(*rpt.last().unwrap() == col.len(), "rpt[last] {} != nnz {}", rpt.last().unwrap(), col.len());
        ensure!(col.len() == val.len(), "col/val length mismatch");
        for i in 0..n_rows {
            ensure!(rpt[i] <= rpt[i + 1], "rpt not monotonic at row {i}");
            let row = &col[rpt[i]..rpt[i + 1]];
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    bail!("row {i} columns not strictly increasing: {} !< {}", w[0], w[1]);
                }
            }
            if let Some(&last) = row.last() {
                ensure!((last as usize) < n_cols, "row {i} col {last} out of bounds {n_cols}");
            }
        }
        Ok(Csr { n_rows, n_cols, rpt, col, val, structure_memo: OnceLock::new(), row_hash_memo: OnceLock::new() })
    }

    /// Construct without validation (hot paths that build valid output by
    /// construction). Debug builds still validate.
    pub fn new_unchecked(n_rows: usize, n_cols: usize, rpt: Vec<usize>, col: Vec<u32>, val: Vec<f64>) -> Csr {
        #[cfg(debug_assertions)]
        {
            Csr::new(n_rows, n_cols, rpt, col, val).expect("invalid CSR in new_unchecked")
        }
        #[cfg(not(debug_assertions))]
        {
            Csr { n_rows, n_cols, rpt, col, val, structure_memo: OnceLock::new(), row_hash_memo: OnceLock::new() }
        }
    }

    /// The empty matrix of a given shape.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Csr {
        Csr { n_rows, n_cols, rpt: vec![0; n_rows + 1], col: vec![], val: vec![], structure_memo: OnceLock::new(), row_hash_memo: OnceLock::new() }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Csr {
        Csr {
            n_rows: n,
            n_cols: n,
            rpt: (0..=n).collect(),
            col: (0..n as u32).collect(),
            val: vec![1.0; n],
            structure_memo: OnceLock::new(),
            row_hash_memo: OnceLock::new(),
        }
    }

    /// Diagonal matrix from a vector.
    pub fn from_diag(d: &[f64]) -> Csr {
        let n = d.len();
        Csr {
            n_rows: n,
            n_cols: n,
            rpt: (0..=n).collect(),
            col: (0..n as u32).collect(),
            val: d.to_vec(),
            structure_memo: OnceLock::new(),
            row_hash_memo: OnceLock::new(),
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.col.len()
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.rpt[i]..self.rpt[i + 1]
    }

    /// (columns, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let r = self.row_range(i);
        (&self.col[r.clone()], &self.val[r])
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.rpt[i + 1] - self.rpt[i]
    }

    /// Transpose via counting sort over columns — O(nnz + n_cols).
    pub fn transpose(&self) -> Csr {
        let mut cnt = vec![0usize; self.n_cols + 1];
        for &c in &self.col {
            cnt[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            cnt[i + 1] += cnt[i];
        }
        let rpt_t = cnt.clone();
        let mut col_t = vec![0u32; self.nnz()];
        let mut val_t = vec![0f64; self.nnz()];
        let mut next = cnt;
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let p = next[c as usize];
                next[c as usize] += 1;
                col_t[p] = i as u32;
                val_t[p] = v;
            }
        }
        // Row-major traversal in increasing i keeps each output row sorted.
        Csr::new_unchecked(self.n_cols, self.n_rows, rpt_t, col_t, val_t)
    }

    /// Dense form for small-matrix tests.
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n_cols]; self.n_rows];
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                d[i][c as usize] = v;
            }
        }
        d
    }

    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(d: &[Vec<f64>]) -> Csr {
        let n_rows = d.len();
        let n_cols = d.first().map(|r| r.len()).unwrap_or(0);
        let mut rpt = Vec::with_capacity(n_rows + 1);
        rpt.push(0);
        let mut col = Vec::new();
        let mut val = Vec::new();
        for row in d {
            for (j, &v) in row.iter().enumerate() {
                if v != 0.0 {
                    col.push(j as u32);
                    val.push(v);
                }
            }
            rpt.push(col.len());
        }
        Csr::new_unchecked(n_rows, n_cols, rpt, col, val)
    }

    /// Structural + numeric equality within `tol` (relative on large values).
    pub fn approx_eq(&self, other: &Csr, tol: f64) -> bool {
        if self.n_rows != other.n_rows || self.n_cols != other.n_cols || self.rpt != other.rpt {
            return false;
        }
        if self.col != other.col {
            return false;
        }
        self.val
            .iter()
            .zip(&other.val)
            .all(|(&a, &b)| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())))
    }

    /// Map values in place.
    pub fn map_values(&mut self, f: impl Fn(f64) -> f64) {
        for v in &mut self.val {
            *v = f(*v);
        }
    }

    /// Drop entries whose value is exactly 0 (after pruning ops).
    pub fn drop_zeros(&self) -> Csr {
        let mut rpt = Vec::with_capacity(self.n_rows + 1);
        rpt.push(0);
        let mut col = Vec::with_capacity(self.nnz());
        let mut val = Vec::with_capacity(self.nnz());
        for i in 0..self.n_rows {
            let (cs, vs) = self.row(i);
            for (&c, &v) in cs.iter().zip(vs) {
                if v != 0.0 {
                    col.push(c);
                    val.push(v);
                }
            }
            rpt.push(col.len());
        }
        Csr::new_unchecked(self.n_rows, self.n_cols, rpt, col, val)
    }

    /// Validate invariants (used by property tests on outputs of the
    /// SpGEMM engines).
    pub fn validate(&self) -> Result<()> {
        Csr::new(self.n_rows, self.n_cols, self.rpt.clone(), self.col.clone(), self.val.clone()).map(|_| ())
    }

    /// Total bytes of the three arrays (for memory accounting in the sim).
    pub fn bytes(&self) -> usize {
        self.rpt.len() * 8 + self.col.len() * 4 + self.val.len() * 8
    }

    /// 64-bit hash of the sparsity *structure* — shape, `rpt`, and `col`;
    /// values are excluded. A SpGEMM plan
    /// ([`crate::spgemm::hash::SymbolicPlan`]) is a pure function of the
    /// operands' structure, so plan-reuse keys on this hash: equal hashes
    /// mean the cached plan is (up to a negligible collision probability)
    /// valid for a new numeric fill.
    ///
    /// Memoized: the first call pays the O(nnz) scan, every later call on
    /// the same matrix (or a clone of it) is a cell read — so the hot
    /// reuse paths that fingerprint-validate per multiply
    /// ([`crate::spgemm::hash::PlannedProduct::matches`], the plan-store
    /// lookups) stop re-hashing the operands on every call, and
    /// `PhaseTimes` accounting charges the structure scan exactly once.
    pub fn structure_hash(&self) -> u64 {
        *self.structure_memo.get_or_init(|| self.compute_structure_hash())
    }

    /// The memoized hash if [`Csr::structure_hash`] has already run
    /// (compute-once regression hook; `None` means no scan happened yet).
    pub fn cached_structure_hash(&self) -> Option<u64> {
        self.structure_memo.get().copied()
    }

    fn compute_structure_hash(&self) -> u64 {
        let mut h = mix(0xcbf2_9ce4_8422_2325, self.n_rows as u64);
        h = mix(h, self.n_cols as u64);
        for &p in &self.rpt {
            h = mix(h, p as u64);
        }
        for &c in &self.col {
            h = mix(h, c as u64);
        }
        h
    }

    /// Per-row 64-bit hashes of the sparsity structure — row i's hash
    /// covers its nnz and column indices, values excluded (same mix
    /// function as [`Csr::structure_hash`]). Two matrices of equal shape
    /// whose row-i hashes agree have (up to collision) identical row-i
    /// patterns, which is exactly what incremental replanning
    /// ([`crate::spgemm::hash::incremental`]) needs to diff old vs new
    /// operands row by row.
    ///
    /// Memoized like the whole-structure hash: first call pays one
    /// O(nnz) scan, clones inherit the memo, value mutation never
    /// invalidates it.
    pub fn row_structure_hashes(&self) -> &[u64] {
        self.row_hash_memo.get_or_init(|| {
            (0..self.n_rows)
                .map(|i| {
                    let (cols, _) = self.row(i);
                    let mut h = mix(0xcbf2_9ce4_8422_2325, cols.len() as u64);
                    for &c in cols {
                        h = mix(h, c as u64);
                    }
                    h
                })
                .collect()
        })
    }
}

/// FNV-1a word step plus an xorshift to spread low-entropy inputs
/// (small column indices) across the high bits. Shared by the
/// whole-structure and per-row hashes so the two stay comparable
/// diagnostics of the same scan.
#[inline]
fn mix(h: u64, x: u64) -> u64 {
    let h = (h ^ x).wrapping_mul(0x100_0000_01b3);
    h ^ (h >> 29)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [1 0 2]
        // [0 0 0]
        // [3 4 0]
        Csr::new(3, 3, vec![0, 2, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap()
    }

    #[test]
    fn construction_validates() {
        assert!(Csr::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // bad rpt len
        assert!(Csr::new(1, 2, vec![0, 2], vec![1, 0], vec![1.0, 1.0]).is_err()); // unsorted
        assert!(Csr::new(1, 2, vec![0, 2], vec![0, 0], vec![1.0, 1.0]).is_err()); // duplicate col
        assert!(Csr::new(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err()); // col OOB
        assert!(small().validate().is_ok());
    }

    #[test]
    fn identity_and_diag() {
        let i3 = Csr::identity(3);
        assert_eq!(i3.to_dense(), vec![vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 0.0], vec![0.0, 0.0, 1.0]]);
        let d = Csr::from_diag(&[2.0, 3.0]);
        assert_eq!(d.to_dense(), vec![vec![2.0, 0.0], vec![0.0, 3.0]]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let t = a.transpose();
        assert_eq!(t.n_rows, 3);
        assert_eq!(t.to_dense(), vec![vec![1.0, 0.0, 3.0], vec![0.0, 0.0, 4.0], vec![2.0, 0.0, 0.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn dense_roundtrip() {
        let a = small();
        assert_eq!(Csr::from_dense(&a.to_dense()), a);
    }

    #[test]
    fn rectangular_transpose() {
        let a = Csr::new(2, 4, vec![0, 2, 3], vec![1, 3, 0], vec![5.0, 6.0, 7.0]).unwrap();
        let t = a.transpose();
        assert_eq!(t.n_rows, 4);
        assert_eq!(t.n_cols, 2);
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn drop_zeros_removes_explicit_zeros() {
        let mut a = small();
        a.val[1] = 0.0;
        let b = a.drop_zeros();
        assert_eq!(b.nnz(), 3);
        assert!(b.validate().is_ok());
        assert_eq!(b.to_dense()[0], vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn approx_eq_tolerance() {
        let a = small();
        let mut b = a.clone();
        b.val[0] += 1e-12;
        assert!(a.approx_eq(&b, 1e-10));
        b.val[0] += 1.0;
        assert!(!a.approx_eq(&b, 1e-10));
    }

    #[test]
    fn structure_hash_ignores_values_and_sees_structure() {
        let a = small();
        let mut b = a.clone();
        b.val[0] = 99.0;
        assert_eq!(a.structure_hash(), b.structure_hash(), "values must not affect the structure hash");
        // Moving an entry to a different column is a structural change.
        let c = Csr::new(3, 3, vec![0, 2, 2, 4], vec![0, 1, 0, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_ne!(a.structure_hash(), c.structure_hash());
        // So is the same nnz distributed over different rows.
        let d = Csr::new(3, 3, vec![0, 1, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_ne!(a.structure_hash(), d.structure_hash());
        // And shape, even at identical arrays.
        let e = Csr::new(3, 4, vec![0, 2, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_ne!(a.structure_hash(), e.structure_hash());
    }

    #[test]
    fn structure_hash_is_memoized_once() {
        let a = small();
        assert_eq!(a.cached_structure_hash(), None, "fresh matrices carry no memo");
        let h = a.structure_hash();
        assert_eq!(a.cached_structure_hash(), Some(h), "first call must populate the memo");
        assert_eq!(a.structure_hash(), h, "later calls read the memo");
        // Clones share the structure, so they inherit the memo.
        let b = a.clone();
        assert_eq!(b.cached_structure_hash(), Some(h));
        // Value mutation never touches the (value-blind) memo.
        let mut c = a.clone();
        c.val[0] = -7.0;
        assert_eq!(c.structure_hash(), h);
        // The memo is derived state: a freshly built identical matrix
        // (memo empty) still compares equal to a hashed one.
        let fresh = Csr::new(3, 3, vec![0, 2, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(fresh.cached_structure_hash(), None);
        assert_eq!(fresh, a);
        assert_eq!(fresh.structure_hash(), h, "memoized and recomputed hashes agree");
    }

    #[test]
    fn row_structure_hashes_localize_changes() {
        let a = small();
        let ha = a.row_structure_hashes().to_vec();
        assert_eq!(ha.len(), 3);
        // Values never affect row hashes.
        let mut b = a.clone();
        b.val[0] = -5.0;
        assert_eq!(b.row_structure_hashes(), &ha[..]);
        // Moving row 2's entry changes only row 2's hash.
        let c = Csr::new(3, 3, vec![0, 2, 2, 4], vec![0, 2, 0, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let hc = c.row_structure_hashes();
        assert_eq!(hc[0], ha[0]);
        assert_eq!(hc[1], ha[1]);
        assert_ne!(hc[2], ha[2]);
        // Identical patterns in different rows hash identically (the row
        // hash is position-independent; position lives in the index).
        let d = Csr::new(2, 3, vec![0, 2, 4], vec![0, 2, 0, 2], vec![1.0; 4]).unwrap();
        let hd = d.row_structure_hashes();
        assert_eq!(hd[0], hd[1]);
        // Clones share the memo.
        let e = a.clone();
        let _ = a.row_structure_hashes();
        assert_eq!(e.row_structure_hashes(), &ha[..]);
    }

    #[test]
    fn row_accessors() {
        let a = small();
        assert_eq!(a.row(0), (&[0u32, 2][..], &[1.0, 2.0][..]));
        assert_eq!(a.row_nnz(1), 0);
        assert_eq!(a.nnz(), 4);
    }
}
