//! Sparse matrix operations used by the applications (MCL, graph
//! contraction, GNN) — everything except SpGEMM itself, which lives in
//! `crate::spgemm`.

use super::csr::Csr;
use crate::util::par_chunks;

/// Add missing self-loops with weight `w` (MCL step 1 — Algorithm 6).
pub fn add_self_loops(m: &Csr, w: f64) -> Csr {
    assert_eq!(m.n_rows, m.n_cols);
    let mut rpt = Vec::with_capacity(m.n_rows + 1);
    rpt.push(0usize);
    let mut col = Vec::with_capacity(m.nnz() + m.n_rows);
    let mut val = Vec::with_capacity(m.nnz() + m.n_rows);
    for i in 0..m.n_rows {
        let (cs, vs) = m.row(i);
        let mut inserted = false;
        for (&c, &v) in cs.iter().zip(vs) {
            if !inserted && (c as usize) > i {
                col.push(i as u32);
                val.push(w);
                inserted = true;
            }
            if c as usize == i {
                inserted = true;
            }
            col.push(c);
            val.push(v);
        }
        if !inserted {
            col.push(i as u32);
            val.push(w);
        }
        rpt.push(col.len());
    }
    Csr::new_unchecked(m.n_rows, m.n_cols, rpt, col, val)
}

/// Column sums of a CSR matrix.
pub fn column_sums(m: &Csr) -> Vec<f64> {
    let mut sums = vec![0.0; m.n_cols];
    for (&c, &v) in m.col.iter().zip(&m.val) {
        sums[c as usize] += v;
    }
    sums
}

/// Normalize columns to sum 1 (column-stochastic; MCL). Columns with zero
/// sum are left zero.
pub fn column_normalize(m: &Csr) -> Csr {
    let sums = column_sums(m);
    let mut out = m.clone();
    for (c, v) in out.col.iter().zip(out.val.iter_mut()) {
        let s = sums[*c as usize];
        if s != 0.0 {
            *v /= s;
        }
    }
    out
}

/// Hadamard power: each entry raised to `r` (MCL inflation).
pub fn hadamard_power(m: &Csr, r: f64) -> Csr {
    let mut out = m.clone();
    out.map_values(|v| v.powf(r));
    out
}

/// MCL pruning (Algorithm 6, lines 6–10): per **column**, remove entries
/// below `theta` and keep only the top-`k` largest by value.
pub fn prune_columns(m: &Csr, theta: f64, k: usize) -> Csr {
    // Work on the transpose so columns become rows, prune rows, transpose
    // back. Cost: two counting-sort transposes — O(nnz).
    let t = m.transpose();
    let pruned = prune_rows(&t, theta, k);
    pruned.transpose()
}

/// Per-row pruning: drop entries `< theta`, keep top-`k` by value.
pub fn prune_rows(m: &Csr, theta: f64, k: usize) -> Csr {
    let mut rpt = Vec::with_capacity(m.n_rows + 1);
    rpt.push(0usize);
    let mut col = Vec::new();
    let mut val = Vec::new();
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for i in 0..m.n_rows {
        let (cs, vs) = m.row(i);
        scratch.clear();
        for (&c, &v) in cs.iter().zip(vs) {
            if v >= theta {
                scratch.push((c, v));
            }
        }
        if scratch.len() > k {
            // Select the k largest by value, then restore column order.
            scratch.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            scratch.truncate(k);
            scratch.sort_unstable_by_key(|e| e.0);
        }
        for &(c, v) in &scratch {
            col.push(c);
            val.push(v);
        }
        rpt.push(col.len());
    }
    Csr::new_unchecked(m.n_rows, m.n_cols, rpt, col, val)
}

/// Frobenius norm of the difference (MCL convergence check), computed on
/// the union pattern.
pub fn frobenius_diff(a: &Csr, b: &Csr) -> f64 {
    assert_eq!((a.n_rows, a.n_cols), (b.n_rows, b.n_cols));
    let mut acc = 0.0;
    for i in 0..a.n_rows {
        let (ca, va) = a.row(i);
        let (cb, vb) = b.row(i);
        let (mut p, mut q) = (0, 0);
        while p < ca.len() || q < cb.len() {
            let d = match (ca.get(p), cb.get(q)) {
                (Some(&x), Some(&y)) if x == y => {
                    let d = va[p] - vb[q];
                    p += 1;
                    q += 1;
                    d
                }
                (Some(&x), Some(&y)) if x < y => {
                    p += 1;
                    va[p - 1]
                }
                (Some(_), Some(_)) => {
                    q += 1;
                    -vb[q - 1]
                }
                (Some(_), None) => {
                    p += 1;
                    va[p - 1]
                }
                (None, Some(_)) => {
                    q += 1;
                    -vb[q - 1]
                }
                (None, None) => unreachable!(),
            };
            acc += d * d;
        }
    }
    acc.sqrt()
}

/// Symmetric GCN normalization: `D^{-1/2} (A + I) D^{-1/2}`.
pub fn gcn_normalize(adj: &Csr) -> Csr {
    let a_hat = add_self_loops(adj, 1.0);
    let mut deg = vec![0.0; a_hat.n_rows];
    for i in 0..a_hat.n_rows {
        deg[i] = a_hat.row(i).1.iter().sum();
    }
    let dinv: Vec<f64> = deg.iter().map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 }).collect();
    let mut out = a_hat;
    for i in 0..out.n_rows {
        let r = out.row_range(i);
        let di = dinv[i];
        for idx in r {
            out.val[idx] *= di * dinv[out.col[idx] as usize];
        }
    }
    out
}

/// Row-mean normalization: each row divided by its degree (GraphSAGE mean
/// aggregator).
pub fn row_mean_normalize(adj: &Csr) -> Csr {
    let mut out = adj.clone();
    for i in 0..out.n_rows {
        let n = out.row_nnz(i);
        if n > 0 {
            let inv = 1.0 / n as f64;
            for idx in out.row_range(i) {
                out.val[idx] *= inv;
            }
        }
    }
    out
}

/// SpMM: sparse CSR × dense row-major `[n_cols × d]` → dense `[n_rows × d]`.
/// Parallel over row blocks. Used by the GNN aggregation fallback and to
/// cross-check the hybrid path.
pub fn spmm_dense(a: &Csr, x: &[f64], d: usize) -> Vec<f64> {
    assert_eq!(x.len(), a.n_cols * d, "dense operand shape mismatch");
    let mut y = vec![0.0; a.n_rows * d];
    {
        let y_rows: &mut [f64] = &mut y;
        // Split the output by row chunks; each chunk is written by one worker.
        let yptr = y_rows.as_mut_ptr() as usize;
        par_chunks(a.n_rows, |start, end| {
            let yp = yptr as *mut f64;
            for i in start..end {
                let (cs, vs) = a.row(i);
                // SAFETY: rows [start,end) are disjoint between workers.
                let out = unsafe { std::slice::from_raw_parts_mut(yp.add(i * d), d) };
                for (&c, &v) in cs.iter().zip(vs) {
                    let xrow = &x[c as usize * d..c as usize * d + d];
                    for (o, &xv) in out.iter_mut().zip(xrow) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
    y
}

/// Connected components on the union pattern of a square matrix
/// (interpreting nonzeros as undirected edges) — used to extract MCL
/// clusters. Returns a label per node.
pub fn connected_components(m: &Csr) -> Vec<usize> {
    assert_eq!(m.n_rows, m.n_cols);
    let n = m.n_rows;
    let mut label = vec![usize::MAX; n];
    let mut next_label = 0;
    let t = m.transpose();
    let mut stack = Vec::new();
    for s in 0..n {
        if label[s] != usize::MAX {
            continue;
        }
        label[s] = next_label;
        stack.push(s);
        while let Some(u) = stack.pop() {
            for &v in m.row(u).0.iter().chain(t.row(u).0) {
                let v = v as usize;
                if label[v] == usize::MAX {
                    label[v] = next_label;
                    stack.push(v);
                }
            }
        }
        next_label += 1;
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> Csr {
        // 0-1, 1-2 undirected chain
        Csr::from_dense(&[
            vec![0.0, 1.0, 0.0],
            vec![1.0, 0.0, 1.0],
            vec![0.0, 1.0, 0.0],
        ])
    }

    #[test]
    fn self_loops_inserted_in_order() {
        let m = chain3();
        let s = add_self_loops(&m, 2.0);
        assert!(s.validate().is_ok());
        let d = s.to_dense();
        assert_eq!(d[0][0], 2.0);
        assert_eq!(d[1][1], 2.0);
        assert_eq!(d[2][2], 2.0);
        // existing self-loop not duplicated
        let s2 = add_self_loops(&s, 3.0);
        assert_eq!(s2.to_dense()[0][0], 2.0);
        assert_eq!(s2.nnz(), s.nnz());
    }

    #[test]
    fn column_normalize_makes_stochastic() {
        let m = add_self_loops(&chain3(), 1.0);
        let cn = column_normalize(&m);
        for s in column_sums(&cn) {
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn hadamard_power_squares() {
        let m = Csr::from_dense(&[vec![2.0, 0.0], vec![0.0, 3.0]]);
        let p = hadamard_power(&m, 2.0);
        assert_eq!(p.to_dense(), vec![vec![4.0, 0.0], vec![0.0, 9.0]]);
    }

    #[test]
    fn prune_rows_threshold_and_topk() {
        let m = Csr::from_dense(&[vec![0.5, 0.1, 0.9, 0.3]]);
        let p = prune_rows(&m, 0.2, 2);
        // 0.1 below theta; top-2 of {0.5, 0.9, 0.3} = {0.9, 0.5}
        assert_eq!(p.to_dense(), vec![vec![0.5, 0.0, 0.9, 0.0]]);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn prune_columns_is_per_column() {
        let m = Csr::from_dense(&[vec![0.9, 0.2], vec![0.5, 0.8], vec![0.6, 0.1]]);
        let p = prune_columns(&m, 0.0, 2);
        // column 0 keeps 0.9, 0.6; column 1 keeps 0.2 and 0.8? top-2 of {0.2,0.8,0.1} = {0.8,0.2}
        assert_eq!(p.to_dense(), vec![vec![0.9, 0.2], vec![0.0, 0.8], vec![0.6, 0.0]]);
    }

    #[test]
    fn frobenius_diff_handles_pattern_mismatch() {
        let a = Csr::from_dense(&[vec![1.0, 2.0], vec![0.0, 0.0]]);
        let b = Csr::from_dense(&[vec![1.0, 0.0], vec![3.0, 0.0]]);
        let d = frobenius_diff(&a, &b);
        assert!((d - (4.0f64 + 9.0).sqrt()).abs() < 1e-12);
        assert_eq!(frobenius_diff(&a, &a), 0.0);
    }

    #[test]
    fn gcn_normalize_rows_and_symmetry() {
        let m = chain3();
        let g = gcn_normalize(&m);
        // symmetric input → symmetric normalized output
        let gt = g.transpose();
        assert!(g.approx_eq(&gt, 1e-12));
        // middle node: degree 3 with self-loop
        assert!((g.to_dense()[1][1] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn row_mean_normalize_sums_to_one() {
        let m = chain3();
        let r = row_mean_normalize(&m);
        for i in 0..3 {
            let s: f64 = r.row(i).1.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn spmm_dense_matches_manual() {
        let a = Csr::from_dense(&[vec![1.0, 2.0], vec![0.0, 3.0]]);
        let x = vec![1.0, 10.0, 2.0, 20.0]; // 2x2 dense row-major
        let y = spmm_dense(&a, &x, 2);
        assert_eq!(y, vec![5.0, 50.0, 6.0, 60.0]);
    }

    #[test]
    fn connected_components_of_two_blocks() {
        let m = Csr::from_dense(&[
            vec![0.0, 1.0, 0.0, 0.0],
            vec![1.0, 0.0, 0.0, 0.0],
            vec![0.0, 0.0, 0.0, 1.0],
            vec![0.0, 0.0, 1.0, 0.0],
        ]);
        let l = connected_components(&m);
        assert_eq!(l[0], l[1]);
        assert_eq!(l[2], l[3]);
        assert_ne!(l[0], l[2]);
    }
}
