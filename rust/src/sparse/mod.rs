//! Sparse-matrix substrate: CSR/COO containers, MatrixMarket IO,
//! element-wise / normalization operations, and summary statistics.
//!
//! Everything above this layer (SpGEMM engines, the AIA simulator, the
//! applications, the GNN stack) consumes these types.

pub mod coo;
pub mod csr;
pub mod io;
pub mod ops;
pub mod stats;

pub use coo::Coo;
pub use csr::Csr;
pub use stats::MatrixStats;
