//! `spgemm-aia` CLI — the L3 leader entrypoint.
//!
//! Subcommands (std-only arg parsing; the offline build has no clap):
//!
//! ```text
//! spgemm-aia repro [all|table1|table2|table3|fig5|fig6|fig7|fig8|fig9|fig10|fig11|planreuse|attention]
//! spgemm-aia spgemm --dataset <name> [--variant aia|hash|cusparse] [--seed N]
//! spgemm-aia triangles --dataset <name> [--seed N]
//! spgemm-aia mcl --dataset <name> [--variant ...]
//! spgemm-aia contract --dataset <name> [--variant ...]
//! spgemm-aia gnn --dataset <name> --arch gcn|gin|sage [--epochs N]
//! spgemm-aia serve --socket <path> [--queue N] [--streams N] [--plan-cache DIR] [--planner P]
//! spgemm-aia plan-cache ls|verify|prune [--dir DIR] [--max-bytes N]
//! spgemm-aia calibrate [--datasets a,b,c] [--grid t1,t2,...] [--out DIR]
//! spgemm-aia info
//! ```

use spgemm_aia::util::error::{anyhow, bail, Result};
use spgemm_aia::apps::{contract, mcl, random_labels, MclParams};
use spgemm_aia::coordinator::executor::{SpgemmExecutor, Variant};
use spgemm_aia::gnn::{Arch, GnnData, Trainer};
use spgemm_aia::repro;
use spgemm_aia::runtime::Runtime;
use spgemm_aia::sim::gflops;
use spgemm_aia::spgemm::ip;
use spgemm_aia::util::Pcg32;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Fetch `--key value` style options.
fn opt<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn seed(args: &[String]) -> u64 {
    opt(args, "--seed").and_then(|s| s.parse().ok()).unwrap_or(repro::SEED)
}

fn variant(args: &[String]) -> Result<Variant> {
    let name = opt(args, "--variant").unwrap_or("aia");
    Variant::parse(name).ok_or_else(|| anyhow!("unknown variant {name} (aia|hash|cusparse)"))
}

fn run(args: &[String]) -> Result<()> {
    // Global knob, honored by every subcommand: density threshold of the
    // plan-guided dense-SPA accumulator (see DESIGN.md §Accumulator
    // selection). Must be set before the first multiply.
    if let Some(t) = opt(args, "--spa-threshold") {
        let parsed: f64 =
            t.parse().map_err(|_| anyhow!("--spa-threshold must be a number (got {t})"))?;
        if !(0.0..=8.0).contains(&parsed) {
            bail!("--spa-threshold out of range (0 forces SPA, ≥1 disables it; got {parsed})");
        }
        if !spgemm_aia::spgemm::hash::set_default_spa_threshold(parsed) {
            eprintln!("warning: SPA threshold was already initialized; --spa-threshold ignored");
        }
    }
    // Global knob, honored by every subcommand: directory of the plan
    // store's on-disk tier (DESIGN.md §Plan persistence). Every
    // functional hash executor built afterwards persists symbolic plans
    // there and loads validated ones back, so repeated runs on the same
    // generated dataset skip the symbolic phase across processes.
    if let Some(dir) = opt(args, "--plan-cache") {
        if dir.is_empty() {
            bail!("--plan-cache needs a directory path");
        }
        if !spgemm_aia::spgemm::hash::set_default_plan_cache_dir(std::path::PathBuf::from(dir)) {
            eprintln!("warning: plan-cache dir was already initialized; --plan-cache ignored");
        }
    }
    // Global knob, honored by every subcommand: symbolic planner policy
    // (DESIGN.md §2g). `estimated` sizes hash tables from a sampled
    // nnz(C) estimate and recovers per row with a grow-and-retry ladder
    // on underestimates; `auto` speculates only on fully-cold one-shot
    // products. Output stays bit-identical to `exact` in every mode —
    // only plan sizing and kernel choice are speculative.
    if let Some(name) = opt(args, "--planner") {
        let policy = spgemm_aia::spgemm::hash::PlannerPolicy::parse(name)
            .ok_or_else(|| anyhow!("unknown planner {name} (expected exact, estimated, or auto)"))?;
        if !spgemm_aia::spgemm::hash::set_default_planner_policy(policy) {
            eprintln!("warning: planner policy was already initialized; --planner ignored");
        }
    }
    match args.first().map(|s| s.as_str()) {
        Some("repro") => cmd_repro(args),
        Some("spgemm") => cmd_spgemm(args),
        Some("triangles") => cmd_triangles(args),
        Some("mcl") => cmd_mcl(args),
        Some("contract") => cmd_contract(args),
        Some("gnn") => cmd_gnn(args),
        Some("serve") => cmd_serve(args),
        Some("plan-cache") => cmd_plan_cache(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other} (try `help`)"),
    }
}

/// `serve` — the daemon (DESIGN.md §2e).
///
/// Its plan store is built from `serve`'s own flag/env resolution
/// ([`spgemm_aia::serve::resolve_plan_cache`]), deliberately bypassing
/// the process-wide `OnceLock` default: that cell latches on first
/// read, so anything constructed before flag parsing could have pinned
/// the wrong cache directory for the daemon's whole lifetime
/// (regression-pinned by `tests/serve.rs`).
fn cmd_serve(args: &[String]) -> Result<()> {
    #[cfg(not(unix))]
    {
        let _ = args;
        bail!("serve needs unix domain sockets (unsupported on this platform)");
    }
    #[cfg(unix)]
    {
        let socket = opt(args, "--socket").ok_or_else(|| anyhow!("--socket PATH required"))?;
        let mut cfg = spgemm_aia::serve::ServeConfig::default();
        if let Some(q) = opt(args, "--queue") {
            cfg.queue_capacity = q.parse().map_err(|_| anyhow!("--queue must be a positive integer (got {q})"))?;
            if cfg.queue_capacity == 0 {
                bail!("--queue must be at least 1");
            }
        }
        if let Some(s) = opt(args, "--streams") {
            cfg.n_streams = s.parse().map_err(|_| anyhow!("--streams must be a positive integer (got {s})"))?;
            if cfg.n_streams == 0 {
                bail!("--streams must be at least 1");
            }
        }
        let env = std::env::var("SPGEMM_AIA_PLAN_CACHE").ok();
        cfg.plan_cache = spgemm_aia::serve::resolve_plan_cache(opt(args, "--plan-cache"), env.as_deref());
        // Same flag-over-env ladder as the plan cache, resolved into the
        // daemon's own config rather than the process-wide `OnceLock`:
        // per-request `"planner"` overrides still win over this default.
        let penv = std::env::var("SPGEMM_AIA_PLANNER").ok();
        if let Some(name) = opt(args, "--planner").or_else(|| penv.as_deref()) {
            cfg.planner = spgemm_aia::spgemm::hash::PlannerPolicy::parse(name)
                .ok_or_else(|| anyhow!("unknown planner {name} (expected exact, estimated, or auto)"))?;
        }
        spgemm_aia::serve::session::run_daemon(std::path::Path::new(socket), &cfg)
    }
}

/// `plan-cache ls|verify|prune` — lifecycle management of the disk
/// tier, over the same validation ladder the loader uses.
fn cmd_plan_cache(args: &[String]) -> Result<()> {
    use spgemm_aia::spgemm::hash::DiskStore;
    let action = args
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("plan-cache needs an action: ls | verify | prune --max-bytes N"))?;
    let dir = opt(args, "--dir")
        .map(std::path::PathBuf::from)
        .or_else(spgemm_aia::spgemm::hash::default_plan_cache_dir)
        .ok_or_else(|| anyhow!("no cache directory (use --dir, --plan-cache, or SPGEMM_AIA_PLAN_CACHE)"))?;
    let store = DiskStore::new(&dir);
    match action {
        "ls" => {
            let entries = store.entries();
            let total: u64 = entries.iter().map(|e| e.bytes).sum();
            for e in &entries {
                println!(
                    "{:>10} B  key={}  {}",
                    e.bytes,
                    e.key.map(|k| format!("{k:016x}")).unwrap_or_else(|| "????".into()),
                    e.path.display()
                );
            }
            println!("{} plan file(s), {} bytes in {}", entries.len(), total, dir.display());
        }
        "verify" => {
            let entries = store.entries();
            let mut bad = 0usize;
            for e in &entries {
                match DiskStore::verify_path(&e.path) {
                    Ok(s) => println!(
                        "ok   {}  key={:016x}  {}x{} * {}x{}  nnz={}  bins={}",
                        e.path.display(),
                        s.key,
                        s.a_shape.0,
                        s.a_shape.1,
                        s.b_shape.0,
                        s.b_shape.1,
                        s.nnz,
                        s.bins
                    ),
                    Err(err) => {
                        bad += 1;
                        println!("BAD  {}: {err:#}", e.path.display());
                    }
                }
            }
            if bad > 0 {
                bail!("{bad} of {} plan file(s) failed verification in {}", entries.len(), dir.display());
            }
            println!("verified {} plan file(s) in {}: all ok", entries.len(), dir.display());
        }
        "prune" => {
            let max = opt(args, "--max-bytes")
                .ok_or_else(|| anyhow!("prune needs --max-bytes N"))?
                .parse::<u64>()
                .map_err(|_| anyhow!("--max-bytes must be a non-negative integer"))?;
            let r = store.prune(max);
            println!(
                "pruned {} -> {} bytes (kept {}, removed {}) in {}",
                r.bytes_before,
                r.bytes_after,
                r.kept,
                r.removed,
                dir.display()
            );
        }
        other => bail!("unknown plan-cache action {other} (ls | verify | prune)"),
    }
    Ok(())
}

/// `calibrate` — sweep the SPA/bitmap density threshold across
/// registered datasets under the traced engine (AIA on), fit the
/// crossover from the measured time/waste curves, and persist it as a
/// versioned `calibration.json` next to the plan cache. Later
/// processes pick it up as their threshold default (`--spa-threshold`
/// still wins; a corrupt file degrades to the geometry fallback).
fn cmd_calibrate(args: &[String]) -> Result<()> {
    use spgemm_aia::spgemm::hash::{calibrate_sweep, default_threshold_grid, CalibrateInput};
    let out = opt(args, "--out")
        .map(std::path::PathBuf::from)
        .or_else(spgemm_aia::spgemm::hash::default_plan_cache_dir)
        .ok_or_else(|| anyhow!("no output directory (use --out, --plan-cache, or SPGEMM_AIA_PLAN_CACHE)"))?;
    let names: Vec<&str> = match opt(args, "--datasets") {
        Some(csv) => csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect(),
        None => vec!["scircuit", "Economics", "p2p-Gnutella04"],
    };
    if names.is_empty() {
        bail!("--datasets needs at least one dataset name");
    }
    let thresholds: Vec<f64> = match opt(args, "--grid") {
        Some(csv) => {
            let mut grid = Vec::new();
            for s in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let t: f64 = s.parse().map_err(|_| anyhow!("--grid: {s} is not a number"))?;
                if !(0.0..=8.0).contains(&t) {
                    bail!("--grid threshold out of range [0, 8]: {t}");
                }
                grid.push(t);
            }
            grid
        }
        None => default_threshold_grid(),
    };
    if thresholds.is_empty() {
        bail!("--grid needs at least one threshold");
    }
    let s = seed(args);
    let mut inputs = Vec::new();
    for name in &names {
        if let Some(ds) = spgemm_aia::gen::table2_by_name(name) {
            inputs.push(CalibrateInput { name: ds.paper.name.to_string(), a: (ds.gen)(s), scale: ds.scale });
        } else if let Some(ds) = spgemm_aia::gen::table3_by_name(name) {
            inputs.push(CalibrateInput { name: ds.paper.name.to_string(), a: (ds.gen)(s), scale: ds.scale });
        } else {
            bail!("unknown dataset {name} (see `info`)");
        }
    }
    println!(
        "calibrating SPA/bitmap threshold: {} dataset(s) x {} grid point(s), traced engine, AIA on",
        inputs.len(),
        thresholds.len()
    );
    let cal = calibrate_sweep(&inputs, &thresholds, |name, t, ms, waste| {
        println!("  {name:<16} t={t:<5} {ms:>10.3} ms  waste {:>5.1}%", 100.0 * waste);
    });
    println!("\n  {:>9} {:>12} {:>10} {:>7}", "threshold", "mean ms", "norm time", "waste");
    for p in &cal.sweep {
        let mark = if (p.threshold - cal.spa_threshold).abs() < 1e-12 { "  <- chosen" } else { "" };
        println!(
            "  {:>9} {:>12.3} {:>10.4} {:>6.1}%{mark}",
            p.threshold,
            p.mean_time_ms,
            p.mean_norm_time,
            100.0 * p.mean_waste
        );
    }
    let path = cal.save(&out)?;
    println!(
        "\ncalibrated spa-threshold = {} (geometry fallback {}) -> {}",
        cal.spa_threshold,
        cal.geometry_threshold,
        path.display()
    );
    Ok(())
}

fn print_help() {
    println!(
        "spgemm-aia — hash-based multi-phase SpGEMM with near-HBM AIA (paper reproduction)\n\n\
         USAGE:\n  spgemm-aia repro [all|table1|table2|table3|fig5|fig6|fig7|fig8|fig9|fig10|fig11|planreuse|attention]\n  \
         spgemm-aia spgemm --dataset scircuit [--variant aia|hash|cusparse] [--seed N]\n  \
         spgemm-aia triangles --dataset p2p-Gnutella04 [--seed N]\n  \
         spgemm-aia mcl --dataset Economics [--variant aia]\n  \
         spgemm-aia contract --dataset RoadTX [--variant aia]\n  \
         spgemm-aia gnn --dataset Flickr --arch gcn [--epochs 5]\n  \
         spgemm-aia serve --socket PATH [--queue 64] [--streams 4] [--plan-cache DIR] [--planner P]\n  \
         spgemm-aia plan-cache ls|verify|prune [--dir DIR] [--max-bytes N]\n  \
         spgemm-aia calibrate [--datasets a,b,c] [--grid t1,t2,...] [--out DIR] [--seed N]\n  \
         spgemm-aia info\n\nSERVE:\n  \
         newline-delimited JSON over a unix socket; ops register, multiply,\n  \
         release, stats, ping, shutdown (see README \"Running as a service\").\n  \
         A full queue answers busy — retry, the daemon never buffers unboundedly.\n\nCALIBRATE:\n  \
         sweeps the SPA/bitmap threshold across registered datasets under the\n  \
         traced simulator (AIA on), fits the crossover from the measured\n  \
         time and line-waste curves, and writes a versioned calibration.json\n  \
         next to the plan cache (--out overrides the directory). Later\n  \
         processes load it as their threshold default; --spa-threshold and\n  \
         the env var still win, and a corrupt file degrades to the geometry\n  \
         fallback (see README \"Calibrated thresholds\").\n\nOPTIONS (all subcommands):\n  \
         --spa-threshold T  dense-kernel density threshold, driving both the numeric SPA\n                     \
         (row switches from hash accumulation when nnz(C_i)/n_cols exceeds T)\n                     \
         and the symbolic bitmap counter (decided from the IP bound).\n                     \
         Default resolves flag > SPGEMM_AIA_SPA_THRESHOLD > persisted\n                     \
         calibration.json (see `calibrate`) > cache geometry (0.25 for\n                     \
         the H200's 32-byte sectors); 0 forces the dense kernels on\n                     \
         every non-trivial row, >=1 disables them\n  \
         --plan-cache DIR   persist symbolic plans to DIR (versioned, fingerprint-keyed\n                     \
         binary files) and load validated ones back, so repeated runs\n                     \
         on the same generated dataset skip the symbolic phase across\n                     \
         processes. Stale/corrupt/old-version files replan silently\n  \
         --planner P        symbolic planner policy: exact (default), estimated (sample rows\n                     \
         of A, size hash tables from the estimated nnz(C), grow-and-retry\n                     \
         per row on underestimates), or auto (speculate only on fully-cold\n                     \
         one-shot products; store hits and batch slots stay exact).\n                     \
         Output is bit-identical to exact in every mode; speculative\n                     \
         plans are never persisted to the plan cache\n\nENV:\n  \
         REPRO_QUICK=1 small subsets; SPGEMM_AIA_ARTIFACTS=dir; SPGEMM_AIA_THREADS=n;\n  \
         SPGEMM_AIA_SPA_THRESHOLD=T (same as --spa-threshold);\n  \
         SPGEMM_AIA_PLAN_CACHE=DIR (same as --plan-cache);\n  \
         SPGEMM_AIA_PLANNER=P (same as --planner)"
    );
}

fn cmd_info() -> Result<()> {
    println!("spgemm-aia {}", env!("CARGO_PKG_VERSION"));
    println!(
        "datasets (Table II): {}",
        spgemm_aia::gen::table2_datasets().iter().map(|d| d.paper.name).collect::<Vec<_>>().join(", ")
    );
    println!(
        "datasets (Table III): {}",
        spgemm_aia::gen::table3_datasets().iter().map(|d| d.paper.name).collect::<Vec<_>>().join(", ")
    );
    println!("threads: {}", spgemm_aia::util::num_threads());
    println!("spa-threshold: {}", spgemm_aia::spgemm::hash::default_spa_threshold());
    println!("planner: {}", spgemm_aia::spgemm::hash::default_planner_policy().name());
    match spgemm_aia::spgemm::hash::default_plan_cache_dir() {
        Some(d) => {
            println!("plan-cache: {}", d.display());
            match spgemm_aia::spgemm::hash::Calibration::load(&d) {
                Some(c) => println!("calibration: spa-threshold {} from {}", c.spa_threshold, d.display()),
                None => println!("calibration: (none — run `calibrate` to fit thresholds)"),
            }
        }
        None => println!("plan-cache: (none — plans live and die with the process)"),
    }
    match Runtime::new(&Runtime::artifacts_dir()) {
        Ok(_) if cfg!(feature = "pjrt") => {
            println!("PJRT CPU client: ok (artifacts dir: {})", Runtime::artifacts_dir().display())
        }
        Ok(_) => println!(
            "PJRT runtime: std-only stub — needs `--features pjrt` + vendored `xla` crate (artifacts dir: {})",
            Runtime::artifacts_dir().display()
        ),
        Err(e) => println!("PJRT CPU client: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_repro(args: &[String]) -> Result<()> {
    let what = args.get(1).map(|s| s.as_str()).unwrap_or("all");
    let t0 = std::time::Instant::now();
    match what {
        "table1" => {
            println!("=== Table I: GPU resource allocation ===");
            for spec in spgemm_aia::spgemm::hash::GROUP_SPECS.iter() {
                println!(
                    "group {} | IP {:>5}..{:<10} | {:?} | block {:>4} | table {}",
                    spec.id,
                    spec.ip_lo,
                    if spec.ip_hi == u64::MAX { "inf".to_string() } else { spec.ip_hi.to_string() },
                    spec.strategy,
                    spec.block_size,
                    spec.table_size.map(|t| t.to_string()).unwrap_or_else(|| "global".into())
                );
            }
        }
        "table2" => {
            repro::table2();
        }
        "table3" => {
            repro::table3();
        }
        "fig5" => {
            repro::fig5();
        }
        "fig6" => {
            repro::fig6();
        }
        "fig7" | "fig8" => {
            repro::fig7_fig8();
        }
        "fig9" => {
            repro::fig9();
        }
        "planreuse" | "plan-reuse" => {
            repro::plan_reuse();
        }
        "attention" => {
            repro::attention();
        }
        "fig10" | "fig11" => {
            let mut rt = Runtime::new(&Runtime::artifacts_dir())?;
            repro::fig10_fig11(&mut rt)?;
        }
        "all" => {
            repro::table2();
            repro::table3();
            repro::fig5();
            repro::fig6();
            repro::fig7_fig8();
            repro::fig9();
            repro::plan_reuse();
            repro::attention();
            // Figs 10/11 need a real PJRT backend. In stub builds skip
            // them rather than failing the other nine experiments; in
            // `pjrt` builds errors are genuine and must propagate.
            if cfg!(feature = "pjrt") {
                let mut rt = Runtime::new(&Runtime::artifacts_dir())?;
                repro::fig10_fig11(&mut rt)?;
            } else {
                eprintln!("skipping fig10/fig11: built without the `pjrt` feature");
            }
        }
        other => bail!("unknown experiment {other}"),
    }
    println!("\n[repro {what} done in {:.1}s]", t0.elapsed().as_secs_f64());
    Ok(())
}

fn dataset_matrix(args: &[String]) -> Result<spgemm_aia::sparse::Csr> {
    let name = opt(args, "--dataset").ok_or_else(|| anyhow!("--dataset required"))?;
    if let Some(ds) = spgemm_aia::gen::table2_by_name(name) {
        return Ok((ds.gen)(seed(args)));
    }
    if let Some(ds) = spgemm_aia::gen::table3_by_name(name) {
        return Ok((ds.gen)(seed(args)));
    }
    // Also accept a MatrixMarket path.
    let p = std::path::Path::new(name);
    if p.exists() {
        return spgemm_aia::sparse::io::read_matrix_market(p);
    }
    bail!("unknown dataset {name} (see `info`)")
}

fn cmd_spgemm(args: &[String]) -> Result<()> {
    let a = dataset_matrix(args)?;
    let v = variant(args)?;
    let total_ip = ip::total_ip(&a, &a);
    let mut ex = SpgemmExecutor::simulated(v);
    let t0 = std::time::Instant::now();
    let c = ex.multiply(&a, &a);
    let wall = t0.elapsed().as_secs_f64();
    println!("A: {}x{} nnz={} | A^2 nnz={} IP={}", a.n_rows, a.n_cols, a.nnz(), c.nnz(), total_ip);
    println!(
        "variant {} | simulated {:.3} ms | {:.1} GFLOPS | engine wall {:.3} s",
        v.name(),
        ex.sim_ms,
        gflops(total_ip, ex.sim_ms),
        wall
    );
    for p in &ex.reports[0].phases {
        println!(
            "  {}: {:.3} ms, L1 hit {:.1}%, HBM {:.1} MB, line waste {:.1}%{}",
            p.phase.name(),
            p.time_ms,
            100.0 * p.l1_hit_ratio,
            p.hbm_bytes as f64 / 1e6,
            100.0 * p.waste_ratio(),
            if p.aia_bound { " [AIA-bound]" } else { "" }
        );
    }
    // Byte-accurate line utilization (the paper's central quantity):
    // how much of every HBM line fetched was actually consumed before
    // eviction, overall and for the heaviest regions.
    let rep = &ex.reports[0];
    if rep.fetched_bytes() > 0 {
        println!(
            "  line utilization: used {:.2} MB of {:.2} MB fetched ({:.1}% waste)",
            rep.used_bytes() as f64 / 1e6,
            rep.fetched_bytes() as f64 / 1e6,
            100.0 * rep.waste_ratio()
        );
        let mut regions = rep.region_waste();
        regions.sort_by(|x, y| y.fetched_bytes.cmp(&x.fetched_bytes));
        for r in regions.iter().take(4) {
            println!(
                "    {:<10} used {:>9.3} MB / fetched {:>9.3} MB ({:.1}% waste)",
                r.region.name(),
                r.used_bytes as f64 / 1e6,
                r.fetched_bytes as f64 / 1e6,
                100.0 * r.waste_ratio()
            );
        }
    }
    // Row-kernel split of the hash engine's plan: the symbolic per-kind
    // counts next to the numeric ones (ESC has no plan to report).
    // Re-derived from what is already in hand — the IP counts (O(nnz))
    // and the computed product's exact row sizes — instead of re-running
    // the whole symbolic analysis just to print six counters.
    if v != Variant::Cusparse {
        use spgemm_aia::spgemm::hash::{select_accumulator, select_symbolic};
        let thr = (spgemm_aia::spgemm::hash::default_spa_threshold()
            * spgemm_aia::sim::DeviceConfig::h200_scaled().dense_row_l2_overflow(a.n_cols))
        .min(8.0);
        let ip_rows = ip::intermediate_products(&a, &a);
        let (mut nk, mut sk) = ([0usize; 3], [0usize; 3]);
        for i in 0..a.n_rows {
            sk[select_symbolic(a.row_nnz(i), ip_rows[i], a.n_cols, thr).index()] += 1;
            let n_out = c.row_nnz(i);
            if n_out > 0 {
                nk[select_accumulator(a.row_nnz(i), n_out, a.n_cols, thr).index()] += 1;
            }
        }
        println!(
            "  plan: numeric rows copy/hash/spa = {}/{}/{} | symbolic rows trivial/hash/bitmap = {}/{}/{}",
            nk[0], nk[1], nk[2], sk[0], sk[1], sk[2]
        );
    }
    Ok(())
}

/// `triangles` — exact triangle counting via masked SpGEMM (DESIGN.md
/// §2i). With A the symmetrized, unit-valued, loop-free adjacency,
/// C = A ⊙ (A·A) restricts the wedge counts of A² to existing edges,
/// so every triangle {i,j,k} contributes exactly 6 to sum(C): one per
/// orientation of each of its three edges. The mask prunes both engine
/// phases — symbolic counts and numeric inserts never touch a column
/// outside row i of A, so the dense wedge rows of A² are never
/// materialized (the post-filter oracle pays for all of them; the wall
/// times below show the gap).
fn cmd_triangles(args: &[String]) -> Result<()> {
    use spgemm_aia::spgemm::hash::{self, Mask};
    let raw = dataset_matrix(args)?;
    if raw.n_rows != raw.n_cols {
        bail!("triangles needs a square adjacency matrix (got {}x{})", raw.n_rows, raw.n_cols);
    }
    // Undirected simple graph: both directions, unit values, no loops.
    let mut coo = spgemm_aia::sparse::Coo::new(raw.n_rows, raw.n_cols);
    for i in 0..raw.n_rows {
        let (cols, _) = raw.row(i);
        for &j in cols {
            if j as usize != i {
                coo.push(i, j as usize, 1.0);
                coo.push(j as usize, i, 1.0);
            }
        }
    }
    let mut adj = coo.to_csr();
    adj.map_values(|_| 1.0); // duplicated edges summed to 2.0 above; clamp back to unit

    let mask = Mask::from_structure(&adj);
    let t0 = std::time::Instant::now();
    let c = hash::multiply_masked(&adj, &adj, &mask);
    let masked_wall = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let full = hash::multiply(&adj, &adj);
    let oracle = mask.filter(&full);
    let oracle_wall = t1.elapsed().as_secs_f64();
    if c != oracle {
        bail!("masked A*A diverged from the multiply-then-filter oracle");
    }
    let paths: f64 = c.val.iter().sum();
    let triangles = (paths / 6.0).round() as u64;
    println!(
        "graph: {} nodes, {} undirected edges (from {} raw nnz)",
        adj.n_rows,
        adj.nnz() / 2,
        raw.nnz()
    );
    println!(
        "masked A.A: nnz={} (unmasked A^2 nnz={}) | masked {:.3} s vs multiply-then-filter {:.3} s",
        c.nnz(),
        full.nnz(),
        masked_wall,
        oracle_wall
    );
    println!("triangles: {triangles}");
    Ok(())
}

fn cmd_mcl(args: &[String]) -> Result<()> {
    let g = dataset_matrix(args)?;
    let v = variant(args)?;
    let mut ex = SpgemmExecutor::simulated(v);
    let r = mcl(&g, &MclParams::default(), &mut ex);
    println!(
        "MCL on {} nodes: {} clusters in {} iterations (converged: {}) | simulated SpGEMM {:.2} ms ({})",
        g.n_rows,
        r.n_clusters,
        r.iterations,
        r.converged,
        r.sim_ms,
        v.name()
    );
    Ok(())
}

fn cmd_contract(args: &[String]) -> Result<()> {
    let g = dataset_matrix(args)?;
    let v = variant(args)?;
    let mut rng = Pcg32::new(seed(args), 5);
    let labels = random_labels(g.n_rows, (g.n_rows / 4).max(1), &mut rng);
    let mut ex = SpgemmExecutor::simulated(v);
    let r = contract(&g, &labels, &mut ex);
    println!(
        "contracted {} -> {} nodes ({} -> {} nnz) | simulated SpGEMM {:.2} ms ({})",
        g.n_rows,
        r.contracted.n_rows,
        g.nnz(),
        r.contracted.nnz(),
        r.sim_ms,
        v.name()
    );
    Ok(())
}

fn cmd_gnn(args: &[String]) -> Result<()> {
    let name = opt(args, "--dataset").unwrap_or("Flickr");
    let ds = spgemm_aia::gen::table3_by_name(name).ok_or_else(|| anyhow!("unknown GNN dataset {name}"))?;
    let arch = Arch::parse(opt(args, "--arch").unwrap_or("gcn")).ok_or_else(|| anyhow!("bad --arch"))?;
    let epochs: usize = opt(args, "--epochs").and_then(|s| s.parse().ok()).unwrap_or(5);
    let data = GnnData::build(&ds, seed(args));
    let mut rt = Runtime::new(&Runtime::artifacts_dir())?;
    let mut trainer = Trainer::new(&mut rt, &data, arch, seed(args));
    if let Some(lr) = opt(args, "--lr").and_then(|s| s.parse::<f32>().ok()) {
        trainer.lr = lr;
    }
    println!(
        "training {} on {} ({} nodes, {} edges), {} epochs",
        arch.name(),
        name,
        data.n,
        data.adj.nnz(),
        epochs
    );
    for e in 0..epochs {
        let s = trainer.epoch()?;
        println!(
            "epoch {e:>3}: loss {:.4} acc {:.3} dense {:.2}s spgemm_jobs {}",
            s.loss, s.accuracy, s.dense_secs, s.spgemm_jobs
        );
    }
    for v in Variant::all() {
        println!("  simulated SpGEMM/epoch {} = {:.2} ms", v.name(), trainer.simulate_epoch_ms(v));
    }
    println!(
        "  plan-reuse hit rate: {:.1}% of aggregations skipped the symbolic phase",
        100.0 * trainer.plan_hit_rate()
    );
    Ok(())
}