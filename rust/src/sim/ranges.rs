//! Byte-accurate line-utilization accounting (DESIGN.md §2h).
//!
//! The paper's central quantity is *cache-line waste*: the two-level
//! indirection of SpGEMM fetches full HBM lines but touches only a few
//! bytes of each. The simulator previously priced a miss as a full
//! `line_bytes` charge and threw the access width away, so it could not
//! report the quantity it exists to study. This module closes that gap
//! with a cachegrind-style structure: a compact coalescing interval set
//! of touched `[lo, hi)` byte spans per *live* cache line, flushed into
//! aggregate used/fetched counters (per region × phase) when the line
//! leaves the L2 — so memory stays bounded by the cache footprint, not
//! by the trace length.

use std::collections::HashMap;

/// Sorted, disjoint, coalescing set of `[lo, hi)` byte intervals within
/// one cache line. Adjacent and overlapping inserts merge, so the span
/// count is bounded by the number of *gaps* ever observed (tiny for a
/// ≤256-byte line).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RangeSet {
    /// Sorted by `lo`, pairwise disjoint and non-adjacent.
    spans: Vec<(u32, u32)>,
}

impl RangeSet {
    pub fn new() -> RangeSet {
        RangeSet { spans: Vec::new() }
    }

    /// Insert `[lo, hi)`, merging with any overlapping or adjacent spans.
    pub fn insert(&mut self, lo: u32, hi: u32) {
        if lo >= hi {
            return;
        }
        // First span that could merge: ends at or after `lo` (an end
        // exactly at `lo` is adjacent, which also merges).
        let i = self.spans.partition_point(|&(_, h)| h < lo);
        let mut new_lo = lo;
        let mut new_hi = hi;
        let mut j = i;
        while j < self.spans.len() && self.spans[j].0 <= hi {
            new_lo = new_lo.min(self.spans[j].0);
            new_hi = new_hi.max(self.spans[j].1);
            j += 1;
        }
        if i == j {
            self.spans.insert(i, (new_lo, new_hi));
        } else {
            self.spans[i] = (new_lo, new_hi);
            self.spans.drain(i + 1..j);
        }
    }

    /// Total bytes covered by the set.
    pub fn covered(&self) -> u64 {
        self.spans.iter().map(|&(l, h)| (h - l) as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// The disjoint spans, sorted by `lo`.
    pub fn spans(&self) -> &[(u32, u32)] {
        &self.spans
    }
}

/// One line currently resident in the (modelled) L2: which region/phase
/// fetched it and which of its bytes have been touched since the fetch.
struct LiveLine {
    region: u16,
    phase: u16,
    touched: RangeSet,
}

/// Aggregate used-vs-fetched byte accounting, keyed by
/// `region × phase` slot ordinals. `fetch` opens a live entry (charging
/// `line_bytes` fetched), `touch` records byte spans against it, and
/// `evict`/`flush` fold the covered bytes into the `used` aggregates —
/// the eviction-time flush is what bounds the live map by the cache
/// footprint.
///
/// Invariant (pinned by tests): `used ≤ fetched` in every cell, because
/// each live entry corresponds to exactly one `line_bytes` fetch charge
/// and a [`RangeSet`] over one line covers at most `line_bytes`.
pub struct LineUseTracker {
    line_bytes: u32,
    phases: usize,
    live: HashMap<u64, LiveLine>,
    /// `[region * phases + phase]` aggregates, in bytes.
    used: Vec<u64>,
    fetched: Vec<u64>,
}

impl LineUseTracker {
    pub fn new(line_bytes: usize, regions: usize, phases: usize) -> LineUseTracker {
        LineUseTracker {
            line_bytes: line_bytes as u32,
            phases,
            live: HashMap::new(),
            used: vec![0; regions * phases],
            fetched: vec![0; regions * phases],
        }
    }

    #[inline]
    fn cell(&self, region: usize, phase: usize) -> usize {
        region * self.phases + phase
    }

    /// The line was fetched from HBM on behalf of `(region, phase)`:
    /// charge `line_bytes` fetched and open a live entry seeded with the
    /// triggering access's `[lo, hi)` span (line-relative offsets). A
    /// stale entry for the same line (evicted without notice) is flushed
    /// first, so the one-fetch-per-entry invariant holds.
    pub fn fetch(&mut self, line: u64, region: usize, phase: usize, lo: u32, hi: u32) {
        self.evict(line);
        let cell = self.cell(region, phase);
        self.fetched[cell] += self.line_bytes as u64;
        let mut touched = RangeSet::new();
        touched.insert(lo.min(self.line_bytes), hi.min(self.line_bytes));
        self.live.insert(line, LiveLine { region: region as u16, phase: phase as u16, touched });
    }

    /// Bytes `[lo, hi)` of `line` were read or written while resident.
    /// A no-op when the line is not live (its fetch predates tracking or
    /// it was already flushed) — dropping touches can only *under*count
    /// used bytes, which keeps `used ≤ fetched` safe.
    pub fn touch(&mut self, line: u64, lo: u32, hi: u32) {
        if let Some(l) = self.live.get_mut(&line) {
            let lb = self.line_bytes;
            l.touched.insert(lo.min(lb), hi.min(lb));
        }
    }

    /// The line left the cache: fold its covered bytes into `used` and
    /// drop the live entry.
    pub fn evict(&mut self, line: u64) {
        if let Some(l) = self.live.remove(&line) {
            let cell = l.region as usize * self.phases + l.phase as usize;
            self.used[cell] += l.touched.covered();
        }
    }

    /// Flush every still-live line (end of simulation).
    pub fn flush(&mut self) {
        let lines: Vec<u64> = self.live.keys().copied().collect();
        for line in lines {
            self.evict(line);
        }
    }

    /// Bytes of fetched lines actually touched, attributed to the
    /// fetching `(region, phase)`. Only complete after [`flush`].
    ///
    /// [`flush`]: LineUseTracker::flush
    pub fn used(&self, region: usize, phase: usize) -> u64 {
        self.used[self.cell(region, phase)]
    }

    /// Bytes fetched from HBM on behalf of `(region, phase)` — always a
    /// whole number of lines.
    pub fn fetched(&self, region: usize, phase: usize) -> u64 {
        self.fetched[self.cell(region, phase)]
    }

    /// Number of live (not yet flushed) line entries — bounded by the
    /// modelled cache footprint, pinned by a test.
    pub fn live_lines(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(pairs: &[(u32, u32)]) -> RangeSet {
        let mut s = RangeSet::new();
        for &(l, h) in pairs {
            s.insert(l, h);
        }
        s
    }

    #[test]
    fn insert_disjoint_sorted() {
        let s = set(&[(8, 12), (0, 4), (20, 24)]);
        assert_eq!(s.spans(), &[(0, 4), (8, 12), (20, 24)]);
        assert_eq!(s.covered(), 12);
    }

    #[test]
    fn insert_adjacent_coalesces() {
        let s = set(&[(0, 4), (4, 8)]);
        assert_eq!(s.spans(), &[(0, 8)]);
        let s = set(&[(4, 8), (0, 4), (8, 12)]);
        assert_eq!(s.spans(), &[(0, 12)]);
    }

    #[test]
    fn insert_overlapping_merges_many() {
        let s = set(&[(0, 4), (8, 12), (16, 20), (2, 18)]);
        assert_eq!(s.spans(), &[(0, 20)]);
        assert_eq!(s.covered(), 20);
    }

    #[test]
    fn insert_contained_is_noop() {
        let mut s = set(&[(0, 32)]);
        s.insert(4, 8);
        assert_eq!(s.spans(), &[(0, 32)]);
    }

    #[test]
    fn empty_span_ignored() {
        let s = set(&[(4, 4), (8, 4)]);
        assert!(s.is_empty());
        assert_eq!(s.covered(), 0);
    }

    #[test]
    fn covered_matches_bitmap_oracle() {
        // Pseudo-random spans within a 256-byte line, cross-checked
        // against a plain byte bitmap.
        let mut s = RangeSet::new();
        let mut bitmap = [false; 256];
        let mut x = 7u64;
        for _ in 0..500 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(11);
            let lo = (x % 256) as u32;
            x = x.wrapping_mul(6364136223846793005).wrapping_add(11);
            let hi = (lo + 1 + (x % 32) as u32).min(256);
            s.insert(lo, hi);
            for b in bitmap.iter_mut().take(hi as usize).skip(lo as usize) {
                *b = true;
            }
            let want = bitmap.iter().filter(|&&b| b).count() as u64;
            assert_eq!(s.covered(), want);
            // Structural invariants: sorted, disjoint, non-adjacent.
            for w in s.spans().windows(2) {
                assert!(w[0].1 < w[1].0, "spans {:?}", s.spans());
            }
        }
    }

    #[test]
    fn tracker_used_bounded_by_fetched() {
        let mut t = LineUseTracker::new(32, 2, 3);
        t.fetch(100, 1, 2, 0, 4);
        t.touch(100, 4, 8);
        t.touch(100, 28, 40); // clamped to line
        t.touch(999, 0, 32); // not live: dropped
        t.flush();
        assert_eq!(t.fetched(1, 2), 32);
        assert_eq!(t.used(1, 2), 12);
        assert_eq!(t.used(0, 0), 0);
    }

    #[test]
    fn tracker_refetch_flushes_stale_entry() {
        let mut t = LineUseTracker::new(32, 1, 1);
        t.fetch(5, 0, 0, 0, 4);
        // Same line fetched again (evicted without notice in between):
        // the stale entry's 4 bytes flush, a second line charge lands.
        t.fetch(5, 0, 0, 8, 16);
        t.flush();
        assert_eq!(t.fetched(0, 0), 64);
        assert_eq!(t.used(0, 0), 12);
        assert!(t.used(0, 0) <= t.fetched(0, 0));
    }

    #[test]
    fn tracker_eviction_folds_into_aggregates() {
        let mut t = LineUseTracker::new(64, 1, 2);
        t.fetch(1, 0, 0, 0, 64);
        t.fetch(2, 0, 1, 0, 8);
        assert_eq!(t.live_lines(), 2);
        t.evict(1);
        assert_eq!(t.live_lines(), 1);
        assert_eq!(t.used(0, 0), 64);
        // evicting a non-live line is a no-op
        t.evict(77);
        t.flush();
        assert_eq!(t.used(0, 1), 8);
        assert_eq!(t.live_lines(), 0);
    }
}
