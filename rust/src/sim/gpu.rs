//! Device configuration for the simulated GPU (H200-class) and its AIA
//! extension.
//!
//! Constants are calibrated once against public H200 specs and the
//! paper's architectural description (Fig. 1: 6 HBM stacks, AIA engine
//! in each stack controller), then shared by **all** experiments — no
//! per-experiment tuning (DESIGN.md §5). Cache capacities are scaled by
//! `cache_scale` to match the dataset down-scaling documented in the
//! registry, preserving capacity-miss behaviour.

/// Whether the AIA near-HBM engine services the two-level indirection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AiaMode {
    Off,
    On,
}

/// Simulated device parameters.
#[derive(Clone, Debug)]
pub struct DeviceConfig {
    /// Streaming multiprocessors (H200: 132).
    pub sms: usize,
    /// SM clock in GHz.
    pub clock_ghz: f64,
    /// Useful ALU ops per SM per cycle the kernels sustain (scalar-ish
    /// integer/hash work, not peak FMA).
    pub ipc_per_sm: f64,
    /// Memory-level parallelism: outstanding misses an SM's warps overlap.
    pub mlp: f64,
    /// Effective MLP for *dependent* pointer-chase loads (the rpt_B
    /// lookup that must return before its range loads can issue — the
    /// 2N-round-trip serialization of Fig. 2). Far lower than `mlp`.
    pub mlp_dep: f64,

    /// L1 data cache per SM, bytes (H200: 256 KiB; scaled).
    pub l1_bytes: usize,
    pub l1_ways: usize,
    /// Cache line bytes (sector granularity on NVIDIA; 128 B line).
    pub line_bytes: usize,
    /// L2 total bytes (H200: 60 MiB; scaled).
    pub l2_bytes: usize,
    pub l2_ways: usize,

    /// Latencies in SM cycles.
    pub l1_lat: f64,
    pub l2_lat: f64,
    pub hbm_lat: f64,

    /// HBM stacks (H200: 6) and aggregate bandwidth GB/s (H200: 4800).
    pub hbm_stacks: usize,
    pub hbm_bw_gbps: f64,

    /// Extra serialization cycles per global atomic (CAS/Add) beyond a
    /// normal access, amortized over the SM's warps.
    pub atomic_cost: f64,
    /// Expected shared-memory bank-conflict slowdown factor for random
    /// hash probing (1.0 = conflict-free).
    pub bank_conflict_factor: f64,
    /// Shared-memory words served per SM per cycle (32 banks).
    pub shared_words_per_cycle: f64,

    /// AIA engine: fixed overhead per ranged-indirect request (engine
    /// cycles) and elements gathered per engine cycle per stack.
    pub aia_req_overhead: f64,
    pub aia_elems_per_cycle: f64,
    /// AIA engine clock, GHz (stack base-die logic is slower than SMs).
    pub aia_clock_ghz: f64,

    /// Concurrent thread blocks resident per SM. The trace is replayed
    /// block-sequentially, so each block's reuse distance is dilated by
    /// this factor on real hardware — the cache model divides effective
    /// L1/L2 capacity by these to compensate (standard trick in
    /// trace-driven GPU cache modelling).
    pub l1_occupancy_div: usize,
    pub l2_occupancy_div: usize,
}

impl DeviceConfig {
    /// H200-class device with caches scaled for ~1/16-scale datasets.
    pub fn h200_scaled() -> DeviceConfig {
        DeviceConfig {
            sms: 132,
            clock_ghz: 1.98,
            ipc_per_sm: 256.0,
            mlp: 48.0,
            mlp_dep: 8.0,
            l1_bytes: 32 << 10, // 256 KiB / 8
            l1_ways: 8,
            // NVIDIA L1/L2 transact in 32 B sectors; hit-ratio counters
            // (what Fig. 5 reports via nsight) are sector-granular.
            line_bytes: 32,
            l2_bytes: 4 << 20, // 60 MiB / 15
            l2_ways: 16,
            l1_lat: 32.0,
            l2_lat: 200.0,
            hbm_lat: 650.0,
            hbm_stacks: 6,
            hbm_bw_gbps: 4800.0,
            atomic_cost: 24.0,
            bank_conflict_factor: 1.35,
            shared_words_per_cycle: 32.0,
            // AIA requests are *batched*: one (dst, N, R, a, b) descriptor
            // covers N lookups (Fig. 2), so per-lookup overhead is small;
            // per-stack gather throughput tracks HBM3e internal bandwidth
            // (~800 GB/s per stack ≈ 64 elements/engine-cycle).
            aia_req_overhead: 2.0,
            aia_elems_per_cycle: 64.0,
            aia_clock_ghz: 1.2,
            l1_occupancy_div: 16,
            l2_occupancy_div: 8,
        }
    }

    /// Full-size H200 caches (for experiments on full-scale inputs).
    pub fn h200_full() -> DeviceConfig {
        DeviceConfig { l1_bytes: 256 << 10, l2_bytes: 60 << 20, ..Self::h200_scaled() }
    }

    /// H200 with caches scaled by a dataset's down-scaling factor, so the
    /// working-set : cache ratio matches what the full-size dataset sees
    /// on real hardware (DESIGN.md §Hardware substitution). Capacities
    /// are clamped to keep valid geometry and rounded to powers of two.
    pub fn h200_for_scale(scale: usize) -> DeviceConfig {
        let scale = scale.max(1);
        let clamp_pow2 = |bytes: usize, min: usize| -> usize {
            let b = (bytes / scale).max(min);
            // round down to a power of two for clean set geometry
            1usize << (usize::BITS - 1 - b.leading_zeros())
        };
        DeviceConfig {
            l1_bytes: clamp_pow2(256 << 10, 8 << 10),
            l2_bytes: clamp_pow2(60 << 20, 512 << 10),
            ..Self::h200_scaled()
        }
    }

    /// Bytes/cycle of aggregate HBM bandwidth, in SM-clock cycles.
    pub fn hbm_bytes_per_cycle(&self) -> f64 {
        self.hbm_bw_gbps * 1e9 / (self.clock_ghz * 1e9)
    }

    /// Base density crossover of the dense row kernels (symbolic bitmap
    /// counter / numeric SPA accumulator) vs Table-I hash probing,
    /// derived from the cache geometry instead of a magic constant.
    ///
    /// Per output non-zero, a hash row touches ~2 table slots of 4
    /// bytes each (load factor ≤ 0.5 ⇒ ≈2 probes per insert), every
    /// one a scattered line; a dense kernel touches one slot plus a
    /// *sequential* scan that costs one line fetch per `line_bytes/4`
    /// columns. Equating the hash path's scattered extra against the
    /// dense scan puts the crossover at `nnz/n_cols = 2·4/line_bytes` —
    /// 0.25 at this device's 32-byte sector granularity (pinned equal
    /// to `spgemm::hash::DEFAULT_SPA_THRESHOLD` by a grouping test).
    pub fn dense_row_threshold_base(&self) -> f64 {
        8.0 / self.line_bytes as f64
    }

    /// How badly one dense row (4 bytes of kernel state per output
    /// column) overflows the per-resident-block share of the L2
    /// (`l2_bytes / l2_occupancy_div` — the same occupancy dilation the
    /// cache model applies). 1.0 while the row fits; grows linearly
    /// with the overflow once the sequential scan starts thrashing the
    /// L2. The engine multiplies the threshold knob by this factor, so
    /// dense kernels switch off progressively on very wide outputs.
    pub fn dense_row_l2_overflow(&self, n_cols: usize) -> f64 {
        let share = (self.l2_bytes / self.l2_occupancy_div.max(1)).max(1) as f64;
        (n_cols as f64 * 4.0 / share).max(1.0)
    }

    /// The cache-adaptive dense-kernel threshold for outputs of width
    /// `n_cols`: [`DeviceConfig::dense_row_threshold_base`] scaled by
    /// [`DeviceConfig::dense_row_l2_overflow`], clamped to the CLI's
    /// accepted `[0, 8]` range (≥ 1.0 already disables the dense
    /// kernels entirely).
    pub fn dense_row_threshold(&self, n_cols: usize) -> f64 {
        (self.dense_row_threshold_base() * self.dense_row_l2_overflow(n_cols)).min(8.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h200_parameters_sane() {
        let d = DeviceConfig::h200_scaled();
        assert_eq!(d.sms, 132);
        assert_eq!(d.hbm_stacks, 6);
        assert!(d.l1_bytes.is_power_of_two());
        assert!((d.l1_bytes / d.line_bytes) % d.l1_ways == 0);
        assert!(d.hbm_bytes_per_cycle() > 1000.0); // ~2424 B/cycle
    }

    #[test]
    fn full_config_scales_caches_only() {
        let s = DeviceConfig::h200_scaled();
        let f = DeviceConfig::h200_full();
        assert_eq!(f.l1_bytes, 8 * s.l1_bytes);
        assert_eq!(f.sms, s.sms);
    }

    #[test]
    fn dense_row_threshold_derivation() {
        let d = DeviceConfig::h200_scaled();
        // 32-byte sectors: crossover at a quarter density.
        assert!((d.dense_row_threshold_base() - 0.25).abs() < 1e-12);
        // Rows that fit the per-block L2 share keep the base threshold.
        assert_eq!(d.dense_row_l2_overflow(1_000), 1.0);
        assert!((d.dense_row_threshold(1_000) - 0.25).abs() < 1e-12);
        // The per-block L2 share is 4 MiB / 8 = 512 KiB = 131072 flag
        // words: wider rows scale the threshold up...
        let wide = 4 * 131_072;
        assert!((d.dense_row_l2_overflow(wide) - 4.0).abs() < 1e-12);
        assert!((d.dense_row_threshold(wide) - 1.0).abs() < 1e-12);
        // ...monotonically, and clamped to the CLI's accepted range.
        assert!(d.dense_row_threshold(wide * 2) >= d.dense_row_threshold(wide));
        assert!(d.dense_row_threshold(usize::MAX / 8) <= 8.0);
    }
}
