//! High-level simulation entry points: run an SpGEMM through the traced
//! engine + machine model and get back the product and a [`SimReport`].

use super::gpu::{AiaMode, DeviceConfig};
use super::machine::{Machine, SimReport};
use super::probe::SamplingProbe;
use crate::spgemm::{ip, spgemm_traced, Algo};
use crate::sparse::Csr;

/// Target sampled intermediate products — keeps a simulation run at a
/// few hundred ms regardless of workload size.
const TARGET_SAMPLED_IP: u64 = 3_000_000;

/// Pick a block-sampling factor for a workload of `total_ip`
/// intermediate products.
pub fn auto_sample(total_ip: u64) -> usize {
    (total_ip / TARGET_SAMPLED_IP).clamp(1, 4096) as usize
}

/// Simulation request.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub device: DeviceConfig,
    pub aia: AiaMode,
    /// Block-sampling factor; `None` = choose from workload size.
    pub sample: Option<usize>,
}

impl SimConfig {
    pub fn new(aia: AiaMode) -> SimConfig {
        SimConfig { device: DeviceConfig::h200_scaled(), aia, sample: None }
    }

    /// Config whose caches are scaled by the dataset's down-scaling
    /// factor (see `DeviceConfig::h200_for_scale`).
    pub fn for_scale(aia: AiaMode, scale: usize) -> SimConfig {
        SimConfig { device: DeviceConfig::h200_for_scale(scale), aia, sample: None }
    }
}

/// Run `C = A · B` on the simulated machine. Returns the (complete,
/// exact) product — computed on the fast parallel path — and the
/// simulation report from a block-sampled stats-only trace. The paper's
/// cuSPARSE baseline (`Algo::Esc`) never uses AIA — enforced here so
/// callers cannot misconfigure the comparison.
pub fn simulate_spgemm(algo: Algo, a: &Csr, b: &Csr, cfg: &SimConfig) -> (Csr, SimReport) {
    let c = crate::spgemm::spgemm(algo, a, b);
    (c, simulate_stats(algo, a, b, cfg))
}

/// Stats-only simulation of the hash engine at an explicit
/// [`EngineConfig`] — the threshold-calibration sweep's entry point:
/// it traces the same workload under a grid of SPA/bitmap thresholds,
/// which the default entry points cannot do (they run at the latched
/// process-wide config).
///
/// [`EngineConfig`]: crate::spgemm::hash::EngineConfig
pub fn simulate_stats_engine_cfg(
    a: &Csr,
    b: &Csr,
    cfg: &SimConfig,
    engine: &crate::spgemm::hash::EngineConfig,
) -> SimReport {
    let total_ip = ip::total_ip(a, b);
    let sample = cfg.sample.unwrap_or_else(|| auto_sample(total_ip));
    let mut machine = Machine::new(cfg.device.clone(), cfg.aia, sample);
    crate::spgemm::hash::engine::multiply_traced_stats_cfg(a, b, &mut machine, sample, engine);
    machine.finish()
}

/// Stats-only simulation (no product).
pub fn simulate_stats(algo: Algo, a: &Csr, b: &Csr, cfg: &SimConfig) -> SimReport {
    let aia = if algo == Algo::Esc { AiaMode::Off } else { cfg.aia };
    let total_ip = ip::total_ip(a, b);
    let sample = cfg.sample.unwrap_or_else(|| auto_sample(total_ip));
    let mut machine = Machine::new(cfg.device.clone(), aia, sample);
    match algo {
        Algo::Hash | Algo::Reference => {
            crate::spgemm::hash::engine::multiply_traced_stats(a, b, &mut machine, sample)
        }
        Algo::Esc => crate::spgemm::esc::multiply_traced_stats(a, b, &mut machine, sample),
    }
    machine.finish()
}

/// Full traced simulation (every block, functional output) — kept for
/// equivalence tests between the traced and stats paths.
pub fn simulate_spgemm_full(algo: Algo, a: &Csr, b: &Csr, cfg: &SimConfig) -> (Csr, SimReport) {
    let aia = if algo == Algo::Esc { AiaMode::Off } else { cfg.aia };
    let mut machine = Machine::new(cfg.device.clone(), aia, 1);
    let c = {
        let mut probe = SamplingProbe::new(&mut machine, 1);
        spgemm_traced(algo, a, b, &mut probe)
    };
    (c, machine.finish())
}

/// GFLOPS as the paper computes it: `2 · IP / time`.
pub fn gflops(total_ip: u64, time_ms: f64) -> f64 {
    if time_ms <= 0.0 {
        return 0.0;
    }
    2.0 * total_ip as f64 / (time_ms * 1e-3) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Pcg32;

    fn random_csr(rng: &mut Pcg32, n: usize, nnz: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.below_usize(n), rng.below_usize(n), rng.f64_range(0.1, 1.0));
        }
        coo.to_csr()
    }

    #[test]
    fn auto_sample_monotonic() {
        assert_eq!(auto_sample(1000), 1);
        assert!(auto_sample(3_000_000_000) > auto_sample(30_000_000));
        assert!(auto_sample(u64::MAX / 2) <= 4096);
    }

    #[test]
    fn simulated_product_is_exact() {
        let mut rng = Pcg32::seeded(42);
        let a = random_csr(&mut rng, 500, 5000);
        let cfg = SimConfig::new(AiaMode::On);
        let (c, report) = simulate_spgemm(Algo::Hash, &a, &a, &cfg);
        let r = crate::spgemm::reference::spgemm_reference(&a, &a);
        assert!(c.approx_eq(&r, 1e-10));
        assert!(report.total_ms > 0.0);
        assert!(report.phase(crate::sim::probe::Phase::Allocation).is_some());
    }

    #[test]
    fn esc_never_gets_aia() {
        let mut rng = Pcg32::seeded(43);
        let a = random_csr(&mut rng, 300, 3000);
        let cfg = SimConfig::new(AiaMode::On);
        let (_, report) = simulate_spgemm(Algo::Esc, &a, &a, &cfg);
        assert_eq!(report.aia, AiaMode::Off);
        assert!(report.phases.iter().all(|p| p.aia_requests == 0));
    }

    #[test]
    fn hash_with_aia_beats_without_on_irregular() {
        // Power-law matrix whose B-side working set exceeds the L2:
        // the AIA sweet spot. (On cache-resident toy matrices AIA is
        // correctly *not* a win — streaming bypasses cache reuse.)
        let mut rng = Pcg32::seeded(44);
        let a = crate::gen::rmat(40_000, 400_000, crate::gen::RmatParams::web(), &mut rng);
        let (_, off) = simulate_spgemm(Algo::Hash, &a, &a, &SimConfig::new(AiaMode::Off));
        let (_, on) = simulate_spgemm(Algo::Hash, &a, &a, &SimConfig::new(AiaMode::On));
        assert!(
            on.total_ms < off.total_ms,
            "AIA should help irregular workloads: on={} off={}",
            on.total_ms,
            off.total_ms
        );
    }

    #[test]
    fn hash_beats_esc_baseline() {
        let mut rng = Pcg32::seeded(45);
        let a = crate::gen::rmat(4096, 40_000, crate::gen::RmatParams::web(), &mut rng);
        let (_, hash) = simulate_spgemm(Algo::Hash, &a, &a, &SimConfig::new(AiaMode::Off));
        let (_, esc) = simulate_spgemm(Algo::Esc, &a, &a, &SimConfig::new(AiaMode::Off));
        assert!(
            hash.total_ms < esc.total_ms,
            "hash engine should beat ESC: hash={} esc={}",
            hash.total_ms,
            esc.total_ms
        );
    }

    #[test]
    fn gflops_formula() {
        assert!((gflops(1_000_000, 2.0) - 1.0).abs() < 1e-9);
        assert_eq!(gflops(100, 0.0), 0.0);
    }
}
