//! Instrumentation interface between the SpGEMM engines and the memory
//! simulator.
//!
//! Every engine phase is written against [`Probe`]: the fast functional
//! path passes [`NullProbe`] (all callbacks inline to nothing and the
//! optimizer erases them); the simulator passes a recording probe that
//! feeds the cache/HBM/AIA models (see `sim::machine`).
//!
//! The abstraction level is deliberately the one the paper's argument
//! lives at: **line-granular global-memory traffic in program order per
//! thread block**, shared-memory accesses as bank events, and the
//! two-level indirection pattern (`rpt_B[col]` → `col_B/val_B[lo..hi]`)
//! surfaced as a single semantic callback so the AIA model can rewrite it.

/// Logical arrays of the kernel working set. The simulator assigns each a
/// disjoint base address; `(region, index)` becomes a byte address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Region {
    RptA,
    ColA,
    ValA,
    RptB,
    ColB,
    ValB,
    RptC,
    ColC,
    ValC,
    /// Global-memory hash table keys (group 3 fallback).
    HashKeys,
    /// Global-memory hash table values (group 3 fallback).
    HashVals,
    /// Row id map (grouping phase output).
    Map,
    /// Intermediate-product counts.
    IpCount,
    /// Group counters updated with atomics in the grouping phase.
    GroupCtr,
    /// AIA stream buffer the engine deposits gathered data into
    /// (GPU-side reads of this are sequential).
    AiaStream,
    /// ESC baseline: expanded triple buffer.
    EscExpand,
    /// Dense-SPA accumulator values (plan-guided dense rows). Accesses
    /// are column-indexed into a contiguous per-row array, so the gather
    /// scan is sequential — SPA rows are priced as streaming and never
    /// go through the AIA engine (`indirect_range` is not emitted).
    SpaVals,
    /// Dense-SPA occupancy flags (one word per output column).
    SpaFlags,
    /// Output-mask row pointers (masked SpGEMM: C = M ⊙ (A·B)).
    MaskRpt,
    /// Output-mask column indices, streamed once per masked row into
    /// the per-row membership probe — sequential, AIA-ineligible.
    MaskCol,
}

impl Region {
    /// Every region, in the simulator's ordinal order (the order
    /// `sim::machine` assigns base addresses in). Waste reports index
    /// into this array.
    pub const ALL: [Region; 20] = [
        Region::RptA,
        Region::ColA,
        Region::ValA,
        Region::RptB,
        Region::ColB,
        Region::ValB,
        Region::RptC,
        Region::ColC,
        Region::ValC,
        Region::HashKeys,
        Region::HashVals,
        Region::Map,
        Region::IpCount,
        Region::GroupCtr,
        Region::AiaStream,
        Region::EscExpand,
        Region::SpaVals,
        Region::SpaFlags,
        Region::MaskRpt,
        Region::MaskCol,
    ];

    /// Stable lowercase name for waste tables, metrics keys, and JSON.
    pub fn name(&self) -> &'static str {
        match self {
            Region::RptA => "rpt_a",
            Region::ColA => "col_a",
            Region::ValA => "val_a",
            Region::RptB => "rpt_b",
            Region::ColB => "col_b",
            Region::ValB => "val_b",
            Region::RptC => "rpt_c",
            Region::ColC => "col_c",
            Region::ValC => "val_c",
            Region::HashKeys => "hash_keys",
            Region::HashVals => "hash_vals",
            Region::Map => "map",
            Region::IpCount => "ip_count",
            Region::GroupCtr => "group_ctr",
            Region::AiaStream => "aia_stream",
            Region::EscExpand => "esc_expand",
            Region::SpaVals => "spa_vals",
            Region::SpaFlags => "spa_flags",
            Region::MaskRpt => "mask_rpt",
            Region::MaskCol => "mask_col",
        }
    }
}

/// Kernel phases, for per-phase accounting (Fig. 5 reports per-phase L1
/// hit ratios).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Grouping,
    /// The symbolic phase (the paper calls it "allocation").
    Allocation,
    /// The numeric phase (the paper calls it "accumulation").
    Accumulation,
    /// ESC baseline phases share one bucket each.
    EscExpand,
    EscSort,
    EscCompress,
    Other,
}

impl Phase {
    /// Stable lowercase name for metrics keys and JSON emission.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Grouping => "grouping",
            Phase::Allocation => "symbolic",
            Phase::Accumulation => "numeric",
            Phase::EscExpand => "esc-expand",
            Phase::EscSort => "esc-sort",
            Phase::EscCompress => "esc-compress",
            Phase::Other => "other",
        }
    }
}

/// Wall-clock seconds per engine phase on the *functional* path (the
/// simulated path reports cycle-derived times through
/// [`crate::sim::PhaseReport`] instead). Produced by
/// `spgemm::hash::engine::multiply_timed` and by the plan-reuse layer
/// (`spgemm::hash::PlannedProduct` splits plan time from fill time),
/// accumulated by the coordinator's executor and metrics registry, and
/// emitted into `BENCH_*.json` by `util::bench`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimes {
    pub grouping_s: f64,
    pub symbolic_s: f64,
    pub numeric_s: f64,
    /// Symbolic seconds split by counting kernel, indexed by
    /// `spgemm::hash::SymbolicKind::index()` (trivial, hash, bitmap).
    /// Sums to at most `symbolic_s` (the remainder is the partitioning
    /// overhead outside the counting sub-bins); stays zero for callers
    /// that only time the whole phase.
    pub symbolic_kind_s: [f64; 3],
    /// Numeric seconds split by accumulator kind, indexed by
    /// `spgemm::hash::AccumKind::index()` (scaled-copy, hash, SPA).
    /// Sums to `numeric_s` for fills timed per bin, stays zero for
    /// callers that only time the whole phase.
    pub numeric_kind_s: [f64; 3],
}

impl PhaseTimes {
    pub fn total_s(&self) -> f64 {
        self.grouping_s + self.symbolic_s + self.numeric_s
    }

    /// Accumulate another measurement (for multi-job executors).
    pub fn accumulate(&mut self, o: &PhaseTimes) {
        self.grouping_s += o.grouping_s;
        self.symbolic_s += o.symbolic_s;
        self.numeric_s += o.numeric_s;
        for (k, v) in self.symbolic_kind_s.iter_mut().zip(o.symbolic_kind_s) {
            *k += v;
        }
        for (k, v) in self.numeric_kind_s.iter_mut().zip(o.numeric_kind_s) {
            *k += v;
        }
    }

    /// Machine-readable form for `BENCH_*.json` / metrics dumps.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("grouping_s", self.grouping_s.into());
        o.set("symbolic_s", self.symbolic_s.into());
        o.set("numeric_s", self.numeric_s.into());
        o.set("symbolic_trivial_s", self.symbolic_kind_s[0].into());
        o.set("symbolic_hash_s", self.symbolic_kind_s[1].into());
        o.set("symbolic_bitmap_s", self.symbolic_kind_s[2].into());
        o.set("numeric_copy_s", self.numeric_kind_s[0].into());
        o.set("numeric_hash_s", self.numeric_kind_s[1].into());
        o.set("numeric_spa_s", self.numeric_kind_s[2].into());
        o.set("total_s", self.total_s().into());
        o
    }
}

/// Access kinds (atomics cost extra and serialize under contention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Read,
    Write,
    /// atomicCAS / atomicAdd on global memory.
    Atomic,
}

/// Instrumentation callbacks. All methods have empty defaults so the
/// functional path compiles to nothing.
pub trait Probe {
    /// Simulated thread block `block` (used for SM assignment) starts
    /// executing `phase`.
    #[inline(always)]
    fn begin_block(&mut self, _block: usize, _phase: Phase) {}

    /// Global-memory access to `region[idx]` of `bytes` bytes.
    #[inline(always)]
    fn access(&mut self, _region: Region, _idx: usize, _bytes: u32, _kind: Kind) {}

    /// Shared-memory access to `word` (bank = word % 32). Hash-table
    /// probes in groups 0–2 land here, not in the cache hierarchy.
    #[inline(always)]
    fn shared(&mut self, _word: usize, _kind: Kind) {}

    /// `ops` ALU operations (hash computation, comparisons, FMA).
    #[inline(always)]
    fn compute(&mut self, _ops: u64) {}

    /// The SpGEMM two-level indirection: read `rpt[ptr_idx]` and
    /// `rpt[ptr_idx+1]`, then stream elements `lo..hi` of each region in
    /// `data` (col_B and usually val_B). The AIA engine model intercepts
    /// exactly this callback; the no-AIA model expands it to raw accesses.
    #[inline(always)]
    fn indirect_range(&mut self, _ptr: Region, _ptr_idx: usize, _data: &[Region], _lo: usize, _hi: usize) {}
}

/// Zero-cost probe for the functional fast path.
#[derive(Default, Clone, Copy)]
pub struct NullProbe;
impl Probe for NullProbe {}

/// Block-sampling wrapper: forwards events only for blocks where
/// `block % every == 0`, so huge workloads can be simulated from a
/// statistical sample (the machine model scales its counters back up by
/// `every`). `every = 1` forwards everything.
pub struct SamplingProbe<'a, P: Probe> {
    pub inner: &'a mut P,
    pub every: usize,
    active: bool,
}

impl<'a, P: Probe> SamplingProbe<'a, P> {
    pub fn new(inner: &'a mut P, every: usize) -> Self {
        SamplingProbe { inner, every: every.max(1), active: true }
    }
}

impl<P: Probe> Probe for SamplingProbe<'_, P> {
    #[inline]
    fn begin_block(&mut self, block: usize, phase: Phase) {
        self.active = block % self.every == 0;
        if self.active {
            self.inner.begin_block(block, phase);
        }
    }
    #[inline]
    fn access(&mut self, region: Region, idx: usize, bytes: u32, kind: Kind) {
        if self.active {
            self.inner.access(region, idx, bytes, kind);
        }
    }
    #[inline]
    fn shared(&mut self, word: usize, kind: Kind) {
        if self.active {
            self.inner.shared(word, kind);
        }
    }
    #[inline]
    fn compute(&mut self, ops: u64) {
        if self.active {
            self.inner.compute(ops);
        }
    }
    #[inline]
    fn indirect_range(&mut self, ptr: Region, ptr_idx: usize, data: &[Region], lo: usize, hi: usize) {
        if self.active {
            self.inner.indirect_range(ptr, ptr_idx, data, lo, hi);
        }
    }
}

/// Counting probe for unit tests: tallies events without simulating.
#[derive(Default, Debug)]
pub struct CountingProbe {
    pub blocks: usize,
    pub accesses: u64,
    pub atomic: u64,
    pub shared: u64,
    pub compute_ops: u64,
    pub indirect_ranges: u64,
    pub indirect_elems: u64,
}

impl Probe for CountingProbe {
    fn begin_block(&mut self, _block: usize, _phase: Phase) {
        self.blocks += 1;
    }
    fn access(&mut self, _r: Region, _i: usize, _b: u32, kind: Kind) {
        self.accesses += 1;
        if kind == Kind::Atomic {
            self.atomic += 1;
        }
    }
    fn shared(&mut self, _w: usize, _k: Kind) {
        self.shared += 1;
    }
    fn compute(&mut self, ops: u64) {
        self.compute_ops += ops;
    }
    fn indirect_range(&mut self, _p: Region, _pi: usize, _d: &[Region], lo: usize, hi: usize) {
        self.indirect_ranges += 1;
        self.indirect_elems += (hi - lo) as u64;
    }
}
