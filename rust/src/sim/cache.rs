//! Set-associative LRU cache model (used for per-SM L1s and the shared
//! L2). Line-granular, true-LRU via access timestamps.

/// Outcome of a cache access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheResult {
    Hit,
    Miss,
}

/// One set-associative cache level.
pub struct Cache {
    sets: usize,
    ways: usize,
    line_shift: u32,
    /// tags[set * ways + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// last-use stamp per way, for LRU.
    stamps: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(bytes: usize, ways: usize, line_bytes: usize) -> Cache {
        assert!(line_bytes.is_power_of_two());
        let lines = bytes / line_bytes;
        assert!(lines >= ways && lines % ways == 0, "cache geometry: {lines} lines, {ways} ways");
        let sets = lines / ways;
        Cache {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            tags: vec![u64::MAX; sets * ways],
            stamps: vec![0; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access the line containing `addr`; returns hit/miss and updates
    /// LRU state (allocate-on-miss, no distinction for writes:
    /// write-allocate, which matches GPU L1/L2 sector behaviour closely
    /// enough for ratio accounting).
    pub fn access(&mut self, addr: u64) -> CacheResult {
        self.access_evicting(addr).0
    }

    /// Like [`Cache::access`], but also reports the *line number* a miss
    /// evicted (`None` on hits and on cold fills into an invalid way).
    /// The byte-utilization tracker flushes the victim's touched spans
    /// into its aggregates at this point (see `sim::ranges`), which is
    /// what keeps its live-line map bounded by the cache footprint.
    pub fn access_evicting(&mut self, addr: u64) -> (CacheResult, Option<u64>) {
        self.clock += 1;
        let line = addr >> self.line_shift;
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        // hit?
        for w in 0..self.ways {
            if self.tags[base + w] == line {
                self.stamps[base + w] = self.clock;
                self.hits += 1;
                return (CacheResult::Hit, None);
            }
        }
        // miss: evict LRU way
        self.misses += 1;
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for w in 0..self.ways {
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if self.stamps[base + w] < oldest {
                oldest = self.stamps[base + w];
                victim = w;
            }
        }
        let evicted = if self.tags[base + victim] == u64::MAX { None } else { Some(self.tags[base + victim]) };
        self.tags[base + victim] = line;
        self.stamps[base + victim] = self.clock;
        (CacheResult::Miss, evicted)
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_within_line_hits() {
        let mut c = Cache::new(1024, 4, 64);
        assert_eq!(c.access(0), CacheResult::Miss);
        assert_eq!(c.access(4), CacheResult::Hit);
        assert_eq!(c.access(63), CacheResult::Hit);
        assert_eq!(c.access(64), CacheResult::Miss);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_order() {
        // 2-way, line 64, 2 sets => set stride 128
        let mut c = Cache::new(256, 2, 64);
        // set 0 lines: addr 0, 128, 256 (tags 0,2,4)
        assert_eq!(c.access(0), CacheResult::Miss);
        assert_eq!(c.access(128), CacheResult::Miss);
        assert_eq!(c.access(0), CacheResult::Hit); // refresh line 0
        assert_eq!(c.access(256), CacheResult::Miss); // evicts line 128 (LRU)
        assert_eq!(c.access(0), CacheResult::Hit);
        assert_eq!(c.access(128), CacheResult::Miss); // was evicted
    }

    #[test]
    fn working_set_within_capacity_all_hits_on_second_pass() {
        let mut c = Cache::new(8192, 8, 64);
        for addr in (0..8192u64).step_by(64) {
            c.access(addr);
        }
        c.reset_counters();
        for addr in (0..8192u64).step_by(64) {
            assert_eq!(c.access(addr), CacheResult::Hit);
        }
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn access_evicting_reports_victim_line() {
        // 2-way, line 64, 2 sets => set-0 tags 0, 2, 4
        let mut c = Cache::new(256, 2, 64);
        assert_eq!(c.access_evicting(0), (CacheResult::Miss, None)); // cold fill
        assert_eq!(c.access_evicting(128), (CacheResult::Miss, None)); // cold fill
        assert_eq!(c.access_evicting(0), (CacheResult::Hit, None));
        // set full: line 128 (tag 2) is LRU, its eviction is surfaced
        assert_eq!(c.access_evicting(256), (CacheResult::Miss, Some(2)));
    }

    #[test]
    fn capacity_thrash_misses() {
        let mut c = Cache::new(1024, 2, 64);
        // stream 4x capacity twice: second pass still mostly misses
        for _ in 0..2 {
            for addr in (0..4096u64).step_by(64) {
                c.access(addr);
            }
        }
        assert!(c.misses > c.hits);
    }
}
