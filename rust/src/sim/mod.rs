//! GPU + HBM + AIA memory-system simulator (paper §IV).
//!
//! - `probe` — the instrumentation interface the SpGEMM engines emit
//!   events through (with `NullProbe` for the functional fast path and
//!   `SamplingProbe` for statistical decimation of huge traces);
//! - `cache` — set-associative LRU model (per-SM L1s, shared L2);
//! - `gpu` — the H200-class `DeviceConfig` and `AiaMode`;
//! - `machine` — the recording probe: cache hierarchy + HBM bandwidth +
//!   per-stack AIA engines + the analytic SM timing model;
//! - `ranges` — byte-accurate line-utilization accounting (coalescing
//!   interval sets per live line, flushed at eviction into per-region ×
//!   per-phase used/fetched aggregates);
//! - `run` — one-call `simulate_spgemm` producing a `SimReport`.

pub mod cache;
pub mod gpu;
pub mod machine;
pub mod probe;
pub mod ranges;
pub mod run;

pub use gpu::{AiaMode, DeviceConfig};
pub use machine::{Machine, PhaseReport, RegionWaste, SimReport};
pub use ranges::{LineUseTracker, RangeSet};
pub use run::{
    auto_sample, gflops, simulate_spgemm, simulate_spgemm_full, simulate_stats, simulate_stats_engine_cfg, SimConfig,
};
