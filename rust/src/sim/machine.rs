//! The machine model: consumes the engines' probe events and produces
//! per-phase cache statistics and runtime estimates (paper Figs. 5–6).
//!
//! Implements [`Probe`]. Per-SM L1 caches + shared L2 + HBM bandwidth,
//! an analytic SM timing model (compute / latency / bandwidth pipes with
//! warp latency-hiding, atomic serialization, shared-memory bank
//! throughput), and the AIA engine model that rewrites the two-level
//! indirection (§IV-C):
//!
//! - **AIA off** — `indirect_range` expands to the raw accesses: two
//!   `rpt_B` reads at a data-dependent index plus an element-granular
//!   walk of `col_B`/`val_B[lo..hi)`, all through the cache hierarchy.
//!   Short scattered rows waste cache lines, exactly the pathology the
//!   paper describes.
//! - **AIA on** — the GPU writes one descriptor and then reads the
//!   gathered elements from a *sequential* stream buffer (near-perfect
//!   line utilization → the Fig. 5 L1 improvement emerges from the cache
//!   model, it is not hard-coded). The stack-local lookups are charged
//!   to the per-stack AIA engines at their own throughput; whichever of
//!   GPU or engine pipe is slower bounds the phase.

use super::cache::{Cache, CacheResult};
use super::gpu::{AiaMode, DeviceConfig};
use super::probe::{Kind, Phase, Probe, Region};
use super::ranges::LineUseTracker;

/// All phases we account separately, in report order. `Other` gets its
/// own slot so waste/traffic attribution can't silently mislabel stray
/// events as ESC work.
pub const PHASES: [Phase; 7] = [
    Phase::Grouping,
    Phase::Allocation,
    Phase::Accumulation,
    Phase::EscExpand,
    Phase::EscSort,
    Phase::EscCompress,
    Phase::Other,
];

fn phase_slot(p: Phase) -> usize {
    match p {
        Phase::Grouping => 0,
        Phase::Allocation => 1,
        Phase::Accumulation => 2,
        Phase::EscExpand => 3,
        Phase::EscSort => 4,
        Phase::EscCompress => 5,
        Phase::Other => 6,
    }
}

fn region_ordinal(r: Region) -> u64 {
    match r {
        Region::RptA => 0,
        Region::ColA => 1,
        Region::ValA => 2,
        Region::RptB => 3,
        Region::ColB => 4,
        Region::ValB => 5,
        Region::RptC => 6,
        Region::ColC => 7,
        Region::ValC => 8,
        Region::HashKeys => 9,
        Region::HashVals => 10,
        Region::Map => 11,
        Region::IpCount => 12,
        Region::GroupCtr => 13,
        Region::AiaStream => 14,
        Region::EscExpand => 15,
        Region::SpaVals => 16,
        Region::SpaFlags => 17,
        Region::MaskRpt => 18,
        Region::MaskCol => 19,
    }
}

#[inline]
fn region_base(r: Region) -> u64 {
    region_ordinal(r) << 36 // 64 GiB apart: regions never alias
}

/// Bytes per element of the data regions streamed by `indirect_range`.
fn data_elem_bytes(r: Region) -> u64 {
    match r {
        Region::ColB | Region::ColA | Region::ColC | Region::RptA | Region::RptB | Region::RptC | Region::Map => 4,
        Region::GroupCtr | Region::HashKeys | Region::SpaFlags | Region::MaskRpt | Region::MaskCol => 4,
        Region::ValA | Region::ValB | Region::ValC | Region::IpCount | Region::HashVals | Region::SpaVals => 8,
        Region::AiaStream | Region::EscExpand => 16,
    }
}

/// Per-SM, per-phase raw counters.
#[derive(Clone, Copy, Default)]
struct SmCounters {
    ops: u64,
    l1_hits: u64,
    l2_hits: u64,
    misses: u64,
    stream_misses: u64,
    atomics: u64,
    shared: u64,
    /// Latency cycles of dependent pointer-chase loads (serialized; see
    /// DeviceConfig::mlp_dep).
    dep_cycles: u64,
}

/// Per-phase aggregate counters (sampled; scale-up happens in `finish`).
#[derive(Clone, Default)]
struct PhaseCounters {
    sm: Vec<SmCounters>,
    hbm_bytes: u64,
    aia_reqs_per_stack: Vec<u64>,
    aia_elems_per_stack: Vec<u64>,
    aia_bytes: u64,
    touched: bool,
}

/// The recording machine. Feed it through [`crate::sim::probe::SamplingProbe`]
/// when the workload is large; pass the same `sample` here so counters
/// scale back up.
pub struct Machine {
    dev: DeviceConfig,
    aia: AiaMode,
    /// Block-sampling factor the probe stream was decimated by.
    pub sample: usize,
    l1: Vec<Cache>,
    l2: Cache,
    phases: Vec<PhaseCounters>,
    cur_phase: usize,
    cur_sm: usize,
    sampled_blocks: u64,
    /// Rolling cursor for the AIA stream buffer (ring).
    stream_cursor: u64,
    /// Per-block hash-table address salt (fresh table per block).
    hash_salt: u64,
    /// Byte-accurate line utilization, per region × phase (see
    /// `sim::ranges`): which bytes of each fetched line were touched.
    waste: LineUseTracker,
}

impl Machine {
    pub fn new(dev: DeviceConfig, aia: AiaMode, sample: usize) -> Machine {
        // Occupancy dilation (see DeviceConfig::l1_occupancy_div), clamped
        // to valid set-associative geometry.
        let eff = |bytes: usize, div: usize, ways: usize| -> usize {
            let min = ways * dev.line_bytes;
            let b = (bytes / div.max(1)).max(min);
            1usize << (usize::BITS - 1 - b.leading_zeros())
        };
        let l1_bytes = eff(dev.l1_bytes, dev.l1_occupancy_div, dev.l1_ways);
        let l2_bytes = eff(dev.l2_bytes, dev.l2_occupancy_div, dev.l2_ways);
        let l1 = (0..dev.sms).map(|_| Cache::new(l1_bytes, dev.l1_ways, dev.line_bytes)).collect();
        let l2 = Cache::new(l2_bytes, dev.l2_ways, dev.line_bytes);
        let mk = || PhaseCounters {
            sm: vec![SmCounters::default(); dev.sms],
            hbm_bytes: 0,
            aia_reqs_per_stack: vec![0; dev.hbm_stacks],
            aia_elems_per_stack: vec![0; dev.hbm_stacks],
            aia_bytes: 0,
            touched: false,
        };
        Machine {
            l1,
            l2,
            phases: (0..PHASES.len()).map(|_| mk()).collect(),
            cur_phase: 0,
            cur_sm: 0,
            sampled_blocks: 0,
            stream_cursor: 0,
            hash_salt: 0,
            waste: LineUseTracker::new(dev.line_bytes, Region::ALL.len(), PHASES.len()),
            dev,
            aia,
            sample: sample.max(1),
        }
    }

    /// Returns the service level (L1/L2/HBM latency in cycles) so
    /// callers can charge dependent-load serialization. An access that
    /// straddles a line boundary (e.g. a 16-byte stream element at
    /// `line_bytes - 8`) is split into one touch per line, so miss
    /// counts and byte accounting stay exact; the split legs overlap in
    /// the memory pipeline, so the charged latency is the max, and an
    /// atomic is still one atomic. `region` attributes the fetched line
    /// for waste accounting (deriving it from the address would be
    /// ambiguous: salted hash-table offsets overflow their 64 GiB base
    /// spans).
    #[inline]
    fn raw_access(&mut self, region: Region, addr: u64, bytes: u64, kind: Kind, stream: bool) -> f64 {
        let lb = self.dev.line_bytes as u64;
        let bytes = bytes.max(1);
        let first = addr / lb;
        let last = (addr + bytes - 1) / lb;
        let mut lat: f64 = 0.0;
        for line in first..=last {
            let lo = addr.max(line * lb) - line * lb;
            let hi = (addr + bytes).min((line + 1) * lb) - line * lb;
            lat = lat.max(self.line_access(region, line, lo as u32, hi as u32, stream));
        }
        if kind == Kind::Atomic {
            self.phases[self.cur_phase].sm[self.cur_sm].atomics += 1;
        }
        lat
    }

    /// One line-granular touch of `[lo, hi)` within `line`, through the
    /// cache hierarchy. L2 misses open a live waste-tracker entry for
    /// the fetching `(region, phase)`; L2 evictions flush the victim's
    /// spans so the tracker stays bounded by the cache footprint.
    fn line_access(&mut self, region: Region, line: u64, lo: u32, hi: u32, stream: bool) -> f64 {
        let addr = line * self.dev.line_bytes as u64;
        match self.l1[self.cur_sm].access(addr) {
            CacheResult::Hit => {
                self.phases[self.cur_phase].sm[self.cur_sm].l1_hits += 1;
                self.waste.touch(line, lo, hi);
                self.dev.l1_lat
            }
            CacheResult::Miss => {
                let (res, evicted) = self.l2.access_evicting(addr);
                if let Some(victim) = evicted {
                    self.waste.evict(victim);
                }
                match res {
                    CacheResult::Hit => {
                        self.phases[self.cur_phase].sm[self.cur_sm].l2_hits += 1;
                        self.waste.touch(line, lo, hi);
                        self.dev.l2_lat
                    }
                    CacheResult::Miss => {
                        let pc = &mut self.phases[self.cur_phase];
                        let sm = &mut pc.sm[self.cur_sm];
                        if stream {
                            sm.stream_misses += 1;
                        } else {
                            sm.misses += 1;
                        }
                        pc.hbm_bytes += self.dev.line_bytes as u64;
                        self.waste.fetch(line, region_ordinal(region) as usize, self.cur_phase, lo, hi);
                        self.dev.hbm_lat
                    }
                }
            }
        }
    }

    /// Finalize into a report.
    pub fn finish(mut self) -> SimReport {
        // Fold still-resident lines' touched spans into the aggregates
        // before reading them out.
        self.waste.flush();
        let dev = &self.dev;
        let mut phases = Vec::new();
        let mut total_ms = 0.0;
        for (slot, phase) in PHASES.iter().enumerate() {
            let pc = &self.phases[slot];
            if !pc.touched {
                continue;
            }
            let mut l1h = 0u64;
            let mut l2h = 0u64;
            let mut miss = 0u64;
            let mut streamm = 0u64;
            let mut atomics = 0u64;
            let mut shared = 0u64;
            let mut ops = 0u64;
            let mut max_sm_cycles: f64 = 0.0;
            for sm in &pc.sm {
                l1h += sm.l1_hits;
                l2h += sm.l2_hits;
                miss += sm.misses;
                streamm += sm.stream_misses;
                atomics += sm.atomics;
                shared += sm.shared;
                ops += sm.ops;
                let compute = sm.ops as f64 / dev.ipc_per_sm
                    + sm.shared as f64 * dev.bank_conflict_factor / dev.shared_words_per_cycle;
                let latency = (sm.l1_hits as f64 * dev.l1_lat
                    + sm.l2_hits as f64 * dev.l2_lat
                    + sm.misses as f64 * dev.hbm_lat
                    + sm.stream_misses as f64 * dev.l2_lat)
                    / dev.mlp
                    + sm.dep_cycles as f64 / dev.mlp_dep;
                let atomic = sm.atomics as f64 * dev.atomic_cost / 32.0;
                max_sm_cycles = max_sm_cycles.max(compute.max(latency) + atomic);
            }
            let bw_cycles = pc.hbm_bytes as f64 / dev.hbm_bytes_per_cycle();
            let gpu_cycles = max_sm_cycles.max(bw_cycles);
            let mut aia_cycles: f64 = 0.0;
            let mut aia_reqs = 0u64;
            let mut aia_elems = 0u64;
            for s in 0..dev.hbm_stacks {
                let c = pc.aia_reqs_per_stack[s] as f64 * dev.aia_req_overhead
                    + pc.aia_elems_per_stack[s] as f64 / dev.aia_elems_per_cycle;
                // convert engine cycles to SM cycles
                aia_cycles = aia_cycles.max(c * dev.clock_ghz / dev.aia_clock_ghz);
                aia_reqs += pc.aia_reqs_per_stack[s];
                aia_elems += pc.aia_elems_per_stack[s];
            }
            let cycles = gpu_cycles.max(aia_cycles) * self.sample as f64;
            let time_ms = cycles / (dev.clock_ghz * 1e9) * 1e3;
            total_ms += time_ms;
            let gl_total = l1h + l2h + miss + streamm;
            let mut regions = Vec::new();
            let mut used_bytes = 0u64;
            let mut fetched_bytes = 0u64;
            for (ri, &region) in Region::ALL.iter().enumerate() {
                let used = self.waste.used(ri, slot) * self.sample as u64;
                let fetched = self.waste.fetched(ri, slot) * self.sample as u64;
                if used == 0 && fetched == 0 {
                    continue;
                }
                used_bytes += used;
                fetched_bytes += fetched;
                regions.push(RegionWaste { region, used_bytes: used, fetched_bytes: fetched });
            }
            phases.push(PhaseReport {
                phase: *phase,
                time_ms,
                l1_hit_ratio: if gl_total == 0 { 0.0 } else { l1h as f64 / gl_total as f64 },
                l2_hit_ratio: if gl_total == l1h { 0.0 } else { l2h as f64 / (gl_total - l1h) as f64 },
                accesses: gl_total * self.sample as u64,
                hbm_bytes: pc.hbm_bytes * self.sample as u64,
                atomics: atomics * self.sample as u64,
                shared: shared * self.sample as u64,
                ops: ops * self.sample as u64,
                aia_requests: aia_reqs * self.sample as u64,
                aia_elems: aia_elems * self.sample as u64,
                aia_bound: aia_cycles > gpu_cycles,
                used_bytes,
                fetched_bytes,
                regions,
            });
        }
        SimReport { aia: self.aia, sample: self.sample, phases, total_ms }
    }
}

impl Probe for Machine {
    fn begin_block(&mut self, _block: usize, phase: Phase) {
        self.cur_phase = phase_slot(phase);
        self.phases[self.cur_phase].touched = true;
        // Sampled blocks fill SMs round-robin so per-SM load stays even
        // under sampling.
        self.cur_sm = (self.sampled_blocks % self.dev.sms as u64) as usize;
        self.sampled_blocks += 1;
        // Fresh hash-table allocation per block (group-3 tables).
        self.hash_salt = self.sampled_blocks << 24;
    }

    fn access(&mut self, region: Region, idx: usize, bytes: u32, kind: Kind) {
        // Hash tables and the dense row kernels (numeric SPA values and
        // the flag words shared by the SPA and the symbolic bitmap
        // counter) are per-block global-memory allocations: salt them
        // so distinct blocks never alias. Dense-kernel rows reach here
        // only through plain `access` events — the engines never emit
        // `indirect_range` for them, which is what keeps bitmap/SPA
        // rows AIA-ineligible (streaming-priced) by construction.
        let salt = if matches!(region, Region::HashKeys | Region::HashVals | Region::SpaVals | Region::SpaFlags) {
            self.hash_salt
        } else {
            0
        };
        let addr = region_base(region) + (salt + idx as u64) * bytes as u64;
        self.raw_access(region, addr, bytes as u64, kind, false);
    }

    fn shared(&mut self, _word: usize, kind: Kind) {
        let pc = &mut self.phases[self.cur_phase];
        let sm = &mut pc.sm[self.cur_sm];
        sm.shared += 1;
        if kind == Kind::Atomic {
            // Shared-memory atomics contend on banks, cheaper than global;
            // fold into the shared counter with a second event.
            sm.shared += 1;
        }
    }

    fn compute(&mut self, ops: u64) {
        let pc = &mut self.phases[self.cur_phase];
        pc.sm[self.cur_sm].ops += ops;
    }

    fn indirect_range(&mut self, ptr: Region, ptr_idx: usize, data: &[Region], lo: usize, hi: usize) {
        match self.aia {
            AiaMode::Off => {
                // Raw two-level indirection through the cache hierarchy.
                // The pointer lookup is a *dependent* load: its full
                // latency serializes before the range loads can issue
                // (the 2N round trips of Fig. 2) — charge it to the
                // low-MLP dependent pipe.
                let pbytes = data_elem_bytes(ptr);
                let pbase = region_base(ptr);
                let lat = self.raw_access(ptr, pbase + ptr_idx as u64 * pbytes, pbytes, Kind::Read, false);
                self.raw_access(ptr, pbase + (ptr_idx as u64 + 1) * pbytes, pbytes, Kind::Read, false);
                self.phases[self.cur_phase].sm[self.cur_sm].dep_cycles += lat as u64;
                for &r in data {
                    let eb = data_elem_bytes(r);
                    let base = region_base(r);
                    for k in lo..hi {
                        self.raw_access(r, base + k as u64 * eb, eb, Kind::Read, false);
                    }
                }
                self.phases[self.cur_phase].sm[self.cur_sm].ops += 2 + (hi - lo) as u64;
            }
            AiaMode::On => {
                // One descriptor write...
                let desc_addr = region_base(Region::AiaStream) + (self.stream_cursor & 0x3F_FFFF);
                self.raw_access(Region::AiaStream, desc_addr, 16, Kind::Write, true);
                // ...engine-side gather, charged per stack. B rows spread
                // over stacks at 4 KiB granularity; bounds-only requests
                // (no data regions) hash on the pointer index instead so
                // they also spread across stacks.
                let granule = if data.is_empty() { ptr_idx as u64 * 4 } else { lo as u64 * 4 };
                let stack = (granule >> 12) as usize % self.dev.hbm_stacks;
                let elems: u64 = data.iter().map(|_| (hi - lo) as u64).sum::<u64>() + 2;
                let bytes: u64 = data.iter().map(|&r| data_elem_bytes(r) * (hi - lo) as u64).sum::<u64>() + 8;
                {
                    let pc = &mut self.phases[self.cur_phase];
                    pc.aia_reqs_per_stack[stack] += 1;
                    pc.aia_elems_per_stack[stack] += elems;
                    pc.aia_bytes += bytes;
                }
                // ...and a sequential GPU-side read of the gathered stream,
                // element-granular so Fig-5 hit ratios compare like for
                // like with the AIA-off trace.
                let sbase = region_base(Region::AiaStream);
                let ring = 8u64 << 20;
                // bounds (the two rpt values)
                for _ in 0..2 {
                    let a = sbase + (self.stream_cursor % ring);
                    self.raw_access(Region::AiaStream, a, 4, Kind::Read, true);
                    self.stream_cursor += 4;
                }
                for &r in data {
                    let eb = data_elem_bytes(r);
                    for _ in lo..hi {
                        let a = sbase + (self.stream_cursor % ring);
                        self.raw_access(Region::AiaStream, a, eb, Kind::Read, true);
                        self.stream_cursor += eb;
                    }
                }
                self.phases[self.cur_phase].sm[self.cur_sm].ops += 2 + (hi - lo) as u64;
            }
        }
    }
}

/// Byte-utilization accounting for one region within one phase: how
/// many bytes HBM delivered on the region's behalf vs how many were
/// actually touched while resident. The paper's cache-line waste is
/// `1 - used/fetched`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegionWaste {
    pub region: Region,
    pub used_bytes: u64,
    pub fetched_bytes: u64,
}

impl RegionWaste {
    /// Fraction of fetched bytes actually touched (0 when nothing was
    /// fetched).
    pub fn utilization(&self) -> f64 {
        if self.fetched_bytes == 0 {
            0.0
        } else {
            self.used_bytes as f64 / self.fetched_bytes as f64
        }
    }

    /// Fraction of fetched bytes never touched.
    pub fn waste_ratio(&self) -> f64 {
        if self.fetched_bytes == 0 {
            0.0
        } else {
            1.0 - self.utilization()
        }
    }
}

/// Per-phase simulation results.
#[derive(Clone, Debug)]
pub struct PhaseReport {
    pub phase: Phase,
    pub time_ms: f64,
    pub l1_hit_ratio: f64,
    pub l2_hit_ratio: f64,
    pub accesses: u64,
    pub hbm_bytes: u64,
    pub atomics: u64,
    pub shared: u64,
    pub ops: u64,
    pub aia_requests: u64,
    pub aia_elems: u64,
    /// True when the AIA engine, not the GPU, bounded this phase.
    pub aia_bound: bool,
    /// Bytes of fetched lines actually touched during this phase
    /// (attributed to the phase that triggered the fetch).
    pub used_bytes: u64,
    /// Bytes fetched from HBM during this phase — equals `hbm_bytes` by
    /// construction (both count whole lines at fetch time).
    pub fetched_bytes: u64,
    /// Per-region breakdown, in `Region::ALL` order; regions with no
    /// traffic are omitted. Sums to `used_bytes`/`fetched_bytes`.
    pub regions: Vec<RegionWaste>,
}

impl PhaseReport {
    /// Fraction of this phase's fetched HBM bytes never touched.
    pub fn waste_ratio(&self) -> f64 {
        if self.fetched_bytes == 0 {
            0.0
        } else {
            1.0 - self.used_bytes as f64 / self.fetched_bytes as f64
        }
    }
}

/// Whole-run simulation report.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub aia: AiaMode,
    pub sample: usize,
    pub phases: Vec<PhaseReport>,
    pub total_ms: f64,
}

impl SimReport {
    pub fn phase(&self, p: Phase) -> Option<&PhaseReport> {
        self.phases.iter().find(|r| r.phase == p)
    }

    /// Weighted overall L1 hit ratio.
    pub fn l1_hit_ratio(&self) -> f64 {
        let total: u64 = self.phases.iter().map(|p| p.accesses).sum();
        if total == 0 {
            return 0.0;
        }
        self.phases.iter().map(|p| p.l1_hit_ratio * p.accesses as f64).sum::<f64>() / total as f64
    }

    /// Total touched bytes of fetched lines, across all phases.
    pub fn used_bytes(&self) -> u64 {
        self.phases.iter().map(|p| p.used_bytes).sum()
    }

    /// Total bytes fetched from HBM, across all phases.
    pub fn fetched_bytes(&self) -> u64 {
        self.phases.iter().map(|p| p.fetched_bytes).sum()
    }

    /// Overall fraction of fetched HBM bytes never touched — the
    /// paper's central waste quantity.
    pub fn waste_ratio(&self) -> f64 {
        let fetched = self.fetched_bytes();
        if fetched == 0 {
            0.0
        } else {
            1.0 - self.used_bytes() as f64 / fetched as f64
        }
    }

    /// Per-region waste aggregated across phases, in `Region::ALL`
    /// order; regions with no traffic are omitted.
    pub fn region_waste(&self) -> Vec<RegionWaste> {
        let mut out: Vec<RegionWaste> = Vec::new();
        for p in &self.phases {
            for rw in &p.regions {
                match out.iter_mut().find(|x| x.region == rw.region) {
                    Some(x) => {
                        x.used_bytes += rw.used_bytes;
                        x.fetched_bytes += rw.fetched_bytes;
                    }
                    None => out.push(rw.clone()),
                }
            }
        }
        out.sort_by_key(|rw| Region::ALL.iter().position(|&r| r == rw.region));
        out
    }

    /// Cross-phase utilization of one region's fetched lines, `None` if
    /// the region was never fetched from HBM.
    pub fn region_utilization(&self, region: Region) -> Option<f64> {
        let rw = self.region_waste().into_iter().find(|x| x.region == region)?;
        if rw.fetched_bytes == 0 {
            None
        } else {
            Some(rw.utilization())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::probe::Probe;

    fn dev() -> DeviceConfig {
        DeviceConfig::h200_scaled()
    }

    #[test]
    fn sequential_reads_hit_l1() {
        let mut m = Machine::new(dev(), AiaMode::Off, 1);
        m.begin_block(0, Phase::Allocation);
        for i in 0..1000 {
            m.access(Region::ColA, i, 4, Kind::Read);
        }
        let r = m.finish();
        let p = r.phase(Phase::Allocation).unwrap();
        // 4-byte elements, 32-byte sectors: 7/8 hits
        assert!(p.l1_hit_ratio > 0.85, "ratio={}", p.l1_hit_ratio);
    }

    #[test]
    fn random_reads_miss() {
        let mut m = Machine::new(dev(), AiaMode::Off, 1);
        m.begin_block(0, Phase::Allocation);
        let mut x = 12345u64;
        for _ in 0..5000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            m.access(Region::ColB, (x % 50_000_000) as usize, 4, Kind::Read);
        }
        let r = m.finish();
        assert!(r.phase(Phase::Allocation).unwrap().l1_hit_ratio < 0.2);
    }

    #[test]
    fn aia_converts_scatter_to_stream_hits() {
        // Scattered short ranged-indirect accesses: AIA-on should produce a
        // much higher L1 hit ratio than AIA-off.
        let run = |mode: AiaMode| -> f64 {
            let mut m = Machine::new(dev(), mode, 1);
            m.begin_block(0, Phase::Allocation);
            let mut x = 99u64;
            for _ in 0..3000 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(7);
                let lo = (x % 10_000_000) as usize;
                m.indirect_range(Region::RptB, lo % 1_000_000, &[Region::ColB], lo, lo + 4);
            }
            m.finish().phase(Phase::Allocation).unwrap().l1_hit_ratio
        };
        let off = run(AiaMode::Off);
        let on = run(AiaMode::On);
        assert!(on > off + 0.15, "AIA on={on} off={off}");
    }

    #[test]
    fn aia_reduces_time_for_irregular_access() {
        let run = |mode: AiaMode| -> f64 {
            let mut m = Machine::new(dev(), mode, 1);
            m.begin_block(0, Phase::Accumulation);
            let mut x = 5u64;
            for _ in 0..20_000 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(3);
                let lo = (x % 40_000_000) as usize;
                m.indirect_range(Region::RptB, lo % 4_000_000, &[Region::ColB, Region::ValB], lo, lo + 3);
            }
            m.finish().total_ms
        };
        let off = run(AiaMode::Off);
        let on = run(AiaMode::On);
        assert!(on < off, "AIA on={on} off={off}");
    }

    #[test]
    fn sample_scales_counters() {
        let mut m1 = Machine::new(dev(), AiaMode::Off, 1);
        m1.begin_block(0, Phase::Grouping);
        for i in 0..100 {
            m1.access(Region::ColA, i * 64, 4, Kind::Read);
        }
        let r1 = m1.finish();
        let mut m4 = Machine::new(dev(), AiaMode::Off, 4);
        m4.begin_block(0, Phase::Grouping);
        for i in 0..100 {
            m4.access(Region::ColA, i * 64, 4, Kind::Read);
        }
        let r4 = m4.finish();
        assert_eq!(r4.phase(Phase::Grouping).unwrap().accesses, 4 * r1.phase(Phase::Grouping).unwrap().accesses);
        assert!((r4.total_ms / r1.total_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn atomics_add_time() {
        let run = |atomic: bool| {
            let mut m = Machine::new(dev(), AiaMode::Off, 1);
            m.begin_block(0, Phase::Grouping);
            for i in 0..10_000 {
                m.access(Region::GroupCtr, i % 4, 4, if atomic { Kind::Atomic } else { Kind::Read });
            }
            m.finish().total_ms
        };
        assert!(run(true) > run(false));
    }

    #[test]
    fn blocks_round_robin_across_sms() {
        let mut m = Machine::new(dev(), AiaMode::Off, 1);
        for b in 0..200 {
            m.begin_block(b, Phase::Allocation);
            m.access(Region::ColA, b * 1000, 4, Kind::Read);
        }
        assert_eq!(m.sampled_blocks, 200);
        let r = m.finish();
        assert!(r.phase(Phase::Allocation).is_some());
    }

    #[test]
    fn region_all_matches_simulator_ordinals() {
        for (i, &r) in Region::ALL.iter().enumerate() {
            assert_eq!(region_ordinal(r), i as u64, "Region::ALL[{i}] = {r:?}");
        }
    }

    #[test]
    fn straddling_access_touches_both_lines() {
        // Regression (satellite): an 8-byte read starting at
        // line_bytes - 4 crosses the line boundary and must count one
        // touch per line, fetch both lines, and use exactly 8 bytes.
        let d = dev();
        let lb = d.line_bytes as u64;
        let mut m = Machine::new(d, AiaMode::Off, 1);
        m.begin_block(0, Phase::Allocation);
        m.raw_access(Region::ColA, lb - 4, 8, Kind::Read, false);
        let r = m.finish();
        let p = r.phase(Phase::Allocation).unwrap();
        assert_eq!(p.accesses, 2);
        assert_eq!(p.hbm_bytes, 2 * lb);
        assert_eq!(p.fetched_bytes, 2 * lb);
        assert_eq!(p.used_bytes, 8);
    }

    #[test]
    fn straddling_atomic_counts_once() {
        let d = dev();
        let lb = d.line_bytes as u64;
        let mut m = Machine::new(d, AiaMode::Off, 1);
        m.begin_block(0, Phase::Grouping);
        m.raw_access(Region::GroupCtr, lb - 4, 8, Kind::Atomic, false);
        let r = m.finish();
        assert_eq!(r.phase(Phase::Grouping).unwrap().atomics, 1);
    }

    #[test]
    fn dense_scan_reports_full_utilization() {
        // A dense sequential 8-byte-element scan touches every byte of
        // every fetched line.
        let mut m = Machine::new(dev(), AiaMode::Off, 1);
        m.begin_block(0, Phase::Accumulation);
        for i in 0..4096 {
            m.access(Region::ValA, i, 8, Kind::Read);
        }
        let r = m.finish();
        let p = r.phase(Phase::Accumulation).unwrap();
        assert!(p.fetched_bytes > 0);
        let util = p.used_bytes as f64 / p.fetched_bytes as f64;
        assert!(util > 0.99, "util={util}");
    }

    #[test]
    fn strided_scan_reports_waste() {
        // 4-byte reads at a 256-byte stride: each fetched line carries
        // elem/line useful bytes — 4/32 on the default sectored device,
        // 1/64 on a 256-byte-line device.
        let run = |d: DeviceConfig| -> f64 {
            let lb = d.line_bytes as f64;
            let mut m = Machine::new(d, AiaMode::Off, 1);
            m.begin_block(0, Phase::Accumulation);
            for i in 0..2000 {
                // idx is in 4-byte elements: stride 64 elems = 256 bytes
                m.access(Region::ColB, i * 64, 4, Kind::Read);
            }
            let r = m.finish();
            let p = r.phase(Phase::Accumulation).unwrap();
            let util = p.used_bytes as f64 / p.fetched_bytes as f64;
            assert!(p.used_bytes <= p.fetched_bytes);
            assert!((util - 4.0 / lb).abs() < 0.01, "util={util} line={lb}");
            util
        };
        run(dev());
        let mut wide = dev();
        wide.line_bytes = 256;
        let util = run(wide);
        assert!((util - 1.0 / 64.0).abs() < 0.005, "util={util}");
    }

    #[test]
    fn aia_scatter_improves_stream_utilization() {
        // Same scatter workload as `aia_converts_scatter_to_stream_hits`:
        // AIA-on reads a sequential stream buffer at near-full line
        // utilization, while AIA-off drags scattered col_B lines through
        // the hierarchy at a fraction of each.
        let run = |mode: AiaMode| -> SimReport {
            let mut m = Machine::new(dev(), mode, 1);
            m.begin_block(0, Phase::Allocation);
            let mut x = 99u64;
            for _ in 0..3000 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(7);
                let lo = (x % 10_000_000) as usize;
                m.indirect_range(Region::RptB, lo % 1_000_000, &[Region::ColB], lo, lo + 4);
            }
            m.finish()
        };
        let off = run(AiaMode::Off);
        let on = run(AiaMode::On);
        // AIA-off never touches the stream buffer at all.
        assert!(off.region_utilization(Region::AiaStream).is_none());
        let stream_on = on.region_utilization(Region::AiaStream).unwrap();
        let colb_off = off.region_utilization(Region::ColB).unwrap();
        assert!(stream_on > 0.9, "stream util={stream_on}");
        assert!(stream_on > colb_off + 0.2, "stream={stream_on} col_b={colb_off}");
        // The overall waste ratio drops too — the Fig. 5 story.
        assert!(on.waste_ratio() < off.waste_ratio(), "on={} off={}", on.waste_ratio(), off.waste_ratio());
    }

    #[test]
    fn used_never_exceeds_fetched_under_random_traces() {
        // Property: used ≤ fetched in every region × phase cell, the
        // per-phase totals match the per-region sums, and fetched bytes
        // equal the HBM bytes the pricing model charged.
        let mut x = 0xC0FFEE_u64;
        let mut step = |x: &mut u64| -> u64 {
            *x = x.wrapping_mul(6364136223846793005).wrapping_add(13);
            *x >> 16
        };
        for (seed, mode) in [(1u64, AiaMode::Off), (2, AiaMode::On), (3, AiaMode::Off), (4, AiaMode::On)] {
            x = seed;
            let mut m = Machine::new(dev(), mode, 1);
            for b in 0..50 {
                let phase = PHASES[(step(&mut x) % PHASES.len() as u64) as usize];
                m.begin_block(b, phase);
                for _ in 0..200 {
                    match step(&mut x) % 3 {
                        0 => {
                            let region = Region::ALL[(step(&mut x) % Region::ALL.len() as u64) as usize];
                            let bytes = [1u32, 4, 8, 16][(step(&mut x) % 4) as usize];
                            m.access(region, (step(&mut x) % 5_000_000) as usize, bytes, Kind::Read);
                        }
                        1 => {
                            let lo = (step(&mut x) % 1_000_000) as usize;
                            let n = (step(&mut x) % 8) as usize;
                            m.indirect_range(Region::RptB, lo % 100_000, &[Region::ColB, Region::ValB], lo, lo + n);
                        }
                        _ => {
                            m.access(Region::GroupCtr, (step(&mut x) % 64) as usize, 4, Kind::Atomic);
                        }
                    }
                }
            }
            let r = m.finish();
            assert!(!r.phases.is_empty());
            for p in &r.phases {
                assert!(p.used_bytes <= p.fetched_bytes, "{:?}: used {} > fetched {}", p.phase, p.used_bytes, p.fetched_bytes);
                assert_eq!(p.fetched_bytes, p.hbm_bytes, "{:?}", p.phase);
                let mut used = 0u64;
                let mut fetched = 0u64;
                for rw in &p.regions {
                    assert!(rw.used_bytes <= rw.fetched_bytes, "{:?}/{:?}", p.phase, rw.region);
                    used += rw.used_bytes;
                    fetched += rw.fetched_bytes;
                }
                assert_eq!(used, p.used_bytes);
                assert_eq!(fetched, p.fetched_bytes);
            }
            assert!(r.used_bytes() <= r.fetched_bytes());
        }
    }

    #[test]
    fn phase_other_gets_its_own_slot() {
        // Regression (satellite): Phase::Other used to fold into the
        // EscCompress slot, mislabelling its traffic.
        let mut m = Machine::new(dev(), AiaMode::Off, 1);
        m.begin_block(0, Phase::EscCompress);
        m.access(Region::ColA, 0, 4, Kind::Read);
        m.begin_block(1, Phase::Other);
        for i in 0..100 {
            m.access(Region::ColB, i * 1000, 4, Kind::Read);
        }
        let r = m.finish();
        let esc = r.phase(Phase::EscCompress).unwrap();
        let other = r.phase(Phase::Other).unwrap();
        assert_eq!(esc.accesses, 1);
        assert_eq!(other.accesses, 100);
    }
}
