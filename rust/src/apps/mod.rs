//! The paper's §V applications, each driving the SpGEMM engines through
//! a `SpgemmExecutor` so the three system variants (AIA / software-only
//! / cuSPARSE baseline) are directly comparable.

pub mod contraction;
pub mod mcl;

pub use contraction::{contract, random_labels, selector_matrix, ContractionResult};
pub use mcl::{mcl, MclParams, MclResult};
