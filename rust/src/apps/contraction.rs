//! Graph Contraction (paper §V-B, Algorithm 7): merge nodes sharing a
//! label via `C = S · G · Sᵀ`, where `S[m×n]` has a 1 at
//! `(labels[v], v)` — two chained SpGEMMs per contraction.

use crate::coordinator::executor::SpgemmExecutor;
use crate::sparse::Csr;

/// Build the selector matrix `S` (m × n) from node labels, m = max+1.
pub fn selector_matrix(labels: &[usize], n: usize) -> Csr {
    assert_eq!(labels.len(), n);
    let m = labels.iter().copied().max().map(|x| x + 1).unwrap_or(0);
    // S^T is the natural CSR construction (one entry per node row), so
    // build T = S^T then transpose — both steps O(n).
    let mut rpt = Vec::with_capacity(n + 1);
    rpt.push(0usize);
    let mut col = Vec::with_capacity(n);
    for &l in labels {
        col.push(l as u32);
        rpt.push(col.len());
    }
    let st = Csr::new_unchecked(n, m, rpt, col, vec![1.0; n]);
    st.transpose()
}

/// Result of one contraction.
pub struct ContractionResult {
    pub contracted: Csr,
    /// Simulated SpGEMM time (ms) if the executor simulates.
    pub sim_ms: f64,
}

/// Contract `g` by `labels` using the executor's SpGEMM engine:
/// `C = S · G · Sᵀ` (Algorithm 7).
pub fn contract(g: &Csr, labels: &[usize], ex: &mut SpgemmExecutor) -> ContractionResult {
    assert_eq!(g.n_rows, g.n_cols, "adjacency must be square");
    let before = ex.sim_ms;
    let s = selector_matrix(labels, g.n_rows);
    let st = s.transpose();
    let sg = ex.multiply(&s, g);
    let contracted = ex.multiply(&sg, &st);
    ContractionResult { contracted, sim_ms: ex.sim_ms - before }
}

/// Coarsening labels by hash-bucketing nodes into `m` groups — the
/// synthetic label assignment the benchmarks use (the paper contracts by
/// application-provided labels; uniform random labels preserve the
/// SpGEMM workload shape).
pub fn random_labels(n: usize, m: usize, rng: &mut crate::util::Pcg32) -> Vec<usize> {
    (0..n).map(|_| rng.below_usize(m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{SpgemmExecutor, Variant};
    use crate::util::Pcg32;

    #[test]
    fn selector_shape() {
        let s = selector_matrix(&[0, 1, 0, 2], 4);
        assert_eq!((s.n_rows, s.n_cols), (3, 4));
        assert_eq!(s.nnz(), 4);
        // row 0 selects nodes 0 and 2
        assert_eq!(s.row(0).0, &[0, 2]);
    }

    #[test]
    fn contracting_a_path_merges_endpoints() {
        // path 0-1-2-3 with labels [0,0,1,1] -> 2 supernodes with one
        // crossing edge (1-2) and intra-edges becoming self-loops.
        let g = Csr::from_dense(&[
            vec![0.0, 1.0, 0.0, 0.0],
            vec![1.0, 0.0, 1.0, 0.0],
            vec![0.0, 1.0, 0.0, 1.0],
            vec![0.0, 0.0, 1.0, 0.0],
        ]);
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let r = contract(&g, &[0, 0, 1, 1], &mut ex);
        let d = r.contracted.to_dense();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0][0], 2.0); // edge 0-1 folded: A[0][1]+A[1][0]
        assert_eq!(d[0][1], 1.0); // crossing edge 1-2
        assert_eq!(d[1][0], 1.0);
        assert_eq!(d[1][1], 2.0);
    }

    #[test]
    fn identity_labels_preserve_graph() {
        let mut rng = Pcg32::seeded(4);
        let g = crate::gen::rmat(128, 900, crate::gen::RmatParams::uniform(), &mut rng);
        let labels: Vec<usize> = (0..g.n_rows).collect();
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let r = contract(&g, &labels, &mut ex);
        assert!(r.contracted.approx_eq(&g, 1e-12));
    }

    #[test]
    fn edge_weights_sum_is_preserved() {
        let mut rng = Pcg32::seeded(5);
        let g = crate::gen::rmat(200, 1500, crate::gen::RmatParams::uniform(), &mut rng);
        let labels = random_labels(g.n_rows, 20, &mut rng);
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let r = contract(&g, &labels, &mut ex);
        let before: f64 = g.val.iter().sum();
        let after: f64 = r.contracted.val.iter().sum();
        assert!((before - after).abs() < 1e-9 * before.abs().max(1.0));
        assert_eq!(ex.jobs, 2); // exactly two SpGEMMs
    }

    #[test]
    fn variants_agree_functionally() {
        let mut rng = Pcg32::seeded(6);
        let g = crate::gen::rmat(150, 1200, crate::gen::RmatParams::web(), &mut rng);
        let labels = random_labels(g.n_rows, 30, &mut rng);
        let mut hash = SpgemmExecutor::fast(Variant::Hash);
        let mut esc = SpgemmExecutor::fast(Variant::Cusparse);
        let a = contract(&g, &labels, &mut hash).contracted;
        let b = contract(&g, &labels, &mut esc).contracted;
        assert!(a.approx_eq(&b, 1e-10));
    }
}
