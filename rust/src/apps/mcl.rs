//! Markov Clustering (paper §V-A, Algorithm 6): iterative expansion
//! (matrix self-product — the SpGEMM hot spot), pruning, inflation, and
//! column normalization until the flow matrix converges; clusters are
//! the connected components of the converged matrix.
//!
//! Expansion reuses the symbolic plan across iterations through
//! [`SpgemmExecutor::multiply_reusing`]: pruning and inflation may
//! change the flow matrix's structure early on (detected via the
//! operands' structure hash). Instead of blanket plan invalidation,
//! the slot's displaced plan becomes the delta baseline: the executor
//! diffs per-row structure hashes and re-plans only the rows the prune
//! step actually dirtied (`spgemm::hash::incremental`), falling back to
//! a full replan when the drift is too large. As the flow stabilises
//! the pattern repeats and later iterations pay only the numeric phase.
//! [`MclResult`] reports the hit/delta/miss split.

use crate::coordinator::executor::SpgemmExecutor;
use crate::spgemm::hash::PlannedProduct;
use crate::sparse::ops;
use crate::sparse::Csr;
use std::sync::Arc;

/// MCL hyper-parameters (paper defaults: e = 2, r = 2).
#[derive(Clone, Debug)]
pub struct MclParams {
    /// Expansion exponent e (A^e per iteration; e=2 → one self-product).
    pub expansion: u32,
    /// Inflation exponent r (Hadamard power).
    pub inflation: f64,
    /// Pruning threshold θ.
    pub theta: f64,
    /// Keep top-k entries per column after pruning.
    pub top_k: usize,
    /// Convergence: stop when ‖A_t − A_{t−1}‖_F < tol.
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for MclParams {
    fn default() -> Self {
        MclParams { expansion: 2, inflation: 2.0, theta: 1e-4, top_k: 32, tol: 1e-6, max_iters: 50 }
    }
}

/// MCL output.
pub struct MclResult {
    /// Cluster label per node.
    pub clusters: Vec<usize>,
    pub n_clusters: usize,
    pub iterations: usize,
    /// Simulated SpGEMM time (ms) if the executor simulates.
    pub sim_ms: f64,
    pub converged: bool,
    /// Expansions served from a reused symbolic plan (functional hash
    /// executors only — 0 under simulation or the ESC baseline).
    pub plan_hits: usize,
    /// Expansions that had to (re)plan from scratch.
    pub plan_misses: usize,
    /// Expansions served by the executor's plan store *disk* tier — a
    /// plan persisted by an earlier process (0 without `--plan-cache`).
    pub disk_hits: usize,
    /// Expansions served by delta-patching the previous iteration's
    /// plan after the prune step dirtied part of the flow structure
    /// (neither hit nor miss; see `spgemm::hash::incremental`).
    pub plan_deltas: usize,
    /// Total rows whose symbolic phase was re-run across all delta
    /// patches (the dirty-set sizes summed).
    pub delta_rows: usize,
}

/// Run MCL on (possibly weighted) adjacency `g` with the executor's
/// SpGEMM engine doing every expansion.
pub fn mcl(g: &Csr, params: &MclParams, ex: &mut SpgemmExecutor) -> MclResult {
    assert_eq!(g.n_rows, g.n_cols, "MCL needs a square adjacency");
    let before = ex.sim_ms;
    let (hits0, misses0, disk0) = (ex.plan_hits, ex.plan_misses, ex.disk_hits);
    let (deltas0, drows0) = (ex.plan_deltas, ex.delta_rows);
    // Algorithm 6 lines 1–3.
    let with_loops = ops::add_self_loops(g, 1.0);
    let mut a = ops::column_normalize(&with_loops);
    let mut converged = false;
    let mut iterations = 0;
    // One plan slot per expansion step: step s always multiplies A^s·A,
    // so when prune/inflate leave the flow structure unchanged between
    // iterations every step reuses its plan (structure-hash checked).
    // Slot misses fall through to the executor's tiered plan store, so
    // with `--plan-cache` a re-run on the same graph starts from the
    // previous process's plans.
    let mut plans: Vec<Option<Arc<PlannedProduct>>> = (1..params.expansion).map(|_| None).collect();
    for _ in 0..params.max_iters {
        iterations += 1;
        // Expansion: A^e through the SpGEMM engine.
        let mut b = a.clone();
        for slot in plans.iter_mut() {
            b = ex.multiply_reusing(slot, &b, &a);
        }
        // Prune (θ, top-k per column).
        let c = ops::prune_columns(&b, params.theta, params.top_k);
        // Inflation + renormalize.
        let inflated = ops::hadamard_power(&c, params.inflation);
        let next = ops::column_normalize(&inflated);
        let delta = ops::frobenius_diff(&next, &a);
        a = next;
        if delta < params.tol {
            converged = true;
            break;
        }
    }
    let clusters_raw = ops::connected_components(&a.drop_zeros());
    let n_clusters = clusters_raw.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    MclResult {
        clusters: clusters_raw,
        n_clusters,
        iterations,
        sim_ms: ex.sim_ms - before,
        converged,
        plan_hits: ex.plan_hits - hits0,
        plan_misses: ex.plan_misses - misses0,
        disk_hits: ex.disk_hits - disk0,
        plan_deltas: ex.plan_deltas - deltas0,
        delta_rows: ex.delta_rows - drows0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{SpgemmExecutor, Variant};
    use crate::sparse::Coo;
    use crate::util::Pcg32;

    /// Two dense blobs joined by one weak edge.
    fn two_cluster_graph() -> Csr {
        let mut coo = Coo::new(10, 10);
        for i in 0..5 {
            for j in 0..5 {
                if i != j {
                    coo.push(i, j, 1.0);
                }
            }
        }
        for i in 5..10 {
            for j in 5..10 {
                if i != j {
                    coo.push(i, j, 1.0);
                }
            }
        }
        coo.push(4, 5, 0.1);
        coo.push(5, 4, 0.1);
        coo.to_csr()
    }

    #[test]
    fn recovers_two_clusters() {
        let g = two_cluster_graph();
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let r = mcl(&g, &MclParams::default(), &mut ex);
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        assert_eq!(r.n_clusters, 2, "labels: {:?}", r.clusters);
        // nodes 0..5 together, 5..10 together
        assert!(r.clusters[..5].iter().all(|&c| c == r.clusters[0]));
        assert!(r.clusters[5..].iter().all(|&c| c == r.clusters[5]));
        assert_ne!(r.clusters[0], r.clusters[5]);
    }

    #[test]
    fn disconnected_components_stay_separate() {
        // 3 disjoint triangles
        let mut coo = Coo::new(9, 9);
        for t in 0..3 {
            let b = t * 3;
            for i in 0..3 {
                for j in 0..3 {
                    if i != j {
                        coo.push(b + i, b + j, 1.0);
                    }
                }
            }
        }
        let g = coo.to_csr();
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let r = mcl(&g, &MclParams::default(), &mut ex);
        assert_eq!(r.n_clusters, 3);
    }

    #[test]
    fn engines_agree_on_clusters() {
        let mut rng = Pcg32::seeded(11);
        let g = crate::gen::structured::community_powerlaw(120, 6, 4, &mut rng);
        let mut h = SpgemmExecutor::fast(Variant::Hash);
        let mut e = SpgemmExecutor::fast(Variant::Cusparse);
        let rh = mcl(&g, &MclParams::default(), &mut h);
        let re = mcl(&g, &MclParams::default(), &mut e);
        assert_eq!(rh.clusters, re.clusters);
        assert_eq!(rh.iterations, re.iterations);
    }

    /// Pinned to a memory-only plan store: these tests assert plan
    /// hit/miss counts, which a `SPGEMM_AIA_PLAN_CACHE` env var leaking
    /// in from the developer's shell (warm disk tier from a previous
    /// `cargo test`) would turn stateful. Cross-process MCL reuse is
    /// covered by `tests/plan_store.rs` with a pinned directory.
    fn mem_pinned_hash() -> SpgemmExecutor {
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        ex.attach_plan_store(crate::spgemm::hash::TieredStore::mem_only());
        ex
    }

    #[test]
    fn expansion_counts_spgemm_jobs() {
        let g = two_cluster_graph();
        let mut ex = mem_pinned_hash();
        let r = mcl(&g, &MclParams { max_iters: 3, tol: 0.0, ..Default::default() }, &mut ex);
        // e=2 → 1 SpGEMM per iteration
        assert_eq!(ex.jobs, r.iterations);
        // Every expansion is accounted as exactly one of: plan hit,
        // disk hit, delta patch, or full miss.
        assert_eq!(r.plan_hits + r.disk_hits + r.plan_deltas + r.plan_misses, r.iterations);
        // Delta patches that did fire re-planned a bounded dirty set.
        if r.plan_deltas > 0 {
            assert!(r.delta_rows >= r.plan_deltas);
        } else {
            assert_eq!(r.delta_rows, 0);
        }
    }

    #[test]
    fn converging_mcl_reuses_plans() {
        let g = two_cluster_graph();
        let mut ex = mem_pinned_hash();
        let r = mcl(&g, &MclParams::default(), &mut ex);
        assert!(r.converged);
        assert!(r.plan_misses >= 1, "first expansion always plans");
        // The flow structure stabilises well before Frobenius convergence,
        // so a converged run must have reused at least one plan.
        assert!(r.plan_hits >= 1, "expected plan reuse on a converging run (iters={})", r.iterations);
        // Simulated executors keep pricing full kernels: no plan counters.
        let mut sim = SpgemmExecutor::simulated(Variant::HashAia);
        let rs = mcl(&g, &MclParams { max_iters: 2, ..Default::default() }, &mut sim);
        assert_eq!((rs.plan_hits, rs.plan_misses), (0, 0));
        assert!(rs.sim_ms > 0.0);
    }
}
