//! The PJRT client wrapper: HLO-text artifact loading, executable
//! caching keyed by `(op, tier)`, and typed execution.
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (see `python/compile/aot.py`).

use super::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Loads and runs AOT artifacts. One compiled executable per (op, tier),
/// compiled lazily on first use and cached for the process lifetime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    exes: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    /// Wall time spent executing (the dense-path cost the GNN trainer
    /// reports), seconds.
    pub exec_secs: f64,
    pub calls: u64,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, dir: artifacts_dir.to_path_buf(), exes: HashMap::new(), exec_secs: 0.0, calls: 0 })
    }

    /// Default artifacts directory (`$SPGEMM_AIA_ARTIFACTS` or `artifacts/`).
    pub fn artifacts_dir() -> PathBuf {
        std::env::var("SPGEMM_AIA_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    fn ensure_compiled(&mut self, op: &str, tier: usize) -> Result<()> {
        let key = (op.to_string(), tier);
        if self.exes.contains_key(&key) {
            return Ok(());
        }
        let path = self.dir.join(format!("{op}_n{tier}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("load {}: {e:?}", path.display()))
            .with_context(|| "run `make artifacts` first")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {op}_n{tier}: {e:?}"))?;
        self.exes.insert(key, exe);
        Ok(())
    }

    /// Execute `op` at `tier` on `inputs`; returns the artifact's output
    /// tuple as host tensors.
    pub fn call(&mut self, op: &str, tier: usize, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(op, tier)?;
        let exe = self.exes.get(&(op.to_string(), tier)).unwrap();
        let lits: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {op}_n{tier}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {op}_n{tier}: {e:?}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.calls += 1;
        // Artifacts always return tuples (aot.py wraps single outputs).
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple {op}_n{tier}: {e:?}"))?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Number of compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.exes.len()
    }
}

#[cfg(test)]
mod tests {
    //! These tests need `make artifacts` to have run; they are the
    //! integration seam between L2 (JAX) and L3 (Rust) and are kept in
    //! `rust/tests/runtime_integration.rs` so `cargo test --lib` stays
    //! artifact-free. Only the pure helpers are tested here.

    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("SPGEMM_AIA_ARTIFACTS", "/tmp/xyz");
        assert_eq!(Runtime::artifacts_dir(), PathBuf::from("/tmp/xyz"));
        std::env::remove_var("SPGEMM_AIA_ARTIFACTS");
        assert_eq!(Runtime::artifacts_dir(), PathBuf::from("artifacts"));
    }
}
