//! The PJRT client wrapper: HLO-text artifact loading, executable
//! caching keyed by `(op, tier)`, and typed execution.
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (see `python/compile/aot.py`).
//!
//! The `xla` crate closure is only available in vendored build
//! environments, so the real client is gated behind the `pjrt` cargo
//! feature. The default build ships a std-only stub with the same API
//! surface: `new` succeeds (so `info` and the trainers construct), and
//! `call` reports exactly what is missing — the artifact, or the
//! feature — so every error stays actionable.

use super::tensor::Tensor;
use crate::util::error::Result;
use std::path::{Path, PathBuf};

/// Default artifacts directory (`$SPGEMM_AIA_ARTIFACTS` or `artifacts/`).
fn artifacts_dir_impl() -> PathBuf {
    std::env::var("SPGEMM_AIA_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::*;
    use crate::util::error::{anyhow, Context};
    use std::collections::HashMap;

    /// Loads and runs AOT artifacts. One compiled executable per
    /// (op, tier), compiled lazily on first use and cached for the
    /// process lifetime.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        exes: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
        /// Wall time spent executing (the dense-path cost the GNN trainer
        /// reports), seconds.
        pub exec_secs: f64,
        pub calls: u64,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            Ok(Runtime { client, dir: artifacts_dir.to_path_buf(), exes: HashMap::new(), exec_secs: 0.0, calls: 0 })
        }

        /// Default artifacts directory (`$SPGEMM_AIA_ARTIFACTS` or `artifacts/`).
        pub fn artifacts_dir() -> PathBuf {
            super::artifacts_dir_impl()
        }

        fn ensure_compiled(&mut self, op: &str, tier: usize) -> Result<()> {
            let key = (op.to_string(), tier);
            if self.exes.contains_key(&key) {
                return Ok(());
            }
            let path = self.dir.join(format!("{op}_n{tier}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("load {}: {e:?}", path.display()))
                .with_context(|| "run `make artifacts` first")?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {op}_n{tier}: {e:?}"))?;
            self.exes.insert(key, exe);
            Ok(())
        }

        /// Execute `op` at `tier` on `inputs`; returns the artifact's
        /// output tuple as host tensors.
        pub fn call(&mut self, op: &str, tier: usize, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            self.ensure_compiled(op, tier)?;
            let exe = self.exes.get(&(op.to_string(), tier)).unwrap();
            let lits: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let t0 = std::time::Instant::now();
            let result = exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow!("execute {op}_n{tier}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("sync {op}_n{tier}: {e:?}"))?;
            self.exec_secs += t0.elapsed().as_secs_f64();
            self.calls += 1;
            // Artifacts always return tuples (aot.py wraps single outputs).
            let parts = result.to_tuple().map_err(|e| anyhow!("untuple {op}_n{tier}: {e:?}"))?;
            parts.iter().map(Tensor::from_literal).collect()
        }

        /// Number of compiled executables resident.
        pub fn compiled_count(&self) -> usize {
            self.exes.len()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;
    use crate::util::error::bail;

    /// Std-only stand-in for the PJRT client (built without the `pjrt`
    /// feature). Construction succeeds so callers can report runtime
    /// status; execution fails with an actionable message.
    pub struct Runtime {
        dir: PathBuf,
        /// Wall time spent executing artifacts — always 0.0 in the stub.
        pub exec_secs: f64,
        pub calls: u64,
    }

    impl Runtime {
        /// Create a stub runtime rooted at an artifacts directory.
        pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
            Ok(Runtime { dir: artifacts_dir.to_path_buf(), exec_secs: 0.0, calls: 0 })
        }

        /// Default artifacts directory (`$SPGEMM_AIA_ARTIFACTS` or `artifacts/`).
        pub fn artifacts_dir() -> PathBuf {
            super::artifacts_dir_impl()
        }

        /// Always fails: without the `pjrt` feature there is no executor.
        /// The message distinguishes "artifact missing" (fix: run
        /// `make artifacts` first) from "artifact present but this build
        /// cannot run it" (fix: build with `--features pjrt`).
        pub fn call(&mut self, op: &str, tier: usize, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let path = self.dir.join(format!("{op}_n{tier}.hlo.txt"));
            if !path.exists() {
                bail!(
                    "artifact {} not found — run `make artifacts` first (and build with `--features pjrt` + a vendored `xla` crate to execute it)",
                    path.display()
                );
            }
            bail!(
                "artifact {} present, but this build has no PJRT backend — rebuild with `--features pjrt` (requires a vendored `xla` crate, see Cargo.toml)",
                path.display()
            );
        }

        /// Number of compiled executables resident (none in the stub).
        pub fn compiled_count(&self) -> usize {
            0
        }
    }
}

pub use imp::Runtime;

#[cfg(test)]
mod tests {
    //! Artifact-dependent tests live in `rust/tests/runtime_integration.rs`
    //! (they need `make artifacts` and the `pjrt` feature); only the pure
    //! helpers and the stub's error contract are tested here.

    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("SPGEMM_AIA_ARTIFACTS", "/tmp/xyz");
        assert_eq!(Runtime::artifacts_dir(), PathBuf::from("/tmp/xyz"));
        std::env::remove_var("SPGEMM_AIA_ARTIFACTS");
        assert_eq!(Runtime::artifacts_dir(), PathBuf::from("artifacts"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_missing_artifact_actionably() {
        let dir = std::env::temp_dir().join("spgemm_aia_stub_client");
        let _ = std::fs::create_dir_all(&dir);
        let mut rt = Runtime::new(&dir).expect("stub client");
        let err = rt.call("layer_fwd", 8192, &[]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
        assert_eq!(rt.compiled_count(), 0);
    }
}
