//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) once,
//! compiles them on the CPU PJRT client, and executes them from the L3
//! hot path. Python never runs here.

pub mod client;
pub mod tensor;

pub use client::Runtime;
pub use tensor::Tensor;
