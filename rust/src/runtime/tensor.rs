//! Host-side f32 tensors, marshalled to/from `xla::Literal` when the
//! `pjrt` feature is enabled (the marshalling pair is feature-gated; the
//! tensor itself is plain std and always available).

#[cfg(feature = "pjrt")]
use crate::util::error::{ensure, Result};

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<i64>) -> Tensor {
        let len = dims.iter().product::<i64>() as usize;
        Tensor { dims, data: vec![0.0; len] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn matrix(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(rows * cols, data.len());
        Tensor { dims: vec![rows as i64, cols as i64], data }
    }

    pub fn rows(&self) -> usize {
        self.dims.first().map(|&d| d as usize).unwrap_or(1)
    }

    pub fn cols(&self) -> usize {
        self.dims.get(1).map(|&d| d as usize).unwrap_or(1)
    }

    /// Convert to an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.dims.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&self.dims)?)
        }
    }

    /// Convert back from an XLA literal (must be f32).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<i64> = shape.dims().to_vec();
        let data = lit.to_vec::<f32>()?;
        ensure!(dims.iter().product::<i64>() as usize == data.len(), "literal shape/data mismatch");
        Ok(Tensor { dims, data })
    }

    /// Elementwise AXPY: `self += alpha * other` (SGD step helper).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.dims, other.dims);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
        assert_eq!(Tensor::scalar(2.5).data, vec![2.5]);
    }

    #[test]
    fn axpy() {
        let mut a = Tensor::matrix(1, 3, vec![1., 2., 3.]);
        let b = Tensor::matrix(1, 3, vec![10., 10., 10.]);
        a.axpy(-0.1, &b);
        assert!((a.data[0] - 0.0).abs() < 1e-6);
        assert!((a.data[2] - 2.0).abs() < 1e-6);
    }
}
