//! Dense ⇄ sparse bridges for the hybrid training loop: the topk-masked
//! dense feature matrix (from the L1 kernel artifact) becomes the CSR
//! right-operand of the SpGEMM aggregation, and the sparse product comes
//! back to dense for the PJRT layer artifacts.

use crate::runtime::Tensor;
use crate::sparse::Csr;

/// Convert a (mostly-zero) dense tensor to CSR, dropping exact zeros —
/// the inverse of the topk mask.
pub fn csr_from_masked(t: &Tensor) -> Csr {
    let (n, d) = (t.rows(), t.cols());
    let mut rpt = Vec::with_capacity(n + 1);
    rpt.push(0usize);
    let mut col = Vec::new();
    let mut val = Vec::new();
    for i in 0..n {
        for j in 0..d {
            let v = t.data[i * d + j];
            if v != 0.0 {
                col.push(j as u32);
                val.push(v as f64);
            }
        }
        rpt.push(col.len());
    }
    Csr::new_unchecked(n, d, rpt, col, val)
}

/// Convert a sparse matrix to a dense row-major tensor.
pub fn dense_from_csr(m: &Csr) -> Tensor {
    let mut data = vec![0f32; m.n_rows * m.n_cols];
    for i in 0..m.n_rows {
        let (cs, vs) = m.row(i);
        for (&c, &v) in cs.iter().zip(vs) {
            data[i * m.n_cols + c as usize] = v as f32;
        }
    }
    Tensor::matrix(m.n_rows, m.n_cols, data)
}

/// Rust-native per-row top-k by |value| → CSR. Used for gradient pruning
/// on the backward path (paper Eq. 3's winner-take-all gradient routing;
/// magnitude-based, unlike the forward's value-based top-k on
/// post-relu activations where the two coincide).
pub fn topk_abs_csr(t: &Tensor, k: usize) -> Csr {
    let (n, d) = (t.rows(), t.cols());
    let mut rpt = Vec::with_capacity(n + 1);
    rpt.push(0usize);
    let mut col = Vec::new();
    let mut val = Vec::new();
    let mut idx: Vec<usize> = Vec::with_capacity(d);
    for i in 0..n {
        let row = &t.data[i * d..(i + 1) * d];
        idx.clear();
        idx.extend(0..d);
        if k < d {
            idx.select_nth_unstable_by(k - 1, |&a, &b| row[b].abs().total_cmp(&row[a].abs()));
            idx.truncate(k);
            idx.sort_unstable();
        }
        for &j in idx.iter() {
            if row[j] != 0.0 {
                col.push(j as u32);
                val.push(row[j] as f64);
            }
        }
        rpt.push(col.len());
    }
    Csr::new_unchecked(n, d, rpt, col, val)
}

/// The binary mask (pattern) of a masked tensor, applied elementwise:
/// `out = mask(pattern_src) ⊙ x`.
pub fn apply_mask(x: &Tensor, pattern_src: &Tensor) -> Tensor {
    debug_assert_eq!(x.dims, pattern_src.dims);
    let data = x
        .data
        .iter()
        .zip(&pattern_src.data)
        .map(|(&v, &p)| if p != 0.0 { v } else { 0.0 })
        .collect();
    Tensor::new(x.dims.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_roundtrip() {
        let t = Tensor::matrix(2, 4, vec![0.0, 1.5, 0.0, 2.0, 0.0, 0.0, -3.0, 0.0]);
        let m = csr_from_masked(&t);
        assert_eq!(m.nnz(), 3);
        assert_eq!(dense_from_csr(&m), t);
    }

    #[test]
    fn topk_abs_keeps_largest_magnitudes() {
        let t = Tensor::matrix(1, 5, vec![0.1, -5.0, 2.0, -0.5, 3.0]);
        let m = topk_abs_csr(&t, 2);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row(0).0, &[1, 4]); // -5.0 and 3.0
        assert_eq!(m.row(0).1, &[-5.0, 3.0]);
    }

    #[test]
    fn topk_abs_k_ge_d_keeps_all_nonzeros() {
        let t = Tensor::matrix(1, 3, vec![1.0, 0.0, -2.0]);
        let m = topk_abs_csr(&t, 5);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn apply_mask_zeroes_outside_pattern() {
        let x = Tensor::matrix(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let p = Tensor::matrix(1, 4, vec![0.0, 9.0, 0.0, -1.0]);
        assert_eq!(apply_mask(&x, &p).data, vec![0.0, 2.0, 0.0, 4.0]);
    }
}
