//! The GNN training stack (paper §V-C): synthetic Table-III datasets,
//! dense⇄sparse bridges, and the hybrid trainer that pairs the Rust
//! SpGEMM engine (simulated on the AIA machine) with PJRT dense
//! artifacts. The Eq. 1 forward and Eq. 3 masked backward both run their
//! aggregations as true SpGEMM.

pub mod data;
pub mod sparsify;
pub mod train;

pub use data::{GnnData, CDIM, FDIM, TOPK};
pub use train::{Arch, EpochStats, Trainer, HIDDEN_LAYERS};
