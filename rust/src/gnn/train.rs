//! The hybrid full-batch GNN trainer (paper §V-C, §VI-C): sparse
//! aggregation through the SpGEMM engine (simulated on the AIA machine
//! model), dense transforms through the PJRT artifacts.
//!
//! Per layer (paper Eq. 1): `X_l = Â · TopK(X_{l-1}, k) · W_l` — the
//! `TopK` runs as the L1 Pallas artifact, the `Â ·` product on the hash
//! SpGEMM engine, the `· W_l` as the L2 matmul artifact.
//!
//! Backward (paper Eq. 3): gradients are routed winner-take-all through
//! the forward masks; the backward aggregation `Âᵀ · G` is kept a true
//! SpGEMM by pruning the gradient matrix G to top-k magnitude per row
//! first (the gradient-sparsity realization of Eq. 3 — see DESIGN.md §6
//! for why this preserves the paper's workload and training behaviour).

use super::data::{GnnData, CDIM, FDIM, TOPK};
use super::sparsify::{apply_mask, csr_from_masked, dense_from_csr, topk_abs_csr};
use crate::coordinator::executor::{SpgemmExecutor, Variant};
use crate::runtime::{Runtime, Tensor};
use crate::sparse::Csr;
use crate::spgemm::hash::PlannedProduct;
use crate::util::Pcg32;
use crate::util::error::Result;
use std::sync::Arc;

/// The three evaluated architectures (paper Table III experiments).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arch {
    Gcn,
    Gin,
    Sage,
}

impl Arch {
    pub fn all() -> [Arch; 3] {
        [Arch::Gcn, Arch::Gin, Arch::Sage]
    }
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Gcn => "GCN",
            Arch::Gin => "GIN",
            Arch::Sage => "SAGE",
        }
    }
    pub fn parse(s: &str) -> Option<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Some(Arch::Gcn),
            "gin" => Some(Arch::Gin),
            "sage" | "graphsage" => Some(Arch::Sage),
            _ => None,
        }
    }
}

/// One recorded SpGEMM job of an epoch: (transposed?, which adjacency,
/// sparse right operand) — replayed under simulated executors to price
/// each system variant.
pub struct SpgemmJob {
    pub adj: AdjKind,
    pub transpose: bool,
    pub rhs: Csr,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjKind {
    Gcn,
    Mean,
    Gin,
}

/// Dense index for the per-[`AdjKind`] caches.
fn kind_idx(k: AdjKind) -> usize {
    match k {
        AdjKind::Gcn => 0,
        AdjKind::Mean => 1,
        AdjKind::Gin => 2,
    }
}

/// The kind→adjacency map, as a free function so it also works under
/// the split borrows in [`Trainer::aggregate`].
fn data_adj(data: &GnnData, kind: AdjKind) -> &Csr {
    match kind {
        AdjKind::Gcn => &data.adj_gcn,
        AdjKind::Mean => &data.adj_mean,
        AdjKind::Gin => &data.adj_gin,
    }
}

/// Hidden-layer forward cache for backprop.
struct LayerCache {
    hp: Tensor,   // TopK-masked input (mask pattern source)
    agg: Tensor,  // aggregated dense features
    gate: Tensor, // relu gate
    mid: Option<(Tensor, Tensor, Tensor)>, // GIN: (agg→m act input, m, gate_b)
    sage_self: Option<Tensor>, // SAGE: the self path input
}

/// Per-epoch statistics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub loss: f32,
    pub accuracy: f64,
    /// Wall-clock seconds spent in PJRT dense ops this epoch.
    pub dense_secs: f64,
    /// Functional SpGEMM jobs issued this epoch.
    pub spgemm_jobs: usize,
}

/// Hybrid trainer. `HIDDEN_LAYERS` GNN layers + aggregated output layer
/// (3 aggregations per forward, matching the paper's 3-layer models).
///
/// The adjacency is static between sparsification events, so the
/// trainer plans its sparse work once and reuses it across epochs:
/// transposed adjacencies are built lazily and cached, and every
/// aggregation call site owns a [`PlannedProduct`] slot driven through
/// [`SpgemmExecutor::multiply_reusing`] — epochs whose top-k mask
/// pattern repeats pay only the numeric phase ([`Trainer::plan_hit_rate`]
/// reports how often that happened). After a sparsification event that
/// edits an adjacency's structure, call
/// [`Trainer::note_sparsification`]: the displaced plans stay in their
/// slots as delta baselines, so the next epoch re-plans only the rows
/// the event dirtied (`spgemm::hash::incremental`) instead of paying a
/// full symbolic pass per call site. [`Trainer::invalidate_plans`]
/// remains the blanket fallback for wholesale adjacency replacement.
pub struct Trainer<'a> {
    pub rt: &'a mut Runtime,
    pub data: &'a GnnData,
    pub arch: Arch,
    pub k: usize,
    pub lr: f32,
    // weights
    w_hidden: Vec<Tensor>,      // GCN: w_l; GIN: wa_l; SAGE: w_neigh_l
    w_hidden2: Vec<Tensor>,     // GIN: wb_l; SAGE: w_self_l; GCN: unused
    w_out: Tensor,
    /// Functional executor used during training.
    pub ex: SpgemmExecutor,
    /// SpGEMM jobs recorded on the most recent epoch.
    pub last_jobs: Vec<SpgemmJob>,
    /// Cached transposed adjacencies, one per [`AdjKind`], built on
    /// first backward use and kept until [`Trainer::invalidate_plans`].
    adj_t: [Option<Csr>; 3],
    /// One plan slot per aggregation call site (forward layers + forward
    /// output, then the backward mirrors). Slot misses fall through to
    /// the executor's tiered plan store, so with `--plan-cache` a
    /// re-trained process starts from the previous run's plans.
    plan_slots: Vec<Option<Arc<PlannedProduct>>>,
}

pub const HIDDEN_LAYERS: usize = 2;

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a mut Runtime, data: &'a GnnData, arch: Arch, seed: u64) -> Trainer<'a> {
        let mut rng = Pcg32::new(seed, 7);
        let mut init = |rows: usize, cols: usize, scale: f64| {
            let data: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect();
            Tensor::matrix(rows, cols, data)
        };
        let he = (2.0 / FDIM as f64).sqrt();
        let w_hidden = (0..HIDDEN_LAYERS).map(|_| init(FDIM, FDIM, he)).collect();
        let w_hidden2 = (0..HIDDEN_LAYERS).map(|_| init(FDIM, FDIM, he)).collect();
        let w_out = init(FDIM, CDIM, he);
        Trainer {
            rt,
            data,
            arch,
            k: TOPK,
            lr: 0.5,
            w_hidden,
            w_hidden2,
            w_out,
            ex: SpgemmExecutor::fast(Variant::Hash),
            last_jobs: Vec::new(),
            adj_t: [None, None, None],
            plan_slots: (0..2 * (HIDDEN_LAYERS + 1)).map(|_| None).collect(),
        }
    }

    /// Owned adjacency for variant replay ([`Trainer::simulate_epoch_ms`]).
    /// The training hot path uses the cached references in
    /// [`Trainer::aggregate`] instead.
    fn adj(&self, kind: AdjKind, transpose: bool) -> Csr {
        let m = self.base_adj(kind);
        if transpose {
            m.transpose()
        } else {
            m.clone()
        }
    }

    fn base_adj(&self, kind: AdjKind) -> &Csr {
        data_adj(self.data, kind)
    }

    /// Drop the cached transposes and every aggregation plan. Use when
    /// an adjacency is replaced wholesale (different graph); the next
    /// epoch transposes and plans from scratch, then reuses again. For
    /// in-place structural edits prefer [`Trainer::note_sparsification`].
    pub fn invalidate_plans(&mut self) {
        self.adj_t = [None, None, None];
        for s in self.plan_slots.iter_mut() {
            *s = None;
        }
    }

    /// Record a sparsification event that edited an adjacency's
    /// structure in place (e.g. edge pruning between epochs). Cached
    /// transposes are stale and dropped, but the aggregation plans stay
    /// in their slots: on the next epoch each call site's structure-hash
    /// check misses and [`SpgemmExecutor::multiply_reusing`] uses the
    /// displaced plan as a delta baseline, re-running the symbolic phase
    /// only for the dirtied rows ([`Trainer::plan_deltas`] counts how
    /// often that path served an aggregation).
    pub fn note_sparsification(&mut self) {
        self.adj_t = [None, None, None];
    }

    /// Aggregations (across all epochs so far) served by delta-patching
    /// a displaced plan after a sparsification event.
    pub fn plan_deltas(&self) -> usize {
        self.ex.plan_deltas
    }

    /// Fraction of aggregations (across all epochs so far) served from a
    /// reused plan instead of a fresh symbolic analysis.
    pub fn plan_hit_rate(&self) -> f64 {
        self.ex.plan_hit_rate()
    }

    fn agg_kind(&self) -> AdjKind {
        match self.arch {
            Arch::Gcn => AdjKind::Gcn,
            Arch::Gin => AdjKind::Gin,
            Arch::Sage => AdjKind::Mean,
        }
    }

    /// One SpGEMM aggregation: `adjᵀ? · rhs`, recorded for variant
    /// replay. `slot` is this call site's plan-slot index: the adjacency
    /// side is static between sparsification events, so whenever the rhs
    /// mask pattern repeats the multiply reuses its plan and pays only
    /// the numeric phase.
    fn aggregate(&mut self, slot: usize, kind: AdjKind, transpose: bool, rhs: Csr) -> Tensor {
        let idx = kind_idx(kind);
        if transpose && self.adj_t[idx].is_none() {
            self.adj_t[idx] = Some(self.base_adj(kind).transpose());
        }
        let out = {
            let Trainer { ex, plan_slots, adj_t, data, .. } = self;
            let adj: &Csr = if transpose {
                adj_t[idx].as_ref().expect("transpose cached above")
            } else {
                data_adj(*data, kind)
            };
            ex.multiply_reusing(&mut plan_slots[slot], adj, &rhs)
        };
        self.last_jobs.push(SpgemmJob { adj: kind, transpose, rhs });
        dense_from_csr(&out)
    }

    /// Forward pass; returns (logits, caches, final-agg, final-mask-src).
    fn forward(&mut self) -> Result<(Tensor, Vec<LayerCache>, Tensor, Tensor)> {
        let n = self.data.n;
        let kind = self.agg_kind();
        let mut h = self.data.features.clone();
        let mut caches = Vec::with_capacity(HIDDEN_LAYERS);
        for l in 0..HIDDEN_LAYERS {
            // L1 kernel artifact: TopK pruning (Eq. 2).
            let hp = self.rt.call("topk_mask", n, &[h.clone()])?.remove(0);
            let s = csr_from_masked(&hp);
            let agg = self.aggregate(l, kind, false, s);
            match self.arch {
                Arch::Gcn => {
                    let mut out = self.rt.call("layer_fwd", n, &[agg.clone(), self.w_hidden[l].clone()])?;
                    let gate = out.remove(1);
                    let act = out.remove(0);
                    caches.push(LayerCache { hp, agg, gate, mid: None, sage_self: None });
                    h = act;
                }
                Arch::Gin => {
                    let mut o1 = self.rt.call("layer_fwd", n, &[agg.clone(), self.w_hidden[l].clone()])?;
                    let gate_a = o1.remove(1);
                    let m = o1.remove(0);
                    let mut o2 = self.rt.call("layer_fwd", n, &[m.clone(), self.w_hidden2[l].clone()])?;
                    let gate_b = o2.remove(1);
                    let act = o2.remove(0);
                    let mid = Some((m, gate_b, Tensor::scalar(0.0)));
                    caches.push(LayerCache { hp, agg, gate: gate_a, mid, sage_self: None });
                    h = act;
                }
                Arch::Sage => {
                    let mut out = self.rt.call(
                        "sage_fwd",
                        n,
                        &[hp.clone(), agg.clone(), self.w_hidden2[l].clone(), self.w_hidden[l].clone()],
                    )?;
                    let gate = out.remove(1);
                    let act = out.remove(0);
                    caches.push(LayerCache { hp: hp.clone(), agg, gate, mid: None, sage_self: Some(hp) });
                    h = act;
                }
            }
        }
        // Output layer: aggregate then linear (Eq. 1 with W_out).
        let hp_out = self.rt.call("topk_mask", n, &[h])?.remove(0);
        let s = csr_from_masked(&hp_out);
        let agg_out = self.aggregate(HIDDEN_LAYERS, kind, false, s);
        let logits = self.rt.call("out_fwd", n, &[agg_out.clone(), self.w_out.clone()])?.remove(0);
        Ok((logits, caches, agg_out, hp_out))
    }

    /// One full training epoch (forward, loss, backward, SGD update).
    pub fn epoch(&mut self) -> Result<EpochStats> {
        let n = self.data.n;
        let kind = self.agg_kind();
        let dense0 = self.rt.exec_secs;
        let jobs0 = self.ex.jobs;
        self.last_jobs.clear();

        let (logits, caches, agg_out, hp_out) = self.forward()?;
        let mut lg = self.rt.call("loss_grad", n, &[logits.clone(), self.data.labels_onehot.clone()])?;
        let dlogits = lg.remove(1);
        let loss = lg.remove(0).data[0];

        // ---- backward ----
        let mut ob = self.rt.call("out_bwd", n, &[agg_out, dlogits, self.w_out.clone()])?;
        let dagg = ob.remove(1);
        let dw_out = ob.remove(0);
        // Gradient aggregation: Âᵀ · TopK(G) (Eq. 3 realization).
        let g = topk_abs_csr(&dagg, self.k);
        let dhp = self.aggregate(HIDDEN_LAYERS + 1, kind, true, g);
        let mut dh = apply_mask(&dhp, &hp_out);

        for l in (0..HIDDEN_LAYERS).rev() {
            let c = &caches[l];
            let (dw1, dw2, dagg_l, d_self): (Tensor, Option<Tensor>, Tensor, Option<Tensor>) = match self.arch {
                Arch::Gcn => {
                    let args = [c.agg.clone(), dh.clone(), c.gate.clone(), self.w_hidden[l].clone()];
                    let mut lb = self.rt.call("layer_bwd", n, &args)?;
                    let dhl = lb.remove(1);
                    let dwl = lb.remove(0);
                    (dwl, None, dhl, None)
                }
                Arch::Gin => {
                    let (m, gate_b, _) = c.mid.as_ref().unwrap();
                    let args = [m.clone(), dh.clone(), gate_b.clone(), self.w_hidden2[l].clone()];
                    let mut b2 = self.rt.call("layer_bwd", n, &args)?;
                    let dm = b2.remove(1);
                    let dwb = b2.remove(0);
                    let args = [c.agg.clone(), dm, c.gate.clone(), self.w_hidden[l].clone()];
                    let mut b1 = self.rt.call("layer_bwd", n, &args)?;
                    let dagg_l = b1.remove(1);
                    let dwa = b1.remove(0);
                    (dwa, Some(dwb), dagg_l, None)
                }
                Arch::Sage => {
                    let hs = c.sage_self.as_ref().unwrap();
                    let mut sb = self.rt.call(
                        "sage_bwd",
                        n,
                        &[
                            hs.clone(),
                            c.agg.clone(),
                            dh.clone(),
                            c.gate.clone(),
                            self.w_hidden2[l].clone(),
                            self.w_hidden[l].clone(),
                        ],
                    )?;
                    let dh_neigh = sb.remove(3);
                    let dh_self = sb.remove(2);
                    let dwn = sb.remove(1);
                    let dws = sb.remove(0);
                    (dwn, Some(dws), dh_neigh, Some(dh_self))
                }
            };
            // Propagate to the previous layer's activations. Run at
            // l == 0 too (dh is unused afterwards there): the aggregate
            // keeps the epoch's SpGEMM job count and variant pricing
            // identical across layers, matching the paper's workload.
            {
                let g = topk_abs_csr(&dagg_l, self.k);
                let mut dhp = self.aggregate(HIDDEN_LAYERS + 2 + l, kind, true, g);
                if let Some(ds) = d_self {
                    dhp.axpy(1.0, &ds);
                }
                dh = apply_mask(&dhp, &caches[l].hp);
            }
            // SGD update
            self.w_hidden[l].axpy(-self.lr, &dw1);
            if let Some(d2) = dw2 {
                self.w_hidden2[l].axpy(-self.lr, &d2);
            }
        }
        self.w_out.axpy(-self.lr, &dw_out);

        // accuracy
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits.data[i * CDIM..(i + 1) * CDIM];
            let pred = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            if pred == self.data.labels[i] as usize {
                correct += 1;
            }
        }
        Ok(EpochStats {
            loss,
            accuracy: correct as f64 / n as f64,
            dense_secs: self.rt.exec_secs - dense0,
            spgemm_jobs: self.ex.jobs - jobs0,
        })
    }

    /// Replay the last epoch's SpGEMM jobs under a simulated executor for
    /// `variant`; returns simulated ms per epoch. This prices the sparse
    /// side of training for Fig. 10/11 without re-simulating every epoch
    /// (mask patterns are statistically stationary across epochs).
    pub fn simulate_epoch_ms(&self, variant: Variant) -> f64 {
        let mut ex = SpgemmExecutor::simulated_scaled(variant, crate::repro::gnn_experiments::GNN_SIM_SCALE);
        for job in &self.last_jobs {
            let adj = self.adj(job.adj, job.transpose);
            let _ = ex.multiply(&adj, &job.rhs);
        }
        ex.sim_ms
    }
}
