//! GNN dataset construction: synthetic Table-III analogues with
//! community-correlated features and labels so full-batch training has
//! real signal to learn (accuracy far above chance is part of the e2e
//! validation).

use crate::gen::GnnDataset;
use crate::runtime::Tensor;
use crate::sparse::{ops, Csr};
use crate::util::Pcg32;

pub const FDIM: usize = 64;
pub const CDIM: usize = 16;
pub const TOPK: usize = 8;

/// A ready-to-train dataset.
pub struct GnnData {
    pub name: String,
    /// Raw adjacency (symmetric).
    pub adj: Csr,
    /// GCN-normalized Â = D^-1/2 (A+I) D^-1/2.
    pub adj_gcn: Csr,
    /// Row-mean normalized adjacency (SAGE neighbour aggregator).
    pub adj_mean: Csr,
    /// GIN aggregator: A + (1+ε)I.
    pub adj_gin: Csr,
    /// Node features [n × FDIM].
    pub features: Tensor,
    /// Class label per node.
    pub labels: Vec<u32>,
    /// One-hot labels [n × CDIM].
    pub labels_onehot: Tensor,
    pub n: usize,
    /// Dataset down-scaling factor (drives simulated cache scaling).
    pub scale: usize,
}

impl GnnData {
    /// Build from a registry entry. Labels follow the generator's
    /// community blocks plus noise; features embed the label direction
    /// with Gaussian noise.
    pub fn build(ds: &GnnDataset, seed: u64) -> GnnData {
        let adj0 = (ds.gen)(seed);
        // Real datasets use arbitrary node ids: permute P·A·Pᵀ, carrying
        // the community assignment through the permutation so labels
        // still follow graph structure (the generators place communities
        // in contiguous blocks).
        let n = adj0.n_rows;
        let mut prng = Pcg32::new(seed, 98);
        let mut p: Vec<u32> = (0..n as u32).collect();
        prng.shuffle(&mut p);
        let adj = crate::gen::structured::permute_symmetric_with(&adj0, &p);
        let block = n.div_ceil(CDIM);
        let mut community = vec![0usize; n];
        for i in 0..n {
            community[p[i] as usize] = (i / block) % CDIM;
        }
        let mut data = Self::from_parts(ds.paper.name, adj, &community, seed);
        data.scale = ds.scale;
        data
    }

    /// Build from an arbitrary symmetric adjacency with block-structured
    /// communities (used by tests and the quickstart example).
    pub fn from_adj(name: &str, adj: Csr, seed: u64) -> GnnData {
        let n = adj.n_rows;
        let block = n.div_ceil(CDIM);
        let community: Vec<usize> = (0..n).map(|i| (i / block) % CDIM).collect();
        Self::from_parts(name, adj, &community, seed)
    }

    /// Build from an adjacency plus a per-node community assignment.
    pub fn from_parts(name: &str, adj: Csr, community: &[usize], seed: u64) -> GnnData {
        let n = adj.n_rows;
        let mut rng = Pcg32::new(seed, 99);
        // Labels follow communities with 90% probability.
        let labels: Vec<u32> = (0..n)
            .map(|i| {
                let base = community[i] % CDIM;
                if rng.coin(0.9) {
                    base as u32
                } else {
                    rng.below(CDIM as u64) as u32
                }
            })
            .collect();
        // Features: label embedding + noise. Embedding vector for class c
        // is a random ±1 pattern (fixed by seed).
        let mut emb = vec![0f32; CDIM * FDIM];
        let mut erng = Pcg32::new(seed, 100);
        for e in emb.iter_mut() {
            *e = if erng.coin(0.5) { 1.0 } else { -1.0 };
        }
        let mut feats = vec![0f32; n * FDIM];
        for i in 0..n {
            let c = labels[i] as usize;
            for f in 0..FDIM {
                feats[i * FDIM + f] = emb[c * FDIM + f] + 0.5 * rng.normal() as f32;
            }
        }
        let mut onehot = vec![0f32; n * CDIM];
        for (i, &l) in labels.iter().enumerate() {
            onehot[i * CDIM + l as usize] = 1.0;
        }
        let adj_gcn = ops::gcn_normalize(&adj);
        let adj_mean = ops::row_mean_normalize(&adj);
        let eps = 0.1;
        // GIN aggregator: D⁻¹A + (1+ε)I. The paper's GIN uses sum
        // aggregation + batch-norm; our stack has no batch-norm, so we
        // degree-normalize the neighbour sum to keep full-batch training
        // stable (documented deviation — SpGEMM workload is identical).
        let adj_gin = {
            let mean = ops::row_mean_normalize(&adj);
            let mut coo = crate::sparse::Coo::from(&mean);
            for i in 0..n {
                coo.push(i, i, 1.0 + eps);
            }
            coo.to_csr()
        };
        GnnData {
            name: name.to_string(),
            adj,
            adj_gcn,
            adj_mean,
            adj_gin,
            features: Tensor::matrix(n, FDIM, feats),
            labels,
            labels_onehot: Tensor::matrix(n, CDIM, onehot),
            n,
            scale: 16,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::structured::community_powerlaw;

    fn small() -> GnnData {
        let adj = community_powerlaw(512, 6, 16, &mut Pcg32::seeded(7));
        GnnData::from_adj("test", adj, 42)
    }

    #[test]
    fn shapes_are_consistent() {
        let d = small();
        assert_eq!(d.features.rows(), d.n);
        assert_eq!(d.features.cols(), FDIM);
        assert_eq!(d.labels.len(), d.n);
        assert_eq!(d.labels_onehot.cols(), CDIM);
        assert_eq!(d.adj_gcn.n_rows, d.n);
    }

    #[test]
    fn labels_correlate_with_blocks() {
        let d = small();
        let block = d.n.div_ceil(CDIM);
        let agree = (0..d.n).filter(|&i| d.labels[i] as usize == (i / block) % CDIM).count();
        assert!(agree as f64 > 0.8 * d.n as f64, "agree={agree}/{}", d.n);
    }

    #[test]
    fn features_are_informative() {
        // same-class feature vectors correlate more than cross-class
        let d = small();
        let f = &d.features.data;
        let dot = |a: usize, b: usize| -> f32 { (0..FDIM).map(|k| f[a * FDIM + k] * f[b * FDIM + k]).sum() };
        // pick nodes from block 0 and block 8
        let (a, b, c) = (0, 1, d.n / 2);
        if d.labels[a] == d.labels[b] && d.labels[a] != d.labels[c] {
            assert!(dot(a, b) > dot(a, c));
        }
    }

    #[test]
    fn gin_adjacency_has_boosted_diagonal() {
        let d = small();
        let dense_diag = d.adj_gin.to_dense()[0][0];
        assert!(dense_diag >= 1.1 - 1e-9);
    }

    #[test]
    fn onehot_rows_sum_to_one() {
        let d = small();
        for i in 0..d.n {
            let s: f32 = d.labels_onehot.data[i * CDIM..(i + 1) * CDIM].iter().sum();
            assert_eq!(s, 1.0);
        }
    }
}
