//! Stream scheduler: the paper launches each row-group's kernels on its
//! own CUDA stream (§III-C); at application level, independent SpGEMM
//! jobs (e.g. a benchmark sweep or bulk GNN sampling minibatches) are
//! likewise overlapped across streams.
//!
//! The scheduler assigns simulated job times to `n_streams` queues with
//! LPT (longest-processing-time-first) and reports the makespan — the
//! batch-level latency a multi-stream GPU run would see — alongside
//! per-stream utilization. The plan-reuse batch executor
//! ([`super::batch::BatchExecutor`]) feeds it the IP-weighted Table-I
//! bins of every planned product, so the group-3 (AIA-heavy) bins
//! co-schedule with the PWPR bins.

/// One schedulable job: an opaque id plus its (simulated) duration.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: String,
    pub ms: f64,
}

/// Result of scheduling a batch onto streams.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Stream index per job (parallel to the input order).
    pub assignment: Vec<usize>,
    /// Total busy time per stream.
    pub stream_ms: Vec<f64>,
    /// Batch makespan (max stream time).
    pub makespan_ms: f64,
    /// Sum of job times (single-stream lower bound... i.e. serial time).
    pub serial_ms: f64,
}

impl Schedule {
    /// Utilization = serial / (streams × makespan).
    pub fn utilization(&self) -> f64 {
        if self.makespan_ms <= 0.0 || self.stream_ms.is_empty() {
            return 0.0;
        }
        self.serial_ms / (self.stream_ms.len() as f64 * self.makespan_ms)
    }
}

/// LPT list scheduling of `jobs` onto `n_streams` streams.
pub fn schedule_lpt(jobs: &[Job], n_streams: usize) -> Schedule {
    assert!(n_streams > 0);
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| jobs[b].ms.total_cmp(&jobs[a].ms));
    let mut stream_ms = vec![0.0f64; n_streams];
    let mut assignment = vec![0usize; jobs.len()];
    for &j in &order {
        // least-loaded stream
        let (s, _) = stream_ms
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        assignment[j] = s;
        stream_ms[s] += jobs[j].ms;
    }
    let makespan_ms = stream_ms.iter().copied().fold(0.0, f64::max);
    let serial_ms = jobs.iter().map(|j| j.ms).sum();
    Schedule { assignment, stream_ms, makespan_ms, serial_ms }
}

/// FIFO round-robin scheduling (the naive single-queue baseline the
/// grouped-stream design improves on — used by the ablation bench).
pub fn schedule_rr(jobs: &[Job], n_streams: usize) -> Schedule {
    assert!(n_streams > 0);
    let mut stream_ms = vec![0.0f64; n_streams];
    let mut assignment = vec![0usize; jobs.len()];
    for (j, job) in jobs.iter().enumerate() {
        let s = j % n_streams;
        assignment[j] = s;
        stream_ms[s] += job.ms;
    }
    let makespan_ms = stream_ms.iter().copied().fold(0.0, f64::max);
    let serial_ms = jobs.iter().map(|j| j.ms).sum();
    Schedule { assignment, stream_ms, makespan_ms, serial_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(ms: &[f64]) -> Vec<Job> {
        ms.iter().enumerate().map(|(i, &m)| Job { id: format!("j{i}"), ms: m }).collect()
    }

    #[test]
    fn lpt_balances_better_than_rr() {
        let js = jobs(&[10.0, 1.0, 1.0, 1.0, 9.0, 1.0, 1.0, 8.0]);
        let lpt = schedule_lpt(&js, 3);
        let rr = schedule_rr(&js, 3);
        assert!(lpt.makespan_ms <= rr.makespan_ms);
        assert!((lpt.serial_ms - 32.0).abs() < 1e-12);
    }

    #[test]
    fn single_stream_is_serial() {
        let js = jobs(&[2.0, 3.0, 4.0]);
        let s = schedule_lpt(&js, 1);
        assert!((s.makespan_ms - 9.0).abs() < 1e-12);
        assert!((s.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn assignment_is_complete_and_in_range() {
        let js = jobs(&[1.0; 17]);
        let s = schedule_lpt(&js, 4);
        assert_eq!(s.assignment.len(), 17);
        assert!(s.assignment.iter().all(|&x| x < 4));
        // 17 unit jobs on 4 streams -> makespan 5
        assert!((s.makespan_ms - 5.0).abs() < 1e-12);
    }

    #[test]
    fn empty_batch() {
        let s = schedule_lpt(&[], 2);
        assert_eq!(s.makespan_ms, 0.0);
        assert_eq!(s.utilization(), 0.0);
    }
}
