//! L3 coordination layer: the SpGEMM job executor (variant selection +
//! simulated-time accounting), the plan-reuse batch executor (pipelined
//! symbolic/numeric execution + plan caching for iterative workloads),
//! the group/stream scheduler, and the metrics registry.

pub mod batch;
pub mod executor;
pub mod metrics;
pub mod scheduler;

pub use batch::{BatchExecutor, BatchReport, BatchStats, CachedMultiply, PlanSource};
pub use executor::{SpgemmExecutor, Variant};
