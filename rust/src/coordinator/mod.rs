//! L3 coordination layer: the SpGEMM job executor (variant selection +
//! simulated-time accounting), the group/stream scheduler, and the
//! metrics registry.

pub mod executor;
pub mod metrics;
pub mod scheduler;

pub use executor::{SpgemmExecutor, Variant};
