//! Metrics registry: named counters and timers, dumped as JSON.

use crate::sim::probe::PhaseTimes;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

/// A process-wide-ish registry (owned by the coordinator, passed where
/// needed — no global state).
#[derive(Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timers: BTreeMap<String, (f64, u64)>, // (total_secs, count)
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add_time(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Record an externally measured duration under `name`.
    pub fn add_time(&mut self, name: &str, secs: f64) {
        let e = self.timers.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    /// Record the engine's per-phase wall times under
    /// `<prefix>.{grouping,symbolic,numeric}` plus the symbolic split
    /// per counting kernel under
    /// `<prefix>.symbolic_{trivial,hash,bitmap}` and the numeric split
    /// per accumulator kind under `<prefix>.numeric_{copy,hash,spa}`
    /// (one observation each).
    pub fn observe_phase_times(&mut self, prefix: &str, pt: &PhaseTimes) {
        self.add_time(&format!("{prefix}.grouping"), pt.grouping_s);
        self.add_time(&format!("{prefix}.symbolic"), pt.symbolic_s);
        self.add_time(&format!("{prefix}.numeric"), pt.numeric_s);
        self.add_time(&format!("{prefix}.symbolic_trivial"), pt.symbolic_kind_s[0]);
        self.add_time(&format!("{prefix}.symbolic_hash"), pt.symbolic_kind_s[1]);
        self.add_time(&format!("{prefix}.symbolic_bitmap"), pt.symbolic_kind_s[2]);
        self.add_time(&format!("{prefix}.numeric_copy"), pt.numeric_kind_s[0]);
        self.add_time(&format!("{prefix}.numeric_hash"), pt.numeric_kind_s[1]);
        self.add_time(&format!("{prefix}.numeric_spa"), pt.numeric_kind_s[2]);
    }

    /// Record a simulated report's byte-accurate line-utilization
    /// accounting: total touched vs fetched HBM bytes under
    /// `<prefix>.{used_bytes,fetched_bytes}`, the per-phase split under
    /// `<prefix>.{used_bytes,fetched_bytes}.<phase>`, and the
    /// `<prefix>.waste_ratio` gauge refreshed from the *cumulative*
    /// counters — so across repeated observations the gauge stays a
    /// byte-weighted aggregate, not a last-report snapshot.
    pub fn observe_sim_waste(&mut self, prefix: &str, rep: &crate::sim::SimReport) {
        self.inc(&format!("{prefix}.used_bytes"), rep.used_bytes());
        self.inc(&format!("{prefix}.fetched_bytes"), rep.fetched_bytes());
        for p in &rep.phases {
            if p.fetched_bytes == 0 {
                continue;
            }
            self.inc(&format!("{prefix}.used_bytes.{}", p.phase.name()), p.used_bytes);
            self.inc(&format!("{prefix}.fetched_bytes.{}", p.phase.name()), p.fetched_bytes);
        }
        let used = self.counter(&format!("{prefix}.used_bytes"));
        let fetched = self.counter(&format!("{prefix}.fetched_bytes"));
        if fetched > 0 {
            self.gauge(&format!("{prefix}.waste_ratio"), 1.0 - used as f64 / fetched as f64);
        }
    }

    /// Record a plan-store counter snapshot under
    /// `<prefix>.{mem_hits,disk_hits,misses,delta_patches,stores,evictions,corrupt,stale}`.
    /// Counters are *set* (not incremented): the stats are cumulative
    /// already, so repeated exports must not double-count.
    pub fn observe_store_stats(&mut self, prefix: &str, ss: &crate::spgemm::hash::StoreStats) {
        for (name, v) in [
            ("mem_hits", ss.mem_hits),
            ("disk_hits", ss.disk_hits),
            ("misses", ss.misses),
            ("delta_patches", ss.delta_patches),
            ("stores", ss.stores),
            ("evictions", ss.evictions),
            ("corrupt", ss.corrupt),
            ("stale", ss.stale),
        ] {
            self.counters.insert(format!("{prefix}.{name}"), v);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn timer_total(&self, name: &str) -> f64 {
        self.timers.get(name).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let mut c = Json::obj();
        for (k, v) in &self.counters {
            c.set(k, (*v as i64).into());
        }
        let mut g = Json::obj();
        for (k, v) in &self.gauges {
            g.set(k, (*v).into());
        }
        let mut t = Json::obj();
        for (k, (total, count)) in &self.timers {
            let mut e = Json::obj();
            e.set("total_s", (*total).into());
            e.set("count", (*count as i64).into());
            t.set(k, e);
        }
        o.set("counters", c);
        o.set("gauges", g);
        o.set("timers", t);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let mut m = Metrics::new();
        m.inc("jobs", 2);
        m.inc("jobs", 3);
        assert_eq!(m.counter("jobs"), 5);
        let out = m.time("work", || 42);
        assert_eq!(out, 42);
        assert!(m.timer_total("work") >= 0.0);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn phase_times_land_in_timers() {
        let mut m = Metrics::new();
        let pt = PhaseTimes {
            grouping_s: 0.5,
            symbolic_s: 1.0,
            numeric_s: 2.0,
            symbolic_kind_s: [0.1, 0.6, 0.3],
            numeric_kind_s: [0.25, 1.5, 0.25],
        };
        m.observe_phase_times("spgemm", &pt);
        m.observe_phase_times("spgemm", &pt);
        assert!((m.timer_total("spgemm.symbolic") - 2.0).abs() < 1e-12);
        assert!((m.timer_total("spgemm.numeric") - 4.0).abs() < 1e-12);
        assert!((m.timer_total("spgemm.numeric_spa") - 0.5).abs() < 1e-12);
        assert!((m.timer_total("spgemm.numeric_hash") - 3.0).abs() < 1e-12);
        assert!((m.timer_total("spgemm.symbolic_bitmap") - 0.6).abs() < 1e-12);
        assert!((m.timer_total("spgemm.symbolic_hash") - 1.2).abs() < 1e-12);
        assert_eq!(m.timer_total("spgemm.missing"), 0.0);
    }

    #[test]
    fn store_stats_are_set_not_summed() {
        use crate::spgemm::hash::StoreStats;
        let mut m = Metrics::new();
        let ss = StoreStats {
            mem_hits: 3,
            disk_hits: 1,
            misses: 2,
            delta_patches: 4,
            stores: 2,
            evictions: 0,
            corrupt: 0,
            stale: 1,
        };
        m.observe_store_stats("s.store", &ss);
        m.observe_store_stats("s.store", &ss); // cumulative snapshot: re-export must not double
        assert_eq!(m.counter("s.store.mem_hits"), 3);
        assert_eq!(m.counter("s.store.disk_hits"), 1);
        assert_eq!(m.counter("s.store.misses"), 2);
        assert_eq!(m.counter("s.store.delta_patches"), 4);
        assert_eq!(m.counter("s.store.stale"), 1);
    }

    #[test]
    fn sim_waste_counters_accumulate_and_gauge_stays_aggregate() {
        use crate::sim::probe::{Phase, Region};
        use crate::sim::{AiaMode, PhaseReport, RegionWaste, SimReport};
        fn phase(p: Phase, used: u64, fetched: u64) -> PhaseReport {
            PhaseReport {
                phase: p,
                time_ms: 1.0,
                l1_hit_ratio: 0.0,
                l2_hit_ratio: 0.0,
                accesses: 0,
                hbm_bytes: fetched,
                atomics: 0,
                shared: 0,
                ops: 0,
                aia_requests: 0,
                aia_elems: 0,
                aia_bound: false,
                used_bytes: used,
                fetched_bytes: fetched,
                regions: vec![RegionWaste { region: Region::ColB, used_bytes: used, fetched_bytes: fetched }],
            }
        }
        let rep = SimReport {
            aia: AiaMode::Off,
            sample: 1,
            phases: vec![
                phase(Phase::Allocation, 32, 128),
                phase(Phase::Accumulation, 96, 128),
                phase(Phase::Grouping, 0, 0),
            ],
            total_ms: 2.0,
        };
        let mut m = Metrics::new();
        m.observe_sim_waste("sim", &rep);
        assert_eq!(m.counter("sim.used_bytes"), 128);
        assert_eq!(m.counter("sim.fetched_bytes"), 256);
        assert_eq!(m.counter("sim.used_bytes.symbolic"), 32);
        assert_eq!(m.counter("sim.used_bytes.numeric"), 96);
        // A phase that fetched nothing adds no per-phase counters.
        assert_eq!(m.counter("sim.fetched_bytes.grouping"), 0);
        // Observing again doubles the counters but the gauge remains the
        // byte-weighted aggregate, not a last-report snapshot.
        m.observe_sim_waste("sim", &rep);
        assert_eq!(m.counter("sim.fetched_bytes"), 512);
        let s = m.to_json().render();
        assert!(s.contains("\"sim.waste_ratio\":0.5"), "gauge missing or wrong in {s}");
    }

    #[test]
    fn json_shape() {
        let mut m = Metrics::new();
        m.inc("a", 1);
        m.gauge("g", 0.5);
        let s = m.to_json().render();
        assert!(s.contains("\"a\":1"));
        assert!(s.contains("\"g\":0.5"));
    }
}
