//! `SpgemmExecutor` — the bridge applications use to issue SpGEMM jobs.
//!
//! An executor pairs an engine choice with an optional machine
//! simulation and accumulates per-job simulated time, so iterative
//! applications (MCL, GNN training) can report end-to-end SpGEMM time
//! per variant exactly the way the paper's figures do (AIA / no-AIA /
//! cuSPARSE). Iterative callers whose operand structure repeats across
//! jobs use [`SpgemmExecutor::multiply_reusing`], which keeps a
//! [`PlannedProduct`] slot alive across calls and skips the
//! grouping/symbolic phases whenever the structure is unchanged; hit and
//! miss counts are accumulated and exported alongside the phase timers.

use super::metrics::Metrics;
use crate::sim::probe::PhaseTimes;
use crate::sim::{simulate_spgemm, AiaMode, SimConfig, SimReport};
use crate::spgemm::hash::PlannedProduct;
use crate::spgemm::{hash, ip, spgemm, Algo};
use crate::sparse::Csr;

/// The three system variants every experiment compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Hash engine + AIA near-HBM acceleration.
    HashAia,
    /// Hash engine, software only.
    Hash,
    /// ESC baseline ("cuSPARSE"), software only.
    Cusparse,
}

impl Variant {
    pub fn all() -> [Variant; 3] {
        [Variant::HashAia, Variant::Hash, Variant::Cusparse]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::HashAia => "hash+aia",
            Variant::Hash => "hash",
            Variant::Cusparse => "cusparse(esc)",
        }
    }

    pub fn algo(&self) -> Algo {
        match self {
            Variant::HashAia | Variant::Hash => Algo::Hash,
            Variant::Cusparse => Algo::Esc,
        }
    }

    pub fn aia(&self) -> AiaMode {
        match self {
            Variant::HashAia => AiaMode::On,
            _ => AiaMode::Off,
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "hash+aia" | "aia" => Some(Variant::HashAia),
            "hash" | "noaia" | "no-aia" => Some(Variant::Hash),
            "cusparse" | "esc" | "cusparse(esc)" => Some(Variant::Cusparse),
            _ => None,
        }
    }
}

/// Executes SpGEMM jobs for one variant, accumulating simulated time.
pub struct SpgemmExecutor {
    pub variant: Variant,
    /// `None` = functional only (no timing model).
    pub sim: Option<SimConfig>,
    /// Accumulated simulated GPU time across jobs, ms.
    pub sim_ms: f64,
    /// Accumulated intermediate products across jobs.
    pub total_ip: u64,
    pub jobs: usize,
    /// Reports per job (kept only when simulating).
    pub reports: Vec<SimReport>,
    /// Accumulated wall time per engine phase across functional Hash
    /// jobs (grouping/symbolic/numeric — zero for simulated executors
    /// and non-hash engines).
    pub phase_times: PhaseTimes,
    /// [`SpgemmExecutor::multiply_reusing`] jobs served by a cached plan
    /// (numeric phase only).
    pub plan_hits: usize,
    /// [`SpgemmExecutor::multiply_reusing`] jobs that had to (re)plan.
    pub plan_misses: usize,
}

impl SpgemmExecutor {
    /// Functional-only executor (fast parallel path).
    pub fn fast(variant: Variant) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, None)
    }

    /// Executor with the machine simulation attached.
    pub fn simulated(variant: Variant) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, Some(SimConfig::new(variant.aia())))
    }

    /// Simulated executor whose device caches are scaled by the
    /// dataset's down-scaling factor (DESIGN.md §Hardware substitution).
    pub fn simulated_scaled(variant: Variant, scale: usize) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, Some(SimConfig::for_scale(variant.aia(), scale)))
    }

    fn with_sim(variant: Variant, sim: Option<SimConfig>) -> SpgemmExecutor {
        SpgemmExecutor {
            variant,
            sim,
            sim_ms: 0.0,
            total_ip: 0,
            jobs: 0,
            reports: Vec::new(),
            phase_times: PhaseTimes::default(),
            plan_hits: 0,
            plan_misses: 0,
        }
    }

    /// Run one SpGEMM job.
    pub fn multiply(&mut self, a: &Csr, b: &Csr) -> Csr {
        self.jobs += 1;
        match &self.sim {
            None => match self.variant.algo() {
                Algo::Hash => {
                    let (c, pt) = hash::engine::multiply_timed(a, b);
                    self.phase_times.accumulate(&pt);
                    c
                }
                other => spgemm(other, a, b),
            },
            Some(cfg) => {
                self.total_ip += ip::total_ip(a, b);
                let (c, report) = simulate_spgemm(self.variant.algo(), a, b, cfg);
                self.sim_ms += report.total_ms;
                self.reports.push(report);
                c
            }
        }
    }

    /// Run one SpGEMM job with plan reuse: if `slot` holds a plan whose
    /// structure fingerprints match `(a, b)`, only the numeric phase
    /// runs; otherwise the job replans and stores the new plan in
    /// `slot`. Output is bit-identical to [`SpgemmExecutor::multiply`].
    ///
    /// Only the functional hash path reuses plans — simulated executors
    /// and the ESC baseline fall through to [`SpgemmExecutor::multiply`]
    /// (the machine model prices the full kernel regardless, and ESC has
    /// no symbolic plan), leaving the hit/miss counters untouched.
    pub fn multiply_reusing(&mut self, slot: &mut Option<PlannedProduct>, a: &Csr, b: &Csr) -> Csr {
        if self.sim.is_some() || self.variant.algo() != Algo::Hash {
            return self.multiply(a, b);
        }
        self.jobs += 1;
        let t_validate = std::time::Instant::now();
        let reuse = slot.as_ref().is_some_and(|p| p.matches(a, b));
        // Plan validation re-hashes both operands' structure — real,
        // O(nnz) operand-analysis work the hit path still pays. Charge
        // it to the grouping slot so a reused job's grouping_s is the
        // validation cost rather than a defaulted 0 and the reported
        // plan-reuse saving is not overstated (the symbolic phase is
        // the part reuse genuinely skips, so symbolic_s stays 0 on
        // hits). Regression-pinned by
        // `reused_jobs_charge_plan_validation_time`.
        self.phase_times.grouping_s += t_validate.elapsed().as_secs_f64();
        if reuse {
            self.plan_hits += 1;
        } else {
            let p = PlannedProduct::plan(a, b);
            self.phase_times.accumulate(&p.plan_times);
            self.plan_misses += 1;
            *slot = Some(p);
        }
        let p = slot.as_ref().expect("slot was just filled on miss");
        // Unchecked: hits were validated by `matches` above; misses hold
        // a plan built from these exact operands.
        let (c, fill_times) = p.fill_unchecked_timed(a, b);
        // Only the numeric fields are populated (incl. the per-kind split).
        self.phase_times.accumulate(&fill_times);
        c
    }

    /// Fraction of [`SpgemmExecutor::multiply_reusing`] jobs served from
    /// a cached plan (0 when no reusing jobs ran).
    pub fn plan_hit_rate(&self) -> f64 {
        let total = self.plan_hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_hits as f64 / total as f64
        }
    }

    /// Aggregate GFLOPS over all jobs so far (paper's metric).
    pub fn gflops(&self) -> f64 {
        crate::sim::gflops(self.total_ip, self.sim_ms)
    }

    /// Export accumulated counters into a [`Metrics`] registry under
    /// `spgemm.<variant>.*` (jobs, simulated ms, per-phase wall times).
    pub fn export_metrics(&self, m: &mut Metrics) {
        let prefix = format!("spgemm.{}", self.variant.name());
        m.inc(&format!("{prefix}.jobs"), self.jobs as u64);
        m.inc(&format!("{prefix}.plan_hits"), self.plan_hits as u64);
        m.inc(&format!("{prefix}.plan_misses"), self.plan_misses as u64);
        m.gauge(&format!("{prefix}.sim_ms"), self.sim_ms);
        m.observe_phase_times(&prefix, &self.phase_times);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn variant_table() {
        assert_eq!(Variant::HashAia.algo(), Algo::Hash);
        assert_eq!(Variant::HashAia.aia(), AiaMode::On);
        assert_eq!(Variant::Cusparse.algo(), Algo::Esc);
        assert_eq!(Variant::parse("AIA"), Some(Variant::HashAia));
        assert_eq!(Variant::parse("esc"), Some(Variant::Cusparse));
        assert_eq!(Variant::parse("x"), None);
    }

    #[test]
    fn fast_executor_runs_without_sim() {
        let a = crate::gen::rmat(256, 2000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(1));
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let c = ex.multiply(&a, &a);
        assert_eq!(ex.jobs, 1);
        assert_eq!(ex.sim_ms, 0.0);
        assert!(c.nnz() > 0);
        // the fast hash path reports distinct per-phase wall times...
        assert!(ex.phase_times.total_s() > 0.0);
        // ...and they export into the metrics registry
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.jobs"), 1);
        assert!(m.timer_total("spgemm.hash.numeric") >= 0.0);
    }

    #[test]
    fn multiply_reusing_hits_on_stable_structure() {
        let a = crate::gen::rmat(192, 1200, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(4));
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let mut slot = None;
        let c1 = ex.multiply_reusing(&mut slot, &a, &a);
        assert_eq!((ex.plan_hits, ex.plan_misses), (0, 1));
        // Same structure, new values: plan must be reused and exact.
        let mut a2 = a.clone();
        a2.map_values(|v| v + 1.0);
        let c2 = ex.multiply_reusing(&mut slot, &a2, &a2);
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 1));
        assert_eq!(c2, crate::spgemm::hash::multiply(&a2, &a2));
        assert_ne!(c1, c2);
        assert_eq!(ex.jobs, 2);
        assert!((ex.plan_hit_rate() - 0.5).abs() < 1e-12);
        // Structural change replans into the same slot.
        let b = crate::gen::rmat(192, 1400, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(5));
        let c3 = ex.multiply_reusing(&mut slot, &b, &b);
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 2));
        assert_eq!(c3, crate::spgemm::hash::multiply(&b, &b));
        // Counters export into the metrics registry.
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.plan_hits"), 1);
        assert_eq!(m.counter("spgemm.hash.plan_misses"), 2);
    }

    /// Regression: the `multiply_reusing` hit path used to leave
    /// `grouping_s` at its defaulted 0 even though validating the plan
    /// re-hashes both operands (O(nnz)) — phase totals reported reuse's
    /// operand analysis as free, overstating the plan-reuse saving.
    #[test]
    fn reused_jobs_charge_plan_validation_time() {
        // Large enough that two structure hashes take measurable time.
        let a = crate::gen::rmat(4096, 40_000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(9));
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let mut slot = None;
        ex.multiply_reusing(&mut slot, &a, &a); // miss: plans
        let after_miss = ex.phase_times;
        assert!(after_miss.grouping_s > 0.0 && after_miss.symbolic_s > 0.0);
        ex.multiply_reusing(&mut slot, &a, &a); // hit: fill only
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 1));
        assert!(
            ex.phase_times.grouping_s > after_miss.grouping_s,
            "the hit path must charge its plan-validation (structure-hash) time to grouping_s"
        );
        // The symbolic phase was genuinely skipped: no new symbolic
        // seconds on the hit.
        assert_eq!(ex.phase_times.symbolic_s, after_miss.symbolic_s);
        assert!(ex.phase_times.numeric_s > after_miss.numeric_s, "the fill itself is still timed");
    }

    #[test]
    fn multiply_reusing_falls_back_for_esc_and_sim() {
        let a = crate::gen::rmat(128, 800, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(6));
        let mut esc = SpgemmExecutor::fast(Variant::Cusparse);
        let mut slot = None;
        let c = esc.multiply_reusing(&mut slot, &a, &a);
        assert!(slot.is_none(), "ESC path must not populate the plan slot");
        assert_eq!((esc.plan_hits, esc.plan_misses), (0, 0));
        assert!(c.approx_eq(&crate::spgemm::hash::multiply(&a, &a), 1e-10));
        let mut sim = SpgemmExecutor::simulated(Variant::HashAia);
        sim.multiply_reusing(&mut slot, &a, &a);
        assert!(slot.is_none(), "simulated path must not populate the plan slot");
        assert_eq!(sim.reports.len(), 1, "simulated path must still price the full kernel");
    }

    #[test]
    fn simulated_executor_accumulates_time() {
        let a = crate::gen::rmat(512, 4000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(2));
        let mut ex = SpgemmExecutor::simulated(Variant::HashAia);
        ex.multiply(&a, &a);
        ex.multiply(&a, &a);
        assert_eq!(ex.jobs, 2);
        assert_eq!(ex.reports.len(), 2);
        assert!(ex.sim_ms > 0.0);
        assert!(ex.total_ip > 0);
        assert!(ex.gflops() > 0.0);
    }
}
