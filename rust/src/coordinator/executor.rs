//! `SpgemmExecutor` — the bridge applications use to issue SpGEMM jobs.
//!
//! An executor pairs an engine choice with an optional machine
//! simulation and accumulates per-job simulated time, so iterative
//! applications (MCL, GNN training) can report end-to-end SpGEMM time
//! per variant exactly the way the paper's figures do (AIA / no-AIA /
//! cuSPARSE). Iterative callers whose operand structure repeats across
//! jobs use [`SpgemmExecutor::multiply_reusing`], which keeps an
//! `Arc<PlannedProduct>` slot alive across calls and skips the
//! grouping/symbolic phases whenever the structure is unchanged. Slot
//! misses consult the executor's tiered plan store when one is attached
//! (automatic once `--plan-cache` / `SPGEMM_AIA_PLAN_CACHE` configures
//! a directory): another call site, or another *process*, may already
//! have planned the structure — a validated disk hit skips the symbolic
//! phase too, charging only load+validate time. Hit, miss, and
//! disk-hit counts are accumulated and exported alongside the phase
//! timers.

use super::metrics::Metrics;
use crate::sim::probe::PhaseTimes;
use crate::sim::{simulate_spgemm, AiaMode, SimConfig, SimReport};
use crate::spgemm::hash::planstore::GetOutcome;
use crate::spgemm::hash::{
    EngineConfig, Mask, PlanFingerprint, PlanStore, PlannedProduct, PlannerPolicy, TieredStore,
};
use crate::spgemm::{hash, ip, spgemm, Algo};
use crate::sparse::Csr;
use std::sync::Arc;

/// The three system variants every experiment compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Hash engine + AIA near-HBM acceleration.
    HashAia,
    /// Hash engine, software only.
    Hash,
    /// ESC baseline ("cuSPARSE"), software only.
    Cusparse,
}

impl Variant {
    pub fn all() -> [Variant; 3] {
        [Variant::HashAia, Variant::Hash, Variant::Cusparse]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::HashAia => "hash+aia",
            Variant::Hash => "hash",
            Variant::Cusparse => "cusparse(esc)",
        }
    }

    pub fn algo(&self) -> Algo {
        match self {
            Variant::HashAia | Variant::Hash => Algo::Hash,
            Variant::Cusparse => Algo::Esc,
        }
    }

    pub fn aia(&self) -> AiaMode {
        match self {
            Variant::HashAia => AiaMode::On,
            _ => AiaMode::Off,
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "hash+aia" | "aia" => Some(Variant::HashAia),
            "hash" | "noaia" | "no-aia" => Some(Variant::Hash),
            "cusparse" | "esc" | "cusparse(esc)" => Some(Variant::Cusparse),
            _ => None,
        }
    }
}

/// Executes SpGEMM jobs for one variant, accumulating simulated time.
pub struct SpgemmExecutor {
    pub variant: Variant,
    /// `None` = functional only (no timing model).
    pub sim: Option<SimConfig>,
    /// Accumulated simulated GPU time across jobs, ms.
    pub sim_ms: f64,
    /// Accumulated intermediate products across jobs.
    pub total_ip: u64,
    pub jobs: usize,
    /// Reports per job (kept only when simulating).
    pub reports: Vec<SimReport>,
    /// Accumulated wall time per engine phase across functional Hash
    /// jobs (grouping/symbolic/numeric — zero for simulated executors
    /// and non-hash engines).
    pub phase_times: PhaseTimes,
    /// [`SpgemmExecutor::multiply_reusing`] jobs served by a cached plan
    /// (numeric phase only) — slot hits plus memory-tier store hits.
    pub plan_hits: usize,
    /// [`SpgemmExecutor::multiply_reusing`] jobs that had to (re)plan.
    pub plan_misses: usize,
    /// [`SpgemmExecutor::multiply_reusing`] jobs served by the plan
    /// store's *disk* tier (plan from an earlier process, validated —
    /// symbolic phase skipped across the process boundary).
    pub disk_hits: usize,
    /// [`SpgemmExecutor::multiply_reusing`] jobs served by patching the
    /// previous slot plan's dirty rows instead of a full replan
    /// ([`crate::spgemm::hash::delta_patch`]). Neither a hit nor a miss
    /// in [`SpgemmExecutor::plan_hit_rate`] — the symbolic phase ran,
    /// but only over the dirty rows.
    pub plan_deltas: usize,
    /// Rows whose symbolic phase re-ran across all delta-patched jobs
    /// (the dirty sets' total size).
    pub delta_rows: usize,
    /// Wall seconds spent building delta patches (the incremental
    /// counterpart of the full plans' `plan_times`).
    pub delta_plan_s: f64,
    /// One-shot [`SpgemmExecutor::multiply`] jobs served by the
    /// speculative estimated planner instead of the exact symbolic
    /// phase ([`crate::spgemm::hash::multiply_estimated`]).
    pub estimated_jobs: usize,
    /// Rows the speculative jobs grew-and-retried after detecting an
    /// underestimate.
    pub fallback_rows: usize,
    /// Wall seconds spent sampling + building speculative plans.
    pub estimate_s: f64,
    /// Planner policy for one-shot [`SpgemmExecutor::multiply`] jobs on
    /// the functional hash path. [`SpgemmExecutor::multiply_reusing`]
    /// always plans exactly — its plans persist in the slot and the
    /// store, so speculation has nothing to win there. Defaults to the
    /// process-wide policy (`--planner` / `SPGEMM_AIA_PLANNER`).
    pub planner: PlannerPolicy,
    /// Tiered plan store consulted on slot misses (and seeded on
    /// replans). `None` = slot-only reuse, the pre-persistence behavior.
    plan_store: Option<TieredStore>,
}

impl SpgemmExecutor {
    /// Functional-only executor (fast parallel path).
    pub fn fast(variant: Variant) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, None)
    }

    /// Executor with the machine simulation attached.
    pub fn simulated(variant: Variant) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, Some(SimConfig::new(variant.aia())))
    }

    /// Simulated executor whose device caches are scaled by the
    /// dataset's down-scaling factor (DESIGN.md §Hardware substitution).
    pub fn simulated_scaled(variant: Variant, scale: usize) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, Some(SimConfig::for_scale(variant.aia(), scale)))
    }

    fn with_sim(variant: Variant, sim: Option<SimConfig>) -> SpgemmExecutor {
        // Functional hash executors pick up the process-default disk
        // tier automatically (that is what makes `--plan-cache` reach
        // every CLI subcommand); simulated/ESC executors never reuse
        // plans, so they carry no store. Without a configured cache
        // directory this is `None` — the pre-persistence behavior.
        let plan_store = if sim.is_none() && variant.algo() == Algo::Hash {
            crate::spgemm::hash::default_plan_cache_dir().map(TieredStore::with_disk)
        } else {
            None
        };
        SpgemmExecutor {
            variant,
            sim,
            sim_ms: 0.0,
            total_ip: 0,
            jobs: 0,
            reports: Vec::new(),
            phase_times: PhaseTimes::default(),
            plan_hits: 0,
            plan_misses: 0,
            disk_hits: 0,
            plan_deltas: 0,
            delta_rows: 0,
            delta_plan_s: 0.0,
            estimated_jobs: 0,
            fallback_rows: 0,
            estimate_s: 0.0,
            planner: EngineConfig::default().planner,
            plan_store,
        }
    }

    /// Functional executor over an explicit, possibly *shared* plan
    /// store — [`TieredStore`] clones share tiers and counters, so the
    /// serve daemon (and anything else holding a clone) pools its plans
    /// with this executor instead of minting a private cache.
    pub fn with_plan_store(variant: Variant, store: TieredStore) -> SpgemmExecutor {
        let mut ex = SpgemmExecutor::fast(variant);
        ex.attach_plan_store(store);
        ex
    }

    /// Attach (or replace) the tiered plan store consulted by
    /// [`SpgemmExecutor::multiply_reusing`] slot misses — tests and
    /// benches pin their cache directories with this.
    pub fn attach_plan_store(&mut self, store: TieredStore) {
        self.plan_store = Some(store);
    }

    /// The attached plan store's counters, if any.
    pub fn plan_store_stats(&self) -> Option<crate::spgemm::hash::StoreStats> {
        self.plan_store.as_ref().map(|s| s.stats())
    }

    /// Run one SpGEMM job.
    pub fn multiply(&mut self, a: &Csr, b: &Csr) -> Csr {
        self.jobs += 1;
        match &self.sim {
            None => match self.variant.algo() {
                // A `multiply` call is exactly the cold one-shot shape
                // speculation targets: no slot, no store, the plan is
                // used once. Output is bit-identical either way.
                Algo::Hash if self.planner.speculates() => {
                    let (c, rep) = hash::multiply_estimated(a, b);
                    self.estimated_jobs += 1;
                    self.estimate_s += rep.estimate_s;
                    self.fallback_rows += rep.fallback_rows;
                    self.phase_times.numeric_s += rep.numeric_s;
                    c
                }
                Algo::Hash => {
                    let (c, pt) = hash::engine::multiply_timed(a, b);
                    self.phase_times.accumulate(&pt);
                    c
                }
                other => spgemm(other, a, b),
            },
            Some(cfg) => {
                self.total_ip += ip::total_ip(a, b);
                let (c, report) = simulate_spgemm(self.variant.algo(), a, b, cfg);
                self.sim_ms += report.total_ms;
                self.reports.push(report);
                c
            }
        }
    }

    /// Run one SpGEMM job with plan reuse: if `slot` holds a plan whose
    /// structure fingerprints match `(a, b)`, only the numeric phase
    /// runs; otherwise the job consults the attached plan store (another
    /// slot or an earlier process may have planned this structure —
    /// memory tier first, then the validated disk tier) and only replans
    /// when the store misses too, seeding both the slot and the store
    /// with the new plan. Output is bit-identical to
    /// [`SpgemmExecutor::multiply`] on every path.
    ///
    /// Only the functional hash path reuses plans — simulated executors
    /// and the ESC baseline fall through to [`SpgemmExecutor::multiply`]
    /// (the machine model prices the full kernel regardless, and ESC has
    /// no symbolic plan), leaving the hit/miss counters untouched.
    pub fn multiply_reusing(&mut self, slot: &mut Option<Arc<PlannedProduct>>, a: &Csr, b: &Csr) -> Csr {
        self.multiply_reusing_inner(slot, a, b, None)
    }

    /// Masked plan reuse: `C = mask ⊙ (A·B)` with the slot/store
    /// machinery of [`SpgemmExecutor::multiply_reusing`]. The mask's
    /// structure hash is part of the plan's identity, so a slot or
    /// store plan is only reused when operands *and* mask are
    /// unchanged; an unmasked plan never serves a masked job (or vice
    /// versa — the plain path refuses masked slot plans too). Only the
    /// functional hash path supports masks; other variants compute the
    /// full product and filter, which is the definitional oracle.
    pub fn multiply_reusing_masked(
        &mut self,
        slot: &mut Option<Arc<PlannedProduct>>,
        a: &Csr,
        b: &Csr,
        mask: &Mask,
    ) -> Csr {
        assert_eq!(mask.shape(), (a.n_rows, b.n_cols), "mask shape must equal the output shape");
        if self.sim.is_some() || self.variant.algo() != Algo::Hash {
            return mask.filter(&self.multiply(a, b));
        }
        self.multiply_reusing_inner(slot, a, b, Some(mask))
    }

    fn multiply_reusing_inner(
        &mut self,
        slot: &mut Option<Arc<PlannedProduct>>,
        a: &Csr,
        b: &Csr,
        mask: Option<&Mask>,
    ) -> Csr {
        if self.sim.is_some() || self.variant.algo() != Algo::Hash {
            return self.multiply(a, b);
        }
        self.jobs += 1;
        let mask_hash = mask.map(|m| m.structure_hash());
        let t_validate = std::time::Instant::now();
        let reuse = slot.as_ref().is_some_and(|p| p.matches(a, b) && p.mask_hash() == mask_hash);
        // Plan validation reads both operands' (memoized) structure
        // hashes — the O(nnz) scan is charged exactly once, on the call
        // that first computes it; later validations are cell reads.
        // Either way the elapsed resolution time lands in the grouping
        // slot so a reused job's grouping_s is the real validation cost
        // rather than a defaulted 0 and the reported plan-reuse saving
        // is not overstated (the symbolic phase is the part reuse
        // genuinely skips, so symbolic_s stays 0 on hits).
        // Regression-pinned by `reused_jobs_charge_plan_validation_time`
        // and `memoized_validation_charges_first_computation_only`.
        if reuse {
            self.plan_hits += 1;
            self.phase_times.grouping_s += t_validate.elapsed().as_secs_f64();
        } else {
            // Slot miss: try the tiered store before paying the
            // symbolic phase. The displaced plan is kept as the delta
            // baseline — if the store misses too, a same-shape mutation
            // of the previous structure replans only its dirty rows.
            let prior = slot.clone();
            let fp = match mask {
                None => PlanFingerprint::of(a, b),
                Some(m) => PlanFingerprint::of_masked(a, b, m),
            };
            let mut from_store = None;
            if let Some(store) = self.plan_store.as_mut() {
                let (found, outcome) = store.get_traced(&fp);
                if found.is_some() {
                    match outcome {
                        GetOutcome::DiskHit => self.disk_hits += 1,
                        _ => self.plan_hits += 1,
                    }
                }
                from_store = found;
            }
            match from_store {
                Some(p) => {
                    // Store hit (possibly a disk load): operand-analysis
                    // work, charged to grouping; the symbolic phase was
                    // skipped, so symbolic_s stays 0.
                    self.phase_times.grouping_s += t_validate.elapsed().as_secs_f64();
                    *slot = Some(p);
                }
                None => {
                    self.phase_times.grouping_s += t_validate.elapsed().as_secs_f64();
                    let cfg = EngineConfig { mask: mask.cloned(), ..EngineConfig::default() };
                    // Dirty-row replanning: patch the displaced plan in
                    // place when the new operands are a small structural
                    // drift of its baseline; fall through to a full
                    // replan on any rebuild verdict.
                    let patched = prior.as_deref().and_then(|base| match hash::delta_patch(base, a, b, &cfg) {
                        hash::DeltaOutcome::Patched(dp) => Some(dp),
                        hash::DeltaOutcome::Rebuild(_) => None,
                    });
                    let p = match patched {
                        Some(dp) => {
                            let p = Arc::new(dp.plan);
                            self.plan_deltas += 1;
                            self.delta_rows += dp.dirty_rows;
                            self.delta_plan_s += p.plan_times.total_s();
                            if let Some(store) = self.plan_store.as_mut() {
                                store.note_delta_patch();
                            }
                            p
                        }
                        None => {
                            let p = Arc::new(PlannedProduct::plan_cfg_hashed(a, b, &cfg, fp.a_hash, fp.b_hash));
                            self.plan_misses += 1;
                            p
                        }
                    };
                    self.phase_times.accumulate(&p.plan_times);
                    if let Some(store) = self.plan_store.as_mut() {
                        store.put(Arc::clone(&p));
                    }
                    *slot = Some(p);
                }
            }
        }
        let p = slot.as_ref().expect("slot was just filled on miss");
        // Unchecked: hits were validated by `matches` above (store hits
        // by the store's fingerprint check); misses hold a plan built
        // from these exact operands.
        let (c, fill_times) = p.fill_unchecked_timed(a, b);
        // Only the numeric fields are populated (incl. the per-kind split).
        self.phase_times.accumulate(&fill_times);
        c
    }

    /// Fraction of [`SpgemmExecutor::multiply_reusing`] jobs served from
    /// a cached plan — slot/memory hits and disk hits both count; 0 when
    /// no reusing jobs ran. Delta-patched jobs are *excluded* from both
    /// numerator and denominator: they neither reused a plan verbatim
    /// nor paid a full replan, and folding them into either side would
    /// skew the rate (pinned by the `delta_patches` regression tests).
    pub fn plan_hit_rate(&self) -> f64 {
        let hits = self.plan_hits + self.disk_hits;
        let total = hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Aggregate GFLOPS over all jobs so far (paper's metric).
    pub fn gflops(&self) -> f64 {
        crate::sim::gflops(self.total_ip, self.sim_ms)
    }

    /// Export accumulated counters into a [`Metrics`] registry under
    /// `spgemm.<variant>.*` (jobs, simulated ms, per-phase wall times).
    pub fn export_metrics(&self, m: &mut Metrics) {
        let prefix = format!("spgemm.{}", self.variant.name());
        m.inc(&format!("{prefix}.jobs"), self.jobs as u64);
        m.inc(&format!("{prefix}.plan_hits"), self.plan_hits as u64);
        m.inc(&format!("{prefix}.plan_misses"), self.plan_misses as u64);
        m.inc(&format!("{prefix}.disk_hits"), self.disk_hits as u64);
        m.inc(&format!("{prefix}.plan_deltas"), self.plan_deltas as u64);
        m.inc(&format!("{prefix}.delta_rows"), self.delta_rows as u64);
        m.gauge(&format!("{prefix}.delta_plan_s"), self.delta_plan_s);
        m.inc(&format!("{prefix}.estimated_jobs"), self.estimated_jobs as u64);
        m.inc(&format!("{prefix}.fallback_rows"), self.fallback_rows as u64);
        m.gauge(&format!("{prefix}.estimate_s"), self.estimate_s);
        if let Some(ss) = self.plan_store_stats() {
            m.observe_store_stats(&format!("{prefix}.store"), &ss);
        }
        m.gauge(&format!("{prefix}.sim_ms"), self.sim_ms);
        // Simulated executors also export the byte-accurate line
        // utilization of every job's report (used/fetched HBM bytes and
        // the cumulative waste-ratio gauge).
        for rep in &self.reports {
            m.observe_sim_waste(&format!("{prefix}.waste"), rep);
        }
        m.observe_phase_times(&prefix, &self.phase_times);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn variant_table() {
        assert_eq!(Variant::HashAia.algo(), Algo::Hash);
        assert_eq!(Variant::HashAia.aia(), AiaMode::On);
        assert_eq!(Variant::Cusparse.algo(), Algo::Esc);
        assert_eq!(Variant::parse("AIA"), Some(Variant::HashAia));
        assert_eq!(Variant::parse("esc"), Some(Variant::Cusparse));
        assert_eq!(Variant::parse("x"), None);
    }

    #[test]
    fn fast_executor_runs_without_sim() {
        let a = crate::gen::rmat(256, 2000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(1));
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let c = ex.multiply(&a, &a);
        assert_eq!(ex.jobs, 1);
        assert_eq!(ex.sim_ms, 0.0);
        assert!(c.nnz() > 0);
        // the fast hash path reports distinct per-phase wall times...
        assert!(ex.phase_times.total_s() > 0.0);
        // ...and they export into the metrics registry
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.jobs"), 1);
        assert!(m.timer_total("spgemm.hash.numeric") >= 0.0);
    }

    /// Executor pinned to a memory-only store: the count-asserting
    /// tests below must not inherit a disk tier from a
    /// `SPGEMM_AIA_PLAN_CACHE` env var leaking in from the developer's
    /// shell (warm plan files would turn misses into disk hits on the
    /// second `cargo test` run). Disk-tier behavior is covered by
    /// `tests/plan_store.rs` with pinned directories.
    fn mem_pinned(variant: Variant) -> SpgemmExecutor {
        let mut ex = SpgemmExecutor::fast(variant);
        ex.attach_plan_store(TieredStore::mem_only());
        ex
    }

    #[test]
    fn multiply_reusing_hits_on_stable_structure() {
        let a = crate::gen::rmat(192, 1200, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(4));
        let mut ex = mem_pinned(Variant::Hash);
        let mut slot = None;
        let c1 = ex.multiply_reusing(&mut slot, &a, &a);
        assert_eq!((ex.plan_hits, ex.plan_misses), (0, 1));
        // Same structure, new values: plan must be reused and exact.
        let mut a2 = a.clone();
        a2.map_values(|v| v + 1.0);
        let c2 = ex.multiply_reusing(&mut slot, &a2, &a2);
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 1));
        assert_eq!(c2, crate::spgemm::hash::multiply(&a2, &a2));
        assert_ne!(c1, c2);
        assert_eq!(ex.jobs, 2);
        assert!((ex.plan_hit_rate() - 0.5).abs() < 1e-12);
        // Structural change replans into the same slot.
        let b = crate::gen::rmat(192, 1400, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(5));
        let c3 = ex.multiply_reusing(&mut slot, &b, &b);
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 2));
        assert_eq!(c3, crate::spgemm::hash::multiply(&b, &b));
        // Counters export into the metrics registry.
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.plan_hits"), 1);
        assert_eq!(m.counter("spgemm.hash.plan_misses"), 2);
    }

    /// Regression: the `multiply_reusing` hit path used to leave
    /// `grouping_s` at its defaulted 0 even though validating the plan
    /// reads both operands' structure fingerprints (an O(nnz) scan on
    /// first touch, a memo read after) — phase totals reported reuse's
    /// operand analysis as free, overstating the plan-reuse saving.
    #[test]
    fn reused_jobs_charge_plan_validation_time() {
        // Large enough that two structure hashes take measurable time.
        let a = crate::gen::rmat(4096, 40_000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(9));
        let mut ex = mem_pinned(Variant::Hash);
        let mut slot = None;
        ex.multiply_reusing(&mut slot, &a, &a); // miss: plans
        let after_miss = ex.phase_times;
        assert!(after_miss.grouping_s > 0.0 && after_miss.symbolic_s > 0.0);
        ex.multiply_reusing(&mut slot, &a, &a); // hit: fill only
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 1));
        assert!(
            ex.phase_times.grouping_s > after_miss.grouping_s,
            "the hit path must charge its plan-validation (structure-hash) time to grouping_s"
        );
        // The symbolic phase was genuinely skipped: no new symbolic
        // seconds on the hit.
        assert_eq!(ex.phase_times.symbolic_s, after_miss.symbolic_s);
        assert!(ex.phase_times.numeric_s > after_miss.numeric_s, "the fill itself is still timed");
    }

    /// Regression for the `Csr::structure_hash` memoization: hot reuse
    /// paths must stop paying O(nnz) per validation. The plan miss
    /// computes (and charges) both operand hashes once; every later
    /// hit's validation is a memo read, so its charged grouping time
    /// must undercut even a single cold structure scan.
    #[test]
    fn memoized_validation_charges_first_computation_only() {
        let a = crate::gen::rmat(4096, 40_000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(17));
        let mut ex = mem_pinned(Variant::Hash);
        let mut slot = None;
        ex.multiply_reusing(&mut slot, &a, &a); // miss: plans, memoizes the hash
        assert_eq!(a.cached_structure_hash(), Some(a.structure_hash()), "the miss must warm the memo");
        let after_miss = ex.phase_times.grouping_s;
        // Cold-hash baseline on an identical matrix with an empty memo
        // (a plain clone would inherit the memo).
        let fresh = crate::sparse::Csr::new_unchecked(a.n_rows, a.n_cols, a.rpt.clone(), a.col.clone(), a.val.clone());
        assert_eq!(fresh.cached_structure_hash(), None);
        let t0 = std::time::Instant::now();
        assert_eq!(fresh.structure_hash(), a.structure_hash());
        let cold_hash_s = t0.elapsed().as_secs_f64();
        ex.multiply_reusing(&mut slot, &a, &a); // hit: memoized validation
        let hit_validation_s = ex.phase_times.grouping_s - after_miss;
        assert!(hit_validation_s > 0.0, "validation is still timed, honestly");
        assert!(
            hit_validation_s < cold_hash_s,
            "memoized validation ({hit_validation_s:.9}s) must undercut one cold O(nnz) hash ({cold_hash_s:.9}s)"
        );
    }

    /// A small structural drift of the previous structure must route
    /// through the dirty-row delta planner: exact output, a lineage-
    /// carrying slot plan, and counters that treat the job as neither a
    /// plan hit nor a full replan.
    #[test]
    fn multiply_reusing_patches_small_structural_drift() {
        let a = crate::gen::rmat(256, 2000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(21));
        let mut ex = mem_pinned(Variant::Hash);
        let mut slot = None;
        ex.multiply_reusing(&mut slot, &a, &a); // cold: full replan
        let a2 = crate::spgemm::hash::mutate_row_fraction(&a, 0.02, 7);
        let c2 = ex.multiply_reusing(&mut slot, &a2, &a2); // drift: delta patch
        assert_eq!((ex.plan_hits, ex.plan_misses, ex.plan_deltas), (0, 1, 1));
        assert!(ex.delta_rows > 0 && ex.delta_rows < a.n_rows, "only dirty rows replanned");
        assert!(ex.delta_plan_s > 0.0, "the patch's plan time is charged, honestly");
        assert_eq!(c2, crate::spgemm::hash::multiply(&a2, &a2), "patched fill must be exact");
        let p = slot.as_ref().expect("slot holds the patched plan");
        assert_eq!(p.delta().expect("patched plan carries lineage").chain_len, 1);
        // The delta job is excluded from the hit rate (0 hits, 1 miss).
        assert_eq!(ex.plan_hit_rate(), 0.0);
        // Re-running the mutated structure is a plain slot hit.
        ex.multiply_reusing(&mut slot, &a2, &a2);
        assert_eq!(ex.plan_hits, 1);
        // An unrelated same-shape structure rebuilds instead of patching.
        let b = crate::gen::rmat(256, 2600, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(22));
        let cb = ex.multiply_reusing(&mut slot, &b, &b);
        assert_eq!((ex.plan_misses, ex.plan_deltas), (2, 1));
        assert_eq!(cb, crate::spgemm::hash::multiply(&b, &b));
        // Store counters agree: one patch, neither hit nor miss there.
        let ss = ex.plan_store_stats().expect("mem-pinned store");
        assert_eq!(ss.delta_patches, 1);
        assert_eq!(ss.hits(), 0, "delta patches must not inflate store hits");
        // And the new counters export.
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.plan_deltas"), 1);
        assert_eq!(m.counter("spgemm.hash.delta_rows"), ex.delta_rows as u64);
    }

    /// The estimated policy reroutes one-shot `multiply` jobs through
    /// the speculative planner — bit-identically — while
    /// `multiply_reusing` keeps planning exactly (its plans are reused,
    /// so speculation has nothing to win).
    #[test]
    fn estimated_policy_covers_one_shot_jobs_only() {
        let a = crate::gen::rmat(256, 2000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(33));
        let mut ex = mem_pinned(Variant::Hash);
        ex.planner = PlannerPolicy::Estimated;
        let c = ex.multiply(&a, &a);
        assert_eq!(c, crate::spgemm::hash::multiply(&a, &a), "speculative one-shot must be bit-identical");
        assert_eq!(ex.estimated_jobs, 1);
        assert!(ex.estimate_s > 0.0, "sampling time is charged, honestly");
        let mut slot = None;
        ex.multiply_reusing(&mut slot, &a, &a);
        ex.multiply_reusing(&mut slot, &a, &a);
        assert_eq!(ex.estimated_jobs, 1, "multiply_reusing must not speculate");
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 1));
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.estimated_jobs"), 1);
        assert_eq!(m.counter("spgemm.hash.jobs"), 3);
    }

    #[test]
    fn masked_reuse_is_keyed_by_the_mask_too() {
        let a = crate::gen::rmat(192, 1200, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(47));
        let mask = Mask::from_structure(&a);
        let oracle = mask.filter(&crate::spgemm::hash::multiply(&a, &a));
        let mut ex = mem_pinned(Variant::Hash);
        let mut slot = None;
        let c1 = ex.multiply_reusing_masked(&mut slot, &a, &a, &mask);
        assert_eq!(c1, oracle, "masked reuse path must equal the filtered oracle");
        assert_eq!((ex.plan_hits, ex.plan_misses), (0, 1));
        assert_eq!(slot.as_ref().unwrap().mask_hash(), Some(mask.structure_hash()));
        // Identical operands + identical mask: a slot hit.
        let c2 = ex.multiply_reusing_masked(&mut slot, &a, &a, &mask);
        assert_eq!(c2, oracle);
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 1));
        // The *unmasked* job must refuse the masked slot plan — same
        // operands, different identity — and serve the full product.
        let c3 = ex.multiply_reusing(&mut slot, &a, &a);
        assert_eq!(c3, crate::spgemm::hash::multiply(&a, &a));
        assert_eq!((ex.plan_hits, ex.plan_misses), (1, 2));
        assert!(slot.as_ref().unwrap().mask_hash().is_none(), "slot now holds the unmasked plan");
        // Masked again: the slot mismatches but the store still holds
        // the masked plan under its own key — a store hit, not a replan.
        let c4 = ex.multiply_reusing_masked(&mut slot, &a, &a, &mask);
        assert_eq!(c4, oracle);
        assert_eq!((ex.plan_hits, ex.plan_misses), (2, 2));
        // ESC executors have no masked kernels: they filter the full
        // product, which is the oracle by definition.
        let mut esc = SpgemmExecutor::fast(Variant::Cusparse);
        let mut esc_slot = None;
        let ce = esc.multiply_reusing_masked(&mut esc_slot, &a, &a, &mask);
        assert!(ce.approx_eq(&oracle, 1e-10));
        assert!(esc_slot.is_none());
    }

    #[test]
    fn multiply_reusing_falls_back_for_esc_and_sim() {
        let a = crate::gen::rmat(128, 800, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(6));
        let mut esc = SpgemmExecutor::fast(Variant::Cusparse);
        let mut slot = None;
        let c = esc.multiply_reusing(&mut slot, &a, &a);
        assert!(slot.is_none(), "ESC path must not populate the plan slot");
        assert_eq!((esc.plan_hits, esc.plan_misses), (0, 0));
        assert!(c.approx_eq(&crate::spgemm::hash::multiply(&a, &a), 1e-10));
        let mut sim = SpgemmExecutor::simulated(Variant::HashAia);
        sim.multiply_reusing(&mut slot, &a, &a);
        assert!(slot.is_none(), "simulated path must not populate the plan slot");
        assert_eq!(sim.reports.len(), 1, "simulated path must still price the full kernel");
    }

    #[test]
    fn simulated_executor_accumulates_time() {
        let a = crate::gen::rmat(512, 4000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(2));
        let mut ex = SpgemmExecutor::simulated(Variant::HashAia);
        ex.multiply(&a, &a);
        ex.multiply(&a, &a);
        assert_eq!(ex.jobs, 2);
        assert_eq!(ex.reports.len(), 2);
        assert!(ex.sim_ms > 0.0);
        assert!(ex.total_ip > 0);
        assert!(ex.gflops() > 0.0);
        // Waste accounting of both jobs' reports lands in the registry.
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        let used = m.counter("spgemm.hash+aia.waste.used_bytes");
        let fetched = m.counter("spgemm.hash+aia.waste.fetched_bytes");
        assert!(fetched > 0, "simulated jobs must export fetched bytes");
        assert!(used > 0 && used <= fetched);
    }
}
