//! `SpgemmExecutor` — the bridge applications use to issue SpGEMM jobs.
//!
//! An executor pairs an engine choice with an optional machine
//! simulation and accumulates per-job simulated time, so iterative
//! applications (MCL, GNN training) can report end-to-end SpGEMM time
//! per variant exactly the way the paper's figures do (AIA / no-AIA /
//! cuSPARSE).

use super::metrics::Metrics;
use crate::sim::probe::PhaseTimes;
use crate::sim::{simulate_spgemm, AiaMode, SimConfig, SimReport};
use crate::spgemm::{hash, ip, spgemm, Algo};
use crate::sparse::Csr;

/// The three system variants every experiment compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Hash engine + AIA near-HBM acceleration.
    HashAia,
    /// Hash engine, software only.
    Hash,
    /// ESC baseline ("cuSPARSE"), software only.
    Cusparse,
}

impl Variant {
    pub fn all() -> [Variant; 3] {
        [Variant::HashAia, Variant::Hash, Variant::Cusparse]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::HashAia => "hash+aia",
            Variant::Hash => "hash",
            Variant::Cusparse => "cusparse(esc)",
        }
    }

    pub fn algo(&self) -> Algo {
        match self {
            Variant::HashAia | Variant::Hash => Algo::Hash,
            Variant::Cusparse => Algo::Esc,
        }
    }

    pub fn aia(&self) -> AiaMode {
        match self {
            Variant::HashAia => AiaMode::On,
            _ => AiaMode::Off,
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "hash+aia" | "aia" => Some(Variant::HashAia),
            "hash" | "noaia" | "no-aia" => Some(Variant::Hash),
            "cusparse" | "esc" | "cusparse(esc)" => Some(Variant::Cusparse),
            _ => None,
        }
    }
}

/// Executes SpGEMM jobs for one variant, accumulating simulated time.
pub struct SpgemmExecutor {
    pub variant: Variant,
    /// `None` = functional only (no timing model).
    pub sim: Option<SimConfig>,
    /// Accumulated simulated GPU time across jobs, ms.
    pub sim_ms: f64,
    /// Accumulated intermediate products across jobs.
    pub total_ip: u64,
    pub jobs: usize,
    /// Reports per job (kept only when simulating).
    pub reports: Vec<SimReport>,
    /// Accumulated wall time per engine phase across functional Hash
    /// jobs (grouping/symbolic/numeric — zero for simulated executors
    /// and non-hash engines).
    pub phase_times: PhaseTimes,
}

impl SpgemmExecutor {
    /// Functional-only executor (fast parallel path).
    pub fn fast(variant: Variant) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, None)
    }

    /// Executor with the machine simulation attached.
    pub fn simulated(variant: Variant) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, Some(SimConfig::new(variant.aia())))
    }

    /// Simulated executor whose device caches are scaled by the
    /// dataset's down-scaling factor (DESIGN.md §Hardware substitution).
    pub fn simulated_scaled(variant: Variant, scale: usize) -> SpgemmExecutor {
        SpgemmExecutor::with_sim(variant, Some(SimConfig::for_scale(variant.aia(), scale)))
    }

    fn with_sim(variant: Variant, sim: Option<SimConfig>) -> SpgemmExecutor {
        SpgemmExecutor {
            variant,
            sim,
            sim_ms: 0.0,
            total_ip: 0,
            jobs: 0,
            reports: Vec::new(),
            phase_times: PhaseTimes::default(),
        }
    }

    /// Run one SpGEMM job.
    pub fn multiply(&mut self, a: &Csr, b: &Csr) -> Csr {
        self.jobs += 1;
        match &self.sim {
            None => match self.variant.algo() {
                Algo::Hash => {
                    let (c, pt) = hash::engine::multiply_timed(a, b);
                    self.phase_times.accumulate(&pt);
                    c
                }
                other => spgemm(other, a, b),
            },
            Some(cfg) => {
                self.total_ip += ip::total_ip(a, b);
                let (c, report) = simulate_spgemm(self.variant.algo(), a, b, cfg);
                self.sim_ms += report.total_ms;
                self.reports.push(report);
                c
            }
        }
    }

    /// Aggregate GFLOPS over all jobs so far (paper's metric).
    pub fn gflops(&self) -> f64 {
        crate::sim::gflops(self.total_ip, self.sim_ms)
    }

    /// Export accumulated counters into a [`Metrics`] registry under
    /// `spgemm.<variant>.*` (jobs, simulated ms, per-phase wall times).
    pub fn export_metrics(&self, m: &mut Metrics) {
        let prefix = format!("spgemm.{}", self.variant.name());
        m.inc(&format!("{prefix}.jobs"), self.jobs as u64);
        m.gauge(&format!("{prefix}.sim_ms"), self.sim_ms);
        m.observe_phase_times(&prefix, &self.phase_times);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn variant_table() {
        assert_eq!(Variant::HashAia.algo(), Algo::Hash);
        assert_eq!(Variant::HashAia.aia(), AiaMode::On);
        assert_eq!(Variant::Cusparse.algo(), Algo::Esc);
        assert_eq!(Variant::parse("AIA"), Some(Variant::HashAia));
        assert_eq!(Variant::parse("esc"), Some(Variant::Cusparse));
        assert_eq!(Variant::parse("x"), None);
    }

    #[test]
    fn fast_executor_runs_without_sim() {
        let a = crate::gen::rmat(256, 2000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(1));
        let mut ex = SpgemmExecutor::fast(Variant::Hash);
        let c = ex.multiply(&a, &a);
        assert_eq!(ex.jobs, 1);
        assert_eq!(ex.sim_ms, 0.0);
        assert!(c.nnz() > 0);
        // the fast hash path reports distinct per-phase wall times...
        assert!(ex.phase_times.total_s() > 0.0);
        // ...and they export into the metrics registry
        let mut m = Metrics::new();
        ex.export_metrics(&mut m);
        assert_eq!(m.counter("spgemm.hash.jobs"), 1);
        assert!(m.timer_total("spgemm.hash.numeric") >= 0.0);
    }

    #[test]
    fn simulated_executor_accumulates_time() {
        let a = crate::gen::rmat(512, 4000, crate::gen::RmatParams::uniform(), &mut Pcg32::seeded(2));
        let mut ex = SpgemmExecutor::simulated(Variant::HashAia);
        ex.multiply(&a, &a);
        ex.multiply(&a, &a);
        assert_eq!(ex.jobs, 2);
        assert_eq!(ex.reports.len(), 2);
        assert!(ex.sim_ms > 0.0);
        assert!(ex.total_ip > 0);
        assert!(ex.gflops() > 0.0);
    }
}
